//! Bootstrap particle filter (sequential Monte Carlo) — the paper's §1
//! motivating application family (Doucet et al.; Murray's GPU particle
//! filters [13, 14]). Random numbers are drawn from the coordinator
//! service, exactly as a GPU-resident SMC would consume the generator's
//! output buffers.
//!
//!   cargo run --release --example particle_filter
//!
//! Model: 1-D nonlinear state space (the classic SMC benchmark)
//!   x_t = x_{t-1}/2 + 25 x_{t-1}/(1+x_{t-1}^2) + 8 cos(1.2 t) + w,  w~N(0,10)
//!   y_t = x_t^2/20 + v,                                             v~N(0,1)
//! Reports the filter's RMSE against the simulated truth and the RNG
//! service statistics.

use xorgens_gp::coordinator::{Coordinator, CoordinatorConfig, TypedStream};

/// Chunked reader over a typed normal stream: one fixed buffer, refilled
/// in place via `draw_into` (the reply buffer is pooled and recycled — the
/// steady state allocates nothing).
struct Rng<'a> {
    stream: TypedStream<'a, f32>,
    buf: Vec<f32>,
    pos: usize,
}

impl<'a> Rng<'a> {
    fn new(stream: TypedStream<'a, f32>) -> Rng<'a> {
        let buf = vec![0.0f32; 65536];
        let pos = buf.len(); // drained: first call refills
        Rng { stream, buf, pos }
    }

    fn normal(&mut self) -> f64 {
        if self.pos == self.buf.len() {
            self.stream.draw_into(&mut self.buf).expect("draw");
            self.pos = 0;
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v as f64
    }
}

fn transition(x: f64, t: usize) -> f64 {
    x / 2.0 + 25.0 * x / (1.0 + x * x) + 8.0 * (1.2 * t as f64).cos()
}

fn main() {
    let n_particles = 4096;
    let steps = 200;
    let coord = Coordinator::new(CoordinatorConfig::default());
    // Typed handles: `.normal()` / `.uniform()` fix transform AND element
    // type — drawing these streams as u32 would not compile.
    let mut rng = Rng::new(coord.builder("pf-normals").normal().expect("stream"));

    // Simulate ground truth + observations.
    let mut truth = vec![0.0f64; steps];
    let mut obs = vec![0.0f64; steps];
    let mut x = 0.1;
    for t in 0..steps {
        x = transition(x, t) + rng.normal() * 10f64.sqrt();
        truth[t] = x;
        obs[t] = x * x / 20.0 + rng.normal();
    }

    // Bootstrap particle filter.
    let mut particles: Vec<f64> = (0..n_particles).map(|_| rng.normal() * 2.0).collect();
    let mut weights = vec![1.0 / n_particles as f64; n_particles];
    let mut estimates = vec![0.0f64; steps];
    let resample_uniforms = coord.builder("pf-uniforms").uniform().expect("stream");

    for t in 0..steps {
        // Propagate.
        for p in particles.iter_mut() {
            *p = transition(*p, t) + rng.normal() * 10f64.sqrt();
        }
        // Weight by observation likelihood.
        let mut sum = 0.0;
        for (p, w) in particles.iter().zip(weights.iter_mut()) {
            let pred = p * p / 20.0;
            let d = obs[t] - pred;
            *w = (-0.5 * d * d).exp() + 1e-300;
            sum += *w;
        }
        for w in weights.iter_mut() {
            *w /= sum;
        }
        estimates[t] = particles.iter().zip(&weights).map(|(p, w)| p * w).sum();
        // Systematic resampling (one uniform from the service).
        let u0 = resample_uniforms.draw(1).expect("draw")[0] as f64 / n_particles as f64;
        let mut new_particles = Vec::with_capacity(n_particles);
        let mut cum = 0.0;
        let mut i = 0;
        for k in 0..n_particles {
            let target = u0 + k as f64 / n_particles as f64;
            while cum + weights[i] < target && i < n_particles - 1 {
                cum += weights[i];
                i += 1;
            }
            new_particles.push(particles[i]);
        }
        particles = new_particles;
        weights.fill(1.0 / n_particles as f64);
    }

    // |x| is what the filter can know (y depends on x^2): report RMSE of |x|.
    let rmse: f64 = (truth
        .iter()
        .zip(&estimates)
        .map(|(t, e)| (t.abs() - e.abs()).powi(2))
        .sum::<f64>()
        / steps as f64)
        .sqrt();
    let scale =
        (truth.iter().map(|t| t * t).sum::<f64>() / steps as f64).sqrt();
    println!("particle filter: {n_particles} particles, {steps} steps");
    println!("RMSE(|x|) = {rmse:.3} (signal RMS {scale:.3})");
    println!("rng service: {}", coord.metrics().render());
    assert!(rmse < 0.6 * scale, "filter diverged: RMSE {rmse} vs scale {scale}");
    coord.shutdown();
}

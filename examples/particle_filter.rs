//! Bootstrap particle filter (sequential Monte Carlo) — the paper's §1
//! motivating application family (Doucet et al.; Murray's GPU particle
//! filters [13, 14]). Random numbers are drawn from the coordinator
//! service, exactly as a GPU-resident SMC would consume the generator's
//! output buffers.
//!
//!   cargo run --release --example particle_filter
//!
//! Model: 1-D nonlinear state space (the classic SMC benchmark)
//!   x_t = x_{t-1}/2 + 25 x_{t-1}/(1+x_{t-1}^2) + 8 cos(1.2 t) + w,  w~N(0,10)
//!   y_t = x_t^2/20 + v,                                             v~N(0,1)
//! Reports the filter's RMSE against the simulated truth and the RNG
//! service statistics.

use xorgens_gp::coordinator::{Coordinator, CoordinatorConfig, StreamConfig};
use xorgens_gp::runtime::Transform;

struct Rng<'a> {
    coord: &'a Coordinator,
    stream: xorgens_gp::coordinator::StreamId,
    buf: Vec<f32>,
    pos: usize,
}

impl Rng<'_> {
    fn normal(&mut self) -> f64 {
        if self.pos == self.buf.len() {
            self.buf = self.coord.draw_f32(self.stream, 65536).expect("draw");
            self.pos = 0;
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v as f64
    }
}

fn transition(x: f64, t: usize) -> f64 {
    x / 2.0 + 25.0 * x / (1.0 + x * x) + 8.0 * (1.2 * t as f64).cos()
}

fn main() {
    let n_particles = 4096;
    let steps = 200;
    let coord = Coordinator::new(CoordinatorConfig::default());
    let stream = coord.stream(
        "pf-normals",
        StreamConfig { transform: Transform::Normal, ..Default::default() },
    );
    let mut rng = Rng { coord: &coord, stream, buf: Vec::new(), pos: 0 };

    // Simulate ground truth + observations.
    let mut truth = vec![0.0f64; steps];
    let mut obs = vec![0.0f64; steps];
    let mut x = 0.1;
    for t in 0..steps {
        x = transition(x, t) + rng.normal() * 10f64.sqrt();
        truth[t] = x;
        obs[t] = x * x / 20.0 + rng.normal();
    }

    // Bootstrap particle filter.
    let mut particles: Vec<f64> = (0..n_particles).map(|_| rng.normal() * 2.0).collect();
    let mut weights = vec![1.0 / n_particles as f64; n_particles];
    let mut estimates = vec![0.0f64; steps];
    let mut uniforms_for_resample = {
        let s = coord.stream(
            "pf-uniforms",
            StreamConfig { transform: Transform::F32, ..Default::default() },
        );
        move |coordr: &Coordinator, n: usize| coordr.draw_f32(s, n).expect("draw")
    };

    for t in 0..steps {
        // Propagate.
        for p in particles.iter_mut() {
            *p = transition(*p, t) + rng.normal() * 10f64.sqrt();
        }
        // Weight by observation likelihood.
        let mut sum = 0.0;
        for (p, w) in particles.iter().zip(weights.iter_mut()) {
            let pred = p * p / 20.0;
            let d = obs[t] - pred;
            *w = (-0.5 * d * d).exp() + 1e-300;
            sum += *w;
        }
        for w in weights.iter_mut() {
            *w /= sum;
        }
        estimates[t] = particles.iter().zip(&weights).map(|(p, w)| p * w).sum();
        // Systematic resampling (one uniform from the service).
        let u0 = uniforms_for_resample(&coord, 1)[0] as f64 / n_particles as f64;
        let mut new_particles = Vec::with_capacity(n_particles);
        let mut cum = 0.0;
        let mut i = 0;
        for k in 0..n_particles {
            let target = u0 + k as f64 / n_particles as f64;
            while cum + weights[i] < target && i < n_particles - 1 {
                cum += weights[i];
                i += 1;
            }
            new_particles.push(particles[i]);
        }
        particles = new_particles;
        weights.fill(1.0 / n_particles as f64);
    }

    // |x| is what the filter can know (y depends on x^2): report RMSE of |x|.
    let rmse: f64 = (truth
        .iter()
        .zip(&estimates)
        .map(|(t, e)| (t.abs() - e.abs()).powi(2))
        .sum::<f64>()
        / steps as f64)
        .sqrt();
    let scale =
        (truth.iter().map(|t| t * t).sum::<f64>() / steps as f64).sqrt();
    println!("particle filter: {n_particles} particles, {steps} steps");
    println!("RMSE(|x|) = {rmse:.3} (signal RMS {scale:.3})");
    println!("rng service: {}", coord.metrics().render());
    assert!(rmse < 0.6 * scale, "filter diverged: RMSE {rmse} vs scale {scale}");
    coord.shutdown();
}

//! End-to-end serving driver (the EXPERIMENTS.md §E2E run): start the
//! coordinator, load it with concurrent clients over BOTH backends, and
//! report latency/throughput. This exercises every layer: Rust service ->
//! dynamic batcher -> (pure-Rust | PJRT-executed AOT JAX/Pallas) backend.
//!
//!   cargo run --release --example serve_demo [-- clients draws n]

use std::sync::Arc;
use std::time::Instant;
use xorgens_gp::coordinator::{BackendKind, Coordinator, CoordinatorConfig, StreamConfig};

fn run_load(backend: BackendKind, clients: usize, draws: usize, n: usize) -> Option<()> {
    if backend == BackendKind::Pjrt
        && !xorgens_gp::runtime::default_dir().join("manifest.txt").exists()
    {
        println!("pjrt: skipped (run `make artifacts`)");
        return None;
    }
    let coord = Arc::new(Coordinator::new(CoordinatorConfig::default()));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let coord = coord.clone();
            scope.spawn(move || {
                let s = coord.stream(
                    &format!("client-{c}"),
                    StreamConfig { backend, ..Default::default() },
                );
                for _ in 0..draws {
                    coord.draw_u32(s, n).expect("draw");
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "{:<5} backend: {} clients x {} draws x {} numbers = {:.3e} RN in {:.2}s -> {:.3e} RN/s",
        match backend {
            BackendKind::Rust => "rust",
            BackendKind::Pjrt => "pjrt",
        },
        clients,
        draws,
        n,
        m.numbers_served as f64,
        dt,
        m.numbers_served as f64 / dt
    );
    println!("      {}", m.render());
    Some(())
}

fn main() {
    let mut args = std::env::args().skip(1);
    let clients: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(8);
    let draws: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(50);
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(262_144);
    println!("serve_demo: {clients} clients, {draws} draws of {n} u32 each, both backends\n");
    run_load(BackendKind::Rust, clients, draws, n);
    run_load(BackendKind::Pjrt, clients, draws, n);
}

//! End-to-end serving driver (the EXPERIMENTS.md §E2E run): start the
//! coordinator, load it with concurrent clients over BOTH backends, and
//! report latency/throughput. This exercises every layer: Rust service ->
//! dynamic batcher -> (pure-Rust | PJRT-executed AOT JAX/Pallas) backend.
//! Clients use the typed-handle API end to end: `submit` tickets pipelined
//! `PIPELINE_DEPTH` deep, `wait_into` draining into one reusable buffer
//! per client (reply buffers recycle through the coordinator's pool).
//!
//!   cargo run --release --example serve_demo [-- clients draws n]

use std::time::Instant;
use xorgens_gp::coordinator::{BackendKind, Coordinator, CoordinatorConfig};

/// In-flight tickets each client keeps ahead of its consumption: requests
/// pipeline against the sharded workers instead of strictly alternating
/// client-wait / worker-generate.
const PIPELINE_DEPTH: usize = 4;

fn run_load(backend: BackendKind, clients: usize, draws: usize, n: usize) -> Option<()> {
    if backend == BackendKind::Pjrt
        && !xorgens_gp::runtime::default_dir().join("manifest.txt").exists()
    {
        println!("pjrt: skipped (run `make artifacts`)");
        return None;
    }
    let coord = Coordinator::new(CoordinatorConfig::default());
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let coord = &coord;
            scope.spawn(move || {
                let s = coord
                    .builder(&format!("client-{c}"))
                    .backend(backend)
                    .u32()
                    .expect("stream");
                // Pipelined typed draws into one reusable buffer: replies
                // recycle through the coordinator's pool (watch the
                // pool_hits counter in the report).
                let mut buf = vec![0u32; n];
                let mut inflight = std::collections::VecDeque::new();
                for _ in 0..draws {
                    while inflight.len() >= PIPELINE_DEPTH {
                        inflight.pop_front().unwrap().wait_into(&mut buf).expect("draw");
                    }
                    inflight.push_back(s.submit(n).expect("submit"));
                }
                for t in inflight {
                    t.wait_into(&mut buf).expect("draw");
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "{:<5} backend: {} clients x {} draws x {} numbers = {:.3e} RN in {:.2}s -> {:.3e} RN/s",
        match backend {
            BackendKind::Rust => "rust",
            BackendKind::Pjrt => "pjrt",
        },
        clients,
        draws,
        n,
        m.numbers_served as f64,
        dt,
        m.numbers_served as f64 / dt
    );
    println!("      {}", m.render());
    Some(())
}

fn main() {
    let mut args = std::env::args().skip(1);
    let clients: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(8);
    let draws: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(50);
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(262_144);
    println!("serve_demo: {clients} clients, {draws} draws of {n} u32 each, both backends\n");
    run_load(BackendKind::Rust, clients, draws, n);
    run_load(BackendKind::Pjrt, clients, draws, n);
}

//! Quickstart: the library in five minutes.
//!
//!   cargo run --release --example quickstart
//!
//! Covers: direct generator use, the paper's parallel structure, the
//! distributions layer, the coordinator service, and (when artifacts are
//! built) the PJRT backend.

use xorgens_gp::coordinator::{BackendKind, Coordinator, CoordinatorConfig, StreamConfig};
use xorgens_gp::prng::distributions::Ziggurat;
use xorgens_gp::prng::{BlockParallel, GeneratorKind, Prng32, Xorgens, XorgensGp};
use xorgens_gp::runtime::Transform;
use xorgens_gp::util::error::Result;

fn main() -> Result<()> {
    // 1. Serial xorgens (Brent's xor4096i parameters) — a plain Prng32.
    let mut rng = Xorgens::new(42);
    println!("serial xorgens:   {:?}", (0..4).map(|_| rng.next_u32()).collect::<Vec<_>>());
    println!("uniform f64:      {:?}", (0..3).map(|_| rng.next_f64()).collect::<Vec<_>>());

    // 2. The paper's xorgensGP: block-parallel, 63 outputs per block per
    //    round (min(s, r-s) with (r, s) = (128, 65), paper §2).
    let mut gp = XorgensGp::new(42, 4);
    println!(
        "xorgensGP:        {} blocks x {} lanes, {} state words/block (Table 1: 129)",
        gp.blocks(),
        gp.lane_width(),
        gp.state_words_per_block()
    );
    let mut round = vec![0u32; gp.round_len()];
    gp.fill_round(&mut round);
    println!("one round:        {} outputs, first 4 = {:?}", round.len(), &round[..4]);

    // 3. Distributions for Monte Carlo work (paper §1's motivation).
    let zig = Ziggurat::new();
    let normals: Vec<f64> = (0..4).map(|_| zig.sample(&mut rng)).collect();
    println!("ziggurat normals: {normals:?}");

    // 4. The coordinator: named streams, dynamic batching, backpressure.
    let coord = Coordinator::new(CoordinatorConfig::default());
    let stream = coord.stream("quickstart", StreamConfig::default());
    let draws = coord.draw_u32(stream, 1_000_000)?;
    println!("coordinator:      drew {} numbers; {}", draws.len(), coord.metrics().render());

    // 5. The PJRT backend (AOT JAX/Pallas artifacts), if built.
    if xorgens_gp::runtime::default_dir().join("manifest.txt").exists() {
        let s2 = coord.stream(
            "quickstart-pjrt",
            StreamConfig {
                backend: BackendKind::Pjrt,
                kind: GeneratorKind::XorgensGp,
                transform: Transform::U32,
                ..Default::default()
            },
        );
        let v = coord.draw_u32(s2, 100_000)?;
        println!("pjrt backend:     drew {} numbers via AOT XLA artifact", v.len());
    } else {
        println!("pjrt backend:     skipped (run `make artifacts`)");
    }
    coord.shutdown();
    Ok(())
}

//! Quickstart: the library in five minutes.
//!
//!   cargo run --release --example quickstart
//!
//! Covers: direct generator use, the paper's parallel structure, the
//! distributions layer, the coordinator service, and (when artifacts are
//! built) the PJRT backend.

use xorgens_gp::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use xorgens_gp::prng::distributions::Ziggurat;
use xorgens_gp::prng::{BlockParallel, GeneratorKind, Prng32, Xorgens, XorgensGp};
use xorgens_gp::util::error::Result;

fn main() -> Result<()> {
    // 1. Serial xorgens (Brent's xor4096i parameters) — a plain Prng32.
    let mut rng = Xorgens::new(42);
    println!("serial xorgens:   {:?}", (0..4).map(|_| rng.next_u32()).collect::<Vec<_>>());
    println!("uniform f64:      {:?}", (0..3).map(|_| rng.next_f64()).collect::<Vec<_>>());

    // 2. The paper's xorgensGP: block-parallel, 63 outputs per block per
    //    round (min(s, r-s) with (r, s) = (128, 65), paper §2).
    let mut gp = XorgensGp::new(42, 4);
    println!(
        "xorgensGP:        {} blocks x {} lanes, {} state words/block (Table 1: 129)",
        gp.blocks(),
        gp.lane_width(),
        gp.state_words_per_block()
    );
    let mut round = vec![0u32; gp.round_len()];
    gp.fill_round(&mut round);
    println!("one round:        {} outputs, first 4 = {:?}", round.len(), &round[..4]);

    // 3. Distributions for Monte Carlo work (paper §1's motivation).
    let zig = Ziggurat::new();
    let normals: Vec<f64> = (0..4).map(|_| zig.sample(&mut rng)).collect();
    println!("ziggurat normals: {normals:?}");

    // 4. The coordinator: typed stream handles over named streams, dynamic
    //    batching, backpressure. The builder's terminal method (`u32`,
    //    `uniform`, `normal`) fixes the element type, so asking an f32
    //    stream for u32s no longer compiles.
    let coord = Coordinator::new(CoordinatorConfig::default());
    let raw = coord.builder("quickstart").u32()?;
    let draws = raw.draw(1_000_000)?;
    println!("coordinator:      drew {} u32; {}", draws.len(), coord.metrics().render());

    // 4b. Zero-copy serving: fill a caller-owned buffer; the reply buffer
    //     is recycled into the coordinator's pool instead of freed.
    let normals = coord.builder("quickstart-normals").normal()?;
    let mut z = vec![0.0f32; 4096];
    normals.draw_into(&mut z)?;
    println!("typed f32 handle: {:?}…", &z[..3]);

    // 4c. Pipelining: submit tickets ahead, wait as results are needed —
    //     the client overlaps its own work with the sharded workers.
    let tickets: Vec<_> =
        (0..4).map(|_| raw.submit(250_000)).collect::<Result<Vec<_>>>()?;
    let total: usize = tickets
        .into_iter()
        .map(|t| t.wait().map(|v| v.len()))
        .sum::<Result<usize>>()?;
    println!("pipelined:        4 tickets x 250k = {total} draws; {}", coord.metrics().render());

    // 5. The PJRT backend (AOT JAX/Pallas artifacts), if built.
    if xorgens_gp::runtime::default_dir().join("manifest.txt").exists() {
        let s2 = coord
            .builder("quickstart-pjrt")
            .backend(BackendKind::Pjrt)
            .kind(GeneratorKind::XorgensGp)
            .u32()?;
        let v = s2.draw(100_000)?;
        println!("pjrt backend:     drew {} numbers via AOT XLA artifact", v.len());
    } else {
        println!("pjrt backend:     skipped (run `make artifacts`)");
    }
    coord.shutdown();
    Ok(())
}

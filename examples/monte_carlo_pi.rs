//! Monte Carlo π estimation — the throughput-bound workload class the
//! paper's intro motivates, comparing all three generators.
//!
//!   cargo run --release --example monte_carlo_pi [-- samples]
//!
//! Demonstrates that (a) every generator gives statistically consistent
//! estimates, and (b) the throughput ordering measured here is the
//! CPU-backend row of EXPERIMENTS.md §T1.

use std::time::Instant;
use xorgens_gp::prng::{make_block_generator, GeneratorKind};

fn estimate_pi(kind: GeneratorKind, samples: usize, seed: u64) -> (f64, f64) {
    let mut gen = make_block_generator(kind, seed, 64);
    let chunk = 1 << 16;
    let mut buf = vec![0u32; chunk];
    let mut inside = 0u64;
    let mut done = 0usize;
    let t0 = Instant::now();
    while done < samples {
        gen.fill_interleaved(&mut buf);
        for pair in buf.chunks_exact(2) {
            // 16.16 fixed point in [0,1): (x^2 + y^2 < 1)?
            let x = (pair[0] >> 16) as u64;
            let y = (pair[1] >> 16) as u64;
            if x * x + y * y < (1u64 << 32) {
                inside += 1;
            }
        }
        done += chunk / 2;
    }
    let dt = t0.elapsed().as_secs_f64();
    (4.0 * inside as f64 / done as f64, done as f64 * 2.0 / dt)
}

fn main() {
    let samples: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20_000_000);
    println!("Monte Carlo pi with {samples} samples per generator\n");
    println!("{:<12} {:>12} {:>12} {:>14}", "generator", "pi-hat", "|error|", "RN/s");
    for kind in GeneratorKind::PAPER_SET {
        let (pi, rate) = estimate_pi(kind, samples, 7);
        println!(
            "{:<12} {:>12.6} {:>12.2e} {:>14.3e}",
            kind.name(),
            pi,
            (pi - std::f64::consts::PI).abs(),
            rate
        );
        // 3-sigma sanity bound: sigma = sqrt(pi/4 * (1-pi/4) / n) * 4.
        let sigma = 4.0 * (0.785_f64 * 0.215 / samples as f64).sqrt();
        assert!(
            (pi - std::f64::consts::PI).abs() < 5.0 * sigma,
            "{}: estimate {pi} implausibly far from pi",
            kind.name()
        );
    }
}

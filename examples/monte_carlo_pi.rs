//! Monte Carlo π estimation — the throughput-bound workload class the
//! paper's intro motivates, comparing all three generators.
//!
//!   cargo run --release --example monte_carlo_pi [-- samples]
//!
//! Demonstrates that (a) every generator gives statistically consistent
//! estimates, (b) the throughput ordering measured here is the
//! CPU-backend row of EXPERIMENTS.md §T1, and (c) the same workload over
//! the coordinator's typed handles (pipelined `submit`/`wait_into`, depth
//! 2) stays close to driving the generator directly.

use std::time::Instant;
use xorgens_gp::coordinator::{Coordinator, CoordinatorConfig};
use xorgens_gp::prng::{make_block_generator, GeneratorKind};

fn estimate_pi(kind: GeneratorKind, samples: usize, seed: u64) -> (f64, f64) {
    let mut gen = make_block_generator(kind, seed, 64);
    let chunk = 1 << 16;
    let mut buf = vec![0u32; chunk];
    let mut inside = 0u64;
    let mut done = 0usize;
    let t0 = Instant::now();
    while done < samples {
        gen.fill_interleaved(&mut buf);
        for pair in buf.chunks_exact(2) {
            // 16.16 fixed point in [0,1): (x^2 + y^2 < 1)?
            let x = (pair[0] >> 16) as u64;
            let y = (pair[1] >> 16) as u64;
            if x * x + y * y < (1u64 << 32) {
                inside += 1;
            }
        }
        done += chunk / 2;
    }
    let dt = t0.elapsed().as_secs_f64();
    (4.0 * inside as f64 / done as f64, done as f64 * 2.0 / dt)
}

/// The same estimator fed by the coordinator: a typed u32 handle with one
/// ticket always in flight (depth-2 pipelining), draining into a single
/// reusable buffer — the serving overhead shows up directly against the
/// direct-generator rows.
fn estimate_pi_served(samples: usize) -> (f64, f64) {
    let coord = Coordinator::new(CoordinatorConfig::default());
    let s = coord.builder("pi").u32().expect("stream");
    let chunk = 1 << 16;
    let mut buf = vec![0u32; chunk];
    let mut inside = 0u64;
    let mut done = 0usize;
    let t0 = Instant::now();
    let mut pending = s.submit(chunk).expect("submit");
    while done < samples {
        // Queue the next chunk before consuming the current one.
        let next = s.submit(chunk).expect("submit");
        pending.wait_into(&mut buf).expect("draw");
        pending = next;
        for pair in buf.chunks_exact(2) {
            let x = (pair[0] >> 16) as u64;
            let y = (pair[1] >> 16) as u64;
            if x * x + y * y < (1u64 << 32) {
                inside += 1;
            }
        }
        done += chunk / 2;
    }
    let dt = t0.elapsed().as_secs_f64();
    drop(pending.wait()); // drain the last in-flight ticket
    coord.shutdown();
    (4.0 * inside as f64 / done as f64, done as f64 * 2.0 / dt)
}

fn main() {
    let samples: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20_000_000);
    println!("Monte Carlo pi with {samples} samples per generator\n");
    println!("{:<16} {:>12} {:>12} {:>14}", "generator", "pi-hat", "|error|", "RN/s");
    // 3-sigma sanity bound: sigma = sqrt(pi/4 * (1-pi/4) / n) * 4.
    let sigma = 4.0 * (0.785_f64 * 0.215 / samples as f64).sqrt();
    for kind in GeneratorKind::PAPER_SET {
        let (pi, rate) = estimate_pi(kind, samples, 7);
        println!(
            "{:<16} {:>12.6} {:>12.2e} {:>14.3e}",
            kind.name(),
            pi,
            (pi - std::f64::consts::PI).abs(),
            rate
        );
        assert!(
            (pi - std::f64::consts::PI).abs() < 5.0 * sigma,
            "{}: estimate {pi} implausibly far from pi",
            kind.name()
        );
    }
    let (pi, rate) = estimate_pi_served(samples);
    println!(
        "{:<16} {:>12.6} {:>12.2e} {:>14.3e}",
        "xorgensgp/served",
        pi,
        (pi - std::f64::consts::PI).abs(),
        rate
    );
    assert!(
        (pi - std::f64::consts::PI).abs() < 5.0 * sigma,
        "served estimate {pi} implausibly far from pi"
    );
}

"""Generate the committed Rust golden-vector files (rust/tests/golden/).

Transliterates the Rust seeding path (SeedSequence = splitmix64-family
mixer, fill_nonzero, per-generator warm-up) and drives the stream through
the repo's pure-NumPy oracles (python/compile/kernels/ref.py) where they
exist, plus independent re-implementations here, cross-checking the two
at every step:

  * mix64 is pinned to the published splitmix64 vectors;
  * MT19937 is pinned to the published init_genrand(5489) vector;
  * xorgensGP block 0 is checked against a serial xorgens stepped from the
    same canonical state (two independent implementations);
  * XORWOW lanes are checked against ref.py's xorwow_steps oracle.

Output files (under rust/tests/golden/):
  fillpath-<kind>-<seed>.txt : line 1 = first 32 outputs of the
      make_generator(kind, seed) stream, line 2 = FNV-1a 64 hash of the
      first 4096 outputs (little-endian byte feed) — asserted by
      rust/tests/golden.rs against both the scalar and the bulk fill path.
  frozen-xorgens-20260710.txt / frozen-xorwow-20260710.txt /
  frozen-xorgensgp-20260710.txt : the legacy 4-word frozen prefixes.

Run from the repo root:  python3 python/tools/gen_golden_vectors.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "compile"))
from kernels import ref  # noqa: E402
import numpy as np  # noqa: E402

M64 = (1 << 64) - 1
M32 = (1 << 32) - 1
WEYL_32 = 0x61C88647
WEYL_GAMMA = 16


def mix64(z):
    z = (z + 0x9E3779B97F4A7C15) & M64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return z ^ (z >> 31)


assert mix64(0) == 0xE220A8397B1DCDAF, "mix64 != published splitmix64 vector"
assert mix64(0x9E3779B97F4A7C15) == 0x6E789E6AA1B965F4


class SeedSequence:
    """rust/src/prng/init.rs::SeedSequence."""

    def __init__(self, seed):
        self.seed = seed & M64
        self.counter = 0

    def child(self, stream):
        return SeedSequence(mix64(self.seed ^ mix64((stream + 0xA076_1D64_78BD_642F) & M64)))

    def next_u64(self):
        v = mix64((self.seed + self.counter * 0x9E3779B97F4A7C15) & M64)
        self.counter += 1
        return v

    def next_u32(self):
        return self.next_u64() >> 32

    def fill_nonzero(self, n):
        while True:
            words = [self.next_u32() for _ in range(n)]
            if any(words):
                return words


class Xorgens:
    """Serial xorgens (rust/src/prng/xorgens.rs), params (r,s,a,b,c,d)."""

    def __init__(self, params, x, w_raw, i):
        self.p = params
        self.x = list(x)
        self.w = w_raw & M32
        self.i = i

    @classmethod
    def seeded(cls, seed, params):
        r = params[0]
        seq = SeedSequence(seed)
        x = seq.fill_nonzero(r)
        w = seq.next_u32()
        g = cls(params, x, w, r - 1)
        for _ in range(4 * r):  # Brent-style warm-up: raw steps, Weyl untouched
            g.step_raw()
        return g

    @classmethod
    def from_canonical(cls, params, q, w_raw):
        return cls(params, q, w_raw, params[0] - 1)

    def step_raw(self):
        r, s, a, b, c, d = self.p
        mask = r - 1
        self.i = (self.i + 1) & mask
        t = self.x[self.i]
        v = self.x[(self.i + r - s) & mask]
        t ^= (t << a) & M32
        t ^= t >> b
        v ^= (v << c) & M32
        v ^= v >> d
        v ^= t
        self.x[self.i] = v
        return v

    def next_u32(self):
        v = self.step_raw()
        self.w = (self.w + WEYL_32) & M32
        return (v + (self.w ^ (self.w >> WEYL_GAMMA))) & M32


BRENT_4096 = (128, 95, 17, 12, 13, 15)
GP_4096 = (128, 65, 15, 14, 12, 17)
assert GP_4096 == (ref.XG_R, ref.XG_S, ref.XG_A, ref.XG_B, ref.XG_C, ref.XG_D)


def xorgensgp_state(seed, blocks):
    """Canonical per-block (q, w) after construction incl. warm-up
    (rust/src/prng/xorgens_gp.rs::with_params)."""
    r, lane = 128, 63
    root = SeedSequence(seed)
    states = []
    for b in range(blocks):
        seq = root.child(b)
        q = np.array(seq.fill_nonzero(r), dtype=np.uint32)
        w = np.uint32(seq.next_u32())
        states.append((q, w))
    discard = -(-4 * r // lane)  # div_ceil(4r, lane) lockstep warm-up rounds
    warmed = []
    for q, w in states:
        q, w, _ = ref.xorgens_gp_rounds(q, w, discard)
        warmed.append((q, w))
    return warmed


def xorgensgp_stream(seed, blocks, rounds):
    """Interleaved stream of XorgensGp::new(seed, blocks) for `rounds`."""
    per_block = []
    for q, w in xorgensgp_state(seed, blocks):
        _, _, out = ref.xorgens_gp_rounds(q, w, rounds)
        per_block.append(out)
    return ref.block_interleave_rounds(np.stack(per_block), ref.XG_LANE)


def mt_init_genrand(seed):
    mt = [0] * 624
    mt[0] = seed & M32
    for i in range(1, 624):
        mt[i] = (1812433253 * (mt[i - 1] ^ (mt[i - 1] >> 30)) + i) & M32
    return mt


def mt19937_stream(seed, n):
    """Serial MT19937 outputs via the MTGP oracle (1-block round = 227
    tempered outputs of the same stream)."""
    q = np.array(mt_init_genrand(seed), dtype=np.uint32)
    rounds = -(-n // ref.MT_LANE)
    _, out = ref.mtgp_rounds(q, rounds)
    return out[:n]


def mt19937_stream_direct(seed, n):
    """Independent serial MT19937 (block generate + temper), for
    cross-checking the oracle path."""
    mt = mt_init_genrand(seed)
    N, M = 624, 397
    out = []
    mti = N
    while len(out) < n:
        if mti >= N:
            for kk in range(N):
                y = (mt[kk] & 0x80000000) | (mt[(kk + 1) % N] & 0x7FFFFFFF)
                x = mt[(kk + M) % N] ^ (y >> 1)
                if y & 1:
                    x ^= 0x9908B0DF
                mt[kk] = x
            mti = 0
        y = mt[mti]
        mti += 1
        y ^= y >> 11
        y ^= (y << 7) & 0x9D2C5680
        y ^= (y << 15) & 0xEFC60000
        y ^= y >> 18
        out.append(y & M32)
    return np.array(out, dtype=np.uint32)


PUBLISHED_5489 = [3499211612, 581869302, 3890346734, 3586334585, 545404204,
                  4161255391, 3922919429, 949333985, 2715962298, 1323567403]
assert list(mt19937_stream(5489, 10)) == PUBLISHED_5489, "oracle MT19937 != published vector"
assert list(mt19937_stream_direct(5489, 10)) == PUBLISHED_5489, "direct MT19937 != published vector"


def mtgp_stream(seed, blocks, n):
    """Interleaved stream of Mtgp::new(seed, blocks) (first n outputs)."""
    root = SeedSequence(seed)
    rounds = -(-n // (blocks * ref.MT_LANE)) + 1
    per_block = []
    for b in range(blocks):
        s32 = root.child(b).next_u32()
        q = np.array(mt_init_genrand(s32), dtype=np.uint32)
        _, out = ref.mtgp_rounds(q, rounds)
        per_block.append(out)
    inter = ref.block_interleave_rounds(np.stack(per_block), ref.MT_LANE)
    return inter[:n]


def xorwow_seeded_state(seq):
    x = seq.fill_nonzero(5)
    d = seq.next_u32()
    return np.array(x, dtype=np.uint32), np.uint32(d)


def xorwow_stream(seed, n):
    """Serial Xorwow::new(seed) outputs via the ref.py oracle."""
    x, d = xorwow_seeded_state(SeedSequence(seed))
    _, _, out = ref.xorwow_steps(x, d, n)
    return out


def xorwow_stream_direct(seed, n):
    """Independent XORWOW implementation for cross-checking."""
    seq = SeedSequence(seed)
    x = seq.fill_nonzero(5)
    d = seq.next_u32()
    out = []
    for _ in range(n):
        t = x[0] ^ (x[0] >> 2)
        x = x[1:] + [0]
        v = (x[3] ^ ((x[3] << 4) & M32)) ^ (t ^ ((t << 1) & M32))
        x[4] = v & M32
        d = (d + 362437) & M32
        out.append((d + x[4]) & M32)
    return np.array(out, dtype=np.uint32)


def fnv64(values):
    h = 0xCBF29CE484222325
    for v in values:
        for byte in int(v).to_bytes(4, "little"):
            h = ((h ^ byte) * 0x100000001B3) & M64
    return h


def write_fillpath(dirpath, kind, seed, stream):
    stream = [int(v) & M32 for v in stream]
    assert len(stream) == 4096
    path = os.path.join(dirpath, f"fillpath-{kind}-{seed}.txt")
    with open(path, "w") as f:
        f.write(" ".join(str(v) for v in stream[:32]) + "\n")
        f.write(str(fnv64(stream)) + "\n")
    print(f"wrote {path}  head={stream[:4]}")


def main():
    out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "golden")
    os.makedirs(out_dir, exist_ok=True)
    n = 4096
    seeds = [20260710, 424242]

    # Cross-check: xorgensGP block 0 vs serial xorgens from the same
    # canonical state (mirrors rust's block_stream_equals_serial).
    (q0, w0) = xorgensgp_state(20260710, 2)[0]
    serial = Xorgens.from_canonical(GP_4096, [int(v) for v in q0], int(w0))
    _, _, gp_out = ref.xorgens_gp_rounds(q0, w0, 4)
    for j, v in enumerate(gp_out):
        assert int(v) == serial.next_u32(), f"gp/serial divergence at {j}"

    # Cross-check: independent XORWOW vs ref.py oracle.
    assert (xorwow_stream(20260710, 500) == xorwow_stream_direct(20260710, 500)).all()
    # Cross-check: oracle MTGP-1-block vs direct serial MT19937 on a
    # seeded (non-5489) stream.
    s32 = SeedSequence(77).child(0).next_u32()
    assert (mt19937_stream(s32, 700) == mt19937_stream_direct(s32, 700)).all()

    for seed in seeds:
        # make_generator streams (rust/src/prng/mod.rs):
        #   xorgens  -> serial Xorgens (BRENT_4096)
        #   xorgensgp-> InterleavedStream(XorgensGp::new(seed, 64))
        #   mt19937  -> Mt19937::new(seed as u32)
        #   mtgp     -> InterleavedStream(Mtgp::new(seed, 64))
        #   xorwow   -> serial Xorwow
        g = Xorgens.seeded(seed, BRENT_4096)
        write_fillpath(out_dir, "xorgens", seed, [g.next_u32() for _ in range(n)])

        rounds = -(-n // (64 * ref.XG_LANE))
        write_fillpath(out_dir, "xorgensgp", seed, xorgensgp_stream(seed, 64, rounds)[:n])

        write_fillpath(out_dir, "mt19937", seed, mt19937_stream(seed & M32, n))
        write_fillpath(out_dir, "mtgp", seed, mtgp_stream(seed, 64, n))
        write_fillpath(out_dir, "xorwow", seed, xorwow_stream(seed, n))

    # Legacy frozen prefixes (rust/tests/golden.rs::record_or_check).
    g = Xorgens.seeded(20260710, BRENT_4096)
    legacy = {
        "xorgens-20260710": [g.next_u32() for _ in range(4)],
        "xorwow-20260710": [int(v) for v in xorwow_stream(20260710, 4)],
        # First 4 outputs of one round of XorgensGp::new(seed, 2): lane 0..3
        # of block 0.
        "xorgensgp-20260710": [int(v) for v in xorgensgp_stream(20260710, 2, 1)[:4]],
    }
    for name, values in legacy.items():
        path = os.path.join(out_dir, f"frozen-{name}.txt")
        with open(path, "w") as f:
            f.write(" ".join(str(v) for v in values))
        print(f"wrote {path}  {values}")


if __name__ == "__main__":
    main()

"""Cross-language golden vectors: the Rust CLI (`cargo run -- golden`)
dumps canonical states and expected outputs; these tests verify the Python
oracles (and hence the Pallas kernels, already tied to the oracles by
test_kernels.py) produce bit-identical streams.
"""

import json
import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from compile.kernels import ref

GOLDEN_DIR = pathlib.Path(__file__).resolve().parents[2] / "tests" / "golden"


def load(name):
    path = GOLDEN_DIR / f"{name}.json"
    if not path.exists():
        pytest.skip(f"golden file {path} missing — run `cargo run --release -- golden`")
    return json.loads(path.read_text())


class TestXorgensGpGolden:
    def test_stream_matches_rust(self):
        g = load("xorgensgp")
        blocks = g["blocks"]
        state = np.array(g["state"], dtype=np.uint32).reshape(blocks, ref.XG_R + 1)
        rounds = g["rounds"]
        per_block = []
        for b in range(blocks):
            _, _, out = ref.xorgens_gp_rounds(state[b, : ref.XG_R], state[b, ref.XG_R], rounds)
            per_block.append(out)
        stream = ref.block_interleave_rounds(np.stack(per_block), ref.XG_LANE)
        expect = np.array(g["outputs"], dtype=np.uint32)
        assert np.array_equal(stream[: len(expect)], expect)


class TestMtgpGolden:
    def test_stream_matches_rust(self):
        g = load("mtgp")
        blocks = g["blocks"]
        state = np.array(g["state"], dtype=np.uint32).reshape(blocks, ref.MT_N)
        rounds = g["rounds"]
        per_block = [ref.mtgp_rounds(state[b], rounds)[1] for b in range(blocks)]
        stream = ref.block_interleave_rounds(np.stack(per_block), ref.MT_LANE)
        expect = np.array(g["outputs"], dtype=np.uint32)
        assert np.array_equal(stream[: len(expect)], expect)


class TestXorwowGolden:
    def test_stream_matches_rust(self):
        g = load("xorwow")
        blocks = g["blocks"]
        state = np.array(g["state"], dtype=np.uint32).reshape(blocks, 6)
        steps = g["rounds"]
        per_block = [
            ref.xorwow_steps(state[b, :5], state[b, 5], steps)[2] for b in range(blocks)
        ]
        stream = ref.block_interleave_rounds(np.stack(per_block), 1)
        expect = np.array(g["outputs"], dtype=np.uint32)
        assert np.array_equal(stream[: len(expect)], expect)


class TestMt19937Golden:
    def test_serial_mt_vector(self):
        """The rust golden includes the classic seed-5489 vector; verify the
        Python chain (init_genrand -> mtgp_rounds) reproduces it too."""
        g = load("mt19937")
        seed = g["seed"]
        mt = np.zeros(624, dtype=np.uint64)
        mt[0] = seed
        for i in range(1, 624):
            mt[i] = (1812433253 * (mt[i - 1] ^ (mt[i - 1] >> np.uint64(30))) + i) & 0xFFFFFFFF
        _, out = ref.mtgp_rounds(mt.astype(np.uint32), 3)
        expect = np.array(g["outputs"], dtype=np.uint32)
        assert np.array_equal(out[: len(expect)], expect)

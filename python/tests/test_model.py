"""L2 correctness: interleave order, output transforms, artifact graphs."""

import sys
import pathlib

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from compile import model
from compile.kernels import ref


class TestInterleave:
    def test_round_major_order(self):
        # 2 blocks, lane 3, 2 rounds: block rows [r0 | r1].
        out = np.array(
            [[1, 2, 3, 7, 8, 9], [4, 5, 6, 10, 11, 12]], dtype=np.uint32
        )
        got = np.asarray(model.interleave(out, 3))
        assert got.tolist() == [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]

    def test_matches_ref_helper(self):
        rng = np.random.RandomState(0)
        out = rng.randint(0, 2**32, (4, 6 * 63), dtype=np.uint32)
        a = np.asarray(model.interleave(out, 63))
        b = ref.block_interleave_rounds(out, 63)
        assert np.array_equal(a, b)


class TestTransforms:
    def test_f32_in_unit_interval(self):
        bits = np.arange(0, 2**32, 2**24, dtype=np.uint32)
        f = np.asarray(model.u32_to_f32(bits))
        assert f.dtype == np.float32
        assert (f >= 0.0).all() and (f < 1.0).all()
        # Top byte dropped: resolution 2^-24; order preserved.
        assert (np.diff(f) >= 0).all()

    def test_box_muller_moments(self):
        rng = np.random.RandomState(1)
        bits = rng.randint(0, 2**32, 200_000, dtype=np.uint32)
        z = np.asarray(model.box_muller(bits))
        assert abs(z.mean()) < 0.01
        assert abs(z.std() - 1.0) < 0.01
        assert abs(((z - z.mean()) ** 3).mean()) < 0.05

    def test_box_muller_no_nans(self):
        # u=0 would give log(0): the +0.5 offset must prevent it.
        bits = np.zeros(2048, dtype=np.uint32)
        z = np.asarray(model.box_muller(bits))
        assert np.isfinite(z).all()


class TestArtifactGraphs:
    @pytest.mark.parametrize("name", sorted(model.ARTIFACTS))
    def test_graph_traces_and_runs(self, name):
        import jax

        fn, args, meta = model.ARTIFACTS[name]()
        rng = np.random.RandomState(42)
        concrete = [
            rng.randint(0, 2**32, a.shape, dtype=np.uint32) for a in args
        ]
        outs = jax.jit(fn)(*concrete)
        stream = np.asarray(outs[-1])
        assert stream.shape == (meta["outputs"],)
        if meta["transform"] == "u32":
            assert stream.dtype == np.uint32
        else:
            assert stream.dtype == np.float32
        # State round-trips shape-wise.
        for i in range(meta["state_args"]):
            assert np.asarray(outs[i]).shape == args[i].shape

    def test_xorgensgp_stream_matches_ref_order(self):
        fn, args, meta = model.ARTIFACTS["xorgensgp_u32_b8_r2"]()
        import jax

        rng = np.random.RandomState(9)
        q = rng.randint(0, 2**32, args[0].shape, dtype=np.uint32)
        w = rng.randint(0, 2**32, args[1].shape, dtype=np.uint32)
        _, _, stream = jax.jit(fn)(q, w)
        per_block = np.stack(
            [ref.xorgens_gp_rounds(q[b], w[b], meta["rounds"])[2] for b in range(8)]
        )
        expect = ref.block_interleave_rounds(per_block, ref.XG_LANE)
        assert np.array_equal(np.asarray(stream), expect)

    def test_manifest_consistency(self):
        # outputs == blocks * rounds * lane for every artifact.
        for name, make in model.ARTIFACTS.items():
            _, _, meta = make()
            assert meta["outputs"] == meta["blocks"] * meta["rounds"] * meta["lane"], name

"""L1 correctness: Pallas kernels (interpret=True) vs the NumPy oracles,
bit-exact, swept over shapes and state contents with hypothesis."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from compile.kernels import ref
from compile.kernels.mtgp import mtgp_kernel
from compile.kernels.xorgens_gp import xorgens_gp_kernel
from compile.kernels.xorwow import xorwow_kernel

u32s = st.integers(min_value=0, max_value=2**32 - 1)


def _rng(seed):
    return np.random.RandomState(seed)


class TestXorgensGp:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), blocks=st.integers(1, 5), rounds=st.integers(1, 6))
    def test_matches_ref(self, seed, blocks, rounds):
        rng = _rng(seed)
        q = rng.randint(0, 2**32, (blocks, ref.XG_R), dtype=np.uint32)
        w = rng.randint(0, 2**32, (blocks,), dtype=np.uint32)
        q2, w2, out = xorgens_gp_kernel(q, w, rounds)
        for b in range(blocks):
            qr, wr, outr = ref.xorgens_gp_rounds(q[b], w[b], rounds)
            assert np.array_equal(np.asarray(q2[b]), qr)
            assert np.asarray(w2[b]) == wr
            assert np.array_equal(np.asarray(out[b]), outr)

    def test_lane_width_is_min_s_r_minus_s(self):
        # Paper §2: the parallel degree of (r=128, s=65) is 63.
        assert ref.XG_LANE == 63
        assert ref.XG_LANE == min(ref.XG_S, ref.XG_R - ref.XG_S)

    def test_rounds_compose(self):
        # Running 4 rounds equals running 2 rounds twice (state carries).
        rng = _rng(3)
        q = rng.randint(0, 2**32, (2, ref.XG_R), dtype=np.uint32)
        w = rng.randint(0, 2**32, (2,), dtype=np.uint32)
        q4, w4, out4 = xorgens_gp_kernel(q, w, 4)
        q2, w2, out2a = xorgens_gp_kernel(q, w, 2)
        q2b, w2b, out2b = xorgens_gp_kernel(np.asarray(q2), np.asarray(w2), 2)
        assert np.array_equal(np.asarray(q4), np.asarray(q2b))
        assert np.array_equal(np.asarray(w4), np.asarray(w2b))
        assert np.array_equal(
            np.asarray(out4), np.concatenate([np.asarray(out2a), np.asarray(out2b)], axis=1)
        )

    def test_weyl_nonlinearity_present(self):
        # Outputs of two states must not XOR to the output of the XORed
        # state (the Weyl addition breaks GF(2) linearity — paper §1.5).
        rng = _rng(5)
        q1 = rng.randint(0, 2**32, (1, ref.XG_R), dtype=np.uint32)
        q2 = rng.randint(0, 2**32, (1, ref.XG_R), dtype=np.uint32)
        w = np.array([7], dtype=np.uint32)
        _, _, o1 = xorgens_gp_kernel(q1, w, 1)
        _, _, o2 = xorgens_gp_kernel(q2, w, 1)
        _, _, ox = xorgens_gp_kernel(q1 ^ q2, w, 1)
        assert not np.array_equal(np.asarray(o1) ^ np.asarray(o2), np.asarray(ox))


class TestMtgp:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), blocks=st.integers(1, 3), rounds=st.integers(1, 4))
    def test_matches_ref(self, seed, blocks, rounds):
        rng = _rng(seed)
        q = rng.randint(0, 2**32, (blocks, ref.MT_N), dtype=np.uint32)
        q2, out = mtgp_kernel(q, rounds)
        for b in range(blocks):
            qr, outr = ref.mtgp_rounds(q[b], rounds)
            assert np.array_equal(np.asarray(q2[b]), qr)
            assert np.array_equal(np.asarray(out[b]), outr)

    def test_lane_is_n_minus_m(self):
        # Paper §1.3: only N - M elements computable in parallel.
        assert ref.MT_LANE == ref.MT_N - ref.MT_M == 227

    def test_gf2_linearity_of_raw_stream(self):
        # The UNtempered state evolution is linear: state xor carries
        # through the twist. (This is what the battery exploits.)
        rng = _rng(11)
        a = rng.randint(0, 2**32, (1, ref.MT_N), dtype=np.uint32)
        b = rng.randint(0, 2**32, (1, ref.MT_N), dtype=np.uint32)
        qa, _ = mtgp_kernel(a, 1)
        qb, _ = mtgp_kernel(b, 1)
        qx, _ = mtgp_kernel(a ^ b, 1)
        assert np.array_equal(np.asarray(qa) ^ np.asarray(qb), np.asarray(qx))


class TestXorwow:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 40))
    def test_matches_ref(self, seed, steps):
        rng = _rng(seed)
        blocks = 8  # TILE multiple
        x = rng.randint(0, 2**32, (blocks, 5), dtype=np.uint32)
        d = rng.randint(0, 2**32, (blocks,), dtype=np.uint32)
        x2, d2, out = xorwow_kernel(x, d, steps)
        for b in range(blocks):
            xr, dr, outr = ref.xorwow_steps(x[b], d[b], steps)
            assert np.array_equal(np.asarray(x2[b]), xr)
            assert np.asarray(d2[b]) == dr
            assert np.array_equal(np.asarray(out[b]), outr)

    def test_marsaglia_reference_state(self):
        # Cross-implementation check of the exact published initial state
        # (mirrors rust/src/prng/xorwow.rs::reference_state_progression).
        x = np.array([123456789, 362436069, 521288629, 88675123, 5783321], dtype=np.uint32)
        d = np.uint32(6615241)
        _, _, out = ref.xorwow_steps(x, d, 4)
        # Independent scalar recomputation:
        xs = [int(v) for v in x]
        dd = int(d)
        expect = []
        for _ in range(4):
            t = xs[0] ^ (xs[0] >> 2)
            xs = xs[1:] + [0]
            v = (xs[3] ^ ((xs[3] << 4) & 0xFFFFFFFF)) ^ (t ^ ((t << 1) & 0xFFFFFFFF))
            xs[4] = v
            dd = (dd + 362437) & 0xFFFFFFFF
            expect.append((dd + v) & 0xFFFFFFFF)
        assert out.tolist() == expect


class TestMt19937CrossCheck:
    def test_ref_matches_numpy_mt19937(self):
        """NumPy's RandomState IS MT19937 with init_genrand for scalar
        seeds — an independent oracle for our MT implementation chain."""
        seed = 5489
        rs = np.random.RandomState(seed)
        expect = rs.randint(0, 2**32, 10, dtype=np.uint32)
        # Rebuild the state via the reference init and run our kernel path.
        mt = np.zeros(624, dtype=np.uint64)
        mt[0] = seed
        for i in range(1, 624):
            mt[i] = (1812433253 * (mt[i - 1] ^ (mt[i - 1] >> np.uint64(30))) + i) & 0xFFFFFFFF
        _, out = ref.mtgp_rounds(mt.astype(np.uint32), 1)
        assert np.array_equal(out[:10], expect)


class TestFusedVariant:
    """§Perf L2-2 ablation: the fused all-blocks kernel is bit-identical to
    the per-block-grid kernel (and measured *slower* on CPU-PJRT — kept as
    a documented negative result, see EXPERIMENTS.md)."""

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), rounds=st.integers(1, 5))
    def test_fused_equals_per_block(self, seed, rounds):
        from compile.kernels.xorgens_gp import xorgens_gp_kernel_fused

        rng = _rng(seed)
        q = rng.randint(0, 2**32, (4, ref.XG_R), dtype=np.uint32)
        w = rng.randint(0, 2**32, (4,), dtype=np.uint32)
        a = xorgens_gp_kernel(q, w, rounds)
        b = xorgens_gp_kernel_fused(q, w, rounds)
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y))

"""XORWOW (CURAND's default, paper §1.4) as a Pallas kernel.

CURAND's model is one 6-word state per *thread* with no intra-state
parallelism, so the natural Pallas mapping vectorises across the B
independent lanes instead: state (B, 6), each fori_loop iteration advances
every lane one step. One grid step processes a tile of lanes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WEYL = 362437


def _kernel(steps):
    def kernel(x_ref, d_ref, x_out_ref, d_out_ref, out_ref):
        x = x_ref[...]  # (TILE, 5)
        d = d_ref[...]  # (TILE,)

        def body(i, carry):
            x, d = carry
            t = x[:, 0] ^ (x[:, 0] >> 2)
            v_prev = x[:, 4]
            v = (v_prev ^ (v_prev << 4)) ^ (t ^ (t << 1))
            x = jnp.concatenate([x[:, 1:], v[:, None]], axis=1)
            d = d + WEYL
            out_ref[:, i] = d + v
            return (x, d)

        x, d = jax.lax.fori_loop(0, steps, body, (x, d))
        x_out_ref[...] = x
        d_out_ref[...] = d

    return kernel


TILE = 8


def xorwow_kernel(x, d, steps):
    """x: (B, 5) uint32; d: (B,) uint32. Returns (x', d', out (B, steps))."""
    blocks = x.shape[0]
    assert x.shape == (blocks, 5) and d.shape == (blocks,)
    assert blocks % TILE == 0, f"lane count must be a multiple of {TILE}"
    grid = (blocks // TILE,)
    return pl.pallas_call(
        _kernel(steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, 5), lambda b: (b, 0)),
            pl.BlockSpec((TILE,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((TILE, 5), lambda b: (b, 0)),
            pl.BlockSpec((TILE,), lambda b: (b,)),
            pl.BlockSpec((TILE, steps), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks, 5), jnp.uint32),
            jax.ShapeDtypeStruct((blocks,), jnp.uint32),
            jax.ShapeDtypeStruct((blocks, steps), jnp.uint32),
        ],
        interpret=True,
    )(x, d)

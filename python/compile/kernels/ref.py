"""Pure-NumPy oracles for the three generator kernels.

These are the L1 correctness references: exact uint32 semantics, written
to be obviously-correct transliterations of the algorithms (paper §1.3-§2),
cross-checked in three directions:

  * pytest: Pallas kernels (interpret=True) vs these oracles, bit-exact;
  * pytest: these oracles vs the Rust golden vectors produced by
    `cargo run -- golden` (same canonical state layouts);
  * cargo test: the PJRT-executed HLO artifacts vs the Rust generators.

State layouts (canonical interchange, shared with rust/src/prng/):
  xorgensGP per block:  q[0..r] rolled oldest-first, then raw Weyl counter
  MTGP per block:       q[0..624] rolled oldest-first
  XORWOW per block:     x[0..5], d
"""

import numpy as np

U32 = np.uint32
MASK = np.uint64(0xFFFFFFFF)

# xorgens parameters (paper §2): the GP set.
XG_R, XG_S, XG_A, XG_B, XG_C, XG_D = 128, 65, 15, 14, 12, 17
XG_LANE = min(XG_S, XG_R - XG_S)  # 63
WEYL = np.uint64(0x61C88647)
WEYL_GAMMA = 16

# MT19937 parameters (the MTGP substitution — see DESIGN.md).
MT_N, MT_M = 624, 397
MT_MATRIX_A = np.uint64(0x9908B0DF)
MT_UPPER, MT_LOWER = np.uint64(0x80000000), np.uint64(0x7FFFFFFF)
MT_LANE = MT_N - MT_M  # 227

XORWOW_WEYL = np.uint64(362437)


def xorgens_gp_rounds(q, w, rounds):
    """Advance one xorgensGP block `rounds` rounds of XG_LANE outputs.

    q: np.ndarray (r,) uint32 rolled oldest-first; w: scalar uint32.
    Returns (q', w', outputs (rounds*XG_LANE,) uint32).
    """
    q = q.astype(np.uint64)
    w = np.uint64(w)
    out = np.zeros(rounds * XG_LANE, dtype=np.uint64)
    for rd in range(rounds):
        t = q[:XG_LANE].copy()  # x_{k+j-r}
        v = q[XG_R - XG_S : XG_R - XG_S + XG_LANE].copy()  # x_{k+j-s}
        t ^= (t << np.uint64(XG_A)) & MASK
        t ^= t >> np.uint64(XG_B)
        v ^= (v << np.uint64(XG_C)) & MASK
        v ^= v >> np.uint64(XG_D)
        new = v ^ t
        wv = (w + WEYL * (np.arange(1, XG_LANE + 1, dtype=np.uint64))) & MASK
        out[rd * XG_LANE : (rd + 1) * XG_LANE] = (
            new + (wv ^ (wv >> np.uint64(WEYL_GAMMA)))
        ) & MASK
        q = np.concatenate([q[XG_LANE:], new])
        w = (w + WEYL * np.uint64(XG_LANE)) & MASK
    return q.astype(U32), U32(w), out.astype(U32)


def mtgp_rounds(q, rounds):
    """Advance one MTGP block `rounds` rounds of MT_LANE tempered outputs.

    q: np.ndarray (624,) uint32 rolled oldest-first.
    Returns (q', outputs (rounds*MT_LANE,) uint32).
    """
    q = q.astype(np.uint64)
    out = np.zeros(rounds * MT_LANE, dtype=np.uint64)
    for rd in range(rounds):
        xa = q[:MT_LANE]
        xb = q[1 : MT_LANE + 1]
        xm = q[MT_M : MT_M + MT_LANE]
        y = (xa & MT_UPPER) | (xb & MT_LOWER)
        x = xm ^ (y >> np.uint64(1)) ^ np.where(
            (y & np.uint64(1)).astype(bool), MT_MATRIX_A, np.uint64(0)
        )
        x &= MASK
        # Tempering (GF(2)-linear — the reason MT fails Table 2's tests).
        t = x.copy()
        t ^= t >> np.uint64(11)
        t ^= (t << np.uint64(7)) & np.uint64(0x9D2C5680)
        t ^= (t << np.uint64(15)) & np.uint64(0xEFC60000)
        t &= MASK
        t ^= t >> np.uint64(18)
        out[rd * MT_LANE : (rd + 1) * MT_LANE] = t & MASK
        q = np.concatenate([q[MT_LANE:], x])
    return q.astype(U32), out.astype(U32)


def xorwow_steps(x, d, steps):
    """Advance one XORWOW lane `steps` outputs.

    x: np.ndarray (5,) uint32; d: scalar uint32.
    Returns (x', d', outputs (steps,) uint32).
    """
    x = [np.uint64(v) for v in x]
    d = np.uint64(d)
    out = np.zeros(steps, dtype=np.uint64)
    for i in range(steps):
        t = x[0] ^ (x[0] >> np.uint64(2))
        x = [x[1], x[2], x[3], x[4], np.uint64(0)]
        v = (x[3] ^ ((x[3] << np.uint64(4)) & MASK)) ^ (t ^ ((t << np.uint64(1)) & MASK))
        x[4] = v & MASK
        d = (d + XORWOW_WEYL) & MASK
        out[i] = (d + x[4]) & MASK
    return np.array(x, dtype=np.uint64).astype(U32), U32(d), out.astype(U32)


def block_interleave_rounds(per_block, lane):
    """Round-interleave per-block outputs: (B, rounds*lane) ->
    (rounds*B*lane,), block-major within each round — the exact stream
    order of rust's `BlockParallel::fill_round` and the PJRT artifacts."""
    arr = np.asarray(per_block)
    b, total = arr.shape
    rounds = total // lane
    assert rounds * lane == total
    return arr.reshape(b, rounds, lane).swapaxes(0, 1).reshape(-1)

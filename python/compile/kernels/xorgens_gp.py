"""xorgensGP as a Pallas kernel — the paper's §2 GPU mapping, re-thought
for the TPU-shaped Pallas model (DESIGN.md §Hardware-Adaptation):

  CUDA block  ->  Pallas grid step (one block's state in VMEM-resident refs)
  63 threads  ->  a 63-wide vector lane dimension (VPU lanes, not MXU:
                  the kernel is pure integer xor/shift/add)
  __syncthreads() between rounds  ->  the sequential fori_loop carry:
                  lockstep is implicit in the dataflow

Per grid step b (block b): state q (r=128 words, rolled oldest-first) and
Weyl counter w. Each round computes the paper's `min(s, r-s) = 63` new
elements at once from *static* slices — q[0:63] (the x_{k+j-r} terms) and
q[63:126] (the x_{k+j-s} terms, since r-s = 63) — then rolls the buffer.
VMEM footprint per block: 129 words of state + 63*R words of output, far
under any VMEM budget; HBM traffic is 4 B/output streaming.

Lowered with interpret=True: on this CPU-PJRT testbed the kernel executes
as plain HLO (a real-TPU Mosaic lowering would emit a custom-call the CPU
client cannot run). The BlockSpec schedule is still the TPU schedule.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

R, S = ref.XG_R, ref.XG_S
A, B_SH, C, D = ref.XG_A, ref.XG_B, ref.XG_C, ref.XG_D
LANE = ref.XG_LANE  # 63
WEYL = 0x61C88647  # Python int: weakly typed, avoids captured kernel constants
GAMMA = ref.WEYL_GAMMA


def _round(q, w):
    """One 63-wide round. q: (R,) uint32 rolled; w: scalar uint32.
    Returns (q', w', out (LANE,) uint32)."""
    t = q[:LANE]
    v = q[R - S : R - S + LANE]
    t = t ^ (t << A)
    t = t ^ (t >> B_SH)
    v = v ^ (v << C)
    v = v ^ (v >> D)
    new = v ^ t
    wv = w + WEYL * jnp.arange(1, LANE + 1, dtype=jnp.uint32)
    out = new + (wv ^ (wv >> GAMMA))
    q = jnp.concatenate([q[LANE:], new])
    w = w + ((WEYL * LANE) & 0xFFFFFFFF)  # precomputed mod 2^32
    return q, w, out


def _kernel(rounds):
    def kernel(q_ref, w_ref, q_out_ref, w_out_ref, out_ref):
        # Block shapes carry a leading 1 (one block per grid step).
        q = q_ref[0]  # (R,)
        w = w_ref[0]  # scalar

        def body(rd, carry):
            q, w = carry
            q, w, out = _round(q, w)
            out_ref[0, pl.dslice(rd * LANE, LANE)] = out
            return (q, w)

        q, w = jax.lax.fori_loop(0, rounds, body, (q, w))
        q_out_ref[0] = q
        w_out_ref[0] = w

    return kernel


def xorgens_gp_kernel(q, w, rounds):
    """Run `rounds` rounds for every block.

    q: (B, 128) uint32 rolled; w: (B,) uint32.
    Returns (q', w', out (B, rounds*63) uint32).
    """
    blocks = q.shape[0]
    assert q.shape == (blocks, R) and w.shape == (blocks,)
    return pl.pallas_call(
        _kernel(rounds),
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((1, R), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, R), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1, rounds * LANE), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks, R), jnp.uint32),
            jax.ShapeDtypeStruct((blocks,), jnp.uint32),
            jax.ShapeDtypeStruct((blocks, rounds * LANE), jnp.uint32),
        ],
        interpret=True,
    )(q, w)


def _kernel_fused(rounds, blocks):
    """All-blocks-in-one-step variant (EXPERIMENTS.md §Perf L2-2): one grid
    step holds every block's state as a (B, r) array and advances all
    blocks with (B, 63)-wide vector ops. On the CPU-PJRT interpret path
    this amortises per-block-program dispatch; on a real TPU it is still a
    valid VMEM tiling for B*129 words (64 blocks = 33 KiB)."""

    def kernel(q_ref, w_ref, q_out_ref, w_out_ref, out_ref):
        q = q_ref[...]  # (B, R)
        w = w_ref[...]  # (B,)

        def body(rd, carry):
            q, w = carry
            t = q[:, :LANE]
            v = q[:, R - S : R - S + LANE]
            t = t ^ (t << A)
            t = t ^ (t >> B_SH)
            v = v ^ (v << C)
            v = v ^ (v >> D)
            new = v ^ t
            wv = w[:, None] + WEYL * jnp.arange(1, LANE + 1, dtype=jnp.uint32)[None, :]
            out_ref[:, pl.dslice(rd * LANE, LANE)] = new + (wv ^ (wv >> GAMMA))
            q = jnp.concatenate([q[:, LANE:], new], axis=1)
            w = w + ((WEYL * LANE) & 0xFFFFFFFF)
            return (q, w)

        q, w = jax.lax.fori_loop(0, rounds, body, (q, w))
        q_out_ref[...] = q
        w_out_ref[...] = w

    return kernel


def xorgens_gp_kernel_fused(q, w, rounds):
    """Fused-block variant of :func:`xorgens_gp_kernel` (same outputs)."""
    blocks = q.shape[0]
    assert q.shape == (blocks, R) and w.shape == (blocks,)
    return pl.pallas_call(
        _kernel_fused(rounds, blocks),
        out_shape=[
            jax.ShapeDtypeStruct((blocks, R), jnp.uint32),
            jax.ShapeDtypeStruct((blocks,), jnp.uint32),
            jax.ShapeDtypeStruct((blocks, rounds * LANE), jnp.uint32),
        ],
        interpret=True,
    )(q, w)

"""MTGP-style block-parallel Mersenne Twister as a Pallas kernel
(paper §1.3's `N - M`-way parallelism; MT19937 parameter substitution per
DESIGN.md). Same grid/BlockSpec mapping as xorgens_gp.py: one CUDA block ->
one Pallas grid step; the 227 parallel lanes -> a static vector slice.
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

N, M = ref.MT_N, ref.MT_M
LANE = ref.MT_LANE  # 227
# np scalar constants: > int31 values must not be weak Python ints (JAX
# rejects them when binding against uint32), and jnp scalars would be
# captured tracer constants — np.uint32 threads the needle.
MATRIX_A = np.uint32(0x9908B0DF)
UPPER, LOWER = np.uint32(0x80000000), np.uint32(0x7FFFFFFF)


def _round(q):
    """One 227-wide round. q: (N,) uint32 rolled oldest-first."""
    xa = q[:LANE]
    xb = q[1 : LANE + 1]
    xm = q[M : M + LANE]
    y = (xa & UPPER) | (xb & LOWER)
    x = xm ^ (y >> 1) ^ jnp.where((y & 1).astype(bool), MATRIX_A, np.uint32(0))
    # Tempering.
    t = x
    t = t ^ (t >> 11)
    t = t ^ ((t << 7) & np.uint32(0x9D2C5680))
    t = t ^ ((t << 15) & np.uint32(0xEFC60000))
    t = t ^ (t >> 18)
    q = jnp.concatenate([q[LANE:], x])
    return q, t


def _kernel(rounds):
    def kernel(q_ref, q_out_ref, out_ref):
        q = q_ref[0]

        def body(rd, q):
            q, out = _round(q)
            out_ref[0, pl.dslice(rd * LANE, LANE)] = out
            return q

        q = jax.lax.fori_loop(0, rounds, body, q)
        q_out_ref[0] = q

    return kernel


def mtgp_kernel(q, rounds):
    """q: (B, 624) uint32 rolled. Returns (q', out (B, rounds*227))."""
    blocks = q.shape[0]
    assert q.shape == (blocks, N)
    return pl.pallas_call(
        _kernel(rounds),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((1, N), lambda b: (b, 0))],
        out_specs=[
            pl.BlockSpec((1, N), lambda b: (b, 0)),
            pl.BlockSpec((1, rounds * LANE), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks, N), jnp.uint32),
            jax.ShapeDtypeStruct((blocks, rounds * LANE), jnp.uint32),
        ],
        interpret=True,
    )(q)

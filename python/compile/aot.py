"""AOT compile path: lower every artifact graph in `model.ARTIFACTS` to
HLO **text** under artifacts/, plus a manifest the Rust runtime parses.

HLO text — NOT `.serialize()`d protos — is the interchange format: jax
>= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. (See
/opt/xla-example/README.md.)

Run once via `make artifacts`; Python never runs at request time.

Usage: python -m compile.aot --out ../artifacts [--only NAME]
"""

import argparse
import pathlib

import jax

from . import model


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: pathlib.Path, only=None) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_lines = [
        "# name kind transform blocks rounds lane outputs state_args",
    ]
    for name, make in sorted(model.ARTIFACTS.items()):
        if only and name != only:
            continue
        fn, args, meta = make()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        manifest_lines.append(
            f"{name} {meta['kind']} {meta['transform']} {meta['blocks']} "
            f"{meta['rounds']} {meta['lane']} {meta['outputs']} {meta['state_args']}"
        )
        print(f"wrote {path} ({len(text)} chars, {meta['outputs']} outputs/launch)")
    if not only:
        (out_dir / "manifest.txt").write_text("\n".join(manifest_lines) + "\n")
        print(f"wrote {out_dir / 'manifest.txt'}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    build(pathlib.Path(args.out), args.only)


if __name__ == "__main__":
    main()

"""L2: the JAX generation graph — batched state advance (calling the L1
Pallas kernels) plus the output transforms the paper's Monte Carlo
applications consume (uniform floats, Box-Muller normals).

Each public `make_*` function returns a jit-able function and its example
arguments; `aot.py` lowers them once to HLO text. The Rust runtime then
drives the artifacts on the request path with *no Python anywhere*.

Output stream order is the canonical round-interleave (block-major within
a round), identical to `rust::prng::BlockParallel::fill_round` — this is
what makes the Rust and PJRT backends bit-comparable.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.mtgp import mtgp_kernel
from .kernels.xorgens_gp import xorgens_gp_kernel
from .kernels.xorwow import xorwow_kernel


def interleave(out, lane):
    """(B, rounds*lane) -> (rounds*B*lane,) round-major stream."""
    b, total = out.shape
    rounds = total // lane
    return out.reshape(b, rounds, lane).swapaxes(0, 1).reshape(-1)


def u32_to_f32(bits):
    """uint32 -> f32 uniform in [0, 1): 24-bit mantissa scaling."""
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / 16777216.0)


def box_muller(bits):
    """uint32 stream (even length) -> standard normals, pairwise
    (cos, sin) Box-Muller. f32 math — the GPU-typical configuration."""
    u = (bits.reshape(-1, 2).astype(jnp.float32) + 0.5) * jnp.float32(1.0 / 4294967296.0)
    r = jnp.sqrt(-2.0 * jnp.log(u[:, 0]))
    theta = jnp.float32(2.0 * 3.14159265358979) * u[:, 1]
    return jnp.stack([r * jnp.cos(theta), r * jnp.sin(theta)], axis=1).reshape(-1)


# ---------------------------------------------------------------------------
# Artifact graphs. Each returns (fn, example_args, metadata).
# ---------------------------------------------------------------------------


def make_xorgens_gp(blocks, rounds, transform="u32"):
    lane = ref.XG_LANE

    def fn(q, w):
        q2, w2, out = xorgens_gp_kernel(q, w, rounds)
        stream = interleave(out, lane)
        return (q2, w2, _apply(stream, transform))

    args = (
        jax.ShapeDtypeStruct((blocks, ref.XG_R), jnp.uint32),
        jax.ShapeDtypeStruct((blocks,), jnp.uint32),
    )
    meta = {
        "kind": "xorgensgp",
        "transform": transform,
        "blocks": blocks,
        "rounds": rounds,
        "lane": lane,
        "outputs": blocks * rounds * lane,
        "state_args": 2,
    }
    return fn, args, meta


def make_mtgp(blocks, rounds, transform="u32"):
    lane = ref.MT_LANE

    def fn(q):
        q2, out = mtgp_kernel(q, rounds)
        stream = interleave(out, lane)
        return (q2, _apply(stream, transform))

    args = (jax.ShapeDtypeStruct((blocks, ref.MT_N), jnp.uint32),)
    meta = {
        "kind": "mtgp",
        "transform": transform,
        "blocks": blocks,
        "rounds": rounds,
        "lane": lane,
        "outputs": blocks * rounds * lane,
        "state_args": 1,
    }
    return fn, args, meta


def make_xorwow(blocks, steps, transform="u32"):
    def fn(x, d):
        x2, d2, out = xorwow_kernel(x, d, steps)
        stream = interleave(out, 1)
        return (x2, d2, _apply(stream, transform))

    args = (
        jax.ShapeDtypeStruct((blocks, 5), jnp.uint32),
        jax.ShapeDtypeStruct((blocks,), jnp.uint32),
    )
    meta = {
        "kind": "xorwow",
        "transform": transform,
        "blocks": blocks,
        "rounds": steps,
        "lane": 1,
        "outputs": blocks * steps,
        "state_args": 2,
    }
    return fn, args, meta


def _apply(stream, transform):
    if transform == "u32":
        return stream
    if transform == "f32":
        return u32_to_f32(stream)
    if transform == "normal":
        return box_muller(stream)
    raise ValueError(f"unknown transform {transform!r}")


# The artifact set `aot.py` builds. Names are load-bearing: the Rust
# runtime resolves `<name>.hlo.txt` via artifacts/manifest.txt.
ARTIFACTS = {
    # Production launch shapes (coordinator hot path). r64 exists because
    # the CPU-PJRT execute path has per-launch overhead (buffer marshalling
    # + dispatch) that the bigger launch amortises — EXPERIMENTS.md §Perf L2-1.
    "xorgensgp_u32_b64_r64": lambda: make_xorgens_gp(64, 64, "u32"),
    "xorgensgp_u32_b64_r16": lambda: make_xorgens_gp(64, 16, "u32"),
    "xorgensgp_f32_b64_r16": lambda: make_xorgens_gp(64, 16, "f32"),
    "xorgensgp_normal_b64_r16": lambda: make_xorgens_gp(64, 16, "normal"),
    "mtgp_u32_b64_r4": lambda: make_mtgp(64, 4, "u32"),
    "xorwow_u32_b256_s256": lambda: make_xorwow(256, 256, "u32"),
    # Small shapes for fast integration tests.
    "xorgensgp_u32_b8_r2": lambda: make_xorgens_gp(8, 2, "u32"),
    "mtgp_u32_b4_r2": lambda: make_mtgp(4, 2, "u32"),
    "xorwow_u32_b16_s32": lambda: make_xorwow(16, 32, "u32"),
}

//! Statistical special functions: CDFs and tails used by the battery to
//! turn test statistics into p-values (paper §1.2), implemented from
//! scratch (no external crates): erfc, regularized incomplete gamma
//! (chi-square), Kolmogorov distribution, and Poisson tails.

/// Complementary error function (Numerical-Recipes-style Chebyshev fit,
/// |rel err| < 1.2e-7 — ample for p-value thresholds of 1e-10 *in the
/// exponent sense*: the fit's exponential factor is exact).
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Standard normal CDF.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Two-sided normal p-value for a z-statistic.
pub fn normal_two_sided_p(z: f64) -> f64 {
    erfc(z.abs() / std::f64::consts::SQRT_2)
}

/// ln Γ(x) (Lanczos).
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 6] = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
    ];
    let mut y = x;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    let mut ser = 1.000000000190015;
    for g in G {
        y += 1.0;
        ser += g / y;
    }
    -tmp + (2.5066282746310005 * ser / x).ln()
}

/// Regularized lower incomplete gamma P(a, x).
pub fn gamma_p(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0);
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // series
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        sum * (-x + a * x.ln() - ln_gamma(a)).exp()
    } else {
        1.0 - gamma_q_cf(a, x)
    }
}

/// Regularized upper incomplete gamma Q(a, x) = 1 - P(a, x).
pub fn gamma_q(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0);
    if x == 0.0 {
        return 1.0;
    }
    if x < a + 1.0 {
        1.0 - gamma_p(a, x)
    } else {
        gamma_q_cf(a, x)
    }
}

/// Continued-fraction evaluation of Q(a, x) for x > a + 1.
fn gamma_q_cf(a: f64, x: f64) -> f64 {
    let fpmin = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / fpmin;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < fpmin {
            d = fpmin;
        }
        c = b + an / c;
        if c.abs() < fpmin {
            c = fpmin;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}

/// Upper-tail p-value of a chi-square statistic with `k` degrees of freedom.
pub fn chi2_sf(x: f64, k: f64) -> f64 {
    gamma_q(k / 2.0, x / 2.0)
}

/// Kolmogorov distribution survival function:
/// P(D_n > d) ≈ 2 Σ (−1)^{j−1} exp(−2 j² n d²).
pub fn kolmogorov_sf(d: f64, n: usize) -> f64 {
    if d <= 0.0 {
        return 1.0;
    }
    let n = n as f64;
    // Stephens' asymptotic correction improves small-n accuracy.
    let t = (n.sqrt() + 0.12 + 0.11 / n.sqrt()) * d;
    let mut sum = 0.0;
    for j in 1..=100 {
        let jf = j as f64;
        let term = (-2.0 * jf * jf * t * t).exp();
        sum += if j % 2 == 1 { term } else { -term };
        if term < 1e-18 {
            break;
        }
    }
    (2.0 * sum).clamp(0.0, 1.0)
}

/// Poisson upper tail P(X >= k) for mean lambda.
pub fn poisson_sf_ge(k: u64, lambda: f64) -> f64 {
    if k == 0 {
        return 1.0;
    }
    // P(X >= k) = P(a=k, x=lambda) (regularized lower incomplete gamma).
    gamma_p(k as f64, lambda)
}

/// Poisson lower tail P(X <= k).
pub fn poisson_cdf(k: u64, lambda: f64) -> f64 {
    gamma_q(k as f64 + 1.0, lambda)
}

/// Two-sided p-value for a Poisson observation (the TestU01 convention for
/// birthday-spacings-style counters): min tail doubled, capped at 1.
pub fn poisson_two_sided_p(k: u64, lambda: f64) -> f64 {
    let lo = poisson_cdf(k, lambda);
    let hi = poisson_sf_ge(k, lambda);
    (2.0 * lo.min(hi)).min(1.0)
}

/// One-sample KS test p-value for sorted uniforms on [0,1).
pub fn ks_uniform_p(sorted: &[f64]) -> f64 {
    let n = sorted.len();
    assert!(n > 0);
    let mut d: f64 = 0.0;
    for (i, &x) in sorted.iter().enumerate() {
        let lo = x - i as f64 / n as f64;
        let hi = (i + 1) as f64 / n as f64 - x;
        d = d.max(lo.max(hi));
    }
    kolmogorov_sf(d, n)
}

/// Chi-square test from observed counts and expected counts.
/// Returns (statistic, p-value); degrees of freedom = cells − 1.
pub fn chi2_test(observed: &[u64], expected: &[f64]) -> (f64, f64) {
    assert_eq!(observed.len(), expected.len());
    let mut stat = 0.0;
    for (&o, &e) in observed.iter().zip(expected) {
        debug_assert!(e > 0.0, "expected count must be positive");
        let diff = o as f64 - e;
        stat += diff * diff / e;
    }
    let df = observed.len() as f64 - 1.0;
    (stat, chi2_sf(stat, df))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erfc_known_values() {
        assert!((erfc(0.0) - 1.0).abs() < 1e-7);
        assert!((erfc(1.0) - 0.15729920705).abs() < 1e-6);
        assert!((erfc(-1.0) - 1.84270079295).abs() < 1e-6);
        assert!(erfc(6.0) < 1e-15);
    }

    #[test]
    fn normal_cdf_symmetry() {
        for x in [0.1, 0.5, 1.0, 2.0, 3.0] {
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-12);
        }
        assert!((normal_cdf(1.959963985) - 0.975).abs() < 1e-6);
    }

    #[test]
    fn gamma_pq_complementary() {
        for (a, x) in [(0.5, 0.3), (2.0, 1.0), (5.0, 10.0), (30.0, 25.0)] {
            assert!((gamma_p(a, x) + gamma_q(a, x) - 1.0).abs() < 1e-12, "a={a} x={x}");
        }
    }

    #[test]
    fn chi2_sf_known() {
        // chi2 with k=1: P(X > 3.841) ≈ 0.05
        assert!((chi2_sf(3.841459, 1.0) - 0.05).abs() < 1e-4);
        // k=10: P(X > 18.307) ≈ 0.05
        assert!((chi2_sf(18.307, 10.0) - 0.05).abs() < 1e-3);
        // median of chi2_k ~ k(1-2/(9k))^3
        assert!((chi2_sf(9.342, 10.0) - 0.5).abs() < 1e-2);
    }

    #[test]
    fn ln_gamma_factorials() {
        for n in 1..10u64 {
            let fact: f64 = (1..n).map(|i| i as f64).product();
            assert!((ln_gamma(n as f64) - fact.ln()).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn kolmogorov_tail_sane() {
        // For large n and d = 1.36/sqrt(n), p ≈ 0.05.
        let n = 10_000;
        let d = 1.358 / (n as f64).sqrt();
        let p = kolmogorov_sf(d, n);
        assert!((p - 0.05).abs() < 0.01, "p={p}");
    }

    #[test]
    fn poisson_tails() {
        // lambda = 4: P(X >= 4) ≈ 0.5665, P(X <= 3) ≈ 0.4335
        assert!((poisson_sf_ge(4, 4.0) - 0.5665).abs() < 1e-3);
        assert!((poisson_cdf(3, 4.0) - 0.4335).abs() < 1e-3);
        assert!((poisson_cdf(10, 4.0) + poisson_sf_ge(11, 4.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn chi2_test_uniform_counts() {
        let observed = vec![100u64, 95, 105, 98, 102];
        let expected = vec![100.0; 5];
        let (stat, p) = chi2_test(&observed, &expected);
        assert!(stat < 2.0);
        assert!(p > 0.5);
    }

    #[test]
    fn ks_uniform_on_perfect_grid() {
        let n = 1000;
        let sorted: Vec<f64> = (0..n).map(|i| (i as f64 + 0.5) / n as f64).collect();
        let p = ks_uniform_p(&sorted);
        assert!(p > 0.9, "p={p}"); // nearly perfect fit
    }
}

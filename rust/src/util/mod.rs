//! Self-contained substrates: this reproduction builds offline against a
//! vendored crate set (only `xla` + `anyhow`), so the CLI parser, the
//! micro-benchmark harness, JSON emission, statistics helpers and the
//! property-testing driver are implemented here rather than pulled from
//! crates.io.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod stats;

//! Self-contained substrates: this reproduction builds fully offline with
//! zero crates.io dependencies, so the CLI parser, the micro-benchmark
//! harness, JSON emission, statistics helpers, the property-testing driver
//! and the error-handling layer are implemented here rather than pulled
//! from crates.io. (The optional `pjrt` feature is the one exception: it
//! needs a vendored `xla` crate — see `runtime::client`.)

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod stats;

//! Tiny JSON emitter (serde is unavailable offline). Only what the
//! experiment reports and golden-vector files need: objects, arrays,
//! strings, numbers, bools.

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(vec![])
    }

    pub fn push(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(fields) = self {
            fields.push((key.to_string(), value));
        } else {
            panic!("push on non-object");
        }
        self
    }

    pub fn arr_of_u32(xs: &[u32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Int(x as i64)).collect())
    }

    pub fn arr_of_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(x) => {
                if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_nested() {
        let mut o = Json::obj();
        o.push("name", Json::Str("xorgensgp".into()))
            .push("rate", Json::Num(7.7e9))
            .push("pass", Json::Bool(true))
            .push("outputs", Json::arr_of_u32(&[1, 2, 3]));
        let s = o.to_string();
        assert_eq!(
            s,
            r#"{"name":"xorgensgp","rate":7700000000,"pass":true,"outputs":[1,2,3]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}

//! Lightweight property-testing driver (proptest is unavailable offline).
//!
//! Generates pseudo-random cases from our own generators — fittingly, the
//! library under test supplies its own entropy — with deterministic seeds,
//! shrink-free but with case-number reporting on failure.

use crate::prng::{Prng32, Xorgens};

/// A deterministic case generator for property tests.
pub struct Cases {
    rng: Xorgens,
    pub case: usize,
}

impl Cases {
    pub fn new(seed: u64) -> Self {
        Cases { rng: Xorgens::new(seed ^ 0x70726f70), case: 0 }
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.rng.next_u64() % (hi - lo + 1) as u64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// A vec of random u32 with length in [min_len, max_len].
    pub fn vec_u32(&mut self, min_len: usize, max_len: usize) -> Vec<u32> {
        let n = self.range(min_len, max_len);
        (0..n).map(|_| self.u32()).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.u32() & 1 == 1
    }
}

/// Run `prop` for `n` generated cases; panics with the failing case number.
pub fn check<F: FnMut(&mut Cases)>(name: &str, n: usize, seed: u64, mut prop: F) {
    let mut cases = Cases::new(seed);
    for case in 0..n {
        cases.case = case;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut cases)));
        if let Err(e) = result {
            eprintln!("property {name:?} failed at case {case} (seed {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_respect_bounds() {
        let mut c = Cases::new(1);
        for _ in 0..1000 {
            let v = c.range(3, 17);
            assert!((3..=17).contains(&v));
        }
        assert_eq!(c.range(5, 5), 5);
    }

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check("counting", 25, 42, |_c| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("failing", 10, 1, |c| {
            assert!(c.case < 5);
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Cases::new(9);
        let mut b = Cases::new(9);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }
}

//! Minimal error-handling substrate (anyhow is unavailable offline).
//!
//! API-compatible with the `anyhow` subset this crate uses: an opaque
//! [`Error`] carrying a context chain, [`Result`], the [`Context`] trait
//! for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! `{e}` displays the outermost message; `{e:#}` displays the whole chain
//! joined by `": "` (matching anyhow's alternate formatting, which the
//! failure-injection tests assert on).

use std::fmt;

/// An opaque error: a chain of context messages, outermost first.
#[derive(Debug, Clone)]
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from any displayable message (the root cause).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<M: fmt::Display>(mut self, message: M) -> Error {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Fold the source chain into context messages.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to error values (mirrors `anyhow::Context`).
pub trait Context<T> {
    fn context<M: fmt::Display>(self, message: M) -> Result<T>;
    fn with_context<M: fmt::Display, F: FnOnce() -> M>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<M: fmt::Display>(self, message: M) -> Result<T> {
        self.map_err(|e| e.into().context(message))
    }

    fn with_context<M: fmt::Display, F: FnOnce() -> M>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<M: fmt::Display>(self, message: M) -> Result<T> {
        self.ok_or_else(|| Error::msg(message))
    }

    fn with_context<M: fmt::Display, F: FnOnce() -> M>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

// Make the crate-root macros importable through this module, so call sites
// can write `use crate::util::error::{bail, Context, Result};`.
pub use crate::{anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root cause {}", 7)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "root cause 7");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert!(format!("{e}").contains("missing"));
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn ensure_macro() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert!(check(3).is_ok());
        assert!(format!("{}", check(12).unwrap_err()).contains("too big"));
    }
}

//! Micro-benchmark harness (criterion is unavailable offline): warmup,
//! repeated timed runs, and robust summary statistics. `cargo bench`
//! targets use this via `harness = false`.

use std::time::{Duration, Instant};

/// Result of one benchmark: per-iteration timings.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per run.
    pub runs: Vec<f64>,
    /// Work units per run (e.g. random numbers generated), for rate reporting.
    pub units_per_run: f64,
}

impl BenchResult {
    pub fn median(&self) -> f64 {
        let mut v = self.runs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    }

    pub fn min(&self) -> f64 {
        self.runs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn mean(&self) -> f64 {
        self.runs.iter().sum::<f64>() / self.runs.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        (self.runs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / self.runs.len() as f64).sqrt()
    }

    /// Work units per second at the median run.
    pub fn rate(&self) -> f64 {
        self.units_per_run / self.median()
    }

    pub fn report(&self) -> String {
        format!(
            "{:<28} median {:>9.4} ms  (±{:>6.2}%)  rate {:>12.3e} /s",
            self.name,
            self.median() * 1e3,
            100.0 * self.stddev() / self.mean().max(1e-300),
            self.rate()
        )
    }
}

/// Benchmark runner with warmup and a time budget.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    min_runs: usize,
    max_runs: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(2),
            min_runs: 5,
            max_runs: 200,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(500),
            min_runs: 3,
            max_runs: 50,
        }
    }

    pub fn with_budget(warmup_ms: u64, measure_ms: u64) -> Self {
        Bencher {
            warmup: Duration::from_millis(warmup_ms),
            measure: Duration::from_millis(measure_ms),
            ..Default::default()
        }
    }

    /// Run `f` repeatedly; `units` is the work per call for rate reporting.
    pub fn run<F: FnMut()>(&self, name: &str, units: f64, mut f: F) -> BenchResult {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut runs = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.measure || runs.len() < self.min_runs)
            && runs.len() < self.max_runs
        {
            let s = Instant::now();
            f();
            runs.push(s.elapsed().as_secs_f64());
        }
        BenchResult { name: name.to_string(), runs, units_per_run: units }
    }
}

/// Prevent the optimizer from discarding a computed value
/// (stable-Rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            min_runs: 3,
            max_runs: 10,
        };
        let mut acc = 0u64;
        let r = b.run("spin", 1000.0, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.runs.len() >= 3);
        assert!(r.median() > 0.0);
        assert!(r.rate() > 0.0);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn summary_stats() {
        let r = BenchResult { name: "x".into(), runs: vec![0.1, 0.2, 0.3], units_per_run: 10.0 };
        assert!((r.median() - 0.2).abs() < 1e-12);
        assert!((r.mean() - 0.2).abs() < 1e-12);
        assert!((r.rate() - 50.0).abs() < 1e-9);
        assert!((r.min() - 0.1).abs() < 1e-12);
    }
}

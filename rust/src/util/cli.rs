//! Minimal command-line parsing (clap is unavailable offline): subcommands,
//! `--flag`, `--key value` / `--key=value`, positional args.

use std::collections::HashMap;

/// Parsed arguments: a subcommand, options and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.opts.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positionals.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("invalid value for --{name}: {s:?}")),
        }
    }

    pub fn opt_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: `--key value` binding is greedy — a bare word after a `--`
        // token is consumed as its value, so boolean flags must come last
        // or be followed by another `--` token.
        let a = parse(&["battery", "extra", "--tier", "small", "--gen=xorgensgp", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("battery"));
        assert_eq!(a.opt("tier"), Some("small"));
        assert_eq!(a.opt("gen"), Some("xorgensgp"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn numeric_parsing() {
        let a = parse(&["bench", "--n", "1000000", "--blocks=64"]);
        assert_eq!(a.opt_parse_or::<u64>("n", 0).unwrap(), 1_000_000);
        assert_eq!(a.opt_parse_or::<usize>("blocks", 0).unwrap(), 64);
        assert_eq!(a.opt_parse_or::<u64>("missing", 7).unwrap(), 7);
        assert!(a.opt_parse::<u64>("gen").is_ok());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--fast", "--deep"]);
        assert!(a.flag("fast") && a.flag("deep"));
        assert_eq!(a.opt("fast"), None);
    }

    #[test]
    fn invalid_numeric_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.opt_parse::<u64>("n").is_err());
    }
}

//! Minimal command-line parsing (clap is unavailable offline): subcommands,
//! `--flag`, `--key value` / `--key=value`, positional args — plus
//! [`ParseEnumError`], the typed error behind the crate's `FromStr` enum
//! impls ([`GeneratorKind`](crate::prng::GeneratorKind),
//! [`BackendKind`](crate::coordinator::BackendKind)), so `--gen`/`--backend`
//! values parse through the same [`Args::opt_parse`] path as numbers.

use std::collections::HashMap;
use std::fmt;

/// Typed parse failure for the crate's name-registry enums: says *what*
/// was being parsed, what was seen, and what would have been accepted.
/// Implements `std::error::Error`, so it converts into the crate's
/// [`Error`](crate::util::error::Error) via `?`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseEnumError {
    /// What was being parsed ("generator kind", "backend kind", …).
    pub what: &'static str,
    /// The rejected input.
    pub input: String,
    /// Accepted spellings, for the error message.
    pub expected: &'static str,
}

impl ParseEnumError {
    pub fn new(what: &'static str, input: &str, expected: &'static str) -> ParseEnumError {
        ParseEnumError { what, input: input.to_string(), expected }
    }
}

impl fmt::Display for ParseEnumError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "unknown {} {:?} (expected one of: {})", self.what, self.input, self.expected)
    }
}

impl std::error::Error for ParseEnumError {}

/// Parsed arguments: a subcommand, options and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.opts.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positionals.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String>
    where
        T::Err: fmt::Display,
    {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("invalid value for --{name}: {s:?} ({e})")),
        }
    }

    pub fn opt_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: fmt::Display,
    {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        // NOTE: `--key value` binding is greedy — a bare word after a `--`
        // token is consumed as its value, so boolean flags must come last
        // or be followed by another `--` token.
        let a = parse(&["battery", "extra", "--tier", "small", "--gen=xorgensgp", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("battery"));
        assert_eq!(a.opt("tier"), Some("small"));
        assert_eq!(a.opt("gen"), Some("xorgensgp"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn numeric_parsing() {
        let a = parse(&["bench", "--n", "1000000", "--blocks=64"]);
        assert_eq!(a.opt_parse_or::<u64>("n", 0).unwrap(), 1_000_000);
        assert_eq!(a.opt_parse_or::<usize>("blocks", 0).unwrap(), 64);
        assert_eq!(a.opt_parse_or::<u64>("missing", 7).unwrap(), 7);
        assert!(a.opt_parse::<u64>("gen").is_ok());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--fast", "--deep"]);
        assert!(a.flag("fast") && a.flag("deep"));
        assert_eq!(a.opt("fast"), None);
    }

    #[test]
    fn invalid_numeric_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.opt_parse::<u64>("n").is_err());
    }

    #[test]
    fn parse_enum_error_display() {
        let e = ParseEnumError::new("generator kind", "nope", "xorgens|mtgp");
        let msg = e.to_string();
        assert!(msg.contains("generator kind"), "{msg}");
        assert!(msg.contains("\"nope\""), "{msg}");
        assert!(msg.contains("xorgens|mtgp"), "{msg}");
        // Converts into the crate error via the std::error::Error blanket.
        let err: crate::util::error::Error = e.into();
        assert!(format!("{err}").contains("generator kind"));
    }

    #[test]
    fn enums_parse_through_opt_parse() {
        use crate::coordinator::BackendKind;
        use crate::prng::GeneratorKind;
        let a = parse(&["gen", "--gen", "mtgp", "--backend", "xla"]);
        assert_eq!(a.opt_parse::<GeneratorKind>("gen").unwrap(), Some(GeneratorKind::Mtgp));
        assert_eq!(a.opt_parse::<BackendKind>("backend").unwrap(), Some(BackendKind::Pjrt));
        let bad = parse(&["gen", "--gen", "nope"]);
        let err = bad.opt_parse::<GeneratorKind>("gen").unwrap_err();
        assert!(err.contains("--gen") && err.contains("expected one of"), "{err}");
    }
}

//! Vectorized generator round kernels.
//!
//! Each kernel is written once, generically over [`U32xN`], and mirrors its
//! scalar counterpart in `prng/` *statement for statement*: same read set,
//! same temporary `new` staging buffer, same end-of-round state roll. The
//! lanes it packs are independent sub-generators (intra-block recurrence
//! lanes for xorgensGP/MTGP, whole blocks for XORWOW's lane-width-1 SoA
//! layout), so vectorization is a pure data-layout transform and the output
//! is bit-identical to the scalar stream — the contract the `rust/tests/simd.rs`
//! proptests and golden pins enforce.
//!
//! Per-ISA entry points are thin monomorphizations; the AVX2 ones carry
//! `#[target_feature(enable = "avx2")]` so the compiler may use VEX forms
//! throughout, and are only reachable once `simd::detect()` has observed
//! AVX2 at runtime. The generic bodies are `#[inline(always)]` so they fuse
//! into the feature-enabled frame.

use super::vec::U32x1;
#[cfg(target_arch = "aarch64")]
use super::vec::U32x4Neon;
#[cfg(target_arch = "x86_64")]
use super::vec::{U32x4Sse2, U32x8Avx2};
use super::vec::U32xN;
use super::SimdKernel;
use crate::prng::mt19937::{M, N};
use crate::prng::params::XorgensParams;
use crate::prng::weyl::{WEYL_32, WEYL_GAMMA};

/// MTGP intra-block parallel degree (`prng::mtgp::LANE`).
const MT_LANE: usize = N - M;

// ---------------------------------------------------------------------------
// xorgensGP: one block, one round — `prng::xorgens_gp::round_block` shape.
// ---------------------------------------------------------------------------

/// Vector core for one xorgensGP block round.
///
/// Lane `j` reads `x[j]` (= x_{k+j-r}) and `x[r-s+j]` (= x_{k+j-s}); with
/// `lane = min(s, r-s)` both read windows lie entirely in the pre-round
/// state, so packing `V::LANES` adjacent lanes per instruction reads and
/// writes exactly what the scalar loop does. The per-lane Weyl value
/// `w0 + ω·(j+1)` is carried as a vector ramp advanced by `ω·LANES` adds —
/// no 32-bit SIMD multiply needed.
#[inline(always)]
fn xorgens_round_v<V: U32xN>(
    params: &XorgensParams,
    lane: usize,
    x: &mut [u32],
    w: &mut u32,
    out: &mut [u32],
) {
    let (r, s) = (params.r, params.s);
    let (a, b, c, d) = (params.a, params.b, params.c, params.d);
    let w0 = *w;
    let mut new = [0u32; 64];
    let new = &mut new[..lane];

    // Ramp start [ω·1, ..., ω·LANES]; 8 covers the widest backend.
    debug_assert!(V::LANES <= 8);
    let mut ramp0 = [0u32; 8];
    for (i, slot) in ramp0.iter_mut().enumerate() {
        *slot = WEYL_32.wrapping_mul(i as u32 + 1);
    }
    let mut ramp = V::load(&ramp0);
    let ramp_step = V::splat(WEYL_32.wrapping_mul(V::LANES as u32));
    let wbase = V::splat(w0);

    let mut j = 0;
    while j + V::LANES <= lane {
        let mut t = V::load(&x[j..]);
        let mut v = V::load(&x[r - s + j..]);
        t = t.xor(t.shl(a));
        t = t.xor(t.shr(b));
        v = v.xor(v.shl(c));
        v = v.xor(v.shr(d));
        let n = v.xor(t);
        n.store(&mut new[j..]);
        let wv = wbase.add(ramp);
        n.add(wv.xor(wv.shr(WEYL_GAMMA))).store(&mut out[j..]);
        ramp = ramp.add(ramp_step);
        j += V::LANES;
    }
    while j < lane {
        let mut t = x[j];
        let mut v = x[r - s + j];
        t ^= t << a;
        t ^= t >> b;
        v ^= v << c;
        v ^= v >> d;
        let n = v ^ t;
        new[j] = n;
        let wv = w0.wrapping_add(WEYL_32.wrapping_mul(j as u32 + 1));
        out[j] = n.wrapping_add(wv ^ (wv >> WEYL_GAMMA));
        j += 1;
    }

    x.copy_within(lane.., 0);
    x[r - lane..].copy_from_slice(new);
    *w = w0.wrapping_add(WEYL_32.wrapping_mul(lane as u32));
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn xorgens_round_avx2(
    params: &XorgensParams,
    lane: usize,
    x: &mut [u32],
    w: &mut u32,
    out: &mut [u32],
) {
    xorgens_round_v::<U32x8Avx2>(params, lane, x, w, out)
}

/// Dispatch one xorgensGP block round to the selected kernel.
///
/// `Scalar` (and any kernel foreign to this architecture — unreachable via
/// the clamped selector) runs the one-lane generic body, bit-identical to
/// the generator's own loop.
pub(crate) fn xorgens_round(
    k: SimdKernel,
    params: &XorgensParams,
    lane: usize,
    x: &mut [u32],
    w: &mut u32,
    out: &mut [u32],
) {
    match k {
        #[cfg(target_arch = "x86_64")]
        SimdKernel::Sse2 => xorgens_round_v::<U32x4Sse2>(params, lane, x, w, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the selector only yields Avx2 after runtime detection.
        SimdKernel::Avx2 => unsafe { xorgens_round_avx2(params, lane, x, w, out) },
        #[cfg(target_arch = "aarch64")]
        SimdKernel::Neon => xorgens_round_v::<U32x4Neon>(params, lane, x, w, out),
        _ => xorgens_round_v::<U32x1>(params, lane, x, w, out),
    }
}

// ---------------------------------------------------------------------------
// MTGP: one block, one round — `prng::mtgp::round_block` shape.
// ---------------------------------------------------------------------------

/// Vector core for one MTGP block round (twist + temper + roll).
///
/// Lane `j < N − M` reads `q[j]`, `q[j+1]`, `q[j+M]` — all pre-round values
/// — so contiguous-lane packing needs only three unaligned loads per step.
/// The conditional MATRIX_A xor is the branchless `(y & 1).wrapping_neg()`
/// mask, expressed as `0 - (y & 1)` lanewise.
#[inline(always)]
fn mtgp_round_v<V: U32xN>(q: &mut [u32], out: &mut [u32]) {
    const MATRIX_A: u32 = 0x9908_b0df;
    let mut new = [0u32; MT_LANE];
    let zero = V::splat(0);
    let one = V::splat(1);
    let upper = V::splat(0x8000_0000);
    let lower = V::splat(0x7fff_ffff);
    let ma = V::splat(MATRIX_A);
    let tm1 = V::splat(0x9d2c_5680);
    let tm2 = V::splat(0xefc6_0000);

    let mut j = 0;
    while j + V::LANES <= MT_LANE {
        let qj = V::load(&q[j..]);
        let qj1 = V::load(&q[j + 1..]);
        let qm = V::load(&q[j + M..]);
        let y = qj.and(upper).or(qj1.and(lower));
        let n = qm.xor(y.shr(1)).xor(zero.sub(y.and(one)).and(ma));
        n.store(&mut new[j..]);
        // Mt19937::temper, lanewise.
        let mut t = n;
        t = t.xor(t.shr(11));
        t = t.xor(t.shl(7).and(tm1));
        t = t.xor(t.shl(15).and(tm2));
        t = t.xor(t.shr(18));
        t.store(&mut out[j..]);
        j += V::LANES;
    }
    while j < MT_LANE {
        let y = (q[j] & 0x8000_0000) | (q[j + 1] & 0x7fff_ffff);
        let n = q[j + M] ^ (y >> 1) ^ ((y & 1).wrapping_neg() & MATRIX_A);
        new[j] = n;
        out[j] = crate::prng::Mt19937::temper(n);
        j += 1;
    }

    q.copy_within(MT_LANE.., 0);
    q[N - MT_LANE..].copy_from_slice(&new);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mtgp_round_avx2(q: &mut [u32], out: &mut [u32]) {
    mtgp_round_v::<U32x8Avx2>(q, out)
}

/// Dispatch one MTGP block round to the selected kernel.
pub(crate) fn mtgp_round(k: SimdKernel, q: &mut [u32], out: &mut [u32]) {
    match k {
        #[cfg(target_arch = "x86_64")]
        SimdKernel::Sse2 => mtgp_round_v::<U32x4Sse2>(q, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the selector only yields Avx2 after runtime detection.
        SimdKernel::Avx2 => unsafe { mtgp_round_avx2(q, out) },
        #[cfg(target_arch = "aarch64")]
        SimdKernel::Neon => mtgp_round_v::<U32x4Neon>(q, out),
        _ => mtgp_round_v::<U32x1>(q, out),
    }
}

// ---------------------------------------------------------------------------
// XORWOW: one round across a block range — `XorwowBlock::step_all` shape.
// ---------------------------------------------------------------------------

/// Vector core for one XORWOW round over `out.len()` blocks.
///
/// XORWOW is lane-width 1 with SoA state, so the vector runs *across
/// blocks*: `t_arr`/`v_arr` are the rotating `x_{k-1}`/`x_{k-5}` columns
/// (always distinct arrays — phase and phase+4 never coincide mod 5) and
/// `d` the Weyl counters. Purely elementwise; loads precede the lane's
/// store exactly as in the scalar loop.
#[inline(always)]
fn xorwow_step_v<V: U32xN>(
    t_arr: &mut [u32],
    v_arr: &[u32],
    d: &mut [u32],
    out: &mut [u32],
    weyl: u32,
) {
    let nblocks = out.len();
    debug_assert!(t_arr.len() >= nblocks && v_arr.len() >= nblocks && d.len() >= nblocks);
    let wv = V::splat(weyl);

    let mut b = 0;
    while b + V::LANES <= nblocks {
        let x0 = V::load(&t_arr[b..]);
        let t = x0.xor(x0.shr(2));
        let vp = V::load(&v_arr[b..]);
        let v = vp.xor(vp.shl(4)).xor(t.xor(t.shl(1)));
        v.store(&mut t_arr[b..]);
        let dv = V::load(&d[b..]).add(wv);
        dv.store(&mut d[b..]);
        dv.add(v).store(&mut out[b..]);
        b += V::LANES;
    }
    while b < nblocks {
        let x0 = t_arr[b];
        let t = x0 ^ (x0 >> 2);
        let vp = v_arr[b];
        let v = (vp ^ (vp << 4)) ^ (t ^ (t << 1));
        t_arr[b] = v;
        let dv = d[b].wrapping_add(weyl);
        d[b] = dv;
        out[b] = dv.wrapping_add(v);
        b += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn xorwow_step_avx2(
    t_arr: &mut [u32],
    v_arr: &[u32],
    d: &mut [u32],
    out: &mut [u32],
    weyl: u32,
) {
    xorwow_step_v::<U32x8Avx2>(t_arr, v_arr, d, out, weyl)
}

/// Dispatch one XORWOW round (across blocks) to the selected kernel.
pub(crate) fn xorwow_step(
    k: SimdKernel,
    t_arr: &mut [u32],
    v_arr: &[u32],
    d: &mut [u32],
    out: &mut [u32],
    weyl: u32,
) {
    match k {
        #[cfg(target_arch = "x86_64")]
        SimdKernel::Sse2 => xorwow_step_v::<U32x4Sse2>(t_arr, v_arr, d, out, weyl),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the selector only yields Avx2 after runtime detection.
        SimdKernel::Avx2 => unsafe { xorwow_step_avx2(t_arr, v_arr, d, out, weyl) },
        #[cfg(target_arch = "aarch64")]
        SimdKernel::Neon => xorwow_step_v::<U32x4Neon>(t_arr, v_arr, d, out, weyl),
        _ => xorwow_step_v::<U32x1>(t_arr, v_arr, d, out, weyl),
    }
}

// ---------------------------------------------------------------------------
// u32 → unit f32 bulk transform (`distributions::unit_f32`, sliced).
// ---------------------------------------------------------------------------

/// 2⁻²⁴ — the `unit_f32` scale factor.
const F32_SCALE: f32 = 1.0 / 16_777_216.0;

fn unit_f32_tail(src: &[u32], dst: &mut [f32]) {
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d = crate::prng::distributions::unit_f32(s);
    }
}

/// Exactness argument shared by every backend below: after `>> 8` each lane
/// holds an integer `m < 2²⁴`, which an i32→f32 convert represents exactly
/// (and non-negatively, so the *signed* x86 convert is safe); multiplying
/// an exact `m` by the power of two 2⁻²⁴ is again exact under any IEEE
/// rounding mode. Hence every backend produces the identical bit pattern
/// to `unit_f32`.
#[cfg(target_arch = "x86_64")]
fn unit_f32_slice_sse2(src: &[u32], dst: &mut [f32]) {
    use core::arch::x86_64::*;
    let n = src.len();
    let mut i = 0;
    // SAFETY: SSE2 baseline; loads/stores stay within `i + 4 <= n`.
    unsafe {
        let scale = _mm_set1_ps(F32_SCALE);
        while i + 4 <= n {
            let v = _mm_loadu_si128(src[i..].as_ptr() as *const __m128i);
            let f = _mm_mul_ps(_mm_cvtepi32_ps(_mm_srli_epi32(v, 8)), scale);
            _mm_storeu_ps(dst[i..].as_mut_ptr(), f);
            i += 4;
        }
    }
    unit_f32_tail(&src[i..], &mut dst[i..n]);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn unit_f32_slice_avx2(src: &[u32], dst: &mut [f32]) {
    use core::arch::x86_64::*;
    let n = src.len();
    let mut i = 0;
    let scale = _mm256_set1_ps(F32_SCALE);
    while i + 8 <= n {
        let v = _mm256_loadu_si256(src[i..].as_ptr() as *const __m256i);
        let f = _mm256_mul_ps(_mm256_cvtepi32_ps(_mm256_srli_epi32(v, 8)), scale);
        _mm256_storeu_ps(dst[i..].as_mut_ptr(), f);
        i += 8;
    }
    unit_f32_tail(&src[i..], &mut dst[i..n]);
}

#[cfg(target_arch = "aarch64")]
fn unit_f32_slice_neon(src: &[u32], dst: &mut [f32]) {
    use core::arch::aarch64::*;
    let n = src.len();
    let mut i = 0;
    // SAFETY: NEON baseline; loads/stores stay within `i + 4 <= n`.
    unsafe {
        while i + 4 <= n {
            let v = vld1q_u32(src[i..].as_ptr());
            let f = vmulq_n_f32(vcvtq_f32_u32(vshrq_n_u32(v, 8)), F32_SCALE);
            vst1q_f32(dst[i..].as_mut_ptr(), f);
            i += 4;
        }
    }
    unit_f32_tail(&src[i..], &mut dst[i..n]);
}

/// Dispatch the bulk u32 → unit-f32 map to the selected kernel.
///
/// `dst` and `src` must be the same length (the public wrapper in
/// `distributions` asserts this).
pub(crate) fn unit_f32_slice(k: SimdKernel, src: &[u32], dst: &mut [f32]) {
    match k {
        #[cfg(target_arch = "x86_64")]
        SimdKernel::Sse2 => unit_f32_slice_sse2(src, dst),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the selector only yields Avx2 after runtime detection.
        SimdKernel::Avx2 => unsafe { unit_f32_slice_avx2(src, dst) },
        #[cfg(target_arch = "aarch64")]
        SimdKernel::Neon => unit_f32_slice_neon(src, dst),
        _ => unit_f32_tail(src, dst),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic word soup (SplitMix-ish) for kernel inputs.
    fn words(seed: u64, n: usize) -> Vec<u32> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_add(0x9e37_79b9_7f4a_7c15);
                let mut z = s;
                z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
                (z ^ (z >> 31)) as u32
            })
            .collect()
    }

    /// Scalar xorgensGP round reference — transcribed from
    /// `prng::xorgens_gp::round_block` (the integration tests in
    /// rust/tests/simd.rs pin against the real generator; this guards the
    /// kernel bodies in isolation).
    fn xorgens_round_ref(
        p: &XorgensParams,
        lane: usize,
        x: &mut [u32],
        w: &mut u32,
        out: &mut [u32],
    ) {
        let w0 = *w;
        let mut new = vec![0u32; lane];
        for j in 0..lane {
            let mut t = x[j];
            let mut v = x[p.r - p.s + j];
            t ^= t << p.a;
            t ^= t >> p.b;
            v ^= v << p.c;
            v ^= v >> p.d;
            new[j] = v ^ t;
        }
        for (j, (&n, o)) in new.iter().zip(out.iter_mut()).enumerate() {
            let wv = w0.wrapping_add(WEYL_32.wrapping_mul(j as u32 + 1));
            *o = n.wrapping_add(wv ^ (wv >> WEYL_GAMMA));
        }
        x.copy_within(lane.., 0);
        x[p.r - lane..].copy_from_slice(&new);
        *w = w0.wrapping_add(WEYL_32.wrapping_mul(lane as u32));
    }

    fn check_xorgens_kernel(k: SimdKernel) {
        for p in [XorgensParams::GP_4096, XorgensParams::BRENT_4096, XorgensParams::TEST_64] {
            let lane = p.parallel_degree();
            let mut xa = words(11 + p.r as u64, p.r);
            let mut xb = xa.clone();
            let (mut wa, mut wb) = (0x1234_5678u32, 0x1234_5678u32);
            let mut oa = vec![0u32; lane];
            let mut ob = vec![0u32; lane];
            for round in 0..8 {
                xorgens_round_ref(&p, lane, &mut xa, &mut wa, &mut oa);
                xorgens_round(k, &p, lane, &mut xb, &mut wb, &mut ob);
                assert_eq!(oa, ob, "out, {k:?} r={} round={round}", p.r);
                assert_eq!(xa, xb, "state, {k:?} r={} round={round}", p.r);
                assert_eq!(wa, wb, "weyl, {k:?} r={} round={round}", p.r);
            }
        }
    }

    fn check_mtgp_kernel(k: SimdKernel) {
        let mut qa = words(7, N);
        let mut qb = qa.clone();
        let mut oa = vec![0u32; MT_LANE];
        let mut ob = vec![0u32; MT_LANE];
        for round in 0..6 {
            // Reference: the one-lane generic body (pinned against the real
            // generator by mtgp_simd tests in rust/tests/simd.rs).
            mtgp_round_v::<U32x1>(&mut qa, &mut oa);
            mtgp_round(k, &mut qb, &mut ob);
            assert_eq!(oa, ob, "out, {k:?} round={round}");
            assert_eq!(qa, qb, "state, {k:?} round={round}");
        }
    }

    fn check_xorwow_kernel(k: SimdKernel) {
        for nblocks in [1usize, 3, 4, 7, 8, 17, 64] {
            let mut ta = words(1, nblocks);
            let mut va = words(2, nblocks);
            let mut da = words(3, nblocks);
            let (mut tb, mut vb, mut db) = (ta.clone(), va.clone(), da.clone());
            let mut oa = vec![0u32; nblocks];
            let mut ob = vec![0u32; nblocks];
            for round in 0..5 {
                xorwow_step_v::<U32x1>(&mut ta, &va, &mut da, &mut oa, 362437);
                xorwow_step(k, &mut tb, &vb, &mut db, &mut ob, 362437);
                assert_eq!(oa, ob, "out, {k:?} blocks={nblocks} round={round}");
                assert_eq!((&ta, &va, &da), (&tb, &vb, &db), "state, {k:?} blocks={nblocks}");
            }
        }
    }

    fn check_unit_f32_kernel(k: SimdKernel) {
        for n in [0usize, 1, 3, 4, 5, 8, 31, 100] {
            let src = words(42, n);
            let mut dst = vec![0f32; n];
            unit_f32_slice(k, &src, &mut dst);
            for (i, (&u, &f)) in src.iter().zip(dst.iter()).enumerate() {
                assert_eq!(
                    f.to_bits(),
                    crate::prng::distributions::unit_f32(u).to_bits(),
                    "{k:?} n={n} i={i}"
                );
            }
        }
    }

    fn each_available(f: impl Fn(SimdKernel)) {
        for k in crate::simd::available_kernels() {
            f(k);
        }
    }

    #[test]
    fn xorgens_kernels_match_reference() {
        each_available(check_xorgens_kernel);
    }

    #[test]
    fn mtgp_kernels_match_reference() {
        each_available(check_mtgp_kernel);
    }

    #[test]
    fn xorwow_kernels_match_reference() {
        each_available(check_xorwow_kernel);
    }

    #[test]
    fn unit_f32_kernels_match_reference() {
        each_available(check_unit_f32_kernel);
    }
}

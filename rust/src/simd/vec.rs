//! Portable 32-bit-lane vector abstraction over `core::arch`.
//!
//! Each implementor packs `LANES` independent `u32` values and provides the
//! exact operation set the generator recurrences need: XOR, AND, OR,
//! wrapping add/sub, and logical shifts by a *runtime* count (the xorgens
//! shift constants live in [`crate::prng::params::XorgensParams`], so they
//! are not compile-time constants here).
//!
//! Lane semantics are bit-identical to the scalar `u32` operators on every
//! backend — this is what makes the SIMD kernels a pure data-layout
//! transform (see [`crate::simd`] for the contract).
//!
//! # Safety model
//!
//! The intrinsic-backed types wrap `unsafe` intrinsic calls in safe methods.
//! That is sound only under the module's dispatch invariant: a vector type
//! is only ever *instantiated* on a code path guarded by the matching ISA
//! check ([`crate::simd::SimdKernel::is_available`]). SSE2 is part of the
//! `x86_64` baseline and NEON is part of the `aarch64` baseline, so
//! [`U32x4Sse2`] / [`U32x4Neon`] are unconditionally sound on their
//! architectures; [`U32x8Avx2`] additionally requires the runtime AVX2
//! check, which `simd::detect()` performs before the kernel selector can
//! ever return [`crate::simd::SimdKernel::Avx2`].

/// `LANES` independent `u32` lanes with scalar-identical semantics.
///
/// `load`/`store` are unaligned and panic (via slice indexing) if the slice
/// holds fewer than `LANES` words — kernels only call them on ranges they
/// have already bounds-checked against the lane count.
pub(crate) trait U32xN: Copy {
    const LANES: usize;

    fn splat(v: u32) -> Self;
    fn load(src: &[u32]) -> Self;
    fn store(self, dst: &mut [u32]);
    fn xor(self, o: Self) -> Self;
    fn and(self, o: Self) -> Self;
    fn or(self, o: Self) -> Self;
    /// Lanewise wrapping add.
    fn add(self, o: Self) -> Self;
    /// Lanewise wrapping sub.
    fn sub(self, o: Self) -> Self;
    /// Lanewise logical shift left; `n` must be in `0..32`.
    fn shl(self, n: u32) -> Self;
    /// Lanewise logical shift right; `n` must be in `0..32`.
    fn shr(self, n: u32) -> Self;
}

/// One-lane reference implementation.
///
/// Never selected by the runtime dispatcher (the scalar kernel choice routes
/// to the generators' original loops), but it lets the generic kernels be
/// unit-tested against the scalar reference on any architecture, proving the
/// *kernel structure* correct independently of any ISA backend.
#[derive(Clone, Copy, Debug)]
pub(crate) struct U32x1(pub u32);

impl U32xN for U32x1 {
    const LANES: usize = 1;

    #[inline(always)]
    fn splat(v: u32) -> Self {
        Self(v)
    }
    #[inline(always)]
    fn load(src: &[u32]) -> Self {
        Self(src[0])
    }
    #[inline(always)]
    fn store(self, dst: &mut [u32]) {
        dst[0] = self.0;
    }
    #[inline(always)]
    fn xor(self, o: Self) -> Self {
        Self(self.0 ^ o.0)
    }
    #[inline(always)]
    fn and(self, o: Self) -> Self {
        Self(self.0 & o.0)
    }
    #[inline(always)]
    fn or(self, o: Self) -> Self {
        Self(self.0 | o.0)
    }
    #[inline(always)]
    fn add(self, o: Self) -> Self {
        Self(self.0.wrapping_add(o.0))
    }
    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        Self(self.0.wrapping_sub(o.0))
    }
    #[inline(always)]
    fn shl(self, n: u32) -> Self {
        Self(self.0 << n)
    }
    #[inline(always)]
    fn shr(self, n: u32) -> Self {
        Self(self.0 >> n)
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::U32xN;
    use core::arch::x86_64::*;

    /// Four lanes over SSE2 (unconditional on the x86_64 baseline).
    #[derive(Clone, Copy)]
    pub(crate) struct U32x4Sse2(__m128i);

    impl U32xN for U32x4Sse2 {
        const LANES: usize = 4;

        #[inline(always)]
        fn splat(v: u32) -> Self {
            // SAFETY: SSE2 is part of the x86_64 baseline feature set.
            Self(unsafe { _mm_set1_epi32(v as i32) })
        }
        #[inline(always)]
        fn load(src: &[u32]) -> Self {
            let src = &src[..4];
            // SAFETY: `src` holds >= 4 words; unaligned load.
            Self(unsafe { _mm_loadu_si128(src.as_ptr() as *const __m128i) })
        }
        #[inline(always)]
        fn store(self, dst: &mut [u32]) {
            let dst = &mut dst[..4];
            // SAFETY: `dst` holds >= 4 words; unaligned store.
            unsafe { _mm_storeu_si128(dst.as_mut_ptr() as *mut __m128i, self.0) }
        }
        #[inline(always)]
        fn xor(self, o: Self) -> Self {
            Self(unsafe { _mm_xor_si128(self.0, o.0) })
        }
        #[inline(always)]
        fn and(self, o: Self) -> Self {
            Self(unsafe { _mm_and_si128(self.0, o.0) })
        }
        #[inline(always)]
        fn or(self, o: Self) -> Self {
            Self(unsafe { _mm_or_si128(self.0, o.0) })
        }
        #[inline(always)]
        fn add(self, o: Self) -> Self {
            Self(unsafe { _mm_add_epi32(self.0, o.0) })
        }
        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            Self(unsafe { _mm_sub_epi32(self.0, o.0) })
        }
        #[inline(always)]
        fn shl(self, n: u32) -> Self {
            // `sll` takes the count from the low 64 bits of a vector, which
            // is how a runtime (non-immediate) per-call shift is expressed.
            Self(unsafe { _mm_sll_epi32(self.0, _mm_cvtsi32_si128(n as i32)) })
        }
        #[inline(always)]
        fn shr(self, n: u32) -> Self {
            Self(unsafe { _mm_srl_epi32(self.0, _mm_cvtsi32_si128(n as i32)) })
        }
    }

    /// Eight lanes over AVX2.
    ///
    /// Only instantiated behind `is_x86_feature_detected!("avx2")` (see the
    /// module safety notes).
    #[derive(Clone, Copy)]
    pub(crate) struct U32x8Avx2(__m256i);

    impl U32xN for U32x8Avx2 {
        const LANES: usize = 8;

        #[inline(always)]
        fn splat(v: u32) -> Self {
            // SAFETY (this and every method below): callers only reach this
            // type through kernels gated on runtime AVX2 detection.
            Self(unsafe { _mm256_set1_epi32(v as i32) })
        }
        #[inline(always)]
        fn load(src: &[u32]) -> Self {
            let src = &src[..8];
            Self(unsafe { _mm256_loadu_si256(src.as_ptr() as *const __m256i) })
        }
        #[inline(always)]
        fn store(self, dst: &mut [u32]) {
            let dst = &mut dst[..8];
            unsafe { _mm256_storeu_si256(dst.as_mut_ptr() as *mut __m256i, self.0) }
        }
        #[inline(always)]
        fn xor(self, o: Self) -> Self {
            Self(unsafe { _mm256_xor_si256(self.0, o.0) })
        }
        #[inline(always)]
        fn and(self, o: Self) -> Self {
            Self(unsafe { _mm256_and_si256(self.0, o.0) })
        }
        #[inline(always)]
        fn or(self, o: Self) -> Self {
            Self(unsafe { _mm256_or_si256(self.0, o.0) })
        }
        #[inline(always)]
        fn add(self, o: Self) -> Self {
            Self(unsafe { _mm256_add_epi32(self.0, o.0) })
        }
        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            Self(unsafe { _mm256_sub_epi32(self.0, o.0) })
        }
        #[inline(always)]
        fn shl(self, n: u32) -> Self {
            Self(unsafe { _mm256_sll_epi32(self.0, _mm_cvtsi32_si128(n as i32)) })
        }
        #[inline(always)]
        fn shr(self, n: u32) -> Self {
            Self(unsafe { _mm256_srl_epi32(self.0, _mm_cvtsi32_si128(n as i32)) })
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) use x86::{U32x4Sse2, U32x8Avx2};

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::U32xN;
    use core::arch::aarch64::*;

    /// Four lanes over NEON (unconditional on the aarch64 baseline).
    #[derive(Clone, Copy)]
    pub(crate) struct U32x4Neon(uint32x4_t);

    impl U32xN for U32x4Neon {
        const LANES: usize = 4;

        #[inline(always)]
        fn splat(v: u32) -> Self {
            // SAFETY: NEON is part of the aarch64 baseline feature set.
            Self(unsafe { vdupq_n_u32(v) })
        }
        #[inline(always)]
        fn load(src: &[u32]) -> Self {
            let src = &src[..4];
            // SAFETY: `src` holds >= 4 words; vld1q is unaligned-tolerant.
            Self(unsafe { vld1q_u32(src.as_ptr()) })
        }
        #[inline(always)]
        fn store(self, dst: &mut [u32]) {
            let dst = &mut dst[..4];
            unsafe { vst1q_u32(dst.as_mut_ptr(), self.0) }
        }
        #[inline(always)]
        fn xor(self, o: Self) -> Self {
            Self(unsafe { veorq_u32(self.0, o.0) })
        }
        #[inline(always)]
        fn and(self, o: Self) -> Self {
            Self(unsafe { vandq_u32(self.0, o.0) })
        }
        #[inline(always)]
        fn or(self, o: Self) -> Self {
            Self(unsafe { vorrq_u32(self.0, o.0) })
        }
        #[inline(always)]
        fn add(self, o: Self) -> Self {
            Self(unsafe { vaddq_u32(self.0, o.0) })
        }
        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            Self(unsafe { vsubq_u32(self.0, o.0) })
        }
        #[inline(always)]
        fn shl(self, n: u32) -> Self {
            // VSHL with a positive per-lane count is a left shift...
            Self(unsafe { vshlq_u32(self.0, vdupq_n_s32(n as i32)) })
        }
        #[inline(always)]
        fn shr(self, n: u32) -> Self {
            // ...and with a negative count a logical right shift.
            Self(unsafe { vshlq_u32(self.0, vdupq_n_s32(-(n as i32))) })
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub(crate) use arm::U32x4Neon;

#[cfg(test)]
mod tests {
    use super::*;

    // Exercise every op on every lane of a backend against the scalar u32
    // semantics. The inputs mix sign-bit-set values, zeros, and odd bit
    // patterns so signed-vs-unsigned confusions (add/sub/shr on x86's
    // signed-flavoured intrinsics) would be caught.
    fn exercise<V: U32xN>() {
        let pat: [u32; 16] = [
            0, 1, 0xffff_ffff, 0x8000_0000, 0x7fff_ffff, 0xdead_beef, 0x0123_4567, 0x89ab_cdef,
            0x6161_6161, 0x9908_b0df, 0x61c8_8647, 2, 3, 0xfffe_0001, 0x0000_ff00, 0xa5a5_a5a5,
        ];
        let other: [u32; 16] = [
            0xffff_ffff, 0x8000_0000, 1, 0x7fff_ffff, 0x1357_9bdf, 5, 0x8000_0001, 0,
            0xcafe_f00d, 7, 0x0f0f_0f0f, 0xf0f0_f0f0, 11, 13, 0x5555_5555, 0xaaaa_aaaa,
        ];
        assert!(V::LANES <= 16);
        let a = V::load(&pat);
        let b = V::load(&other);
        let mut got = [0u32; 16];

        a.xor(b).store(&mut got);
        for i in 0..V::LANES {
            assert_eq!(got[i], pat[i] ^ other[i], "xor lane {i}");
        }
        a.and(b).store(&mut got);
        for i in 0..V::LANES {
            assert_eq!(got[i], pat[i] & other[i], "and lane {i}");
        }
        a.or(b).store(&mut got);
        for i in 0..V::LANES {
            assert_eq!(got[i], pat[i] | other[i], "or lane {i}");
        }
        a.add(b).store(&mut got);
        for i in 0..V::LANES {
            assert_eq!(got[i], pat[i].wrapping_add(other[i]), "add lane {i}");
        }
        a.sub(b).store(&mut got);
        for i in 0..V::LANES {
            assert_eq!(got[i], pat[i].wrapping_sub(other[i]), "sub lane {i}");
        }
        for n in [1u32, 2, 7, 8, 15, 16, 17, 31] {
            a.shl(n).store(&mut got);
            for i in 0..V::LANES {
                assert_eq!(got[i], pat[i] << n, "shl({n}) lane {i}");
            }
            a.shr(n).store(&mut got);
            for i in 0..V::LANES {
                assert_eq!(got[i], pat[i] >> n, "shr({n}) lane {i}");
            }
        }
        V::splat(0x6161_6161).store(&mut got);
        for i in 0..V::LANES {
            assert_eq!(got[i], 0x6161_6161, "splat lane {i}");
        }
    }

    #[test]
    fn scalar_reference_lane() {
        exercise::<U32x1>();
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse2_lanes_match_scalar_ops() {
        exercise::<U32x4Sse2>();
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_lanes_match_scalar_ops() {
        if std::arch::is_x86_feature_detected!("avx2") {
            exercise::<U32x8Avx2>();
        }
    }

    #[cfg(target_arch = "aarch64")]
    #[test]
    fn neon_lanes_match_scalar_ops() {
        exercise::<U32x4Neon>();
    }
}

//! SIMD fill kernels: vectorized multi-lane generator cores with runtime
//! dispatch.
//!
//! The paper's central observation is that xorshift-class recurrences map
//! onto wide-lane hardware because every lane advances an *independent*
//! sub-generator with nothing but XORs, shifts, and adds (§2). On the GPU
//! that lane is a CUDA thread; here it is a SIMD lane. This module is the
//! CPU analogue of the paper's warp: [`kernels`] packs `min(s, r−s)`
//! xorgensGP recurrence lanes (or MTGP twist lanes, or whole XORWOW blocks)
//! into `core::arch` vectors, and the selector below picks the widest
//! instruction set the CPU offers at runtime.
//!
//! # Bit-identity contract
//!
//! SIMD lanes are independent sub-generators, so vectorization is a pure
//! data-layout transform: **every kernel produces the exact scalar stream**
//! for every generator kind, seed, and placement. Golden vectors, placed
//! substreams, cluster wire pins, and the threaded fill engine are all
//! unaffected by the kernel choice — which is also what makes the
//! process-wide selector safe to flip at any time.
//!
//! # Selection
//!
//! * `auto` (default): widest available — AVX2 (8 lanes) else SSE2 (4, the
//!   x86_64 baseline) on x86_64; NEON (4, the aarch64 baseline) on aarch64;
//!   scalar elsewhere.
//! * `XORGENSGP_SIMD=auto|scalar|sse2|avx2|neon` — process-wide env
//!   override, read on first use.
//! * `serve --simd KERNEL` / `bench --simd KERNEL` — CLI override via
//!   [`set_forced`] (wins over the env var).
//!
//! Forcing a kernel the CPU cannot run falls back to the best available
//! one with a warning on stderr, mirroring the coordinator's env-knob
//! handling. The `scalar` choice routes to the generators' original loops,
//! untouched by this subsystem.
//!
//! Selection composes with the rest of the stack: the kernels run inside
//! [`crate::exec::RangeFill`] parts, so SIMD × `fill_threads` ×
//! prefetch multiply. Observability surfaces the active kernel and
//! per-kernel fill counts as the `xg_simd_active_kernel` /
//! `xg_simd_fills_total` families.

pub(crate) mod kernels;
mod vec;

use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use crate::util::cli::ParseEnumError;

/// One vector instruction-set backend for the fill kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SimdKernel {
    /// The generators' original scalar loops (always available).
    Scalar,
    /// 4 × u32 over SSE2 (x86_64 baseline).
    Sse2,
    /// 8 × u32 over AVX2 (x86_64, runtime-detected).
    Avx2,
    /// 4 × u32 over NEON (aarch64 baseline).
    Neon,
}

impl SimdKernel {
    /// Every kernel, in counter/display order.
    pub const ALL: [SimdKernel; 4] =
        [SimdKernel::Scalar, SimdKernel::Sse2, SimdKernel::Avx2, SimdKernel::Neon];

    pub fn name(self) -> &'static str {
        match self {
            SimdKernel::Scalar => "scalar",
            SimdKernel::Sse2 => "sse2",
            SimdKernel::Avx2 => "avx2",
            SimdKernel::Neon => "neon",
        }
    }

    /// u32 lanes advanced per instruction.
    pub fn width(self) -> u32 {
        match self {
            SimdKernel::Scalar => 1,
            SimdKernel::Sse2 | SimdKernel::Neon => 4,
            SimdKernel::Avx2 => 8,
        }
    }

    /// Can this process execute the kernel?
    pub fn is_available(self) -> bool {
        match self {
            SimdKernel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            SimdKernel::Sse2 => true,
            #[cfg(target_arch = "x86_64")]
            SimdKernel::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            SimdKernel::Neon => true,
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    fn idx(self) -> usize {
        match self {
            SimdKernel::Scalar => 0,
            SimdKernel::Sse2 => 1,
            SimdKernel::Avx2 => 2,
            SimdKernel::Neon => 3,
        }
    }

    fn from_idx(i: u8) -> SimdKernel {
        Self::ALL[i as usize]
    }
}

impl fmt::Display for SimdKernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for SimdKernel {
    type Err = ParseEnumError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Ok(SimdKernel::Scalar),
            "sse2" => Ok(SimdKernel::Sse2),
            "avx2" => Ok(SimdKernel::Avx2),
            "neon" => Ok(SimdKernel::Neon),
            _ => Err(ParseEnumError::new("simd kernel", s, "scalar|sse2|avx2|neon")),
        }
    }
}

/// A kernel *choice*: either follow detection or force one kernel.
///
/// This is the value of the `XORGENSGP_SIMD` env var and the `--simd` CLI
/// flag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    /// Widest available kernel (the default).
    Auto,
    /// Force one specific kernel.
    Force(SimdKernel),
}

impl fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KernelChoice::Auto => f.write_str("auto"),
            KernelChoice::Force(k) => f.write_str(k.name()),
        }
    }
}

impl FromStr for KernelChoice {
    type Err = ParseEnumError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s.eq_ignore_ascii_case("auto") {
            return Ok(KernelChoice::Auto);
        }
        s.parse::<SimdKernel>()
            .map(KernelChoice::Force)
            .map_err(|_| ParseEnumError::new("simd kernel", s, "auto|scalar|sse2|avx2|neon"))
    }
}

/// Environment override, read once on first selection.
pub const SIMD_ENV: &str = "XORGENSGP_SIMD";

/// Best-detected kernel, cached after the first probe. 0 = unprobed, else
/// `idx + 1`.
static DETECTED: AtomicU8 = AtomicU8::new(0);

/// Process-wide selection state. 0 = uninitialized (env var not yet read),
/// 1 = auto, else `idx + 2` for a forced kernel.
static STATE: AtomicU8 = AtomicU8::new(0);

/// Per-kernel fill-dispatch counters, indexed by [`SimdKernel::idx`]. One
/// tick per `fill_round` call or per worker-part run — the granularity at
/// which the kernel is resolved.
static FILLS: [AtomicU64; 4] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];

/// Widest kernel this CPU can run (cached; never `Scalar` on
/// x86_64/aarch64, where SSE2/NEON are baseline).
pub fn detect() -> SimdKernel {
    let cached = DETECTED.load(Ordering::Relaxed);
    if cached != 0 {
        return SimdKernel::from_idx(cached - 1);
    }
    let best = if SimdKernel::Avx2.is_available() {
        SimdKernel::Avx2
    } else if SimdKernel::Sse2.is_available() {
        SimdKernel::Sse2
    } else if SimdKernel::Neon.is_available() {
        SimdKernel::Neon
    } else {
        SimdKernel::Scalar
    };
    DETECTED.store(best.idx() as u8 + 1, Ordering::Relaxed);
    best
}

/// Every kernel this process can execute (always starts with `Scalar`).
pub fn available_kernels() -> Vec<SimdKernel> {
    SimdKernel::ALL.iter().copied().filter(|k| k.is_available()).collect()
}

/// Clamp a choice to what the CPU offers, warning on stderr when a forced
/// kernel is unavailable (house style: warn and fall back, never abort —
/// mirrors `parse_env_usize`).
fn clamp(choice: KernelChoice, origin: &str) -> u8 {
    match choice {
        KernelChoice::Auto => 1,
        KernelChoice::Force(k) if k.is_available() => k.idx() as u8 + 2,
        KernelChoice::Force(k) => {
            let best = detect();
            eprintln!(
                "xorgens-gp: {origin}: simd kernel {:?} unavailable on this CPU; using {:?}",
                k.name(),
                best.name()
            );
            best.idx() as u8 + 2
        }
    }
}

/// First-use initialisation from `XORGENSGP_SIMD`. Unset or `auto` →
/// detection; unparsable values warn and fall back to auto.
fn init_from_env() -> u8 {
    let v = match std::env::var(SIMD_ENV) {
        Ok(v) if !v.trim().is_empty() => match v.trim().parse::<KernelChoice>() {
            Ok(choice) => clamp(choice, SIMD_ENV),
            Err(e) => {
                eprintln!("xorgens-gp: ignoring {SIMD_ENV}: {e}");
                1
            }
        },
        _ => 1,
    };
    // First writer wins; a racing thread that lost adopts the stored value.
    match STATE.compare_exchange(0, v, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => v,
        Err(cur) => cur,
    }
}

/// Force (or un-force, with [`KernelChoice::Auto`]) the process-wide kernel
/// selection; wins over the env var. Returns the kernel now in effect.
///
/// Safe to call at any time from any thread: every kernel emits the
/// identical stream, so in-flight fills are unaffected beyond which
/// instructions they retire.
pub fn set_forced(choice: KernelChoice) -> SimdKernel {
    STATE.store(clamp(choice, "--simd"), Ordering::Relaxed);
    active_kernel()
}

fn resolve() -> SimdKernel {
    let s = STATE.load(Ordering::Relaxed);
    let s = if s == 0 { init_from_env() } else { s };
    if s == 1 {
        detect()
    } else {
        SimdKernel::from_idx(s - 2)
    }
}

/// The kernel currently in effect (no counter side effects).
pub fn active_kernel() -> SimdKernel {
    resolve()
}

/// Resolve the kernel for one fill dispatch and count it. Generators call
/// this once per `fill_round` / per worker-part run, then thread the value
/// through their block loops.
pub(crate) fn fill_kernel() -> SimdKernel {
    let k = resolve();
    FILLS[k.idx()].fetch_add(1, Ordering::Relaxed);
    k
}

/// Cumulative fill dispatches per kernel, in [`SimdKernel::ALL`] order —
/// the `xg_simd_fills_total` exposition family.
pub fn fill_counts() -> [(SimdKernel, u64); 4] {
    let mut out = [(SimdKernel::Scalar, 0); 4];
    for (slot, k) in out.iter_mut().zip(SimdKernel::ALL) {
        *slot = (k, FILLS[k.idx()].load(Ordering::Relaxed));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for k in SimdKernel::ALL {
            assert_eq!(k.name().parse::<SimdKernel>().unwrap(), k);
            assert_eq!(format!("{k}").parse::<SimdKernel>().unwrap(), k);
        }
        assert_eq!("auto".parse::<KernelChoice>().unwrap(), KernelChoice::Auto);
        assert_eq!("AVX2".parse::<KernelChoice>().unwrap(), KernelChoice::Force(SimdKernel::Avx2));
        assert!("wide".parse::<KernelChoice>().is_err());
        assert!("wide".parse::<SimdKernel>().is_err());
    }

    #[test]
    fn widths() {
        assert_eq!(SimdKernel::Scalar.width(), 1);
        assert_eq!(SimdKernel::Sse2.width(), 4);
        assert_eq!(SimdKernel::Avx2.width(), 8);
        assert_eq!(SimdKernel::Neon.width(), 4);
    }

    #[test]
    fn scalar_always_available_and_detection_consistent() {
        assert!(SimdKernel::Scalar.is_available());
        let avail = available_kernels();
        assert_eq!(avail[0], SimdKernel::Scalar);
        // detect() must itself be in the available set.
        assert!(avail.contains(&detect()));
        #[cfg(target_arch = "x86_64")]
        assert!(avail.contains(&SimdKernel::Sse2));
        #[cfg(target_arch = "aarch64")]
        assert!(avail.contains(&SimdKernel::Neon));
        // Cached probe is stable.
        assert_eq!(detect(), detect());
    }

    #[test]
    fn fill_counts_cover_all_kernels_in_order() {
        let counts = fill_counts();
        for (slot, k) in counts.iter().zip(SimdKernel::ALL) {
            assert_eq!(slot.0, k);
        }
        // The counter array is live: a dispatch ticks the active kernel.
        // (Do NOT force a kernel here — unit tests share the process-wide
        // selector with every other in-crate test; rust/tests/simd.rs owns
        // the forcing tests behind a mutex.)
        let before = fill_counts();
        let active = fill_kernel();
        let after = fill_counts();
        let i = SimdKernel::ALL.iter().position(|&k| k == active).unwrap();
        // `>=`: other in-crate tests fill concurrently and tick it too.
        assert!(after[i].1 >= before[i].1 + 1);
    }
}

//! Generator traits and the kind registry.

/// A 32-bit pseudo-random generator (single logical stream).
pub trait Prng32 {
    /// Next raw 32-bit output.
    fn next_u32(&mut self) -> u32;

    /// Next 64-bit output (two 32-bit draws, low word first — matching how
    /// the GPU generators of the paper emit 64-bit values).
    fn next_u64(&mut self) -> u64 {
        let lo = self.next_u32() as u64;
        let hi = self.next_u32() as u64;
        lo | (hi << 32)
    }

    /// Uniform on [0, 1): 32-bit mantissa scaling (2^-32), never 1.0.
    fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4294967296.0)
    }

    /// Uniform on [0, 1) single precision (24-bit mantissa).
    fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / 16777216.0)
    }

    /// Fill a buffer with raw 32-bit outputs.
    fn fill_u32(&mut self, out: &mut [u32]) {
        for x in out.iter_mut() {
            *x = self.next_u32();
        }
    }

    /// Human-readable name.
    fn name(&self) -> &'static str;

    /// State size in 32-bit words (paper Table 1, "State-Space" column).
    fn state_words(&self) -> usize;

    /// log2 of the period (paper Table 1, "Period" column).
    fn period_log2(&self) -> f64;
}

/// A block-parallel generator: `B` independent subsequences ("blocks" in the
/// paper's CUDA mapping) advanced in lockstep rounds.
///
/// `fill_interleaved` produces the stream the paper's experiments consume:
/// each round, every block emits its next `lane_width` outputs; rounds are
/// concatenated block-major within a round. This is the same output order
/// the Pallas kernel produces, so Rust backend and PJRT backend are
/// bit-comparable.
pub trait BlockParallel {
    /// Number of blocks (independent subsequences).
    fn blocks(&self) -> usize;

    /// Outputs emitted per block per round — the paper's intra-block
    /// parallel degree: `min(s, r−s)` for xorgensGP, `N−M` for MTGP, 1 for
    /// XORWOW (CURAND's per-thread model).
    fn lane_width(&self) -> usize;

    /// Advance every block one round, appending `blocks() * lane_width()`
    /// outputs to `out` (block-major: block 0's lane outputs first).
    fn next_round(&mut self, out: &mut Vec<u32>);

    /// Fill `out` exactly, running as many rounds as needed and buffering
    /// any excess internally.
    fn fill_interleaved(&mut self, out: &mut [u32]);

    /// Raw state access for the PJRT path: concatenated per-block states,
    /// layout documented by each implementation (must round-trip through
    /// `load_state`).
    fn dump_state(&self) -> Vec<u32>;

    /// Restore a state dumped by `dump_state`.
    fn load_state(&mut self, words: &[u32]);

    fn name(&self) -> &'static str;

    /// Per-block state footprint in 32-bit words (Table 1 column).
    fn state_words_per_block(&self) -> usize;

    fn period_log2(&self) -> f64;
}

/// Registry of the generators the paper evaluates.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum GeneratorKind {
    /// Brent's serial xorgens (xor4096i parameters).
    Xorgens,
    /// The paper's block-parallel xorgensGP (r=128, s=65).
    XorgensGp,
    /// Serial Mersenne Twister MT19937.
    Mt19937,
    /// Block-parallel MTGP-style Mersenne Twister.
    Mtgp,
    /// CURAND default: Marsaglia's XORWOW.
    Xorwow,
}

impl GeneratorKind {
    /// The three generators of the paper's evaluation (Tables 1 and 2).
    pub const PAPER_SET: [GeneratorKind; 3] =
        [GeneratorKind::XorgensGp, GeneratorKind::Mtgp, GeneratorKind::Xorwow];

    /// All kinds.
    pub const ALL: [GeneratorKind; 5] = [
        GeneratorKind::Xorgens,
        GeneratorKind::XorgensGp,
        GeneratorKind::Mt19937,
        GeneratorKind::Mtgp,
        GeneratorKind::Xorwow,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            GeneratorKind::Xorgens => "xorgens",
            GeneratorKind::XorgensGp => "xorgensgp",
            GeneratorKind::Mt19937 => "mt19937",
            GeneratorKind::Mtgp => "mtgp",
            GeneratorKind::Xorwow => "xorwow",
        }
    }

    pub fn parse(s: &str) -> Option<GeneratorKind> {
        match s.to_ascii_lowercase().as_str() {
            "xorgens" => Some(GeneratorKind::Xorgens),
            "xorgensgp" | "xorgens-gp" | "xorgens_gp" => Some(GeneratorKind::XorgensGp),
            "mt19937" | "mt" => Some(GeneratorKind::Mt19937),
            "mtgp" => Some(GeneratorKind::Mtgp),
            "xorwow" | "curand" => Some(GeneratorKind::Xorwow),
            _ => None,
        }
    }
}

impl std::fmt::Display for GeneratorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Adapter: view a [`BlockParallel`] generator as a single [`Prng32`] stream
/// (the interleaved stream, which is what the paper's TestU01 runs consume).
pub struct InterleavedStream<B: BlockParallel> {
    inner: B,
    buf: Vec<u32>,
    pos: usize,
}

impl<B: BlockParallel> InterleavedStream<B> {
    pub fn new(inner: B) -> Self {
        InterleavedStream { inner, buf: Vec::new(), pos: 0 }
    }

    pub fn into_inner(self) -> B {
        self.inner
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }
}

impl<B: BlockParallel> Prng32 for InterleavedStream<B> {
    fn next_u32(&mut self) -> u32 {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.inner.next_round(&mut self.buf);
            self.pos = 0;
        }
        let x = self.buf[self.pos];
        self.pos += 1;
        x
    }

    fn fill_u32(&mut self, out: &mut [u32]) {
        let mut i = 0;
        while i < out.len() {
            if self.pos == self.buf.len() {
                self.buf.clear();
                self.inner.next_round(&mut self.buf);
                self.pos = 0;
            }
            let take = (out.len() - i).min(self.buf.len() - self.pos);
            out[i..i + take].copy_from_slice(&self.buf[self.pos..self.pos + take]);
            self.pos += take;
            i += take;
        }
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn state_words(&self) -> usize {
        self.inner.state_words_per_block()
    }

    fn period_log2(&self) -> f64 {
        self.inner.period_log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter(u32);
    impl Prng32 for Counter {
        fn next_u32(&mut self) -> u32 {
            self.0 = self.0.wrapping_add(1);
            self.0
        }
        fn name(&self) -> &'static str {
            "counter"
        }
        fn state_words(&self) -> usize {
            1
        }
        fn period_log2(&self) -> f64 {
            32.0
        }
    }

    #[test]
    fn default_conversions() {
        let mut c = Counter(0);
        assert_eq!(c.next_u64(), 1 | (2u64 << 32));
        let f = c.next_f64();
        assert!((0.0..1.0).contains(&f));
        let g = c.next_f32();
        assert!((0.0..1.0).contains(&g));
        let mut buf = [0u32; 4];
        c.fill_u32(&mut buf);
        assert_eq!(buf, [5, 6, 7, 8]);
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in GeneratorKind::ALL {
            assert_eq!(GeneratorKind::parse(k.name()), Some(k));
        }
        assert_eq!(GeneratorKind::parse("curand"), Some(GeneratorKind::Xorwow));
        assert_eq!(GeneratorKind::parse("nope"), None);
    }
}

//! Generator traits and the kind registry.
//!
//! The hot path is **slice-oriented**: generators fill caller-owned
//! buffers ([`Prng32::fill_u32`], [`BlockParallel::fill_round`]) with no
//! allocation; scalar draws ([`Prng32::next_u32`]) are a convenience
//! derived from the fill path through a small internal refill buffer.

/// A 32-bit pseudo-random generator (single logical stream).
///
/// `fill_u32` is the primary entry point: implementations write straight
/// into the caller's slice with no per-draw virtual dispatch and no heap
/// allocation. The scalar accessors are defined in terms of the same
/// stream (calling `next_u32` n times is bit-identical to one
/// `fill_u32` of n words).
pub trait Prng32 {
    /// Next raw 32-bit output.
    fn next_u32(&mut self) -> u32;

    /// Next 64-bit output (two 32-bit draws, low word first — matching how
    /// the GPU generators of the paper emit 64-bit values).
    fn next_u64(&mut self) -> u64 {
        let lo = self.next_u32() as u64;
        let hi = self.next_u32() as u64;
        lo | (hi << 32)
    }

    /// Uniform on [0, 1): 32-bit mantissa scaling (2^-32), never 1.0.
    fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4294967296.0)
    }

    /// Uniform on [0, 1) single precision (24-bit mantissa; the canonical
    /// [`distributions::unit_f32`](crate::prng::distributions::unit_f32)
    /// map).
    fn next_f32(&mut self) -> f32 {
        crate::prng::distributions::unit_f32(self.next_u32())
    }

    /// Fill a caller-owned buffer with raw 32-bit outputs — the bulk entry
    /// point. The default loops `next_u32`; generators with internal
    /// parallel structure override it with a slice-fill pipeline.
    fn fill_u32(&mut self, out: &mut [u32]) {
        for x in out.iter_mut() {
            *x = self.next_u32();
        }
    }

    /// Human-readable name.
    fn name(&self) -> &'static str;

    /// State size in 32-bit words (paper Table 1, "State-Space" column).
    fn state_words(&self) -> usize;

    /// log2 of the period (paper Table 1, "Period" column).
    fn period_log2(&self) -> f64;
}

thread_local! {
    /// One-round bounce buffer for [`BlockParallel::fill_interleaved`]'s
    /// partial-tail path. Thread-local because the default trait method has
    /// no per-generator state to hang a scratch off; per-thread reuse keeps
    /// the steady state allocation-free without changing the trait surface.
    static TAIL_SCRATCH: std::cell::RefCell<Vec<u32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// A block-parallel generator: `B` independent subsequences ("blocks" in the
/// paper's CUDA mapping) advanced in lockstep rounds.
///
/// The interleaved stream (each round, every block emits its next
/// `lane_width` outputs; rounds concatenated block-major within a round)
/// is the stream the paper's experiments consume. It is the same output
/// order the Pallas kernel produces, so Rust backend and PJRT backend are
/// bit-comparable.
pub trait BlockParallel {
    /// Number of blocks (independent subsequences).
    fn blocks(&self) -> usize;

    /// Outputs emitted per block per round — the paper's intra-block
    /// parallel degree: `min(s, r−s)` for xorgensGP, `N−M` for MTGP, 1 for
    /// XORWOW (CURAND's per-thread model).
    fn lane_width(&self) -> usize;

    /// Words produced per lockstep round: `blocks() * lane_width()`.
    fn round_len(&self) -> usize {
        self.blocks() * self.lane_width()
    }

    /// Advance every block one round, writing exactly [`round_len`] words
    /// into the caller's slice (block-major: block 0's lane outputs first).
    /// No allocation; panics if `out.len() != round_len()`.
    ///
    /// [`round_len`]: BlockParallel::round_len
    fn fill_round(&mut self, out: &mut [u32]);

    /// Fill `out`, running as many rounds as needed. Whole rounds are
    /// written straight into `out`; only a final partial round goes
    /// through a bounce buffer, and its excess outputs are **discarded**
    /// (EXPERIMENTS.md §Perf L3-2). Callers that need exact stream
    /// continuation draw in multiples of `round_len()` — the coordinator's
    /// batcher does — or go through [`InterleavedStream`], which buffers
    /// the excess instead.
    fn fill_interleaved(&mut self, out: &mut [u32]) {
        let chunk = self.round_len();
        let mut done = 0;
        while done + chunk <= out.len() {
            self.fill_round(&mut out[done..done + chunk]);
            done += chunk;
        }
        if done < out.len() {
            // Partial tail: bounce one round through a thread-local
            // scratch, reused across calls — consumers with non-round
            // buffer sizes (the π example's 2^16 buffer against a 4032
            // round, `measure_rate`'s 2^20) hit this every call, so a
            // per-call `vec![0; chunk]` here was a steady-state allocation
            // on the bulk path. Stream contents are unchanged: same one
            // `fill_round`, same excess-discarding contract.
            TAIL_SCRATCH.with(|cell| {
                let mut scratch = cell.borrow_mut();
                scratch.resize(chunk, 0);
                self.fill_round(&mut scratch[..]);
                out[done..].copy_from_slice(&scratch[..out.len() - done]);
            });
        }
    }

    /// Split the generator into per-block-range parts for the parallel
    /// fill engine ([`crate::exec`]).
    ///
    /// `bounds` are strictly-ascending block cut points; the part for
    /// consecutive pair `(bounds[i], bounds[i+1])` takes exclusive `&mut`
    /// ownership of those blocks' state and, when driven, advances them
    /// exactly `rounds` rounds, writing outputs through
    /// [`StridedOut::block_slice`](crate::exec::StridedOut::block_slice)
    /// at absolute block indices.
    ///
    /// Contract for implementors:
    ///
    /// * Kinds with shared cross-block bookkeeping (XORWOW's rotating
    ///   phase) may require `bounds` to cover `0..blocks()` and return
    ///   `None` otherwise; they advance the shared bookkeeping **at split
    ///   time**, so every returned part must then be driven or the
    ///   generator is left torn.
    /// * Returning `None` (the default — also the leapfrog wrapper, whose
    ///   output is an inherently serial deal from one master) makes the
    ///   engine fall back to the serial path; the stream is identical
    ///   either way.
    fn split_fill<'a>(
        &'a mut self,
        rounds: usize,
        bounds: &[usize],
    ) -> Option<Vec<Box<dyn crate::exec::RangeFill + 'a>>> {
        let _ = (rounds, bounds);
        None
    }

    /// Fill `rounds` rounds of the block range `blocks` into `out`, laid
    /// out like the interleaved stream restricted to those columns: round
    /// `t`, range-local block `i` at `out[t * width * lane + i * lane]`
    /// where `width = blocks.len()`. Requires
    /// `out.len() == rounds * width * lane_width()`.
    ///
    /// Routed through [`split_fill`](BlockParallel::split_fill) when the
    /// generator supports range splits; otherwise only the full range
    /// `0..blocks()` is accepted (served by a serial `fill_round` loop).
    fn fill_rounds_range(&mut self, rounds: usize, blocks: std::ops::Range<usize>, out: &mut [u32]) {
        let lane = self.lane_width();
        let width = blocks.len();
        assert!(blocks.start < blocks.end && blocks.end <= self.blocks(), "bad block range");
        assert_eq!(out.len(), rounds * width * lane, "output/range size mismatch");
        if rounds == 0 {
            return;
        }
        if let Some(mut parts) = self.split_fill(rounds, &[blocks.start, blocks.end]) {
            assert_eq!(parts.len(), 1);
            let view = crate::exec::StridedOut::with_block_base(out, width * lane, lane, blocks.start);
            parts[0].fill_rounds(&view);
            return;
        }
        assert!(
            blocks.start == 0 && blocks.end == self.blocks(),
            "{}: partial block-range fill unsupported (no split_fill)",
            BlockParallel::name(self)
        );
        let round = width * lane;
        for t in 0..rounds {
            self.fill_round(&mut out[t * round..(t + 1) * round]);
        }
    }

    /// [`fill_interleaved`](BlockParallel::fill_interleaved) with an
    /// opt-in threaded bulk path: when `threads > 1`, the whole-rounds
    /// span is at least [`PAR_FILL_MIN_WORDS`](crate::exec::PAR_FILL_MIN_WORDS)
    /// and the generator can [`split_fill`](BlockParallel::split_fill),
    /// the rounds are filled by the parallel engine; any partial tail is
    /// then bounced exactly like the serial path (excess discarded).
    /// Bit-identical to `fill_interleaved` in every case — small fills,
    /// `threads <= 1`, and non-splittable generators take the serial path
    /// unchanged.
    fn fill_interleaved_threaded(&mut self, threads: usize, out: &mut [u32]) {
        let chunk = self.round_len();
        let whole = out.len() - out.len() % chunk;
        if threads > 1
            && whole >= crate::exec::PAR_FILL_MIN_WORDS
            && crate::exec::fill_rounds_parallel(self, threads, &mut out[..whole])
        {
            if whole < out.len() {
                // Same partial-tail contract as fill_interleaved: one
                // bounced round, excess discarded.
                TAIL_SCRATCH.with(|cell| {
                    let mut scratch = cell.borrow_mut();
                    scratch.resize(chunk, 0);
                    self.fill_round(&mut scratch[..]);
                    out[whole..].copy_from_slice(&scratch[..out.len() - whole]);
                });
            }
            return;
        }
        self.fill_interleaved(out);
    }

    /// [`fill_interleaved_threaded`](BlockParallel::fill_interleaved_threaded)'s
    /// twin over a persistent [`FillPool`](crate::exec::pool::FillPool):
    /// same [`PAR_FILL_MIN_WORDS`](crate::exec::PAR_FILL_MIN_WORDS)
    /// crossover, same partial-tail bounce, same serial fallback, but the
    /// whole-rounds span fans out across the pool's long-lived workers
    /// (the calling thread runs part 0 and help-steals) instead of
    /// spawning scoped threads per dispatch. Bit-identical to
    /// `fill_interleaved` in every case.
    fn fill_interleaved_pooled(&mut self, pool: &crate::exec::pool::FillPool, out: &mut [u32]) {
        let chunk = self.round_len();
        let whole = out.len() - out.len() % chunk;
        if whole >= crate::exec::PAR_FILL_MIN_WORDS && pool.fill_rounds(self, &mut out[..whole]) {
            if whole < out.len() {
                // Same partial-tail contract as fill_interleaved: one
                // bounced round, excess discarded.
                TAIL_SCRATCH.with(|cell| {
                    let mut scratch = cell.borrow_mut();
                    scratch.resize(chunk, 0);
                    self.fill_round(&mut scratch[..]);
                    out[whole..].copy_from_slice(&scratch[..out.len() - whole]);
                });
            }
            return;
        }
        self.fill_interleaved(out);
    }

    /// Raw state access for the PJRT path: concatenated per-block states,
    /// layout documented by each implementation (must round-trip through
    /// `load_state`).
    fn dump_state(&self) -> Vec<u32>;

    /// Restore a state dumped by `dump_state`.
    fn load_state(&mut self, words: &[u32]);

    fn name(&self) -> &'static str;

    /// Per-block state footprint in 32-bit words (Table 1 column).
    fn state_words_per_block(&self) -> usize;

    fn period_log2(&self) -> f64;
}

/// Forwarding impl so boxed generators (`make_block_generator`'s return
/// type) plug straight into [`InterleavedStream`] and the placement
/// wrappers without a bespoke adapter. Forwards `fill_interleaved`
/// explicitly to preserve any override on the boxed type.
impl<B: BlockParallel + ?Sized> BlockParallel for Box<B> {
    fn blocks(&self) -> usize {
        (**self).blocks()
    }
    fn lane_width(&self) -> usize {
        (**self).lane_width()
    }
    fn fill_round(&mut self, out: &mut [u32]) {
        (**self).fill_round(out)
    }
    fn fill_interleaved(&mut self, out: &mut [u32]) {
        (**self).fill_interleaved(out)
    }
    fn split_fill<'a>(
        &'a mut self,
        rounds: usize,
        bounds: &[usize],
    ) -> Option<Vec<Box<dyn crate::exec::RangeFill + 'a>>> {
        (**self).split_fill(rounds, bounds)
    }
    fn fill_rounds_range(&mut self, rounds: usize, blocks: std::ops::Range<usize>, out: &mut [u32]) {
        (**self).fill_rounds_range(rounds, blocks, out)
    }
    fn fill_interleaved_threaded(&mut self, threads: usize, out: &mut [u32]) {
        (**self).fill_interleaved_threaded(threads, out)
    }
    fn fill_interleaved_pooled(&mut self, pool: &crate::exec::pool::FillPool, out: &mut [u32]) {
        (**self).fill_interleaved_pooled(pool, out)
    }
    fn dump_state(&self) -> Vec<u32> {
        (**self).dump_state()
    }
    fn load_state(&mut self, words: &[u32]) {
        (**self).load_state(words)
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn state_words_per_block(&self) -> usize {
        (**self).state_words_per_block()
    }
    fn period_log2(&self) -> f64 {
        (**self).period_log2()
    }
}

/// Registry of the generators the paper evaluates.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum GeneratorKind {
    /// Brent's serial xorgens (xor4096i parameters).
    Xorgens,
    /// The paper's block-parallel xorgensGP (r=128, s=65).
    XorgensGp,
    /// Serial Mersenne Twister MT19937.
    Mt19937,
    /// Block-parallel MTGP-style Mersenne Twister.
    Mtgp,
    /// CURAND default: Marsaglia's XORWOW.
    Xorwow,
}

impl GeneratorKind {
    /// The three generators of the paper's evaluation (Tables 1 and 2).
    pub const PAPER_SET: [GeneratorKind; 3] =
        [GeneratorKind::XorgensGp, GeneratorKind::Mtgp, GeneratorKind::Xorwow];

    /// All kinds.
    pub const ALL: [GeneratorKind; 5] = [
        GeneratorKind::Xorgens,
        GeneratorKind::XorgensGp,
        GeneratorKind::Mt19937,
        GeneratorKind::Mtgp,
        GeneratorKind::Xorwow,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            GeneratorKind::Xorgens => "xorgens",
            GeneratorKind::XorgensGp => "xorgensgp",
            GeneratorKind::Mt19937 => "mt19937",
            GeneratorKind::Mtgp => "mtgp",
            GeneratorKind::Xorwow => "xorwow",
        }
    }

    /// Shim over the [`FromStr`](std::str::FromStr) impl for callers that
    /// want an `Option` (the typed error is discarded).
    pub fn parse(s: &str) -> Option<GeneratorKind> {
        s.parse().ok()
    }
}

impl std::str::FromStr for GeneratorKind {
    type Err = crate::util::cli::ParseEnumError;

    fn from_str(s: &str) -> Result<GeneratorKind, Self::Err> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "xorgens" => GeneratorKind::Xorgens,
            "xorgensgp" | "xorgens-gp" | "xorgens_gp" => GeneratorKind::XorgensGp,
            "mt19937" | "mt" => GeneratorKind::Mt19937,
            "mtgp" => GeneratorKind::Mtgp,
            "xorwow" | "curand" => GeneratorKind::Xorwow,
            _ => {
                return Err(crate::util::cli::ParseEnumError::new(
                    "generator kind",
                    s,
                    "xorgens, xorgensgp, mt19937, mtgp, xorwow (aliases: xorgens-gp, \
                     xorgens_gp, mt, curand)",
                ))
            }
        })
    }
}

impl std::fmt::Display for GeneratorKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Adapter: view a [`BlockParallel`] generator as a single [`Prng32`] stream
/// (the interleaved stream, which is what the paper's TestU01 runs consume).
///
/// Owns one round's worth of refill buffer, allocated once at construction
/// and reused for the lifetime of the stream: the steady state is
/// cursor-advance only — no `clear()`, no realloc, no per-round
/// allocation. `fill_u32` bypasses the buffer entirely for whole rounds,
/// writing them straight into the caller's slice, and unlike
/// `fill_interleaved` it buffers (rather than discards) the excess of the
/// final partial round, so mixed scalar/bulk consumption reads one
/// continuous stream.
pub struct InterleavedStream<B: BlockParallel> {
    inner: B,
    /// One round of output; `pos == buf.len()` means drained.
    buf: Box<[u32]>,
    pos: usize,
    /// Worker count for the threaded bulk path of `fill_u32` (1 = serial).
    threads: usize,
}

impl<B: BlockParallel> InterleavedStream<B> {
    pub fn new(inner: B) -> Self {
        let round = inner.round_len();
        assert!(round > 0);
        InterleavedStream { inner, buf: vec![0u32; round].into_boxed_slice(), pos: round, threads: 1 }
    }

    /// Enable the threaded bulk path: large `fill_u32` calls route their
    /// whole-rounds span through
    /// [`BlockParallel::fill_interleaved_threaded`] with `n` workers
    /// (clamped to at least 1). The served stream is bit-identical for
    /// every `n`; fills below the
    /// [`PAR_FILL_MIN_WORDS`](crate::exec::PAR_FILL_MIN_WORDS) crossover
    /// stay serial.
    pub fn fill_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    pub fn into_inner(self) -> B {
        self.inner
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Refill the internal buffer with the next round.
    #[cold]
    fn refill(&mut self) {
        self.inner.fill_round(&mut self.buf);
        self.pos = 0;
    }
}

impl<B: BlockParallel> Prng32 for InterleavedStream<B> {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.pos == self.buf.len() {
            self.refill();
        }
        let x = self.buf[self.pos];
        self.pos += 1;
        x
    }

    fn fill_u32(&mut self, out: &mut [u32]) {
        // 1. Drain the buffered remainder of the current round.
        let mut i = (out.len()).min(self.buf.len() - self.pos);
        out[..i].copy_from_slice(&self.buf[self.pos..self.pos + i]);
        self.pos += i;
        // 2. Whole rounds go straight into the caller's slice — the
        //    zero-copy bulk path (no bounce through self.buf). The span is
        //    an exact multiple of the round, so the threaded variant (== a
        //    fill_round loop when serial or under the crossover) serves
        //    the identical stream.
        let round = self.buf.len();
        let span = (out.len() - i) / round * round;
        if span > 0 {
            self.inner.fill_interleaved_threaded(self.threads, &mut out[i..i + span]);
            i += span;
        }
        // 3. Final partial round lands in the buffer; serve the head and
        //    keep the rest for the next call (exact stream continuation).
        if i < out.len() {
            self.refill();
            let take = out.len() - i;
            out[i..].copy_from_slice(&self.buf[..take]);
            self.pos = take;
        }
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn state_words(&self) -> usize {
        self.inner.state_words_per_block()
    }

    fn period_log2(&self) -> f64 {
        self.inner.period_log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Counter(u32);
    impl Prng32 for Counter {
        fn next_u32(&mut self) -> u32 {
            self.0 = self.0.wrapping_add(1);
            self.0
        }
        fn name(&self) -> &'static str {
            "counter"
        }
        fn state_words(&self) -> usize {
            1
        }
        fn period_log2(&self) -> f64 {
            32.0
        }
    }

    /// A deterministic fake block generator: block b, step k emits
    /// b * 1000 + k (lane 3), so interleaving is easy to predict.
    struct FakeBlocks {
        blocks: usize,
        step: u32,
    }

    impl BlockParallel for FakeBlocks {
        fn blocks(&self) -> usize {
            self.blocks
        }
        fn lane_width(&self) -> usize {
            3
        }
        fn fill_round(&mut self, out: &mut [u32]) {
            assert_eq!(out.len(), self.round_len());
            for b in 0..self.blocks {
                for j in 0..3 {
                    out[b * 3 + j] = (b as u32) * 1000 + self.step + j as u32;
                }
            }
            self.step += 3;
        }
        fn dump_state(&self) -> Vec<u32> {
            vec![self.step]
        }
        fn load_state(&mut self, words: &[u32]) {
            self.step = words[0];
        }
        fn name(&self) -> &'static str {
            "fake"
        }
        fn state_words_per_block(&self) -> usize {
            1
        }
        fn period_log2(&self) -> f64 {
            32.0
        }
    }

    #[test]
    fn default_conversions() {
        let mut c = Counter(0);
        assert_eq!(c.next_u64(), 1 | (2u64 << 32));
        let f = c.next_f64();
        assert!((0.0..1.0).contains(&f));
        let g = c.next_f32();
        assert!((0.0..1.0).contains(&g));
        let mut buf = [0u32; 4];
        c.fill_u32(&mut buf);
        assert_eq!(buf, [5, 6, 7, 8]);
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in GeneratorKind::ALL {
            assert_eq!(GeneratorKind::parse(k.name()), Some(k));
            assert_eq!(k.name().parse::<GeneratorKind>(), Ok(k));
        }
        assert_eq!(GeneratorKind::parse("curand"), Some(GeneratorKind::Xorwow));
        assert_eq!(GeneratorKind::parse("nope"), None);
        // The FromStr path carries a typed, descriptive error.
        let err = "nope".parse::<GeneratorKind>().unwrap_err();
        assert_eq!(err.what, "generator kind");
        assert!(err.to_string().contains("\"nope\""), "{err}");
    }

    #[test]
    fn interleaved_scalar_matches_rounds() {
        let mut st = InterleavedStream::new(FakeBlocks { blocks: 2, step: 0 });
        let got: Vec<u32> = (0..12).map(|_| st.next_u32()).collect();
        assert_eq!(got, vec![0, 1, 2, 1000, 1001, 1002, 3, 4, 5, 1003, 1004, 1005]);
    }

    #[test]
    fn interleaved_fill_matches_scalar_for_all_chunkings() {
        // The load-bearing equivalence: any chunking of fill_u32 yields the
        // same stream as scalar next_u32.
        let total = 47usize;
        let mut scalar = InterleavedStream::new(FakeBlocks { blocks: 2, step: 0 });
        let expect: Vec<u32> = (0..total).map(|_| scalar.next_u32()).collect();
        for chunk in [1usize, 2, 3, 5, 6, 7, 12, 13, 46, 47] {
            let mut bulk = InterleavedStream::new(FakeBlocks { blocks: 2, step: 0 });
            let mut got = Vec::new();
            while got.len() < total {
                let k = chunk.min(total - got.len());
                let mut buf = vec![0u32; k];
                bulk.fill_u32(&mut buf);
                got.extend(buf);
            }
            assert_eq!(got, expect, "chunk={chunk}");
        }
    }

    #[test]
    fn default_fill_interleaved_discards_partial_tail() {
        // fill_interleaved's contract: whole rounds direct, tail bounced,
        // excess discarded (the next round starts fresh).
        let mut g = FakeBlocks { blocks: 2, step: 0 };
        let mut buf = vec![0u32; 8]; // round_len = 6, so 6 direct + 2 bounced
        g.fill_interleaved(&mut buf);
        assert_eq!(&buf[..6], &[0, 1, 2, 1000, 1001, 1002]);
        assert_eq!(&buf[6..], &[3, 4]); // excess 5, 1003.. discarded
        assert_eq!(g.dump_state(), vec![6]); // two rounds consumed
    }

    #[test]
    fn round_len_is_blocks_times_lane() {
        let g = FakeBlocks { blocks: 4, step: 0 };
        assert_eq!(g.round_len(), 12);
    }

    #[test]
    fn fill_interleaved_tail_scratch_leaves_stream_unchanged() {
        // The thread-local tail scratch (which replaced a per-call
        // `vec![0; chunk]` bounce allocation) must not change what lands
        // in the caller's buffer: repeated partial-tail fills produce
        // exactly the rounds-with-excess-discarded stream, including when
        // generators with different round lengths interleave on the same
        // thread (the scratch is resized per call).
        let total = 20usize; // round_len = 6: every 20-word fill has a tail
        let mut via_scratch = FakeBlocks { blocks: 2, step: 0 };
        let mut reference = FakeBlocks { blocks: 2, step: 0 };
        for _ in 0..5 {
            let mut got = vec![0u32; total];
            via_scratch.fill_interleaved(&mut got);
            // Reference semantics, spelled out: whole rounds, then one
            // bounced round with the excess discarded.
            let mut expect = Vec::new();
            while expect.len() + 6 <= total {
                let mut r = vec![0u32; 6];
                reference.fill_round(&mut r);
                expect.extend(r);
            }
            let mut r = vec![0u32; 6];
            reference.fill_round(&mut r);
            expect.extend(&r[..total - expect.len()]);
            assert_eq!(got, expect);
            // Perturb the shared scratch with a different round length in
            // between — must not leak into the next fill.
            let mut other = FakeBlocks { blocks: 5, step: 400 };
            let mut buf = vec![0u32; 17]; // round_len = 15, tail of 2
            other.fill_interleaved(&mut buf);
        }
        assert_eq!(via_scratch.dump_state(), reference.dump_state());
    }

    #[test]
    fn fill_interleaved_tail_matches_real_generator_stream() {
        // Same check against a real generator: a tail-heavy chunking must
        // serve the same stream as whole-round consumption with per-call
        // excess discarded.
        use crate::prng::XorgensGp;
        let round = XorgensGp::new(9, 2).round_len(); // 2 * 63 = 126
        let odd = round + 17;
        let mut bulk = XorgensGp::new(9, 2);
        let mut a = vec![0u32; odd];
        bulk.fill_interleaved(&mut a);
        let mut rounds = XorgensGp::new(9, 2);
        let mut expect = vec![0u32; 2 * round];
        rounds.fill_round(&mut expect[..round]);
        rounds.fill_round(&mut expect[round..]);
        assert_eq!(&a[..], &expect[..odd]);
        // Both generators have now consumed exactly two rounds.
        let mut b = vec![0u32; round];
        let mut c = vec![0u32; round];
        bulk.fill_round(&mut b);
        rounds.fill_round(&mut c);
        assert_eq!(b, c);
    }
}

//! Serial Mersenne Twister MT19937 (Matsumoto & Nishimura 1998) — the basis
//! of the paper's MTGP comparator (§1.3). Bit-exact with the reference C
//! implementation (`init_genrand` seeding); verified against the published
//! test vector (seed 5489) and cross-checked against NumPy's MT19937 in
//! `python/tests/test_golden.py`.

use super::traits::Prng32;
use crate::gf2::LinearStep;

pub const N: usize = 624;
pub const M: usize = 397;
const MATRIX_A: u32 = 0x9908_b0df;
const UPPER_MASK: u32 = 0x8000_0000;
const LOWER_MASK: u32 = 0x7fff_ffff;

/// Serial MT19937.
#[derive(Clone)]
pub struct Mt19937 {
    mt: [u32; N],
    mti: usize,
}

impl Mt19937 {
    /// Reference `init_genrand` seeding.
    pub fn new(seed: u32) -> Self {
        let mut mt = [0u32; N];
        mt[0] = seed;
        for i in 1..N {
            mt[i] = 1812433253u32
                .wrapping_mul(mt[i - 1] ^ (mt[i - 1] >> 30))
                .wrapping_add(i as u32);
        }
        Mt19937 { mt, mti: N }
    }

    /// Construct from a full 624-word state with the next output index at
    /// the start of a freshly twisted block (used by [`super::Mtgp`] and the
    /// Pallas kernel for bit-exact block comparison).
    pub fn from_state(mt: [u32; N]) -> Self {
        Mt19937 { mt, mti: N }
    }

    /// Current raw state.
    pub fn state(&self) -> &[u32; N] {
        &self.mt
    }

    /// The twist: x_k = x_{k-N+M} ^ ((x_{k-N} & UPPER | x_{k-N+1} & LOWER) >> 1)
    ///                  ^ (lsb ? MATRIX_A : 0)
    #[inline]
    pub fn twist(xa: u32, xb: u32, xm: u32) -> u32 {
        let y = (xa & UPPER_MASK) | (xb & LOWER_MASK);
        let mut x = xm ^ (y >> 1);
        if y & 1 == 1 {
            x ^= MATRIX_A;
        }
        x
    }

    /// The tempering transform (GF(2)-linear — which is exactly why MT-class
    /// generators fail the linearity tests of paper Table 2).
    #[inline]
    pub fn temper(mut y: u32) -> u32 {
        y ^= y >> 11;
        y ^= (y << 7) & 0x9d2c_5680;
        y ^= (y << 15) & 0xefc6_0000;
        y ^ (y >> 18)
    }

    fn generate_block(&mut self) {
        for kk in 0..N - M {
            self.mt[kk] = Self::twist(self.mt[kk], self.mt[kk + 1], self.mt[kk + M]);
        }
        for kk in N - M..N - 1 {
            self.mt[kk] = Self::twist(self.mt[kk], self.mt[kk + 1], self.mt[kk + M - N]);
        }
        self.mt[N - 1] = Self::twist(self.mt[N - 1], self.mt[0], self.mt[M - 1]);
        self.mti = 0;
    }
}

/// The MT19937/MTGP recurrence as a [`LinearStep`] on the rolled window
/// layout (`q[m] = x_{k-N+m}`, oldest first — exactly
/// [`super::Mtgp::dump_state`]'s per-block layout). One step computes
/// `x_k = twist(q[0], q[1], q[M])` and rolls by one, so `LANE = N − M`
/// steps equal one MTGP round — the unit the jump engine places blocks in.
pub struct MtStep;

impl LinearStep for MtStep {
    fn n_bits(&self) -> usize {
        32 * N
    }

    fn step_words(&self, state: &mut [u32]) {
        debug_assert_eq!(state.len(), N);
        let x = Mt19937::twist(state[0], state[1], state[M]);
        state.copy_within(1.., 0);
        state[N - 1] = x;
    }
}

impl Prng32 for Mt19937 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.mti >= N {
            self.generate_block();
        }
        let y = self.mt[self.mti];
        self.mti += 1;
        Self::temper(y)
    }

    /// Bulk fill straight from the internal 624-word block: tempering runs
    /// over slices (auto-vectorizable) instead of one call per draw.
    /// Bit-identical to repeated `next_u32`.
    fn fill_u32(&mut self, out: &mut [u32]) {
        let mut i = 0;
        while i < out.len() {
            if self.mti >= N {
                self.generate_block();
            }
            let take = (out.len() - i).min(N - self.mti);
            for (o, &y) in out[i..i + take].iter_mut().zip(&self.mt[self.mti..self.mti + take]) {
                *o = Self::temper(y);
            }
            self.mti += take;
            i += take;
        }
    }

    fn name(&self) -> &'static str {
        "mt19937"
    }

    fn state_words(&self) -> usize {
        N // paper-style accounting: index not counted
    }

    fn period_log2(&self) -> f64 {
        19937.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Published reference outputs for `init_genrand(5489)` (the default
    /// seed of the reference implementation).
    #[test]
    fn reference_vector_seed_5489() {
        let mut mt = Mt19937::new(5489);
        let expect: [u32; 10] = [
            3499211612, 581869302, 3890346734, 3586334585, 545404204, 4161255391, 3922919429,
            949333985, 2715962298, 1323567403,
        ];
        for (i, &e) in expect.iter().enumerate() {
            assert_eq!(mt.next_u32(), e, "output {i}");
        }
    }

    #[test]
    fn deterministic() {
        let mut a = Mt19937::new(1);
        let mut b = Mt19937::new(1);
        for _ in 0..2000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn tempering_is_invertible_linear() {
        // temper is a bijective linear map: check temper(x)^temper(y) == temper(x^y).
        for (x, y) in [(0x12345678u32, 0x9abcdef0u32), (1, 2), (0xffffffff, 0x0f0f0f0f)] {
            assert_eq!(Mt19937::temper(x) ^ Mt19937::temper(y), Mt19937::temper(x ^ y));
        }
    }

    #[test]
    fn twist_linear_over_gf2() {
        // twist(xa,xb,xm) is linear in (xa,xb,xm) jointly over GF(2).
        let (a1, b1, m1) = (0xdeadbeefu32, 0x12345678u32, 0x0f0f0f0fu32);
        let (a2, b2, m2) = (0xcafebabeu32, 0x87654321u32, 0xf0f0f0f0u32);
        assert_eq!(
            Mt19937::twist(a1, b1, m1) ^ Mt19937::twist(a2, b2, m2),
            Mt19937::twist(a1 ^ a2, b1 ^ b2, m1 ^ m2)
        );
    }

    #[test]
    fn fill_matches_scalar_across_block_boundaries() {
        let mut scalar = Mt19937::new(99);
        let expect: Vec<u32> = (0..N * 2 + 37).map(|_| scalar.next_u32()).collect();
        let mut bulk = Mt19937::new(99);
        let mut got = vec![0u32; N * 2 + 37];
        // Odd chunking to cross the 624-word boundary mid-fill.
        let (a, b) = got.split_at_mut(400);
        bulk.fill_u32(a);
        bulk.fill_u32(b);
        assert_eq!(got, expect);
    }

    #[test]
    fn mt_step_lane_steps_equal_one_mtgp_round() {
        // LANE single MtStep steps on the rolled window == one MTGP round.
        use crate::prng::mtgp::LANE;
        use crate::prng::{BlockParallel, Mtgp};
        let mut block = Mtgp::new(42, 1);
        let mut q = block.dump_state();
        let mut out = vec![0u32; block.round_len()];
        block.fill_round(&mut out);
        for _ in 0..LANE {
            MtStep.step_words(&mut q);
        }
        assert_eq!(q, block.dump_state());
    }

    #[test]
    fn crosses_block_boundary() {
        let mut mt = Mt19937::new(7);
        let first: Vec<u32> = (0..N * 2 + 5).map(|_| mt.next_u32()).collect();
        let mut mt2 = Mt19937::new(7);
        let second: Vec<u32> = (0..N * 2 + 5).map(|_| mt2.next_u32()).collect();
        assert_eq!(first, second);
    }
}

//! The Weyl generator `w_k = w_{k-1} + ω (mod 2^32)` that xorgens combines
//! with its xorshift output to break GF(2) linearity (paper §1.5, eq. (1)).

/// Brent's 32-bit Weyl increment: an odd constant close to
/// `2^31 (√5 − 1)` ≈ 0x9E3779B9 — we use its negation 0x61C88647 exactly as
/// xorgens v3.05 does (adding −ω each step walks the same Weyl orbit).
pub const WEYL_32: u32 = 0x61c8_8647;

/// Right-shift distance γ ≈ w/2 in eq. (1): output uses `w ^ (w >> 16)` so
/// the *low* bits also receive high-linear-complexity material (a raw Weyl
/// LSB has period 2).
pub const WEYL_GAMMA: u32 = 16;

/// A 32-bit Weyl sequence.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Weyl {
    w: u32,
}

impl Weyl {
    pub fn new(w0: u32) -> Self {
        Weyl { w: w0 }
    }

    /// Advance and return the *combined* term `w ^ (w >> γ)` of eq. (1).
    #[inline]
    pub fn next_term(&mut self) -> u32 {
        self.w = self.w.wrapping_add(WEYL_32);
        self.w ^ (self.w >> WEYL_GAMMA)
    }

    /// Current raw counter value.
    pub fn raw(&self) -> u32 {
        self.w
    }

    /// Jump `k` steps in O(1): the Weyl orbit is an arithmetic progression.
    pub fn jump(&mut self, k: u64) {
        self.w = self.w.wrapping_add((WEYL_32 as u64).wrapping_mul(k) as u32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weyl_constant_is_odd() {
        assert_eq!(WEYL_32 % 2, 1, "ω must be odd for full period 2^32");
    }

    #[test]
    fn jump_matches_stepping() {
        let mut a = Weyl::new(123);
        let mut b = Weyl::new(123);
        for _ in 0..1000 {
            a.next_term();
        }
        b.jump(1000);
        assert_eq!(a.raw(), b.raw());
    }

    #[test]
    fn full_period_32() {
        // ω odd ⇒ the map w -> w + ω is a 2^32-cycle. Spot-check injectivity
        // over a window instead of the full orbit.
        let mut seen_start = Weyl::new(0);
        let first = seyl_terms(&mut seen_start, 4);
        let mut again = Weyl::new(0);
        assert_eq!(first, seyl_terms(&mut again, 4));
    }

    fn seyl_terms(w: &mut Weyl, n: usize) -> Vec<u32> {
        (0..n).map(|_| w.next_term()).collect()
    }

    #[test]
    fn low_bits_not_trivially_periodic() {
        // Raw Weyl LSB has period 2; the combined term must not.
        let mut w = Weyl::new(0);
        let bits: Vec<bool> = (0..64).map(|_| w.next_term() & 1 == 1).collect();
        let alternating: Vec<bool> = (0..64).map(|i| i % 2 == 0).collect();
        let constant = bits.iter().all(|&b| b == bits[0]);
        assert!(bits != alternating && !constant, "combined LSB looks period-<=2");
    }
}

//! 64-bit xorgens (Brent's xor4096l family) — extension beyond the paper's
//! 32-bit evaluation (§1.5 notes the family covers "any convenient power of
//! two up to 4096"; MTGP likewise ships 32- and 64-bit versions, §1.3).
//!
//! Same recurrence over 64-bit words with shifts < 64 and a 64-bit Weyl
//! combination (γ = 32). Exposed to the 32-bit battery/serving machinery
//! through `Prng32` (low word, then high word — the GPU convention of the
//! 32-bit trait's `next_u64`).

use super::init::SeedSequence;
use super::traits::Prng32;

/// Brent's 64-bit Weyl increment: odd, close to `2^63 (√5 − 1)` —
/// the constant from xorgens v3.05 (`0x61c88646 << 32 | 0x80b583eb`,
/// the negated golden-ratio fraction scaled to 64 bits).
pub const WEYL_64: u64 = 0x61c8_8646_80b5_83eb;
const GAMMA_64: u32 = 32;

/// Parameter set for the 64-bit family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Xorgens64Params {
    pub r: usize,
    pub s: usize,
    pub a: u32,
    pub b: u32,
    pub c: u32,
    pub d: u32,
}

impl Xorgens64Params {
    /// Brent's xor4096l (64-bit, r=64): period `(2^4096 − 1)·2^64`.
    ///
    /// Shift constants from xorgens v3.05's 64-bit table. Maximality of
    /// this big set is Brent's result (2^4096 − 1 is far beyond offline
    /// factorisation); we verify the *structural* conditions plus full
    /// rank of the 4096-bit transition matrix (`check_invertible`), and
    /// verify maximality *exactly* for the small sets (`TEST_128`).
    pub const BRENT_4096: Xorgens64Params =
        Xorgens64Params { r: 64, s: 53, a: 33, b: 26, c: 27, d: 29 };

    /// GP-style tap (`s = r/2 + 1`) for the 64-bit family: parallel degree
    /// `min(s, r−s) = 31`.
    pub const GP_4096: Xorgens64Params =
        Xorgens64Params { r: 64, s: 33, a: 33, b: 26, c: 27, d: 29 };

    /// Exhaustively verified two-word set (see `find_small_params64` test:
    /// maximal period `2^128 − 1` proven by matrix order against the known
    /// factorisation of `2^128 − 1`).
    pub const TEST_128: Xorgens64Params = Xorgens64Params { r: 2, s: 1, a: 1, b: 1, c: 4, d: 35 };

    pub fn parallel_degree(&self) -> usize {
        self.s.min(self.r - self.s)
    }

    pub fn period_log2(&self) -> f64 {
        (64 * self.r + 64) as f64
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.r.is_power_of_two() || self.r < 2 {
            return Err(format!("r={} must be a power of two >= 2", self.r));
        }
        if self.s == 0 || self.s >= self.r {
            return Err(format!("s={} must satisfy 0 < s < r", self.s));
        }
        if gcd(self.r, self.s) != 1 {
            return Err(format!("gcd(r={}, s={}) must be 1", self.r, self.s));
        }
        for (name, v) in [("a", self.a), ("b", self.b), ("c", self.c), ("d", self.d)] {
            if v == 0 || v >= 64 {
                return Err(format!("shift {name}={v} out of range 1..64"));
            }
        }
        Ok(())
    }

    /// Full-rank check of the `64r`-bit transition matrix (necessary for
    /// maximal period; exact maximality needs factoring `2^(64r) − 1`).
    pub fn check_invertible(&self) -> bool {
        let m = crate::gf2::transition_matrix(&RawStep64(*self));
        m.rank() == 64 * self.r
    }

    /// Exact maximal-period check for `64r = 128` via the known prime
    /// factorisation of `2^128 − 1`.
    pub fn check_max_period_128(&self) -> bool {
        assert_eq!(self.r, 2, "exact 64-bit check implemented for r=2");
        // 2^128 − 1 = 3·5·17·257·641·65537·274177·6700417·67280421310721
        const FACTORS: [u128; 9] =
            [3, 5, 17, 257, 641, 65537, 274177, 6700417, 67280421310721];
        let order = u128::MAX; // 2^128 − 1
        debug_assert_eq!(FACTORS.iter().product::<u128>(), order);
        let m = crate::gf2::transition_matrix(&RawStep64(*self));
        if !m.pow(order).is_identity() {
            return false;
        }
        for q in FACTORS {
            if m.pow(order / q).is_identity() {
                return false;
            }
        }
        true
    }
}

/// Rolled one-word linear step on u32-packed state (for gf2 probing).
struct RawStep64(Xorgens64Params);

impl crate::gf2::LinearStep for RawStep64 {
    fn n_bits(&self) -> usize {
        64 * self.0.r
    }

    fn step_words(&self, state: &mut [u32]) {
        let p = &self.0;
        let get = |st: &[u32], i: usize| (st[2 * i] as u64) | ((st[2 * i + 1] as u64) << 32);
        let mut t = get(state, 0);
        let mut v = get(state, p.r - p.s);
        t ^= t << p.a;
        t ^= t >> p.b;
        v ^= v << p.c;
        v ^= v >> p.d;
        let new = v ^ t;
        state.copy_within(2.., 0);
        let n = state.len();
        state[n - 2] = new as u32;
        state[n - 1] = (new >> 32) as u32;
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Serial 64-bit xorgens.
#[derive(Clone)]
pub struct Xorgens64 {
    params: Xorgens64Params,
    x: Vec<u64>,
    w: u64,
    i: usize,
    /// Buffered high word for the Prng32 view.
    pending_hi: Option<u32>,
}

impl Xorgens64 {
    pub fn new(seed: u64) -> Self {
        Self::with_params(seed, Xorgens64Params::BRENT_4096)
    }

    pub fn with_params(seed: u64, params: Xorgens64Params) -> Self {
        params.validate().expect("invalid xorgens64 parameters");
        let mut seq = SeedSequence::new(seed ^ 0x3634_u64);
        let mut x = vec![0u64; params.r];
        loop {
            for v in x.iter_mut() {
                *v = seq.next_u64();
            }
            if x.iter().any(|&v| v != 0) {
                break;
            }
        }
        let w = seq.next_u64();
        let mut g = Xorgens64 { params, x, w, i: params.r - 1, pending_hi: None };
        for _ in 0..4 * params.r {
            g.step_raw();
        }
        g
    }

    #[inline]
    pub fn step_raw(&mut self) -> u64 {
        let p = &self.params;
        let mask = p.r - 1;
        self.i = (self.i + 1) & mask;
        let mut t = self.x[self.i];
        let mut v = self.x[(self.i + p.r - p.s) & mask];
        t ^= t << p.a;
        t ^= t >> p.b;
        v ^= v << p.c;
        v ^= v >> p.d;
        v ^= t;
        self.x[self.i] = v;
        v
    }

    /// Next full 64-bit output with the Weyl combination (eq. (1), w=64).
    #[inline]
    pub fn next_u64_direct(&mut self) -> u64 {
        let v = self.step_raw();
        self.w = self.w.wrapping_add(WEYL_64);
        v.wrapping_add(self.w ^ (self.w >> GAMMA_64))
    }

    pub fn params(&self) -> Xorgens64Params {
        self.params
    }
}

impl Prng32 for Xorgens64 {
    fn next_u32(&mut self) -> u32 {
        if let Some(hi) = self.pending_hi.take() {
            return hi;
        }
        let v = self.next_u64_direct();
        self.pending_hi = Some((v >> 32) as u32);
        v as u32
    }

    fn next_u64(&mut self) -> u64 {
        // Native path (skips the split buffer when aligned).
        if self.pending_hi.is_none() {
            return self.next_u64_direct();
        }
        let lo = self.next_u32() as u64;
        let hi = self.next_u32() as u64;
        lo | (hi << 32)
    }

    fn name(&self) -> &'static str {
        "xorgens64"
    }

    fn state_words(&self) -> usize {
        2 * self.params.r + 2
    }

    fn period_log2(&self) -> f64 {
        self.params.period_log2()
    }
}

/// Exhaustive search for maximal-period 64-bit sets at `r = 2` (the same
/// procedure as `params::find_small_params`, against `2^128 − 1`).
pub fn find_small_params64(limit: usize) -> Vec<Xorgens64Params> {
    let mut found = vec![];
    for a in 1..64u32 {
        for b in 1..64u32 {
            for c in 1..64u32 {
                for d in c..64u32 {
                    let p = Xorgens64Params { r: 2, s: 1, a, b, c, d };
                    if p.validate().is_ok() && p.check_max_period_128() {
                        found.push(p);
                        if found.len() >= limit {
                            return found;
                        }
                    }
                }
            }
        }
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Xorgens64::new(1);
        let mut b = Xorgens64::new(1);
        let mut c = Xorgens64::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64_direct()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64_direct()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64_direct()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn prng32_view_splits_words() {
        let mut a = Xorgens64::new(9);
        let mut b = Xorgens64::new(9);
        let v = a.next_u64_direct();
        assert_eq!(b.next_u32(), v as u32);
        assert_eq!(b.next_u32(), (v >> 32) as u32);
    }

    #[test]
    fn production_params_validate_and_invertible() {
        Xorgens64Params::BRENT_4096.validate().unwrap();
        Xorgens64Params::GP_4096.validate().unwrap();
        assert_eq!(Xorgens64Params::GP_4096.parallel_degree(), 31);
        // Full-rank transition (necessary condition), small set only in
        // unit tests — the 4096-bit check lives in the integration suite.
        assert!(Xorgens64Params::TEST_128.check_invertible());
    }

    #[test]
    fn test128_is_maximal_and_search_finds_it_first() {
        let found = find_small_params64(1);
        assert_eq!(found.first().copied(), Some(Xorgens64Params::TEST_128),
            "update TEST_128 if the search order changes: {found:?}");
        assert!(Xorgens64Params::TEST_128.check_max_period_128());
    }

    #[test]
    fn recurrence_holds() {
        let p = Xorgens64Params::TEST_128;
        let mut g = Xorgens64::with_params(5, p);
        let mut hist: Vec<u64> = (0..p.r).map(|_| g.step_raw()).collect();
        for _ in 0..200 {
            let k = hist.len();
            let mut t = hist[k - p.r];
            let mut v = hist[k - p.s];
            t ^= t << p.a;
            t ^= t >> p.b;
            v ^= v << p.c;
            v ^= v >> p.d;
            let got = g.step_raw();
            assert_eq!(got, v ^ t);
            hist.push(got);
        }
    }

    #[test]
    fn weyl64_constant_odd() {
        assert_eq!(WEYL_64 % 2, 1);
    }

    /// The 64-bit stream (as 32-bit halves) passes a quick battery sample.
    #[test]
    fn passes_spot_battery() {
        let mut g = Xorgens64::new(7);
        let r = crate::testu01::collision::collision(&mut g, 1 << 13, 24);
        assert!(!r.is_fail(), "collision p={}", r.p_value);
        let mut g = Xorgens64::new(7);
        let r = crate::testu01::linear_complexity::linear_complexity_test(&mut g, 20_000, 2);
        assert!(!r.is_fail(), "lincomp p={}", r.p_value);
    }
}

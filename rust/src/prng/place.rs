//! Substream placement: where in the master sequence a stream's blocks
//! live, and the machinery that puts them there *provably*.
//!
//! The paper's correctness claim for parallel generation (§2, §4) is that
//! parallel streams occupy **disjoint** subsequences of one master
//! sequence. Three strategies, in increasing order of guarantee:
//!
//! * [`Placement::SeedMix`] (default) — every block is seeded through the
//!   avalanche-mixed [`SeedSequence`]; disjointness is probabilistic
//!   (overlap odds ~`streams² · draws / period`, i.e. ~2^-4000 for
//!   xorgens). Bit-identical to the pre-placement-engine behavior.
//! * [`Placement::ExactJump`] — block `b` of stream `i` *is* the master
//!   sequence jumped forward `(slot_i + b) · 2^log2_spacing` steps, via
//!   [`crate::gf2::JumpEngine`] polynomial jump-ahead. Disjointness is a
//!   theorem as long as each block draws fewer than `2^log2_spacing`
//!   outputs. Works for **every** linear kind — including the 4096-bit
//!   xorgens state and the MT-class 19968-bit window, which the old dense
//!   `BitMatrix` path could not touch.
//! * [`Placement::Leapfrog`] — the stream's blocks deal one master
//!   sequence out round-robin at round granularity: block `b` owns master
//!   rounds `b, b + B, b + 2B, …`. The interleaved stream a consumer sees
//!   is therefore *exactly the serial master sequence*, independent of
//!   the block count — trivially disjoint blocks plus bit-reproducibility
//!   across launch geometries.
//!
//! [`SeedSequence`]: super::init::SeedSequence

use super::init::{mix64, SeedSequence};
use super::mt19937::MtStep;
use super::mtgp::Mtgp;
use super::params::XorgensParams;
use super::traits::{BlockParallel, GeneratorKind};
use super::weyl::WEYL_32;
use super::xorgens::XorgensLfsr;
use super::xorgens_gp::XorgensGp;
use super::xorwow::{Xorwow, XorwowLfsr};
use crate::gf2::{GfPoly, JumpEngine, LinearStep};
use crate::util::cli::ParseEnumError;
use std::collections::HashMap;

/// XORWOW's Weyl increment (the `d += 362437` of the published step).
const XORWOW_WEYL_INC: u32 = 362437;

/// How a stream's blocks are placed in the generator's master sequence.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Placement {
    /// Avalanche-mixed per-block seeding (the default; probabilistic
    /// disjointness, bit-identical to historical behavior).
    #[default]
    SeedMix,
    /// Exact polynomial jump-ahead: consecutive substream slots spaced
    /// `2^log2_spacing` steps apart in the master sequence. Provably
    /// disjoint while each block draws `< 2^log2_spacing` outputs.
    ExactJump {
        /// log2 of the spacing between substream origins.
        log2_spacing: u32,
    },
    /// Round-granularity leapfrog over one master sequence: the stream's
    /// interleaved output equals the serial master stream for any block
    /// count.
    Leapfrog,
}

impl Placement {
    /// Spacing used when `exact-jump` is requested without an explicit
    /// exponent (matches the legacy XORWOW `exact_jump` placement of
    /// stream `i` at offset `i · 2^96`).
    pub const DEFAULT_LOG2_SPACING: u32 = 96;

    /// Largest spacing exponent accepted from user input. Every period we
    /// serve fits in 2^19969, and base-polynomial setup is linear in the
    /// exponent, so anything beyond this is a typo, not a placement —
    /// rejecting it at parse time beats minutes of pointless squarings
    /// (or a multi-GB exponent-bit allocation).
    pub const MAX_LOG2_SPACING: u32 = 8192;

    pub fn name(&self) -> String {
        match self {
            Placement::SeedMix => "seed-mix".to_string(),
            Placement::ExactJump { log2_spacing } => format!("exact-jump:{log2_spacing}"),
            Placement::Leapfrog => "leapfrog".to_string(),
        }
    }
}

impl std::str::FromStr for Placement {
    type Err = ParseEnumError;

    fn from_str(s: &str) -> Result<Placement, Self::Err> {
        let lower = s.to_ascii_lowercase();
        let (head, spacing) = match lower.split_once(':') {
            Some((h, sp)) => (h, Some(sp)),
            None => (lower.as_str(), None),
        };
        let bad = || {
            ParseEnumError::new(
                "placement",
                s,
                "seed-mix, exact-jump[:log2spacing], leapfrog (aliases: seedmix, mix, \
                 exact, jump)",
            )
        };
        match head {
            "seed-mix" | "seedmix" | "mix" => {
                if spacing.is_some() {
                    return Err(bad());
                }
                Ok(Placement::SeedMix)
            }
            "leapfrog" => {
                if spacing.is_some() {
                    return Err(bad());
                }
                Ok(Placement::Leapfrog)
            }
            "exact-jump" | "exact_jump" | "exactjump" | "exact" | "jump" => {
                let log2_spacing = match spacing {
                    None => Placement::DEFAULT_LOG2_SPACING,
                    Some(sp) => sp
                        .parse::<u32>()
                        .ok()
                        .filter(|&sp| sp <= Placement::MAX_LOG2_SPACING)
                        .ok_or_else(bad)?,
                };
                Ok(Placement::ExactJump { log2_spacing })
            }
            _ => Err(bad()),
        }
    }
}

impl std::fmt::Display for Placement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

/// The kind whose master sequence serves `kind`'s placement: serial
/// aliases share their block-parallel sibling's master (the same grouping
/// `make_block_generator` uses), so caches keyed on the canonical kind
/// never build two identical jump engines.
pub fn canonical_master_kind(kind: GeneratorKind) -> GeneratorKind {
    match kind {
        GeneratorKind::Xorgens | GeneratorKind::XorgensGp => GeneratorKind::XorgensGp,
        GeneratorKind::Mt19937 | GeneratorKind::Mtgp => GeneratorKind::Mtgp,
        GeneratorKind::Xorwow => GeneratorKind::Xorwow,
    }
}

/// The [`LinearStep`] stepper for a generator kind's per-block LFSR, on
/// the kind's own `dump_state` word layout (minus any Weyl word).
pub fn stepper_for(kind: GeneratorKind) -> Box<dyn LinearStep + Send> {
    match kind {
        GeneratorKind::Xorwow => Box::new(XorwowLfsr),
        GeneratorKind::Xorgens | GeneratorKind::XorgensGp => {
            Box::new(XorgensLfsr(XorgensParams::GP_4096))
        }
        GeneratorKind::Mt19937 | GeneratorKind::Mtgp => Box::new(MtStep),
    }
}

/// File name of the jump-polynomial cache under the artifact dir
/// ([`crate::runtime::default_dir`]): one text line per canonical kind,
/// `"<kind> <n_bits> <hex>:<hex>:…"` with the minimal polynomial's
/// LSB-first `u64` words ([`GfPoly::words`]) in hex, low word first.
const JUMP_CACHE_FILE: &str = "jump_poly.cache";

fn jump_cache_path() -> std::path::PathBuf {
    crate::runtime::default_dir().join(JUMP_CACHE_FILE)
}

/// Look up `(name, n_bits)` in the cache file. Malformed or mismatched
/// lines are skipped, never trusted — the caller re-verifies the
/// polynomial against the live stepper anyway
/// ([`JumpEngine::from_cached`]).
fn load_cached_poly(path: &std::path::Path, name: &str, n_bits: usize) -> Option<GfPoly> {
    let text = std::fs::read_to_string(path).ok()?;
    for line in text.lines() {
        let mut it = line.split_whitespace();
        let (kind, bits, hex) = match (it.next(), it.next(), it.next()) {
            (Some(k), Some(b), Some(h)) => (k, b, h),
            _ => continue,
        };
        if kind != name || bits.parse::<usize>() != Ok(n_bits) {
            continue;
        }
        let words: Option<Vec<u64>> =
            hex.split(':').map(|w| u64::from_str_radix(w, 16).ok()).collect();
        match words {
            Some(w) if !w.is_empty() => return Some(GfPoly::from_words(w)),
            _ => continue,
        }
    }
    None
}

/// Rewrite the cache with `name`'s line replaced. Serialized process-wide
/// and written via a temp-file rename, so concurrent tests (or a fleet of
/// coordinators sharing one artifact dir) cannot interleave a torn file —
/// and even a torn file only costs a re-probe, never a wrong jump.
fn store_cached_poly(
    path: &std::path::Path,
    name: &str,
    n_bits: usize,
    poly: &GfPoly,
) -> std::io::Result<()> {
    static STORE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    let _guard = STORE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut lines: Vec<String> = std::fs::read_to_string(path)
        .map(|t| {
            t.lines()
                .filter(|l| l.split_whitespace().next() != Some(name))
                .map(String::from)
                .collect()
        })
        .unwrap_or_default();
    let hex: Vec<String> = poly.words().iter().map(|w| format!("{w:x}")).collect();
    lines.push(format!("{name} {n_bits} {}", hex.join(":")));
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, lines.join("\n") + "\n")?;
    std::fs::rename(&tmp, path)
}

/// The jump engine for `kind`'s stepper, through the polynomial cache:
/// load + verify on a warm start (skipping the ~1 s MT-family min-poly
/// probe), probe + write-through on a cold start or any cache mismatch.
fn engine_for(kind: GeneratorKind, stepper: &dyn LinearStep) -> JumpEngine {
    let path = jump_cache_path();
    let name = canonical_master_kind(kind).name();
    if let Some(poly) = load_cached_poly(&path, name, stepper.n_bits()) {
        if let Some(engine) = JumpEngine::from_cached(stepper, poly) {
            return engine;
        }
    }
    let engine = JumpEngine::probe(stepper);
    // Best-effort write-through: a read-only artifact dir must not break
    // placement, it just stays a cold start.
    let _ = store_cached_poly(&path, name, engine.n_bits(), engine.min_poly());
    engine
}

/// One generator kind's master sequence plus its jump engine: hands out
/// per-block states at exact offsets. Built once per `(kind, root_seed)`
/// and memoized (the coordinator's registry caches one per kind; the
/// battery's placed mode builds one per run).
pub struct PlacedMaster {
    kind: GeneratorKind,
    stepper: Box<dyn LinearStep + Send>,
    engine: JumpEngine,
    /// One block's `dump_state`-layout master state.
    master: Vec<u32>,
    /// Leading words of `master` that form the linear (jumpable) state;
    /// the remainder is the Weyl counter, offset in closed form.
    lfsr_words: usize,
    /// `(word index, per-step increment)` of the non-linear counter, if
    /// the kind has one.
    counter: Option<(usize, u32)>,
    /// Memoized `x^(2^spacing) mod p` per spacing — stream `i`'s residue
    /// is this base raised to `i` (square-and-multiply on `i`), never an
    /// O(i) walk.
    bases: HashMap<u32, GfPoly>,
}

impl PlacedMaster {
    /// Build the master for `kind` from `root_seed`.
    ///
    /// The XORWOW master keeps the legacy construction
    /// (`SeedSequence(root ^ "XORW")`), so exact placement is bit-
    /// compatible with the old `xorwow_exact_state` matrix path.
    pub fn new(kind: GeneratorKind, root_seed: u64) -> PlacedMaster {
        let (master, lfsr_words, counter) = match kind {
            GeneratorKind::Xorwow => {
                let mut seq = SeedSequence::new(root_seed ^ 0x584f_5257); // "XORW"
                let g = Xorwow::from_seq(&mut seq);
                let (x, d) = g.state();
                let mut master = x.to_vec();
                master.push(d);
                (master, 5, Some((5, XORWOW_WEYL_INC)))
            }
            GeneratorKind::Xorgens | GeneratorKind::XorgensGp => {
                let params = XorgensParams::GP_4096;
                let g = XorgensGp::with_params(mix64(root_seed ^ 0x5847_3936), 1, params); // "XG96"
                let master = g.dump_state(); // r words rolled + Weyl
                (master, params.r, Some((params.r, WEYL_32)))
            }
            GeneratorKind::Mt19937 | GeneratorKind::Mtgp => {
                let g = Mtgp::new(mix64(root_seed ^ 0x4d54_4750), 1); // "MTGP"
                let master = g.dump_state(); // rolled 624-word window, no counter
                (master, crate::prng::mt19937::N, None)
            }
        };
        let stepper = stepper_for(kind);
        let engine = engine_for(kind, stepper.as_ref());
        PlacedMaster { kind, stepper, engine, master, lfsr_words, counter, bases: HashMap::new() }
    }

    pub fn kind(&self) -> GeneratorKind {
        self.kind
    }

    /// The jump engine (minimal polynomial etc.) for tests and tools.
    pub fn engine(&self) -> &JumpEngine {
        &self.engine
    }

    /// The master's one-block state in `dump_state` layout (offset 0).
    pub fn master_state(&self) -> &[u32] {
        &self.master
    }

    /// Words per placed block state (the kind's `dump_state` block width).
    pub fn block_words(&self) -> usize {
        self.master.len()
    }

    /// Leading words of a block state that form the linear (jumpable)
    /// LFSR; any remainder is the Weyl counter.
    pub fn lfsr_words(&self) -> usize {
        self.lfsr_words
    }

    /// The state of substream `index` under spacing `2^log2_spacing`:
    /// the master jumped `index · 2^log2_spacing` steps. Memoizes the
    /// per-spacing base polynomial, so each call costs O(log index)
    /// polynomial products plus one O(deg) Horner application.
    pub fn state_at(&mut self, index: u64, log2_spacing: u32) -> Vec<u32> {
        if !self.bases.contains_key(&log2_spacing) {
            let base = self.engine.base_for_spacing(log2_spacing);
            self.bases.insert(log2_spacing, base);
        }
        let base = &self.bases[&log2_spacing];
        let residue = self.engine.residue_from_base(base, index);
        self.place(&residue, steps_mod32(index, log2_spacing))
    }

    /// The state exactly `k` steps into the master sequence (arbitrary
    /// offset — the CLI `jump` command and the algebra tests use this).
    pub fn state_at_offset(&self, k: u128) -> Vec<u32> {
        let residue = self.engine.residue(k);
        self.place(&residue, k as u32)
    }

    /// Apply a jump residue to the master's LFSR words and offset the
    /// Weyl counter in closed form (`counter += inc · (k mod 2^32)` —
    /// the Weyl orbit is an arithmetic progression, paper §1.5).
    fn place(&self, residue: &GfPoly, k_mod32: u32) -> Vec<u32> {
        let mut out = self.master.clone();
        self.engine.apply(self.stepper.as_ref(), residue, &mut out[..self.lfsr_words]);
        if let Some((pos, inc)) = self.counter {
            out[pos] = out[pos].wrapping_add(inc.wrapping_mul(k_mod32));
        }
        out
    }
}

/// `(index · 2^spacing) mod 2^32` without big-integer arithmetic.
fn steps_mod32(index: u64, log2_spacing: u32) -> u32 {
    if log2_spacing >= 32 {
        0
    } else {
        (index as u32) << log2_spacing
    }
}

/// Round-granularity leapfrog over one master generator: `B` virtual
/// blocks deal out the master's rounds round-robin, so the interleaved
/// stream is exactly the serial master sequence for any `B`
/// ([`Placement::Leapfrog`]).
///
/// The virtual blocks share the single master state: `dump_state` /
/// `load_state` carry one block's words, not `B` of them.
pub struct LeapfrogBlock {
    inner: Box<dyn BlockParallel + Send>,
    virtual_blocks: usize,
}

impl LeapfrogBlock {
    /// Wrap a single-block master generator in `virtual_blocks` leapfrog
    /// lanes.
    pub fn new(inner: Box<dyn BlockParallel + Send>, virtual_blocks: usize) -> LeapfrogBlock {
        assert_eq!(inner.blocks(), 1, "leapfrog deals out ONE master sequence");
        assert!(virtual_blocks >= 1);
        LeapfrogBlock { inner, virtual_blocks }
    }
}

impl BlockParallel for LeapfrogBlock {
    fn blocks(&self) -> usize {
        self.virtual_blocks
    }

    fn lane_width(&self) -> usize {
        self.inner.lane_width()
    }

    fn fill_round(&mut self, out: &mut [u32]) {
        assert_eq!(out.len(), self.round_len(), "fill_round needs round_len() words");
        let lane = self.inner.round_len();
        for b in 0..self.virtual_blocks {
            self.inner.fill_round(&mut out[b * lane..(b + 1) * lane]);
        }
    }

    /// Leapfrog never splits: the virtual blocks deal ONE master sequence
    /// out round-robin, so "block" outputs are serially dependent — there
    /// is no disjoint state to partition. The parallel fill engine falls
    /// back to the serial path (bit-identical by contract).
    fn split_fill<'a>(
        &'a mut self,
        _rounds: usize,
        _bounds: &[usize],
    ) -> Option<Vec<Box<dyn crate::exec::RangeFill + 'a>>> {
        None
    }

    fn dump_state(&self) -> Vec<u32> {
        self.inner.dump_state()
    }

    fn load_state(&mut self, words: &[u32]) {
        self.inner.load_state(words);
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn state_words_per_block(&self) -> usize {
        self.inner.state_words_per_block()
    }

    fn period_log2(&self) -> f64 {
        self.inner.period_log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::traits::InterleavedStream;
    use crate::prng::{make_block_generator, Prng32};

    #[test]
    fn placement_parse_roundtrip() {
        assert_eq!("seed-mix".parse::<Placement>(), Ok(Placement::SeedMix));
        assert_eq!("seedmix".parse::<Placement>(), Ok(Placement::SeedMix));
        assert_eq!(
            "exact-jump".parse::<Placement>(),
            Ok(Placement::ExactJump { log2_spacing: 96 })
        );
        assert_eq!(
            "exact-jump:40".parse::<Placement>(),
            Ok(Placement::ExactJump { log2_spacing: 40 })
        );
        assert_eq!("leapfrog".parse::<Placement>(), Ok(Placement::Leapfrog));
        for p in [
            Placement::SeedMix,
            Placement::ExactJump { log2_spacing: 96 },
            Placement::ExactJump { log2_spacing: 8 },
            Placement::Leapfrog,
        ] {
            assert_eq!(p.name().parse::<Placement>(), Ok(p), "{p}");
        }
        let err = "warp".parse::<Placement>().unwrap_err();
        assert_eq!(err.what, "placement");
        assert!("leapfrog:4".parse::<Placement>().is_err());
        assert!("exact-jump:x".parse::<Placement>().is_err());
        // Absurd spacings are typos, not placements.
        assert!("exact-jump:4000000000".parse::<Placement>().is_err());
        assert!("exact-jump:8192".parse::<Placement>().is_ok());
    }

    #[test]
    fn canonical_kind_groups_aliases() {
        use GeneratorKind::*;
        assert_eq!(canonical_master_kind(Xorgens), canonical_master_kind(XorgensGp));
        assert_eq!(canonical_master_kind(Mt19937), canonical_master_kind(Mtgp));
        assert_eq!(canonical_master_kind(Xorwow), Xorwow);
    }

    #[test]
    fn xorwow_state_at_small_offsets_match_iteration() {
        let master = PlacedMaster::new(GeneratorKind::Xorwow, 3);
        let base = master.master_state().to_vec();
        // Brute-force the master LFSR + Weyl forward k steps.
        let mut g = Xorwow::from_state([base[0], base[1], base[2], base[3], base[4]], base[5]);
        for k in 0..=40u128 {
            let placed = master.state_at_offset(k);
            let (x, d) = g.state();
            assert_eq!(&placed[..5], &x[..], "k={k}");
            assert_eq!(placed[5], d, "k={k}");
            g.next_u32(); // one step: LFSR + Weyl together
        }
    }

    #[test]
    fn spaced_index_equals_direct_offset() {
        let mut master = PlacedMaster::new(GeneratorKind::Xorwow, 9);
        for (i, sp) in [(0u64, 8u32), (1, 8), (5, 8), (3, 33), (2, 96)] {
            let spaced = master.state_at(i, sp);
            let direct = master.state_at_offset((i as u128) << sp);
            assert_eq!(spaced, direct, "i={i} sp={sp}");
        }
    }

    #[test]
    fn xorgens_placed_state_continues_master_stream() {
        // Jump the 4096-bit xorgens master by exactly one round of a
        // single-block generator: the placed state must equal the live
        // state after that round.
        let master = PlacedMaster::new(GeneratorKind::XorgensGp, 7);
        let mut live = XorgensGp::with_params(1, 1, XorgensParams::GP_4096);
        live.load_state(master.master_state());
        let lane = live.lane_width() as u128;
        let mut out = vec![0u32; live.round_len()];
        live.fill_round(&mut out);
        assert_eq!(master.state_at_offset(lane), live.dump_state());
    }

    #[test]
    fn mtgp_placed_state_continues_master_stream() {
        let master = PlacedMaster::new(GeneratorKind::Mtgp, 11);
        let mut live = Mtgp::new(1, 1);
        live.load_state(master.master_state());
        let lane = live.lane_width() as u128;
        let mut out = vec![0u32; live.round_len()];
        live.fill_round(&mut out);
        assert_eq!(master.state_at_offset(lane), live.dump_state());
    }

    #[test]
    fn exact_jump_substreams_are_master_subsequences() {
        // Substream i under a small spacing reads the master sequence
        // starting at output i·2^sp — verified against one long serial
        // read of the master.
        let sp = 9u32; // 512 outputs apart
        let mut master = PlacedMaster::new(GeneratorKind::XorgensGp, 5);
        let mut serial = XorgensGp::with_params(1, 1, XorgensParams::GP_4096);
        serial.load_state(master.master_state());
        let mut long = vec![0u32; 3 * (1 << sp)];
        // Consume in whole rounds (63 | 512·k is false, so draw extra and
        // trim): use the interleaved adapter for exact continuation.
        let mut st = InterleavedStream::new(serial);
        st.fill_u32(&mut long);
        for i in 0..3u64 {
            let mut sub = XorgensGp::with_params(1, 1, XorgensParams::GP_4096);
            sub.load_state(&master.state_at(i, sp));
            let mut got = vec![0u32; 100];
            InterleavedStream::new(sub).fill_u32(&mut got);
            let at = (i as usize) << sp;
            assert_eq!(got[..], long[at..at + 100], "substream {i}");
        }
    }

    #[test]
    fn jump_cache_roundtrips_and_survives_corruption() {
        let dir = std::env::temp_dir().join(format!("xorgensgp-jumpcache-{}", std::process::id()));
        let path = dir.join("jump_poly.cache");
        let _ = std::fs::remove_file(&path);
        let stepper = stepper_for(GeneratorKind::Xorwow);
        let probed = JumpEngine::probe(stepper.as_ref());
        // Miss → None.
        assert!(load_cached_poly(&path, "xorwow", 160).is_none());
        // Store → load round-trips the polynomial exactly.
        store_cached_poly(&path, "xorwow", 160, probed.min_poly()).unwrap();
        let loaded = load_cached_poly(&path, "xorwow", 160).expect("cache hit");
        assert_eq!(&loaded, probed.min_poly());
        assert!(JumpEngine::from_cached(stepper.as_ref(), loaded).is_some());
        // A second kind's line coexists; the first stays intact.
        store_cached_poly(&path, "mtgp", 19968, probed.min_poly()).unwrap();
        assert_eq!(load_cached_poly(&path, "xorwow", 160).as_ref(), Some(probed.min_poly()));
        // Re-storing the same kind replaces, not duplicates.
        store_cached_poly(&path, "xorwow", 160, probed.min_poly()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().filter(|l| l.starts_with("xorwow ")).count(), 1);
        // n_bits mismatch is a miss (stale cache from a changed layout).
        assert!(load_cached_poly(&path, "xorwow", 192).is_none());
        // Corruption falls back to a miss, not a panic or a wrong poly.
        std::fs::write(&path, "xorwow 160 zz:!!\nnot a line\nxorwow\n").unwrap();
        assert!(load_cached_poly(&path, "xorwow", 160).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn leapfrog_interleaved_stream_is_serial_master() {
        // Any virtual block count reproduces the serial master stream.
        let mk = |blocks: usize| {
            let inner = make_block_generator(GeneratorKind::XorgensGp, 77, 1);
            InterleavedStream::new(LeapfrogBlock::new(inner, blocks))
        };
        let mut serial = InterleavedStream::new(make_block_generator(
            GeneratorKind::XorgensGp,
            77,
            1,
        ));
        let expect: Vec<u32> = (0..1000).map(|_| serial.next_u32()).collect();
        for blocks in [1usize, 2, 4, 7] {
            let mut st = mk(blocks);
            let got: Vec<u32> = (0..1000).map(|_| st.next_u32()).collect();
            assert_eq!(got, expect, "blocks={blocks}");
        }
    }
}

//! xorgens parameter sets `(r, s, a, b, c, d)` and their validation.
//!
//! The recurrence (paper §2) over 32-bit words is
//!
//! ```text
//! x_k = x_{k-r} (I + L^a)(I + R^b)  ^  x_{k-s} (I + L^c)(I + R^d)
//! ```
//!
//! Structural constraints (Brent 2007): `r` a power of two (cheap circular
//! indexing), `0 < s < r`, `gcd(r, s) = 1`, shifts in `1..32`. For a maximal
//! period `2^(32r) − 1` the characteristic polynomial of the 32r-bit
//! transition matrix must be primitive; we verify this exactly for small `r`
//! (where `2^(32r) − 1` is factorable) via [`crate::gf2`], and verify
//! invertibility (full rank — a necessary condition) for the big production
//! sets.
//!
//! The paper adds one more constraint for the GPU variant: the intra-block
//! parallel degree is `min(s, r−s)`, so `s ≈ r/2` is chosen — with
//! `gcd(r, s) = 1` forcing `s = r/2 ± 1` (paper §2). Brent's serial xor4096i
//! instead uses `s = 95`.

use crate::gf2::{transition_matrix, LinearStep};

/// A full xorgens parameter set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct XorgensParams {
    /// Degree of recurrence = state words (power of two).
    pub r: usize,
    /// Second tap, `0 < s < r`, `gcd(r, s) = 1`.
    pub s: usize,
    /// Left shift on the `x_{k-r}` term.
    pub a: u32,
    /// Right shift on the `x_{k-r}` term.
    pub b: u32,
    /// Left shift on the `x_{k-s}` term.
    pub c: u32,
    /// Right shift on the `x_{k-s}` term.
    pub d: u32,
}

impl XorgensParams {
    /// Brent's serial xor4096i (xorgens v3.05, 32-bit): period `2^4096 − 1`
    /// (times `2^32` with the Weyl combination).
    pub const BRENT_4096: XorgensParams =
        XorgensParams { r: 128, s: 95, a: 17, b: 12, c: 13, d: 15 };

    /// The paper's xorgensGP set (§2): `s = 65 = r/2 + 1` maximises the
    /// parallel degree `min(s, r−s) = 63`.
    pub const GP_4096: XorgensParams =
        XorgensParams { r: 128, s: 65, a: 15, b: 14, c: 12, d: 17 };

    /// A tiny two-word set used by unit tests and the gf2 machinery
    /// (exhaustively verified primitive at build time by
    /// `find_small_params` — see `tests` below).
    pub const TEST_64: XorgensParams = XorgensParams { r: 2, s: 1, a: 17, b: 14, c: 12, d: 19 };

    /// Intra-block parallel degree: `min(s, r−s)` (paper §2).
    pub fn parallel_degree(&self) -> usize {
        self.s.min(self.r - self.s)
    }

    /// State bits of the LFSR part.
    pub fn n_bits(&self) -> usize {
        32 * self.r
    }

    /// log2 of the full period including the Weyl factor:
    /// `(2^(32r) − 1) · 2^32` ≈ `2^(32r + 32)`.
    pub fn period_log2(&self) -> f64 {
        (32 * self.r + 32) as f64
    }

    /// Structural validation (cheap, always run).
    pub fn validate(&self) -> Result<(), String> {
        if !self.r.is_power_of_two() || self.r < 2 {
            return Err(format!("r={} must be a power of two >= 2", self.r));
        }
        if self.s == 0 || self.s >= self.r {
            return Err(format!("s={} must satisfy 0 < s < r={}", self.s, self.r));
        }
        if gcd(self.r, self.s) != 1 {
            return Err(format!("gcd(r={}, s={}) must be 1", self.r, self.s));
        }
        for (name, v) in [("a", self.a), ("b", self.b), ("c", self.c), ("d", self.d)] {
            if v == 0 || v >= 32 {
                return Err(format!("shift {name}={v} out of range 1..32"));
            }
        }
        Ok(())
    }

    /// Necessary condition for maximal period: the transition matrix of the
    /// LFSR part is invertible (full rank). Exact for any `r`, O((32r)^3/64).
    pub fn check_invertible(&self) -> bool {
        let m = transition_matrix(&RawStep(*self));
        m.rank() == self.n_bits()
    }

    /// Exact maximal-period check for small `r` (needs `2^(32r) − 1`
    /// factorable; we support `32r <= 64`): the transition matrix `M` must
    /// have order exactly `2^n − 1`.
    pub fn check_max_period_small(&self) -> bool {
        let n = self.n_bits();
        assert!(n <= 64, "exact period check limited to 32r <= 64");
        let m = transition_matrix(&RawStep(*self));
        let order: u128 = (1u128 << n) - 1;
        // M^order must be I…
        if !m.pow(order).is_identity() {
            return false;
        }
        // …and no proper divisor order: M^(order/q) != I for prime q | order.
        for q in crate::gf2::factor_u128(order) {
            if m.pow(order / q).is_identity() {
                return false;
            }
        }
        true
    }
}

/// One raw LFSR step of the xorgens recurrence, advanced a full `r` words so
/// the map is state→state on exactly `32r` bits (stepping one *word* is not
/// a square map because of the moving index; stepping `r` words is).
///
/// Wait — one word per step *is* linear on the (state, index) pair, but the
/// index isn't GF(2) data. We therefore define the linear step as "advance
/// by one word with the buffer kept in rolled canonical order" (oldest word
/// first), which is a fixed linear map on 32r bits.
struct RawStep(XorgensParams);

impl LinearStep for RawStep {
    fn n_bits(&self) -> usize {
        self.0.n_bits()
    }

    fn step_words(&self, state: &mut [u32]) {
        let p = &self.0;
        // state[m] = x_{k-r+m}; compute x_k, then roll left by one.
        let mut t = state[0]; // x_{k-r}
        let mut v = state[p.r - p.s]; // x_{k-s}
        t ^= t << p.a;
        t ^= t >> p.b;
        v ^= v << p.c;
        v ^= v >> p.d;
        let new = v ^ t;
        state.copy_within(1.., 0);
        state[p.r - 1] = new;
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Search for a maximal-period `(a, b, c, d)` for a small `(r, s)`
/// (32r <= 64). Used by tests and the `params-search` CLI subcommand —
/// the same procedure Brent used to produce the xorgens tables.
pub fn find_small_params(r: usize, s: usize, limit: usize) -> Vec<XorgensParams> {
    let mut found = vec![];
    for a in 1..32u32 {
        for b in 1..32u32 {
            for c in 1..32u32 {
                for d in c..32u32 {
                    let p = XorgensParams { r, s, a, b, c, d };
                    if p.validate().is_ok() && p.check_max_period_small() {
                        found.push(p);
                        if found.len() >= limit {
                            return found;
                        }
                    }
                }
            }
        }
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn production_sets_validate() {
        XorgensParams::BRENT_4096.validate().unwrap();
        XorgensParams::GP_4096.validate().unwrap();
        assert_eq!(XorgensParams::GP_4096.parallel_degree(), 63);
        assert_eq!(XorgensParams::BRENT_4096.parallel_degree(), 33);
    }

    #[test]
    fn bad_params_rejected() {
        let bad_r = XorgensParams { r: 100, ..XorgensParams::GP_4096 };
        assert!(bad_r.validate().is_err());
        let bad_s = XorgensParams { s: 64, ..XorgensParams::GP_4096 }; // gcd(128,64)=64
        assert!(bad_s.validate().is_err());
        let bad_shift = XorgensParams { a: 0, ..XorgensParams::GP_4096 };
        assert!(bad_shift.validate().is_err());
        let bad_shift2 = XorgensParams { d: 32, ..XorgensParams::GP_4096 };
        assert!(bad_shift2.validate().is_err());
    }

    #[test]
    fn gp_set_maximises_parallel_degree() {
        // Paper §2: gcd(r,s)=1 forces s = r/2 ± 1; both give degree 63.
        for s in [63usize, 65] {
            let p = XorgensParams { s, ..XorgensParams::GP_4096 };
            p.validate().unwrap();
            assert_eq!(p.parallel_degree(), 63);
        }
        // Anything else is worse.
        let p = XorgensParams { s: 95, ..XorgensParams::GP_4096 };
        assert!(p.parallel_degree() < 63);
    }

    #[test]
    fn small_search_finds_max_period_sets() {
        let found = find_small_params(2, 1, 1);
        assert!(!found.is_empty(), "no maximal-period (r=2,s=1) set found");
        assert!(found[0].check_invertible());
    }

    #[test]
    fn test64_set_is_max_period() {
        // The constant used across unit tests must itself be maximal.
        assert!(XorgensParams::TEST_64.check_max_period_small());
    }

    #[test]
    fn invertibility_detects_degenerate() {
        // A deliberately degenerate "shift by 0" can't be expressed (validate
        // rejects it); instead check that some valid-looking sets are NOT
        // maximal, i.e. the checker can say no.
        let mut any_false = false;
        for d in [1u32, 2, 3] {
            let p = XorgensParams { r: 2, s: 1, a: 1, b: 1, c: 1, d };
            if p.validate().is_ok() && !p.check_max_period_small() {
                any_false = true;
            }
        }
        assert!(any_false, "period checker accepted everything");
    }
}

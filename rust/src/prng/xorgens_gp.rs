//! xorgensGP — the paper's contribution (§2): block-parallel xorgens.
//!
//! **Data-flow analysis (paper §2).** Writing the recurrence for a run of
//! consecutive outputs shows `x_{k+j}` depends on `x_{k+j-r}` and
//! `x_{k+j-s}`; as long as `j < min(s, r−s)` every input predates the batch,
//! so `min(s, r−s)` terms are computable simultaneously. With the GP
//! parameter set `(r, s) = (128, 65)` this gives 63-way parallelism inside
//! each block — the paper's "thread-level parallelism".
//!
//! **Block-level parallelism.** Each block owns a full generator state and
//! produces an independent subsequence: identical parameters, different
//! (well-mixed) seeds — the paper found per-block *parameter* sets (MTGP
//! style) cost occupancy without quality gains (§4). Block `b` of a
//! generator seeded `seed` uses `SeedSequence(seed).child(b)` — the
//! "consecutive seed values" + strong initialisation scheme of §4.
//!
//! **Canonical state layout** (shared bit-exactly with the Pallas kernel):
//! per block, `r` words `q[0..r]` in *rolled* order (`q[m] = x_{k-r+m}`,
//! oldest first) followed by the raw Weyl counter: `r + 1 = 129` words —
//! Table 1's xorgensGP footprint.

use super::init::SeedSequence;
use super::params::XorgensParams;
use super::traits::BlockParallel;
use super::weyl::{WEYL_32, WEYL_GAMMA};

/// Block-parallel xorgensGP.
pub struct XorgensGp {
    params: XorgensParams,
    /// Per-block state buffers, concatenated (`blocks * r` words), kept in
    /// **rolled** order: word `m` of a block is `x_{k-r+m}` (oldest first).
    /// Keeping the roll invariant (instead of a circular index) gives the
    /// round kernel static offsets — see `round_block` perf note.
    x: Vec<u32>,
    /// Per-block raw Weyl counters.
    w: Vec<u32>,
    blocks: usize,
    lane: usize,
}

impl XorgensGp {
    /// Default block count used by `make_generator` (matches the grid the
    /// paper launches: enough blocks to fill the device).
    pub const DEFAULT_BLOCKS: usize = 64;

    pub fn new(seed: u64, blocks: usize) -> Self {
        Self::with_params(seed, blocks, XorgensParams::GP_4096)
    }

    pub fn with_params(seed: u64, blocks: usize, params: XorgensParams) -> Self {
        params.validate().expect("invalid xorgens parameters");
        assert!(blocks >= 1);
        let r = params.r;
        let root = SeedSequence::new(seed);
        let mut x = vec![0u32; blocks * r];
        let mut w = vec![0u32; blocks];
        for b in 0..blocks {
            // Consecutive block ids, decorrelated by the seed sequence —
            // the paper's §4 initialisation scheme.
            let mut seq = root.child(b as u64);
            seq.fill_nonzero(&mut x[b * r..(b + 1) * r]);
            w[b] = seq.next_u32();
        }
        let mut g = XorgensGp { params, x, w, blocks, lane: params.parallel_degree() };
        // Warm-up each block (lockstep): discard ~4r outputs per block
        // through the fill path. The sink is a lane-sized stack buffer
        // (lane <= 64 — see `round_block`), so warm-up is allocation-free.
        let mut sink = [0u32; 64];
        let rounds_to_discard = (4 * r).div_ceil(g.lane);
        let k = crate::simd::fill_kernel();
        for _ in 0..rounds_to_discard {
            for b in 0..blocks {
                let x = &mut g.x[b * r..(b + 1) * r];
                Self::round_block_k(k, &g.params, g.lane, x, &mut g.w[b], &mut sink[..g.lane]);
            }
        }
        g
    }

    /// Construct directly from a canonical state dump (the
    /// `blocks * (r + 1)` layout of [`BlockParallel::dump_state`]) with
    /// the default GP parameters — no seeding, no warm-up. This is the
    /// placed-stream cold-start path: exact-jump backends build their
    /// generator from jumped states and must not pay (or be observed
    /// through) a throwaway seed + ~4r-round warm-up that `load_state`
    /// immediately overwrites.
    pub fn from_state(blocks: usize, words: &[u32]) -> Self {
        Self::from_state_with_params(XorgensParams::GP_4096, blocks, words)
    }

    pub fn from_state_with_params(params: XorgensParams, blocks: usize, words: &[u32]) -> Self {
        params.validate().expect("invalid xorgens parameters");
        assert!(blocks >= 1);
        let r = params.r;
        let mut g = XorgensGp {
            params,
            x: vec![0u32; blocks * r],
            w: vec![0u32; blocks],
            blocks,
            lane: params.parallel_degree(),
        };
        g.load_state(words);
        g
    }

    pub fn params(&self) -> XorgensParams {
        self.params
    }

    /// Advance block `b` one lockstep round, writing `lane` outputs.
    ///
    /// Reads are completed against the pre-round state by construction
    /// (`j < min(s, r−s)` — see module docs), so the plain in-order loop is
    /// bit-exact with a truly simultaneous (SIMD / CUDA-warp) evaluation.
    /// Perf (EXPERIMENTS.md §Perf L3-1): the buffer is kept rolled, so
    /// lane `j` reads `x[j]` and `x[r-s+j]` at static offsets — no per-lane
    /// masking or bounds checks in the hot chain, and LLVM auto-vectorizes
    /// the whole xor/shift/Weyl pipeline. The roll costs one `copy_within`
    /// of `r - lane` words per `lane` outputs.
    #[inline]
    fn round_block(
        params: &XorgensParams,
        lane: usize,
        x: &mut [u32],
        w: &mut u32,
        out: &mut [u32],
    ) {
        let (r, s) = (params.r, params.s);
        let (a, b, c, d) = (params.a, params.b, params.c, params.d);
        debug_assert!(lane <= s.min(r - s) && lane <= 64);
        let w0 = *w;
        // Two disjoint read regions; writes go to a stack-local buffer so
        // the compute loop has no aliasing and vectorizes cleanly.
        let mut new = [0u32; 64]; // max lane for r=128 is 63
        let new = &mut new[..lane];
        for j in 0..lane {
            let mut t = x[j]; // x_{k+j-r}
            let mut v = x[r - s + j]; // x_{k+j-s}
            t ^= t << a;
            t ^= t >> b;
            v ^= v << c;
            v ^= v >> d;
            new[j] = v ^ t;
        }
        for (j, (&n, o)) in new.iter().zip(out.iter_mut()).enumerate() {
            let wv = w0.wrapping_add(WEYL_32.wrapping_mul(j as u32 + 1));
            *o = n.wrapping_add(wv ^ (wv >> WEYL_GAMMA));
        }
        // Roll: [x[lane..r], new].
        x.copy_within(lane.., 0);
        x[r - lane..].copy_from_slice(new);
        *w = w0.wrapping_add(WEYL_32.wrapping_mul(lane as u32));
    }

    /// `round_block` through the selected SIMD kernel ([`crate::simd`]):
    /// `Scalar` runs the loop above verbatim, the vector kernels pack
    /// adjacent recurrence lanes per instruction — bit-identical output
    /// either way (the lanes are independent by the §2 data-flow
    /// analysis, so packing is a pure data-layout transform).
    #[inline]
    fn round_block_k(
        k: crate::simd::SimdKernel,
        params: &XorgensParams,
        lane: usize,
        x: &mut [u32],
        w: &mut u32,
        out: &mut [u32],
    ) {
        if k == crate::simd::SimdKernel::Scalar {
            Self::round_block(params, lane, x, w, out);
        } else {
            crate::simd::kernels::xorgens_round(k, params, lane, x, w, out);
        }
    }
}

/// One worker's share of a split [`XorgensGp`]: exclusive views of a
/// contiguous block range's recurrence buffers and Weyl counters. Blocks
/// are fully independent, so any sub-range splits cleanly.
struct GpPart<'a> {
    params: XorgensParams,
    lane: usize,
    rounds: usize,
    /// Absolute index of the first owned block.
    lo: usize,
    /// Owned recurrence state, `(hi - lo) * r` words.
    x: &'a mut [u32],
    /// Owned Weyl counters, `hi - lo` words.
    w: &'a mut [u32],
}

impl crate::exec::RangeFill for GpPart<'_> {
    fn fill_rounds(&mut self, out: &crate::exec::StridedOut) {
        let r = self.params.r;
        // One kernel resolution per part run: SIMD × threads compose, and
        // the choice cannot change mid-fill.
        let k = crate::simd::fill_kernel();
        for (i, w) in self.w.iter_mut().enumerate() {
            let x = &mut self.x[i * r..(i + 1) * r];
            for t in 0..self.rounds {
                // SAFETY: this part exclusively owns block `lo + i` (the
                // split handed out disjoint ranges), so no other worker
                // touches these (round, block) windows.
                let dst = unsafe { out.block_slice(t, self.lo + i) };
                XorgensGp::round_block_k(k, &self.params, self.lane, x, w, dst);
            }
        }
    }
}

impl BlockParallel for XorgensGp {
    fn blocks(&self) -> usize {
        self.blocks
    }

    fn lane_width(&self) -> usize {
        self.lane
    }

    fn split_fill<'a>(
        &'a mut self,
        rounds: usize,
        bounds: &[usize],
    ) -> Option<Vec<Box<dyn crate::exec::RangeFill + 'a>>> {
        debug_assert!(bounds.len() >= 2 && bounds.windows(2).all(|w| w[0] < w[1]));
        assert!(*bounds.last().unwrap() <= self.blocks, "split bounds exceed block count");
        let r = self.params.r;
        let mut parts: Vec<Box<dyn crate::exec::RangeFill + 'a>> =
            Vec::with_capacity(bounds.len() - 1);
        let mut x_rest = &mut self.x[bounds[0] * r..];
        let mut w_rest = &mut self.w[bounds[0]..];
        for pair in bounds.windows(2) {
            let (lo, hi) = (pair[0], pair[1]);
            let take = hi - lo;
            let (x, x_next) = std::mem::take(&mut x_rest).split_at_mut(take * r);
            x_rest = x_next;
            let (w, w_next) = std::mem::take(&mut w_rest).split_at_mut(take);
            w_rest = w_next;
            parts.push(Box::new(GpPart { params: self.params, lane: self.lane, rounds, lo, x, w }));
        }
        Some(parts)
    }

    fn fill_round(&mut self, out: &mut [u32]) {
        let r = self.params.r;
        assert_eq!(out.len(), self.blocks * self.lane, "fill_round needs round_len() words");
        let k = crate::simd::fill_kernel();
        for b in 0..self.blocks {
            let x = &mut self.x[b * r..(b + 1) * r];
            let o = &mut out[b * self.lane..(b + 1) * self.lane];
            Self::round_block_k(k, &self.params, self.lane, x, &mut self.w[b], o);
        }
    }

    fn dump_state(&self) -> Vec<u32> {
        let r = self.params.r;
        let mut out = Vec::with_capacity(self.blocks * (r + 1));
        for b in 0..self.blocks {
            // The buffer is already rolled (oldest first).
            out.extend_from_slice(&self.x[b * r..(b + 1) * r]);
            out.push(self.w[b]);
        }
        out
    }

    fn load_state(&mut self, words: &[u32]) {
        let r = self.params.r;
        assert_eq!(words.len(), self.blocks * (r + 1), "state size mismatch");
        for b in 0..self.blocks {
            let src = &words[b * (r + 1)..(b + 1) * (r + 1)];
            self.x[b * r..(b + 1) * r].copy_from_slice(&src[..r]);
            self.w[b] = src[r];
        }
    }

    fn name(&self) -> &'static str {
        "xorgensgp"
    }

    fn state_words_per_block(&self) -> usize {
        self.params.r + 1
    }

    fn period_log2(&self) -> f64 {
        self.params.period_log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::traits::InterleavedStream;
    use crate::prng::{Prng32, Xorgens};

    /// The fundamental correctness property: each block's subsequence is
    /// bit-identical to a serial xorgens started from the same state.
    #[test]
    fn block_stream_equals_serial() {
        let mut gp = XorgensGp::new(42, 3);
        let state = gp.dump_state();
        let r = gp.params().r;
        // Serial replicas from each block's canonical state.
        let mut serials: Vec<Xorgens> = (0..3)
            .map(|b| {
                let s = &state[b * (r + 1)..(b + 1) * (r + 1)];
                Xorgens::from_canonical_state(gp.params(), &s[..r], s[r])
            })
            .collect();
        let mut out = vec![0u32; gp.round_len()];
        for _round in 0..10 {
            gp.fill_round(&mut out);
            for (b, serial) in serials.iter_mut().enumerate() {
                for j in 0..gp.lane_width() {
                    let got = out[b * gp.lane_width() + j];
                    assert_eq!(got, serial.next_u32(), "block {b} lane {j}");
                }
            }
        }
    }

    #[test]
    fn dump_load_roundtrip() {
        let mut a = XorgensGp::new(7, 4);
        let mut round = vec![0u32; a.round_len()];
        a.fill_round(&mut round); // desynchronise from canonical
        let st = a.dump_state();
        let mut b = XorgensGp::new(0, 4);
        b.load_state(&st);
        let mut oa = vec![0u32; 5 * a.round_len()];
        let mut ob = vec![0u32; 5 * a.round_len()];
        a.fill_interleaved(&mut oa);
        b.fill_interleaved(&mut ob);
        assert_eq!(oa, ob);
    }

    #[test]
    fn lane_width_is_paper_value() {
        let gp = XorgensGp::new(1, 1);
        assert_eq!(gp.lane_width(), 63);
        assert_eq!(gp.state_words_per_block(), 129); // Table 1
    }

    #[test]
    fn blocks_are_distinct_subsequences() {
        let mut gp = XorgensGp::new(5, 2);
        let mut out = vec![0u32; gp.round_len()];
        gp.fill_round(&mut out);
        let lane = gp.lane_width();
        assert_ne!(out[..lane], out[lane..2 * lane]);
    }

    #[test]
    fn interleaved_stream_consistent_with_rounds() {
        let gp1 = XorgensGp::new(9, 2);
        let mut gp2 = XorgensGp::new(9, 2);
        let mut st = InterleavedStream::new(gp1);
        let round = gp2.round_len();
        let mut expect = vec![0u32; 2 * round];
        gp2.fill_round(&mut expect[..round]);
        gp2.fill_round(&mut expect[round..]);
        let got: Vec<u32> = (0..expect.len()).map(|_| st.next_u32()).collect();
        assert_eq!(got, expect);
    }

    /// Scalar draws and bulk fill over the adapter are the same stream.
    #[test]
    fn scalar_and_bulk_paths_bit_identical() {
        let mut scalar = InterleavedStream::new(XorgensGp::new(77, 2));
        let mut bulk = InterleavedStream::new(XorgensGp::new(77, 2));
        let expect: Vec<u32> = (0..500).map(|_| scalar.next_u32()).collect();
        let mut got = vec![0u32; 500];
        bulk.fill_u32(&mut got);
        assert_eq!(got, expect);
    }

    /// The cold-start constructor: `from_state` is bit-identical to the
    /// old seed + warm-up + `load_state` dance, with no dead work.
    #[test]
    fn from_state_matches_seed_then_load() {
        let mut src = XorgensGp::new(11, 3);
        let mut round = vec![0u32; src.round_len()];
        src.fill_round(&mut round);
        let st = src.dump_state();
        let mut old_path = XorgensGp::new(999, 3);
        old_path.load_state(&st);
        let mut cold = XorgensGp::from_state(3, &st);
        let mut a = vec![0u32; 2 * src.round_len()];
        let mut b = vec![0u32; 2 * src.round_len()];
        old_path.fill_interleaved(&mut a);
        cold.fill_interleaved(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn fill_exact_sizes() {
        for n in [1usize, 62, 63, 64, 126, 1000] {
            let mut gp = XorgensGp::new(3, 2);
            let mut buf = vec![0u32; n];
            gp.fill_interleaved(&mut buf);
            // No unwritten tail (prob. of a genuine 0 is 2^-32 per word; with
            // these small sizes just ensure not ALL trailing words are zero).
            assert!(buf.iter().any(|&x| x != 0), "n={n}");
        }
    }
}

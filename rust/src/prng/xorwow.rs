//! XORWOW (Marsaglia 2003, §"Xorwow") — the CURAND default generator
//! (paper §1.4). Bit-exact with the algorithm as published:
//!
//! ```c
//! t = x ^ (x >> 2);  x = y; y = z; z = w; w = v;
//! v = (v ^ (v << 4)) ^ (t ^ (t << 1));
//! return (d += 362437) + v;
//! ```
//!
//! State: 5 xorshift words + 1 Weyl counter = 6 words (Table 1), period
//! `(2^160 − 1)·2^32 ≈ 2^192 − 2^32` (Table 1's "2^192 − 2^32").

use super::init::SeedSequence;
use super::traits::{BlockParallel, Prng32};
use crate::gf2::LinearStep;

const WEYL_INC: u32 = 362437;

/// Marsaglia's published initial state, used by the paper's test-vector
/// checks (`Xorwow::marsaglia_reference`).
const REF_STATE: [u32; 5] = [123456789, 362436069, 521288629, 88675123, 5783321];
const REF_D: u32 = 6615241;

/// Serial XORWOW.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Xorwow {
    x: [u32; 5],
    d: u32,
}

impl Xorwow {
    /// Seeded construction: fills the 5-word LFSR state from the mixed seed
    /// sequence (CURAND similarly scrambles `(seed, subsequence)` into the
    /// state; its exact constants are unpublished — see DESIGN.md).
    pub fn new(seed: u64) -> Self {
        Self::from_seq(&mut SeedSequence::new(seed))
    }

    pub(crate) fn from_seq(seq: &mut SeedSequence) -> Self {
        let mut x = [0u32; 5];
        seq.fill_nonzero(&mut x);
        Xorwow { x, d: seq.next_u32() }
    }

    /// The exact initial state from Marsaglia's paper.
    pub fn marsaglia_reference() -> Self {
        Xorwow { x: REF_STATE, d: REF_D }
    }

    pub fn from_state(x: [u32; 5], d: u32) -> Self {
        assert!(x.iter().any(|&v| v != 0), "LFSR state must be nonzero");
        Xorwow { x, d }
    }

    pub fn state(&self) -> ([u32; 5], u32) {
        (self.x, self.d)
    }

    /// Raw LFSR step without the Weyl counter (for linearity probes).
    #[inline]
    pub fn step_raw(&mut self) -> u32 {
        let t = self.x[0] ^ (self.x[0] >> 2);
        self.x[0] = self.x[1];
        self.x[1] = self.x[2];
        self.x[2] = self.x[3];
        self.x[3] = self.x[4];
        let v = (self.x[4] ^ (self.x[4] << 4)) ^ (t ^ (t << 1));
        self.x[4] = v;
        v
    }
}

impl Prng32 for Xorwow {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        let v = self.step_raw();
        self.d = self.d.wrapping_add(WEYL_INC);
        self.d.wrapping_add(v)
    }

    fn name(&self) -> &'static str {
        "xorwow"
    }

    fn state_words(&self) -> usize {
        6 // Table 1
    }

    fn period_log2(&self) -> f64 {
        192.0
    }
}

/// The 160-bit LFSR part as a linear step (for gf2 jump-ahead: the
/// coordinator jumps XORWOW streams apart exactly).
pub struct XorwowLfsr;

impl LinearStep for XorwowLfsr {
    fn n_bits(&self) -> usize {
        160
    }

    fn step_words(&self, state: &mut [u32]) {
        let mut g = Xorwow { x: [state[0], state[1], state[2], state[3], state[4]], d: 0 };
        g.step_raw();
        state.copy_from_slice(&g.x);
    }
}

/// Block-parallel XORWOW: `B` independent single-word-lane generators —
/// CURAND's one-state-per-thread model (the paper's CURAND rows launch a
/// grid of such threads; there is no intra-state parallelism to exploit in
/// a 6-word generator, hence `lane_width() == 1`).
///
/// Perf (EXPERIMENTS.md §Perf L3-4): state is stored SoA — five lane-wide
/// word arrays plus the Weyl counters — with a rotating *phase* assigning
/// roles (`x0` of round k lives in `arr[(phase) % 5]`), so a round is one
/// tight loop over contiguous arrays (auto-vectorized) and the 5-word
/// "shift" costs nothing.
pub struct XorwowBlock {
    /// Five SoA word arrays; logical `x_i` of the current round is
    /// `arr[(phase + i) % 5]`.
    arr: [Vec<u32>; 5],
    d: Vec<u32>,
    phase: usize,
    blocks: usize,
}

impl XorwowBlock {
    pub fn new(seed: u64, blocks: usize) -> Self {
        assert!(blocks >= 1);
        let root = SeedSequence::new(seed);
        let mut g = XorwowBlock {
            arr: std::array::from_fn(|_| vec![0u32; blocks]),
            d: vec![0u32; blocks],
            phase: 0,
            blocks,
        };
        for b in 0..blocks {
            let lane = Xorwow::from_seq(&mut root.child(b as u64));
            let (x, d) = lane.state();
            for i in 0..5 {
                g.arr[i][b] = x[i];
            }
            g.d[b] = d;
        }
        g
    }

    /// Construct with *consecutive raw seeds and weak mixing* — an
    /// ablation reproducing the paper's §4 hypothesis that CURAND's
    /// BigCrush failure stems from block-level initialisation. Used by the
    /// `battery --weak-init` path and EXPERIMENTS.md.
    pub fn new_weak_init(seed: u64, blocks: usize) -> Self {
        let mut g = XorwowBlock {
            arr: std::array::from_fn(|_| vec![0u32; blocks]),
            d: vec![0u32; blocks],
            phase: 0,
            blocks,
        };
        for b in 0..blocks {
            // Raw consecutive seeds dropped straight into the state —
            // exactly what proper initialisation is supposed to prevent.
            let s = seed.wrapping_add(b as u64) as u32;
            let x = [
                s | 1,
                s.wrapping_add(1),
                s.wrapping_add(2),
                s.wrapping_add(3),
                s.wrapping_add(4),
            ];
            for i in 0..5 {
                g.arr[i][b] = x[i];
            }
            g.d[b] = s;
        }
        g
    }

    /// Construct directly from a state dump (`blocks * 6` words, the
    /// `dump_state` layout) — no seed mixing: the placed-stream
    /// cold-start path for exact-jump backends.
    pub fn from_state(blocks: usize, words: &[u32]) -> Self {
        assert!(blocks >= 1);
        let mut g = XorwowBlock {
            arr: std::array::from_fn(|_| vec![0u32; blocks]),
            d: vec![0u32; blocks],
            phase: 0,
            blocks,
        };
        g.load_state(words);
        g
    }

    /// One lockstep step of every lane, writing one output per lane.
    #[inline]
    fn step_all(&mut self, out: &mut [u32]) {
        let i0 = self.phase % 5;
        let i4 = (self.phase + 4) % 5;
        // i0 != i4 always; borrow disjoint arrays via split.
        let (lo, hi) = (i0.min(i4), i0.max(i4));
        let (head, tail) = self.arr.split_at_mut(hi);
        let (a_lo, a_hi) = (&mut head[lo], &mut tail[0]);
        let (t_arr, v_arr): (&mut Vec<u32>, &Vec<u32>) =
            if i0 < i4 { (a_lo, a_hi) } else { (a_hi, a_lo) };
        // XORWOW vectorizes *across blocks* (lane width is 1): the SoA
        // arrays are the vector axis. Scalar runs the original loop.
        let k = crate::simd::fill_kernel();
        if k == crate::simd::SimdKernel::Scalar {
            for b in 0..self.blocks {
                let x0 = t_arr[b];
                let t = x0 ^ (x0 >> 2);
                let vp = v_arr[b];
                let v = (vp ^ (vp << 4)) ^ (t ^ (t << 1));
                t_arr[b] = v; // becomes x4 of the next round
                let d = self.d[b].wrapping_add(WEYL_INC);
                self.d[b] = d;
                out[b] = d.wrapping_add(v);
            }
        } else {
            crate::simd::kernels::xorwow_step(
                k,
                t_arr.as_mut_slice(),
                v_arr.as_slice(),
                &mut self.d,
                out,
                WEYL_INC,
            );
        }
        self.phase = (self.phase + 1) % 5;
    }
}

/// One worker's share of a split [`XorwowBlock`]: exclusive views of a
/// lane range across all five SoA arrays and the Weyl counters, plus a
/// local copy of the rotation phase. `fill_rounds` advances **all** baked
/// rounds in one virtual call — with `lane_width() == 1` a per-round
/// dispatch would cost more than the 1-word round itself (the ISSUE's
/// round-batching point).
struct XwPart<'a> {
    arr: [&'a mut [u32]; 5],
    d: &'a mut [u32],
    phase: usize,
    rounds: usize,
    /// Absolute index of the first owned lane.
    lo: usize,
}

impl crate::exec::RangeFill for XwPart<'_> {
    fn fill_rounds(&mut self, out: &crate::exec::StridedOut) {
        // One kernel resolution per part run (SIMD × threads compose).
        let k = crate::simd::fill_kernel();
        let nblocks = self.d.len();
        for t in 0..self.rounds {
            // Same role mapping and kernel as `step_all`, restricted to
            // the owned lanes. With lane width 1 the round's whole output
            // row for this block range is one contiguous slice — the
            // vectorization axis.
            let i0 = self.phase % 5;
            let i4 = (self.phase + 4) % 5;
            let (lo_i, hi_i) = (i0.min(i4), i0.max(i4));
            let (head, tail) = self.arr.split_at_mut(hi_i);
            let a_lo = &mut *head[lo_i];
            let a_hi = &mut *tail[0];
            let (t_arr, v_arr) = if i0 < i4 { (a_lo, a_hi) } else { (a_hi, a_lo) };
            // SAFETY: this part exclusively owns lanes `lo..lo + nblocks`.
            let row = unsafe { out.block_slice_range(t, self.lo, self.lo + nblocks) };
            if k == crate::simd::SimdKernel::Scalar {
                for b in 0..nblocks {
                    let x0 = t_arr[b];
                    let tt = x0 ^ (x0 >> 2);
                    let vp = v_arr[b];
                    let v = (vp ^ (vp << 4)) ^ (tt ^ (tt << 1));
                    t_arr[b] = v;
                    let d = self.d[b].wrapping_add(WEYL_INC);
                    self.d[b] = d;
                    row[b] = d.wrapping_add(v);
                }
            } else {
                crate::simd::kernels::xorwow_step(k, t_arr, v_arr, self.d, row, WEYL_INC);
            }
            self.phase = (self.phase + 1) % 5;
        }
    }
}

impl BlockParallel for XorwowBlock {
    fn blocks(&self) -> usize {
        self.blocks
    }

    fn lane_width(&self) -> usize {
        1
    }

    fn fill_round(&mut self, out: &mut [u32]) {
        assert_eq!(out.len(), self.blocks, "fill_round needs round_len() words");
        self.step_all(out);
    }

    /// XORWOW's rotating `phase` is shared bookkeeping across every lane,
    /// so partial coverage cannot advance it consistently: the split
    /// requires `bounds` to cover `0..blocks` and advances the parent's
    /// phase eagerly (`+rounds`), each part carrying a local copy — which
    /// is exactly why every returned part must be driven.
    fn split_fill<'a>(
        &'a mut self,
        rounds: usize,
        bounds: &[usize],
    ) -> Option<Vec<Box<dyn crate::exec::RangeFill + 'a>>> {
        debug_assert!(bounds.len() >= 2 && bounds.windows(2).all(|w| w[0] < w[1]));
        if bounds.first() != Some(&0) || bounds.last() != Some(&self.blocks) {
            return None;
        }
        let phase0 = self.phase;
        self.phase = (self.phase + rounds) % 5;
        let [a0, a1, a2, a3, a4] = &mut self.arr;
        let mut arr_rest: [&mut [u32]; 5] =
            [&mut a0[..], &mut a1[..], &mut a2[..], &mut a3[..], &mut a4[..]];
        let mut d_rest: &mut [u32] = &mut self.d;
        let mut parts: Vec<Box<dyn crate::exec::RangeFill + 'a>> =
            Vec::with_capacity(bounds.len() - 1);
        for pair in bounds.windows(2) {
            let (lo, hi) = (pair[0], pair[1]);
            let take = hi - lo;
            let arr: [&mut [u32]; 5] = std::array::from_fn(|i| {
                let (part, rest) = std::mem::take(&mut arr_rest[i]).split_at_mut(take);
                arr_rest[i] = rest;
                part
            });
            let (d, d_next) = std::mem::take(&mut d_rest).split_at_mut(take);
            d_rest = d_next;
            parts.push(Box::new(XwPart { arr, d, phase: phase0, rounds, lo }));
        }
        Some(parts)
    }

    fn dump_state(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.blocks * 6);
        for b in 0..self.blocks {
            for i in 0..5 {
                out.push(self.arr[(self.phase + i) % 5][b]);
            }
            out.push(self.d[b]);
        }
        out
    }

    fn load_state(&mut self, words: &[u32]) {
        assert_eq!(words.len(), self.blocks * 6, "state size mismatch");
        self.phase = 0;
        for b in 0..self.blocks {
            let s = &words[b * 6..(b + 1) * 6];
            for i in 0..5 {
                self.arr[i][b] = s[i];
            }
            self.d[b] = s[5];
        }
    }

    fn name(&self) -> &'static str {
        "xorwow"
    }

    fn state_words_per_block(&self) -> usize {
        6
    }

    fn period_log2(&self) -> f64 {
        192.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_state_progression() {
        // First outputs from Marsaglia's published initial state. The
        // expected words are locked in as a golden vector (also cross-
        // checked against an independent Python implementation in
        // python/tests/test_golden.py).
        let mut g = Xorwow::marsaglia_reference();
        let first: Vec<u32> = (0..4).map(|_| g.next_u32()).collect();
        // Recompute by hand-stepping a second copy to guard regressions.
        let mut h = Xorwow::marsaglia_reference();
        let mut expect = Vec::new();
        for _ in 0..4 {
            let t = h.x[0] ^ (h.x[0] >> 2);
            h.x.rotate_left(1); // [y, z, w, v, x] — old v now at index 3
            let v_prev = h.x[3];
            let v = (v_prev ^ (v_prev << 4)) ^ (t ^ (t << 1));
            h.x[4] = v;
            h.d = h.d.wrapping_add(WEYL_INC);
            expect.push(h.d.wrapping_add(v));
        }
        assert_eq!(first, expect);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a: Vec<u32> = {
            let mut g = Xorwow::new(5);
            (0..8).map(|_| g.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut g = Xorwow::new(5);
            (0..8).map(|_| g.next_u32()).collect()
        };
        let c: Vec<u32> = {
            let mut g = Xorwow::new(6);
            (0..8).map(|_| g.next_u32()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn lfsr_is_linear() {
        // step_raw(x1) ^ step_raw(x2) == step_raw(x1 ^ x2) on the state.
        let s1 = [0x1234u32, 0x5678, 0x9abc, 0xdef0, 0x1111];
        let s2 = [0xffffu32, 0x0f0f, 0xf0f0, 0x3333, 0x7777];
        let sx: Vec<u32> = s1.iter().zip(&s2).map(|(a, b)| a ^ b).collect();
        let mut g1 = Xorwow::from_state(s1, 0);
        let mut g2 = Xorwow::from_state(s2, 0);
        let mut gx = Xorwow::from_state([sx[0], sx[1], sx[2], sx[3], sx[4]], 0);
        assert_eq!(g1.step_raw() ^ g2.step_raw(), gx.step_raw());
        assert_eq!(g1.x.iter().zip(&g2.x).map(|(a, b)| a ^ b).collect::<Vec<_>>(), gx.x.to_vec());
    }

    #[test]
    fn jump_ahead_via_gf2() {
        use crate::gf2::{jump_state, transition_matrix, transition_power};
        let m = transition_matrix(&XorwowLfsr);
        let mk = transition_power(&m, 12345);
        let mut g = Xorwow::new(9);
        let (x0, _) = g.state();
        for _ in 0..12345 {
            g.step_raw();
        }
        let jumped = jump_state(&mk, &x0);
        assert_eq!(jumped, g.state().0.to_vec());
    }

    #[test]
    fn block_lanes_independent() {
        let mut b = XorwowBlock::new(1, 4);
        let mut out = vec![0u32; b.round_len()];
        b.fill_round(&mut out);
        assert_eq!(out.len(), 4);
        assert!(out.windows(2).any(|w| w[0] != w[1]));
    }

    /// Each lane of the block generator reproduces the serial XORWOW
    /// seeded from the same seed sequence, through the bulk fill path.
    #[test]
    fn block_lanes_equal_serial_via_fill() {
        let blocks = 4;
        let mut blk = XorwowBlock::new(9, blocks);
        let mut out = vec![0u32; blocks * 16];
        blk.fill_interleaved(&mut out);
        for b in 0..blocks {
            let mut serial = Xorwow::from_seq(&mut SeedSequence::new(9).child(b as u64));
            for k in 0..16 {
                assert_eq!(out[k * blocks + b], serial.next_u32(), "lane {b} step {k}");
            }
        }
    }

    #[test]
    fn weak_init_correlated_lanes() {
        // The §4 ablation: consecutive raw seeds leave lanes measurably
        // correlated at the start (this is what the battery detects).
        let mut b = XorwowBlock::new_weak_init(1000, 8);
        let mut out = vec![0u32; b.round_len()];
        b.fill_round(&mut out);
        // Lanes seeded s, s+1, ... start nearly identical states — top bits
        // of the first outputs collide far more than chance.
        let top: Vec<u32> = out.iter().map(|x| x >> 24).collect();
        let mut collisions = 0;
        for i in 0..top.len() {
            for j in i + 1..top.len() {
                if top[i] == top[j] {
                    collisions += 1;
                }
            }
        }
        assert!(collisions >= 1, "expected early collisions from weak init, top bytes {top:?}");
    }
}

//! Serial xorgens (Brent 2007, xorgens v3.05) — paper §1.5.
//!
//! Step (32-bit words, parameters `(r, s, a, b, c, d)`):
//!
//! ```text
//! t = x_{k-r};  t ^= t << a;  t ^= t >> b;      // t (I+L^a)(I+R^b)
//! v = x_{k-s};  v ^= v << c;  v ^= v >> d;      // v (I+L^c)(I+R^d)
//! x_k = v ^ t;
//! w  += ω;                                      // Weyl
//! out = x_k + (w ^ (w >> γ))       (mod 2^32)   // eq. (1)
//! ```
//!
//! The Weyl addition is non-linear over GF(2), which is what lets xorgens
//! pass the linear-complexity and matrix-rank tests that fail every pure
//! LFSR (paper §1.5, Table 2).

use super::init::SeedSequence;
use super::params::XorgensParams;
use super::traits::Prng32;
use super::weyl::Weyl;
use crate::gf2::LinearStep;

/// Serial xorgens with Brent's xor4096i parameters by default.
#[derive(Clone)]
pub struct Xorgens {
    params: XorgensParams,
    x: Vec<u32>,
    w: Weyl,
    i: usize, // index of the most recently written slot
}

impl Xorgens {
    /// Brent's xor4096i (r=128, s=95).
    pub fn new(seed: u64) -> Self {
        Self::with_params(seed, XorgensParams::BRENT_4096)
    }

    /// Any validated parameter set.
    pub fn with_params(seed: u64, params: XorgensParams) -> Self {
        params.validate().expect("invalid xorgens parameters");
        let mut seq = SeedSequence::new(seed);
        let mut x = vec![0u32; params.r];
        seq.fill_nonzero(&mut x);
        let w = Weyl::new(seq.next_u32());
        let mut g = Xorgens { params, x, w, i: params.r - 1 };
        // Brent-style warm-up: discard a few r of outputs so the state
        // leaves the neighbourhood of the (already well-mixed) seed fill.
        for _ in 0..4 * params.r {
            g.step_raw();
        }
        g
    }

    /// Construct from an explicit rolled state (oldest word first) and raw
    /// Weyl counter — the canonical interchange layout shared with the
    /// Pallas kernel (`python/compile/kernels/xorgens_gp.py`) and
    /// [`super::XorgensGp::dump_state`]. No warm-up is applied.
    pub fn from_canonical_state(params: XorgensParams, q: &[u32], w_raw: u32) -> Self {
        assert_eq!(q.len(), params.r);
        assert!(q.iter().any(|&v| v != 0), "LFSR state must be nonzero");
        Xorgens { params, x: q.to_vec(), w: Weyl::new(w_raw), i: params.r - 1 }
    }

    /// Export the rolled canonical state `(q oldest-first, w_raw)`.
    pub fn canonical_state(&self) -> (Vec<u32>, u32) {
        let r = self.params.r;
        let mut q = vec![0u32; r];
        for m in 0..r {
            // q[m] = x_{k-r+m}; slot of x_{k-j} is (i + r + 1 - j) mod r …
            // most recent (x_{k-1}) lives at slot i, oldest (x_{k-r}) at
            // slot (i+1) mod r.
            q[m] = self.x[(self.i + 1 + m) % r];
        }
        (q, self.w.raw())
    }

    /// One raw LFSR step (no Weyl) — exposed for linearity tests.
    #[inline]
    pub fn step_raw(&mut self) -> u32 {
        let p = &self.params;
        let mask = p.r - 1;
        self.i = (self.i + 1) & mask;
        let mut t = self.x[self.i]; // x_{k-r}
        let mut v = self.x[(self.i + p.r - p.s) & mask]; // x_{k-s}
        t ^= t << p.a;
        t ^= t >> p.b;
        v ^= v << p.c;
        v ^= v >> p.d;
        v ^= t;
        self.x[self.i] = v;
        v
    }

    pub fn params(&self) -> XorgensParams {
        self.params
    }
}

/// The xorgens LFSR as a [`LinearStep`] on the **rolled canonical layout**
/// (`q[m] = x_{k-r+m}`, oldest first — the interchange layout of
/// [`Xorgens::canonical_state`] and [`super::XorgensGp::dump_state`],
/// minus the Weyl word). One step computes `x_k` from `q[0] = x_{k-r}`
/// and `q[r-s] = x_{k-s}` and rolls the window by one.
///
/// This is what makes polynomial jump-ahead of the 4096-bit state
/// tractable: [`crate::gf2::JumpEngine`] needs only `step_words`, never a
/// dense 4096×4096 transition matrix.
pub struct XorgensLfsr(pub XorgensParams);

impl LinearStep for XorgensLfsr {
    fn n_bits(&self) -> usize {
        32 * self.0.r
    }

    fn step_words(&self, state: &mut [u32]) {
        let p = &self.0;
        debug_assert_eq!(state.len(), p.r);
        let mut t = state[0]; // x_{k-r}
        let mut v = state[p.r - p.s]; // x_{k-s}
        t ^= t << p.a;
        t ^= t >> p.b;
        v ^= v << p.c;
        v ^= v >> p.d;
        state.copy_within(1.., 0);
        state[p.r - 1] = v ^ t;
    }
}

impl Prng32 for Xorgens {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        let v = self.step_raw();
        v.wrapping_add(self.w.next_term())
    }

    fn name(&self) -> &'static str {
        "xorgens"
    }

    fn state_words(&self) -> usize {
        self.params.r + 1 // +1 Weyl; circular index not counted (paper Table 1)
    }

    fn period_log2(&self) -> f64 {
        self.params.period_log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Xorgens::new(1);
        let mut b = Xorgens::new(1);
        let mut c = Xorgens::new(2);
        let va: Vec<u32> = (0..16).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..16).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..16).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn canonical_state_roundtrip() {
        let mut a = Xorgens::new(99);
        for _ in 0..1000 {
            a.next_u32();
        }
        let (q, w) = a.canonical_state();
        let mut b = Xorgens::from_canonical_state(a.params(), &q, w);
        for _ in 0..500 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn small_params_work() {
        let mut g = Xorgens::with_params(7, XorgensParams::TEST_64);
        let v: Vec<u32> = (0..8).map(|_| g.next_u32()).collect();
        assert!(v.iter().any(|&x| x != 0));
    }

    #[test]
    fn raw_step_matches_recurrence() {
        // Drive the generator r+s steps and re-check the recurrence
        // x_k = A(x_{k-r}) ^ B(x_{k-s}) from recorded raw outputs.
        let p = XorgensParams::GP_4096;
        let mut g = Xorgens::with_params(3, p);
        // Record the last r raw values as history, then verify new ones.
        let mut hist: Vec<u32> = (0..p.r).map(|_| g.step_raw()).collect();
        for _ in 0..300 {
            let k = hist.len();
            let mut t = hist[k - p.r];
            let mut v = hist[k - p.s];
            t ^= t << p.a;
            t ^= t >> p.b;
            v ^= v << p.c;
            v ^= v >> p.d;
            let expect = v ^ t;
            let got = g.step_raw();
            assert_eq!(got, expect);
            hist.push(got);
        }
    }

    #[test]
    fn lfsr_step_matches_serial_rolled_state() {
        // XorgensLfsr on the rolled canonical layout must track the serial
        // generator's raw LFSR exactly, step for step.
        for params in [XorgensParams::GP_4096, XorgensParams::TEST_64] {
            let mut serial = Xorgens::with_params(5, params);
            let step = XorgensLfsr(params);
            let (mut q, _) = serial.canonical_state();
            for k in 0..300 {
                serial.step_raw();
                step.step_words(&mut q);
                let (expect, _) = serial.canonical_state();
                assert_eq!(q, expect, "r={} step {k}", params.r);
            }
        }
    }

    #[test]
    fn weyl_breaks_linearity_of_output() {
        // XOR of outputs at superposed seeds differs from output of XORed
        // states (a crude witness that the Weyl add is non-linear).
        let p = XorgensParams::TEST_64;
        let mut g1 = Xorgens::with_params(11, p);
        let mut g2 = Xorgens::with_params(12, p);
        let o1: Vec<u32> = (0..64).map(|_| g1.next_u32()).collect();
        let o2: Vec<u32> = (0..64).map(|_| g2.next_u32()).collect();
        // If output were linear in state, o1^o2 would be the output of a
        // valid state; raw LFSR outputs satisfy the recurrence, combined
        // outputs must not (generically).
        let xor: Vec<u32> = o1.iter().zip(&o2).map(|(a, b)| a ^ b).collect();
        let k = xor.len() - 1;
        let mut t = xor[k - p.r];
        let mut v = xor[k - p.s];
        t ^= t << p.a;
        t ^= t >> p.b;
        v ^= v << p.c;
        v ^= v >> p.d;
        assert_ne!(xor[k], v ^ t, "outputs look GF(2)-linear");
    }
}

//! Seeding / state initialisation.
//!
//! The paper (§4) attributes CURAND's BigCrush failure in the multi-block
//! setting to weak block-level initialisation, and credits xorgens'
//! "attention ... paid to the initialisation code" for the absence of
//! inter-block correlation even with *consecutive* integer seeds
//! (block id). We follow the same design rule Brent's xorgens 3.05 uses:
//! never feed raw seeds into the state — run every word through a strong
//! avalanche mixer, reject the all-zero LFSR state, then discard a few
//! multiples of `r` outputs so the state leaves the low-entropy
//! neighbourhood of the seed.

/// 64-bit avalanche mixer (the SplitMix64 / MurmurHash3 finalizer family —
/// every input bit affects every output bit with probability ~1/2).
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// A deterministic stream of well-mixed 32-bit words from a seed, used to
/// fill generator states. Distinct `(seed, counter)` pairs give distinct,
/// decorrelated words, so consecutive seeds (block ids) are safe.
pub struct SeedSequence {
    seed: u64,
    counter: u64,
}

impl SeedSequence {
    pub fn new(seed: u64) -> Self {
        SeedSequence { seed, counter: 0 }
    }

    /// Derive a child sequence (used for per-block seeding: child(block_id)).
    pub fn child(&self, stream: u64) -> SeedSequence {
        // Mix the stream id through before combining so that consecutive
        // stream ids land far apart.
        SeedSequence {
            seed: mix64(self.seed ^ mix64(stream.wrapping_add(0xa076_1d64_78bd_642f))),
            counter: 0,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let v = mix64(self.seed.wrapping_add(self.counter.wrapping_mul(0x9e3779b97f4a7c15)));
        self.counter += 1;
        v
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Fill `out`, guaranteeing the result is not all-zero (LFSR states must
    /// be nonzero; probability of needing the fixup is ~2^-32·len).
    pub fn fill_nonzero(&mut self, out: &mut [u32]) {
        loop {
            for w in out.iter_mut() {
                *w = self.next_u32();
            }
            if out.iter().any(|&w| w != 0) {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_avalanche() {
        // Flipping one input bit should flip roughly half the output bits.
        let base = mix64(0x1234_5678_9abc_def0);
        let mut total = 0u32;
        for b in 0..64 {
            let flipped = mix64(0x1234_5678_9abc_def0 ^ (1u64 << b));
            total += (base ^ flipped).count_ones();
        }
        let avg = total as f64 / 64.0;
        assert!((avg - 32.0).abs() < 4.0, "avalanche avg {avg}");
    }

    #[test]
    fn consecutive_seeds_decorrelated() {
        // The paper's block seeding: ids 0,1,2,... must yield state words
        // differing in ~half their bits.
        let mut a = SeedSequence::new(7).child(0);
        let mut b = SeedSequence::new(7).child(1);
        let mut diff = 0u32;
        const N: usize = 64;
        for _ in 0..N {
            diff += (a.next_u32() ^ b.next_u32()).count_ones();
        }
        let avg = diff as f64 / N as f64;
        assert!((avg - 16.0).abs() < 3.0, "avg bit diff {avg}");
    }

    #[test]
    fn deterministic() {
        let mut s1 = SeedSequence::new(42);
        let mut s2 = SeedSequence::new(42);
        for _ in 0..10 {
            assert_eq!(s1.next_u64(), s2.next_u64());
        }
    }

    #[test]
    fn fill_nonzero_never_zero() {
        let mut s = SeedSequence::new(0);
        let mut buf = [0u32; 4];
        s.fill_nonzero(&mut buf);
        assert!(buf.iter().any(|&w| w != 0));
    }
}

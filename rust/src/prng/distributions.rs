//! Output distributions over raw 32-bit draws — what the paper's target
//! Monte Carlo applications (§1: MCMC, SMC, particle MCMC) actually consume.
//!
//! Includes a table-driven ziggurat for the normal distribution (the
//! serving hot path) plus Box–Muller and inversion methods used as oracles.

use super::traits::Prng32;

/// Uniform on the open interval (0, 1) — never exactly 0 or 1, safe for
/// log() in Box–Muller / exponential inversion.
#[inline]
pub fn u01_open<R: Prng32 + ?Sized>(rng: &mut R) -> f64 {
    // (x + 0.5) / 2^32 ∈ (0, 1)
    (rng.next_u32() as f64 + 0.5) * (1.0 / 4294967296.0)
}

/// The canonical raw-word → single-precision uniform map of this repo
/// ([`Transform::F32`](crate::runtime::Transform) streams,
/// [`Prng32::next_f32`]): top 24 bits scaled by 2^-24, uniform on [0, 1)
/// and never 1.0. One definition, shared by the generator trait, the
/// coordinator's F32 backend transform, and the CLI formatter — the
/// cross-layer bit-exactness contract depends on all of them agreeing.
#[inline]
pub fn unit_f32(u: u32) -> f32 {
    (u >> 8) as f32 * (1.0 / 16_777_216.0)
}

/// Bulk [`unit_f32`]: map `src` into `dst` through the selected SIMD
/// kernel ([`crate::simd`]), bit-identical to the element-wise map for
/// every input — `(u >> 8) * 2^-24` is exact arithmetic (a < 2²⁴ integer
/// times a power of two), so no backend ever rounds. This is the bulk
/// F32 path of the coordinator backend and the battery's `ChunkedRng`.
///
/// # Panics
///
/// If the slices differ in length.
pub fn unit_f32_slice(src: &[u32], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len(), "unit_f32_slice length mismatch");
    let k = crate::simd::fill_kernel();
    if k == crate::simd::SimdKernel::Scalar {
        for (d, &s) in dst.iter_mut().zip(src.iter()) {
            *d = unit_f32(s);
        }
    } else {
        crate::simd::kernels::unit_f32_slice(k, src, dst);
    }
}

/// Standard normal via Box–Muller (pair-at-a-time; second value cached by
/// [`NormalBoxMuller`]). Used as the oracle for the ziggurat.
pub fn box_muller<R: Prng32 + ?Sized>(rng: &mut R) -> (f64, f64) {
    let u1 = u01_open(rng);
    let u2 = u01_open(rng);
    let r = (-2.0 * u1.ln()).sqrt();
    let theta = 2.0 * std::f64::consts::PI * u2;
    (r * theta.cos(), r * theta.sin())
}

/// Stateful Box–Muller sampler.
pub struct NormalBoxMuller {
    cached: Option<f64>,
}

impl NormalBoxMuller {
    pub fn new() -> Self {
        NormalBoxMuller { cached: None }
    }

    pub fn sample<R: Prng32 + ?Sized>(&mut self, rng: &mut R) -> f64 {
        if let Some(v) = self.cached.take() {
            return v;
        }
        let (a, b) = box_muller(rng);
        self.cached = Some(b);
        a
    }
}

impl Default for NormalBoxMuller {
    fn default() -> Self {
        Self::new()
    }
}

/// Exponential(1) by inversion.
#[inline]
pub fn exponential<R: Prng32 + ?Sized>(rng: &mut R) -> f64 {
    -u01_open(rng).ln()
}

// ---------------------------------------------------------------------------
// Ziggurat (Marsaglia & Tsang 2000) for the standard normal.
// ---------------------------------------------------------------------------

const ZIG_LAYERS: usize = 256;
/// Tail cut-off x_255 and layer area for the 256-layer normal ziggurat.
const ZIG_R: f64 = 3.654152885361008796;
const ZIG_V: f64 = 0.004928673233974655;

/// Precomputed ziggurat tables (built once; ~6 KiB).
pub struct Ziggurat {
    x: [f64; ZIG_LAYERS + 1],
    y: [f64; ZIG_LAYERS],
}

fn pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp()
}

impl Ziggurat {
    /// The process-wide shared tables: built once on first use, then
    /// served by reference forever. The tables are pure functions of the
    /// ziggurat constants, so every `Transform::Normal` backend can share
    /// one copy instead of rebuilding ~6 KiB per
    /// `RustBackend::new` — coordinators spin backends up per stream
    /// registration, so the rebuild was pure waste.
    pub fn shared() -> &'static Ziggurat {
        static SHARED: std::sync::OnceLock<Ziggurat> = std::sync::OnceLock::new();
        SHARED.get_or_init(Ziggurat::new)
    }

    pub fn new() -> Self {
        let mut x = [0.0; ZIG_LAYERS + 1];
        let mut y = [0.0; ZIG_LAYERS];
        x[ZIG_LAYERS] = ZIG_V / pdf(ZIG_R); // x_256: base layer virtual width
        x[ZIG_LAYERS - 1] = ZIG_R;
        for i in (1..ZIG_LAYERS - 1).rev() {
            // x_i such that layer area is constant: f(x_i) = f(x_{i+1}) + V / x_{i+1}
            let fy = pdf(x[i + 1]) + ZIG_V / x[i + 1];
            x[i] = (-2.0 * fy.ln()).sqrt();
        }
        x[0] = 0.0;
        for i in 0..ZIG_LAYERS {
            y[i] = pdf(x[i]);
        }
        Ziggurat { x, y }
    }

    /// One standard normal sample.
    pub fn sample<R: Prng32 + ?Sized>(&self, rng: &mut R) -> f64 {
        loop {
            let u = rng.next_u32();
            let i = (u & 0xff) as usize; // layer
            let sign = if u & 0x100 != 0 { 1.0 } else { -1.0 };
            // 23 remaining bits + a fresh draw for the coordinate.
            let uf = u01_open(rng);
            let x = uf * self.x[i + 1];
            if x < self.x[i] {
                return sign * x; // inside the rectangle: accept immediately
            }
            if i == ZIG_LAYERS - 1 {
                // Tail: Marsaglia's exact tail method.
                loop {
                    let e = -u01_open(rng).ln() / ZIG_R;
                    let f = -u01_open(rng).ln();
                    if 2.0 * f > e * e {
                        return sign * (ZIG_R + e);
                    }
                }
            }
            // Wedge: accept with probability proportional to the pdf gap.
            let fy = self.y[i + 1] + u01_open(rng) * (self.y[i] - self.y[i + 1]);
            if fy < pdf(x) {
                return sign * x;
            }
        }
    }
}

impl Default for Ziggurat {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xorgens;

    fn moments(samples: &[f64]) -> (f64, f64, f64, f64) {
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        let skew = samples.iter().map(|x| (x - mean).powi(3)).sum::<f64>() / n / var.powf(1.5);
        let kurt = samples.iter().map(|x| (x - mean).powi(4)).sum::<f64>() / n / var.powi(2);
        (mean, var, skew, kurt)
    }

    #[test]
    fn u01_in_open_interval() {
        let mut g = Xorgens::new(1);
        for _ in 0..10000 {
            let u = u01_open(&mut g);
            assert!(u > 0.0 && u < 1.0);
        }
    }

    #[test]
    fn unit_f32_map_pinned() {
        assert_eq!(unit_f32(0), 0.0);
        assert_eq!(unit_f32(u32::MAX), (16_777_215) as f32 / 16_777_216.0);
        assert!(unit_f32(u32::MAX) < 1.0, "never 1.0");
        // Bit-identical with the Prng32 convenience accessor.
        let mut a = Xorgens::new(11);
        let mut b = Xorgens::new(11);
        for _ in 0..1000 {
            assert_eq!(a.next_f32(), unit_f32(b.next_u32()));
        }
    }

    #[test]
    fn unit_f32_slice_matches_elementwise_map() {
        // Odd lengths exercise every vector-remainder split; the values
        // include the extremes and sign-bit patterns.
        for n in [0usize, 1, 3, 4, 7, 8, 9, 31, 257, 4096] {
            let mut g = Xorgens::new(n as u64 + 1);
            let mut src: Vec<u32> = (0..n).map(|_| g.next_u32()).collect();
            if n >= 2 {
                src[0] = 0;
                src[1] = u32::MAX;
            }
            let mut dst = vec![0f32; n];
            unit_f32_slice(&src, &mut dst);
            for (i, (&u, &f)) in src.iter().zip(dst.iter()).enumerate() {
                assert_eq!(f.to_bits(), unit_f32(u).to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn unit_f32_slice_rejects_mismatched_lengths() {
        let mut dst = vec![0f32; 3];
        unit_f32_slice(&[1, 2], &mut dst);
    }

    #[test]
    fn shared_ziggurat_is_one_instance_with_unchanged_tables() {
        let a = Ziggurat::shared();
        let b = Ziggurat::shared();
        assert!(std::ptr::eq(a, b), "shared() must return one process-wide table");
        // And the shared tables sample the identical stream to a fresh build.
        let fresh = Ziggurat::new();
        let mut g1 = Xorgens::new(31);
        let mut g2 = Xorgens::new(31);
        for _ in 0..1000 {
            assert_eq!(a.sample(&mut g1), fresh.sample(&mut g2));
        }
    }

    #[test]
    fn box_muller_moments() {
        let mut g = Xorgens::new(2);
        let mut bm = NormalBoxMuller::new();
        let samples: Vec<f64> = (0..200_000).map(|_| bm.sample(&mut g)).collect();
        let (mean, var, skew, kurt) = moments(&samples);
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!(skew.abs() < 0.03, "skew {skew}");
        assert!((kurt - 3.0).abs() < 0.1, "kurt {kurt}");
    }

    #[test]
    fn ziggurat_moments_match_normal() {
        let zig = Ziggurat::new();
        let mut g = Xorgens::new(3);
        let samples: Vec<f64> = (0..200_000).map(|_| zig.sample(&mut g)).collect();
        let (mean, var, skew, kurt) = moments(&samples);
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!(skew.abs() < 0.03, "skew {skew}");
        assert!((kurt - 3.0).abs() < 0.1, "kurt {kurt}");
    }

    #[test]
    fn ziggurat_vs_box_muller_ks() {
        // Two-sample Kolmogorov–Smirnov between ziggurat and Box–Muller.
        let zig = Ziggurat::new();
        let mut g = Xorgens::new(4);
        let n = 50_000;
        let mut a: Vec<f64> = (0..n).map(|_| zig.sample(&mut g)).collect();
        let mut bm = NormalBoxMuller::new();
        let mut b: Vec<f64> = (0..n).map(|_| bm.sample(&mut g)).collect();
        a.sort_by(|p, q| p.partial_cmp(q).unwrap());
        b.sort_by(|p, q| p.partial_cmp(q).unwrap());
        let (mut i, mut j, mut d) = (0usize, 0usize, 0.0f64);
        while i < n && j < n {
            if a[i] <= b[j] {
                i += 1;
            } else {
                j += 1;
            }
            d = d.max((i as f64 / n as f64 - j as f64 / n as f64).abs());
        }
        // critical value ~1.63 * sqrt(2/n) at alpha = 0.01
        let crit = 1.63 * (2.0 / n as f64).sqrt();
        assert!(d < crit, "KS d={d} crit={crit}");
    }

    #[test]
    fn exponential_mean() {
        let mut g = Xorgens::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| exponential(&mut g)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn ziggurat_tail_reachable() {
        let zig = Ziggurat::new();
        let mut g = Xorgens::new(6);
        let found_tail = (0..2_000_000).any(|_| zig.sample(&mut g).abs() > ZIG_R);
        assert!(found_tail, "no tail samples beyond r={ZIG_R}");
    }
}

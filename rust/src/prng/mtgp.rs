//! MTGP-style block-parallel Mersenne Twister (paper §1.3).
//!
//! The paper explains MTGP's parallelisation: with the recurrence
//! `x_k = h(x_{k-N}, x_{k-N+1}, x_{k-N+M})`, exactly `N − M` new elements
//! can be computed in parallel before a freshly-computed value would be
//! needed. Each CUDA block runs its own generator over a shared-memory
//! state array.
//!
//! **Substitution (DESIGN.md):** the real MTGP draws a *distinct parameter
//! set per block* from Saito's MTGPDC tables (mexp 11213: N = 351 words,
//! padded to a 1024-word shared buffer — Table 1's footprint). Those tables
//! are not derivable offline, so each of our blocks runs the canonical
//! MT19937 parameter set (N = 624, M = 397, parallel degree N − M = 227)
//! with per-block decorrelated seeding. Identical algebraic class
//! (GF(2)-linear, fails the same linearity tests), same block-parallel
//! harness.

use super::init::SeedSequence;
use super::mt19937::{Mt19937, M, N};
use super::traits::BlockParallel;

/// Intra-block parallel degree: `N − M` (paper §1.3).
pub const LANE: usize = N - M; // 227

/// Block-parallel MTGP-style generator.
pub struct Mtgp {
    /// Per-block rolled state: `q[m] = x_{k-N+m}` (oldest first).
    q: Vec<u32>,
    blocks: usize,
}

impl Mtgp {
    pub const DEFAULT_BLOCKS: usize = 64;

    pub fn new(seed: u64, blocks: usize) -> Self {
        assert!(blocks >= 1);
        let root = SeedSequence::new(seed);
        let mut q = vec![0u32; blocks * N];
        for b in 0..blocks {
            // Per-block 32-bit seed through the reference init_genrand,
            // mirroring MTGP's per-block initialisation-by-block-id.
            let mut seq = root.child(b as u64);
            let mt = Mt19937::new(seq.next_u32());
            q[b * N..(b + 1) * N].copy_from_slice(mt.state());
        }
        Mtgp { q, blocks }
    }

    /// Construct directly from a state dump (`blocks * N` rolled words) —
    /// no seeding through MT19937's init: the placed-stream cold-start
    /// path for exact-jump backends.
    pub fn from_state(blocks: usize, words: &[u32]) -> Self {
        assert!(blocks >= 1);
        let mut g = Mtgp { q: vec![0u32; blocks * N], blocks };
        g.load_state(words);
        g
    }

    /// Advance one block one round (LANE new elements), rolled layout.
    ///
    /// Perf (EXPERIMENTS.md §Perf L3-3): lane j reads q[j], q[j+1], q[j+M]
    /// at static offsets from three disjoint-enough windows; new values go
    /// to a stack buffer (no in-place aliasing), the twist is branchless
    /// (`(y & 1).wrapping_neg() & MATRIX_A`), and the roll is a single
    /// `copy_within` — the loop auto-vectorizes.
    #[inline]
    fn round_block(q: &mut [u32], out: &mut [u32]) {
        // Lane j computes x_{k+j} from q[j] (= x_{k+j-N}), q[j+1], q[j+M];
        // j < N − M keeps every index below N: reads touch only pre-round
        // values, so the loop is bit-exact with simultaneous evaluation.
        let mut new = [0u32; LANE];
        for j in 0..LANE {
            let y = (q[j] & 0x8000_0000) | (q[j + 1] & 0x7fff_ffff);
            new[j] = q[j + M] ^ (y >> 1) ^ ((y & 1).wrapping_neg() & 0x9908_b0df);
        }
        for (o, &x) in out.iter_mut().zip(new.iter()) {
            *o = Mt19937::temper(x);
        }
        // Roll: new state is [q[LANE..N], new].
        q.copy_within(LANE.., 0);
        q[N - LANE..].copy_from_slice(&new);
    }

    /// `round_block` through the selected SIMD kernel ([`crate::simd`]):
    /// lane `j < N − M` reads only pre-round values, so packing adjacent
    /// twist/temper lanes per instruction is bit-identical to the scalar
    /// loop above (which `Scalar` runs verbatim).
    #[inline]
    fn round_block_k(k: crate::simd::SimdKernel, q: &mut [u32], out: &mut [u32]) {
        if k == crate::simd::SimdKernel::Scalar {
            Self::round_block(q, out);
        } else {
            crate::simd::kernels::mtgp_round(k, q, out);
        }
    }
}

/// One worker's share of a split [`Mtgp`]: exclusive views of a
/// contiguous block range's rolled state windows. Blocks are fully
/// independent, so any sub-range splits cleanly.
struct MtPart<'a> {
    rounds: usize,
    /// Absolute index of the first owned block.
    lo: usize,
    /// Owned state, `(hi - lo) * N` words.
    q: &'a mut [u32],
}

impl crate::exec::RangeFill for MtPart<'_> {
    fn fill_rounds(&mut self, out: &crate::exec::StridedOut) {
        // One kernel resolution per part run (SIMD × threads compose).
        let k = crate::simd::fill_kernel();
        for i in 0..self.q.len() / N {
            let q = &mut self.q[i * N..(i + 1) * N];
            for t in 0..self.rounds {
                // SAFETY: this part exclusively owns block `lo + i`.
                Mtgp::round_block_k(k, q, unsafe { out.block_slice(t, self.lo + i) });
            }
        }
    }
}

impl BlockParallel for Mtgp {
    fn blocks(&self) -> usize {
        self.blocks
    }

    fn lane_width(&self) -> usize {
        LANE
    }

    fn split_fill<'a>(
        &'a mut self,
        rounds: usize,
        bounds: &[usize],
    ) -> Option<Vec<Box<dyn crate::exec::RangeFill + 'a>>> {
        debug_assert!(bounds.len() >= 2 && bounds.windows(2).all(|w| w[0] < w[1]));
        assert!(*bounds.last().unwrap() <= self.blocks, "split bounds exceed block count");
        let mut parts: Vec<Box<dyn crate::exec::RangeFill + 'a>> =
            Vec::with_capacity(bounds.len() - 1);
        let mut q_rest = &mut self.q[bounds[0] * N..];
        for pair in bounds.windows(2) {
            let (lo, hi) = (pair[0], pair[1]);
            let (q, q_next) = std::mem::take(&mut q_rest).split_at_mut((hi - lo) * N);
            q_rest = q_next;
            parts.push(Box::new(MtPart { rounds, lo, q }));
        }
        Some(parts)
    }

    fn fill_round(&mut self, out: &mut [u32]) {
        assert_eq!(out.len(), self.blocks * LANE, "fill_round needs round_len() words");
        let k = crate::simd::fill_kernel();
        for b in 0..self.blocks {
            Self::round_block_k(
                k,
                &mut self.q[b * N..(b + 1) * N],
                &mut out[b * LANE..(b + 1) * LANE],
            );
        }
    }

    fn dump_state(&self) -> Vec<u32> {
        self.q.clone()
    }

    fn load_state(&mut self, words: &[u32]) {
        assert_eq!(words.len(), self.blocks * N, "state size mismatch");
        self.q.copy_from_slice(words);
    }

    fn name(&self) -> &'static str {
        "mtgp"
    }

    fn state_words_per_block(&self) -> usize {
        N
    }

    fn period_log2(&self) -> f64 {
        19937.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Prng32;

    /// Wait — lane j writes q[j] *before* lane j' > j reads q[j'+1]; does
    /// any lane read a slot an earlier lane wrote? Lane j writes slot j;
    /// later lane j' reads slots j', j'+1, j'+M — all > j' − 1 ≥ j. So no:
    /// verified here against a pure read-only evaluation.
    #[test]
    fn in_place_round_matches_two_phase() {
        let mt = Mt19937::new(123);
        let mut q1: Vec<u32> = mt.state().to_vec();
        let mut q2 = q1.clone();
        // Two-phase: compute all lanes from a frozen copy, then roll.
        let frozen = q2.clone();
        let mut out2 = vec![0u32; LANE];
        for j in 0..LANE {
            let x = Mt19937::twist(frozen[j], frozen[j + 1], frozen[j + M]);
            out2[j] = Mt19937::temper(x);
            q2[j] = x;
        }
        q2.rotate_left(LANE);
        let mut out1 = vec![0u32; LANE];
        Mtgp::round_block(&mut q1, &mut out1);
        assert_eq!(out1, out2);
        assert_eq!(q1, q2);
    }

    /// Single-block MTGP produces exactly the serial MT19937 stream.
    #[test]
    fn one_block_equals_serial_mt() {
        let seed32 = {
            let mut s = SeedSequence::new(77).child(0);
            s.next_u32()
        };
        let mut serial = Mt19937::new(seed32);
        let mut block = Mtgp::new(77, 1);
        let mut out = vec![0u32; block.round_len()];
        for _ in 0..10 {
            block.fill_round(&mut out);
            for (j, &o) in out.iter().enumerate() {
                assert_eq!(o, serial.next_u32(), "lane {j}");
            }
        }
    }

    #[test]
    fn lane_width_is_n_minus_m() {
        let g = Mtgp::new(1, 2);
        assert_eq!(g.lane_width(), 227);
        assert_eq!(g.state_words_per_block(), 624);
    }

    #[test]
    fn dump_load_roundtrip() {
        let mut a = Mtgp::new(3, 2);
        let mut sink = vec![0u32; a.round_len()];
        a.fill_round(&mut sink);
        let st = a.dump_state();
        let mut b = Mtgp::new(999, 2);
        b.load_state(&st);
        let mut oa = vec![0u32; a.round_len()];
        let mut ob = vec![0u32; a.round_len()];
        a.fill_round(&mut oa);
        b.fill_round(&mut ob);
        assert_eq!(oa, ob);
    }

    use super::super::init::SeedSequence;
}

//! The generator library: the paper's xorgensGP plus every comparator.
//!
//! | Generator | Paper role | State (32-bit words) | Period |
//! |---|---|---|---|
//! | [`Xorgens`] | Brent's serial xorgens (basis of the contribution) | r + 1 (+index) | (2^(32r) − 1)·2^32 |
//! | [`XorgensGp`] | **the paper's contribution** — block-parallel xorgens | 129/block | (2^4096 − 1)·2^32 |
//! | [`Mt19937`] | serial Mersenne Twister (basis of MTGP comparator) | 624 (+index) | 2^19937 − 1 |
//! | [`Mtgp`] | MTGP-style block-parallel Mersenne Twister | 624/block | 2^19937 − 1 |
//! | [`Xorwow`] | CURAND's default generator | 6 | (2^160 − 1)·2^32 |
//!
//! Substitution note (see DESIGN.md §Hardware-Adaptation): the paper's MTGP
//! uses parameter sets emitted by Saito's MTGPDC tool, which are not
//! reproducible offline; our [`Mtgp`] places the canonical MT19937
//! parameter set inside the same `N−M`-parallel block harness the paper
//! describes in §1.3. The algebraic structure (GF(2)-linear LFSR; fails
//! linear-complexity tests; `N−M` elements computable in parallel) is
//! identical.

pub mod distributions;
pub mod init;
pub mod mt19937;
pub mod mtgp;
pub mod params;
pub mod place;
pub mod traits;
pub mod weyl;
pub mod xorgens;
pub mod xorgens64;
pub mod xorgens_gp;
pub mod xorwow;

pub use mt19937::Mt19937;
pub use mtgp::Mtgp;
pub use params::XorgensParams;
pub use place::{LeapfrogBlock, PlacedMaster, Placement};
pub use traits::{BlockParallel, GeneratorKind, Prng32};
pub use weyl::Weyl;
pub use xorgens::Xorgens;
pub use xorgens64::Xorgens64;
pub use xorgens_gp::XorgensGp;
pub use xorwow::Xorwow;

/// Construct a boxed generator by kind with the given seed (single stream).
///
/// Block-parallel kinds are wrapped in [`traits::InterleavedStream`]: the
/// resulting stream is the interleaved multi-block output — exactly what
/// the paper feeds to TestU01.
pub fn make_generator(kind: GeneratorKind, seed: u64) -> Box<dyn Prng32 + Send> {
    use traits::InterleavedStream;
    match kind {
        GeneratorKind::Xorgens => Box::new(Xorgens::new(seed)),
        GeneratorKind::XorgensGp => {
            Box::new(InterleavedStream::new(XorgensGp::new(seed, XorgensGp::DEFAULT_BLOCKS)))
        }
        GeneratorKind::Mt19937 => Box::new(Mt19937::new(seed as u32)),
        GeneratorKind::Mtgp => {
            Box::new(InterleavedStream::new(Mtgp::new(seed, Mtgp::DEFAULT_BLOCKS)))
        }
        GeneratorKind::Xorwow => Box::new(Xorwow::new(seed)),
    }
}

/// Construct the block-parallel generator the paper benchmarks for `kind`,
/// with an explicit block count (XORWOW runs one independent lane per
/// "block", matching CURAND's one-state-per-thread model).
pub fn make_block_generator(
    kind: GeneratorKind,
    seed: u64,
    blocks: usize,
) -> Box<dyn BlockParallel + Send> {
    match kind {
        GeneratorKind::XorgensGp | GeneratorKind::Xorgens => Box::new(XorgensGp::new(seed, blocks)),
        GeneratorKind::Mtgp | GeneratorKind::Mt19937 => Box::new(Mtgp::new(seed, blocks)),
        GeneratorKind::Xorwow => Box::new(xorwow::XorwowBlock::new(seed, blocks)),
    }
}

/// Construct the block-parallel generator for `kind` directly from a
/// `dump_state` dump — the placed-stream cold start: no seeding, no
/// warm-up, no throwaway state that `load_state` would overwrite.
/// Bit-identical to `make_block_generator(kind, any_seed, blocks)` +
/// `load_state(state)`.
pub fn make_block_generator_from_state(
    kind: GeneratorKind,
    blocks: usize,
    state: &[u32],
) -> Box<dyn BlockParallel + Send> {
    match kind {
        GeneratorKind::XorgensGp | GeneratorKind::Xorgens => {
            Box::new(XorgensGp::from_state(blocks, state))
        }
        GeneratorKind::Mtgp | GeneratorKind::Mt19937 => Box::new(Mtgp::from_state(blocks, state)),
        GeneratorKind::Xorwow => Box::new(xorwow::XorwowBlock::from_state(blocks, state)),
    }
}

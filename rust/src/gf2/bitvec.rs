//! Dense bit vectors over GF(2), packed into `u64` words (LSB-first).

/// A fixed-length bit vector packed into `u64` words.
///
/// Bit `i` lives in word `i / 64`, position `i % 64`. Addition over GF(2) is
/// XOR ([`BitVec::xor_assign`]).
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitVec {
    len: usize,
    words: Vec<u64>,
}

impl BitVec {
    /// All-zero vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitVec { len, words: vec![0; len.div_ceil(64)] }
    }

    /// Unit vector: a single 1 at position `i`.
    pub fn unit(len: usize, i: usize) -> Self {
        let mut v = Self::zeros(len);
        v.set(i, true);
        v
    }

    /// Build from a little-endian bit iterator (bit 0 first).
    pub fn from_bits<I: IntoIterator<Item = bool>>(bits: I) -> Self {
        let bits: Vec<bool> = bits.into_iter().collect();
        let mut v = Self::zeros(bits.len());
        for (i, b) in bits.iter().enumerate() {
            v.set(i, *b);
        }
        v
    }

    /// Pack a `u32` slice into a bit vector (word 0 bit 0 first).
    pub fn from_u32s(xs: &[u32]) -> Self {
        let mut v = Self::zeros(xs.len() * 32);
        for (i, &x) in xs.iter().enumerate() {
            for j in 0..32 {
                if (x >> j) & 1 == 1 {
                    v.set(i * 32 + j, true);
                }
            }
        }
        v
    }

    /// Unpack into `u32` words (inverse of [`BitVec::from_u32s`]).
    pub fn to_u32s(&self) -> Vec<u32> {
        assert_eq!(self.len % 32, 0, "bit length must be a multiple of 32");
        let mut out = vec![0u32; self.len / 32];
        for (i, w) in out.iter_mut().enumerate() {
            for j in 0..32 {
                if self.get(i * 32 + j) {
                    *w |= 1 << j;
                }
            }
        }
        out
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if the vector has no bits.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Write bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize, b: bool) {
        debug_assert!(i < self.len);
        let mask = 1u64 << (i % 64);
        if b {
            self.words[i / 64] |= mask;
        } else {
            self.words[i / 64] &= !mask;
        }
    }

    /// `self ^= other` (GF(2) addition).
    #[inline]
    pub fn xor_assign(&mut self, other: &BitVec) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a ^= b;
        }
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True if every bit is zero.
    pub fn is_zero(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Index of the lowest set bit, or `None` if zero.
    pub fn lowest_set(&self) -> Option<usize> {
        for (wi, &w) in self.words.iter().enumerate() {
            if w != 0 {
                return Some(wi * 64 + w.trailing_zeros() as usize);
            }
        }
        None
    }

    /// Inner product over GF(2): parity of `self & other`.
    pub fn dot(&self, other: &BitVec) -> bool {
        debug_assert_eq!(self.len, other.len);
        let mut acc = 0u64;
        for (a, b) in self.words.iter().zip(&other.words) {
            acc ^= a & b;
        }
        acc.count_ones() % 2 == 1
    }

    /// Raw word access (LSB-first packing) — used by the rank hot loop.
    pub(crate) fn words(&self) -> &[u64] {
        &self.words
    }

    pub(crate) fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }
}

impl std::fmt::Debug for BitVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BitVec[{}; ", self.len)?;
        for i in 0..self.len.min(64) {
            write!(f, "{}", self.get(i) as u8)?;
        }
        if self.len > 64 {
            write!(f, "…")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVec::zeros(130);
        for i in [0, 1, 63, 64, 65, 128, 129] {
            assert!(!v.get(i));
            v.set(i, true);
            assert!(v.get(i));
        }
        assert_eq!(v.count_ones(), 7);
        v.set(64, false);
        assert!(!v.get(64));
        assert_eq!(v.count_ones(), 6);
    }

    #[test]
    fn u32_pack_roundtrip() {
        let xs = [0xdeadbeefu32, 0x01234567, 0, u32::MAX];
        let v = BitVec::from_u32s(&xs);
        assert_eq!(v.len(), 128);
        assert_eq!(v.to_u32s(), xs);
    }

    #[test]
    fn xor_and_dot() {
        let a = BitVec::from_u32s(&[0b1010]);
        let b = BitVec::from_u32s(&[0b0110]);
        let mut c = a.clone();
        c.xor_assign(&b);
        assert_eq!(c.to_u32s(), vec![0b1100]);
        // dot(1010, 0110) = parity(0010) = 1
        assert!(a.dot(&b));
    }

    #[test]
    fn lowest_set_across_words() {
        let mut v = BitVec::zeros(200);
        assert_eq!(v.lowest_set(), None);
        v.set(130, true);
        v.set(199, true);
        assert_eq!(v.lowest_set(), Some(130));
    }

    #[test]
    fn unit_vectors() {
        let v = BitVec::unit(96, 70);
        assert_eq!(v.count_ones(), 1);
        assert!(v.get(70));
    }
}

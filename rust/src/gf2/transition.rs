//! Transition matrices for linear (xorshift-class) generators, and
//! jump-ahead by matrix powers.
//!
//! Any generator whose step is linear over GF(2) — the LFSR part of
//! xorgens, XORWOW and the Mersenne Twister — is `state' = M · state` for a
//! fixed bit matrix `M`. Jumping `k` steps is multiplication by `M^k`,
//! computable in O(log k) matrix products. The coordinator uses this to hand
//! out *provably* disjoint subsequences of one master sequence for
//! small-state generators (XORWOW: 160-bit LFSR), and block-id seeding for
//! the large ones (xorgens r=128: 4096-bit state, where a matrix power is
//! done once and cached, or Brent-style decorrelating initialisation is
//! used instead).

use super::bitmat::BitMatrix;
use super::bitvec::BitVec;

/// A linear step function on an `n_bits`-wide state, expressed on u32 words.
///
/// Implementors expose their raw linear state as `u32` words; the harness
/// probes the step with unit vectors to *derive* the transition matrix —
/// no hand-derivation of M, so the matrix always matches the code.
pub trait LinearStep {
    /// State width in bits (a multiple of 32).
    fn n_bits(&self) -> usize;
    /// Apply one step to a packed state (little-endian u32 words).
    fn step_words(&self, state: &mut [u32]);
}

/// Derive the transition matrix of `g` by probing with unit vectors.
///
/// Column `j` of `M` is `step(e_j)`. Cost: `n` step evaluations — cheap for
/// XORWOW (192 probes) and tolerable one-off for xorgens r=128 (4096 probes
/// of a 128-word state).
pub fn transition_matrix<G: LinearStep + ?Sized>(g: &G) -> BitMatrix {
    let n = g.n_bits();
    assert_eq!(n % 32, 0);
    let words = n / 32;
    // Build columns, then transpose into rows.
    let mut cols: Vec<BitVec> = Vec::with_capacity(n);
    for j in 0..n {
        let mut state = vec![0u32; words];
        state[j / 32] = 1 << (j % 32);
        g.step_words(&mut state);
        cols.push(BitVec::from_u32s(&state));
    }
    BitMatrix::from_fn(n, n, |i, j| cols[j].get(i))
}

/// `M^k` for jump-ahead by `k` steps.
pub fn transition_power(m: &BitMatrix, k: u128) -> BitMatrix {
    m.pow(k)
}

/// Apply a jump matrix to a packed u32 state.
pub fn jump_state(m: &BitMatrix, state: &[u32]) -> Vec<u32> {
    let v = BitVec::from_u32s(state);
    m.mul_vec(&v).to_u32s()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy 64-bit xorshift for testing the probe/jump machinery.
    struct Toy;

    impl Toy {
        fn step(x: u64) -> u64 {
            let mut x = x;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        }
    }

    impl LinearStep for Toy {
        fn n_bits(&self) -> usize {
            64
        }
        fn step_words(&self, state: &mut [u32]) {
            let x = (state[0] as u64) | ((state[1] as u64) << 32);
            let y = Toy::step(x);
            state[0] = y as u32;
            state[1] = (y >> 32) as u32;
        }
    }

    #[test]
    fn matrix_matches_step() {
        let m = transition_matrix(&Toy);
        for x0 in [1u64, 0xdeadbeefcafebabe, 0x123456789abcdef0] {
            let state = [x0 as u32, (x0 >> 32) as u32];
            let direct = Toy::step(x0);
            let via_m = jump_state(&m, &state);
            assert_eq!(via_m, vec![direct as u32, (direct >> 32) as u32]);
        }
    }

    #[test]
    fn jump_equals_iterated_step() {
        let m = transition_matrix(&Toy);
        let k = 1000u128;
        let mk = transition_power(&m, k);
        let x0 = 0x9e3779b97f4a7c15u64;
        let mut x = x0;
        for _ in 0..k {
            x = Toy::step(x);
        }
        let jumped = jump_state(&mk, &[x0 as u32, (x0 >> 32) as u32]);
        assert_eq!(jumped, vec![x as u32, (x >> 32) as u32]);
    }

    #[test]
    fn transition_matrix_invertible() {
        // xorshift steps are invertible -> full rank.
        let m = transition_matrix(&Toy);
        assert_eq!(m.rank(), 64);
    }

    #[test]
    fn jump_zero_is_identity() {
        let m = transition_matrix(&Toy);
        assert!(transition_power(&m, 0).is_identity());
    }
}

//! Polynomials over GF(2), used for period verification of small xorshift
//! parameter sets (the characteristic polynomial of the transition matrix
//! must be primitive for the generator to reach its maximal period 2^n - 1).

/// A polynomial over GF(2), LSB-first packed in `u64` words
/// (bit `i` of the packing = coefficient of `x^i`).
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct GfPoly {
    words: Vec<u64>,
}

impl GfPoly {
    /// The zero polynomial.
    pub fn zero() -> Self {
        GfPoly { words: vec![] }
    }

    /// The constant 1.
    pub fn one() -> Self {
        GfPoly { words: vec![1] }
    }

    /// `x^k`.
    pub fn x_pow(k: usize) -> Self {
        let mut words = vec![0u64; k / 64 + 1];
        words[k / 64] = 1 << (k % 64);
        GfPoly { words }
    }

    /// From explicit coefficient bits (index = exponent).
    pub fn from_coeffs(bits: &[bool]) -> Self {
        let mut words = vec![0u64; bits.len() / 64 + 1];
        for (i, &b) in bits.iter().enumerate() {
            if b {
                words[i / 64] |= 1 << (i % 64);
            }
        }
        let mut p = GfPoly { words };
        p.normalize();
        p
    }

    /// The normalized LSB-first `u64` packing (no trailing zero words) —
    /// the serialization surface for the jump-polynomial cache.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from a `words()` packing (trailing zero words tolerated).
    pub fn from_words(words: Vec<u64>) -> Self {
        let mut p = GfPoly { words };
        p.normalize();
        p
    }

    fn normalize(&mut self) {
        while self.words.last() == Some(&0) {
            self.words.pop();
        }
    }

    /// Degree; `None` for the zero polynomial.
    pub fn degree(&self) -> Option<usize> {
        let last = self.words.last()?;
        Some((self.words.len() - 1) * 64 + 63 - last.leading_zeros() as usize)
    }

    /// Coefficient of `x^i`.
    pub fn coeff(&self, i: usize) -> bool {
        self.words.get(i / 64).map_or(false, |w| (w >> (i % 64)) & 1 == 1)
    }

    pub fn is_zero(&self) -> bool {
        self.words.is_empty()
    }

    /// Addition over GF(2) (= XOR).
    pub fn add(&self, other: &GfPoly) -> GfPoly {
        let n = self.words.len().max(other.words.len());
        let mut words = vec![0u64; n];
        for (i, w) in words.iter_mut().enumerate() {
            *w = self.words.get(i).copied().unwrap_or(0) ^ other.words.get(i).copied().unwrap_or(0);
        }
        let mut p = GfPoly { words };
        p.normalize();
        p
    }

    /// Schoolbook multiplication (fine for the small degrees we validate).
    pub fn mul(&self, other: &GfPoly) -> GfPoly {
        if self.is_zero() || other.is_zero() {
            return GfPoly::zero();
        }
        let (da, db) = (self.degree().unwrap(), other.degree().unwrap());
        let mut words = vec![0u64; (da + db) / 64 + 1];
        for i in 0..=da {
            if self.coeff(i) {
                // words ^= other << i
                let (ws, bs) = (i / 64, i % 64);
                for (j, &w) in other.words.iter().enumerate() {
                    words[ws + j] ^= w << bs;
                    if bs > 0 && ws + j + 1 < words.len() {
                        words[ws + j + 1] ^= w >> (64 - bs);
                    }
                }
            }
        }
        let mut p = GfPoly { words };
        p.normalize();
        p
    }

    /// Remainder `self mod m`.
    pub fn rem(&self, m: &GfPoly) -> GfPoly {
        self.divmod(m).1
    }

    /// Euclidean division: `(quotient, remainder)` with
    /// `self = q·m + r` and `deg r < deg m`.
    pub fn divmod(&self, m: &GfPoly) -> (GfPoly, GfPoly) {
        let dm = m.degree().expect("modulus must be nonzero");
        let mut r = self.clone();
        let dq = self.degree().map_or(0, |d| d.saturating_sub(dm));
        let mut q = GfPoly { words: vec![0u64; dq / 64 + 1] };
        while let Some(dr) = r.degree() {
            if dr < dm {
                break;
            }
            // r ^= m << (dr - dm); q |= x^(dr - dm)
            let shift = dr - dm;
            q.words[shift / 64] |= 1 << (shift % 64);
            let (ws, bs) = (shift / 64, shift % 64);
            for (j, &w) in m.words.iter().enumerate() {
                r.words[ws + j] ^= w << bs;
                if bs > 0 && ws + j + 1 < r.words.len() {
                    r.words[ws + j + 1] ^= w >> (64 - bs);
                }
            }
            r.normalize();
        }
        q.normalize();
        (q, r)
    }

    /// `x^e mod m` by square-and-reduce (e may be astronomically large,
    /// passed as (base-2 exponent bits, most significant first)).
    pub fn x_pow_mod(e_bits_msb_first: &[bool], m: &GfPoly) -> GfPoly {
        GfPoly::x_pow(1).pow_mod(e_bits_msb_first, m)
    }

    /// `self^e mod m` by square-and-multiply (exponent as base-2 bits, most
    /// significant first). This is what makes stream placement O(log i) per
    /// stream: the per-spacing base `x^(2^spacing) mod p` is memoized once
    /// and raised to the stream index here.
    pub fn pow_mod(&self, e_bits_msb_first: &[bool], m: &GfPoly) -> GfPoly {
        let base = self.rem(m);
        let mut acc = GfPoly::one().rem(m);
        for &bit in e_bits_msb_first {
            acc = acc.mul(&acc).rem(m);
            if bit {
                acc = acc.mul(&base).rem(m);
            }
        }
        acc
    }

    /// GCD of two polynomials.
    pub fn gcd(a: &GfPoly, b: &GfPoly) -> GfPoly {
        let (mut a, mut b) = (a.clone(), b.clone());
        while !b.is_zero() {
            let r = a.rem(&b);
            a = b;
            b = r;
        }
        a
    }

    /// LCM of two polynomials (`a·b / gcd(a, b)`; zero if either is zero).
    pub fn lcm(a: &GfPoly, b: &GfPoly) -> GfPoly {
        if a.is_zero() || b.is_zero() {
            return GfPoly::zero();
        }
        a.mul(b).divmod(&GfPoly::gcd(a, b)).0
    }

    /// Irreducibility test (Rabin): `p` of degree `n` is irreducible iff
    /// `x^(2^n) = x (mod p)` and `gcd(x^(2^(n/q)) - x, p) = 1` for every
    /// prime divisor `q` of `n`.
    pub fn is_irreducible(&self) -> bool {
        let n = match self.degree() {
            Some(0) | None => return false,
            Some(n) => n,
        };
        if !self.coeff(0) {
            return false; // divisible by x
        }
        // x^(2^n) mod p == x ?
        let mut t = GfPoly::x_pow(1).rem(self);
        for _ in 0..n {
            t = t.mul(&t).rem(self);
        }
        if t != GfPoly::x_pow(1).rem(self) {
            return false;
        }
        for q in prime_divisors(n) {
            let k = n / q;
            let mut t = GfPoly::x_pow(1).rem(self);
            for _ in 0..k {
                t = t.mul(&t).rem(self);
            }
            let diff = t.add(&GfPoly::x_pow(1).rem(self));
            if GfPoly::gcd(&diff, self).degree() != Some(0) {
                return false;
            }
        }
        true
    }

    /// Primitivity test for an irreducible polynomial of degree `n`:
    /// the order of `x` mod p must be exactly `2^n - 1`, i.e.
    /// `x^((2^n-1)/q) != 1` for every prime factor `q` of `2^n - 1`.
    ///
    /// Requires factoring `2^n - 1`; practical for `n <= 64` via trial
    /// division + Pollard rho (see [`factor_u128`]).
    pub fn is_primitive(&self) -> bool {
        let n = match self.degree() {
            Some(0) | None => return false,
            Some(n) => n,
        };
        if n > 64 {
            panic!("primitivity check limited to degree <= 64 (need to factor 2^n - 1)");
        }
        if !self.is_irreducible() {
            return false;
        }
        let order: u128 = (1u128 << n) - 1;
        for q in factor_u128(order) {
            let e = order / q;
            let bits = u128_bits_msb(e);
            if GfPoly::x_pow_mod(&bits, self) == GfPoly::one() {
                return false;
            }
        }
        true
    }
}

/// Most-significant-first bit expansion of a u128.
pub fn u128_bits_msb(e: u128) -> Vec<bool> {
    if e == 0 {
        return vec![false];
    }
    let top = 127 - e.leading_zeros() as usize;
    (0..=top).rev().map(|i| (e >> i) & 1 == 1).collect()
}

/// Distinct prime divisors of a small integer.
fn prime_divisors(mut n: usize) -> Vec<usize> {
    let mut out = vec![];
    let mut d = 2;
    while d * d <= n {
        if n % d == 0 {
            out.push(d);
            while n % d == 0 {
                n /= d;
            }
        }
        d += 1;
    }
    if n > 1 {
        out.push(n);
    }
    out
}

/// Distinct prime factors of a u128 via trial division then Pollard rho.
pub fn factor_u128(mut n: u128) -> Vec<u128> {
    let mut out = vec![];
    for d in [2u128, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67, 71, 73] {
        if n % d == 0 {
            out.push(d);
            while n % d == 0 {
                n /= d;
            }
        }
    }
    let mut stack = vec![n];
    while let Some(m) = stack.pop() {
        if m == 1 {
            continue;
        }
        if is_prime_u128(m) {
            if !out.contains(&m) {
                out.push(m);
            }
            continue;
        }
        let d = pollard_rho(m);
        stack.push(d);
        stack.push(m / d);
    }
    out.sort_unstable();
    out
}

fn mul_mod(a: u128, b: u128, m: u128) -> u128 {
    // Schoolbook double-and-add to avoid overflow (m < 2^127).
    let mut result = 0u128;
    let mut a = a % m;
    let mut b = b;
    while b > 0 {
        if b & 1 == 1 {
            result = (result + a) % m;
        }
        a = (a << 1) % m;
        b >>= 1;
    }
    result
}

fn pow_mod(mut a: u128, mut e: u128, m: u128) -> u128 {
    let mut r = 1u128;
    a %= m;
    while e > 0 {
        if e & 1 == 1 {
            r = mul_mod(r, a, m);
        }
        a = mul_mod(a, a, m);
        e >>= 1;
    }
    r
}

/// Deterministic Miller-Rabin for u128 (witness set good far beyond 2^64;
/// for the 2^n - 1, n <= 64 values we factor it is ample).
fn is_prime_u128(n: u128) -> bool {
    if n < 2 {
        return false;
    }
    for p in [2u128, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n % p == 0 {
            return n == p;
        }
    }
    let mut d = n - 1;
    let mut s = 0;
    while d % 2 == 0 {
        d /= 2;
        s += 1;
    }
    'witness: for a in [2u128, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = pow_mod(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = mul_mod(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

fn pollard_rho(n: u128) -> u128 {
    if n % 2 == 0 {
        return 2;
    }
    let mut c = 1u128;
    loop {
        let f = |x: u128| (mul_mod(x, x, n) + c) % n;
        let (mut x, mut y, mut d) = (2u128, 2u128, 1u128);
        while d == 1 {
            x = f(x);
            y = f(f(y));
            let diff = if x > y { x - y } else { y - x };
            d = gcd_u128(diff, n);
        }
        if d != n {
            return d;
        }
        c += 1;
    }
}

fn gcd_u128(mut a: u128, mut b: u128) -> u128 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_and_coeffs() {
        let p = GfPoly::from_coeffs(&[true, false, true]); // 1 + x^2
        assert_eq!(p.degree(), Some(2));
        assert!(p.coeff(0) && !p.coeff(1) && p.coeff(2));
        assert_eq!(GfPoly::zero().degree(), None);
        assert_eq!(GfPoly::one().degree(), Some(0));
        assert_eq!(GfPoly::x_pow(100).degree(), Some(100));
    }

    #[test]
    fn mul_and_rem() {
        // (1+x)(1+x) = 1 + x^2 over GF(2)
        let a = GfPoly::from_coeffs(&[true, true]);
        let sq = a.mul(&a);
        assert_eq!(sq, GfPoly::from_coeffs(&[true, false, true]));
        // x^5 mod (x^2+x+1): x^5 = x^2 -> wait compute: x^2 = x+1, x^3=x^2+x=1, x^4=x, x^5=x^2=x+1
        let m = GfPoly::from_coeffs(&[true, true, true]);
        assert_eq!(GfPoly::x_pow(5).rem(&m), GfPoly::from_coeffs(&[true, true]));
    }

    #[test]
    fn irreducibility_known_cases() {
        // x^2 + x + 1 irreducible
        assert!(GfPoly::from_coeffs(&[true, true, true]).is_irreducible());
        // x^2 + 1 = (x+1)^2 reducible
        assert!(!GfPoly::from_coeffs(&[true, false, true]).is_irreducible());
        // x^4 + x + 1 irreducible (and primitive)
        let p = GfPoly::from_coeffs(&[true, true, false, false, true]);
        assert!(p.is_irreducible());
        assert!(p.is_primitive());
        // x^4 + x^3 + x^2 + x + 1 irreducible but NOT primitive (order 5)
        let q = GfPoly::from_coeffs(&[true, true, true, true, true]);
        assert!(q.is_irreducible());
        assert!(!q.is_primitive());
    }

    #[test]
    fn primitive_trinomials() {
        // x^31 + x^3 + 1 is a classic primitive trinomial.
        let mut bits = vec![false; 32];
        bits[0] = true;
        bits[3] = true;
        bits[31] = true;
        let p = GfPoly::from_coeffs(&bits);
        assert!(p.is_primitive());
    }

    #[test]
    fn factoring() {
        assert_eq!(factor_u128((1 << 16) - 1), vec![3, 5, 17, 257]); // 65535
        assert_eq!(factor_u128(2), vec![2]);
        assert_eq!(factor_u128((1u128 << 31) - 1), vec![(1u128 << 31) - 1]); // Mersenne prime
        // 2^32 - 1 = 3 * 5 * 17 * 257 * 65537
        assert_eq!(factor_u128((1u128 << 32) - 1), vec![3, 5, 17, 257, 65537]);
    }

    #[test]
    fn divmod_reconstructs() {
        // (q, r) = a.divmod(m)  =>  a == q·m + r with deg r < deg m.
        let a = GfPoly::from_coeffs(&[true, false, true, true, false, true, true]); // deg 6
        let m = GfPoly::from_coeffs(&[true, true, true]); // x^2+x+1
        let (q, r) = a.divmod(&m);
        assert_eq!(q.mul(&m).add(&r), a);
        assert!(r.degree().map_or(true, |d| d < 2));
        // Exact division: remainder zero, quotient recovers the cofactor.
        let prod = a.mul(&m);
        let (q2, r2) = prod.divmod(&m);
        assert_eq!(q2, a);
        assert!(r2.is_zero());
        // Zero dividend.
        let (qz, rz) = GfPoly::zero().divmod(&m);
        assert!(qz.is_zero() && rz.is_zero());
    }

    #[test]
    fn pow_mod_matches_repeated_mul() {
        let m = GfPoly::from_coeffs(&[true, true, false, false, true]); // x^4+x+1
        let base = GfPoly::from_coeffs(&[true, true, true]); // x^2+x+1
        let mut acc = GfPoly::one();
        for e in 0u32..=20 {
            let bits = u128_bits_msb(e as u128);
            assert_eq!(base.pow_mod(&bits, &m), acc.rem(&m), "e={e}");
            acc = acc.mul(&base);
        }
        // x_pow_mod is the base-x special case of pow_mod.
        let bits = u128_bits_msb(1000);
        assert_eq!(GfPoly::x_pow_mod(&bits, &m), GfPoly::x_pow(1).pow_mod(&bits, &m));
    }

    #[test]
    fn lcm_of_coprime_and_shared() {
        let a = GfPoly::from_coeffs(&[true, true]); // 1+x
        let b = GfPoly::from_coeffs(&[true, true, true]); // 1+x+x^2 (coprime with a)
        assert_eq!(GfPoly::lcm(&a, &b), a.mul(&b));
        // lcm(a·b, b) = a·b.
        assert_eq!(GfPoly::lcm(&a.mul(&b), &b), a.mul(&b));
        assert!(GfPoly::lcm(&a, &GfPoly::zero()).is_zero());
    }

    #[test]
    fn gcd_poly() {
        // gcd((1+x)^2, (1+x)(1+x+x^2)) has degree 1
        let a = GfPoly::from_coeffs(&[true, true]);
        let b = GfPoly::from_coeffs(&[true, true, true]);
        let g = GfPoly::gcd(&a.mul(&a), &a.mul(&b));
        // normalize: over GF(2) gcd is monic automatically
        assert_eq!(g.degree(), Some(1));
    }
}

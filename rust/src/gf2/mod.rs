//! GF(2) linear algebra substrate.
//!
//! Everything in the xorshift world is linear over GF(2): a generator step is
//! multiplication of the state (a bit vector) by a fixed transition matrix.
//! This module provides the bit-vector / bit-matrix machinery used by
//!
//! * parameter validation ([`crate::prng::params`]) — full-rank /
//!   maximal-period checks of candidate `(r, s, a, b, c, d)` sets,
//! * jump-ahead — [`JumpEngine`] places streams at exact offsets of any
//!   linear generator's sequence via minimal-polynomial arithmetic
//!   (O(deg) step calls per jump; the dense-matrix path
//!   [`transition_power`] remains as the small-state cross-check), and
//! * the battery's matrix-rank and linear-complexity tests
//!   ([`rank`], [`berlekamp_massey`]).

mod bitmat;
mod bitvec;
mod bm;
mod jump;
mod poly;
mod transition;

pub use bitmat::BitMatrix;
pub use bitvec::BitVec;
pub use bm::{berlekamp_massey, lfsr_check, linear_complexity};
pub use jump::JumpEngine;
pub use poly::{factor_u128, GfPoly};
pub use transition::{jump_state, transition_matrix, transition_power, LinearStep};

/// Rank of a GF(2) matrix (consumes a copy; see [`BitMatrix::rank`]).
pub fn rank(m: &BitMatrix) -> usize {
    m.rank()
}

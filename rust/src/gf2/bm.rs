//! Berlekamp–Massey over GF(2): the shortest LFSR that generates a bit
//! sequence. This is the engine of the battery's linear-complexity test —
//! the TestU01 test family (Crush #71/#72, BigCrush #80/#81) that
//! discriminates the paper's three generators in Table 2.

/// Run Berlekamp–Massey on `bits` and return the linear complexity `L`
/// (degree of the shortest LFSR reproducing the sequence).
///
/// Bit-packed implementation: connection polynomials are kept in `u64`
/// words, so each update is O(L/64). Total cost O(n·L/64), which keeps the
/// BigCrush-tier instances (n ≈ 4·10^5) around a second.
pub fn linear_complexity(bits: &[bool]) -> usize {
    berlekamp_massey(bits).1
}

/// Berlekamp–Massey returning `(connection polynomial, L)`.
///
/// The connection polynomial is returned LSB-first: coefficient of `x^i` is
/// bit `i` (`c[0]` is always 1). The recurrence it encodes is
/// `s_j = sum_{i=1..=L} c_i * s_{j-i}` over GF(2).
pub fn berlekamp_massey(bits: &[bool]) -> (Vec<u64>, usize) {
    let n = bits.len();
    let nw = n / 64 + 1;
    // c = current connection polynomial, b = previous one.
    let mut c = vec![0u64; nw];
    let mut b = vec![0u64; nw];
    c[0] = 1;
    b[0] = 1;
    let mut l: usize = 0; // current complexity
    let mut m: isize = -1; // index of last complexity change
    // Pack the sequence for fast discrepancy computation.
    let mut s = vec![0u64; nw];
    for (i, &bit) in bits.iter().enumerate() {
        if bit {
            s[i / 64] |= 1 << (i % 64);
        }
    }

    for i in 0..n {
        // Discrepancy d = s_i ^ sum_{j=1..=l} c_j s_{i-j}
        //              = parity of (c & reversed-window of s ending at i).
        // Compute as parity over words of c ANDed with s shifted so that
        // s_{i-j} aligns with c_j. We need bits s_i, s_{i-1}, ..., s_{i-l}
        // dotted with c_0..c_l (c_0 = 1 picks up s_i itself).
        let mut d = 0u64;
        let full_words = l / 64 + 1;
        for w in 0..full_words {
            // word w of c covers exponents [64w, 64w+63] -> needs
            // s bits [i-64w-63, i-64w], i.e. a 64-bit window of s ending
            // at index i-64w, reversed.
            let hi = i as isize - (w as isize) * 64;
            d ^= c[w] & rev_window(&s, hi);
        }
        let d = (d.count_ones() & 1) == 1;
        if d {
            let t = c.clone();
            // c ^= b << (i - m)
            let shift = (i as isize - m) as usize;
            xor_shifted(&mut c, &b, shift);
            if 2 * l <= i {
                l = i + 1 - l;
                m = i as isize;
                b = t;
            }
        }
    }
    c.truncate(l / 64 + 1);
    (c, l)
}

/// A 64-bit window of `s` ending at bit index `hi`, reversed so that bit `k`
/// of the result is `s[hi - k]` (out-of-range indices read as 0).
#[inline]
fn rev_window(s: &[u64], hi: isize) -> u64 {
    if hi < 0 {
        return 0;
    }
    let hi = hi as usize;
    let (q, r) = (hi / 64, hi % 64);
    // Forward window f: bit t = s[hi - 63 + t] (so bit 63 = s[hi]).
    // Word q holds index `idx` at position `idx - 64q`; in f it sits at
    // position `idx - hi + 63`, a left shift by 63 - r.
    let mut f = s.get(q).copied().unwrap_or(0) << (63 - r);
    if r < 63 && q >= 1 {
        f |= s[q - 1] >> (r + 1);
    }
    // Clear positions corresponding to negative indices.
    if hi < 63 {
        f &= !0u64 << (63 - hi);
    }
    // Desired bit k = s[hi - k] = f bit (63 - k): reverse.
    f.reverse_bits()
}

/// `c ^= b << shift` (bitwise over the packed u64 representation).
fn xor_shifted(c: &mut [u64], b: &[u64], shift: usize) {
    let ws = shift / 64;
    let bs = shift % 64;
    for i in (0..c.len()).rev() {
        if i < ws {
            break;
        }
        let mut v = b.get(i - ws).copied().unwrap_or(0) << bs;
        if bs > 0 && i - ws >= 1 {
            v |= b.get(i - ws - 1).copied().unwrap_or(0) >> (64 - bs);
        }
        c[i] ^= v;
    }
}

/// Verify that the connection polynomial `c` (LSB-first packed) of degree
/// `l` reproduces `bits`: `s_j = sum_{i=1..=l} c_i s_{j-i}` for all `j >= l`.
pub fn lfsr_check(c: &[u64], l: usize, bits: &[bool]) -> bool {
    for j in l..bits.len() {
        let mut acc = false;
        for i in 1..=l {
            if (c[i / 64] >> (i % 64)) & 1 == 1 {
                acc ^= bits[j - i];
            }
        }
        if acc != bits[j] {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bm_naive(bits: &[bool]) -> usize {
        // Textbook O(n^2) Berlekamp-Massey for cross-checking.
        let n = bits.len();
        let mut c = vec![false; n + 1];
        let mut b = vec![false; n + 1];
        c[0] = true;
        b[0] = true;
        let (mut l, mut m) = (0usize, -1isize);
        for i in 0..n {
            let mut d = bits[i];
            for j in 1..=l {
                if c[j] && bits[i - j] {
                    d = !d;
                }
            }
            if d {
                let t = c.clone();
                let shift = (i as isize - m) as usize;
                for j in 0..(n + 1 - shift) {
                    if b[j] {
                        c[j + shift] = !c[j + shift];
                    }
                }
                if 2 * l <= i {
                    l = i + 1 - l;
                    m = i as isize;
                    b = t;
                }
            }
        }
        l
    }

    #[test]
    fn constant_and_trivial() {
        assert_eq!(linear_complexity(&[false; 100]), 0);
        // 1 followed by zeros: L = 1
        let mut s = vec![false; 50];
        s[0] = true;
        assert_eq!(linear_complexity(&s), 1);
        // all ones: s_j = s_{j-1}, L = 1
        assert_eq!(linear_complexity(&[true; 100]), 1);
        // alternating: L = 2
        let alt: Vec<bool> = (0..100).map(|i| i % 2 == 0).collect();
        assert_eq!(linear_complexity(&alt), 2);
    }

    #[test]
    fn known_lfsr_recovered() {
        // x^5 + x^2 + 1 (maximal, period 31): s_j = s_{j-3} ^ s_{j-5}... use
        // taps (5, 3): s_j = s_{j-5} ^ s_{j-3}.
        let mut s = vec![true, false, false, true, true];
        for j in 5..200 {
            let b = s[j - 5] ^ s[j - 3];
            s.push(b);
        }
        assert_eq!(linear_complexity(&s), 5);
        let (c, l) = berlekamp_massey(&s);
        assert!(lfsr_check(&c, l, &s));
    }

    #[test]
    fn matches_naive_on_pseudorandom() {
        // Deterministic pseudo-random bits from a simple LCG (not one of our
        // generators to keep the test independent).
        let mut x = 12345u64;
        for n in [1usize, 2, 3, 17, 64, 65, 127, 128, 129, 500] {
            let bits: Vec<bool> = (0..n)
                .map(|_| {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    (x >> 63) & 1 == 1
                })
                .collect();
            assert_eq!(linear_complexity(&bits), bm_naive(&bits), "n={n}");
        }
    }

    #[test]
    fn random_sequence_complexity_near_half() {
        let mut x = 99u64;
        let bits: Vec<bool> = (0..4096)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 62) & 1 == 1
            })
            .collect();
        let l = linear_complexity(&bits);
        let half = bits.len() / 2;
        assert!((l as isize - half as isize).unsigned_abs() < 16, "L={l} vs n/2={half}");
    }

    #[test]
    fn lfsr_of_big_degree() {
        // degree-97 LFSR: s_j = s_{j-97} ^ s_{j-6}
        let mut s: Vec<bool> = (0..97).map(|i| (i * 7 + 3) % 5 < 2).collect();
        for j in 97..1000 {
            let b = s[j - 97] ^ s[j - 6];
            s.push(b);
        }
        assert_eq!(linear_complexity(&s), 97);
    }
}

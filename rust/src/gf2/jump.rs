//! Polynomial jump-ahead: place a stream `k` steps into any GF(2)-linear
//! generator's sequence in O(deg · log k) — without dense matrices.
//!
//! The dense-matrix path ([`super::transition_power`]) squares an `n × n`
//! bit matrix per exponent bit: O(n³/64) per square. Fine for XORWOW
//! (n = 160), hopeless for xorgens r=128 (n = 4096) and MT-class state
//! (n ≈ 20 000). This module replaces it with the classic
//! characteristic-polynomial trick (the same one behind the published
//! xoroshiro/MT jump functions):
//!
//! 1. **Minimal polynomial.** Probe the generator's own [`LinearStep`]
//!    with a random state, observe the bit sequence `b_i = ⟨mask, Mⁱ s₀⟩`,
//!    and run Berlekamp–Massey ([`super::berlekamp_massey`]) on `2n + 64`
//!    bits. The recovered LFSR is the minimal polynomial `p(x)` of that
//!    sequence, which divides the minimal polynomial of `M`; a Horner
//!    check on fresh random states verifies it annihilates `M` itself
//!    (taking an lcm over further probes in the rare deficient case).
//!    For our maximal-period generators `p` is the full characteristic
//!    polynomial, so one probe suffices.
//! 2. **Exponent reduction.** `x^k ≡ r(x) (mod p(x))` by square-and-reduce
//!    in [`GfPoly`] — O(deg²/64) per exponent bit, so a 2^96-step jump of
//!    the 4096-bit xorgens state is ~100 polynomial squarings, not 96
//!    squarings of a 4096×4096 matrix.
//! 3. **Application.** Since `p(M) = 0`, `M^k s = r(M) s`, evaluated by
//!    Horner over the generator's own `step_words`: `deg p` step calls
//!    and at most `deg p` state XORs — no matrix is ever materialised.
//!
//! The coordinator's stream-placement engine
//! ([`crate::prng::place::PlacedMaster`]) builds on this to hand out
//! provably disjoint substreams for *every* linear generator kind.

use super::bm::berlekamp_massey;
use super::poly::{u128_bits_msb, GfPoly};
use super::transition::LinearStep;

/// Probes before giving up on deriving an annihilating polynomial. A
/// single probe succeeds unless the probe functional is degenerate for
/// the generator's invariant factors (probability ≤ 2^-64 per extra
/// probe for our generators).
const MAX_PROBES: usize = 8;

/// A reusable jump plan for one generator family: its minimal polynomial,
/// derived once by probing, plus the modular-arithmetic helpers that turn
/// step counts into appliable residues.
#[derive(Clone, Debug)]
pub struct JumpEngine {
    n_bits: usize,
    min_poly: GfPoly,
}

impl JumpEngine {
    /// Derive the jump engine for `g` by probing its step function.
    ///
    /// Cost: `2n + 64` step calls per probe plus one Berlekamp–Massey run
    /// (O(n²/64)) — for xorgens r=128 (n = 4096) a few milliseconds, for
    /// MT-class state (n ≈ 20 000) well under a second.
    pub fn probe<G: LinearStep + ?Sized>(g: &G) -> JumpEngine {
        let n = g.n_bits();
        assert_eq!(n % 32, 0, "LinearStep states are whole u32 words");
        let words = n / 32;
        let mut rng = ProbeRng::new(0x6a75_6d70_u64 ^ n as u64); // "jump"
        let mut poly = GfPoly::one();
        for _ in 0..MAX_PROBES {
            let state0 = rng.nonzero_words(words);
            let mask = rng.nonzero_words(words);
            let len = 2 * n + 64;
            let mut bits = Vec::with_capacity(len);
            let mut s = state0;
            for _ in 0..len {
                bits.push(parity(&s, &mask));
                g.step_words(&mut s);
            }
            let (c, l) = berlekamp_massey(&bits);
            let candidate = annihilator_from_connection(&c, l);
            poly = if poly == GfPoly::one() {
                candidate
            } else {
                GfPoly::lcm(&poly, &candidate)
            };
            if !poly.is_zero()
                && poly.degree().is_some()
                && Self::annihilates(g, &poly, words, &mut rng)
            {
                return JumpEngine { n_bits: n, min_poly: poly };
            }
        }
        panic!(
            "jump engine: no annihilating polynomial for {}-bit generator after {} probes",
            n, MAX_PROBES
        );
    }

    /// Rebuild an engine from a previously-derived minimal polynomial
    /// (the jump-polynomial persistence path: MT-class probing costs
    /// ~a second per process, so warm starts load the polynomial from a
    /// cache file instead).
    ///
    /// The candidate is **verified** before acceptance — shape checks
    /// (nonzero, degree in `1..=n_bits`) plus the same
    /// annihilation-on-random-states test `probe` uses, under a distinct
    /// deterministic seed. Returns `None` on any mismatch (stale or
    /// corrupt cache), in which case the caller falls back to probing.
    pub fn from_cached<G: LinearStep + ?Sized>(g: &G, min_poly: GfPoly) -> Option<JumpEngine> {
        let n = g.n_bits();
        if n == 0 || n % 32 != 0 {
            return None;
        }
        match min_poly.degree() {
            Some(d) if d >= 1 && d <= n => {}
            _ => return None,
        }
        let mut rng = ProbeRng::new(0x6361_6368_u64 ^ n as u64); // "cach"
        if !Self::annihilates(g, &min_poly, n / 32, &mut rng) {
            return None;
        }
        Some(JumpEngine { n_bits: n, min_poly })
    }

    /// The annihilating (minimal) polynomial of the generator's transition
    /// map, as derived by probing.
    pub fn min_poly(&self) -> &GfPoly {
        &self.min_poly
    }

    /// State width in bits.
    pub fn n_bits(&self) -> usize {
        self.n_bits
    }

    /// `x^k mod p` — the residue that realises a jump of `k` steps.
    pub fn residue(&self, k: u128) -> GfPoly {
        GfPoly::x_pow_mod(&u128_bits_msb(k), &self.min_poly)
    }

    /// `x^(2^log2_spacing) mod p` — the memoizable per-spacing base: raise
    /// it to the stream index (see [`residue_from_base`]) to place stream
    /// `i` at offset `i · 2^log2_spacing` in O(log i) polynomial products.
    ///
    /// [`residue_from_base`]: JumpEngine::residue_from_base
    pub fn base_for_spacing(&self, log2_spacing: u32) -> GfPoly {
        let mut bits = vec![true];
        bits.resize(1 + log2_spacing as usize, false);
        GfPoly::x_pow_mod(&bits, &self.min_poly)
    }

    /// `base^index mod p` by square-and-multiply on `index` — with
    /// `base = x^(2^spacing) mod p` this is `x^(index · 2^spacing) mod p`
    /// without ever re-walking the spacing squarings.
    pub fn residue_from_base(&self, base: &GfPoly, index: u64) -> GfPoly {
        base.pow_mod(&u128_bits_msb(index as u128), &self.min_poly)
    }

    /// Apply a jump residue to a live state: `state ← r(M) · state`, by
    /// Horner over the generator's step function. O(deg p) step calls.
    pub fn apply<G: LinearStep + ?Sized>(&self, g: &G, residue: &GfPoly, state: &mut [u32]) {
        assert_eq!(state.len() * 32, self.n_bits, "state width mismatch");
        horner_apply(g, residue, state);
    }

    /// Convenience: jump `state` forward `k` steps.
    pub fn jump<G: LinearStep + ?Sized>(&self, g: &G, state: &mut [u32], k: u128) {
        let r = self.residue(k);
        self.apply(g, &r, state);
    }

    /// Does `p(M) v = 0` hold for fresh random states `v`? (The acceptance
    /// check for a candidate annihilator.)
    fn annihilates<G: LinearStep + ?Sized>(
        g: &G,
        p: &GfPoly,
        words: usize,
        rng: &mut ProbeRng,
    ) -> bool {
        for _ in 0..2 {
            let mut v = rng.nonzero_words(words);
            horner_apply(g, p, &mut v);
            if v.iter().any(|&w| w != 0) {
                return false;
            }
        }
        true
    }
}

/// `state ← r(M) · state` by Horner: iterate coefficients of `r` from the
/// top, stepping the accumulator once per degree and XOR-ing in the
/// original state wherever a coefficient is set.
fn horner_apply<G: LinearStep + ?Sized>(g: &G, residue: &GfPoly, state: &mut [u32]) {
    let mut acc = vec![0u32; state.len()];
    if let Some(deg) = residue.degree() {
        for j in (0..=deg).rev() {
            if j != deg {
                g.step_words(&mut acc);
            }
            if residue.coeff(j) {
                for (a, &s) in acc.iter_mut().zip(state.iter()) {
                    *a ^= s;
                }
            }
        }
    }
    state.copy_from_slice(&acc);
}

/// Convert a Berlekamp–Massey connection polynomial (LSB-first packed,
/// `c₀ = 1`, recurrence `s_j = Σ_{i=1..L} c_i s_{j-i}`) into the
/// annihilating polynomial `p(x) = Σ_{i=0..L} c_i x^(L-i)` (the reversal,
/// monic of degree exactly `L`).
fn annihilator_from_connection(c: &[u64], l: usize) -> GfPoly {
    let coeffs: Vec<bool> = (0..=l)
        .map(|j| {
            let i = l - j; // coefficient of x^j is c_{L-j}
            (c.get(i / 64).copied().unwrap_or(0) >> (i % 64)) & 1 == 1
        })
        .collect();
    GfPoly::from_coeffs(&coeffs)
}

/// `⟨mask, s⟩` over GF(2): parity of the masked state.
#[inline]
fn parity(s: &[u32], mask: &[u32]) -> bool {
    let mut acc = 0u32;
    for (a, b) in s.iter().zip(mask) {
        acc ^= a & b;
    }
    acc.count_ones() & 1 == 1
}

/// Tiny deterministic word source for probe states/masks (splitmix-style
/// finalizer; self-contained so gf2 stays independent of prng).
struct ProbeRng {
    z: u64,
}

impl ProbeRng {
    fn new(seed: u64) -> ProbeRng {
        ProbeRng { z: seed }
    }

    fn next_u64(&mut self) -> u64 {
        self.z = self.z.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut x = self.z;
        x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        x ^ (x >> 31)
    }

    fn nonzero_words(&mut self, n: usize) -> Vec<u32> {
        loop {
            let v: Vec<u32> = (0..n).map(|_| (self.next_u64() >> 32) as u32).collect();
            if v.iter().any(|&w| w != 0) {
                return v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy 64-bit xorshift (same parameters as the transition-matrix
    /// tests; full period 2^64 − 1, so the minimal polynomial is the
    /// degree-64 characteristic polynomial).
    struct Toy;

    impl Toy {
        fn step(mut x: u64) -> u64 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        }
    }

    impl LinearStep for Toy {
        fn n_bits(&self) -> usize {
            64
        }
        fn step_words(&self, state: &mut [u32]) {
            let x = (state[0] as u64) | ((state[1] as u64) << 32);
            let y = Toy::step(x);
            state[0] = y as u32;
            state[1] = (y >> 32) as u32;
        }
    }

    #[test]
    fn min_poly_has_full_degree_and_annihilates() {
        let e = JumpEngine::probe(&Toy);
        assert_eq!(e.min_poly().degree(), Some(64));
        // p(M) kills arbitrary states.
        let mut v = vec![0xdead_beefu32, 0x1234_5678];
        let p = e.min_poly().clone();
        e.apply(&Toy, &p, &mut v);
        assert_eq!(v, vec![0, 0]);
    }

    #[test]
    fn jump_matches_iteration() {
        let e = JumpEngine::probe(&Toy);
        for k in [0u128, 1, 2, 3, 63, 64, 65, 1000, 4097] {
            let x0 = 0x9e37_79b9_7f4a_7c15u64;
            let mut state = vec![x0 as u32, (x0 >> 32) as u32];
            e.jump(&Toy, &mut state, k);
            let mut x = x0;
            for _ in 0..k {
                x = Toy::step(x);
            }
            assert_eq!(state, vec![x as u32, (x >> 32) as u32], "k={k}");
        }
    }

    #[test]
    fn jump_composes_additively() {
        let e = JumpEngine::probe(&Toy);
        let mut a = vec![0x0123_4567u32, 0x89ab_cdef];
        let mut b = a.clone();
        e.jump(&Toy, &mut a, 12345 + 678);
        e.jump(&Toy, &mut b, 12345);
        e.jump(&Toy, &mut b, 678);
        assert_eq!(a, b);
    }

    #[test]
    fn spacing_base_matches_direct_residue() {
        let e = JumpEngine::probe(&Toy);
        let base = e.base_for_spacing(10);
        for i in [0u64, 1, 2, 3, 17] {
            let via_base = e.residue_from_base(&base, i);
            let direct = e.residue((i as u128) << 10);
            assert_eq!(via_base, direct, "i={i}");
        }
    }

    #[test]
    fn from_cached_verifies_the_polynomial() {
        let e = JumpEngine::probe(&Toy);
        // The genuine minimal polynomial round-trips.
        let back = JumpEngine::from_cached(&Toy, e.min_poly().clone())
            .expect("genuine min-poly must verify");
        assert_eq!(back.min_poly(), e.min_poly());
        let mut a = vec![0x1111_2222u32, 0x3333_4444];
        let mut b = a.clone();
        e.jump(&Toy, &mut a, 99991);
        back.jump(&Toy, &mut b, 99991);
        assert_eq!(a, b);
        // Corrupt / mismatched candidates are rejected, not trusted.
        assert!(JumpEngine::from_cached(&Toy, GfPoly::zero()).is_none());
        assert!(JumpEngine::from_cached(&Toy, GfPoly::one()).is_none());
        assert!(JumpEngine::from_cached(&Toy, GfPoly::x_pow(65)).is_none());
        let tweaked = e.min_poly().add(&GfPoly::x_pow(3));
        assert!(JumpEngine::from_cached(&Toy, tweaked).is_none());
    }

    #[test]
    fn huge_jump_agrees_with_dense_matrix() {
        use crate::gf2::{jump_state, transition_matrix, transition_power};
        let e = JumpEngine::probe(&Toy);
        let m = transition_matrix(&Toy);
        let k = 1u128 << 96;
        let mk = transition_power(&m, k);
        let state0 = vec![0xcafe_babeu32, 0xdead_beef];
        let dense = jump_state(&mk, &state0);
        let mut poly = state0;
        e.jump(&Toy, &mut poly, k);
        assert_eq!(poly, dense);
    }
}

//! Dense GF(2) matrices (row-major bit-packed), with the operations the
//! battery and jump-ahead need: multiply, square, power, rank, identity.

use super::bitvec::BitVec;

/// Dense `rows x cols` matrix over GF(2). Each row is a [`BitVec`].
#[derive(Clone, PartialEq, Eq)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    data: Vec<BitVec>,
}

impl BitMatrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        BitMatrix { rows, cols, data: vec![BitVec::zeros(cols); rows] }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i].set(i, true);
        }
        m
    }

    /// Build from closures: entry `(i, j)` is `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> bool) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                if f(i, j) {
                    m.data[i].set(j, true);
                }
            }
        }
        m
    }

    /// Build a square matrix whose rows are the given bit vectors.
    pub fn from_rows(rows: Vec<BitVec>) -> Self {
        let cols = rows.first().map_or(0, |r| r.len());
        assert!(rows.iter().all(|r| r.len() == cols));
        BitMatrix { rows: rows.len(), cols, data: rows }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Entry access.
    pub fn get(&self, i: usize, j: usize) -> bool {
        self.data[i].get(j)
    }

    pub fn set(&mut self, i: usize, j: usize, b: bool) {
        self.data[i].set(j, b);
    }

    /// Row access.
    pub fn row(&self, i: usize) -> &BitVec {
        &self.data[i]
    }

    /// Matrix-vector product `self * v` (v as column vector).
    pub fn mul_vec(&self, v: &BitVec) -> BitVec {
        assert_eq!(self.cols, v.len());
        let mut out = BitVec::zeros(self.rows);
        for i in 0..self.rows {
            if self.data[i].dot(v) {
                out.set(i, true);
            }
        }
        out
    }

    /// Matrix product `self * other`.
    ///
    /// Row-oriented: row `i` of the product is the XOR of rows `j` of `other`
    /// for every set bit `j` in row `i` of `self` — O(r·c/64) per row pair,
    /// fast enough for the ≤4k-dimension matrices we use.
    pub fn mul(&self, other: &BitMatrix) -> BitMatrix {
        assert_eq!(self.cols, other.rows);
        let mut out = BitMatrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let row = &self.data[i];
            let out_row = &mut out.data[i];
            for (wi, &w) in row.words().iter().enumerate() {
                let mut w = w;
                while w != 0 {
                    let j = wi * 64 + w.trailing_zeros() as usize;
                    out_row.xor_assign(&other.data[j]);
                    w &= w - 1;
                }
            }
        }
        out
    }

    /// `self^k` by binary exponentiation (square matrices only).
    pub fn pow(&self, mut k: u128) -> BitMatrix {
        assert_eq!(self.rows, self.cols);
        let mut result = BitMatrix::identity(self.rows);
        let mut base = self.clone();
        while k > 0 {
            if k & 1 == 1 {
                result = result.mul(&base);
            }
            k >>= 1;
            if k > 0 {
                base = base.mul(&base);
            }
        }
        result
    }

    /// Rank by Gaussian elimination on a copy.
    pub fn rank(&self) -> usize {
        let mut rows: Vec<BitVec> = self.data.clone();
        let mut rank = 0;
        let mut pivot_col = 0;
        while pivot_col < self.cols && rank < self.rows {
            // Find a pivot row with a 1 in pivot_col at or below `rank`.
            let word = pivot_col / 64;
            let mask = 1u64 << (pivot_col % 64);
            let mut pivot = None;
            for (r, row) in rows.iter().enumerate().skip(rank) {
                if row.words()[word] & mask != 0 {
                    pivot = Some(r);
                    break;
                }
            }
            if let Some(p) = pivot {
                rows.swap(rank, p);
                let (head, tail) = rows.split_at_mut(rank + 1);
                let pivot_row = &head[rank];
                for row in tail.iter_mut() {
                    if row.words()[word] & mask != 0 {
                        for (a, b) in row.words_mut().iter_mut().zip(pivot_row.words()) {
                            *a ^= b;
                        }
                    }
                }
                rank += 1;
            }
            pivot_col += 1;
        }
        rank
    }

    /// True if `self` is the identity.
    pub fn is_identity(&self) -> bool {
        self.rows == self.cols && *self == BitMatrix::identity(self.rows)
    }
}

impl std::fmt::Debug for BitMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "BitMatrix {}x{}", self.rows, self.cols)?;
        for i in 0..self.rows.min(16) {
            for j in 0..self.cols.min(64) {
                write!(f, "{}", self.get(i, j) as u8)?;
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_identity() {
        let i = BitMatrix::identity(100);
        assert!(i.is_identity());
        assert_eq!(i.rank(), 100);
    }

    #[test]
    fn mul_by_identity() {
        let m = BitMatrix::from_fn(65, 65, |i, j| (i * 31 + j * 17) % 5 == 0);
        assert_eq!(m.mul(&BitMatrix::identity(65)), m);
        assert_eq!(BitMatrix::identity(65).mul(&m), m);
    }

    #[test]
    fn pow_matches_repeated_mul() {
        let m = BitMatrix::from_fn(20, 20, |i, j| (i + 2 * j) % 3 == 0 || j == (i + 1) % 20);
        let mut acc = BitMatrix::identity(20);
        for k in 0..=9u128 {
            assert_eq!(m.pow(k), acc, "k={k}");
            acc = acc.mul(&m);
        }
    }

    #[test]
    fn rank_of_singular() {
        // Two identical rows -> rank 1.
        let mut m = BitMatrix::zeros(2, 8);
        for j in [1, 3, 5] {
            m.set(0, j, true);
            m.set(1, j, true);
        }
        assert_eq!(m.rank(), 1);
        // Zero matrix -> rank 0.
        assert_eq!(BitMatrix::zeros(7, 7).rank(), 0);
    }

    #[test]
    fn rank_full_random_ish() {
        // Companion-style full-rank matrix: shift + feedback.
        let n = 130;
        let m = BitMatrix::from_fn(n, n, |i, j| j == i + 1 || (i == n - 1 && (j % 7 == 0)));
        // A companion matrix of a polynomial with nonzero constant term is invertible.
        assert_eq!(m.rank(), n);
    }

    #[test]
    fn mul_vec_matches_mul() {
        let m = BitMatrix::from_fn(33, 33, |i, j| (i ^ j) % 3 == 1);
        let v = BitVec::from_bits((0..33).map(|i| i % 2 == 0));
        let mv = m.mul_vec(&v);
        // Compare against explicit sum of columns.
        let mut expect = BitVec::zeros(33);
        for j in 0..33 {
            if v.get(j) {
                for i in 0..33 {
                    if m.get(i, j) {
                        let cur = expect.get(i);
                        expect.set(i, !cur);
                    }
                }
            }
        }
        assert_eq!(mv, expect);
    }
}

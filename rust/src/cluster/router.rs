//! The cluster router: hashes stream registrations across shards, pins
//! every stream's *global* placement identity, retries idempotent ops
//! with capped exponential backoff, and fails streams over when their
//! shard dies — behind a [`TypedStream`]-shaped client surface
//! ([`RoutedBuilder`] / [`RoutedStream`]), so porting a caller is one
//! constructor change.
//!
//! **Bit-identical routing.** The router is the cluster's placement
//! authority: it assigns global stream ids `0, 1, 2, …` in registration
//! order and pins each stream's identity *before* choosing a shard —
//! seed-mix/leapfrog streams get the explicit seed a single-process
//! registry would derive (`SeedSequence(root).child(global_id)`), and
//! exact-jump streams get an explicit [`StreamConfig::slot_base`] from
//! the router's global slot counter. A stream therefore produces the
//! same bits on *whichever* shard serves it, and the whole routed
//! cluster is bit-identical to one local `Coordinator` registering the
//! same streams in the same order — provided every shard (and the
//! router) shares `root_seed`.
//!
//! **Failure semantics.** Register/renew/stats are idempotent and are
//! retried with capped exponential backoff ([`RetryPolicy`]). A draw is
//! *not* blindly retried — a broken connection cannot reveal whether the
//! shard advanced the stream before dying — so any transport failure on
//! a draw marks the shard dead (lease revoked), re-registers the stream
//! on the next live shard in its probe order, and **restarts it from its
//! origin**: at-least-once delivery of a deterministic sequence, never a
//! silent gap.
//!
//! [`TypedStream`]: crate::coordinator::TypedStream

use super::client::ShardClient;
use super::lease::LeaseManager;
use super::wire::{Reply, Request};
use crate::coordinator::backend::{BackendKind, Draws};
use crate::coordinator::handle::{BufferPool, Sample};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::stream::{Placement, StreamConfig};
use crate::obs::trace::{self as otrace, SpanKind, SpanTimer};
use crate::prng::init::SeedSequence;
use crate::prng::GeneratorKind;
use crate::runtime::Transform;
use crate::util::error::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::marker::PhantomData;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Capped exponential backoff for idempotent retries.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts (first try included).
    pub max_attempts: u32,
    /// Delay before the first retry; doubles per retry.
    pub base_delay: Duration,
    /// Backoff cap.
    pub max_delay: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_millis(500),
        }
    }
}

impl RetryPolicy {
    /// Delay before retry number `retry` (0-based): `base · 2^retry`,
    /// capped at `max_delay`.
    pub fn delay(&self, retry: u32) -> Duration {
        let exp = self.base_delay.saturating_mul(1u32 << retry.min(16));
        exp.min(self.max_delay)
    }
}

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Shard addresses; index in this list is the shard id.
    pub shards: Vec<String>,
    /// Must match every shard's `CoordinatorConfig::root_seed` — it
    /// anchors both seed derivation and the exact-jump placement masters.
    pub root_seed: u64,
    /// Liveness-lease ttl for the router's shard bookkeeping.
    pub lease_ttl: Duration,
    /// Per-request reply deadline.
    pub reply_timeout: Duration,
    pub retry: RetryPolicy,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            shards: Vec::new(),
            // Matches CoordinatorConfig::default().root_seed.
            root_seed: 0x9e37_79b9,
            lease_ttl: Duration::from_secs(10),
            reply_timeout: Duration::from_secs(30),
            retry: RetryPolicy::default(),
        }
    }
}

#[derive(Clone)]
struct RoutedEntry {
    /// Which shard currently serves the stream.
    shard: usize,
    /// The stream's id on that shard.
    remote_id: u64,
    /// The config as the caller requested it (conflict detection).
    requested: StreamConfig,
    /// The config with the global identity pinned (seed / slot_base) —
    /// what gets re-registered verbatim on failover.
    pinned: StreamConfig,
}

struct RouterInner {
    conns: Vec<Option<ShardClient>>,
    leases: LeaseManager,
    streams: HashMap<String, RoutedEntry>,
    next_global_id: u64,
    next_slot: u64,
}

/// The multi-process client: a router over a set of shard servers.
pub struct Router {
    config: RouterConfig,
    metrics: Arc<Metrics>,
    pool: Arc<BufferPool>,
    inner: Mutex<RouterInner>,
}

impl Router {
    /// Connect to the shard fleet. Unreachable shards are tolerated as
    /// long as at least one answers a lease renew.
    pub fn connect(config: RouterConfig) -> Result<Router> {
        ensure!(!config.shards.is_empty(), "router needs at least one shard address");
        let mut leases = LeaseManager::new(config.lease_ttl);
        let now = Instant::now();
        let mut conns: Vec<Option<ShardClient>> = Vec::new();
        for (j, addr) in config.shards.iter().enumerate() {
            let conn = ShardClient::connect(addr, config.reply_timeout)
                .ok()
                .and_then(|mut c| c.renew(j as u64).ok().map(|_| c));
            if conn.is_some() {
                leases.grant(j as u64, now)?;
            }
            conns.push(conn);
        }
        ensure!(
            conns.iter().any(Option::is_some),
            "no shard reachable among {:?}",
            config.shards
        );
        Ok(Router {
            config,
            metrics: Arc::new(Metrics::new()),
            pool: Arc::new(BufferPool::new()),
            inner: Mutex::new(RouterInner {
                conns,
                leases,
                streams: HashMap::new(),
                next_global_id: 0,
                next_slot: 0,
            }),
        })
    }

    /// Start building a routed stream; finish with a typed terminal
    /// (`u32`/`uniform`/`normal`), exactly like the local builder.
    pub fn builder(&self, name: &str) -> RoutedBuilder<'_> {
        RoutedBuilder { router: self, name: name.to_string(), config: StreamConfig::default() }
    }

    /// Router-side metrics (requests, retries, failovers, latencies).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Shards with an active liveness lease, sorted.
    pub fn active_shards(&self) -> Vec<u64> {
        let mut inner = self.inner.lock().unwrap();
        let now = Instant::now();
        inner.leases.reclaim_expired(now);
        inner.leases.active_shards(now)
    }

    /// The shard currently serving `name` (None if unregistered).
    pub fn stream_home(&self, name: &str) -> Option<usize> {
        self.inner.lock().unwrap().streams.get(name).map(|e| e.shard)
    }

    /// Per-shard metrics JSON, keyed by address (`Err` for dead shards).
    pub fn shard_stats(&self) -> Vec<(String, Result<String>)> {
        let mut inner = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for j in 0..self.config.shards.len() {
            let addr = self.config.shards[j].clone();
            let stats = match ensure_conn(&self.config, &mut inner, j) {
                Some(conn) => conn.stats(),
                None => Err(crate::anyhow!("shard {addr} unreachable")),
            };
            out.push((addr, stats));
        }
        out
    }

    /// Per-shard labeled exposition JSON (the `metrics` wire verb), keyed
    /// by address (`Err` for dead shards) — the cluster-wide scrape.
    pub fn shard_metrics(&self) -> Vec<(String, Result<String>)> {
        let mut inner = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for j in 0..self.config.shards.len() {
            let addr = self.config.shards[j].clone();
            let metrics = match ensure_conn(&self.config, &mut inner, j) {
                Some(conn) => conn.metrics_json(),
                None => Err(crate::anyhow!("shard {addr} unreachable")),
            };
            out.push((addr, metrics));
        }
        out
    }

    /// Send `Shutdown` to every reachable shard.
    pub fn shutdown_shards(&self) {
        let mut inner = self.inner.lock().unwrap();
        for j in 0..self.config.shards.len() {
            if let Some(conn) = ensure_conn(&self.config, &mut inner, j) {
                let _ = conn.shutdown();
            }
            inner.conns[j] = None;
            inner.leases.revoke(j as u64);
        }
    }

    /// Register `name` with the router (idempotent; conflicting configs
    /// rejected) and pin its global placement identity.
    fn register_stream(&self, name: &str, config: StreamConfig) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(entry) = inner.streams.get(name) {
            ensure!(
                entry.requested == config,
                "stream {name:?} already routed with a different config \
                 (existing: {:?}, requested: {:?})",
                entry.requested,
                config
            );
            return Ok(());
        }
        // Pin the global identity BEFORE shard choice, mirroring what a
        // single-process registry would assign at this registration.
        let gid = inner.next_global_id;
        let mut pinned = config.clone();
        match pinned.placement {
            Placement::ExactJump { .. } => {
                if pinned.slot_base.is_none() {
                    let blocks = pinned.blocks as u64;
                    let base = inner.next_slot;
                    ensure!(
                        base.checked_add(blocks).is_some(),
                        "stream {name:?}: global slot allocation overflows"
                    );
                    pinned.slot_base = Some(base);
                    inner.next_slot = base + blocks;
                }
            }
            Placement::SeedMix | Placement::Leapfrog => {
                if pinned.seed.is_none() {
                    pinned.seed =
                        Some(SeedSequence::new(self.config.root_seed).child(gid).next_u64());
                }
            }
        }
        inner.next_global_id += 1;
        let (shard, remote_id) =
            self.place_with_retry(&mut inner, name, &pinned, /* skip: */ None)?;
        inner.streams.insert(
            name.to_string(),
            RoutedEntry { shard, remote_id, requested: config, pinned },
        );
        Ok(())
    }

    /// Register `pinned` on the first healthy shard in `name`'s probe
    /// order, retrying the whole pass with backoff (registration is
    /// idempotent by name, so re-sending is safe).
    fn place_with_retry(
        &self,
        inner: &mut RouterInner,
        name: &str,
        pinned: &StreamConfig,
        skip: Option<usize>,
    ) -> Result<(usize, u64)> {
        let nshards = self.config.shards.len();
        let preferred = (fnv1a(name) % nshards as u64) as usize;
        let mut last_err = None;
        for attempt in 0..self.config.retry.max_attempts {
            if attempt > 0 {
                self.metrics.retries.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(self.config.retry.delay(attempt - 1));
            }
            for off in 0..nshards {
                let j = (preferred + off) % nshards;
                if Some(j) == skip {
                    continue;
                }
                let Some(conn) = ensure_conn(&self.config, inner, j) else { continue };
                match conn.request(&Request::Register {
                    name: name.to_string(),
                    config: pinned.clone(),
                }) {
                    Ok(Reply::Registered { id, .. }) => {
                        let now = Instant::now();
                        if inner.leases.renew(j as u64, now).is_err() {
                            inner.leases.reclaim_expired(now);
                            let _ = inner.leases.grant(j as u64, now);
                        }
                        return Ok((j, id));
                    }
                    // Shard-reported rejection (config conflict, lease
                    // exhausted): not a liveness problem — propagate.
                    Ok(Reply::Error { message }) => {
                        bail!("shard {}: {message}", self.config.shards[j])
                    }
                    Ok(other) => {
                        bail!("shard {}: unexpected reply {other:?}", self.config.shards[j])
                    }
                    Err(e) => {
                        mark_dead(inner, j);
                        last_err = Some(e);
                    }
                }
            }
        }
        match last_err {
            Some(e) => Err(e).with_context(|| {
                format!(
                    "placing stream {name:?}: no live shard after {} attempts",
                    self.config.retry.max_attempts
                )
            }),
            None => bail!("placing stream {name:?}: no live shard"),
        }
    }

    /// Serve one draw, failing the stream over to another shard (and
    /// restarting it from its origin) on transport failure.
    fn draw_raw(&self, name: &str, n: usize) -> Result<Draws> {
        let mut inner = self.inner.lock().unwrap();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let started = Instant::now();
        // The router is the cluster's client edge: mint the causal trace
        // id here and carry it on every Draw frame, so the shard (same
        // host: same span ring) stitches its server-side spans onto it.
        let trace = otrace::next_trace_id();
        let route_span = SpanTimer::start(trace, SpanKind::Route);
        for attempt in 0..self.config.retry.max_attempts {
            if attempt > 0 {
                self.metrics.retries.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(self.config.retry.delay(attempt - 1));
            }
            let entry =
                inner.streams.get(name).cloned().context("stream not registered with the router")?;
            let outcome = match ensure_conn(&self.config, &mut inner, entry.shard) {
                Some(conn) => conn.request_pooled(
                    &Request::Draw { id: entry.remote_id, n: n as u64, trace: Some(trace) },
                    &self.pool,
                ),
                None => Err(crate::anyhow!("shard {} unreachable", self.config.shards[entry.shard])),
            };
            match outcome {
                Ok(Reply::Draws(d)) if d.len() == n => {
                    let now = Instant::now();
                    if inner.leases.renew(entry.shard as u64, now).is_err() {
                        inner.leases.reclaim_expired(now);
                        let _ = inner.leases.grant(entry.shard as u64, now);
                    }
                    self.metrics.numbers_served.fetch_add(n as u64, Ordering::Relaxed);
                    self.metrics.record_latency(started.elapsed());
                    route_span.finish(n as u64);
                    return Ok(d);
                }
                // Malformed length: shard bug — do NOT pool the buffer.
                Ok(Reply::Draws(d)) => {
                    let got = d.len();
                    drop(d);
                    bail!("stream {name:?}: shard served {got} of {n} elements");
                }
                Ok(Reply::Error { message }) => bail!("stream {name:?}: {message}"),
                Ok(other) => bail!("stream {name:?}: unexpected reply {other:?}"),
                Err(_) => {
                    // Transport failure: the shard may or may not have
                    // advanced the stream — re-home and restart it rather
                    // than risk a silent gap.
                    mark_dead(&mut inner, entry.shard);
                    self.metrics.failovers.fetch_add(1, Ordering::Relaxed);
                    // Instantaneous marker span: arg = the dead shard id.
                    let t = otrace::now_us();
                    otrace::record(trace, SpanKind::Failover, t, t, entry.shard as u64);
                    let (shard, remote_id) = self
                        .place_with_retry(&mut inner, name, &entry.pinned, Some(entry.shard))
                        .with_context(|| {
                            format!("failing stream {name:?} over from dead shard {}", entry.shard)
                        })?;
                    let e = inner.streams.get_mut(name).expect("entry vanished under lock");
                    e.shard = shard;
                    e.remote_id = remote_id;
                }
            }
        }
        bail!(
            "stream {name:?}: draw failed after {} attempts",
            self.config.retry.max_attempts
        )
    }

    fn recycle(&self, d: Draws) {
        self.pool.put(d);
    }
}

/// Connect (or reconnect) shard `j`, returning a usable client or None.
fn ensure_conn<'i>(
    config: &RouterConfig,
    inner: &'i mut RouterInner,
    j: usize,
) -> Option<&'i mut ShardClient> {
    if inner.conns[j].is_none() {
        match ShardClient::connect(&config.shards[j], config.reply_timeout) {
            Ok(mut c) => {
                // A reconnect must prove liveness before it re-enters the
                // rotation; success re-grants the local lease.
                if c.renew(j as u64).is_ok() {
                    let now = Instant::now();
                    inner.leases.reclaim_expired(now);
                    if !inner.leases.is_active(j as u64, now) {
                        let _ = inner.leases.grant(j as u64, now);
                    }
                    inner.conns[j] = Some(c);
                }
            }
            Err(_) => {}
        }
    }
    inner.conns[j].as_mut()
}

fn mark_dead(inner: &mut RouterInner, j: usize) {
    inner.conns[j] = None;
    inner.leases.revoke(j as u64);
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fluent routed-stream construction — the cluster twin of
/// [`StreamBuilder`](crate::coordinator::StreamBuilder).
#[must_use = "a RoutedBuilder does nothing until a terminal method (u32/uniform/normal) runs"]
pub struct RoutedBuilder<'r> {
    router: &'r Router,
    name: String,
    config: StreamConfig,
}

impl<'r> RoutedBuilder<'r> {
    pub fn kind(mut self, kind: GeneratorKind) -> Self {
        self.config.kind = kind;
        self
    }

    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.config.backend = backend;
        self
    }

    pub fn blocks(mut self, blocks: usize) -> Self {
        self.config.blocks = blocks;
        self
    }

    pub fn rounds_per_launch(mut self, rounds: usize) -> Self {
        self.config.rounds_per_launch = rounds;
        self
    }

    pub fn placement(mut self, placement: Placement) -> Self {
        self.config.placement = placement;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.config.seed = Some(seed);
        self
    }

    pub fn with_config(mut self, config: StreamConfig) -> Self {
        self.config = config;
        self
    }

    /// Terminal: raw 32-bit draws.
    pub fn u32(self) -> Result<RoutedStream<'r, u32>> {
        self.finish(Transform::U32)
    }

    /// Terminal: uniform draws on [0, 1).
    pub fn uniform(self) -> Result<RoutedStream<'r, f32>> {
        self.finish(Transform::F32)
    }

    /// Terminal: standard-normal draws.
    pub fn normal(self) -> Result<RoutedStream<'r, f32>> {
        self.finish(Transform::Normal)
    }

    fn finish<T: Sample>(mut self, transform: Transform) -> Result<RoutedStream<'r, T>> {
        debug_assert!(T::matches(transform));
        self.config.transform = transform;
        self.router
            .register_stream(&self.name, self.config)
            .with_context(|| format!("building routed stream {:?}", self.name))?;
        Ok(RoutedStream { router: self.router, name: self.name, _elem: PhantomData })
    }
}

/// A typed handle on one routed stream — the cluster twin of
/// [`TypedStream`](crate::coordinator::TypedStream). Draws go to
/// whichever shard currently serves the stream; on shard death the
/// stream re-homes and restarts from its origin (see the module docs).
pub struct RoutedStream<'r, T: Sample> {
    router: &'r Router,
    name: String,
    _elem: PhantomData<fn() -> T>,
}

impl<T: Sample> std::fmt::Debug for RoutedStream<'_, T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RoutedStream")
            .field("name", &self.name)
            .field("elem", &T::NAME)
            .finish()
    }
}

impl<T: Sample> RoutedStream<'_, T> {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Draw `n` elements, blocking; the reply's storage becomes the
    /// returned `Vec`.
    pub fn draw(&self, n: usize) -> Result<Vec<T>> {
        let d = self.router.draw_raw(&self.name, n)?;
        T::take(d)
    }

    /// Fill the caller-owned slice, blocking; the decoded reply buffer is
    /// recycled into the router's pool.
    pub fn draw_into(&self, out: &mut [T]) -> Result<()> {
        let d = self.router.draw_raw(&self.name, out.len())?;
        T::copy_from(&d, out)?;
        self.router.recycle(d);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_backoff_doubles_and_caps() {
        let p = RetryPolicy {
            max_attempts: 6,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_millis(70),
        };
        assert_eq!(p.delay(0), Duration::from_millis(10));
        assert_eq!(p.delay(1), Duration::from_millis(20));
        assert_eq!(p.delay(2), Duration::from_millis(40));
        assert_eq!(p.delay(3), Duration::from_millis(70), "capped");
        assert_eq!(p.delay(30), Duration::from_millis(70), "shift clamped, still capped");
    }

    #[test]
    fn fnv_spreads_names() {
        let h: std::collections::HashSet<u64> =
            (0..64).map(|i| fnv1a(&format!("stream-{i}"))).collect();
        assert_eq!(h.len(), 64, "fnv1a must not collide on trivial names");
    }

    #[test]
    fn router_requires_a_live_shard() {
        // Nothing listens on these ports (connect_timeout-free connect to
        // a closed port fails fast on loopback).
        let err = Router::connect(RouterConfig {
            shards: vec!["127.0.0.1:9".into()],
            ..Default::default()
        })
        .unwrap_err();
        assert!(format!("{err:#}").contains("no shard reachable"), "{err:#}");
    }
}

//! The shard server: one `Coordinator` behind a TCP listener.
//!
//! One accept thread polls a non-blocking listener; each connection gets
//! its own handler thread (capped by
//! [`ShardServerConfig::max_connections`] — at the cap, new clients wait
//! in the listener backlog) speaking the [`wire`](super::wire) protocol
//! with a [`FrameReader`] over a short read timeout, so every thread
//! observes the stop flag within one poll interval. Draw requests go
//! through the coordinator's normal submit path with a bounded
//! `recv_timeout` — a stuck backend turns into an error reply, not a
//! wedged connection — and reply buffers are recycled into the
//! coordinator's pool right after they are serialized onto the wire.
//!
//! **Graceful drain**: `stop()` (or a `Shutdown` frame) flips the shared
//! stop flag; connection handlers finish serving the request in hand,
//! then exit at the next frame boundary, and the server joins them all
//! before dropping the coordinator (whose own `Drop` joins its workers).
//!
//! The shard's substream-slot **lease** is structural: unless the caller
//! pinned `CoordinatorConfig::substream_slots`, binding installs
//! [`shard_slot_range`]`(shard_id)` so exact-jump allocation cannot
//! leave the shard's range. The `Renew` verb keeps the bookkeeping lease
//! fresh (and doubles as the router's health probe); if it lapses, the
//! next renew re-grants with a bumped fencing epoch.

use super::lease::{shard_slot_range, LeaseManager};
use super::wire::{write_frame, FramePoll, FrameReader, Reply, Request};
use crate::coordinator::stream::StreamId;
use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::obs::registry::ShardCounters;
use crate::obs::trace::{self as otrace, SpanKind, SpanTimer};
use crate::util::error::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How often blocked reads/accepts wake up to check the stop flag.
const POLL_INTERVAL: Duration = Duration::from_millis(25);

/// Shard server configuration.
#[derive(Clone, Debug)]
pub struct ShardServerConfig {
    /// This shard's id: decides its slot lease (`shard_id·2^32 ..`).
    pub shard_id: u64,
    /// The wrapped coordinator's config. `root_seed` must match across
    /// the cluster (and the router) for placement to be bit-identical
    /// wherever a stream lands; `substream_slots`, when `None`, is
    /// filled in from the shard lease.
    pub coordinator: CoordinatorConfig,
    /// Bookkeeping-lease ttl (`Renew` cadence must beat it).
    pub lease_ttl: Duration,
    /// Per-request serve deadline: a draw not answered by the backend in
    /// this window becomes an error reply.
    pub request_timeout: Duration,
    /// Cap on concurrently live connection-handler threads. When the cap
    /// is reached the accept loop stops accepting until a handler exits;
    /// waiting clients queue in the listener backlog (never dropped), so
    /// this is backpressure, not rejection. Fill work itself runs on the
    /// coordinator's shared [`FillPool`](crate::exec::pool::FillPool)
    /// regardless, so the cap bounds thread count — not throughput.
    pub max_connections: usize,
}

impl Default for ShardServerConfig {
    fn default() -> Self {
        ShardServerConfig {
            shard_id: 0,
            coordinator: CoordinatorConfig::default(),
            lease_ttl: Duration::from_secs(10),
            request_timeout: Duration::from_secs(30),
            max_connections: 64,
        }
    }
}

/// A running shard server. Dropping it (or calling [`stop`]) drains and
/// joins everything.
///
/// [`stop`]: ShardServer::stop
pub struct ShardServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    /// The wrapped coordinator — kept so embedders (the `serve` CLI's
    /// `--metrics-addr` listener) can scrape its exposition.
    coord: Arc<Coordinator>,
}

impl ShardServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving.
    pub fn bind(listen: &str, config: ShardServerConfig) -> Result<ShardServer> {
        let lease_range = shard_slot_range(config.shard_id)?;
        let mut coord_cfg = config.coordinator.clone();
        if coord_cfg.substream_slots.is_none() {
            coord_cfg.substream_slots = Some(lease_range);
        }
        let coord = Arc::new(Coordinator::new(coord_cfg));
        // Mark the coordinator as this shard in its labeled families, so
        // per-shard counters (and the shard block of the exposition) are
        // live from the first connection.
        let shard_obs = coord.obs().set_shard(config.shard_id);
        let mut leases = LeaseManager::new(config.lease_ttl);
        leases.grant(config.shard_id, Instant::now())?;
        let leases = Arc::new(Mutex::new(leases));

        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding shard listener on {listen}"))?;
        let addr = listener.local_addr().context("reading bound address")?;
        listener.set_nonblocking(true).context("non-blocking listener")?;
        let stop = Arc::new(AtomicBool::new(false));

        let accept = {
            let stop = stop.clone();
            let coord = Arc::clone(&coord);
            let shard_id = config.shard_id;
            let request_timeout = config.request_timeout;
            let max_connections = config.max_connections.max(1);
            std::thread::Builder::new()
                .name(format!("shard-{shard_id}-accept"))
                .spawn(move || {
                    accept_loop(
                        listener,
                        coord,
                        leases,
                        shard_obs,
                        shard_id,
                        request_timeout,
                        max_connections,
                        stop,
                    )
                })
                .context("spawning accept thread")?
        };
        Ok(ShardServer { addr, stop, accept: Some(accept), coord })
    }

    /// The wrapped coordinator — e.g. to hang a
    /// [`MetricsServer`](crate::obs::http::MetricsServer) scrape
    /// endpoint off its [`exposition`](Coordinator::exposition).
    pub fn coordinator(&self) -> Arc<Coordinator> {
        Arc::clone(&self.coord)
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Has the server been asked to stop (via [`stop`], drop, or a
    /// `Shutdown` frame)?
    ///
    /// [`stop`]: ShardServer::stop
    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Signal stop, drain in-flight requests, join every thread.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Join every finished handler thread, keeping only live ones.
fn reap_finished(conns: &mut Vec<std::thread::JoinHandle<()>>) {
    let (done, live): (Vec<_>, Vec<_>) = conns.drain(..).partition(|h| h.is_finished());
    for h in done {
        let _ = h.join();
    }
    *conns = live;
}

fn accept_loop(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    leases: Arc<Mutex<LeaseManager>>,
    shard_obs: Arc<ShardCounters>,
    shard_id: u64,
    request_timeout: Duration,
    max_connections: usize,
    stop: Arc<AtomicBool>,
) {
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        // At the cap: park until a handler finishes. Not accepting is the
        // backpressure — pending clients sit in the listener backlog.
        reap_finished(&mut conns);
        if conns.len() >= max_connections {
            std::thread::sleep(POLL_INTERVAL);
            continue;
        }
        match listener.accept() {
            Ok((sock, _peer)) => {
                let _ = sock.set_read_timeout(Some(POLL_INTERVAL));
                let _ = sock.set_nodelay(true);
                shard_obs.connections_total.fetch_add(1, Ordering::Relaxed);
                let coord = coord.clone();
                let leases = leases.clone();
                let shard_obs = shard_obs.clone();
                let stop = stop.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("shard-{shard_id}-conn"))
                    .spawn(move || {
                        handle_conn(sock, coord, leases, shard_obs, shard_id, request_timeout, stop)
                    });
                match handle {
                    Ok(h) => conns.push(h),
                    Err(_) => continue, // spawn failed: drop the socket
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL_INTERVAL);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => std::thread::sleep(POLL_INTERVAL),
        }
    }
    // Graceful drain: handlers exit at their next frame boundary.
    for h in conns {
        let _ = h.join();
    }
}

fn handle_conn(
    mut sock: TcpStream,
    coord: Arc<Coordinator>,
    leases: Arc<Mutex<LeaseManager>>,
    shard_obs: Arc<ShardCounters>,
    shard_id: u64,
    request_timeout: Duration,
    stop: Arc<AtomicBool>,
) {
    shard_obs.connections.fetch_add(1, Ordering::Relaxed);
    let pool = coord.pool_handle();
    let mut reader = FrameReader::new();
    loop {
        match reader.poll(&mut sock) {
            Ok(FramePoll::Frame { verb, payload }) => {
                let reply = match Request::decode(verb, &payload) {
                    Ok(req) => serve(req, &coord, &leases, &shard_obs, shard_id, request_timeout),
                    Err(e) => Reply::Error { message: format!("{e:#}") },
                };
                let shutting = matches!(reply, Reply::ShuttingDown);
                let (rverb, rpayload) = reply.encode();
                let sent = write_frame(&mut sock, rverb, &rpayload).is_ok();
                // The draw reply's buffer is spent once serialized:
                // recycle it. It came straight off the serve path (length
                // already vetted in `serve`), so it is well-formed by
                // construction here.
                if let Reply::Draws(d) = reply {
                    pool.put(d);
                }
                if shutting {
                    stop.store(true, Ordering::Relaxed);
                    break;
                }
                if !sent {
                    break;
                }
            }
            Ok(FramePoll::Idle) => {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Ok(FramePoll::Closed) => break,
            // Protocol corruption or hard socket error: the stream can no
            // longer be framed — close.
            Err(_) => break,
        }
    }
    shard_obs.connections.fetch_sub(1, Ordering::Relaxed);
}

fn serve(
    req: Request,
    coord: &Coordinator,
    leases: &Mutex<LeaseManager>,
    shard_obs: &ShardCounters,
    shard_id: u64,
    request_timeout: Duration,
) -> Reply {
    match req {
        Request::Register { name, config } => {
            let transform = config.transform;
            match coord.register_checked(&name, config) {
                Ok(id) => Reply::Registered { id: id.0, transform },
                Err(e) => Reply::Error { message: format!("{e:#}") },
            }
        }
        Request::Draw { id, n, trace } => {
            let n = n as usize;
            // Continue the client's trace if the frame carried one; a
            // bare (old-layout or direct-client) draw gets a fresh id so
            // its server-side spans still correlate.
            let trace = trace.unwrap_or_else(otrace::next_trace_id);
            let span = SpanTimer::start(trace, SpanKind::Draw);
            let rx = match coord.submit_traced(StreamId(id), n, trace) {
                Ok(rx) => rx,
                Err(e) => return Reply::Error { message: format!("{e:#}") },
            };
            match rx.recv_timeout(request_timeout) {
                Ok(Ok(d)) if d.len() == n => {
                    span.finish(n as u64);
                    Reply::Draws(d)
                }
                // A mis-sized reply is a serve-path bug: surface it and
                // drop the buffer (never pool a malformed one).
                Ok(Ok(d)) => {
                    let got = d.len();
                    drop(d);
                    Reply::Error { message: format!("malformed reply: {got} of {n} elements") }
                }
                Ok(Err(e)) => Reply::Error { message: format!("{e:#}") },
                // Timeout: abandoning `rx` makes the worker's eventual
                // send fail, and the worker-side recycle (gated on
                // well-formed length) reclaims the buffer.
                Err(RecvTimeoutError::Timeout) => Reply::Error {
                    message: format!("draw of {n} timed out after {request_timeout:?}"),
                },
                Err(RecvTimeoutError::Disconnected) => {
                    Reply::Error { message: "worker dropped reply".into() }
                }
            }
        }
        Request::Stats => Reply::Stats { json: coord.metrics().to_json().to_string() },
        Request::Metrics => {
            Reply::MetricsJson { json: coord.exposition().to_json().to_string() }
        }
        Request::Renew { shard } => {
            if shard != shard_id {
                return Reply::Error {
                    message: format!("lease renew for shard {shard} sent to shard {shard_id}"),
                };
            }
            let now = Instant::now();
            let mut lm = leases.lock().unwrap();
            let renewed = lm.renew(shard, now).or_else(|_| {
                // Lapsed (e.g. an idle standalone shard): re-grant with a
                // bumped epoch so the caller can see the discontinuity.
                shard_obs.epoch_fences.fetch_add(1, Ordering::Relaxed);
                lm.reclaim_expired(now);
                lm.grant(shard, now)
            });
            match renewed {
                Ok(lease) => {
                    shard_obs.lease_renews.fetch_add(1, Ordering::Relaxed);
                    Reply::Renewed { shard, epoch: lease.epoch }
                }
                Err(e) => Reply::Error { message: format!("{e:#}") },
            }
        }
        Request::Shutdown => Reply::ShuttingDown,
    }
}

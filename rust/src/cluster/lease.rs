//! Slot-range leases: how the PR 3 disjointness theorem survives process
//! boundaries.
//!
//! Shard `j` owns the substream-slot range `[j·2^32, (j+1)·2^32)`
//! ([`shard_slot_range`]). A shard's registry allocates exact-jump slots
//! only inside its leased range (`CoordinatorConfig::substream_slots`),
//! so two shards can place streams with **no coordination at all** and
//! the placed substreams remain provably disjoint — each slot maps to a
//! distinct `slot · 2^log2_spacing` offset of the kind's master sequence.
//!
//! [`LeaseManager`] is the bookkeeping half: grant/renew/revoke plus an
//! expiry-driven reclaim path, with a monotone **epoch** per grant so a
//! holder that was presumed dead and re-granted can be fenced (its stale
//! epoch no longer matches). Time is passed in (`now: Instant`) rather
//! than sampled, so expiry logic is testable without sleeping.

use crate::util::error::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::ops::Range;
use std::time::{Duration, Instant};

/// log2 of the slots each shard owns: shard `j` gets `2^32` slots.
pub const SLOTS_PER_SHARD_LOG2: u32 = 32;

/// The substream-slot range shard `j` owns: `j·2^32 .. (j+1)·2^32`.
///
/// The final representable shard (`j = 2^32 - 1`) gets `j·2^32 ..
/// u64::MAX` — one slot short, since the exclusive end `2^64` does not
/// fit in a `u64`.
pub fn shard_slot_range(shard: u64) -> Result<Range<u64>> {
    ensure!(
        shard < 1u64 << SLOTS_PER_SHARD_LOG2,
        "shard id {shard} out of range (max {})",
        (1u64 << SLOTS_PER_SHARD_LOG2) - 1
    );
    let start = shard << SLOTS_PER_SHARD_LOG2;
    let end = match (shard + 1).checked_shl(SLOTS_PER_SHARD_LOG2) {
        Some(e) if e != 0 => e,
        _ => u64::MAX,
    };
    Ok(start..end)
}

/// A granted slot-range lease.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lease {
    pub shard: u64,
    pub slots: Range<u64>,
    /// Fencing token: strictly increasing across grants, so state tagged
    /// with an old epoch can be rejected after a reclaim + re-grant.
    pub epoch: u64,
}

struct Held {
    lease: Lease,
    expires_at: Instant,
}

/// Grant/renew/revoke bookkeeping for shard slot leases, with
/// expiry-driven reclaim. Used by the router (tracking which shards are
/// live) and by each shard server (tracking its own grant).
pub struct LeaseManager {
    ttl: Duration,
    next_epoch: u64,
    held: HashMap<u64, Held>,
}

impl LeaseManager {
    pub fn new(ttl: Duration) -> LeaseManager {
        LeaseManager { ttl, next_epoch: 1, held: HashMap::new() }
    }

    pub fn ttl(&self) -> Duration {
        self.ttl
    }

    /// Grant shard `shard` its slot range. Fails while an unexpired grant
    /// is outstanding; an expired one is silently reclaimed first, and
    /// the new grant carries a higher epoch (the fencing token).
    pub fn grant(&mut self, shard: u64, now: Instant) -> Result<Lease> {
        if let Some(h) = self.held.get(&shard) {
            if now < h.expires_at {
                bail!(
                    "shard {shard} lease already held (epoch {}, expires in {:?})",
                    h.lease.epoch,
                    h.expires_at - now
                );
            }
            self.held.remove(&shard);
        }
        let lease = Lease { shard, slots: shard_slot_range(shard)?, epoch: self.next_epoch };
        self.next_epoch += 1;
        self.held.insert(shard, Held { lease: lease.clone(), expires_at: now + self.ttl });
        Ok(lease)
    }

    /// Extend an active lease by the ttl. Fails if the lease was never
    /// granted, was revoked, or has already expired (re-grant instead —
    /// the epoch bump tells everyone the holder may have missed time).
    pub fn renew(&mut self, shard: u64, now: Instant) -> Result<Lease> {
        let h = self
            .held
            .get_mut(&shard)
            .with_context(|| format!("shard {shard} holds no lease"))?;
        ensure!(now < h.expires_at, "shard {shard} lease expired; re-grant required");
        h.expires_at = now + self.ttl;
        Ok(h.lease.clone())
    }

    /// Drop a lease immediately (shard observed dead, or clean handoff).
    pub fn revoke(&mut self, shard: u64) -> Option<Lease> {
        self.held.remove(&shard).map(|h| h.lease)
    }

    /// Remove and return every expired lease (sorted by shard id) — the
    /// reclaim path a routing layer runs before placement decisions.
    pub fn reclaim_expired(&mut self, now: Instant) -> Vec<Lease> {
        let dead: Vec<u64> = self
            .held
            .iter()
            .filter(|(_, h)| now >= h.expires_at)
            .map(|(&s, _)| s)
            .collect();
        let mut out: Vec<Lease> =
            dead.iter().filter_map(|s| self.held.remove(s).map(|h| h.lease)).collect();
        out.sort_by_key(|l| l.shard);
        out
    }

    /// Is `shard`'s lease granted and unexpired?
    pub fn is_active(&self, shard: u64, now: Instant) -> bool {
        self.held.get(&shard).map_or(false, |h| now < h.expires_at)
    }

    /// Shards with active leases, sorted.
    pub fn active_shards(&self, now: Instant) -> Vec<u64> {
        let mut out: Vec<u64> = self
            .held
            .iter()
            .filter(|(_, h)| now < h.expires_at)
            .map(|(&s, _)| s)
            .collect();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_the_slot_space() {
        // Adjacent shards tile the space exactly: disjoint and gap-free.
        for j in [0u64, 1, 2, 1000, (1 << 20) - 1] {
            let a = shard_slot_range(j).unwrap();
            let b = shard_slot_range(j + 1).unwrap();
            assert_eq!(a.end, b.start, "shard {j}: ranges must tile");
            assert_eq!(a.end - a.start, 1 << 32, "shard {j}: 2^32 slots each");
        }
        // The last shard saturates rather than overflowing.
        let last = shard_slot_range((1 << 32) - 1).unwrap();
        assert_eq!(last.end, u64::MAX);
        assert!(shard_slot_range(1 << 32).is_err());
    }

    #[test]
    fn grant_renew_revoke_lifecycle() {
        let t0 = Instant::now();
        let mut lm = LeaseManager::new(Duration::from_secs(10));
        let a = lm.grant(0, t0).unwrap();
        assert_eq!(a.slots, 0..1 << 32);
        assert_eq!(a.epoch, 1);
        // Double-grant of an active lease is refused.
        assert!(lm.grant(0, t0 + Duration::from_secs(1)).is_err());
        // Renewal extends: still active 15s in after a renew at 8s.
        lm.renew(0, t0 + Duration::from_secs(8)).unwrap();
        assert!(lm.is_active(0, t0 + Duration::from_secs(15)));
        // Revoke frees it for an immediate re-grant with a higher epoch.
        assert_eq!(lm.revoke(0).unwrap().epoch, 1);
        let b = lm.grant(0, t0 + Duration::from_secs(2)).unwrap();
        assert_eq!(b.epoch, 2, "re-grant must bump the fencing epoch");
    }

    #[test]
    fn expiry_reclaims_and_fences() {
        let t0 = Instant::now();
        let mut lm = LeaseManager::new(Duration::from_secs(5));
        lm.grant(0, t0).unwrap();
        lm.grant(1, t0 + Duration::from_secs(3)).unwrap();
        assert_eq!(lm.active_shards(t0 + Duration::from_secs(4)), vec![0, 1]);
        // At t0+6 shard 0's lease (expires t0+5) is gone, shard 1's is not.
        let reclaimed = lm.reclaim_expired(t0 + Duration::from_secs(6));
        assert_eq!(reclaimed.len(), 1);
        assert_eq!(reclaimed[0].shard, 0);
        assert_eq!(lm.active_shards(t0 + Duration::from_secs(6)), vec![1]);
        // An expired lease cannot be renewed — only re-granted (epoch 3,
        // fencing any holder that still believes in epoch 1).
        assert!(lm.renew(0, t0 + Duration::from_secs(6)).is_err());
        let re = lm.grant(0, t0 + Duration::from_secs(6)).unwrap();
        assert_eq!(re.epoch, 3);
        // Grant over an expired (not yet reclaimed) lease also works.
        let t_late = t0 + Duration::from_secs(60);
        let re2 = lm.grant(1, t_late).unwrap();
        assert_eq!(re2.epoch, 4);
    }
}

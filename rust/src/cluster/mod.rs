//! Multi-process serving: a sharded coordinator cluster.
//!
//! One [`Coordinator`](crate::coordinator::Coordinator) scales to the
//! cores of one machine; the "millions of users" story needs many of
//! them. This subsystem takes the serving layer multi-process while
//! keeping the library's two core guarantees intact across process
//! boundaries:
//!
//! 1. **Provable disjointness** — substream-slot **leases** ([`lease`]):
//!    shard `j` owns slots `[j·2^32, (j+1)·2^32)`, so exact-jump
//!    placement on independent shards can never collide, with zero
//!    runtime coordination (the PR 3 theorem, now per-process).
//! 2. **Bit-identical streams** — the [`router`] pins every stream's
//!    global identity (derived seed, or global slot base) *before*
//!    picking a shard, so a routed cluster reproduces a single local
//!    coordinator bit for bit, and a failed-over stream replays its
//!    exact sequence rather than inventing a new one.
//!
//! ## Pieces
//!
//! * [`wire`] — the length-prefixed binary protocol (zero deps, plain
//!   `std::net::TcpStream`): register / draw / stats / renew / shutdown.
//! * [`lease`] — slot-range lease bookkeeping: grant, renew, revoke,
//!   expiry-driven reclaim, fencing epochs.
//! * [`server`] — [`ShardServer`]: a `Coordinator` behind a listener;
//!   per-connection handler threads, request timeouts, graceful drain.
//! * [`client`] — [`ShardClient`]: one shard connection with a framed,
//!   deadline-bounded request/reply loop.
//! * [`router`] — [`Router`]: hashed stream placement, capped-backoff
//!   retries for idempotent ops, shard-death failover; client surface
//!   ([`RoutedBuilder`] / [`RoutedStream`]) mirrors the local typed
//!   handles, so callers port with one constructor change.
//!
//! ## Wire format
//!
//! Every message is one frame on a TCP stream:
//!
//! | offset | size | field                               |
//! |--------|------|-------------------------------------|
//! | 0      | 4    | magic `b"xgw1"`                     |
//! | 4      | 1    | verb                                |
//! | 5      | 3    | reserved (zero)                     |
//! | 8      | 4    | payload length (LE `u32`)           |
//! | 12     | len  | payload                             |
//!
//! Verbs: `0x01` register, `0x02` draw, `0x03` stats, `0x04` shutdown,
//! `0x05` renew, `0x06` metrics (the labeled exposition); a success
//! reply echoes the request verb with the high bit set (`0x80 | verb`);
//! `0x7f` is the error reply. See [`wire`] for the payload codecs.
//!
//! The draw payload carries an optional **trailing trace-id field**
//! (presence byte + LE `u64`): the router's causal trace id, continued
//! by the shard's server-side spans. Absent encodes byte-identically to
//! the pre-trace layout, so old and new peers interoperate — see the
//! "trailing optional fields" note in [`wire`].
//!
//! ## Example (loopback)
//!
//! ```no_run
//! use xorgens_gp::cluster::{Router, RouterConfig, ShardServer, ShardServerConfig};
//!
//! let s0 = ShardServer::bind("127.0.0.1:0", ShardServerConfig::default())?;
//! let s1 = ShardServer::bind(
//!     "127.0.0.1:0",
//!     ShardServerConfig { shard_id: 1, ..Default::default() },
//! )?;
//! let router = Router::connect(RouterConfig {
//!     shards: vec![s0.addr().to_string(), s1.addr().to_string()],
//!     ..Default::default()
//! })?;
//! let stream = router.builder("prices").blocks(64).u32()?;
//! let draws = stream.draw(4096)?; // identical to a local Coordinator's
//! # Ok::<(), xorgens_gp::util::error::Error>(())
//! ```

pub mod client;
pub mod lease;
pub mod router;
pub mod server;
pub mod wire;

pub use client::ShardClient;
pub use lease::{shard_slot_range, Lease, LeaseManager};
pub use router::{RetryPolicy, RoutedBuilder, RoutedStream, Router, RouterConfig};
pub use server::{ShardServer, ShardServerConfig};
pub use wire::{FramePoll, FrameReader, Reply, Request};

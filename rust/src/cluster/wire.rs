//! The cluster wire protocol: length-prefixed binary frames over
//! `std::net::TcpStream`, zero dependencies.
//!
//! Every message — request or reply — is one **frame**:
//!
//! | offset | size | field                                     |
//! |--------|------|-------------------------------------------|
//! | 0      | 4    | magic `b"xgw1"`                           |
//! | 4      | 1    | verb                                      |
//! | 5      | 3    | reserved (zero)                           |
//! | 8      | 4    | payload length, little-endian `u32`       |
//! | 12     | len  | payload (verb-specific)                   |
//!
//! Request verbs are `0x01..=0x06`; a success reply echoes the request
//! verb with the high bit set (`0x80 | verb`); `0x7f` is the error reply.
//! All integers are little-endian; strings are a `u32` byte length
//! followed by UTF-8; options are a presence byte (`0`/`1`) followed by
//! the value when present; `f32` draws travel as their IEEE-754 bits.
//!
//! **Trailing optional fields** (the compatibility idiom): a frame may
//! grow new fields only at the end of its payload, encoded only when
//! present; decoders read them `if` bytes remain. The draw request's
//! optional trace id uses this — old peers' frames (no field) and new
//! peers' untraced frames decode identically, and an old decoder never
//! sees the field it does not know.
//!
//! [`FrameReader`] accumulates partial bytes across short reads, so it
//! composes with sockets under `set_read_timeout` (a timed-out `read`
//! may deliver a prefix of a frame; `read_exact` would lose it).

use crate::coordinator::backend::{BackendKind, Draws};
use crate::coordinator::handle::BufferPool;
use crate::coordinator::stream::{Placement, StreamConfig};
use crate::prng::GeneratorKind;
use crate::runtime::Transform;
use crate::util::error::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::time::{Duration, Instant};

/// Frame magic: protocol name + version. Bump the digit on layout breaks.
pub const MAGIC: [u8; 4] = *b"xgw1";
/// Fixed frame-header size (magic + verb + padding + payload length).
pub const HEADER_LEN: usize = 12;
/// Payload cap: 2^28 bytes (64M u32 draws per request), so a corrupt
/// length prefix cannot make a peer attempt a multi-gigabyte allocation.
pub const MAX_PAYLOAD: usize = 1 << 28;

/// Request verbs.
pub const VERB_REGISTER: u8 = 0x01;
pub const VERB_DRAW: u8 = 0x02;
pub const VERB_STATS: u8 = 0x03;
pub const VERB_SHUTDOWN: u8 = 0x04;
pub const VERB_RENEW: u8 = 0x05;
pub const VERB_METRICS: u8 = 0x06;
/// Success replies echo the request verb with this bit set.
pub const REPLY_BIT: u8 = 0x80;
/// The error reply verb (any request can fail).
pub const VERB_ERROR: u8 = 0x7f;

/// A client-to-shard request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Register (or re-attach) a named stream on the shard.
    Register { name: String, config: StreamConfig },
    /// Draw `n` elements from a registered stream. `trace` is the
    /// router's causal trace id, carried as an optional **trailing**
    /// frame field (absent on the wire when `None`), so traced draws
    /// correlate across the process boundary and old peers interoperate.
    Draw { id: u64, n: u64, trace: Option<u64> },
    /// Fetch the shard's legacy global metrics snapshot as JSON.
    Stats,
    /// Fetch the shard's full labeled exposition (global + per-stream +
    /// per-worker + per-shard families) as JSON.
    Metrics,
    /// Renew the shard's slot lease (doubles as a health probe).
    Renew { shard: u64 },
    /// Ask the shard to drain in-flight work and exit.
    Shutdown,
}

/// A shard-to-client reply.
#[derive(Debug, PartialEq)]
pub enum Reply {
    Registered { id: u64, transform: Transform },
    Draws(Draws),
    Stats { json: String },
    MetricsJson { json: String },
    Renewed { shard: u64, epoch: u64 },
    ShuttingDown,
    Error { message: String },
}

impl Request {
    /// Serialize to `(verb, payload)` for [`write_frame`].
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut p = Vec::new();
        match self {
            Request::Register { name, config } => {
                put_str(&mut p, name);
                put_config(&mut p, config);
                (VERB_REGISTER, p)
            }
            Request::Draw { id, n, trace } => {
                put_u64(&mut p, *id);
                put_u64(&mut p, *n);
                // Trailing optional field: written only when present, so
                // untraced frames are byte-identical to the pre-trace
                // protocol (see the module docs).
                if trace.is_some() {
                    put_opt_u64(&mut p, *trace);
                }
                (VERB_DRAW, p)
            }
            Request::Stats => (VERB_STATS, p),
            Request::Metrics => (VERB_METRICS, p),
            Request::Renew { shard } => {
                put_u64(&mut p, *shard);
                (VERB_RENEW, p)
            }
            Request::Shutdown => (VERB_SHUTDOWN, p),
        }
    }

    /// Parse a received frame back into a request.
    pub fn decode(verb: u8, payload: &[u8]) -> Result<Request> {
        let mut c = Cursor::new(payload);
        let req = match verb {
            VERB_REGISTER => {
                let name = c.str()?;
                let config = get_config(&mut c)?;
                Request::Register { name, config }
            }
            VERB_DRAW => {
                let id = c.u64()?;
                let n = c.u64()?;
                let trace = if c.remaining() > 0 { c.opt_u64()? } else { None };
                Request::Draw { id, n, trace }
            }
            VERB_STATS => Request::Stats,
            VERB_METRICS => Request::Metrics,
            VERB_RENEW => Request::Renew { shard: c.u64()? },
            VERB_SHUTDOWN => Request::Shutdown,
            v => bail!("unknown request verb {v:#04x}"),
        };
        c.done()?;
        Ok(req)
    }
}

impl Reply {
    /// Serialize to `(verb, payload)` for [`write_frame`]. Borrows, so a
    /// server can encode a draw reply and then recycle its buffer.
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut p = Vec::new();
        match self {
            Reply::Registered { id, transform } => {
                put_u64(&mut p, *id);
                p.push(transform_code(*transform));
                (REPLY_BIT | VERB_REGISTER, p)
            }
            Reply::Draws(d) => {
                match d {
                    Draws::U32(v) => {
                        p.push(0);
                        put_u64(&mut p, v.len() as u64);
                        p.reserve(v.len() * 4);
                        for &x in v {
                            p.extend_from_slice(&x.to_le_bytes());
                        }
                    }
                    Draws::F32(v) => {
                        p.push(1);
                        put_u64(&mut p, v.len() as u64);
                        p.reserve(v.len() * 4);
                        for &x in v {
                            p.extend_from_slice(&x.to_bits().to_le_bytes());
                        }
                    }
                }
                (REPLY_BIT | VERB_DRAW, p)
            }
            Reply::Stats { json } => {
                put_str(&mut p, json);
                (REPLY_BIT | VERB_STATS, p)
            }
            Reply::MetricsJson { json } => {
                put_str(&mut p, json);
                (REPLY_BIT | VERB_METRICS, p)
            }
            Reply::Renewed { shard, epoch } => {
                put_u64(&mut p, *shard);
                put_u64(&mut p, *epoch);
                (REPLY_BIT | VERB_RENEW, p)
            }
            Reply::ShuttingDown => (REPLY_BIT | VERB_SHUTDOWN, p),
            Reply::Error { message } => {
                put_str(&mut p, message);
                (VERB_ERROR, p)
            }
        }
    }

    /// Parse a received frame back into a reply (draw storage freshly
    /// allocated; the client hot path uses [`Reply::decode_pooled`]).
    pub fn decode(verb: u8, payload: &[u8]) -> Result<Reply> {
        Self::decode_with(verb, payload, None)
    }

    /// Like [`Reply::decode`], but draw replies land in a buffer popped
    /// from `pool` — the cluster leg of the zero-copy reply story.
    pub(crate) fn decode_pooled(verb: u8, payload: &[u8], pool: &BufferPool) -> Result<Reply> {
        Self::decode_with(verb, payload, Some(pool))
    }

    fn decode_with(verb: u8, payload: &[u8], pool: Option<&BufferPool>) -> Result<Reply> {
        let mut c = Cursor::new(payload);
        let reply = match verb {
            v if v == REPLY_BIT | VERB_REGISTER => {
                let id = c.u64()?;
                let transform = transform_from(c.u8()?)?;
                Reply::Registered { id, transform }
            }
            v if v == REPLY_BIT | VERB_DRAW => {
                let tag = c.u8()?;
                let n = c.u64()? as usize;
                ensure!(
                    n.checked_mul(4).map_or(false, |b| b <= c.remaining()),
                    "draw reply claims {n} elements but carries {} bytes",
                    c.remaining()
                );
                let mut d = match (tag, pool) {
                    (0, Some(pool)) => pool.get(Transform::U32).0,
                    (0, None) => Draws::U32(Vec::new()),
                    (1, Some(pool)) => pool.get(Transform::F32).0,
                    (1, None) => Draws::F32(Vec::new()),
                    (t, _) => bail!("unknown draw variant tag {t}"),
                };
                d.reserve(n);
                match &mut d {
                    Draws::U32(v) => {
                        for _ in 0..n {
                            v.push(c.u32()?);
                        }
                    }
                    Draws::F32(v) => {
                        for _ in 0..n {
                            v.push(f32::from_bits(c.u32()?));
                        }
                    }
                }
                Reply::Draws(d)
            }
            v if v == REPLY_BIT | VERB_STATS => Reply::Stats { json: c.str()? },
            v if v == REPLY_BIT | VERB_METRICS => Reply::MetricsJson { json: c.str()? },
            v if v == REPLY_BIT | VERB_RENEW => {
                Reply::Renewed { shard: c.u64()?, epoch: c.u64()? }
            }
            v if v == REPLY_BIT | VERB_SHUTDOWN => Reply::ShuttingDown,
            VERB_ERROR => Reply::Error { message: c.str()? },
            v => bail!("unknown reply verb {v:#04x}"),
        };
        c.done()?;
        Ok(reply)
    }
}

/// Write one frame (header + payload) and flush.
pub fn write_frame(w: &mut impl Write, verb: u8, payload: &[u8]) -> Result<()> {
    ensure!(
        payload.len() <= MAX_PAYLOAD,
        "frame payload {} exceeds the {MAX_PAYLOAD}-byte cap",
        payload.len()
    );
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4] = verb;
    header[8..12].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header).context("writing frame header")?;
    w.write_all(payload).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// One [`FrameReader::poll`] outcome.
#[derive(Debug)]
pub enum FramePoll {
    /// A complete frame arrived.
    Frame { verb: u8, payload: Vec<u8> },
    /// The read timed out (or would block); any partial frame stays
    /// buffered for the next poll. Callers check their stop flag here.
    Idle,
    /// The peer closed the connection at a frame boundary.
    Closed,
}

/// Incremental frame parser for sockets with read timeouts: partial
/// bytes accumulate across polls, so a slow sender never corrupts the
/// stream and an idle socket periodically yields control to the caller.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Drive the reader one step: returns a frame if one is (or becomes)
    /// complete, `Idle` on timeout, `Closed` on clean EOF. EOF with a
    /// partial frame buffered is an error (truncated stream).
    pub fn poll<R: Read>(&mut self, r: &mut R) -> Result<FramePoll> {
        loop {
            if let Some((verb, payload)) = self.try_parse()? {
                return Ok(FramePoll::Frame { verb, payload });
            }
            let mut tmp = [0u8; 4096];
            match r.read(&mut tmp) {
                Ok(0) => {
                    if self.buf.is_empty() {
                        return Ok(FramePoll::Closed);
                    }
                    bail!("connection closed mid-frame ({} bytes buffered)", self.buf.len());
                }
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(FramePoll::Idle)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("socket read"),
            }
        }
    }

    fn try_parse(&mut self) -> Result<Option<(u8, Vec<u8>)>> {
        if self.buf.len() < HEADER_LEN {
            return Ok(None);
        }
        ensure!(self.buf[..4] == MAGIC, "bad frame magic {:02x?}", &self.buf[..4]);
        let verb = self.buf[4];
        let len =
            u32::from_le_bytes([self.buf[8], self.buf[9], self.buf[10], self.buf[11]]) as usize;
        ensure!(len <= MAX_PAYLOAD, "frame length {len} exceeds the {MAX_PAYLOAD}-byte cap");
        if self.buf.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let payload = self.buf[HEADER_LEN..HEADER_LEN + len].to_vec();
        self.buf.drain(..HEADER_LEN + len);
        Ok(Some((verb, payload)))
    }
}

/// Poll until a full frame arrives or `timeout` elapses.
pub fn read_frame_blocking<R: Read>(
    r: &mut R,
    reader: &mut FrameReader,
    timeout: Duration,
) -> Result<(u8, Vec<u8>)> {
    let deadline = Instant::now() + timeout;
    loop {
        match reader.poll(r)? {
            FramePoll::Frame { verb, payload } => return Ok((verb, payload)),
            FramePoll::Closed => bail!("connection closed while waiting for a reply"),
            FramePoll::Idle => {
                ensure!(Instant::now() < deadline, "timed out after {timeout:?} waiting for a reply")
            }
        }
    }
}

// --- scalar/config codecs ------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_opt_u64(out: &mut Vec<u8>, v: Option<u64>) {
    match v {
        Some(x) => {
            out.push(1);
            put_u64(out, x);
        }
        None => out.push(0),
    }
}

fn put_config(out: &mut Vec<u8>, c: &StreamConfig) {
    out.push(kind_code(c.kind));
    out.push(transform_code(c.transform));
    out.push(match c.backend {
        BackendKind::Rust => 0,
        BackendKind::Pjrt => 1,
    });
    put_u64(out, c.blocks as u64);
    put_u64(out, c.rounds_per_launch as u64);
    match c.placement {
        Placement::SeedMix => out.push(0),
        Placement::ExactJump { log2_spacing } => {
            out.push(1);
            put_u32(out, log2_spacing);
        }
        Placement::Leapfrog => out.push(2),
    }
    put_opt_u64(out, c.seed);
    put_opt_u64(out, c.slot_base);
    put_opt_u64(out, c.prefetch.map(|p| p as u64));
}

fn get_config(c: &mut Cursor<'_>) -> Result<StreamConfig> {
    let kind = kind_from(c.u8()?)?;
    let transform = transform_from(c.u8()?)?;
    let backend = match c.u8()? {
        0 => BackendKind::Rust,
        1 => BackendKind::Pjrt,
        b => bail!("unknown backend code {b}"),
    };
    let blocks = c.u64()? as usize;
    let rounds_per_launch = c.u64()? as usize;
    let placement = match c.u8()? {
        0 => Placement::SeedMix,
        1 => Placement::ExactJump { log2_spacing: c.u32()? },
        2 => Placement::Leapfrog,
        p => bail!("unknown placement code {p}"),
    };
    let seed = c.opt_u64()?;
    let slot_base = c.opt_u64()?;
    let prefetch = c.opt_u64()?.map(|p| p as usize);
    Ok(StreamConfig {
        kind,
        transform,
        backend,
        blocks,
        rounds_per_launch,
        placement,
        seed,
        slot_base,
        prefetch,
    })
}

fn kind_code(k: GeneratorKind) -> u8 {
    match k {
        GeneratorKind::Xorgens => 0,
        GeneratorKind::XorgensGp => 1,
        GeneratorKind::Mt19937 => 2,
        GeneratorKind::Mtgp => 3,
        GeneratorKind::Xorwow => 4,
    }
}

fn kind_from(code: u8) -> Result<GeneratorKind> {
    Ok(match code {
        0 => GeneratorKind::Xorgens,
        1 => GeneratorKind::XorgensGp,
        2 => GeneratorKind::Mt19937,
        3 => GeneratorKind::Mtgp,
        4 => GeneratorKind::Xorwow,
        c => bail!("unknown generator-kind code {c}"),
    })
}

fn transform_code(t: Transform) -> u8 {
    match t {
        Transform::U32 => 0,
        Transform::F32 => 1,
        Transform::Normal => 2,
    }
}

fn transform_from(code: u8) -> Result<Transform> {
    Ok(match code {
        0 => Transform::U32,
        1 => Transform::F32,
        2 => Transform::Normal,
        c => bail!("unknown transform code {c}"),
    })
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.remaining() >= n, "truncated payload: need {n} bytes, have {}", self.remaining());
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn opt_u64(&mut self) -> Result<Option<u64>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u64()?)),
            f => bail!("bad option flag {f}"),
        }
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let b = self.take(len)?;
        String::from_utf8(b.to_vec()).context("payload string is not UTF-8")
    }

    fn done(&self) -> Result<()> {
        ensure!(self.remaining() == 0, "{} trailing bytes after payload", self.remaining());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_bytes(verb: u8, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, verb, payload).unwrap();
        out
    }

    fn roundtrip_request(req: Request) {
        let (verb, payload) = req.encode();
        let back = Request::decode(verb, &payload).unwrap();
        assert_eq!(back, req);
    }

    fn roundtrip_reply(reply: Reply) {
        let (verb, payload) = reply.encode();
        let back = Reply::decode(verb, &payload).unwrap();
        assert_eq!(back, reply);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_request(Request::Register {
            name: "stream/α".into(),
            config: StreamConfig::default(),
        });
        roundtrip_request(Request::Register {
            name: "exact".into(),
            config: StreamConfig {
                kind: GeneratorKind::Xorwow,
                transform: Transform::Normal,
                blocks: 7,
                rounds_per_launch: 3,
                placement: Placement::ExactJump { log2_spacing: 48 },
                seed: Some(99),
                slot_base: Some(1 << 33),
                prefetch: Some(2),
                ..Default::default()
            },
        });
        roundtrip_request(Request::Draw { id: 5, n: 4096, trace: None });
        roundtrip_request(Request::Draw { id: 5, n: 4096, trace: Some(77) });
        roundtrip_request(Request::Stats);
        roundtrip_request(Request::Metrics);
        roundtrip_request(Request::Renew { shard: 3 });
        roundtrip_request(Request::Shutdown);
    }

    /// Back-compat: a pre-trace peer's draw frame (16-byte payload, no
    /// trailing field) must decode as `trace: None`, and an untraced new
    /// frame must be byte-identical to the old layout.
    #[test]
    fn draw_trace_field_is_backward_compatible() {
        let mut old = Vec::new();
        old.extend_from_slice(&5u64.to_le_bytes());
        old.extend_from_slice(&4096u64.to_le_bytes());
        assert_eq!(
            Request::decode(VERB_DRAW, &old).unwrap(),
            Request::Draw { id: 5, n: 4096, trace: None }
        );
        let (_, untraced) = Request::Draw { id: 5, n: 4096, trace: None }.encode();
        assert_eq!(untraced, old, "None must encode to the pre-trace layout");
        let (_, traced) = Request::Draw { id: 5, n: 4096, trace: Some(9) }.encode();
        assert_eq!(traced.len(), old.len() + 9, "presence byte + u64");
    }

    #[test]
    fn replies_roundtrip() {
        roundtrip_reply(Reply::Registered { id: 9, transform: Transform::F32 });
        roundtrip_reply(Reply::Draws(Draws::U32(vec![0, 1, u32::MAX, 0xdead_beef])));
        roundtrip_reply(Reply::Draws(Draws::F32(vec![0.0, 0.5, -1.25e-7])));
        roundtrip_reply(Reply::Stats { json: r#"{"requests":1}"#.into() });
        roundtrip_reply(Reply::MetricsJson { json: r#"{"global":{},"streams":[]}"#.into() });
        roundtrip_reply(Reply::Renewed { shard: 1, epoch: 4 });
        roundtrip_reply(Reply::ShuttingDown);
        roundtrip_reply(Reply::Error { message: "no such stream".into() });
    }

    #[test]
    fn pooled_decode_reuses_buffers() {
        let pool = BufferPool::new();
        pool.put(Draws::U32({
            let mut v = Vec::with_capacity(1024);
            v.push(7);
            v
        }));
        let (verb, payload) = Reply::Draws(Draws::U32(vec![1, 2, 3])).encode();
        let Reply::Draws(d) = Reply::decode_pooled(verb, &payload, &pool).unwrap() else {
            panic!("wrong reply variant");
        };
        let Draws::U32(v) = d else { panic!("wrong draw variant") };
        assert_eq!(v, vec![1, 2, 3]);
        assert!(v.capacity() >= 1024, "decode must reuse the pooled buffer");
    }

    #[test]
    fn frame_reader_accumulates_partial_reads() {
        // A reader that delivers one byte per call, with a WouldBlock
        // between deliveries — the worst case a socket timeout produces.
        struct Trickle {
            data: Vec<u8>,
            pos: usize,
            ready: bool,
        }
        impl Read for Trickle {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if self.pos >= self.data.len() {
                    return Ok(0);
                }
                if !self.ready {
                    self.ready = true;
                    return Err(std::io::ErrorKind::WouldBlock.into());
                }
                self.ready = false;
                out[0] = self.data[self.pos];
                self.pos += 1;
                Ok(1)
            }
        }
        let (verb, payload) = Request::Draw { id: 1, n: 64, trace: None }.encode();
        let mut src = Trickle { data: frame_bytes(verb, &payload), pos: 0, ready: false };
        let mut reader = FrameReader::new();
        let mut idles = 0;
        loop {
            match reader.poll(&mut src).unwrap() {
                FramePoll::Frame { verb: v, payload: p } => {
                    assert_eq!(
                        Request::decode(v, &p).unwrap(),
                        Request::Draw { id: 1, n: 64, trace: None }
                    );
                    break;
                }
                FramePoll::Idle => idles += 1,
                FramePoll::Closed => panic!("closed before the frame completed"),
            }
        }
        assert!(idles > 0, "the trickle source must have forced idle polls");
        // After the frame, EOF at the boundary reads as a clean close.
        assert!(matches!(reader.poll(&mut src).unwrap(), FramePoll::Closed));
    }

    #[test]
    fn frame_reader_rejects_corruption() {
        // Bad magic.
        let mut bad = frame_bytes(VERB_STATS, &[]);
        bad[0] = b'X';
        let mut reader = FrameReader::new();
        assert!(reader.poll(&mut &bad[..]).is_err());
        // Oversize length prefix.
        let mut huge = frame_bytes(VERB_STATS, &[]);
        huge[8..12].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        let mut reader = FrameReader::new();
        assert!(reader.poll(&mut &huge[..]).is_err());
        // EOF mid-frame.
        let whole = frame_bytes(VERB_RENEW, &5u64.to_le_bytes());
        let mut reader = FrameReader::new();
        assert!(reader.poll(&mut &whole[..whole.len() - 3]).is_err());
    }

    #[test]
    fn decode_rejects_malformed_payloads() {
        // Truncated draw reply: claims 10 elements, carries 2.
        let mut p = vec![0u8];
        p.extend_from_slice(&10u64.to_le_bytes());
        p.extend_from_slice(&[1, 0, 0, 0, 2, 0, 0, 0]);
        assert!(Reply::decode(REPLY_BIT | VERB_DRAW, &p).is_err());
        // Trailing garbage.
        let (verb, mut payload) = Request::Stats.encode();
        payload.push(0);
        assert!(Request::decode(verb, &payload).is_err());
        // Unknown verbs.
        assert!(Request::decode(0x6e, &[]).is_err());
        assert!(Reply::decode(0x6e, &[]).is_err());
    }
}

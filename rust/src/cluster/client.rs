//! Net client for one shard: a `TcpStream` speaking the wire protocol.
//!
//! [`ShardClient::request`] is the transport primitive: it returns
//! `Err` only for transport-level failures (connect/read/write/frame
//! corruption/timeout) and `Ok(Reply::Error { .. })` for shard-reported
//! application errors — the distinction the router's retry/failover
//! logic is built on (transport failures are retriable/failoverable;
//! application errors are not). The typed convenience methods collapse
//! both into `Result` for direct callers.

use super::wire::{
    read_frame_blocking, write_frame, FrameReader, Reply, Request,
};
use crate::coordinator::backend::Draws;
use crate::coordinator::handle::BufferPool;
use crate::coordinator::stream::StreamConfig;
use crate::runtime::Transform;
use crate::util::error::{bail, Context, Result};
use std::net::TcpStream;
use std::time::Duration;

/// How often a blocked reply read wakes to check its deadline.
const POLL_INTERVAL: Duration = Duration::from_millis(25);

/// A connection to one shard server.
pub struct ShardClient {
    sock: TcpStream,
    reader: FrameReader,
    addr: String,
    reply_timeout: Duration,
}

impl ShardClient {
    /// Connect to a shard at `addr` (`host:port`).
    pub fn connect(addr: &str, reply_timeout: Duration) -> Result<ShardClient> {
        let sock =
            TcpStream::connect(addr).with_context(|| format!("connecting to shard {addr}"))?;
        let _ = sock.set_nodelay(true);
        sock.set_read_timeout(Some(POLL_INTERVAL)).context("setting read timeout")?;
        Ok(ShardClient { sock, reader: FrameReader::new(), addr: addr.to_string(), reply_timeout })
    }

    /// The address this client dialed.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One request/reply round trip. `Err` means the transport failed;
    /// a shard-reported failure arrives as `Ok(Reply::Error { .. })`.
    pub fn request(&mut self, req: &Request) -> Result<Reply> {
        self.request_with(req, None)
    }

    /// Like [`request`](ShardClient::request), but a draw reply's storage
    /// comes from `pool` (the router's recycled reply buffers).
    pub(crate) fn request_pooled(&mut self, req: &Request, pool: &BufferPool) -> Result<Reply> {
        self.request_with(req, Some(pool))
    }

    fn request_with(&mut self, req: &Request, pool: Option<&BufferPool>) -> Result<Reply> {
        let (verb, payload) = req.encode();
        write_frame(&mut self.sock, verb, &payload)
            .with_context(|| format!("sending to shard {}", self.addr))?;
        let (rverb, rpayload) =
            read_frame_blocking(&mut self.sock, &mut self.reader, self.reply_timeout)
                .with_context(|| format!("awaiting reply from shard {}", self.addr))?;
        match pool {
            Some(pool) => Reply::decode_pooled(rverb, &rpayload, pool),
            None => Reply::decode(rverb, &rpayload),
        }
    }

    /// Register (or re-attach) a named stream; returns the shard-local
    /// stream id and the stream's transform.
    pub fn register(&mut self, name: &str, config: StreamConfig) -> Result<(u64, Transform)> {
        match self.request(&Request::Register { name: name.to_string(), config })? {
            Reply::Registered { id, transform } => Ok((id, transform)),
            Reply::Error { message } => bail!("shard {}: {message}", self.addr),
            other => bail!("shard {}: unexpected reply {other:?} to register", self.addr),
        }
    }

    /// Draw `n` elements from a registered stream (untraced; the router
    /// threads its trace id through [`request`](ShardClient::request)
    /// directly).
    pub fn draw(&mut self, id: u64, n: usize) -> Result<Draws> {
        match self.request(&Request::Draw { id, n: n as u64, trace: None })? {
            Reply::Draws(d) if d.len() == n => Ok(d),
            Reply::Draws(d) => bail!("shard {}: short draw ({} of {n})", self.addr, d.len()),
            Reply::Error { message } => bail!("shard {}: {message}", self.addr),
            other => bail!("shard {}: unexpected reply {other:?} to draw", self.addr),
        }
    }

    /// Fetch the shard's metrics snapshot as a JSON string.
    pub fn stats(&mut self) -> Result<String> {
        match self.request(&Request::Stats)? {
            Reply::Stats { json } => Ok(json),
            Reply::Error { message } => bail!("shard {}: {message}", self.addr),
            other => bail!("shard {}: unexpected reply {other:?} to stats", self.addr),
        }
    }

    /// Fetch the shard's full labeled exposition (global snapshot plus
    /// per-stream / per-worker / per-shard families) as a JSON string —
    /// the `metrics` wire verb.
    pub fn metrics_json(&mut self) -> Result<String> {
        match self.request(&Request::Metrics)? {
            Reply::MetricsJson { json } => Ok(json),
            Reply::Error { message } => bail!("shard {}: {message}", self.addr),
            other => bail!("shard {}: unexpected reply {other:?} to metrics", self.addr),
        }
    }

    /// Renew the shard's lease (health probe); returns the lease epoch.
    pub fn renew(&mut self, shard: u64) -> Result<u64> {
        match self.request(&Request::Renew { shard })? {
            Reply::Renewed { epoch, .. } => Ok(epoch),
            Reply::Error { message } => bail!("shard {}: {message}", self.addr),
            other => bail!("shard {}: unexpected reply {other:?} to renew", self.addr),
        }
    }

    /// Ask the shard to drain and exit.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.request(&Request::Shutdown)? {
            Reply::ShuttingDown => Ok(()),
            Reply::Error { message } => bail!("shard {}: {message}", self.addr),
            other => bail!("shard {}: unexpected reply {other:?} to shutdown", self.addr),
        }
    }
}

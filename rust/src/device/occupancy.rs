//! CUDA occupancy calculation — which resource (blocks, registers, shared
//! memory, threads) limits how many warps are resident per MP.
//!
//! This is the quantitative heart of the paper's §4 discussion: per-block
//! parameter tables (MTGP-style) increase the shared-memory footprint,
//! reduce resident blocks, and hence occupancy — the reason xorgensGP uses
//! one shared parameter set.

use super::profiles::DeviceProfile;

/// Resources one kernel instance (block) consumes.
#[derive(Clone, Copy, Debug)]
pub struct KernelResources {
    pub threads_per_block: u32,
    pub registers_per_thread: u32,
    pub shared_mem_per_block: u32,
}

/// Occupancy result.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Occupancy {
    pub blocks_per_mp: u32,
    pub active_threads: u32,
    pub active_warps: u32,
    /// active_warps / max_warps.
    pub fraction: f64,
    /// Which limit bound (for reporting).
    pub limiter: Limiter,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Limiter {
    Blocks,
    Threads,
    Registers,
    SharedMem,
}

/// Compute occupancy of `k` on `dev`.
pub fn occupancy(dev: &DeviceProfile, k: &KernelResources) -> Occupancy {
    assert!(k.threads_per_block > 0);
    let by_blocks = dev.max_blocks_per_mp;
    let by_threads = dev.max_threads_per_mp / k.threads_per_block;
    let regs_per_block = k.registers_per_thread * k.threads_per_block;
    let by_regs =
        if regs_per_block == 0 { u32::MAX } else { dev.registers_per_mp / regs_per_block };
    let by_shared = if k.shared_mem_per_block == 0 {
        u32::MAX
    } else {
        dev.shared_mem_per_mp / k.shared_mem_per_block
    };
    let blocks = by_blocks.min(by_threads).min(by_regs).min(by_shared);
    let limiter = if blocks == by_shared && k.shared_mem_per_block > 0 {
        Limiter::SharedMem
    } else if blocks == by_regs && regs_per_block > 0 {
        Limiter::Registers
    } else if blocks == by_threads {
        Limiter::Threads
    } else {
        Limiter::Blocks
    };
    let active_threads = blocks * k.threads_per_block;
    let active_warps = active_threads.div_ceil(dev.warp_size);
    let max_warps = dev.max_threads_per_mp / dev.warp_size;
    Occupancy {
        blocks_per_mp: blocks,
        active_threads,
        active_warps,
        fraction: active_warps as f64 / max_warps as f64,
        limiter,
    }
}

#[cfg(test)]
mod tests {
    use super::super::profiles::{GTX_295, GTX_480};
    use super::*;

    #[test]
    fn unconstrained_kernel_hits_block_limit() {
        let k = KernelResources {
            threads_per_block: 64,
            registers_per_thread: 8,
            shared_mem_per_block: 0,
        };
        let o = occupancy(&GTX_480, &k);
        assert_eq!(o.blocks_per_mp, 8);
        assert_eq!(o.limiter, Limiter::Blocks);
    }

    #[test]
    fn register_pressure_limits_gt200() {
        // 20 regs × 256 threads = 5120 regs/block; GT200: 16384/5120 = 3 blocks.
        let k = KernelResources {
            threads_per_block: 256,
            registers_per_thread: 20,
            shared_mem_per_block: 0,
        };
        let o = occupancy(&GTX_295, &k);
        assert_eq!(o.blocks_per_mp, 3);
        assert_eq!(o.limiter, Limiter::Registers);
        // Fermi's doubled register file fits 6.
        let o480 = occupancy(&GTX_480, &k);
        assert_eq!(o480.blocks_per_mp, 6);
    }

    #[test]
    fn shared_memory_limits_mtgp_style() {
        // MTGP-like: 4 KiB shared per block on GT200 (16 KiB) -> 4 blocks.
        let k = KernelResources {
            threads_per_block: 128,
            registers_per_thread: 14,
            shared_mem_per_block: 4096,
        };
        let o = occupancy(&GTX_295, &k);
        assert_eq!(o.blocks_per_mp, 4);
        assert_eq!(o.limiter, Limiter::SharedMem);
    }

    #[test]
    fn paper_section4_ablation_parameter_tables_cost_occupancy() {
        // §4: storing per-block parameter tables (say +1 KiB shared/block)
        // must reduce blocks/occupancy on the 16 KiB device.
        let shared_params = KernelResources {
            threads_per_block: 64,
            registers_per_thread: 10,
            shared_mem_per_block: 516,
        };
        let perblock_params = KernelResources {
            threads_per_block: 64,
            registers_per_thread: 14,
            shared_mem_per_block: 516 + 1024,
        };
        let a = occupancy(&GTX_295, &shared_params);
        let b = occupancy(&GTX_295, &perblock_params);
        assert!(b.fraction <= a.fraction);
    }

    #[test]
    fn fraction_bounded() {
        let k = KernelResources {
            threads_per_block: 1024,
            registers_per_thread: 63,
            shared_mem_per_block: 49152,
        };
        for dev in [&GTX_480, &GTX_295] {
            let o = occupancy(dev, &k);
            assert!(o.fraction >= 0.0 && o.fraction <= 1.0);
        }
    }
}

//! Throughput prediction: occupancy × issue rates × per-output op mix.
//!
//! `RN/s ≈ efficiency · occupancy · MPs · clock / cycles_per_output`, where
//! `cycles_per_output` charges each op class at the device's issue rate,
//! capped by the memory-bandwidth store bound. The per-generator op mixes
//! below are counted directly from the kernel inner loops in
//! `rust/src/prng/` / `python/compile/kernels/`.

use super::occupancy::{occupancy, KernelResources};
use super::profiles::DeviceProfile;
use crate::prng::GeneratorKind;

/// Per-output instruction mix and per-block resources of a generator kernel.
#[derive(Clone, Copy, Debug)]
pub struct GeneratorKernelProfile {
    pub kind: GeneratorKind,
    /// Logical/arithmetic int ops per output (xor, and, or, add).
    pub int_ops: f64,
    /// Shift ops per output.
    pub shift_ops: f64,
    /// Shared-memory 32-bit accesses per output (loads + stores).
    pub shared_accesses: f64,
    /// Barrier synchronisations per output (amortised over the lane width).
    pub syncs: f64,
    /// Local-memory 32-bit accesses per output (state kept per-thread
    /// outside shared memory — CURAND's model).
    pub local_accesses: f64,
    /// Kernel launch resources.
    pub resources: KernelResources,
}

impl GeneratorKernelProfile {
    /// xorgensGP (paper §2): per output — t,v: 2 shifts + 2 xors each;
    /// combine 1 xor; Weyl add; (w ^ w>>16) 1 shift + 1 xor; final add.
    /// State in shared memory: 2 loads + 1 store; 129 words/block; one
    /// barrier per 63-output round. 64 threads/block (63 active lanes).
    pub fn xorgens_gp() -> Self {
        GeneratorKernelProfile {
            kind: GeneratorKind::XorgensGp,
            int_ops: 8.0,
            shift_ops: 5.0,
            shared_accesses: 3.0,
            syncs: 1.0 / 63.0,
            local_accesses: 0.0,
            resources: KernelResources {
                threads_per_block: 64,
                registers_per_thread: 10,
                shared_mem_per_block: 129 * 4 + 8, // state + index/weyl spill
            },
        }
    }

    /// MTGP (paper §1.3): twist (mask/xor/shift chain + table lookup) +
    /// tempering (two shift-mask-xor rounds + table lookup). Heavier shared
    /// traffic (3 state loads + 2 table lookups + 1 store). 1024-word
    /// shared buffer (Table 1's footprint = state padded to a power of two
    /// plus parameter tables); 256 threads/block; barrier per 227-output
    /// round.
    pub fn mtgp() -> Self {
        GeneratorKernelProfile {
            kind: GeneratorKind::Mtgp,
            int_ops: 9.0,
            shift_ops: 5.0,
            shared_accesses: 6.0,
            syncs: 1.0 / 227.0,
            local_accesses: 0.0,
            resources: KernelResources {
                threads_per_block: 256,
                registers_per_thread: 14,
                shared_mem_per_block: 1024 * 4,
            },
        }
    }

    /// CURAND/XORWOW (paper §1.4): 6-word state entirely in registers — no
    /// shared memory, no barriers; ~7 logical + 2 adds, 3 shifts per
    /// output. CURAND's generator state + stack runs ~20 registers/thread
    /// (the Fermi-oriented design the paper mentions: fine on GF100's 32k
    /// register file, constraining on GT200's 16k).
    pub fn xorwow() -> Self {
        GeneratorKernelProfile {
            kind: GeneratorKind::Xorwow,
            int_ops: 9.0,
            shift_ops: 3.0,
            shared_accesses: 0.0,
            syncs: 0.0,
            local_accesses: 12.0, // 6-word state read+written per output
            resources: KernelResources {
                threads_per_block: 256,
                registers_per_thread: 20,
                shared_mem_per_block: 0,
            },
        }
    }

    pub fn for_kind(kind: GeneratorKind) -> Self {
        match kind {
            GeneratorKind::XorgensGp | GeneratorKind::Xorgens => Self::xorgens_gp(),
            GeneratorKind::Mtgp | GeneratorKind::Mt19937 => Self::mtgp(),
            GeneratorKind::Xorwow => Self::xorwow(),
        }
    }
}

/// Predict RN/s for a generator kernel on a device.
///
/// `rate = efficiency / C_total` outputs per MP-clock, where `C_total`
/// charges: int ops and shifts at the device issue rates, shared-memory
/// accesses at the bank rate, local-memory traffic at the per-arch cost
/// (L1 vs DRAM), and barriers amortised over lane width and resident
/// blocks. These kernels are issue-bound at the occupancies the paper's
/// launch shapes achieve (every profile clears ~1/3 occupancy, enough to
/// saturate the integer pipes), so occupancy enters through the
/// blocks-per-MP sync amortisation rather than a latency-hiding factor.
pub fn predict_rn_per_sec(dev: &DeviceProfile, prof: &GeneratorKernelProfile) -> f64 {
    let occ = occupancy(dev, &prof.resources);
    let cycles_per_output = prof.int_ops / dev.int_ops_per_clock_mp
        + prof.shift_ops / dev.shift_ops_per_clock_mp
        + prof.shared_accesses / dev.shared_acc_per_clock_mp
        + prof.local_accesses * dev.local_access_cycles
        + prof.syncs * dev.sync_cycles / (occ.blocks_per_mp.max(1) as f64);
    let rate_per_mp_clock = dev.efficiency / cycles_per_output;
    let compute_bound =
        rate_per_mp_clock * dev.multiprocessors as f64 * dev.shader_clock_mhz as f64 * 1e6;
    compute_bound.min(dev.store_rate_per_sec())
}

/// Paper Table 1 reference values (RN/s) for comparison in reports.
pub fn paper_table1_rn_per_sec(kind: GeneratorKind, dev: &DeviceProfile) -> Option<f64> {
    let is480 = dev.name.contains("480");
    match kind {
        GeneratorKind::XorgensGp => Some(if is480 { 7.7e9 } else { 9.1e9 }),
        GeneratorKind::Mtgp => Some(if is480 { 7.5e9 } else { 10.7e9 }),
        GeneratorKind::Xorwow => Some(if is480 { 8.5e9 } else { 7.1e9 }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::super::profiles::{GTX_295, GTX_480};
    use super::*;

    fn all_profiles() -> [GeneratorKernelProfile; 3] {
        [
            GeneratorKernelProfile::xorgens_gp(),
            GeneratorKernelProfile::mtgp(),
            GeneratorKernelProfile::xorwow(),
        ]
    }

    #[test]
    fn predictions_in_paper_magnitude() {
        // Every prediction within 2x of the paper's value (Table 1 states
        // the differences are small; we require the magnitude to match).
        for dev in [&GTX_480, &GTX_295] {
            for p in all_profiles() {
                let pred = predict_rn_per_sec(dev, &p);
                let paper = paper_table1_rn_per_sec(p.kind, dev).unwrap();
                let ratio = pred / paper;
                assert!(
                    (0.5..2.0).contains(&ratio),
                    "{} on {}: pred {pred:.3e} vs paper {paper:.3e}",
                    p.kind,
                    dev.name
                );
            }
        }
    }

    #[test]
    fn paper_orderings_reproduced() {
        // GTX 480: CURAND fastest, MTGP slowest. GTX 295: MTGP fastest,
        // CURAND slowest (paper §3).
        let r480: Vec<f64> =
            all_profiles().iter().map(|p| predict_rn_per_sec(&GTX_480, p)).collect();
        let (xg, mt, xw) = (r480[0], r480[1], r480[2]);
        assert!(xw > xg && xg > mt, "GTX480 ordering: xg={xg:.3e} mt={mt:.3e} xw={xw:.3e}");
        let r295: Vec<f64> =
            all_profiles().iter().map(|p| predict_rn_per_sec(&GTX_295, p)).collect();
        let (xg, mt, xw) = (r295[0], r295[1], r295[2]);
        assert!(mt > xg && xg > xw, "GTX295 ordering: xg={xg:.3e} mt={mt:.3e} xw={xw:.3e}");
    }

    #[test]
    fn no_generator_breaks_bandwidth_bound() {
        for dev in [&GTX_480, &GTX_295] {
            for p in all_profiles() {
                assert!(predict_rn_per_sec(dev, &p) <= dev.store_rate_per_sec());
            }
        }
    }
}

//! Analytical GPU device model (the reproduction's stand-in for the paper's
//! GTX 480 / GTX 295 testbed — see DESIGN.md §Hardware-Adaptation).
//!
//! The paper's Table 1 reports RN/s for three generators on two devices.
//! Without the hardware, we regenerate those columns from a mechanistic
//! model with three ingredients:
//!
//! 1. **Device profiles** ([`profiles`]) — public die specs of the GTX 480
//!    (Fermi GF100) and one GPU of the GTX 295 (GT200b).
//! 2. **Occupancy** ([`occupancy`]) — the CUDA occupancy calculation from
//!    block/register/shared-memory limits; this is where the generators'
//!    different footprints (Table 1's State-Space column) bite.
//! 3. **Instruction cost** ([`model`]) — per-output op mixes of each
//!    generator kernel, issued at per-architecture rates.
//!
//! The model is calibrated with a single per-architecture efficiency
//! constant (fit once against the paper's Table 1, see EXPERIMENTS.md);
//! orderings and ratios then *emerge* from occupancy + op mixes.

pub mod model;
pub mod occupancy;
pub mod profiles;

pub use model::{predict_rn_per_sec, GeneratorKernelProfile};
pub use occupancy::{occupancy, KernelResources, Occupancy};
pub use profiles::{DeviceProfile, GTX_295, GTX_480};

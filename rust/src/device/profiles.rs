//! Device profiles for the paper's two test devices (public specifications).

/// Static description of a CUDA device (one GPU die).
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub multiprocessors: u32,
    /// CUDA cores (scalar ALUs) per MP.
    pub cores_per_mp: u32,
    /// Shader clock in MHz (CUDA cores run at the shader clock).
    pub shader_clock_mhz: u32,
    /// Shared memory per MP in bytes (the per-block state arrays live here).
    pub shared_mem_per_mp: u32,
    /// 32-bit registers per MP.
    pub registers_per_mp: u32,
    /// Hardware cap on resident threads per MP.
    pub max_threads_per_mp: u32,
    /// Hardware cap on resident blocks per MP.
    pub max_blocks_per_mp: u32,
    /// Warp size (threads issued together).
    pub warp_size: u32,
    /// Device memory bandwidth, GB/s.
    pub mem_bandwidth_gbs: f64,
    /// Integer-op issue rate per MP per shader-clock cycle
    /// (logical/add; Fermi issues 32-wide, GT200 8-wide).
    pub int_ops_per_clock_mp: f64,
    /// Shift issue rate per MP per clock (GT200 and GF100 both shift at a
    /// reduced rate relative to logical ops).
    pub shift_ops_per_clock_mp: f64,
    /// Shared-memory 32-bit accesses per MP per clock (bank count).
    pub shared_acc_per_clock_mp: f64,
    /// Cost (cycles per MP) of one 32-bit local-memory access: Fermi backs
    /// local memory with L1 (cheap); GT200 spills to DRAM (expensive).
    /// This is what penalises CURAND's register/local-heavy state on the
    /// GTX 295 (paper §3's "designed for Fermi").
    pub local_access_cycles: f64,
    /// Barrier cost in cycles (pipeline drain + shared-memory turnaround;
    /// much costlier on GT200's shallow 8-wide SMs).
    pub sync_cycles: f64,
    /// Calibrated pipeline-efficiency factor (fraction of peak issue
    /// sustained by these memory-light kernels; fit once per architecture
    /// against paper Table 1 — see EXPERIMENTS.md §T1).
    pub efficiency: f64,
}

/// NVIDIA GeForce GTX 480 — Fermi GF100, CUDA compute capability 2.0.
pub const GTX_480: DeviceProfile = DeviceProfile {
    name: "GTX 480",
    multiprocessors: 15,
    cores_per_mp: 32,
    shader_clock_mhz: 1401,
    shared_mem_per_mp: 48 * 1024,
    registers_per_mp: 32768,
    max_threads_per_mp: 1536,
    max_blocks_per_mp: 8,
    warp_size: 32,
    mem_bandwidth_gbs: 177.4,
    int_ops_per_clock_mp: 32.0,
    shift_ops_per_clock_mp: 16.0, // GF100 shifts at half rate
    shared_acc_per_clock_mp: 32.0, // 32 banks
    local_access_cycles: 0.005,    // local memory hits Fermi's L1
    sync_cycles: 40.0,
    efficiency: 0.269,
};

/// One GPU of the NVIDIA GeForce GTX 295 — GT200b, compute capability 1.3.
pub const GTX_295: DeviceProfile = DeviceProfile {
    name: "GTX 295 (one GPU)",
    multiprocessors: 30,
    cores_per_mp: 8,
    shader_clock_mhz: 1242,
    shared_mem_per_mp: 16 * 1024,
    registers_per_mp: 16384,
    max_threads_per_mp: 1024,
    max_blocks_per_mp: 8,
    warp_size: 32,
    mem_bandwidth_gbs: 111.9,
    int_ops_per_clock_mp: 8.0,
    shift_ops_per_clock_mp: 8.0, // GT200 full-rate shifts on the SP pipe
    shared_acc_per_clock_mp: 16.0, // 16 banks
    local_access_cycles: 0.153,    // no cache: local memory is DRAM
    sync_cycles: 400.0,
    efficiency: 0.636,
};

impl DeviceProfile {
    /// Peak integer throughput in Gop/s (logical ops).
    pub fn peak_int_gops(&self) -> f64 {
        self.multiprocessors as f64
            * self.int_ops_per_clock_mp
            * self.shader_clock_mhz as f64
            * 1e-3
    }

    /// Peak 4-byte store rate from memory bandwidth (upper bound on RN/s
    /// for any generator writing its output to device memory).
    pub fn store_rate_per_sec(&self) -> f64 {
        self.mem_bandwidth_gbs * 1e9 / 4.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_sane() {
        // Core counts: 15*32 = 480 (the "480" in GTX 480), 30*8 = 240.
        assert_eq!(GTX_480.multiprocessors * GTX_480.cores_per_mp, 480);
        assert_eq!(GTX_295.multiprocessors * GTX_295.cores_per_mp, 240);
        assert!(GTX_480.peak_int_gops() > GTX_295.peak_int_gops());
    }

    #[test]
    fn memory_bound_exceeds_paper_rates() {
        // Table 1's rates (7-11 G RN/s) must sit below the 4-byte store
        // bound, else the model premise (compute-bound) is wrong.
        assert!(GTX_480.store_rate_per_sec() > 11e9);
        assert!(GTX_295.store_rate_per_sec() > 11e9);
    }
}

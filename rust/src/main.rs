//! xorgensgp — CLI for the reproduction.
//!
//! Subcommands:
//!   gen        draw numbers from any generator/backend to stdout or a file
//!   battery    run the crushr tiers (regenerates paper Table 2)
//!   bench      throughput + footprint report (regenerates paper Table 1)
//!   occupancy  device-model occupancy report (+ §4 parameter-set ablation)
//!   serve      run the coordinator with a synthetic client load, or (with
//!              --listen) as a cluster shard server speaking the wire protocol
//!   route      drive a shard cluster through the router (bit-identical to
//!              a single local coordinator)
//!   stats      scrape a running serve/shard's metrics endpoint (one-shot
//!              or --watch)
//!   trace      dump the structured span journal (local, or from a
//!              --metrics-addr endpoint)
//!   golden     dump cross-language golden vectors to tests/golden/
//!   selftest   quick end-to-end smoke of all layers
//!   params-search   exhaustive small-parameter search (Brent's procedure)

use xorgens_gp::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use xorgens_gp::device::{occupancy, GeneratorKernelProfile, GTX_295, GTX_480};
use xorgens_gp::prng::{make_block_generator, make_generator, GeneratorKind, Prng32};
use xorgens_gp::runtime::Transform;
use xorgens_gp::testu01::battery::{
    run_battery, run_battery_interleaved, run_battery_leapfrog, run_battery_placed, Tier,
};
use xorgens_gp::util::cli::Args;
use xorgens_gp::util::error::{bail, Error, Result};
use xorgens_gp::util::json::Json;
use xorgens_gp::{anyhow, ensure};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("gen") => cmd_gen(&args),
        Some("battery") => cmd_battery(&args),
        Some("bench") => cmd_bench(&args),
        Some("occupancy") => cmd_occupancy(&args),
        Some("serve") => cmd_serve(&args),
        Some("route") => cmd_route(&args),
        Some("stats") => cmd_stats(&args),
        Some("trace") => cmd_trace(&args),
        Some("golden") => cmd_golden(&args),
        Some("selftest") => cmd_selftest(&args),
        Some("params-search") => cmd_params_search(&args),
        Some("jump") => cmd_jump(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "xorgensgp — reproduction of 'High-Performance PRNG on GPUs' (Nandapalan et al. 2011)\n\
         \n\
         usage: xorgensgp <subcommand> [--options]\n\
         \n\
         gen        --gen xorgensgp|mtgp|xorwow|xorgens|mt19937 --n N [--seed S]\n\
         \u{20}          [--backend rust|pjrt] [--format u32|f32|hex] [--out FILE]\n\
         battery    --tier small|crush|big [--gen NAME|all] [--seed S] [--verbose]\n\
         \u{20}          [--interleaved-blocks B] [--weak-init] [--strict]\n\
         \u{20}          [--exact-substreams K [--spacing LOG2]]   (placed-substream probe)\n\
         \u{20}          [--leapfrog-blocks B]   (leapfrog-dealt placement probe)\n\
         \u{20}          [--threads T]   (parallel fill engine; output is bit-identical)\n\
         \u{20}          [--stats-json]   (machine-readable report on stdout)\n\
         bench      [--n N] [--gen NAME|all] [--table1] [--footprint]\n\
         \u{20}          [--threads T]   (adds a threaded fill column + efficiency)\n\
         \u{20}          [--pool]   (adds a persistent-worker-pool fill column)\n\
         \u{20}          [--simd auto|scalar|sse2|avx2|neon]   (force the fill kernel;\n\
         \u{20}           output is bit-identical for every choice)\n\
         occupancy  [--compare-paramsets]\n\
         serve      [--clients C] [--draws D] [--n N] [--backend rust|pjrt]\n\
         \u{20}          [--placement seed-mix|exact-jump[:LOG2]|leapfrog]\n\
         \u{20}          [--fill-threads T | --pool-threads T]   (parallel fill engine)\n\
         \u{20}          [--prefetch [D]] [--pin-cores]   (generation-ahead depth,\n\
         \u{20}           bare --prefetch means 1; pin pool workers to cores)\n\
         \u{20}          [--simd auto|scalar|sse2|avx2|neon]   (force the SIMD fill\n\
         \u{20}           kernel; also the XORGENSGP_SIMD env var — bit-identical)\n\
         \u{20}          [--listen ADDR --shard-id J [--lease-ttl-ms MS] [--root-seed S]\n\
         \u{20}           [--max-connections C]]\n\
         \u{20}          (cluster shard mode: coordinator behind the wire protocol,\n\
         \u{20}           substream slots leased as J*2^32 ..)\n\
         \u{20}          [--metrics-addr HOST:PORT]   (HTTP scrape endpoint: /metrics\n\
         \u{20}           Prometheus text, /metrics.json, /trace?last=N — both modes)\n\
         route      --shards HOST:PORT,HOST:PORT,… [--clients C] [--draws D] [--n N]\n\
         \u{20}          [--placement P] [--root-seed S] [--stats-json] [--shutdown]\n\
         \u{20}          [--metrics-json]   (per-shard labeled exposition, metrics verb)\n\
         \u{20}          (drive a shard cluster; output bit-identical to one coordinator)\n\
         stats      --addr HOST:PORT [--json] [--watch [SECS]]\n\
         \u{20}          (scrape a --metrics-addr endpoint; --watch re-scrapes forever)\n\
         trace      [--last N] [--addr HOST:PORT]\n\
         \u{20}          (span-journal timeline; --addr reads a remote /trace endpoint)\n\
         golden     [--out DIR]\n\
         selftest\n\
         params-search --r R --s S [--limit K]\n\
         jump       --k K [--gen NAME] [--seed S]   (polynomial jump-ahead, any kind)"
    );
}

/// Shared pool knobs for `serve` (both modes): `--pool-threads T`
/// overrides `--fill-threads`, `--prefetch [D]` sets generation-ahead
/// depth (bare flag means 1), `--pin-cores` pins pool workers.
fn apply_pool_flags(args: &Args, cfg: &mut CoordinatorConfig) -> Result<()> {
    if let Some(t) = args.opt_parse::<usize>("pool-threads").map_err(Error::msg)? {
        ensure!(t >= 1, "--pool-threads must be at least 1");
        cfg.fill_threads = t;
    }
    cfg.prefetch = if args.flag("prefetch") {
        1
    } else {
        args.opt_parse_or("prefetch", cfg.prefetch).map_err(Error::msg)?
    };
    if args.flag("pin-cores") {
        cfg.pin_fill_workers = true;
    }
    Ok(())
}

/// `--metrics-addr HOST:PORT`: hang the HTTP scrape listener off a
/// coordinator's exposition. Returns the running server (kept alive by
/// the caller for the duration of the load) or `None` when the flag is
/// absent.
fn maybe_metrics_server(
    args: &Args,
    coord: &std::sync::Arc<Coordinator>,
) -> Result<Option<xorgens_gp::obs::MetricsServer>> {
    use xorgens_gp::obs::{MetricsServer, ScrapeHandlers};
    let Some(addr) = args.opt("metrics-addr") else { return Ok(None) };
    let c1 = std::sync::Arc::clone(coord);
    let c2 = std::sync::Arc::clone(coord);
    let server = MetricsServer::bind(
        addr,
        ScrapeHandlers {
            prometheus: Box::new(move || c1.exposition().to_prometheus()),
            json: Box::new(move || c2.exposition().to_json().to_string()),
        },
    )?;
    println!(
        "metrics on http://{0}/metrics (also /metrics.json, /trace?last=N)",
        server.addr()
    );
    Ok(Some(server))
}

/// `--simd auto|scalar|sse2|avx2|neon`: force the process-wide SIMD fill
/// kernel ([`xorgens_gp::simd`]). Output is bit-identical for every
/// choice; an unavailable kernel clamps to the widest detected one with
/// a warning. Without the flag the env var / auto-detection stands.
/// Returns the kernel now active, for the summary line.
fn apply_simd_flag(args: &Args) -> Result<xorgens_gp::simd::SimdKernel> {
    use xorgens_gp::simd::{self, KernelChoice};
    Ok(match args.opt_parse::<KernelChoice>("simd").map_err(Error::msg)? {
        Some(choice) => simd::set_forced(choice),
        None => simd::active_kernel(),
    })
}

fn parse_kind(args: &Args) -> Result<GeneratorKind> {
    // FromStr wiring: bad values surface the typed ParseEnumError message
    // (what was parsed, what is accepted) through the generic CLI path.
    args.opt_parse_or("gen", GeneratorKind::XorgensGp).map_err(Error::msg)
}

fn parse_backend(args: &Args) -> Result<BackendKind> {
    args.opt_parse_or("backend", BackendKind::Rust).map_err(Error::msg)
}

fn cmd_gen(args: &Args) -> Result<()> {
    let kind = parse_kind(args)?;
    let n: usize = args.opt_parse_or("n", 16).map_err(Error::msg)?;
    let seed: u64 = args.opt_parse_or("seed", 20260710).map_err(Error::msg)?;
    let backend = parse_backend(args)?;
    let format = args.opt_or("format", "u32");
    let mut buf = vec![0u32; n];
    match backend {
        BackendKind::Rust => {
            let mut g = make_generator(kind, seed);
            g.fill_u32(&mut buf);
        }
        BackendKind::Pjrt => {
            let mut be = xorgens_gp::coordinator::PjrtBackend::best(
                &xorgens_gp::runtime::default_dir(),
                kind,
                Transform::U32,
                seed,
            )?;
            let mut got = 0;
            while got < n {
                use xorgens_gp::coordinator::{Backend, Draws};
                let Draws::U32(v) = be.launch()? else { bail!("expected u32") };
                let take = (n - got).min(v.len());
                buf[got..got + take].copy_from_slice(&v[..take]);
                got += take;
            }
        }
    }
    let mut out = String::new();
    for (i, x) in buf.iter().enumerate() {
        match format.as_str() {
            "u32" => out.push_str(&x.to_string()),
            "hex" => out.push_str(&format!("{x:08x}")),
            "f32" => out
                .push_str(&format!("{}", xorgens_gp::prng::distributions::unit_f32(*x))),
            other => bail!("unknown format {other:?}"),
        }
        out.push(if (i + 1) % 8 == 0 { '\n' } else { ' ' });
    }
    match args.opt("out") {
        Some(path) => std::fs::write(path, out)?,
        None => print!("{out}"),
    }
    Ok(())
}

fn cmd_battery(args: &Args) -> Result<()> {
    // Tier parses through the typed FromStr path, like --gen/--backend.
    let tier: Tier = args.opt_parse_or("tier", Tier::Small).map_err(Error::msg)?;
    let seed: u64 = args.opt_parse_or("seed", 20260710).map_err(Error::msg)?;
    let verbose = args.flag("verbose");
    let strict = args.flag("strict");
    let gen_arg = args.opt_or("gen", "all");
    let kinds: Vec<GeneratorKind> = if gen_arg == "all" {
        GeneratorKind::PAPER_SET.to_vec()
    } else {
        vec![gen_arg.parse()?]
    };
    let interleaved: Option<usize> =
        args.opt_parse("interleaved-blocks").map_err(Error::msg)?;
    let exact_substreams: Option<usize> =
        args.opt_parse("exact-substreams").map_err(Error::msg)?;
    let spacing: u32 = args.opt_parse_or("spacing", 64).map_err(Error::msg)?;
    ensure!(
        spacing <= xorgens_gp::prng::Placement::MAX_LOG2_SPACING,
        "--spacing {spacing} exceeds the maximum log2 spacing {}",
        xorgens_gp::prng::Placement::MAX_LOG2_SPACING
    );
    ensure!(
        exact_substreams != Some(0),
        "--exact-substreams must be at least 1"
    );
    ensure!(
        args.opt("spacing").is_none() || exact_substreams.is_some(),
        "--spacing only applies to the --exact-substreams placed mode"
    );
    let leapfrog: Option<usize> = args.opt_parse("leapfrog-blocks").map_err(Error::msg)?;
    ensure!(leapfrog != Some(0), "--leapfrog-blocks must be at least 1");
    let weak = args.flag("weak-init");
    ensure!(
        exact_substreams.is_none() || (interleaved.is_none() && !weak),
        "--exact-substreams conflicts with --interleaved-blocks/--weak-init \
         (pick one battery mode)"
    );
    ensure!(
        leapfrog.is_none() || (exact_substreams.is_none() && interleaved.is_none() && !weak),
        "--leapfrog-blocks conflicts with the other battery modes (pick one)"
    );
    // Parallel fill engine worker count for the multi-block battery modes
    // (verdicts are bit-identical for every value — the per-block default
    // mode has nothing to partition and ignores it).
    let fill_threads: usize = args.opt_parse_or("threads", 1).map_err(Error::msg)?;
    ensure!(fill_threads >= 1, "--threads must be at least 1");
    let stats_json = args.flag("stats-json");
    if !stats_json {
        println!("=== crushr {} (paper Table 2 regeneration) ===", tier.name());
    }
    let mut cells = Vec::new();
    let mut reports_json = Vec::new();
    let mut total_failures = 0usize;
    for kind in kinds {
        let report = if let Some(blocks) = leapfrog {
            run_battery_leapfrog(tier, kind, seed, blocks, fill_threads)
        } else {
            match (exact_substreams, interleaved) {
                (Some(k), _) => run_battery_placed(tier, kind, seed, k, spacing, fill_threads),
                (None, Some(blocks)) => {
                    run_battery_interleaved(tier, kind, seed, blocks, weak, fill_threads)
                }
                (None, None) => run_battery(tier, kind, seed),
            }
        };
        if stats_json {
            reports_json.push(report.to_json());
        } else {
            print!("{}", report.render(verbose));
        }
        total_failures += report.failures().len();
        cells.push((report.generator.clone(), report.table2_cell()));
    }
    if stats_json {
        // One JSON array on stdout — the scheduled sweep archives this.
        println!("{}", Json::Arr(reports_json).to_string());
    } else {
        println!("\nTable 2 ({}) column:", tier.name());
        for (g, cell) in cells {
            println!("  {g:<24} {cell}");
        }
    }
    if strict && total_failures > 0 {
        bail!("--strict: {total_failures} battery instance(s) failed");
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let n: usize = args.opt_parse_or("n", 100_000_000).map_err(Error::msg)?;
    let simd = apply_simd_flag(args)?;
    println!("simd kernel: {} (width {})", simd.name(), simd.width());
    if args.flag("footprint") || args.flag("table1") {
        table1_report(n)?;
        return Ok(());
    }
    let gen_arg = args.opt_or("gen", "all");
    let kinds: Vec<GeneratorKind> = if gen_arg == "all" {
        GeneratorKind::PAPER_SET.to_vec()
    } else {
        vec![gen_arg.parse()?]
    };
    let threads: usize = args.opt_parse_or("threads", 1).map_err(Error::msg)?;
    ensure!(threads >= 1, "--threads must be at least 1");
    let pool = args.flag("pool");
    for kind in kinds {
        let rate = measure_rate(kind, n, 1);
        println!("{:<12} {:>12.4e} RN/s (measured, rust single-thread)", kind.name(), rate);
        if threads > 1 {
            let par = measure_rate(kind, n, threads);
            println!(
                "{:<12} {:>12.4e} RN/s ({threads} fill threads, {:.2}x, efficiency {:.0}%)",
                kind.name(),
                par,
                par / rate,
                100.0 * par / rate / threads as f64
            );
        }
        if pool {
            let pooled = measure_rate_pooled(kind, n, threads);
            println!(
                "{:<12} {:>12.4e} RN/s (persistent pool, {threads} threads, {:.2}x vs serial)",
                kind.name(),
                pooled,
                pooled / rate,
            );
        }
    }
    Ok(())
}

/// Measured fill rate (the paper's methodology: generate 10^8 numbers
/// repeatedly and time it). `threads > 1` routes through the parallel fill
/// engine — same stream, partitioned blocks.
fn measure_rate(kind: GeneratorKind, n: usize, threads: usize) -> f64 {
    let mut gen = make_block_generator(kind, 1, 64);
    let chunk = 1 << 20;
    let mut buf = vec![0u32; chunk];
    gen.fill_interleaved_threaded(threads, &mut buf); // warmup
    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    while done < n {
        gen.fill_interleaved_threaded(threads, &mut buf);
        done += chunk;
    }
    done as f64 / t0.elapsed().as_secs_f64()
}

/// Same methodology through the persistent worker pool instead of the
/// per-call scoped fan-out (the `--pool` bench column). Output is
/// bit-identical either way; only the dispatch overhead differs.
fn measure_rate_pooled(kind: GeneratorKind, n: usize, threads: usize) -> f64 {
    use xorgens_gp::exec::pool::{FillPool, PoolConfig};
    // The caller participates as part 0, so the pool itself holds T-1
    // workers (floored at 1 to keep a background lane).
    let pool = FillPool::new(PoolConfig {
        workers: threads.saturating_sub(1).max(1),
        pin_cores: false,
    });
    let mut gen = make_block_generator(kind, 1, 64);
    let chunk = 1 << 20;
    let mut buf = vec![0u32; chunk];
    gen.fill_interleaved_pooled(&pool, &mut buf); // warmup
    let t0 = std::time::Instant::now();
    let mut done = 0usize;
    while done < n {
        gen.fill_interleaved_pooled(&pool, &mut buf);
        done += chunk;
    }
    done as f64 / t0.elapsed().as_secs_f64()
}

/// The full Table 1 regeneration: footprint, period, measured CPU rate,
/// and device-model predictions for both paper devices.
fn table1_report(n: usize) -> Result<()> {
    use xorgens_gp::device::model::paper_table1_rn_per_sec;
    use xorgens_gp::device::predict_rn_per_sec;
    println!("=== Table 1 regeneration ===");
    println!(
        "{:<12} {:>12} {:>10} {:>14} {:>22} {:>22}",
        "Generator",
        "State(words)",
        "Period",
        "CPU RN/s",
        "GTX480 RN/s (paper)",
        "GTX295 RN/s (paper)"
    );
    for kind in GeneratorKind::PAPER_SET {
        let gen = make_block_generator(kind, 1, 1);
        let prof = GeneratorKernelProfile::for_kind(kind);
        let rate = measure_rate(kind, n.min(50_000_000), 1);
        let p480 = predict_rn_per_sec(&GTX_480, &prof);
        let p295 = predict_rn_per_sec(&GTX_295, &prof);
        let ref480 = paper_table1_rn_per_sec(kind, &GTX_480).unwrap_or(f64::NAN);
        let ref295 = paper_table1_rn_per_sec(kind, &GTX_295).unwrap_or(f64::NAN);
        println!(
            "{:<12} {:>12} 2^{:<8.0} {:>13.3e} {:>11.2e} ({:>8.2e}) {:>11.2e} ({:>8.2e})",
            kind.name(),
            gen.state_words_per_block(),
            gen.period_log2(),
            rate,
            p480,
            ref480,
            p295,
            ref295,
        );
    }
    Ok(())
}

fn cmd_occupancy(args: &Args) -> Result<()> {
    println!("=== occupancy report (device model) ===");
    for dev in [&GTX_480, &GTX_295] {
        println!("{}:", dev.name);
        for kind in GeneratorKind::PAPER_SET {
            let prof = GeneratorKernelProfile::for_kind(kind);
            let occ = occupancy(dev, &prof.resources);
            println!(
                "  {:<12} blocks/MP={} threads/MP={} occupancy={:.2} (limited by {:?})",
                kind.name(),
                occ.blocks_per_mp,
                occ.active_threads,
                occ.fraction,
                occ.limiter
            );
        }
    }
    if args.flag("compare-paramsets") {
        // Paper §4 ablation: per-block parameter tables cost occupancy.
        println!("\n=== §4 ablation: shared vs per-block parameter sets (xorgensGP) ===");
        let shared = GeneratorKernelProfile::xorgens_gp().resources;
        let mut perblock = shared;
        perblock.shared_mem_per_block += 1024; // parameter tables
        perblock.registers_per_thread += 4; // parameter pointers/indices
        for dev in [&GTX_480, &GTX_295] {
            let a = occupancy(dev, &shared);
            let b = occupancy(dev, &perblock);
            println!(
                "  {:<18} shared-params occupancy={:.2}  per-block-params occupancy={:.2}  \
                 (Δ={:+.0}%)",
                dev.name,
                a.fraction,
                b.fraction,
                100.0 * (b.fraction - a.fraction) / a.fraction
            );
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use xorgens_gp::prng::Placement;
    if let Some(listen) = args.opt("listen") {
        return cmd_serve_shard(args, &listen);
    }
    let clients: usize = args.opt_parse_or("clients", 8).map_err(Error::msg)?;
    let draws: usize = args.opt_parse_or("draws", 100).map_err(Error::msg)?;
    let n: usize = args.opt_parse_or("n", 65536).map_err(Error::msg)?;
    let backend = parse_backend(args)?;
    let placement: Placement =
        args.opt_parse_or("placement", Placement::SeedMix).map_err(Error::msg)?;
    // Default comes from CoordinatorConfig (1, or XORGENSGP_FILL_THREADS).
    let default_cfg = CoordinatorConfig::default();
    let fill_threads: usize =
        args.opt_parse_or("fill-threads", default_cfg.fill_threads).map_err(Error::msg)?;
    ensure!(fill_threads >= 1, "--fill-threads must be at least 1");
    let mut cfg = CoordinatorConfig { fill_threads, ..default_cfg };
    apply_pool_flags(args, &mut cfg)?;
    let simd = apply_simd_flag(args)?;
    let (fill_threads, prefetch) = (cfg.fill_threads, cfg.prefetch);
    let coord = std::sync::Arc::new(Coordinator::new(cfg));
    let _metrics_http = maybe_metrics_server(args, &coord)?;
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let coord = &coord;
            scope.spawn(move || {
                // Typed handle + caller-owned buffer: the steady-state
                // reply path recycles pooled buffers instead of allocating.
                let s = coord
                    .builder(&format!("client-{c}"))
                    .backend(backend)
                    .placement(placement)
                    .u32()
                    .expect("stream");
                let mut buf = vec![0u32; n];
                for _ in 0..draws {
                    s.draw_into(&mut buf).expect("draw");
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "served {} numbers in {:.2}s = {:.3e} RN/s (fill threads: {fill_threads}, prefetch: {prefetch}, simd: {simd})",
        m.numbers_served,
        dt,
        m.numbers_served as f64 / dt
    );
    println!("{}", m.render());
    Ok(())
}

/// `serve --listen ADDR --shard-id J`: run one cluster shard — a
/// coordinator behind the wire protocol, its substream slots leased as
/// `J·2^32 ..` so exact-jump placement cannot collide with any other
/// shard's.
fn cmd_serve_shard(args: &Args, listen: &str) -> Result<()> {
    use xorgens_gp::cluster::{shard_slot_range, ShardServer, ShardServerConfig};
    let shard_id: u64 = args.opt_parse_or("shard-id", 0).map_err(Error::msg)?;
    let lease_ttl_ms: u64 = args.opt_parse_or("lease-ttl-ms", 10_000).map_err(Error::msg)?;
    ensure!(lease_ttl_ms >= 1, "--lease-ttl-ms must be at least 1");
    let default_cfg = CoordinatorConfig::default();
    let fill_threads: usize =
        args.opt_parse_or("fill-threads", default_cfg.fill_threads).map_err(Error::msg)?;
    ensure!(fill_threads >= 1, "--fill-threads must be at least 1");
    // Placement is bit-identical across the cluster only when every shard
    // (and the router) agrees on the root seed.
    let root_seed: u64 =
        args.opt_parse_or("root-seed", default_cfg.root_seed).map_err(Error::msg)?;
    let mut coord_cfg = CoordinatorConfig { root_seed, fill_threads, ..default_cfg };
    apply_pool_flags(args, &mut coord_cfg)?;
    apply_simd_flag(args)?;
    let max_connections: usize = args.opt_parse_or("max-connections", 64).map_err(Error::msg)?;
    ensure!(max_connections >= 1, "--max-connections must be at least 1");
    let slots = shard_slot_range(shard_id)?;
    let server = ShardServer::bind(
        listen,
        ShardServerConfig {
            shard_id,
            coordinator: coord_cfg,
            lease_ttl: std::time::Duration::from_millis(lease_ttl_ms),
            max_connections,
            ..ShardServerConfig::default()
        },
    )?;
    println!(
        "shard {shard_id} serving on {} (substream slots {}..{}; send a shutdown frame to stop)",
        server.addr(),
        slots.start,
        slots.end
    );
    let _metrics_http = maybe_metrics_server(args, &server.coordinator())?;
    while !server.stopping() {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    server.stop();
    println!("shard {shard_id} drained");
    Ok(())
}

/// `route --shards a,b,…`: drive a shard cluster through the router with
/// the same synthetic load as local `serve` — the drawn streams are
/// bit-identical to a single coordinator with the same root seed.
fn cmd_route(args: &Args) -> Result<()> {
    use xorgens_gp::cluster::{Router, RouterConfig};
    use xorgens_gp::prng::Placement;
    let shards_arg =
        args.opt("shards").ok_or_else(|| anyhow!("route requires --shards HOST:PORT,…"))?;
    let shards: Vec<String> =
        shards_arg.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    ensure!(!shards.is_empty(), "--shards must list at least one address");
    let clients: usize = args.opt_parse_or("clients", 8).map_err(Error::msg)?;
    let draws: usize = args.opt_parse_or("draws", 100).map_err(Error::msg)?;
    let n: usize = args.opt_parse_or("n", 65536).map_err(Error::msg)?;
    let placement: Placement =
        args.opt_parse_or("placement", Placement::SeedMix).map_err(Error::msg)?;
    let root_seed: u64 = args
        .opt_parse_or("root-seed", CoordinatorConfig::default().root_seed)
        .map_err(Error::msg)?;
    let router = Router::connect(RouterConfig { shards, root_seed, ..RouterConfig::default() })?;
    println!("router up: live shards {:?}", router.active_shards());
    let t0 = std::time::Instant::now();
    for c in 0..clients {
        let s = router.builder(&format!("client-{c}")).placement(placement).u32()?;
        let mut buf = vec![0u32; n];
        for _ in 0..draws {
            s.draw_into(&mut buf)?;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = router.metrics();
    println!(
        "routed {} numbers across {} shard(s) in {dt:.2}s = {:.3e} RN/s",
        m.numbers_served,
        router.active_shards().len(),
        m.numbers_served as f64 / dt
    );
    println!("{}", m.render());
    if args.flag("stats-json") {
        for (addr, stats) in router.shard_stats() {
            match stats {
                Ok(json) => println!("{addr} {json}"),
                Err(e) => println!("{addr} unreachable: {e:#}"),
            }
        }
    }
    if args.flag("metrics-json") {
        // The labeled exposition (metrics wire verb): global snapshot
        // plus per-stream / per-worker / per-shard families, per shard.
        for (addr, metrics) in router.shard_metrics() {
            match metrics {
                Ok(json) => println!("{addr} {json}"),
                Err(e) => println!("{addr} unreachable: {e:#}"),
            }
        }
    }
    if args.flag("shutdown") {
        router.shutdown_shards();
        println!("shutdown sent to all shards");
    }
    Ok(())
}

/// `stats --addr HOST:PORT`: scrape a running `serve --metrics-addr`
/// endpoint — Prometheus text by default, the JSON exposition with
/// `--json`; `--watch [SECS]` re-scrapes forever (bare flag: every 2s).
fn cmd_stats(args: &Args) -> Result<()> {
    use xorgens_gp::obs::http_get;
    let addr =
        args.opt("addr").ok_or_else(|| anyhow!("stats requires --addr HOST:PORT"))?.to_string();
    let path = if args.flag("json") { "/metrics.json" } else { "/metrics" };
    let watch: Option<u64> = if args.flag("watch") {
        Some(2)
    } else {
        args.opt_parse::<u64>("watch").map_err(Error::msg)?
    };
    match watch {
        None => print!("{}", http_get(&addr, path)?),
        Some(secs) => {
            ensure!(secs >= 1, "--watch interval must be at least 1 second");
            loop {
                match http_get(&addr, path) {
                    Ok(body) => print!("=== {addr}{path} ===\n{body}\n"),
                    Err(e) => eprintln!("scrape failed: {e:#}"),
                }
                std::thread::sleep(std::time::Duration::from_secs(secs));
            }
        }
    }
    Ok(())
}

/// `trace [--last N] [--addr HOST:PORT]`: print the span-journal
/// timeline, grouped by causal trace id. With `--addr` the dump comes
/// from a remote `/trace` endpoint (a `serve --metrics-addr` process);
/// without it, from this process's own ring — which only has content
/// when something in-process recorded spans, so the remote form is the
/// useful one from the CLI.
fn cmd_trace(args: &Args) -> Result<()> {
    use xorgens_gp::obs;
    let last: usize = args.opt_parse_or("last", 200).map_err(Error::msg)?;
    ensure!(last >= 1, "--last must be at least 1");
    match args.opt("addr") {
        Some(addr) => print!("{}", obs::http_get(addr, &format!("/trace?last={last}"))?),
        None => print!("{}", obs::render_dump(&obs::dump(last))),
    }
    Ok(())
}

fn cmd_golden(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.opt_or("out", "tests/golden"));
    std::fs::create_dir_all(&dir)?;
    let seed = 20260710u64;

    // xorgensGP: 3 blocks, 4 rounds.
    {
        use xorgens_gp::prng::BlockParallel;
        let mut gen = xorgens_gp::prng::XorgensGp::new(seed, 3);
        let state = gen.dump_state();
        let mut out = vec![0u32; 4 * gen.round_len()];
        gen.fill_interleaved(&mut out);
        write_golden(&dir, "xorgensgp", 3, 4, state, out)?;
    }
    // MTGP: 2 blocks, 3 rounds.
    {
        use xorgens_gp::prng::BlockParallel;
        let mut gen = xorgens_gp::prng::Mtgp::new(seed, 2);
        let state = gen.dump_state();
        let mut out = vec![0u32; 3 * gen.round_len()];
        gen.fill_interleaved(&mut out);
        write_golden(&dir, "mtgp", 2, 3, state, out)?;
    }
    // XORWOW: 4 blocks, 64 steps.
    {
        use xorgens_gp::prng::BlockParallel;
        let mut gen = xorgens_gp::prng::xorwow::XorwowBlock::new(seed, 4);
        let state = gen.dump_state();
        let mut out = vec![0u32; 64 * gen.round_len()];
        gen.fill_interleaved(&mut out);
        write_golden(&dir, "xorwow", 4, 64, state, out)?;
    }
    // Serial MT19937 with the classic seed.
    {
        let mut mt = xorgens_gp::prng::Mt19937::new(5489);
        let outputs: Vec<u32> = (0..64).map(|_| mt.next_u32()).collect();
        let mut j = Json::obj();
        j.push("seed", Json::Int(5489)).push("outputs", Json::arr_of_u32(&outputs));
        std::fs::write(dir.join("mt19937.json"), j.to_string())?;
    }
    println!("golden vectors written to {dir:?}");
    Ok(())
}

fn write_golden(
    dir: &std::path::Path,
    name: &str,
    blocks: usize,
    rounds: usize,
    state: Vec<u32>,
    outputs: Vec<u32>,
) -> Result<()> {
    let mut j = Json::obj();
    j.push("generator", Json::Str(name.into()))
        .push("blocks", Json::Int(blocks as i64))
        .push("rounds", Json::Int(rounds as i64))
        .push("state", Json::arr_of_u32(&state))
        .push("outputs", Json::arr_of_u32(&outputs));
    std::fs::write(dir.join(format!("{name}.json")), j.to_string())?;
    Ok(())
}

fn cmd_selftest(_args: &Args) -> Result<()> {
    // 1. Generators deterministic.
    let mut g = make_generator(GeneratorKind::XorgensGp, 1);
    let a: Vec<u32> = (0..8).map(|_| g.next_u32()).collect();
    let mut g = make_generator(GeneratorKind::XorgensGp, 1);
    let b: Vec<u32> = (0..8).map(|_| g.next_u32()).collect();
    ensure!(a == b, "determinism");
    println!("[1/4] generators deterministic: ok");
    // 2. PJRT runtime round-trip (if artifacts built AND the pjrt feature
    // is compiled in — the stub would error at launch otherwise).
    let dir = xorgens_gp::runtime::default_dir();
    if !cfg!(all(feature = "pjrt", xla_vendored)) {
        println!("[2/4] PJRT skipped (needs `--features pjrt` and a vendored xla crate)");
    } else if dir.join("manifest.txt").exists() {
        use xorgens_gp::prng::BlockParallel;
        let mut rt = xorgens_gp::runtime::PjrtRuntime::new(&dir)?;
        let mut gen = xorgens_gp::prng::XorgensGp::new(42, 8);
        let st = gen.dump_state();
        let (_, out) = rt.launch("xorgensgp_u32_b8_r2", &st)?;
        let mut expect = vec![0u32; 2 * gen.round_len()];
        gen.fill_interleaved(&mut expect);
        ensure!(out.as_u32() == Some(&expect[..]), "PJRT != rust");
        println!("[2/4] PJRT artifact bit-exact with rust ({}): ok", rt.platform());
    } else {
        println!("[2/4] PJRT skipped (run `make artifacts`)");
    }
    // 3. Coordinator round-trip over a typed handle, pipelined.
    let coord = Coordinator::new(CoordinatorConfig::default());
    let s = coord.builder("selftest").u32()?;
    let ticket = s.submit(10_000)?; // in flight while we draw blocking
    let v = s.draw(5_000)?;
    ensure!(v.len() == 5_000, "coordinator draw");
    ensure!(ticket.wait()?.len() == 10_000, "coordinator pipelined draw");
    coord.shutdown();
    println!("[3/4] coordinator: ok (typed handle + pipelined ticket)");
    // 4. One quick battery instance.
    let mut g = make_generator(GeneratorKind::XorgensGp, 7);
    let r = xorgens_gp::testu01::collision::collision(g.as_mut(), 1 << 12, 22);
    ensure!(!r.is_fail(), "collision test failed: p={}", r.p_value);
    println!("[4/4] battery spot-check: ok (p={:.3})", r.p_value);
    println!("selftest passed");
    Ok(())
}

/// Polynomial jump-ahead demo: place any linear generator's master state
/// `k` steps ahead via the minimal-polynomial engine, and verify against
/// explicit iteration for small `k`.
fn cmd_jump(args: &Args) -> Result<()> {
    use xorgens_gp::gf2::LinearStep;
    use xorgens_gp::prng::place::{stepper_for, PlacedMaster};
    let kind: GeneratorKind = args.opt_parse_or("gen", GeneratorKind::Xorwow).map_err(Error::msg)?;
    let k: u128 = args
        .opt_or("k", "1000000")
        .parse()
        .map_err(|_| anyhow!("invalid --k"))?;
    let seed: u64 = args.opt_parse_or("seed", 1).map_err(Error::msg)?;
    let t0 = std::time::Instant::now();
    let master = PlacedMaster::new(kind, seed);
    let probe_ms = t0.elapsed().as_secs_f64() * 1e3;
    let deg = master.engine().min_poly().degree().unwrap_or(0);
    let t1 = std::time::Instant::now();
    let placed = master.state_at_offset(k);
    let jump_ms = t1.elapsed().as_secs_f64() * 1e3;
    println!(
        "{} seed {seed}: minimal polynomial degree {deg} (probed in {probe_ms:.1} ms); \
         state after {k} steps in {jump_ms:.3} ms:",
        kind.name()
    );
    let show = placed.len().min(8);
    let words: Vec<String> = placed[..show].iter().map(|w| format!("{w:08x}")).collect();
    println!("  [{}{}]", words.join(" "), if placed.len() > show { " …" } else { "" });
    if k <= 1_000_000 {
        let stepper = stepper_for(kind);
        let n = master.lfsr_words();
        let mut lfsr = master.master_state()[..n].to_vec();
        for _ in 0..k {
            stepper.step_words(&mut lfsr);
        }
        ensure!(lfsr == placed[..n], "jump disagrees with iteration");
        println!("  verified against {k} explicit steps: ok");
    }
    Ok(())
}

fn cmd_params_search(args: &Args) -> Result<()> {
    let r: usize = args.opt_parse_or("r", 2).map_err(Error::msg)?;
    let s: usize = args.opt_parse_or("s", 1).map_err(Error::msg)?;
    let limit: usize = args.opt_parse_or("limit", 5).map_err(Error::msg)?;
    ensure!(32 * r <= 64, "exact search limited to 32r <= 64 (see gf2 docs)");
    println!("searching maximal-period xorgens parameter sets for r={r} s={s}…");
    let found = xorgens_gp::prng::params::find_small_params(r, s, limit);
    for p in &found {
        println!("  (r={}, s={}, a={}, b={}, c={}, d={})", p.r, p.s, p.a, p.b, p.c, p.d);
    }
    println!(
        "{} set(s) found (period 2^{} - 1 each, verified by matrix order)",
        found.len(),
        32 * r
    );
    Ok(())
}

//! The parallel fill engine: block-partitioned multi-threaded generation.
//!
//! The paper's performance story is that xorgensGP/MTGP/XORWOW decompose
//! into **independent per-block subsequences** that a GPU advances in
//! lockstep. On the CPU backend the same independence makes the bulk fill
//! embarrassingly parallel: partition the blocks into disjoint ranges,
//! hand each range to a worker, and let every worker write its blocks'
//! strided lanes directly into the caller's slice. Because the
//! interleaved layout puts block `b` of round `t` at a fixed offset
//! `t * round_len + b * lane`, the workers' write sets are disjoint by
//! construction and the result is **bit-identical** to the serial
//! interleaved stream.
//!
//! Two execution strategies share that decomposition:
//!
//! * **Scoped** ([`fill_rounds_parallel`]) — spawn workers under
//!   [`std::thread::scope`] per dispatch; zero state to manage, ideal
//!   for one-shot bulk fills (the battery, the benches).
//! * **Pooled** ([`pool::FillPool`]) — persistent, optionally
//!   core-pinned workers pulling parts from a per-dispatch latch, plus
//!   whole-generator background jobs for the coordinator's
//!   generation-ahead prefetch; ideal for serve loops doing thousands
//!   of launches per second (no spawn/join per dispatch, warm caches).
//!
//! Three pieces underneath both:
//!
//! * [`StridedOut`] — an unsafe-but-contained shared view of the output
//!   slice. All `unsafe` in the engine lives behind its
//!   [`block_slice`](StridedOut::block_slice) method, whose safety
//!   contract is the disjoint-block-ownership argument above.
//! * [`RangeFill`] — one worker's slice of a generator: a part that owns
//!   `&mut` views of its blocks' state and fills **many rounds per
//!   dispatch** (one virtual call per part per fill, not per round —
//!   essential for XORWOW, whose rounds are 1 word/block).
//! * [`fill_rounds_parallel`] — the dispatcher:
//!   [`split_fill`](crate::prng::BlockParallel::split_fill) the generator
//!   into per-range parts, fan out under `thread::scope`, run part 0 on
//!   the calling thread.
//!
//! Consumers never call this module directly on the hot path: the trait
//! method
//! [`fill_interleaved_threaded`](crate::prng::BlockParallel::fill_interleaved_threaded)
//! applies the [`PAR_FILL_MIN_WORDS`] crossover (small fills stay serial
//! — thread spawn costs ~10µs, a 4096-word battery chunk is cheaper than
//! that) and falls back to the serial `fill_interleaved` whenever the
//! generator cannot split (leapfrog wrappers, single block, one thread).

pub mod pool;

use crate::prng::BlockParallel;

/// Crossover threshold for the threaded bulk path, in output words.
///
/// Below this, [`BlockParallel::fill_interleaved_threaded`] stays serial:
/// scoped-thread spawn + join costs on the order of tens of microseconds,
/// which a fill this small completes in anyway. The default coordinator
/// launch (64 blocks × 63 lanes × 16 rounds = 64512 words) clears the
/// threshold; the battery's 4096-word `ChunkedRng` scratch does not and
/// is served serially (bit-identical either way).
pub const PAR_FILL_MIN_WORDS: usize = 1 << 15;

/// A shared, strided view of an interleaved output slice.
///
/// Round `t`, block `b` of the interleaved stream occupies the fixed
/// `lane`-word window at `t * round_len + (b - first_block) * lane`, so a
/// set of workers owning **disjoint block ranges** write disjoint windows
/// — that disjointness is the single safety argument for the whole
/// engine, and the only place it is consumed is
/// [`block_slice`](StridedOut::block_slice).
pub struct StridedOut {
    base: *mut u32,
    len: usize,
    round_len: usize,
    lane: usize,
    /// Absolute block index mapped to column 0 of the view (0 for a
    /// full-width fill; `range.start` for a sub-range buffer).
    first_block: usize,
}

// SAFETY: the raw pointer is only dereferenced through `block_slice`,
// whose contract guarantees disjoint (round, block) windows per caller;
// the underlying buffer outlives the view (it is a reborrow of the
// caller's `&mut [u32]`, and `fill_rounds_parallel` scopes all workers
// inside that borrow).
unsafe impl Send for StridedOut {}
unsafe impl Sync for StridedOut {}

impl StridedOut {
    /// View over a whole-width interleaved buffer (`out.len()` a multiple
    /// of `round_len`; block 0 at column 0).
    pub fn new(out: &mut [u32], round_len: usize, lane: usize) -> StridedOut {
        StridedOut::with_block_base(out, round_len, lane, 0)
    }

    /// View over a sub-range buffer whose column 0 holds absolute block
    /// `first_block` (the [`fill_rounds_range`] layout:
    /// `round_len = range_width * lane`).
    ///
    /// [`fill_rounds_range`]: crate::prng::BlockParallel::fill_rounds_range
    pub fn with_block_base(
        out: &mut [u32],
        round_len: usize,
        lane: usize,
        first_block: usize,
    ) -> StridedOut {
        assert!(round_len > 0 && lane > 0 && round_len % lane == 0);
        assert_eq!(out.len() % round_len, 0, "output not a whole number of rounds");
        StridedOut { base: out.as_mut_ptr(), len: out.len(), round_len, lane, first_block }
    }

    /// Number of whole rounds the view covers.
    pub fn rounds(&self) -> usize {
        self.len / self.round_len
    }

    /// The `lane`-word output window of `(round, block)`, with `block` an
    /// **absolute** block index.
    ///
    /// # Safety
    ///
    /// The caller must be the sole writer of this `(round, block)` cell
    /// for the lifetime of the returned slice. The engine guarantees this
    /// by giving each [`RangeFill`] part a disjoint block range and each
    /// part exclusive ownership of its range's state; both must be in
    /// bounds (`debug_assert`ed).
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn block_slice(&self, round: usize, block: usize) -> &mut [u32] {
        debug_assert!(block >= self.first_block);
        let off = round * self.round_len + (block - self.first_block) * self.lane;
        debug_assert!(off + self.lane <= self.len, "block_slice out of bounds");
        std::slice::from_raw_parts_mut(self.base.add(off), self.lane)
    }

    /// The contiguous `(hi - lo) * lane`-word output window covering
    /// **absolute** blocks `lo..hi` of `round` — adjacent blocks of one
    /// round are adjacent in the interleaved layout, so a part that owns a
    /// whole block range can hand its per-round output row to a SIMD
    /// kernel as one slice instead of `hi - lo` single-block slices (the
    /// XORWOW part does exactly this: lane width 1 makes the row the
    /// vectorization axis).
    ///
    /// # Safety
    ///
    /// Same contract as [`block_slice`](StridedOut::block_slice), extended
    /// over the range: the caller must be the sole writer of every
    /// `(round, block)` cell for `block` in `lo..hi` while the slice
    /// lives.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn block_slice_range(&self, round: usize, lo: usize, hi: usize) -> &mut [u32] {
        debug_assert!(lo >= self.first_block && lo <= hi);
        let off = round * self.round_len + (lo - self.first_block) * self.lane;
        let len = (hi - lo) * self.lane;
        debug_assert!(off + len <= self.len, "block_slice_range out of bounds");
        std::slice::from_raw_parts_mut(self.base.add(off), len)
    }
}

/// One worker's share of a split generator: exclusive `&mut` views of a
/// disjoint block range's state, plus the round count baked in at split
/// time.
///
/// Contract: `fill_rounds` is called **exactly once** per part (on any
/// thread — the trait is `Send`), advances every owned block by the
/// split's round count, and writes each `(round, block)` output through
/// [`StridedOut::block_slice`] at the block's absolute index. Dropping a
/// part without driving it leaves its blocks behind the rest of the
/// generator — which is why the engine, not callers, drives parts.
pub trait RangeFill: Send {
    /// Fill all owned blocks for all baked-in rounds.
    fn fill_rounds(&mut self, out: &StridedOut);
}

/// Balanced block partition: `workers + 1` strictly-ascending bounds
/// `0 = b_0 < … < b_workers = blocks`, part sizes differing by at most 1
/// (the first `blocks % workers` parts get the extra block). Requires
/// `1 <= workers <= blocks`.
pub fn partition_blocks(blocks: usize, workers: usize) -> Vec<usize> {
    assert!(workers >= 1 && workers <= blocks);
    let base = blocks / workers;
    let rem = blocks % workers;
    let mut bounds = Vec::with_capacity(workers + 1);
    bounds.push(0);
    let mut acc = 0;
    for i in 0..workers {
        acc += base + usize::from(i < rem);
        bounds.push(acc);
    }
    bounds
}

/// Fill `out` (a whole number of rounds) with `threads`-way parallelism,
/// bit-identically to the serial `fill_interleaved` and leaving the
/// generator in the identical advanced state.
///
/// Returns `false` without touching `out` when the parallel path does not
/// apply — one effective worker (`threads <= 1` or a single block), zero
/// rounds, or a generator whose
/// [`split_fill`](BlockParallel::split_fill) declines (e.g. the leapfrog
/// wrapper, whose output is inherently a serial deal) — so callers can
/// fall back to the serial path. No crossover threshold is applied here
/// (tests drive small buffers through it directly); the trait-level
/// `fill_interleaved_threaded` owns that policy.
///
/// # Panics
///
/// If `out.len()` is not a multiple of `round_len()`, or a worker
/// panics (the panic is propagated after all workers join).
pub fn fill_rounds_parallel<B: BlockParallel + ?Sized>(
    gen: &mut B,
    threads: usize,
    out: &mut [u32],
) -> bool {
    let round = gen.round_len();
    let lane = gen.lane_width();
    let blocks = gen.blocks();
    assert!(round > 0 && out.len() % round == 0, "output not a whole number of rounds");
    let rounds = out.len() / round;
    let workers = threads.min(blocks);
    if workers <= 1 || rounds == 0 {
        return false;
    }
    let bounds = partition_blocks(blocks, workers);
    let Some(mut parts) = gen.split_fill(rounds, &bounds) else {
        return false;
    };
    assert_eq!(parts.len(), workers, "split_fill returned a wrong part count");
    let view = StridedOut::new(out, round, lane);
    std::thread::scope(|scope| {
        let mut rest = parts.iter_mut();
        // Part 0 runs on the calling thread: with `workers` parts there
        // are only `workers - 1` spawns, and a 1-worker degenerate split
        // costs no thread at all.
        let first = rest.next().expect("split_fill returned no parts");
        let handles: Vec<_> = rest
            .map(|part| {
                let view = &view;
                scope.spawn(move || part.fill_rounds(view))
            })
            .collect();
        first.fill_rounds(&view);
        for h in handles {
            if let Err(e) = h.join() {
                std::panic::resume_unwind(e);
            }
        }
    });
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::xorwow::XorwowBlock;
    use crate::prng::{Mtgp, XorgensGp};

    #[test]
    fn partition_is_balanced_and_exhaustive() {
        for blocks in 1..40 {
            for workers in 1..=blocks {
                let b = partition_blocks(blocks, workers);
                assert_eq!(b.len(), workers + 1);
                assert_eq!((b[0], *b.last().unwrap()), (0, blocks));
                let sizes: Vec<usize> = b.windows(2).map(|w| w[1] - w[0]).collect();
                assert!(sizes.iter().all(|&s| s >= 1));
                let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "unbalanced: {sizes:?}");
                assert_eq!(sizes.iter().sum::<usize>(), blocks);
            }
        }
    }

    /// The engine's core promise: parallel fill == serial fill, bit for
    /// bit, and the generator lands in the identical state (checked by
    /// drawing one more round from both afterwards).
    #[test]
    fn parallel_fill_matches_serial_xorgensgp() {
        for threads in [2usize, 3, 5] {
            let blocks = 7;
            let mut par = XorgensGp::new(42, blocks);
            let mut ser = XorgensGp::new(42, blocks);
            let rounds = 9;
            let n = rounds * par.round_len();
            let mut a = vec![0u32; n];
            let mut b = vec![0u32; n];
            assert!(fill_rounds_parallel(&mut par, threads, &mut a));
            ser.fill_interleaved(&mut b);
            assert_eq!(a, b, "threads={threads}");
            let mut a2 = vec![0u32; par.round_len()];
            let mut b2 = vec![0u32; ser.round_len()];
            par.fill_round(&mut a2);
            ser.fill_round(&mut b2);
            assert_eq!(a2, b2, "continuation diverged at threads={threads}");
        }
    }

    #[test]
    fn parallel_fill_matches_serial_mtgp() {
        let mut par = Mtgp::new(7, 4);
        let mut ser = Mtgp::new(7, 4);
        let n = 3 * par.round_len();
        let mut a = vec![0u32; n];
        let mut b = vec![0u32; n];
        assert!(fill_rounds_parallel(&mut par, 4, &mut a));
        ser.fill_interleaved(&mut b);
        assert_eq!(a, b);
    }

    /// XORWOW's split advances the shared phase eagerly; a round count
    /// that is not a multiple of the 5-word rotation is the case that
    /// would expose a phase bug in the continuation.
    #[test]
    fn xorwow_phase_continues_after_threaded_fill() {
        let blocks = 6;
        let mut par = XorwowBlock::new(3, blocks);
        let mut ser = XorwowBlock::new(3, blocks);
        let rounds = 13; // 13 % 5 != 0
        let mut a = vec![0u32; rounds * blocks];
        let mut b = vec![0u32; rounds * blocks];
        assert!(fill_rounds_parallel(&mut par, 3, &mut a));
        ser.fill_interleaved(&mut b);
        assert_eq!(a, b);
        for _ in 0..7 {
            let mut a2 = vec![0u32; blocks];
            let mut b2 = vec![0u32; blocks];
            par.fill_round(&mut a2);
            ser.fill_round(&mut b2);
            assert_eq!(a2, b2);
        }
    }

    #[test]
    fn single_worker_declines() {
        let mut g = XorgensGp::new(1, 4);
        let mut buf = vec![0u32; g.round_len()];
        assert!(!fill_rounds_parallel(&mut g, 1, &mut buf));
        // Untouched buffer: the caller owns the serial fallback.
        assert!(buf.iter().all(|&x| x == 0));
        let mut one_block = XorgensGp::new(1, 1);
        let mut buf = vec![0u32; one_block.round_len()];
        assert!(!fill_rounds_parallel(&mut one_block, 8, &mut buf));
    }
}

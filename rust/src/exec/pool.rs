//! The persistent fill-worker pool: long-lived, optionally core-pinned
//! workers replacing the per-dispatch `std::thread::scope` fan-out.
//!
//! The scoped engine in [`super::fill_rounds_parallel`] is correct but
//! pays thread spawn + join (~tens of µs) and cold caches on **every**
//! bulk launch — fine for one big battery fill, painful for a serve loop
//! doing thousands of launches per second. [`FillPool`] keeps
//! `workers` threads parked on a condvar and feeds them two kinds of
//! work:
//!
//! * **Parts** ([`RangeFill`] halves of a split generator) from a
//!   per-dispatch latch: [`FillPool::fill_rounds`] splits exactly like
//!   the scoped engine, queues `parts[1..]`, runs part 0 on the calling
//!   thread, then *help-steals* remaining parts while waiting on the
//!   latch — so a dispatch can never deadlock behind other work, even
//!   with every worker busy or the pool already shut down.
//! * **Generate jobs** (a whole generator + buffer, moved in) for the
//!   coordinator's generation-ahead prefetch: the worker fills the
//!   buffer — recursively fanning its parts across the pool — and sends
//!   generator + buffer back on a channel.
//!
//! Queue discipline: parts go to the **front** (LIFO, prioritized),
//! generate jobs to the back, so no part is ever stuck behind a whole
//! generate job and the help-steal loop ("pop only if the front is a
//! part") is complete.
//!
//! Panics in a part are caught on the worker (which survives — the pool
//! never wedges), recorded in the dispatch latch, and **resumed on the
//! submitting thread** after the latch drains, matching the scoped
//! engine's contract. Panics in a generate job come back as
//! [`GenerateOutcome::Panicked`].
//!
//! The output is bit-identical to the serial interleaved stream for the
//! same reason the scoped engine's is: disjoint block ranges through
//! [`StridedOut`], same split, same per-part kernels.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::{partition_blocks, RangeFill, StridedOut, PAR_FILL_MIN_WORDS};
use crate::obs::registry::WorkerStats;
use crate::obs::trace::{self as otrace, SpanKind, SpanTimer};
use crate::prng::BlockParallel;

/// Construction knobs for [`FillPool`].
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Worker thread count (clamped to at least 1). A dispatching caller
    /// participates as one more executor, so `workers = fill_threads - 1`
    /// reproduces the scoped engine's `fill_threads`-way parallelism.
    pub workers: usize,
    /// Pin worker `i` to core `i % available_parallelism` via the raw
    /// `sched_setaffinity` syscall. Linux (x86_64/aarch64) only; a no-op
    /// everywhere else, and best-effort there (a restricted cpuset cannot
    /// take the pool down).
    pub pin_cores: bool,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig { workers: 1, pin_cores: false }
    }
}

/// One queued [`RangeFill`] part plus its dispatch latch.
///
/// The part pointer's lifetime is erased to `'static`: the borrow it
/// actually holds is the submitting dispatch's `&'a mut` generator, and
/// [`Shared::fill_rounds`] blocks on the latch until every queued part
/// has run (or panicked) before returning — the borrow never outlives
/// the dispatch frame. Same containment argument as [`StridedOut`]'s raw
/// base pointer, one level up.
struct PartTask {
    part: *mut (dyn RangeFill + 'static),
    view: *const StridedOut,
    latch: Arc<Latch>,
    /// Causal trace id inherited from the dispatching request (0 = none).
    trace: u64,
    /// Enqueue instant, for the per-worker queue-wait telemetry.
    queued: Instant,
}

// SAFETY: the pointers are only dereferenced by exactly one executor
// (each queued task is popped once), the pointees outlive the task (the
// dispatch frame waits on the latch), and RangeFill itself is Send.
unsafe impl Send for PartTask {}

/// A whole-buffer generation job for the prefetch path: the generator and
/// buffer are moved in, filled, and handed back through `reply`.
struct GenerateJob {
    gen: Box<dyn BlockParallel + Send>,
    buf: Vec<u32>,
    reply: std::sync::mpsc::SyncSender<GenerateOutcome>,
    /// Causal trace id of the draw that triggered this refill (0 = none);
    /// re-installed as the executing worker's scope so nested part
    /// fan-outs inherit it.
    trace: u64,
    /// Enqueue instant, for the per-worker queue-wait telemetry.
    queued: Instant,
}

/// What a generate job sends back.
pub enum GenerateOutcome {
    /// The buffer is fully written and the generator advanced past it —
    /// both ready for the next dispatch.
    Filled { gen: Box<dyn BlockParallel + Send>, buf: Vec<u32> },
    /// The fill panicked; the payload is for the consumer to
    /// [`resume_unwind`]. The generator state is torn and discarded.
    Panicked(Box<dyn Any + Send>),
}

enum Task {
    Part(PartTask),
    Generate(GenerateJob),
}

/// Per-dispatch completion latch: counts queued parts down to zero and
/// keeps the first captured panic for the submitter.
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn Any + Send>>,
}

impl Latch {
    fn new(parts: usize) -> Latch {
        Latch { state: Mutex::new(LatchState { remaining: parts, panic: None }), done: Condvar::new() }
    }

    fn count_down(&self, panic: Option<Box<dyn Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        if st.panic.is_none() {
            st.panic = panic;
        }
        if st.remaining == 0 {
            self.done.notify_all();
        }
    }
}

/// State shared between the handle and the workers. All execution logic
/// lives here so a worker running a generate job can itself dispatch
/// parts across the pool.
struct Shared {
    queue: Mutex<VecDeque<Task>>,
    available: Condvar,
    shutdown: AtomicBool,
    /// Queued-task gauge (parts + generate jobs), for the
    /// `pool_queue_depth` metric.
    depth: AtomicUsize,
    workers: usize,
    /// Optional external mirror of `depth` (the coordinator installs its
    /// `Metrics::pool_queue_depth` here), maintained **live** at the same
    /// enqueue/dequeue sites instead of being written at snapshot time.
    gauge: OnceLock<Arc<AtomicU64>>,
    /// Per-slot telemetry: `stats[i]` for worker `i`, plus one extra
    /// trailing slot for dispatching callers (part 0 + help-steals).
    stats: Vec<Arc<WorkerStats>>,
}

impl Shared {
    /// Enqueue accounting: internal depth + the external gauge mirror.
    fn depth_add(&self, n: usize) {
        self.depth.fetch_add(n, Ordering::Relaxed);
        if let Some(g) = self.gauge.get() {
            g.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    /// Dequeue accounting, the inverse of [`Shared::depth_add`].
    fn depth_sub(&self, n: usize) {
        self.depth.fetch_sub(n, Ordering::Relaxed);
        if let Some(g) = self.gauge.get() {
            g.fetch_sub(n as u64, Ordering::Relaxed);
        }
    }

    /// The caller-slot index in `stats` (one past the last worker).
    fn caller_slot(&self) -> usize {
        self.workers
    }
    /// Pop-and-run loop for one worker thread. On shutdown the queue is
    /// **drained first** — queued generate jobs still deliver their
    /// outcome, queued parts still release their latch — then the worker
    /// exits.
    fn worker_loop(&self, slot: usize) {
        let mut queue = self.queue.lock().unwrap();
        loop {
            if let Some(task) = queue.pop_front() {
                self.depth_sub(1);
                drop(queue);
                self.run_task(task, slot);
                queue = self.queue.lock().unwrap();
                continue;
            }
            if self.shutdown.load(Ordering::Acquire) {
                return;
            }
            queue = self.available.wait(queue).unwrap();
        }
    }

    /// Execute one task on `slot` (a worker index, or the caller slot for
    /// help-steals); never panics (worker threads must survive any part
    /// or job panicking). All per-worker telemetry — task counts, queue
    /// wait, fill time — and the `generate`/`fill_part` trace spans are
    /// recorded here, the single execution site.
    fn run_task(&self, task: Task, slot: usize) {
        let stats = &self.stats[slot];
        match task {
            Task::Part(p) => {
                stats.parts.fetch_add(1, Ordering::Relaxed);
                stats
                    .queue_wait_us
                    .fetch_add(p.queued.elapsed().as_micros() as u64, Ordering::Relaxed);
                let span = SpanTimer::start(p.trace, SpanKind::FillPart);
                let t0 = Instant::now();
                // SAFETY: sole executor of this part (popped once); the
                // dispatch frame keeps part + view alive until the latch
                // (counted down below, panic or not) reaches zero.
                let result =
                    catch_unwind(AssertUnwindSafe(|| unsafe { (*p.part).fill_rounds(&*p.view) }));
                stats.fill_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
                span.finish(slot as u64);
                p.latch.count_down(result.err());
            }
            Task::Generate(job) => {
                let GenerateJob { mut gen, mut buf, reply, trace, queued } = job;
                stats.generates.fetch_add(1, Ordering::Relaxed);
                stats
                    .queue_wait_us
                    .fetch_add(queued.elapsed().as_micros() as u64, Ordering::Relaxed);
                // Scope the originating draw's trace id onto this thread
                // so the nested part fan-out inherits causality.
                let prev = otrace::set_current_trace(trace);
                let span = SpanTimer::start(trace, SpanKind::Generate);
                let t0 = Instant::now();
                let result =
                    catch_unwind(AssertUnwindSafe(|| self.fill_buffer(&mut gen, &mut buf)));
                stats.fill_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
                span.finish(buf.len() as u64);
                otrace::set_current_trace(prev);
                let outcome = match result {
                    Ok(()) => GenerateOutcome::Filled { gen, buf },
                    Err(p) => GenerateOutcome::Panicked(p),
                };
                // A dropped receiver (stream torn down mid-prefetch) is
                // fine — the generator and buffer just drop with it.
                let _ = reply.send(outcome);
            }
        }
    }

    /// The pool analogue of `fill_interleaved_threaded`: whole rounds
    /// through [`Shared::fill_rounds`] above the crossover, serial
    /// otherwise, partial tail bounced with the excess discarded. Used by
    /// generate jobs; the caller-facing twin is the trait method
    /// [`BlockParallel::fill_interleaved_pooled`].
    fn fill_buffer<B: BlockParallel + ?Sized>(&self, gen: &mut B, out: &mut [u32]) {
        let chunk = gen.round_len();
        let whole = out.len() - out.len() % chunk;
        if whole >= PAR_FILL_MIN_WORDS && self.fill_rounds(gen, &mut out[..whole]) {
            if whole < out.len() {
                let mut scratch = vec![0u32; chunk];
                gen.fill_round(&mut scratch);
                out[whole..].copy_from_slice(&scratch[..out.len() - whole]);
            }
            return;
        }
        gen.fill_interleaved(out);
    }

    /// Split `gen` and fan the parts across the pool; same contract and
    /// same `false` fallback conditions as
    /// [`super::fill_rounds_parallel`], with `workers + 1` effective
    /// executors (the caller runs part 0 and then help-steals).
    fn fill_rounds<B: BlockParallel + ?Sized>(&self, gen: &mut B, out: &mut [u32]) -> bool {
        let round = gen.round_len();
        let lane = gen.lane_width();
        let blocks = gen.blocks();
        assert!(round > 0 && out.len() % round == 0, "output not a whole number of rounds");
        let rounds = out.len() / round;
        let parts_n = (self.workers + 1).min(blocks);
        if parts_n <= 1 || rounds == 0 {
            return false;
        }
        let bounds = partition_blocks(blocks, parts_n);
        let Some(mut parts) = gen.split_fill(rounds, &bounds) else {
            return false;
        };
        assert_eq!(parts.len(), parts_n, "split_fill returned a wrong part count");
        let view = StridedOut::new(out, round, lane);
        let latch = Arc::new(Latch::new(parts_n - 1));
        let (first, rest) = parts.split_first_mut().expect("split_fill returned no parts");
        let trace = otrace::current_trace();
        let queued = Instant::now();
        {
            let mut queue = self.queue.lock().unwrap();
            for part in rest.iter_mut() {
                // SAFETY (lifetime erasure): see PartTask — the latch
                // wait below outlives every queued part's execution.
                let raw = unsafe {
                    std::mem::transmute::<*mut (dyn RangeFill + '_), *mut (dyn RangeFill + 'static)>(
                        &mut **part,
                    )
                };
                queue.push_front(Task::Part(PartTask {
                    part: raw,
                    view: &view,
                    latch: Arc::clone(&latch),
                    trace,
                    queued,
                }));
            }
            self.depth_add(rest.len());
        }
        self.available.notify_all();
        // Part 0 on the calling thread, exactly like the scoped engine.
        let caller = &self.stats[self.caller_slot()];
        caller.parts.fetch_add(1, Ordering::Relaxed);
        let span = SpanTimer::start(trace, SpanKind::FillPart);
        let t0 = Instant::now();
        let first_result = catch_unwind(AssertUnwindSafe(|| first.fill_rounds(&view)));
        caller.fill_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        span.finish(self.caller_slot() as u64);
        self.help_until_done(&latch);
        // Every part has now run; the borrows behind the raw pointers are
        // dead and the split results can be dropped/propagated.
        drop(parts);
        if let Err(p) = first_result {
            resume_unwind(p);
        }
        if let Some(p) = latch.state.lock().unwrap().panic.take() {
            resume_unwind(p);
        }
        true
    }

    /// Wait for `latch` while stealing any queued **parts** (this
    /// dispatch's or another's — both shrink the critical path). The
    /// timed wait is load-bearing: a generate job running on a worker can
    /// push new parts after we last saw an empty queue, and those must
    /// not wait for a parked helper.
    fn help_until_done(&self, latch: &Latch) {
        loop {
            loop {
                let mut queue = self.queue.lock().unwrap();
                // Queue discipline guarantees any pending part is at the
                // front; never steal a generate job (unbounded work that
                // would delay this dispatch's own completion).
                match queue.front() {
                    Some(Task::Part(_)) => {
                        let task = queue.pop_front().expect("front was Some");
                        self.depth_sub(1);
                        drop(queue);
                        let caller = self.caller_slot();
                        self.stats[caller].steals.fetch_add(1, Ordering::Relaxed);
                        self.run_task(task, caller);
                    }
                    _ => break,
                }
            }
            let st = latch.state.lock().unwrap();
            if st.remaining == 0 {
                return;
            }
            let _ = self.done_wait(st, latch);
        }
    }

    fn done_wait<'a>(
        &self,
        st: std::sync::MutexGuard<'a, LatchState>,
        latch: &'a Latch,
    ) -> std::sync::MutexGuard<'a, LatchState> {
        let (st, _timeout) = latch.done.wait_timeout(st, Duration::from_micros(500)).unwrap();
        st
    }
}

/// The persistent worker pool. One per coordinator (shared by its worker
/// shards, backends, and prefetch jobs); drop or [`FillPool::shutdown`]
/// joins the workers after draining the queue.
pub struct FillPool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl FillPool {
    /// Spawn `cfg.workers.max(1)` parked worker threads
    /// (`fill-pool-{i}`), optionally pinned round-robin across cores.
    pub fn new(cfg: PoolConfig) -> FillPool {
        let workers = cfg.workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            depth: AtomicUsize::new(0),
            workers,
            gauge: OnceLock::new(),
            // One slot per worker + the trailing caller slot.
            stats: (0..=workers).map(|_| Arc::new(WorkerStats::default())).collect(),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            let pin = cfg.pin_cores;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fill-pool-{i}"))
                    .spawn(move || {
                        if pin {
                            pin_to_core(i);
                        }
                        sh.worker_loop(i);
                    })
                    .expect("spawn fill-pool worker"),
            );
        }
        FillPool { shared, handles: Mutex::new(handles) }
    }

    /// Install a live external mirror of the queue-depth gauge (the
    /// coordinator passes its `Metrics::pool_queue_depth` here). First
    /// call wins; must be installed while the queue is empty (it is, at
    /// coordinator construction) so the mirror never drifts.
    pub fn set_depth_gauge(&self, gauge: Arc<AtomicU64>) {
        let _ = self.shared.gauge.set(gauge);
    }

    /// Per-slot telemetry handles: index `i` is worker `i`; the **last**
    /// slot aggregates dispatching callers (part 0 + help-steals).
    pub fn worker_stats(&self) -> Vec<Arc<WorkerStats>> {
        self.shared.stats.iter().map(Arc::clone).collect()
    }

    /// Worker thread count (the pool adds the dispatching caller on top,
    /// so effective fill parallelism is `workers() + 1`).
    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    /// Currently queued tasks (parts + generate jobs) — the
    /// `pool_queue_depth` gauge.
    pub fn queue_depth(&self) -> usize {
        self.shared.depth.load(Ordering::Relaxed)
    }

    /// Fill `out` (a whole number of rounds) through the pool,
    /// bit-identically to the serial `fill_interleaved`; returns `false`
    /// without touching `out` when the parallel path does not apply (same
    /// conditions as [`super::fill_rounds_parallel`]). Callers usually go
    /// through [`BlockParallel::fill_interleaved_pooled`], which owns the
    /// crossover + tail policy.
    ///
    /// Safe to call even after [`FillPool::shutdown`]: the caller
    /// help-steals its own parts, so the dispatch completes (serially) on
    /// the calling thread.
    pub fn fill_rounds<B: BlockParallel + ?Sized>(&self, gen: &mut B, out: &mut [u32]) -> bool {
        self.shared.fill_rounds(gen, out)
    }

    /// Queue a whole-buffer generation job (the prefetch path): fill
    /// `buf` from `gen` in the background and hand both back through the
    /// returned channel. After [`FillPool::shutdown`] the channel reports
    /// disconnected instead of queueing into a dead pool.
    pub fn submit_generate(
        &self,
        gen: Box<dyn BlockParallel + Send>,
        buf: Vec<u32>,
    ) -> Receiver<GenerateOutcome> {
        let (tx, rx) = sync_channel(1);
        if self.shared.shutdown.load(Ordering::Acquire) {
            return rx; // tx drops here -> receiver sees Disconnected
        }
        {
            let mut queue = self.shared.queue.lock().unwrap();
            queue.push_back(Task::Generate(GenerateJob {
                gen,
                buf,
                reply: tx,
                trace: otrace::current_trace(),
                queued: Instant::now(),
            }));
        }
        self.shared.depth_add(1);
        self.shared.available.notify_one();
        rx
    }

    /// Graceful shutdown: workers drain the queue (generate jobs still
    /// deliver), then exit and are joined. Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.available.notify_all();
        let mut handles = self.handles.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for FillPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Best-effort thread pinning via the raw `sched_setaffinity` syscall —
/// zero dependencies, current thread (pid 0), errors ignored (a
/// restricted container cpuset must not break the pool).
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
fn pin_to_core(worker: usize) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cpu = worker % cores;
    let mut mask = vec![0u64; cpu / 64 + 1];
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    unsafe {
        sched_setaffinity_raw(&mask);
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn pin_to_core(_worker: usize) {}

/// `sched_setaffinity(0, mask.len() * 8, mask.as_ptr())`, syscall 203.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
unsafe fn sched_setaffinity_raw(mask: &[u64]) -> isize {
    let ret: isize;
    std::arch::asm!(
        "syscall",
        inlateout("rax") 203isize => ret,
        in("rdi") 0usize,
        in("rsi") mask.len() * 8,
        in("rdx") mask.as_ptr(),
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

/// `sched_setaffinity(0, mask.len() * 8, mask.as_ptr())`, syscall 122.
#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
unsafe fn sched_setaffinity_raw(mask: &[u64]) -> isize {
    let ret: isize;
    std::arch::asm!(
        "svc #0",
        in("x8") 122usize,
        inlateout("x0") 0usize => ret,
        in("x1") mask.len() * 8,
        in("x2") mask.as_ptr(),
        options(nostack),
    );
    ret
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::xorwow::XorwowBlock;
    use crate::prng::{make_block_generator, GeneratorKind, Mtgp, XorgensGp};

    fn pool(workers: usize) -> FillPool {
        FillPool::new(PoolConfig { workers, pin_cores: false })
    }

    /// The pool's core promise, mirroring the scoped engine's test:
    /// pooled fill == serial fill bit for bit, and the generator lands in
    /// the identical state (continuation checked).
    #[test]
    fn pooled_fill_matches_serial_xorgensgp() {
        for workers in [1usize, 2, 4] {
            let p = pool(workers);
            let blocks = 7;
            let mut par = XorgensGp::new(42, blocks);
            let mut ser = XorgensGp::new(42, blocks);
            let rounds = 9;
            let n = rounds * par.round_len();
            let mut a = vec![0u32; n];
            let mut b = vec![0u32; n];
            assert!(p.fill_rounds(&mut par, &mut a));
            ser.fill_interleaved(&mut b);
            assert_eq!(a, b, "workers={workers}");
            let mut a2 = vec![0u32; par.round_len()];
            let mut b2 = vec![0u32; ser.round_len()];
            par.fill_round(&mut a2);
            ser.fill_round(&mut b2);
            assert_eq!(a2, b2, "continuation diverged at workers={workers}");
        }
    }

    #[test]
    fn pooled_fill_matches_serial_mtgp() {
        let p = pool(3);
        let mut par = Mtgp::new(7, 4);
        let mut ser = Mtgp::new(7, 4);
        let n = 3 * par.round_len();
        let mut a = vec![0u32; n];
        let mut b = vec![0u32; n];
        assert!(p.fill_rounds(&mut par, &mut a));
        ser.fill_interleaved(&mut b);
        assert_eq!(a, b);
    }

    /// XORWOW's eagerly-advanced shared phase, through the pool, with a
    /// round count that is not a multiple of the 5-word rotation.
    #[test]
    fn xorwow_phase_continues_after_pooled_fill() {
        let p = pool(2);
        let blocks = 6;
        let mut par = XorwowBlock::new(3, blocks);
        let mut ser = XorwowBlock::new(3, blocks);
        let rounds = 13; // 13 % 5 != 0
        let mut a = vec![0u32; rounds * blocks];
        let mut b = vec![0u32; rounds * blocks];
        assert!(p.fill_rounds(&mut par, &mut a));
        ser.fill_interleaved(&mut b);
        assert_eq!(a, b);
        for _ in 0..7 {
            let mut a2 = vec![0u32; blocks];
            let mut b2 = vec![0u32; blocks];
            par.fill_round(&mut a2);
            ser.fill_round(&mut b2);
            assert_eq!(a2, b2);
        }
    }

    #[test]
    fn single_block_declines() {
        let p = pool(4);
        let mut one_block = XorgensGp::new(1, 1);
        let mut buf = vec![0u32; one_block.round_len()];
        assert!(!p.fill_rounds(&mut one_block, &mut buf));
        assert!(buf.iter().all(|&x| x == 0));
    }

    /// A generator whose split parts panic on demand: block range
    /// `[panic_from, ..)` panics, everything else writes a marker.
    struct PanicGen {
        blocks: usize,
        panic_from: usize,
    }

    struct PanicPart {
        range: std::ops::Range<usize>,
        rounds: usize,
        panic: bool,
    }

    impl RangeFill for PanicPart {
        fn fill_rounds(&mut self, out: &StridedOut) {
            if self.panic {
                panic!("boom in part");
            }
            for t in 0..self.rounds {
                for b in self.range.clone() {
                    // SAFETY: disjoint block ranges per part.
                    unsafe { out.block_slice(t, b) }[0] = 0x5eed_0000 | b as u32;
                }
            }
        }
    }

    impl BlockParallel for PanicGen {
        fn blocks(&self) -> usize {
            self.blocks
        }
        fn lane_width(&self) -> usize {
            1
        }
        fn fill_round(&mut self, out: &mut [u32]) {
            for (b, x) in out.iter_mut().enumerate() {
                *x = 0x5eed_0000 | b as u32;
            }
        }
        fn split_fill<'a>(
            &'a mut self,
            rounds: usize,
            bounds: &[usize],
        ) -> Option<Vec<Box<dyn RangeFill + 'a>>> {
            let panic_from = self.panic_from;
            Some(
                bounds
                    .windows(2)
                    .map(|w| {
                        Box::new(PanicPart {
                            range: w[0]..w[1],
                            rounds,
                            panic: w[1] > panic_from,
                        }) as Box<dyn RangeFill>
                    })
                    .collect(),
            )
        }
        fn dump_state(&self) -> Vec<u32> {
            Vec::new()
        }
        fn load_state(&mut self, _words: &[u32]) {}
        fn name(&self) -> &'static str {
            "panicgen"
        }
        fn state_words_per_block(&self) -> usize {
            0
        }
        fn period_log2(&self) -> f64 {
            1.0
        }
    }

    /// A panicking part is resumed on the submitting thread, the worker
    /// survives, and the pool keeps serving real fills afterwards.
    #[test]
    fn part_panic_resumes_on_submitter_without_wedging_pool() {
        let p = pool(2);
        let mut g = PanicGen { blocks: 6, panic_from: 4 };
        let mut buf = vec![0u32; 6 * 3];
        let err = catch_unwind(AssertUnwindSafe(|| p.fill_rounds(&mut g, &mut buf)))
            .expect_err("part panic must propagate to the submitter");
        assert_eq!(*err.downcast_ref::<&str>().unwrap(), "boom in part");
        // Pool still alive and correct.
        let mut par = XorgensGp::new(5, 4);
        let mut ser = XorgensGp::new(5, 4);
        let n = 4 * par.round_len();
        let mut a = vec![0u32; n];
        let mut b = vec![0u32; n];
        assert!(p.fill_rounds(&mut par, &mut a));
        ser.fill_interleaved(&mut b);
        assert_eq!(a, b);
        assert_eq!(p.queue_depth(), 0);
    }

    /// Generate jobs: the background fill equals the foreground serial
    /// fill, and the returned generator continues the stream exactly.
    #[test]
    fn submit_generate_fills_and_returns_continuable_generator() {
        let p = pool(2);
        let gen = make_block_generator(GeneratorKind::XorgensGp, 11, 8);
        let mut ser = make_block_generator(GeneratorKind::XorgensGp, 11, 8);
        let n = 4 * ser.round_len();
        let rx = p.submit_generate(gen, vec![0u32; n]);
        let mut expect = vec![0u32; n];
        ser.fill_interleaved(&mut expect);
        match rx.recv().expect("outcome") {
            GenerateOutcome::Filled { mut gen, buf } => {
                assert_eq!(buf, expect);
                let mut a = vec![0u32; gen.round_len()];
                let mut b = vec![0u32; ser.round_len()];
                gen.fill_round(&mut a);
                ser.fill_round(&mut b);
                assert_eq!(a, b, "returned generator diverged from serial");
            }
            GenerateOutcome::Panicked(p) => resume_unwind(p),
        }
    }

    /// Shutdown with queued generate jobs drains cleanly: every receiver
    /// still gets its outcome (the workers finish the queue before
    /// exiting), and submits after shutdown report disconnected.
    #[test]
    fn shutdown_drains_inflight_generate_jobs() {
        let p = pool(1);
        let n = 2 * 8 * 63;
        let rxs: Vec<_> = (0..3)
            .map(|i| {
                p.submit_generate(
                    make_block_generator(GeneratorKind::XorgensGp, 100 + i, 8),
                    vec![0u32; n],
                )
            })
            .collect();
        p.shutdown();
        for (i, rx) in rxs.into_iter().enumerate() {
            match rx.recv().expect("queued job must still deliver after shutdown") {
                GenerateOutcome::Filled { buf, .. } => {
                    let mut ser = make_block_generator(GeneratorKind::XorgensGp, 100 + i as u64, 8);
                    let mut expect = vec![0u32; n];
                    ser.fill_interleaved(&mut expect);
                    assert_eq!(buf, expect, "job {i}");
                }
                GenerateOutcome::Panicked(p) => resume_unwind(p),
            }
        }
        let rx = p.submit_generate(make_block_generator(GeneratorKind::XorgensGp, 1, 8), vec![0; n]);
        assert!(rx.recv().is_err(), "post-shutdown submit must report disconnected");
        // Dispatches still complete on the caller after shutdown.
        let mut par = XorgensGp::new(9, 4);
        let mut ser = XorgensGp::new(9, 4);
        let m = 3 * par.round_len();
        let mut a = vec![0u32; m];
        let mut b = vec![0u32; m];
        assert!(p.fill_rounds(&mut par, &mut a));
        ser.fill_interleaved(&mut b);
        assert_eq!(a, b);
    }

    /// The pin shim is best-effort and must never fail a thread (smoke:
    /// run it for a couple of worker indices on this platform).
    #[test]
    fn pin_to_core_is_best_effort() {
        std::thread::spawn(|| {
            pin_to_core(0);
            pin_to_core(1000);
        })
        .join()
        .unwrap();
    }
}

//! # xorgens-gp
//!
//! Reproduction of *"High-Performance Pseudo-Random Number Generation on
//! Graphics Processing Units"* (Nandapalan, Brent, Murray, Rendell; 2011).
//!
//! The paper adapts Brent's **xorgens** family of xorshift+Weyl generators to
//! GPUs ("xorgensGP"), exploiting the observation that `min(s, r-s)` terms of
//! the recurrence
//!
//! ```text
//! x_i = x_{i-r} (I + L^a)(I + R^b)  ^  x_{i-s} (I + L^c)(I + R^d)
//! ```
//!
//! can be computed in parallel, and runs one independent subsequence per GPU
//! block. It compares speed (paper Table 1) and statistical quality under
//! TestU01 (paper Table 2) against MTGP and CURAND/XORWOW.
//!
//! ## The bulk-fill engine
//!
//! The entire data path is **slice-oriented**: random numbers move from the
//! recurrence kernels to consumers by filling caller-owned buffers, never by
//! per-draw calls on the hot path.
//!
//! * [`prng::BlockParallel::fill_round`] is the primitive: advance every
//!   block one lockstep round, writing `blocks × lane_width` words into a
//!   caller slice — zero allocation, bit-exact with simultaneous (GPU-warp)
//!   evaluation.
//! * [`prng::BlockParallel::fill_interleaved`] tiles whole rounds straight
//!   into arbitrarily large buffers; [`prng::traits::InterleavedStream`]
//!   adapts the same stream to [`prng::Prng32`] through a
//!   once-allocated, cursor-managed round buffer ([`prng::Prng32::fill_u32`]
//!   bypasses it for whole rounds).
//! * The battery consumes via a chunked scratch reader
//!   (`testu01::suite::ChunkedRng`): one virtual `fill_u32` per 4096 draws
//!   instead of one per draw.
//! * The coordinator's backends append into persistent buffers
//!   (`coordinator::Backend::launch_into`), and each stream buffers its
//!   remainder in an offset-cursor ring that never copy-compacts.
//! * Clients hold **typed stream handles**
//!   ([`coordinator::TypedStream`], built by
//!   [`coordinator::StreamBuilder`]): element types are fixed at the type
//!   level (`TypedStream<u32>` vs `TypedStream<f32>`), `draw_into`
//!   extends the caller-owned-buffer contract across the service boundary
//!   with pool-recycled replies, and `submit`/[`coordinator::Ticket`]
//!   pipeline requests against the sharded workers.
//!
//! * Large fills go **multi-threaded** through the parallel fill engine
//!   ([`exec`]): blocks are partitioned into disjoint ranges, workers
//!   write their blocks' strided lanes directly into the caller's
//!   slice ([`exec::fill_rounds_parallel`] per-dispatch, or the
//!   persistent [`exec::pool::FillPool`] on the serve path), and the
//!   output stays bit-identical to the serial interleaved stream. Opt
//!   in via `CoordinatorConfig::fill_threads`, the battery/bench
//!   `--threads` flags, or
//!   [`prng::BlockParallel::fill_interleaved_threaded`] /
//!   [`prng::BlockParallel::fill_interleaved_pooled`].
//! * The serve path **generates ahead**: with
//!   `CoordinatorConfig::prefetch` ≥ 1 (or
//!   [`coordinator::StreamBuilder::prefetch`]), each stream
//!   double-buffers its launches — the pool refills one buffer in the
//!   background while the client drains the other, so the steady-state
//!   draw is a memcpy. Hits/stalls surface in
//!   [`coordinator::MetricsSnapshot`].
//!
//! Golden-vector tests (rust/tests/golden.rs) pin the bulk path
//! byte-identical to scalar draws for every generator, against vectors
//! cross-generated from the independent NumPy oracles.
//!
//! ## Layers
//!
//! * [`prng`] — the generator library: serial [`prng::Xorgens`], the paper's
//!   block-parallel [`prng::XorgensGp`], a block-parallel Mersenne-Twister
//!   harness ([`prng::Mtgp`], built on a test-vector-exact
//!   [`prng::Mt19937`]), and the bit-exact CURAND default
//!   [`prng::Xorwow`].
//! * [`exec`] — the parallel fill engine: disjoint per-worker block
//!   ranges ([`exec::StridedOut`], [`exec::RangeFill`]) driven either
//!   by a per-dispatch scoped fan-out ([`exec::fill_rounds_parallel`])
//!   or by the persistent, optionally core-pinned
//!   [`exec::pool::FillPool`] with generation-ahead job submission —
//!   zero dependencies, bit-identical to the serial stream.
//! * [`simd`] — SIMD fill kernels: the CPU analogue of the paper's warp.
//!   A zero-dep portable vector layer over `core::arch` (SSE2/AVX2 on
//!   x86_64, NEON on aarch64) packs independent recurrence lanes per
//!   instruction for xorgensGP, MTGP, and XORWOW, with runtime detection
//!   and a process-wide override (`XORGENSGP_SIMD`, `serve/bench --simd`).
//!   Every kernel is bit-identical to the scalar stream — a pure
//!   data-layout transform — so SIMD composes multiplicatively with the
//!   thread pool and prefetch without touching any golden vector.
//! * [`gf2`] — GF(2) linear algebra: bit matrices, rank, Berlekamp–Massey,
//!   transition matrices, and polynomial jump-ahead ([`gf2::JumpEngine`])
//!   for xorshift-class generators.
//! * [`testu01`] — "crushr", a from-scratch TestU01-style statistical
//!   battery with SmallCrush/Crush/BigCrush-scaled tiers (paper Table 2).
//! * [`device`] — an analytical GPU device model (GTX 480 / GTX 295
//!   profiles, occupancy calculator) used to regenerate the two device
//!   columns of paper Table 1 on non-GPU hardware.
//! * [`runtime`] — PJRT client wrapper that loads and executes the
//!   AOT-compiled JAX/Pallas artifacts from `artifacts/` (behind the
//!   off-by-default `pjrt` cargo feature; a stub with clear errors
//!   otherwise, so the default build is fully offline).
//! * [`coordinator`] — the serving layer: stream registry with provably
//!   disjoint subsequences, dynamic batcher, a threaded request-loop
//!   service with pluggable (pure-Rust / PJRT) backends filling per-stream
//!   ring buffers in place, and the typed/pipelined client handle API
//!   ([`coordinator::handle`]).
//! * [`cluster`] — multi-process serving: a length-prefixed binary wire
//!   protocol over `std::net`, slot-range leases (shard `j` owns
//!   substream slots `j·2^32 ..`), shard servers wrapping coordinators,
//!   and a router with retry/failover whose routed streams are
//!   bit-identical to a single local coordinator.
//! * [`obs`] — end-to-end observability: a lock-free structured trace
//!   ring (causal `trace_id` from the client handle down to the fill
//!   pool and across the cluster wire), labeled metric families
//!   (per-stream / per-worker / per-shard) summing exactly to the
//!   legacy global snapshot, and a Prometheus/JSON scrape surface
//!   (`metrics` wire verb + `serve --metrics-addr` HTTP listener).
//! * [`util`] — substrates this offline build provides for itself: CLI
//!   parsing, a micro-benchmark harness, JSON emission, statistics
//!   helpers, a lightweight property-testing driver, and the
//!   anyhow-compatible error layer ([`util::error`]).
//!
//! ## Substream placement
//!
//! Parallel streams are identified by *where they live in the master
//! sequence* ([`prng::Placement`], threaded through
//! [`coordinator::StreamConfig`] and the handle builder): the default
//! `SeedMix` avalanche seeding, provably disjoint `ExactJump` substreams
//! (polynomial jump-ahead over each generator's minimal polynomial —
//! tractable even for the 4096-bit xorgens and MT-class states), or
//! round-robin `Leapfrog` dealing whose output is independent of the
//! block count. See the README "Stream placement" section.
//!
//! Python (JAX + Pallas) exists only on the compile path
//! (`python/compile/`): it authors the kernels and lowers them once to HLO
//! text in `artifacts/`; the Rust binary is self-contained afterwards.

pub mod cluster;
pub mod coordinator;
pub mod device;
pub mod exec;
pub mod gf2;
pub mod obs;
pub mod prng;
pub mod runtime;
pub mod simd;
pub mod testu01;
pub mod util;

pub use prng::{GeneratorKind, Prng32};

//! Minimal `std::net` HTTP/1.1 scrape surface: a background listener
//! serving `GET /metrics` (Prometheus text), `GET /metrics.json`, and
//! `GET /trace[?last=N]` (the span-journal dump), plus the tiny blocking
//! GET client the `stats`/`trace` CLI verbs use. Zero dependencies, one
//! thread per connection is deliberately avoided — scrapes are short, so
//! one accept thread handles connections serially.

use crate::obs::trace;
use crate::util::error::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How often the accept loop wakes to check the stop flag, and the
/// per-connection read deadline.
const POLL_INTERVAL: Duration = Duration::from_millis(25);

/// Closures the listener calls per scrape — how it stays decoupled from
/// the coordinator (the CLI builds these from an `Arc<Coordinator>`).
pub struct ScrapeHandlers {
    /// Body for `GET /metrics` (Prometheus text format).
    pub prometheus: Box<dyn Fn() -> String + Send + Sync>,
    /// Body for `GET /metrics.json`.
    pub json: Box<dyn Fn() -> String + Send + Sync>,
}

/// The background scrape listener. Dropping (or [`stop`](Self::stop))
/// shuts the accept thread down.
pub struct MetricsServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (`host:port`; port 0 picks a free one) and start
    /// serving scrapes built from `handlers`.
    pub fn bind(addr: &str, handlers: ScrapeHandlers) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics listener on {addr}"))?;
        let local = listener.local_addr().context("metrics listener local_addr")?;
        listener.set_nonblocking(true).context("metrics listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("metrics-http".into())
            .spawn(move || accept_loop(listener, handlers, stop2))
            .context("spawning metrics accept thread")?;
        Ok(MetricsServer { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread (idempotent).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, handlers: ScrapeHandlers, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((sock, _)) => {
                // Serve inline: scrapes are tiny and the listener is not
                // a production data path.
                let _ = serve_conn(sock, &handlers);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL_INTERVAL);
            }
            Err(_) => std::thread::sleep(POLL_INTERVAL),
        }
    }
}

fn serve_conn(mut sock: TcpStream, handlers: &ScrapeHandlers) -> std::io::Result<()> {
    sock.set_read_timeout(Some(Duration::from_secs(2)))?;
    let _ = sock.set_nodelay(true);
    // Read until the end of the request head (we ignore any body).
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        let n = sock.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 64 * 1024 {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let request_line = head.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    if method != "GET" {
        return respond(&mut sock, 405, "text/plain", "method not allowed\n");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    match path {
        "/metrics" => {
            let body = (handlers.prometheus)();
            respond(&mut sock, 200, "text/plain; version=0.0.4", &body)
        }
        "/metrics.json" => {
            let body = (handlers.json)();
            respond(&mut sock, 200, "application/json", &body)
        }
        "/trace" => {
            let last = query
                .split('&')
                .find_map(|kv| kv.strip_prefix("last="))
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(trace::TRACE_CAP);
            let body = trace::render_dump(&trace::dump(last));
            respond(&mut sock, 200, "text/plain", &body)
        }
        _ => respond(&mut sock, 404, "text/plain", "not found\n"),
    }
}

fn respond(
    sock: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    sock.write_all(head.as_bytes())?;
    sock.write_all(body.as_bytes())?;
    sock.flush()
}

/// Blocking one-shot `GET http://addr{path}`; returns the body. Used by
/// the `stats --watch` / `trace --last N` CLI verbs (and tests) so the
/// binary needs no HTTP client dependency.
pub fn http_get(addr: &str, path: &str) -> Result<String> {
    let mut sock =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    sock.set_read_timeout(Some(Duration::from_secs(5))).context("setting read timeout")?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    sock.write_all(req.as_bytes()).with_context(|| format!("sending GET {path}"))?;
    let mut raw = Vec::new();
    sock.read_to_end(&mut raw).with_context(|| format!("reading GET {path} reply"))?;
    let text = String::from_utf8_lossy(&raw).into_owned();
    let body = match text.split_once("\r\n\r\n") {
        Some((head, body)) => {
            let status = head.lines().next().unwrap_or("");
            ensure!(status.contains("200"), "GET {path} on {addr}: {status}");
            body.to_string()
        }
        None => bail!("GET {path} on {addr}: malformed HTTP reply"),
    };
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_server() -> MetricsServer {
        MetricsServer::bind(
            "127.0.0.1:0",
            ScrapeHandlers {
                prometheus: Box::new(|| "xg_requests_total 7\n".to_string()),
                json: Box::new(|| "{\"global\":{}}".to_string()),
            },
        )
        .unwrap()
    }

    #[test]
    fn scrape_roundtrip() {
        let mut s = test_server();
        let addr = s.addr().to_string();
        let prom = http_get(&addr, "/metrics").unwrap();
        assert_eq!(prom, "xg_requests_total 7\n");
        let json = http_get(&addr, "/metrics.json").unwrap();
        assert!(json.starts_with('{'));
        s.stop();
    }

    #[test]
    fn trace_endpoint_serves_dump() {
        let mut s = test_server();
        let addr = s.addr().to_string();
        let id = trace::next_trace_id();
        trace::record(id, trace::SpanKind::Route, 1, 2, 3);
        let body = http_get(&addr, "/trace?last=100000").unwrap();
        assert!(body.contains(&format!("trace {id}")), "{body}");
        s.stop();
    }

    #[test]
    fn unknown_path_is_404() {
        let mut s = test_server();
        let addr = s.addr().to_string();
        let err = http_get(&addr, "/nope").unwrap_err();
        assert!(format!("{err:#}").contains("404"), "{err:#}");
        s.stop();
    }
}

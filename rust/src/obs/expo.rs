//! Exposition: renders one coordinator's telemetry — the legacy global
//! snapshot plus the labeled per-stream / per-worker / per-shard
//! families — as Prometheus text format and as JSON (the `metrics` wire
//! verb and the `/metrics.json` scrape path).
//!
//! The global snapshot is emitted **verbatim** (same numbers as
//! [`MetricsSnapshot::render`]/`to_json`), and because every family
//! increment is paired with its global increment at the same site, the
//! families sum exactly to the global values: `sum_j
//! xg_stream_launches_total{stream=j} == xg_launches_total`, always.

use crate::coordinator::metrics::MetricsSnapshot;
use crate::obs::registry::{
    shard_counter_values, stream_counter_values, worker_stat_values, ShardCounters,
    StreamCounters, StreamLabels, WorkerStats,
};
use crate::util::json::Json;
use std::sync::Arc;

/// A point-in-time bundle of everything one coordinator exposes.
/// Build via [`Coordinator::exposition`](crate::coordinator::Coordinator::exposition);
/// render via [`to_prometheus`](Exposition::to_prometheus) /
/// [`to_json`](Exposition::to_json).
pub struct Exposition {
    /// The legacy global aggregate (bit-compatible with the `stats`
    /// verb).
    pub global: MetricsSnapshot,
    /// Per-stream families: `(stream id, labels, counters)`.
    pub streams: Vec<(u64, StreamLabels, Arc<StreamCounters>)>,
    /// Per-fill-worker stats; the **last** slot is the submitting-caller
    /// slot (part 0 + help-steals).
    pub workers: Vec<Arc<WorkerStats>>,
    /// Per-shard counters when this process serves as a cluster shard.
    pub shard: Option<(u64, Arc<ShardCounters>)>,
}

/// Every metric family name the exposition emits, in emission order —
/// the contract the CI scrape check greps for.
pub const FAMILY_NAMES: &[&str] = &[
    "xg_requests_total",
    "xg_numbers_served_total",
    "xg_launches_total",
    "xg_rejected_total",
    "xg_pool_hits_total",
    "xg_pool_misses_total",
    "xg_retries_total",
    "xg_failovers_total",
    "xg_prefetch_hits_total",
    "xg_prefetch_stalls_total",
    "xg_pool_queue_depth",
    "xg_latency_us_bucket",
    "xg_stream_requests_total",
    "xg_stream_numbers_served_total",
    "xg_stream_launches_total",
    "xg_stream_rejected_total",
    "xg_stream_pool_hits_total",
    "xg_stream_pool_misses_total",
    "xg_stream_prefetch_hits_total",
    "xg_stream_prefetch_stalls_total",
    "xg_worker_parts_total",
    "xg_worker_generates_total",
    "xg_worker_steals_total",
    "xg_worker_queue_wait_us_total",
    "xg_worker_fill_us_total",
    "xg_shard_lease_renews_total",
    "xg_shard_epoch_fences_total",
    "xg_shard_connections",
    "xg_shard_connections_total",
    "xg_simd_active_kernel",
    "xg_simd_fills_total",
];

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

impl Exposition {
    /// Prometheus text format, one `# TYPE`-annotated family at a time.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut counter = |name: &str, v: u64| {
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        };
        let g = &self.global;
        counter("xg_requests_total", g.requests);
        counter("xg_numbers_served_total", g.numbers_served);
        counter("xg_launches_total", g.launches);
        counter("xg_rejected_total", g.rejected);
        counter("xg_pool_hits_total", g.pool_hits);
        counter("xg_pool_misses_total", g.pool_misses);
        counter("xg_retries_total", g.retries);
        counter("xg_failovers_total", g.failovers);
        counter("xg_prefetch_hits_total", g.prefetch_hits);
        counter("xg_prefetch_stalls_total", g.prefetch_stalls);
        out.push_str(&format!(
            "# TYPE xg_pool_queue_depth gauge\nxg_pool_queue_depth {}\n",
            g.pool_queue_depth
        ));
        // Cumulative latency histogram, Prometheus-style le= buckets.
        out.push_str("# TYPE xg_latency_us_bucket counter\n");
        let mut acc = 0u64;
        for (i, &c) in g.lat_buckets.iter().enumerate() {
            acc += c;
            out.push_str(&format!(
                "xg_latency_us_bucket{{le=\"{}\"}} {acc}\n",
                1u64 << (i + 1)
            ));
        }
        out.push_str(&format!("xg_latency_us_bucket{{le=\"+Inf\"}} {acc}\n"));

        for field in [
            "requests",
            "numbers_served",
            "launches",
            "rejected",
            "pool_hits",
            "pool_misses",
            "prefetch_hits",
            "prefetch_stalls",
        ] {
            let name = format!("xg_stream_{field}_total");
            out.push_str(&format!("# TYPE {name} counter\n"));
            for (id, labels, c) in &self.streams {
                let v = stream_counter_values(c)
                    .iter()
                    .find(|(n, _)| *n == field)
                    .map(|(_, v)| *v)
                    .unwrap_or(0);
                out.push_str(&format!(
                    "{name}{{stream=\"{id}\",kind=\"{}\",placement=\"{}\",transform=\"{}\"}} {v}\n",
                    escape_label(&labels.kind),
                    escape_label(&labels.placement),
                    escape_label(&labels.transform),
                ));
            }
        }

        for field in ["parts", "generates", "steals", "queue_wait_us", "fill_us"] {
            let name = format!("xg_worker_{field}_total");
            out.push_str(&format!("# TYPE {name} counter\n"));
            let caller = self.workers.len().saturating_sub(1);
            for (i, w) in self.workers.iter().enumerate() {
                let v = worker_stat_values(w)
                    .iter()
                    .find(|(n, _)| *n == field)
                    .map(|(_, v)| *v)
                    .unwrap_or(0);
                let slot =
                    if i == caller { "caller".to_string() } else { format!("{i}") };
                out.push_str(&format!("{name}{{worker=\"{slot}\"}} {v}\n"));
            }
        }

        if let Some((shard, s)) = &self.shard {
            for (field, v) in shard_counter_values(s) {
                let (name, ty) = match field {
                    "connections" => ("xg_shard_connections".to_string(), "gauge"),
                    f => (format!("xg_shard_{f}_total"), "counter"),
                };
                out.push_str(&format!(
                    "# TYPE {name} {ty}\n{name}{{shard=\"{shard}\"}} {v}\n"
                ));
            }
        }

        // SIMD kernel selection ([`crate::simd`]) is process-wide, not
        // per-coordinator, so it is sampled here at render time: the
        // kernel fill dispatch currently resolves to (gauge value =
        // vector width in u32 lanes) and cumulative dispatches per
        // kernel (every kernel emitted, zero-valued when unused).
        let ak = crate::simd::active_kernel();
        out.push_str(&format!(
            "# TYPE xg_simd_active_kernel gauge\nxg_simd_active_kernel{{kernel=\"{}\"}} {}\n",
            ak.name(),
            ak.width()
        ));
        out.push_str("# TYPE xg_simd_fills_total counter\n");
        for (k, v) in crate::simd::fill_counts() {
            out.push_str(&format!("xg_simd_fills_total{{kernel=\"{}\"}} {v}\n", k.name()));
        }
        out
    }

    /// The JSON shape served by the `metrics` wire verb and
    /// `/metrics.json`: `{"global": <legacy to_json()>, "streams":
    /// [...], "workers": [...], "shard": {...}|null}`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.push("global", self.global.to_json());
        let mut streams = Vec::new();
        for (id, labels, c) in &self.streams {
            let mut s = Json::obj();
            s.push("stream", Json::Int(*id as i64))
                .push("kind", Json::Str(labels.kind.clone()))
                .push("placement", Json::Str(labels.placement.clone()))
                .push("transform", Json::Str(labels.transform.clone()));
            for (name, v) in stream_counter_values(c) {
                s.push(name, Json::Int(v as i64));
            }
            streams.push(s);
        }
        o.push("streams", Json::Arr(streams));
        let caller = self.workers.len().saturating_sub(1);
        let mut workers = Vec::new();
        for (i, w) in self.workers.iter().enumerate() {
            let mut ws = Json::obj();
            let slot = if i == caller { "caller".to_string() } else { format!("{i}") };
            ws.push("worker", Json::Str(slot));
            for (name, v) in worker_stat_values(w) {
                ws.push(name, Json::Int(v as i64));
            }
            workers.push(ws);
        }
        o.push("workers", Json::Arr(workers));
        match &self.shard {
            Some((shard, s)) => {
                let mut sh = Json::obj();
                sh.push("shard", Json::Int(*shard as i64));
                for (name, v) in shard_counter_values(s) {
                    sh.push(name, Json::Int(v as i64));
                }
                o.push("shard", sh);
            }
            None => {
                o.push("shard", Json::Null);
            }
        }
        // Process-wide SIMD kernel state, sampled at render time (same
        // data as the Prometheus gauge/counters above).
        let ak = crate::simd::active_kernel();
        let mut simd = Json::obj();
        simd.push("active_kernel", Json::Str(ak.name().to_string()))
            .push("width", Json::Int(ak.width() as i64));
        let mut fills = Json::obj();
        for (k, v) in crate::simd::fill_counts() {
            fills.push(k.name(), Json::Int(v as i64));
        }
        simd.push("fills", fills);
        o.push("simd", simd);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;
    use std::sync::atomic::Ordering;

    fn sample() -> Exposition {
        let m = Metrics::new();
        m.requests.fetch_add(2, Ordering::Relaxed);
        m.launches.fetch_add(3, Ordering::Relaxed);
        m.record_latency(std::time::Duration::from_micros(100));
        let sc = Arc::new(StreamCounters::default());
        sc.requests.fetch_add(2, Ordering::Relaxed);
        sc.launches.fetch_add(3, Ordering::Relaxed);
        let w = Arc::new(WorkerStats::default());
        w.parts.fetch_add(4, Ordering::Relaxed);
        let sh = Arc::new(ShardCounters::default());
        sh.lease_renews.fetch_add(5, Ordering::Relaxed);
        Exposition {
            global: m.snapshot(),
            streams: vec![(
                0,
                StreamLabels {
                    kind: "xorgensgp".into(),
                    placement: "seed-mix".into(),
                    transform: "u32".into(),
                },
                sc,
            )],
            workers: vec![w],
            shard: Some((1, sh)),
        }
    }

    #[test]
    fn prometheus_contains_every_family() {
        let text = sample().to_prometheus();
        for fam in FAMILY_NAMES {
            assert!(text.contains(fam), "family {fam} missing from:\n{text}");
        }
        assert!(text.contains("xg_requests_total 2"), "{text}");
        assert!(
            text.contains("xg_stream_launches_total{stream=\"0\",kind=\"xorgensgp\""),
            "{text}"
        );
        assert!(text.contains("xg_shard_lease_renews_total{shard=\"1\"} 5"), "{text}");
        assert!(text.contains("le=\"+Inf\""), "{text}");
        // All four SIMD kernels appear, used or not.
        for k in crate::simd::SimdKernel::ALL {
            assert!(
                text.contains(&format!("xg_simd_fills_total{{kernel=\"{}\"}}", k.name())),
                "{text}"
            );
        }
        let ak = crate::simd::active_kernel();
        assert!(
            text.contains(&format!(
                "xg_simd_active_kernel{{kernel=\"{}\"}} {}",
                ak.name(),
                ak.width()
            )),
            "{text}"
        );
    }

    #[test]
    fn json_nests_global_and_families() {
        let j = sample().to_json().to_string();
        assert!(j.contains(r#""global":{"requests":2"#), "{j}");
        assert!(j.contains(r#""streams":[{"stream":0"#), "{j}");
        assert!(j.contains(r#""workers":[{"worker":"caller""#), "{j}");
        assert!(j.contains(r#""shard":{"shard":1"#), "{j}");
        assert!(j.contains(r#""lease_renews":5"#), "{j}");
        assert!(j.contains(r#""simd":{"active_kernel":"#), "{j}");
        assert!(j.contains(r#""scalar":"#), "{j}");
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let text = sample().to_prometheus();
        // One 100µs sample lands in the 64..128 bucket; every le >= 128
        // then reports 1, including +Inf.
        assert!(text.contains("xg_latency_us_bucket{le=\"128\"} 1"), "{text}");
        assert!(text.contains("xg_latency_us_bucket{le=\"64\"} 0"), "{text}");
        assert!(text.contains("xg_latency_us_bucket{le=\"+Inf\"} 1"), "{text}");
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("a\"b\\c"), "a\\\"b\\\\c");
    }
}

//! Structured trace layer: a fixed-capacity, lock-free ring journal of
//! typed spans, correlated across threads (and, over the cluster wire,
//! across processes on the same host) by a causal `trace_id` minted at
//! the client handle.
//!
//! Recording is wait-free on the hot path: one `fetch_add` on the write
//! cursor plus a handful of relaxed stores into the claimed slot, all
//! behind a process-global enable flag so the bench ablation (and any
//! latency-critical deployment) can turn the journal off entirely.
//! Readers use a per-slot seqlock: a slot whose sequence word changes
//! between the pre- and post-read is discarded, so a dump never blocks
//! a writer and never returns a torn record (a concurrent full-ring
//! wrap during one write could in principle alias two writers onto one
//! slot; with a 4096-slot ring that window is negligible for telemetry).

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Ring capacity in spans (power of two; the newest `TRACE_CAP` spans
/// survive).
pub const TRACE_CAP: usize = 4096;

/// What a span measured. Each variant corresponds to one instrumented
/// site in the stack; together they reconstruct the life of a draw from
/// the client handle down to the fill-pool worker that generated its
/// words.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Client-side: submit → reply receipt (`arg` = elements drawn).
    /// Recorded by the typed handle and, server-side, around the shard's
    /// submit → reply wait.
    Draw,
    /// Coordinator worker: one request served through the backend
    /// (`arg` = elements).
    Launch,
    /// Fill-pool worker: one generation-ahead buffer refill (`arg` =
    /// words filled).
    Generate,
    /// Fill-pool worker or help-stealing caller: one block-range part
    /// of a partitioned fill (`arg` = worker slot that ran it).
    FillPart,
    /// Router: one routed draw, submit → reply (`arg` = elements).
    Route,
    /// Router: a shard died and a stream re-homed (instantaneous;
    /// `arg` = the dead shard id).
    Failover,
}

impl SpanKind {
    /// Stable wire/code number (also the order `render` groups by).
    pub fn code(self) -> u64 {
        match self {
            SpanKind::Draw => 1,
            SpanKind::Launch => 2,
            SpanKind::Generate => 3,
            SpanKind::FillPart => 4,
            SpanKind::Route => 5,
            SpanKind::Failover => 6,
        }
    }

    /// Inverse of [`code`](SpanKind::code); `None` for junk.
    pub fn from_code(code: u64) -> Option<SpanKind> {
        Some(match code {
            1 => SpanKind::Draw,
            2 => SpanKind::Launch,
            3 => SpanKind::Generate,
            4 => SpanKind::FillPart,
            5 => SpanKind::Route,
            6 => SpanKind::Failover,
            _ => return None,
        })
    }

    /// Lowercase label used in dumps and the `/trace` endpoint.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Draw => "draw",
            SpanKind::Launch => "launch",
            SpanKind::Generate => "generate",
            SpanKind::FillPart => "fill_part",
            SpanKind::Route => "route",
            SpanKind::Failover => "failover",
        }
    }
}

/// One completed span as read back out of the ring.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// Causal id minted at the client handle (0 never appears in the
    /// ring — it is the "untraced" sentinel at recording sites).
    pub trace_id: u64,
    pub kind: SpanKind,
    /// Microseconds since the process trace epoch.
    pub start_us: u64,
    pub end_us: u64,
    /// Kind-specific payload (see [`SpanKind`]).
    pub arg: u64,
}

struct Slot {
    /// 0 = never written; odd = write in progress; even = committed
    /// (value `2·ticket + 2`, so every rewrite changes it).
    seq: AtomicU64,
    trace: AtomicU64,
    kind: AtomicU64,
    start_us: AtomicU64,
    end_us: AtomicU64,
    arg: AtomicU64,
}

impl Slot {
    const fn empty() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            trace: AtomicU64::new(0),
            kind: AtomicU64::new(0),
            start_us: AtomicU64::new(0),
            end_us: AtomicU64::new(0),
            arg: AtomicU64::new(0),
        }
    }
}

/// The process-global span journal. Normally reached through the free
/// functions ([`record`], [`dump`]); the struct is public so tests can
/// own private rings.
pub struct Tracer {
    slots: Box<[Slot]>,
    cursor: AtomicU64,
    enabled: AtomicBool,
}

impl Tracer {
    /// A fresh ring of [`TRACE_CAP`] slots, enabled.
    pub fn new() -> Tracer {
        let slots: Vec<Slot> = (0..TRACE_CAP).map(|_| Slot::empty()).collect();
        Tracer {
            slots: slots.into_boxed_slice(),
            cursor: AtomicU64::new(0),
            enabled: AtomicBool::new(true),
        }
    }

    /// Is recording on? Sites check this before taking timestamps so a
    /// disabled tracer costs one relaxed load per span site.
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Turn recording on/off (the bench ablation flips this).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Append one completed span. Wait-free; silently drops nothing
    /// (old spans are overwritten ring-wise). A `trace_id` of 0 or a
    /// disabled tracer is a no-op.
    pub fn record(&self, trace_id: u64, kind: SpanKind, start_us: u64, end_us: u64, arg: u64) {
        if trace_id == 0 || !self.is_enabled() {
            return;
        }
        let t = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(t as usize) & (TRACE_CAP - 1)];
        slot.seq.store(2 * t + 1, Ordering::Release);
        slot.trace.store(trace_id, Ordering::Relaxed);
        slot.kind.store(kind.code(), Ordering::Relaxed);
        slot.start_us.store(start_us, Ordering::Relaxed);
        slot.end_us.store(end_us, Ordering::Relaxed);
        slot.arg.store(arg, Ordering::Relaxed);
        slot.seq.store(2 * t + 2, Ordering::Release);
    }

    /// Snapshot up to `last` most-recent committed spans, oldest first
    /// (sorted by start, then end). Slots mid-write are skipped, never
    /// waited on.
    pub fn dump(&self, last: usize) -> Vec<SpanRecord> {
        let mut out = Vec::new();
        for slot in self.slots.iter() {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue;
            }
            let trace_id = slot.trace.load(Ordering::Relaxed);
            let kind = slot.kind.load(Ordering::Relaxed);
            let start_us = slot.start_us.load(Ordering::Relaxed);
            let end_us = slot.end_us.load(Ordering::Relaxed);
            let arg = slot.arg.load(Ordering::Relaxed);
            std::sync::atomic::fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) != s1 {
                continue; // overwritten while reading: discard, don't tear
            }
            let Some(kind) = SpanKind::from_code(kind) else { continue };
            out.push(SpanRecord { trace_id, kind, start_us, end_us, arg });
        }
        out.sort_by_key(|r| (r.start_us, r.end_us, r.kind.code()));
        if out.len() > last {
            out.drain(..out.len() - last);
        }
        out
    }
}

impl Default for Tracer {
    fn default() -> Tracer {
        Tracer::new()
    }
}

static TRACER: OnceLock<Tracer> = OnceLock::new();
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    /// The trace id "in scope" on this thread — how layers that cannot
    /// take a trace parameter (the fill pool's nested part fan-out)
    /// inherit causality from the request being served.
    static CURRENT_TRACE: Cell<u64> = const { Cell::new(0) };
}

/// The process-global tracer (created on first use, enabled).
pub fn tracer() -> &'static Tracer {
    TRACER.get_or_init(Tracer::new)
}

/// Mint a fresh, process-unique, non-zero causal trace id.
pub fn next_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
}

/// Microseconds since the process trace epoch (first call wins).
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Is the global tracer recording?
pub fn enabled() -> bool {
    tracer().is_enabled()
}

/// Enable/disable the global tracer (bench ablation, quiet deployments).
pub fn set_enabled(on: bool) {
    tracer().set_enabled(on);
}

/// Append one completed span to the global ring.
pub fn record(trace_id: u64, kind: SpanKind, start_us: u64, end_us: u64, arg: u64) {
    tracer().record(trace_id, kind, start_us, end_us, arg);
}

/// Snapshot the last `last` spans from the global ring, oldest first.
pub fn dump(last: usize) -> Vec<SpanRecord> {
    tracer().dump(last)
}

/// The trace id currently in scope on this thread (0 = none).
pub fn current_trace() -> u64 {
    CURRENT_TRACE.with(|c| c.get())
}

/// Put `trace` in scope on this thread; returns the previous value so
/// callers can restore it (scopes nest).
pub fn set_current_trace(trace: u64) -> u64 {
    CURRENT_TRACE.with(|c| c.replace(trace))
}

/// Start/finish helper: captures the start timestamp only when tracing
/// is live for this span, so disabled tracing costs one relaxed load.
#[derive(Clone, Copy, Debug)]
pub struct SpanTimer {
    trace: u64,
    kind: SpanKind,
    start_us: u64,
    active: bool,
}

impl SpanTimer {
    /// Begin a span for `trace` (inactive — and free — when `trace` is
    /// 0 or tracing is disabled).
    pub fn start(trace: u64, kind: SpanKind) -> SpanTimer {
        let active = trace != 0 && enabled();
        SpanTimer { trace, kind, start_us: if active { now_us() } else { 0 }, active }
    }

    /// End the span now and commit it with `arg`.
    pub fn finish(self, arg: u64) {
        if self.active {
            record(self.trace, self.kind, self.start_us, now_us(), arg);
        }
    }
}

/// Render a dump as the human timeline `trace dump` prints: one line
/// per span, grouped by trace id, indented by layer depth.
pub fn render_dump(records: &[SpanRecord]) -> String {
    let mut ids: Vec<u64> = records.iter().map(|r| r.trace_id).collect();
    ids.sort_unstable();
    ids.dedup();
    let mut out = String::new();
    for id in ids {
        out.push_str(&format!("trace {id}\n"));
        for r in records.iter().filter(|r| r.trace_id == id) {
            let indent = match r.kind {
                SpanKind::Route | SpanKind::Failover => 1,
                SpanKind::Draw => 2,
                SpanKind::Launch => 3,
                SpanKind::Generate | SpanKind::FillPart => 4,
            };
            out.push_str(&format!(
                "{:indent$}{:<9} [{:>10} .. {:>10}] us  arg={}\n",
                "",
                r.kind.name(),
                r.start_us,
                r.end_us,
                r.arg,
                indent = indent * 2
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_dump_roundtrip() {
        let t = Tracer::new();
        t.record(7, SpanKind::Draw, 10, 20, 1000);
        t.record(7, SpanKind::Launch, 12, 18, 1000);
        let d = t.dump(16);
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].kind, SpanKind::Draw);
        assert_eq!(d[1].kind, SpanKind::Launch);
        assert!(d.iter().all(|r| r.trace_id == 7));
    }

    #[test]
    fn zero_trace_and_disabled_are_dropped() {
        let t = Tracer::new();
        t.record(0, SpanKind::Draw, 1, 2, 3);
        t.set_enabled(false);
        t.record(9, SpanKind::Draw, 1, 2, 3);
        assert!(t.dump(16).is_empty());
        t.set_enabled(true);
        t.record(9, SpanKind::Draw, 1, 2, 3);
        assert_eq!(t.dump(16).len(), 1);
    }

    #[test]
    fn ring_keeps_newest_spans() {
        let t = Tracer::new();
        for i in 0..(TRACE_CAP as u64 + 10) {
            t.record(1, SpanKind::Launch, i, i + 1, i);
        }
        let d = t.dump(TRACE_CAP * 2);
        assert_eq!(d.len(), TRACE_CAP);
        // The oldest 10 were overwritten.
        assert!(d.iter().all(|r| r.start_us >= 10));
    }

    #[test]
    fn dump_last_n_truncates_from_the_front() {
        let t = Tracer::new();
        for i in 0..10u64 {
            t.record(1, SpanKind::Draw, i, i + 1, 0);
        }
        let d = t.dump(3);
        assert_eq!(d.len(), 3);
        assert_eq!(d[0].start_us, 7);
    }

    #[test]
    fn current_trace_scopes_and_restores() {
        assert_eq!(current_trace(), 0);
        let prev = set_current_trace(42);
        assert_eq!(prev, 0);
        assert_eq!(current_trace(), 42);
        set_current_trace(prev);
        assert_eq!(current_trace(), 0);
    }

    #[test]
    fn span_timer_records_only_active() {
        let base = dump(usize::MAX).len();
        let s = SpanTimer::start(0, SpanKind::Draw);
        s.finish(1);
        assert_eq!(dump(usize::MAX).len(), base, "trace 0 must not record");
        let id = next_trace_id();
        let s = SpanTimer::start(id, SpanKind::Draw);
        s.finish(5);
        let d = dump(usize::MAX);
        assert!(d.iter().any(|r| r.trace_id == id && r.arg == 5));
    }

    #[test]
    fn kind_codes_roundtrip() {
        for k in [
            SpanKind::Draw,
            SpanKind::Launch,
            SpanKind::Generate,
            SpanKind::FillPart,
            SpanKind::Route,
            SpanKind::Failover,
        ] {
            assert_eq!(SpanKind::from_code(k.code()), Some(k));
        }
        assert_eq!(SpanKind::from_code(0), None);
        assert_eq!(SpanKind::from_code(99), None);
    }

    #[test]
    fn render_groups_by_trace() {
        let recs = vec![
            SpanRecord { trace_id: 2, kind: SpanKind::Route, start_us: 0, end_us: 5, arg: 10 },
            SpanRecord { trace_id: 2, kind: SpanKind::Launch, start_us: 1, end_us: 4, arg: 10 },
        ];
        let s = render_dump(&recs);
        assert!(s.contains("trace 2"));
        assert!(s.contains("route"));
        assert!(s.contains("launch"));
    }
}

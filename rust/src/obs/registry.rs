//! Labeled metric families layered over the legacy global
//! [`Metrics`](crate::coordinator::MetricsSnapshot) aggregate.
//!
//! The registry does **not** replace the global counters — every
//! labeled site increments its family counter *and* the matching global
//! one at the same instruction site, so the per-stream / per-worker /
//! per-shard families always sum exactly to the legacy snapshot (the
//! bit-compatibility the stats verb and `--stats-json` consumers rely
//! on). Hot paths touch only pre-resolved `Arc`s of atomics: family
//! lookup happens once, at stream registration / pool construction /
//! shard bind, never per draw.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// The label set of a per-stream family: `kind × placement × transform`
/// (all lowercase, as the builder spells them).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamLabels {
    pub kind: String,
    pub placement: String,
    pub transform: String,
}

/// Per-stream counters. Every field pairs with (and sums to) the
/// identically named global counter; increments happen at the same
/// sites in the coordinator's worker loop and backend.
#[derive(Debug, Default)]
pub struct StreamCounters {
    pub requests: AtomicU64,
    pub numbers_served: AtomicU64,
    pub launches: AtomicU64,
    pub rejected: AtomicU64,
    pub pool_hits: AtomicU64,
    pub pool_misses: AtomicU64,
    pub prefetch_hits: AtomicU64,
    pub prefetch_stalls: AtomicU64,
}

/// Per-fill-worker counters (slot `workers()` is the submitting-caller
/// slot: part 0 plus help-steals run there).
#[derive(Debug, Default)]
pub struct WorkerStats {
    /// Block-range parts executed on this slot.
    pub parts: AtomicU64,
    /// Generation-ahead buffer refills executed on this slot.
    pub generates: AtomicU64,
    /// Parts this slot stole while waiting on a latch (callers only;
    /// pool workers' pops are their normal work, not steals).
    pub steals: AtomicU64,
    /// Total µs tasks spent queued before this slot picked them up.
    pub queue_wait_us: AtomicU64,
    /// Total µs this slot spent filling (parts + generates).
    pub fill_us: AtomicU64,
}

/// Per-shard counters, live only on a process serving as a cluster
/// shard (see [`ObsRegistry::set_shard`]).
#[derive(Debug, Default)]
pub struct ShardCounters {
    /// Successful lease renewals served (the router's health probe).
    pub lease_renews: AtomicU64,
    /// Lapsed-lease re-grants: each one advances the fencing epoch.
    pub epoch_fences: AtomicU64,
    /// Currently open client connections (gauge).
    pub connections: AtomicU64,
    /// Connections ever accepted.
    pub connections_total: AtomicU64,
}

/// One coordinator's family registry: per-stream counters keyed by
/// stream id, plus the optional shard identity. (Per-worker stats live
/// in the [`FillPool`](crate::exec::pool::FillPool) itself, which owns
/// the worker threads.)
#[derive(Default)]
pub struct ObsRegistry {
    streams: Mutex<Vec<(u64, StreamLabels, Arc<StreamCounters>)>>,
    shard: OnceLock<(u64, Arc<ShardCounters>)>,
}

impl ObsRegistry {
    pub fn new() -> ObsRegistry {
        ObsRegistry::default()
    }

    /// The counters for stream `id`, created with `labels` on first
    /// touch. Callers cache the returned `Arc`; this lock is cold-path
    /// only (registration / first request per stream per worker).
    pub fn stream(&self, id: u64, labels: impl FnOnce() -> StreamLabels) -> Arc<StreamCounters> {
        let mut streams = self.streams.lock().unwrap();
        if let Some((_, _, c)) = streams.iter().find(|(sid, _, _)| *sid == id) {
            return Arc::clone(c);
        }
        let c = Arc::new(StreamCounters::default());
        streams.push((id, labels(), Arc::clone(&c)));
        c
    }

    /// Snapshot every per-stream family, ordered by stream id.
    pub fn streams(&self) -> Vec<(u64, StreamLabels, Arc<StreamCounters>)> {
        let mut v: Vec<_> = self
            .streams
            .lock()
            .unwrap()
            .iter()
            .map(|(id, l, c)| (*id, l.clone(), Arc::clone(c)))
            .collect();
        v.sort_by_key(|(id, _, _)| *id);
        v
    }

    /// Mark this coordinator as cluster shard `id` (idempotent; the
    /// first id wins) and return its counters.
    pub fn set_shard(&self, id: u64) -> Arc<ShardCounters> {
        let (_, c) = self.shard.get_or_init(|| (id, Arc::new(ShardCounters::default())));
        Arc::clone(c)
    }

    /// The shard identity and counters, if [`set_shard`](Self::set_shard)
    /// ran.
    pub fn shard(&self) -> Option<(u64, Arc<ShardCounters>)> {
        self.shard.get().map(|(id, c)| (*id, Arc::clone(c)))
    }
}

impl std::fmt::Debug for ObsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsRegistry")
            .field("streams", &self.streams.lock().unwrap().len())
            .field("shard", &self.shard.get().map(|(id, _)| *id))
            .finish()
    }
}

/// Group labeled stream counters by label set, summing counters — the
/// family aggregation the Prometheus exposition renders (`stream` stays
/// a label, so per-id series remain distinguishable; this helper is for
/// consumers that want the `kind × placement × transform` rollup).
pub fn rollup_by_labels(
    streams: &[(u64, StreamLabels, Arc<StreamCounters>)],
) -> Vec<(StreamLabels, HashMap<&'static str, u64>)> {
    let mut out: Vec<(StreamLabels, HashMap<&'static str, u64>)> = Vec::new();
    for (_, labels, c) in streams {
        let entry = match out.iter_mut().find(|(l, _)| l == labels) {
            Some((_, m)) => m,
            None => {
                out.push((labels.clone(), HashMap::new()));
                &mut out.last_mut().unwrap().1
            }
        };
        for (name, v) in stream_counter_values(c) {
            *entry.entry(name).or_insert(0) += v;
        }
    }
    out
}

/// The (name, value) pairs of one [`StreamCounters`] — single source of
/// truth for every exposition format.
pub fn stream_counter_values(c: &StreamCounters) -> [(&'static str, u64); 8] {
    [
        ("requests", c.requests.load(Ordering::Relaxed)),
        ("numbers_served", c.numbers_served.load(Ordering::Relaxed)),
        ("launches", c.launches.load(Ordering::Relaxed)),
        ("rejected", c.rejected.load(Ordering::Relaxed)),
        ("pool_hits", c.pool_hits.load(Ordering::Relaxed)),
        ("pool_misses", c.pool_misses.load(Ordering::Relaxed)),
        ("prefetch_hits", c.prefetch_hits.load(Ordering::Relaxed)),
        ("prefetch_stalls", c.prefetch_stalls.load(Ordering::Relaxed)),
    ]
}

/// The (name, value) pairs of one [`WorkerStats`].
pub fn worker_stat_values(w: &WorkerStats) -> [(&'static str, u64); 5] {
    [
        ("parts", w.parts.load(Ordering::Relaxed)),
        ("generates", w.generates.load(Ordering::Relaxed)),
        ("steals", w.steals.load(Ordering::Relaxed)),
        ("queue_wait_us", w.queue_wait_us.load(Ordering::Relaxed)),
        ("fill_us", w.fill_us.load(Ordering::Relaxed)),
    ]
}

/// The (name, value) pairs of one [`ShardCounters`].
pub fn shard_counter_values(s: &ShardCounters) -> [(&'static str, u64); 4] {
    [
        ("lease_renews", s.lease_renews.load(Ordering::Relaxed)),
        ("epoch_fences", s.epoch_fences.load(Ordering::Relaxed)),
        ("connections", s.connections.load(Ordering::Relaxed)),
        ("connections_total", s.connections_total.load(Ordering::Relaxed)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(kind: &str) -> StreamLabels {
        StreamLabels {
            kind: kind.into(),
            placement: "seed-mix".into(),
            transform: "u32".into(),
        }
    }

    #[test]
    fn stream_is_get_or_create() {
        let r = ObsRegistry::new();
        let a = r.stream(3, || labels("xorgensgp"));
        a.requests.fetch_add(5, Ordering::Relaxed);
        let b = r.stream(3, || labels("IGNORED-on-second-touch"));
        assert_eq!(b.requests.load(Ordering::Relaxed), 5, "same Arc");
        assert_eq!(r.streams().len(), 1);
        assert_eq!(r.streams()[0].1.kind, "xorgensgp");
    }

    #[test]
    fn streams_sorted_by_id() {
        let r = ObsRegistry::new();
        r.stream(9, || labels("a"));
        r.stream(1, || labels("b"));
        let ids: Vec<u64> = r.streams().iter().map(|(id, _, _)| *id).collect();
        assert_eq!(ids, vec![1, 9]);
    }

    #[test]
    fn shard_set_once() {
        let r = ObsRegistry::new();
        assert!(r.shard().is_none());
        let c = r.set_shard(2);
        c.lease_renews.fetch_add(1, Ordering::Relaxed);
        let again = r.set_shard(7); // first id wins
        assert_eq!(again.lease_renews.load(Ordering::Relaxed), 1);
        assert_eq!(r.shard().unwrap().0, 2);
    }

    #[test]
    fn rollup_sums_same_label_sets() {
        let r = ObsRegistry::new();
        r.stream(1, || labels("x")).launches.fetch_add(3, Ordering::Relaxed);
        r.stream(2, || labels("x")).launches.fetch_add(4, Ordering::Relaxed);
        r.stream(3, || labels("y")).launches.fetch_add(5, Ordering::Relaxed);
        let roll = rollup_by_labels(&r.streams());
        assert_eq!(roll.len(), 2);
        let x = roll.iter().find(|(l, _)| l.kind == "x").unwrap();
        assert_eq!(x.1["launches"], 7);
        let y = roll.iter().find(|(l, _)| l.kind == "y").unwrap();
        assert_eq!(y.1["launches"], 5);
    }
}

//! End-to-end observability: structured tracing, labeled metric
//! families, and the scrape surface — zero dependencies, atomics-only
//! on every hot path.
//!
//! Three pieces:
//!
//! * [`trace`] — a lock-free ring journal of typed spans
//!   ([`SpanKind`]: `draw`, `launch`, `generate`, `fill_part`, `route`,
//!   `failover`), correlated by a causal `trace_id` minted at the
//!   client handle and threaded through `submit`, the fill-pool job
//!   queue, the prefetch double-buffer, and (as an optional wire-frame
//!   field) the cluster protocol. `trace::dump` + `render_dump`
//!   reconstruct the cross-thread timeline of any draw.
//! * [`registry`] — labeled counter families layered **on top of** the
//!   legacy global [`Metrics`](crate::coordinator::MetricsSnapshot):
//!   per-stream (`kind × placement × transform`), per-fill-worker
//!   (parts, generates, steals, queue wait, fill time), per-shard
//!   (lease renews, epoch fences, connections). Every family increment
//!   pairs with its global increment at the same site, so families sum
//!   exactly to the legacy snapshot and existing `render`/`to_json`
//!   consumers see unchanged output.
//! * [`expo`] + [`http`] — exposition: Prometheus text and JSON
//!   renders of one coordinator's [`Exposition`], served by the
//!   `metrics` wire verb and the `serve --metrics-addr` HTTP listener
//!   (`/metrics`, `/metrics.json`, `/trace`), and consumed by the
//!   `stats --watch` / `trace --last N` CLI verbs.

pub mod expo;
pub mod http;
pub mod registry;
pub mod trace;

pub use expo::{Exposition, FAMILY_NAMES};
pub use http::{http_get, MetricsServer, ScrapeHandlers};
pub use registry::{ObsRegistry, ShardCounters, StreamCounters, StreamLabels, WorkerStats};
pub use trace::{
    current_trace, dump, enabled, next_trace_id, now_us, record, render_dump,
    set_current_trace, set_enabled, SpanKind, SpanRecord, SpanTimer, Tracer,
};

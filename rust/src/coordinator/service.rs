//! The coordinator service: sharded worker threads, bounded queues
//! (backpressure), dynamic batching per stream.
//!
//! Offline-build note: tokio is unavailable, so the event loop is built on
//! `std::sync::mpsc` + worker threads — one worker owns each shard of
//! streams (shard = id % workers), so stream state needs no locking; the
//! request path is: client → bounded shard queue → worker drains a batch →
//! `plan_batch` → backend launches → per-request replies over oneshot
//! channels. This is the same shape as an async runtime's actor loop.

use super::backend::{Backend, BackendKind, Draws, PjrtBackend, RustBackend};
use super::batcher::{group_fifo, plan_batch, PendingRequest};
use super::handle::{BufferPool, Sample, StreamBuilder, TypedStream};
use super::metrics::{Metrics, MetricsSnapshot};
use super::stream::{StreamConfig, StreamId, StreamRegistry};
use crate::exec::pool::{FillPool, PoolConfig};
use crate::obs::registry::{ObsRegistry, StreamCounters, StreamLabels};
use crate::obs::trace::{self as otrace, SpanKind, SpanTimer};
use crate::obs::Exposition;
use crate::util::error::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub root_seed: u64,
    pub workers: usize,
    /// Bounded queue depth per worker (backpressure: `draw` returns an
    /// error when the queue is full and `block_on_full` is false).
    pub queue_depth: usize,
    pub block_on_full: bool,
    /// Artifacts dir for PJRT-backed streams.
    pub artifact_dir: PathBuf,
    /// Max requests drained per batching cycle.
    pub max_batch: usize,
    /// Worker threads per bulk fill inside a Rust backend launch (the
    /// parallel fill engine, [`crate::exec`]); 1 = serial. Streams are
    /// bit-identical for every value. Defaults to 1, overridable via the
    /// `XORGENSGP_FILL_THREADS` env var (how the CI oversubscription job
    /// pushes the whole suite through the threaded path).
    pub fill_threads: usize,
    /// Default generation-ahead depth, in launches per background job
    /// (the double-buffer prefetch): 0 (the default) serves launches
    /// inline; `d >= 1` keeps the next `d` launches of every U32/F32
    /// Rust-backed stream generating on the fill pool while the current
    /// buffer drains, making steady-state draw latency a memcpy. Streams
    /// are bit-identical for every value. Overridable per stream via
    /// [`StreamConfig::prefetch`] / `StreamBuilder::prefetch`, and via
    /// the `XORGENSGP_PREFETCH` env var here.
    pub prefetch: usize,
    /// Pin the fill-pool workers round-robin across cores (Linux only —
    /// the zero-dep `sched_setaffinity` shim; a no-op elsewhere).
    pub pin_fill_workers: bool,
    /// Leased substream-slot range for exact-jump placement. `None` (the
    /// default) leaves the registry on the full `0..u64::MAX` space — the
    /// single-process behavior. A cluster shard sets this to its leased
    /// range ([`crate::cluster::lease::shard_slot_range`]: shard `j` owns
    /// `j·2^32 .. (j+1)·2^32`), which keeps exact-jump substreams
    /// provably disjoint *across* coordinator processes with no central
    /// coordination. Explicit [`StreamConfig::slot_base`] assignments
    /// (the router's global allocation) bypass the range.
    pub substream_slots: Option<std::ops::Range<u64>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            root_seed: 0x9e37_79b9,
            workers: 2,
            queue_depth: 1024,
            block_on_full: true,
            artifact_dir: crate::runtime::default_dir(),
            max_batch: 64,
            fill_threads: env_usize("XORGENSGP_FILL_THREADS", 1, 1),
            prefetch: env_usize("XORGENSGP_PREFETCH", 0, 0),
            pin_fill_workers: false,
            substream_slots: None,
        }
    }
}

/// Read a `usize` knob from the environment: unset → `default`; a valid
/// value is clamped to at least `min`; an **invalid** value is no longer
/// silently ignored — it logs a one-line warning carrying the typed parse
/// error and falls back to `default`.
fn env_usize(var: &str, default: usize, min: usize) -> usize {
    parse_env_usize(var, std::env::var(var).ok().as_deref(), default, min)
}

/// Testable core of [`env_usize`] (the env read is injected).
fn parse_env_usize(var: &str, value: Option<&str>, default: usize, min: usize) -> usize {
    match value {
        None => default,
        Some(s) => match s.trim().parse::<usize>() {
            Ok(n) => n.max(min),
            Err(e) => {
                eprintln!("warning: ignoring invalid {var}={s:?} ({e}); using default {default}");
                default
            }
        },
    }
}

enum Msg {
    Draw {
        stream: StreamId,
        n: usize,
        reply: SyncSender<Result<Draws>>,
        enqueued: Instant,
        /// Causal trace id minted at the client handle (0 = untraced).
        trace: u64,
    },
    Shutdown,
}

/// The coordinator: create streams, draw numbers, read metrics.
///
/// The client surface is the typed-handle API: [`Coordinator::builder`]
/// returns a [`StreamBuilder`] whose terminal methods yield
/// [`TypedStream`] handles with blocking (`draw`, `draw_into`) and
/// pipelined (`submit`) draws. The untyped `draw*` methods are deprecated
/// shims over the same request path.
pub struct Coordinator {
    registry: Arc<StreamRegistry>,
    config: CoordinatorConfig,
    shards: Vec<SyncSender<Msg>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
    pool: Arc<BufferPool>,
    /// The persistent fill-worker pool, shared by every worker shard's
    /// backends (bulk fills when `fill_threads > 1`, generation-ahead
    /// jobs when prefetch is on).
    fill_pool: Arc<FillPool>,
    /// Labeled metric families (per-stream; per-shard when clustered).
    obs: Arc<ObsRegistry>,
}

impl Coordinator {
    pub fn new(config: CoordinatorConfig) -> Coordinator {
        let registry = Arc::new(match config.substream_slots.clone() {
            Some(slots) => StreamRegistry::with_slot_range(config.root_seed, slots),
            None => StreamRegistry::new(config.root_seed),
        });
        let metrics = Arc::new(Metrics::new());
        let pool = Arc::new(BufferPool::new());
        // The dispatching coordinator worker participates as one executor
        // (part 0 + help-steal), so `fill_threads - 1` pool workers
        // reproduce `fill_threads`-way fill parallelism; the floor of 1
        // keeps a background lane for prefetch even at fill_threads = 1.
        let fill_pool = Arc::new(FillPool::new(PoolConfig {
            workers: config.fill_threads.saturating_sub(1).max(1),
            pin_cores: config.pin_fill_workers,
        }));
        // Hand the pool a live mirror of the queue-depth gauge while the
        // queue is still empty, so the snapshot value never drifts.
        fill_pool.set_depth_gauge(Arc::clone(&metrics.pool_queue_depth));
        let obs = Arc::new(ObsRegistry::new());
        let mut shards = Vec::new();
        let mut workers = Vec::new();
        for w in 0..config.workers.max(1) {
            let (tx, rx) = sync_channel::<Msg>(config.queue_depth);
            shards.push(tx);
            let reg = registry.clone();
            let met = metrics.clone();
            let cfg = config.clone();
            let pl = pool.clone();
            let fp = fill_pool.clone();
            let ob = obs.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("coord-worker-{w}"))
                    .spawn(move || worker_loop(rx, reg, met, cfg, pl, fp, ob))
                    .expect("spawn worker"),
            );
        }
        Coordinator { registry, config, shards, workers, metrics, pool, fill_pool, obs }
    }

    /// Register (or fetch) a named stream at the registry level (idempotent
    /// by name, new config ignored on a name hit). Typed clients go through
    /// [`Coordinator::builder`] instead, which rejects config conflicts.
    pub fn stream(&self, name: &str, config: StreamConfig) -> StreamId {
        self.registry.register(name, config)
    }

    /// Start building a typed stream handle; finish with one of the
    /// builder's terminal methods (`u32`/`uniform`/`normal`).
    pub fn builder(&self, name: &str) -> StreamBuilder<'_> {
        StreamBuilder::new(self, name)
    }

    /// Attach a typed handle to an already-registered stream, validating
    /// that the stream's transform produces `T` (the one runtime check the
    /// typed surface needs — everything after it is compile-time).
    pub fn typed<T: Sample>(&self, id: StreamId) -> Result<TypedStream<'_, T>> {
        let config = self.registry.config(id).context("unknown stream")?;
        ensure!(
            T::matches(config.transform),
            "stream {id:?} produces {} draws, handle expects {}",
            config.transform.name(),
            T::NAME
        );
        Ok(TypedStream::attach(self, id, config.transform))
    }

    /// Checked registration for the builder path.
    pub(crate) fn register_checked(&self, name: &str, config: StreamConfig) -> Result<StreamId> {
        self.registry.register_checked(name, config)
    }

    /// Shared reply-buffer pool (tickets recycle into it).
    pub(crate) fn pool_handle(&self) -> Arc<BufferPool> {
        self.pool.clone()
    }

    /// Enqueue one draw request and hand back the reply channel — the
    /// common path under both the blocking and the pipelined client calls.
    /// Inherits the thread's in-scope trace id, minting a fresh one when
    /// none is in scope (the deprecated untyped shims land here).
    pub(crate) fn submit_raw(&self, stream: StreamId, n: usize) -> Result<Receiver<Result<Draws>>> {
        let trace = match otrace::current_trace() {
            0 => otrace::next_trace_id(),
            t => t,
        };
        self.submit_traced(stream, n, trace)
    }

    /// Enqueue one draw carrying an explicit causal `trace` id — how the
    /// client handle and the cluster shard server thread the id they
    /// minted (or received over the wire) into the worker loop.
    pub fn submit_traced(
        &self,
        stream: StreamId,
        n: usize,
        trace: u64,
    ) -> Result<Receiver<Result<Draws>>> {
        let shard = (stream.0 as usize) % self.shards.len();
        let (reply_tx, reply_rx) = sync_channel(1);
        let msg = Msg::Draw { stream, n, reply: reply_tx, enqueued: Instant::now(), trace };
        if self.config.block_on_full {
            self.shards[shard].send(msg).context("service stopped")?;
        } else {
            match self.shards[shard].try_send(msg) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => {
                    self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    self.stream_obs(stream).rejected.fetch_add(1, Ordering::Relaxed);
                    bail!("backpressure: queue full");
                }
                Err(TrySendError::Disconnected(_)) => bail!("service stopped"),
            }
        }
        Ok(reply_rx)
    }

    /// The labeled counters for `stream` (created with its registry
    /// labels on first touch).
    fn stream_obs(&self, stream: StreamId) -> Arc<StreamCounters> {
        self.obs.stream(stream.0, || stream_labels(&self.registry, stream))
    }

    fn draw_raw(&self, stream: StreamId, n: usize) -> Result<Draws> {
        self.submit_raw(stream, n)?.recv().context("worker dropped reply")?
    }

    /// Draw `n` numbers from a stream (blocking call).
    #[deprecated(note = "use typed handles: `Coordinator::builder(name)` / `Coordinator::typed` \
                         — see the README migration guide")]
    pub fn draw(&self, stream: StreamId, n: usize) -> Result<Draws> {
        self.draw_raw(stream, n)
    }

    /// Convenience: draw u32s.
    #[deprecated(note = "use a `TypedStream<u32>` from `Coordinator::builder(name).u32()` \
                         — see the README migration guide")]
    pub fn draw_u32(&self, stream: StreamId, n: usize) -> Result<Vec<u32>> {
        match self.draw_raw(stream, n)? {
            Draws::U32(v) => Ok(v),
            Draws::F32(_) => bail!("stream produces f32"),
        }
    }

    /// Convenience: draw f32s (uniform or normal per the stream transform).
    #[deprecated(note = "use a `TypedStream<f32>` from `Coordinator::builder(name).uniform()` \
                         or `.normal()` — see the README migration guide")]
    pub fn draw_f32(&self, stream: StreamId, n: usize) -> Result<Vec<f32>> {
        match self.draw_raw(stream, n)? {
            Draws::F32(v) => Ok(v),
            Draws::U32(_) => bail!("stream produces u32"),
        }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        // The queue-depth gauge is maintained live by the pool's
        // enqueue/dequeue sites (see `FillPool::set_depth_gauge`), so a
        // snapshot is a plain read — no sampling race with in-flight jobs.
        self.metrics.snapshot()
    }

    /// The labeled-family registry (per-stream counters; per-shard when
    /// this coordinator serves as a cluster shard).
    pub fn obs(&self) -> &Arc<ObsRegistry> {
        &self.obs
    }

    /// Everything this coordinator exposes, as one point-in-time bundle:
    /// the legacy global snapshot plus the per-stream / per-fill-worker /
    /// per-shard families. This is what the `metrics` wire verb and the
    /// `--metrics-addr` HTTP listener render.
    pub fn exposition(&self) -> Exposition {
        Exposition {
            global: self.metrics(),
            streams: self.obs.streams(),
            workers: self.fill_pool.worker_stats(),
            shard: self.obs.shard(),
        }
    }

    /// Stop workers and join.
    pub fn shutdown(mut self) {
        for tx in &self.shards {
            let _ = tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.shards {
            let _ = tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Per-stream worker-side state: the **offset-cursor ring**.
///
/// One persistent buffer per stream plus a read cursor. Serving copies
/// exactly the requested span; the buffer is reset (cursor to zero,
/// length to zero, capacity kept) whenever it fully drains — which the
/// serve loop guarantees happens before any new launch lands in it, so
/// the ring never copy-compacts and never exceeds one launch of storage.
/// Backends fill it in place via [`Backend::launch_into`].
struct StreamState {
    backend: Box<dyn Backend>,
    buffer: Draws,
    pos: usize,
    /// This stream's labeled counters — resolved once at backend
    /// creation, so the serve loop touches only atomics.
    obs: Arc<StreamCounters>,
}

impl StreamState {
    fn buffered(&self) -> usize {
        self.buffer.len() - self.pos
    }

    /// Copy `n` buffered items onto `resp` and advance the cursor (one
    /// `extend_from_slice`, no temporary batch).
    fn take_into(&mut self, n: usize, resp: &mut Draws) {
        resp.extend_from_range(&self.buffer, self.pos, n);
        self.pos += n;
        self.reset_if_drained();
    }

    fn reset_if_drained(&mut self) {
        if self.pos == self.buffer.len() && self.pos > 0 {
            self.buffer.clear();
            self.pos = 0;
        }
    }
}

fn worker_loop(
    rx: Receiver<Msg>,
    registry: Arc<StreamRegistry>,
    metrics: Arc<Metrics>,
    cfg: CoordinatorConfig,
    pool: Arc<BufferPool>,
    fill_pool: Arc<FillPool>,
    obs: Arc<ObsRegistry>,
) {
    let mut streams: HashMap<StreamId, StreamState> = HashMap::new();
    // Per-stream counter Arcs cached worker-locally, so the request
    // drain pairs its family increment without taking the registry lock.
    let mut obs_cache: HashMap<StreamId, Arc<StreamCounters>> = HashMap::new();
    let mut req_counter = 0u64;
    'outer: loop {
        // Block for the first message, then drain opportunistically — this
        // is the dynamic-batching window. Pipelined clients (`submit`)
        // widen it: their queued requests coalesce into one cycle here.
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        let mut msgs = vec![first];
        while msgs.len() < cfg.max_batch {
            match rx.try_recv() {
                Ok(m) => msgs.push(m),
                Err(_) => break,
            }
        }
        // Group draw requests by stream (FIFO within a stream).
        type Pending = (PendingRequest, SyncSender<Result<Draws>>, Instant, u64);
        let mut items: Vec<(StreamId, Pending)> = Vec::new();
        let mut shutdown = false;
        for msg in msgs {
            match msg {
                Msg::Shutdown => shutdown = true,
                Msg::Draw { stream, n, reply, enqueued, trace } => {
                    req_counter += 1;
                    metrics.requests.fetch_add(1, Ordering::Relaxed);
                    obs_cache
                        .entry(stream)
                        .or_insert_with(|| {
                            obs.stream(stream.0, || stream_labels(&registry, stream))
                        })
                        .requests
                        .fetch_add(1, Ordering::Relaxed);
                    items.push((
                        stream,
                        (PendingRequest { request_id: req_counter, n }, reply, enqueued, trace),
                    ));
                }
            }
        }
        let (order, mut by_stream) = group_fifo(items);
        for stream in order {
            let entries = by_stream.remove(&stream).unwrap();
            // Materialise backend on first use.
            if !streams.contains_key(&stream) {
                match make_backend(&registry, &cfg, stream, &fill_pool, &metrics, &obs) {
                    Ok(state) => {
                        streams.insert(stream, state);
                    }
                    Err(e) => {
                        let shared = format!("{e:#}");
                        for (_, reply, _, _) in entries {
                            let _ = reply.send(Err(crate::anyhow!("{shared}")));
                        }
                        continue;
                    }
                }
            }
            let st = streams.get_mut(&stream).unwrap();
            let requests: Vec<PendingRequest> =
                entries.iter().map(|(r, _, _, _)| r.clone()).collect();
            // plan_batch is the proptested invariant model; the serving loop
            // below realises exactly that plan but streams full launches
            // straight into responses (EXPERIMENTS.md §Perf L3-5: the bulk
            // of a large draw is moved, not round-tripped through the
            // buffer).
            let plan = plan_batch(&requests, st.buffered(), st.backend.launch_size());
            let mut launches_left = plan.launches;
            let mut failed: Option<String> = None;
            for ((req, reply, enqueued, trace), (rid, n)) in
                entries.into_iter().zip(plan.allocations.iter())
            {
                debug_assert_eq!(req.request_id, *rid);
                let resp = if let Some(msg) = &failed {
                    Err(crate::anyhow!("launch failed: {msg}"))
                } else {
                    // Put the request's trace in scope so the fill pool's
                    // jobs (parts, generate-ahead) inherit its causal id,
                    // and time the serve as a `launch` span.
                    let prev = otrace::set_current_trace(trace);
                    let span = SpanTimer::start(trace, SpanKind::Launch);
                    let resp =
                        serve_one(st, *n, &mut launches_left, &metrics, &pool).map_err(|e| {
                            let msg = format!("{e:#}");
                            failed = Some(msg.clone());
                            crate::anyhow!("launch failed: {msg}")
                        });
                    span.finish(*n as u64);
                    otrace::set_current_trace(prev);
                    resp
                };
                if resp.is_ok() {
                    metrics.numbers_served.fetch_add(*n as u64, Ordering::Relaxed);
                    st.obs.numbers_served.fetch_add(*n as u64, Ordering::Relaxed);
                }
                metrics.record_latency(enqueued.elapsed());
                // A failed send means the client dropped its ticket (or a
                // dead cluster connection abandoned the request): recycle
                // the abandoned reply buffer instead of leaking the
                // allocation to the drop — but only a **well-formed** one
                // (exactly the served length). A mis-sized reply is
                // evidence of a serve-path bug; feeding it back into the
                // shared pool would spread the corruption to unrelated
                // streams, so it is dropped instead.
                if let Err(send_err) = reply.send(resp) {
                    if let Ok(d) = send_err.0 {
                        if d.len() == *n {
                            pool.put(d);
                        }
                    }
                }
            }
            debug_assert!(failed.is_some() || launches_left == 0);
        }
        if shutdown {
            break 'outer;
        }
    }
}

/// Serve one request of `n` numbers: drain the ring first, then fill
/// whole launches directly into the response; only the final partial
/// launch lands in the ring (which is empty and reset at that point, so
/// the backend fills reused storage in place). The response buffer comes
/// from the recycle pool — steady-state replies reuse storage returned by
/// `draw_into`/`wait_into` clients.
fn serve_one(
    st: &mut StreamState,
    n: usize,
    launches_left: &mut usize,
    metrics: &Metrics,
    pool: &BufferPool,
) -> Result<Draws> {
    let (mut resp, hit) = pool.get(st.backend.transform());
    let counter = if hit { &metrics.pool_hits } else { &metrics.pool_misses };
    counter.fetch_add(1, Ordering::Relaxed);
    let scounter = if hit { &st.obs.pool_hits } else { &st.obs.pool_misses };
    scounter.fetch_add(1, Ordering::Relaxed);
    resp.reserve(n);
    let take_now = st.buffered().min(n);
    st.take_into(take_now, &mut resp);
    while resp.len() < n {
        debug_assert!(*launches_left > 0, "plan under-provisioned");
        *launches_left = launches_left.saturating_sub(1);
        metrics.launches.fetch_add(1, Ordering::Relaxed);
        st.obs.launches.fetch_add(1, Ordering::Relaxed);
        let need = n - resp.len();
        if st.backend.launch_size() <= need {
            // Whole launch fits: generate straight into the response.
            st.backend.launch_into(&mut resp)?;
        } else {
            // Final partial launch: into the (empty) ring, serve the head,
            // keep the tail buffered for the next request.
            debug_assert_eq!(st.buffer.len(), 0);
            st.backend.launch_into(&mut st.buffer)?;
            st.take_into(need, &mut resp);
        }
    }
    Ok(resp)
}

/// The label set the registry records for `stream` (`unknown` labels for
/// ids the registry has never seen — those requests still count).
fn stream_labels(registry: &StreamRegistry, stream: StreamId) -> StreamLabels {
    match registry.config(stream) {
        Some(c) => StreamLabels {
            kind: c.kind.to_string(),
            placement: c.placement.to_string(),
            transform: c.transform.name().to_string(),
        },
        None => StreamLabels {
            kind: "unknown".into(),
            placement: "unknown".into(),
            transform: "unknown".into(),
        },
    }
}

fn make_backend(
    registry: &StreamRegistry,
    cfg: &CoordinatorConfig,
    stream: StreamId,
    fill_pool: &Arc<FillPool>,
    metrics: &Arc<Metrics>,
    obs: &ObsRegistry,
) -> Result<StreamState> {
    use crate::prng::place::{LeapfrogBlock, Placement};
    use crate::prng::{make_block_generator, make_block_generator_from_state, BlockParallel};
    let sconf = registry.config(stream).context("unknown stream")?;
    let seed = registry.stream_seed(stream);
    let sobs = obs.stream(stream.0, || stream_labels(registry, stream));
    let backend: Box<dyn Backend> = match sconf.backend {
        BackendKind::Rust => {
            let gen: Box<dyn BlockParallel + Send> = match sconf.placement {
                // The historical path, bit for bit.
                Placement::SeedMix => make_block_generator(sconf.kind, seed, sconf.blocks),
                // Blocks constructed directly from master states at the
                // registry-allocated substream slots: provably disjoint,
                // and no throwaway seed-and-warm pass that `load_state`
                // would immediately overwrite.
                Placement::ExactJump { .. } => {
                    let states = registry.placed_block_states(stream)?;
                    make_block_generator_from_state(sconf.kind, sconf.blocks, &states)
                }
                // One master sequence dealt round-robin to virtual blocks.
                Placement::Leapfrog => Box::new(LeapfrogBlock::new(
                    make_block_generator(sconf.kind, seed, 1),
                    sconf.blocks,
                )),
            };
            // Per-stream prefetch override wins; the coordinator default
            // covers streams that don't set one. The backend forces the
            // depth to 0 for the Normal transform.
            let depth = sconf.prefetch.unwrap_or(cfg.prefetch);
            Box::new(
                RustBackend::with_generator(gen, sconf.transform, sconf.rounds_per_launch)
                    .fill_threads(cfg.fill_threads)
                    .pooled(Arc::clone(fill_pool), depth)
                    .metrics_sink(Arc::clone(metrics))
                    .obs_sink(Arc::clone(&sobs)),
            )
        }
        BackendKind::Pjrt => {
            ensure!(
                sconf.placement == Placement::SeedMix,
                "placement {} is not supported on the PJRT backend yet (artifacts carry \
                 seed-mix initial states)",
                sconf.placement
            );
            Box::new(PjrtBackend::best(&cfg.artifact_dir, sconf.kind, sconf.transform, seed)?)
        }
    };
    let buffer = Draws::empty_like(sconf.transform);
    Ok(StreamState { backend, buffer, pos: 0, obs: sobs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::GeneratorKind;

    fn quick_config() -> CoordinatorConfig {
        CoordinatorConfig { workers: 2, ..Default::default() }
    }

    #[test]
    fn draw_roundtrip() {
        let coord = Coordinator::new(quick_config());
        let s = coord.builder("test").blocks(4).rounds_per_launch(2).u32().unwrap();
        let v = s.draw(1000).unwrap();
        assert_eq!(v.len(), 1000);
        let m = coord.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.numbers_served, 1000);
        assert!(m.launches >= 2); // 1000 > 4*63*2=504 -> 2 launches
        coord.shutdown();
    }

    #[test]
    fn stream_continuity_across_draws() {
        // Two draws must be a contiguous prefix of one larger draw.
        let c1 = Coordinator::new(quick_config());
        let c2 = Coordinator::new(quick_config());
        let mk = |c: &Coordinator| c.builder("cont").blocks(2).rounds_per_launch(1).u32().unwrap();
        let s1 = mk(&c1);
        let s2 = mk(&c2);
        let mut a = s1.draw(100).unwrap();
        a.extend(s1.draw(150).unwrap());
        let b = s2.draw(250).unwrap();
        assert_eq!(a, b);
        c1.shutdown();
        c2.shutdown();
    }

    #[test]
    fn distinct_streams_distinct_output() {
        let coord = Coordinator::new(quick_config());
        let s1 = coord.builder("a").blocks(2).u32().unwrap();
        let s2 = coord.builder("b").blocks(2).u32().unwrap();
        let v1 = s1.draw(64).unwrap();
        let v2 = s2.draw(64).unwrap();
        assert_ne!(v1, v2);
        coord.shutdown();
    }

    #[test]
    fn f32_and_normal_streams() {
        let coord = Coordinator::new(quick_config());
        let sf = coord.builder("f").blocks(2).uniform().unwrap();
        let sn = coord.builder("n").blocks(2).normal().unwrap();
        let f = sf.draw(500).unwrap();
        assert!(f.iter().all(|&x| (0.0..1.0).contains(&x)));
        let z = sn.draw(500).unwrap();
        assert!(z.iter().any(|&x| x < 0.0) && z.iter().any(|&x| x > 0.0));
        // A u32 handle on the f32 stream is rejected at attach time (with
        // typed construction the mismatch cannot even be expressed).
        assert!(coord.typed::<u32>(sf.id()).is_err());
        assert!(coord.typed::<f32>(sf.id()).is_ok());
        coord.shutdown();
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_shims_match_typed_handles() {
        // The deprecated untyped surface must serve bit-identical streams
        // through the same request path.
        let c1 = Coordinator::new(quick_config());
        let c2 = Coordinator::new(quick_config());
        let typed = c1.builder("legacy").blocks(2).rounds_per_launch(1).u32().unwrap();
        let id = c2.stream(
            "legacy",
            StreamConfig { blocks: 2, rounds_per_launch: 1, ..Default::default() },
        );
        assert_eq!(typed.draw(300).unwrap(), c2.draw_u32(id, 300).unwrap());
        match c2.draw(id, 10).unwrap() {
            Draws::U32(v) => assert_eq!(v.len(), 10),
            Draws::F32(_) => panic!("wrong variant"),
        }
        // The legacy type mismatch stays a runtime error.
        assert!(c2.draw_f32(id, 1).is_err());
        c1.shutdown();
        c2.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let coord = Arc::new(Coordinator::new(quick_config()));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = coord.clone();
            handles.push(std::thread::spawn(move || {
                let s = c.builder("shared").blocks(4).u32().unwrap();
                s.draw(10_000).unwrap().len()
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 80_000);
        assert_eq!(coord.metrics().numbers_served, 80_000);
    }

    #[test]
    fn xorwow_and_mtgp_streams() {
        let coord = Coordinator::new(quick_config());
        for (name, kind) in
            [("xw", GeneratorKind::Xorwow), ("mt", GeneratorKind::Mtgp)]
        {
            let s = coord
                .builder(name)
                .kind(kind)
                .blocks(4)
                .rounds_per_launch(1)
                .u32()
                .unwrap();
            let v = s.draw(300).unwrap();
            assert_eq!(v.len(), 300);
        }
        coord.shutdown();
    }

    #[test]
    fn placement_streams_serve_and_are_deterministic() {
        use crate::coordinator::Placement;
        let mk = |placement| {
            let coord = Coordinator::new(quick_config());
            let s = coord
                .builder("placed")
                .kind(GeneratorKind::Xorwow)
                .blocks(2)
                .rounds_per_launch(1)
                .placement(placement)
                .u32()
                .unwrap();
            let v = s.draw(256).unwrap();
            coord.shutdown();
            v
        };
        let exact = mk(Placement::ExactJump { log2_spacing: 40 });
        let exact2 = mk(Placement::ExactJump { log2_spacing: 40 });
        let mix = mk(Placement::SeedMix);
        let leap = mk(Placement::Leapfrog);
        assert_eq!(exact, exact2, "exact placement is deterministic");
        assert_ne!(exact, mix);
        assert_ne!(leap, mix);
    }

    #[test]
    fn leapfrog_stream_is_block_count_independent() {
        use crate::coordinator::Placement;
        let draw = |blocks| {
            let coord = Coordinator::new(quick_config());
            let s = coord
                .builder("leap")
                .blocks(blocks)
                .rounds_per_launch(1)
                .placement(Placement::Leapfrog)
                .u32()
                .unwrap();
            let v = s.draw(1000).unwrap();
            coord.shutdown();
            v
        };
        // The whole point of leapfrog: the stream a client sees does not
        // depend on the launch geometry.
        assert_eq!(draw(2), draw(4));
    }

    #[test]
    fn exact_jump_streams_disjoint_across_streams() {
        use crate::coordinator::Placement;
        let coord = Coordinator::new(quick_config());
        let mk = |name: &str| {
            coord
                .builder(name)
                .kind(GeneratorKind::Xorwow)
                .blocks(2)
                .rounds_per_launch(1)
                .placement(Placement::ExactJump { log2_spacing: 40 })
                .u32()
                .unwrap()
        };
        let a = mk("ea");
        let b = mk("eb");
        assert_ne!(a.draw(512).unwrap(), b.draw(512).unwrap());
        coord.shutdown();
    }

    #[test]
    fn fill_threads_leave_stream_unchanged() {
        // A launch of 64 blocks × 16 rounds = 64512 u32s exceeds the
        // parallel-fill crossover, so `fill_threads: 4` genuinely threads
        // the backend fills — and the served stream must be bit-identical
        // to the serial coordinator, for seed-mix and placed streams alike.
        use crate::coordinator::Placement;
        let draw = |fill_threads: usize, placement: Placement| {
            let coord = Coordinator::new(CoordinatorConfig { fill_threads, ..quick_config() });
            let s = coord
                .builder("par")
                .kind(GeneratorKind::XorgensGp)
                .blocks(64)
                .rounds_per_launch(16)
                .placement(placement)
                .u32()
                .unwrap();
            // Spill past one launch so the ring/cursor path runs too.
            let mut v = s.draw(70_000).unwrap();
            v.extend(s.draw(1_000).unwrap());
            coord.shutdown();
            v
        };
        for placement in [Placement::SeedMix, Placement::ExactJump { log2_spacing: 64 }] {
            assert_eq!(draw(1, placement), draw(4, placement), "placement {placement}");
        }
    }

    #[test]
    fn invalid_env_values_warn_and_fall_back() {
        // Satellite fix: an invalid XORGENSGP_FILL_THREADS used to be
        // silently ignored via `.ok()`. The parse core now falls back to
        // the default explicitly (the warning goes to stderr).
        assert_eq!(parse_env_usize("X", None, 1, 1), 1);
        assert_eq!(parse_env_usize("X", Some("3"), 1, 1), 3);
        assert_eq!(parse_env_usize("X", Some(" 4 "), 1, 1), 4, "whitespace tolerated");
        assert_eq!(parse_env_usize("X", Some("0"), 1, 1), 1, "clamped to min");
        assert_eq!(parse_env_usize("X", Some("0"), 0, 0), 0, "min 0 allows 0");
        for bad in ["", "abc", "-2", "3.5", "1e3"] {
            assert_eq!(parse_env_usize("X", Some(bad), 1, 1), 1, "{bad:?} -> default");
            assert_eq!(parse_env_usize("X", Some(bad), 7, 1), 7, "{bad:?} -> default");
        }
    }

    #[test]
    fn prefetch_leaves_stream_unchanged() {
        // Generation-ahead double buffering must be invisible in the
        // stream: prefetched buffers are the same whole-round fills
        // computed early. Mixed draw sizes cross launch AND prefetch
        // buffer boundaries.
        let draw = |prefetch: usize, fill_threads: usize| {
            let coord = Coordinator::new(CoordinatorConfig {
                fill_threads,
                prefetch,
                ..quick_config()
            });
            let s = coord.builder("pre").blocks(8).rounds_per_launch(4).u32().unwrap();
            let mut v = s.draw(3000).unwrap();
            v.extend(s.draw(1217).unwrap());
            v.extend(s.draw(5000).unwrap());
            coord.shutdown();
            v
        };
        let base = draw(0, 1);
        for (p, t) in [(1usize, 1usize), (1, 3), (2, 4)] {
            assert_eq!(base, draw(p, t), "prefetch={p} fill_threads={t}");
        }
    }

    #[test]
    fn prefetch_counters_observable_in_metrics() {
        let coord =
            Coordinator::new(CoordinatorConfig { workers: 1, prefetch: 1, ..Default::default() });
        let s = coord.builder("prem").blocks(2).rounds_per_launch(1).u32().unwrap();
        for _ in 0..8 {
            s.draw(500).unwrap();
        }
        let m = coord.metrics();
        assert!(
            m.prefetch_hits + m.prefetch_stalls >= 1,
            "prefetch accounting missing: {}",
            m.render()
        );
        coord.shutdown();
    }

    #[test]
    fn pool_recycling_observable_in_metrics() {
        let coord = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
        let s = coord.builder("pool").blocks(2).rounds_per_launch(1).u32().unwrap();
        let mut buf = vec![0u32; 512];
        for _ in 0..16 {
            s.draw_into(&mut buf).unwrap();
        }
        let m = coord.metrics();
        assert_eq!(m.pool_hits + m.pool_misses, 16);
        // draw_into recycles every reply, so after the first (cold) reply
        // the single worker always finds a pooled buffer.
        assert!(m.pool_hits >= 14, "expected steady-state recycling: {}", m.render());
        coord.shutdown();
    }
}

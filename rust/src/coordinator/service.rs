//! The coordinator service: sharded worker threads, bounded queues
//! (backpressure), dynamic batching per stream.
//!
//! Offline-build note: tokio is unavailable, so the event loop is built on
//! `std::sync::mpsc` + worker threads — one worker owns each shard of
//! streams (shard = id % workers), so stream state needs no locking; the
//! request path is: client → bounded shard queue → worker drains a batch →
//! `plan_batch` → backend launches → per-request replies over oneshot
//! channels. This is the same shape as an async runtime's actor loop.

use super::backend::{Backend, BackendKind, Draws, PjrtBackend, RustBackend};
use super::batcher::{plan_batch, PendingRequest};
use super::metrics::{Metrics, MetricsSnapshot};
use super::stream::{StreamConfig, StreamId, StreamRegistry};
use crate::util::error::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub root_seed: u64,
    pub workers: usize,
    /// Bounded queue depth per worker (backpressure: `draw` returns an
    /// error when the queue is full and `block_on_full` is false).
    pub queue_depth: usize,
    pub block_on_full: bool,
    /// Artifacts dir for PJRT-backed streams.
    pub artifact_dir: PathBuf,
    /// Max requests drained per batching cycle.
    pub max_batch: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            root_seed: 0x9e37_79b9,
            workers: 2,
            queue_depth: 1024,
            block_on_full: true,
            artifact_dir: crate::runtime::default_dir(),
            max_batch: 64,
        }
    }
}

enum Msg {
    Draw { stream: StreamId, n: usize, reply: SyncSender<Result<Draws>>, enqueued: Instant },
    Shutdown,
}

/// The coordinator: create streams, draw numbers, read metrics.
pub struct Coordinator {
    registry: Arc<StreamRegistry>,
    config: CoordinatorConfig,
    shards: Vec<SyncSender<Msg>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

impl Coordinator {
    pub fn new(config: CoordinatorConfig) -> Coordinator {
        let registry = Arc::new(StreamRegistry::new(config.root_seed));
        let metrics = Arc::new(Metrics::new());
        let mut shards = Vec::new();
        let mut workers = Vec::new();
        for w in 0..config.workers.max(1) {
            let (tx, rx) = sync_channel::<Msg>(config.queue_depth);
            shards.push(tx);
            let reg = registry.clone();
            let met = metrics.clone();
            let cfg = config.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("coord-worker-{w}"))
                    .spawn(move || worker_loop(rx, reg, met, cfg))
                    .expect("spawn worker"),
            );
        }
        Coordinator { registry, config, shards, workers, metrics }
    }

    /// Register (or fetch) a named stream.
    pub fn stream(&self, name: &str, config: StreamConfig) -> StreamId {
        self.registry.register(name, config)
    }

    /// Draw `n` numbers from a stream (blocking call).
    pub fn draw(&self, stream: StreamId, n: usize) -> Result<Draws> {
        let shard = (stream.0 as usize) % self.shards.len();
        let (reply_tx, reply_rx) = sync_channel(1);
        let msg = Msg::Draw { stream, n, reply: reply_tx, enqueued: Instant::now() };
        if self.config.block_on_full {
            self.shards[shard].send(msg).context("service stopped")?;
        } else {
            match self.shards[shard].try_send(msg) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => {
                    self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    bail!("backpressure: queue full");
                }
                Err(TrySendError::Disconnected(_)) => bail!("service stopped"),
            }
        }
        reply_rx.recv().context("worker dropped reply")?
    }

    /// Convenience: draw u32s.
    pub fn draw_u32(&self, stream: StreamId, n: usize) -> Result<Vec<u32>> {
        match self.draw(stream, n)? {
            Draws::U32(v) => Ok(v),
            Draws::F32(_) => bail!("stream produces f32"),
        }
    }

    /// Convenience: draw f32s (uniform or normal per the stream transform).
    pub fn draw_f32(&self, stream: StreamId, n: usize) -> Result<Vec<f32>> {
        match self.draw(stream, n)? {
            Draws::F32(v) => Ok(v),
            Draws::U32(_) => bail!("stream produces u32"),
        }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Stop workers and join.
    pub fn shutdown(mut self) {
        for tx in &self.shards {
            let _ = tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.shards {
            let _ = tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Per-stream worker-side state: the **offset-cursor ring**.
///
/// One persistent buffer per stream plus a read cursor. Serving copies
/// exactly the requested span; the buffer is reset (cursor to zero,
/// length to zero, capacity kept) whenever it fully drains — which the
/// serve loop guarantees happens before any new launch lands in it, so
/// the ring never copy-compacts and never exceeds one launch of storage.
/// Backends fill it in place via [`Backend::launch_into`].
struct StreamState {
    backend: Box<dyn Backend>,
    buffer: Draws,
    pos: usize,
}

impl StreamState {
    fn buffered(&self) -> usize {
        self.buffer.len() - self.pos
    }

    /// Copy `n` buffered items onto `resp` and advance the cursor (one
    /// `extend_from_slice`, no temporary batch).
    fn take_into(&mut self, n: usize, resp: &mut Draws) {
        resp.extend_from_range(&self.buffer, self.pos, n);
        self.pos += n;
        self.reset_if_drained();
    }

    fn reset_if_drained(&mut self) {
        if self.pos == self.buffer.len() && self.pos > 0 {
            self.buffer.clear();
            self.pos = 0;
        }
    }
}

fn worker_loop(
    rx: Receiver<Msg>,
    registry: Arc<StreamRegistry>,
    metrics: Arc<Metrics>,
    cfg: CoordinatorConfig,
) {
    let mut streams: HashMap<StreamId, StreamState> = HashMap::new();
    let mut req_counter = 0u64;
    'outer: loop {
        // Block for the first message, then drain opportunistically — this
        // is the dynamic-batching window.
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => break,
        };
        let mut msgs = vec![first];
        while msgs.len() < cfg.max_batch {
            match rx.try_recv() {
                Ok(m) => msgs.push(m),
                Err(_) => break,
            }
        }
        // Group draw requests by stream (FIFO within a stream).
        type Pending = (PendingRequest, SyncSender<Result<Draws>>, Instant);
        let mut by_stream: HashMap<StreamId, Vec<Pending>> = HashMap::new();
        let mut order: Vec<StreamId> = Vec::new();
        let mut shutdown = false;
        for msg in msgs {
            match msg {
                Msg::Shutdown => shutdown = true,
                Msg::Draw { stream, n, reply, enqueued } => {
                    req_counter += 1;
                    metrics.requests.fetch_add(1, Ordering::Relaxed);
                    if !by_stream.contains_key(&stream) {
                        order.push(stream);
                    }
                    by_stream
                        .entry(stream)
                        .or_default()
                        .push((PendingRequest { request_id: req_counter, n }, reply, enqueued));
                }
            }
        }
        for stream in order {
            let entries = by_stream.remove(&stream).unwrap();
            // Materialise backend on first use.
            if !streams.contains_key(&stream) {
                match make_backend(&registry, &cfg, stream) {
                    Ok(state) => {
                        streams.insert(stream, state);
                    }
                    Err(e) => {
                        let shared = format!("{e:#}");
                        for (_, reply, _) in entries {
                            let _ = reply.send(Err(crate::anyhow!("{shared}")));
                        }
                        continue;
                    }
                }
            }
            let st = streams.get_mut(&stream).unwrap();
            let requests: Vec<PendingRequest> = entries.iter().map(|(r, _, _)| r.clone()).collect();
            // plan_batch is the proptested invariant model; the serving loop
            // below realises exactly that plan but streams full launches
            // straight into responses (EXPERIMENTS.md §Perf L3-5: the bulk
            // of a large draw is moved, not round-tripped through the
            // buffer).
            let plan = plan_batch(&requests, st.buffered(), st.backend.launch_size());
            let mut launches_left = plan.launches;
            let mut failed: Option<String> = None;
            for ((req, reply, enqueued), (rid, n)) in
                entries.into_iter().zip(plan.allocations.iter())
            {
                debug_assert_eq!(req.request_id, *rid);
                let resp = if let Some(msg) = &failed {
                    Err(crate::anyhow!("launch failed: {msg}"))
                } else {
                    serve_one(st, *n, &mut launches_left, &metrics).map_err(|e| {
                        let msg = format!("{e:#}");
                        failed = Some(msg.clone());
                        crate::anyhow!("launch failed: {msg}")
                    })
                };
                if resp.is_ok() {
                    metrics.numbers_served.fetch_add(*n as u64, Ordering::Relaxed);
                }
                metrics.record_latency(enqueued.elapsed());
                let _ = reply.send(resp);
            }
            debug_assert!(failed.is_some() || launches_left == 0);
        }
        if shutdown {
            break 'outer;
        }
    }
}

/// Serve one request of `n` numbers: drain the ring first, then fill
/// whole launches directly into the response; only the final partial
/// launch lands in the ring (which is empty and reset at that point, so
/// the backend fills reused storage in place).
fn serve_one(
    st: &mut StreamState,
    n: usize,
    launches_left: &mut usize,
    metrics: &Metrics,
) -> Result<Draws> {
    let mut resp = Draws::empty_like(st.backend.transform());
    resp.reserve(n);
    let take_now = st.buffered().min(n);
    st.take_into(take_now, &mut resp);
    while resp.len() < n {
        debug_assert!(*launches_left > 0, "plan under-provisioned");
        *launches_left = launches_left.saturating_sub(1);
        metrics.launches.fetch_add(1, Ordering::Relaxed);
        let need = n - resp.len();
        if st.backend.launch_size() <= need {
            // Whole launch fits: generate straight into the response.
            st.backend.launch_into(&mut resp)?;
        } else {
            // Final partial launch: into the (empty) ring, serve the head,
            // keep the tail buffered for the next request.
            debug_assert_eq!(st.buffer.len(), 0);
            st.backend.launch_into(&mut st.buffer)?;
            st.take_into(need, &mut resp);
        }
    }
    Ok(resp)
}

fn make_backend(
    registry: &StreamRegistry,
    cfg: &CoordinatorConfig,
    stream: StreamId,
) -> Result<StreamState> {
    let sconf = registry.config(stream).context("unknown stream")?;
    let seed = registry.stream_seed(stream);
    let backend: Box<dyn Backend> = match sconf.backend {
        BackendKind::Rust => Box::new(RustBackend::new(
            sconf.kind,
            sconf.transform,
            seed,
            sconf.blocks,
            sconf.rounds_per_launch,
        )),
        BackendKind::Pjrt => {
            Box::new(PjrtBackend::best(&cfg.artifact_dir, sconf.kind, sconf.transform, seed)?)
        }
    };
    let buffer = Draws::empty_like(sconf.transform);
    Ok(StreamState { backend, buffer, pos: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::GeneratorKind;
    use crate::runtime::Transform;

    fn quick_config() -> CoordinatorConfig {
        CoordinatorConfig { workers: 2, ..Default::default() }
    }

    #[test]
    fn draw_roundtrip() {
        let coord = Coordinator::new(quick_config());
        let s = coord.stream(
            "test",
            StreamConfig { blocks: 4, rounds_per_launch: 2, ..Default::default() },
        );
        let v = coord.draw_u32(s, 1000).unwrap();
        assert_eq!(v.len(), 1000);
        let m = coord.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.numbers_served, 1000);
        assert!(m.launches >= 2); // 1000 > 4*63*2=504 -> 2 launches
        coord.shutdown();
    }

    #[test]
    fn stream_continuity_across_draws() {
        // Two draws must be a contiguous prefix of one larger draw.
        let mk = || {
            let coord = Coordinator::new(quick_config());
            let s = coord.stream(
                "cont",
                StreamConfig { blocks: 2, rounds_per_launch: 1, ..Default::default() },
            );
            (coord, s)
        };
        let (c1, s1) = mk();
        let (c2, s2) = mk();
        let mut a = c1.draw_u32(s1, 100).unwrap();
        a.extend(c1.draw_u32(s1, 150).unwrap());
        let b = c2.draw_u32(s2, 250).unwrap();
        assert_eq!(a, b);
        c1.shutdown();
        c2.shutdown();
    }

    #[test]
    fn distinct_streams_distinct_output() {
        let coord = Coordinator::new(quick_config());
        let s1 = coord.stream("a", StreamConfig { blocks: 2, ..Default::default() });
        let s2 = coord.stream("b", StreamConfig { blocks: 2, ..Default::default() });
        let v1 = coord.draw_u32(s1, 64).unwrap();
        let v2 = coord.draw_u32(s2, 64).unwrap();
        assert_ne!(v1, v2);
        coord.shutdown();
    }

    #[test]
    fn f32_and_normal_streams() {
        let coord = Coordinator::new(quick_config());
        let sf = coord.stream(
            "f",
            StreamConfig { transform: Transform::F32, blocks: 2, ..Default::default() },
        );
        let sn = coord.stream(
            "n",
            StreamConfig { transform: Transform::Normal, blocks: 2, ..Default::default() },
        );
        let f = coord.draw_f32(sf, 500).unwrap();
        assert!(f.iter().all(|&x| (0.0..1.0).contains(&x)));
        let z = coord.draw_f32(sn, 500).unwrap();
        assert!(z.iter().any(|&x| x < 0.0) && z.iter().any(|&x| x > 0.0));
        // Type mismatch is an error.
        assert!(coord.draw_u32(sf, 1).is_err());
        coord.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let coord = Arc::new(Coordinator::new(quick_config()));
        let s = coord.stream("shared", StreamConfig { blocks: 4, ..Default::default() });
        let mut handles = Vec::new();
        for _ in 0..8 {
            let c = coord.clone();
            handles.push(std::thread::spawn(move || c.draw_u32(s, 10_000).unwrap().len()));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 80_000);
        assert_eq!(coord.metrics().numbers_served, 80_000);
    }

    #[test]
    fn xorwow_and_mtgp_streams() {
        let coord = Coordinator::new(quick_config());
        for (name, kind) in
            [("xw", GeneratorKind::Xorwow), ("mt", GeneratorKind::Mtgp)]
        {
            let s = coord.stream(
                name,
                StreamConfig { kind, blocks: 4, rounds_per_launch: 1, ..Default::default() },
            );
            let v = coord.draw_u32(s, 300).unwrap();
            assert_eq!(v.len(), 300);
        }
        coord.shutdown();
    }
}

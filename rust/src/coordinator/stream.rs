//! Stream registry: named logical streams with provably disjoint
//! subsequences.
//!
//! Disjointness strategy (paper §4 + our gf2 machinery):
//!
//! * **Across streams**: stream id `i` seeds its generator with
//!   `SeedSequence(root).child(i)` — the avalanche-mixed "consecutive
//!   seeds" scheme the paper credits xorgens' initialisation for; for the
//!   4096-bit xorgens state the probability of overlap within any
//!   practical horizon is ~2^-4000-ish (period (2^4096−1)·2^32 split into
//!   random phases).
//! * **Within a stream**: blocks are decorrelated by the same scheme (the
//!   generator's own per-block seeding).
//! * **XORWOW exact mode**: the 160-bit LFSR admits cheap jump-ahead via
//!   the GF(2) transition matrix; `StreamConfig::exact_jump` places stream
//!   `i` at offset `i · 2^96` in the master sequence — *provably* disjoint
//!   (used by the `ablation_s`/EXPERIMENTS init studies and available in
//!   the public API).

use super::backend::BackendKind;
use crate::gf2::{jump_state, transition_matrix, transition_power, BitMatrix};
use crate::prng::init::SeedSequence;
use crate::prng::xorwow::{Xorwow, XorwowLfsr};
use crate::prng::GeneratorKind;
use crate::runtime::Transform;
use crate::util::error::{bail, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// Stream handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamId(pub u64);

/// Configuration for a new stream.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamConfig {
    pub kind: GeneratorKind,
    pub transform: Transform,
    pub backend: BackendKind,
    /// Blocks for the Rust backend (PJRT uses the artifact's shape).
    pub blocks: usize,
    /// Rounds per launch for the Rust backend.
    pub rounds_per_launch: usize,
    /// XORWOW only: place streams at exact 2^96-spaced offsets via GF(2)
    /// jump-ahead instead of seed mixing.
    pub exact_jump: bool,
    /// Explicit generator seed. `None` (the default) derives the seed from
    /// the coordinator's root seed by avalanche mixing — the disjointness
    /// scheme documented above. `Some(s)` seeds the stream's generator
    /// with exactly `s`, reproducing a library-level generator
    /// (`make_block_generator(kind, s, blocks)`) through the service —
    /// the golden-vector equivalence tests pin this path.
    pub seed: Option<u64>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            kind: GeneratorKind::XorgensGp,
            transform: Transform::U32,
            backend: BackendKind::Rust,
            blocks: 64,
            rounds_per_launch: 16,
            exact_jump: false,
            seed: None,
        }
    }
}

/// Registry: stream name -> id + config; seeds derived from a root seed.
pub struct StreamRegistry {
    root: u64,
    inner: Mutex<RegistryInner>,
    /// Cached M^(2^96) for XORWOW exact jumps (computed on first use).
    jump_matrix: Mutex<Option<BitMatrix>>,
}

struct RegistryInner {
    by_name: HashMap<String, StreamId>,
    configs: HashMap<StreamId, StreamConfig>,
    next: u64,
}

impl StreamRegistry {
    pub fn new(root_seed: u64) -> Self {
        StreamRegistry {
            root: root_seed,
            inner: Mutex::new(RegistryInner {
                by_name: HashMap::new(),
                configs: HashMap::new(),
                next: 0,
            }),
            jump_matrix: Mutex::new(None),
        }
    }

    /// Register (or look up) a named stream.
    ///
    /// Idempotent by name: re-registering an existing name returns the
    /// existing id and **ignores** the new config. The typed-handle
    /// builder goes through [`register_checked`] instead, which rejects
    /// conflicting re-registration.
    ///
    /// [`register_checked`]: StreamRegistry::register_checked
    pub fn register(&self, name: &str, config: StreamConfig) -> StreamId {
        let mut inner = self.inner.lock().unwrap();
        if let Some(&id) = inner.by_name.get(name) {
            return id;
        }
        let id = StreamId(inner.next);
        inner.next += 1;
        inner.by_name.insert(name.to_string(), id);
        inner.configs.insert(id, config);
        id
    }

    /// Register a named stream, erroring if the name is already registered
    /// with a *different* config (re-attaching with an identical config is
    /// fine and returns the existing id).
    pub fn register_checked(&self, name: &str, config: StreamConfig) -> Result<StreamId> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(&id) = inner.by_name.get(name) {
            let existing = &inner.configs[&id];
            if *existing != config {
                bail!(
                    "stream {name:?} already registered with a different config \
                     (existing: {existing:?}, requested: {config:?})"
                );
            }
            return Ok(id);
        }
        let id = StreamId(inner.next);
        inner.next += 1;
        inner.by_name.insert(name.to_string(), id);
        inner.configs.insert(id, config);
        Ok(id)
    }

    pub fn config(&self, id: StreamId) -> Option<StreamConfig> {
        self.inner.lock().unwrap().configs.get(&id).cloned()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The seed for a stream: the explicit [`StreamConfig::seed`] override
    /// when set, otherwise the avalanche-mixed child of the root (the
    /// paper-§4 "consecutive ids, strong init" scheme).
    pub fn stream_seed(&self, id: StreamId) -> u64 {
        if let Some(seed) = self.inner.lock().unwrap().configs.get(&id).and_then(|c| c.seed) {
            return seed;
        }
        SeedSequence::new(self.root).child(id.0).next_u64()
    }

    /// XORWOW exact placement: the state of stream `id` at offset
    /// `id · 2^96` of the master sequence (LFSR jumped exactly; Weyl
    /// counter offset by `(id · 2^96) mod 2^32 = 0` — 2^96 is a multiple
    /// of 2^32, so the counter is unchanged).
    pub fn xorwow_exact_state(&self, id: StreamId) -> ([u32; 5], u32) {
        let mut cache = self.jump_matrix.lock().unwrap();
        let m96 = cache.get_or_insert_with(|| {
            let m = transition_matrix(&XorwowLfsr);
            // M^(2^96) by 96 squarings.
            let mut acc = m;
            for _ in 0..96 {
                acc = acc.mul(&acc);
            }
            acc
        });
        // Master state from the root seed.
        let mut seq = SeedSequence::new(self.root ^ 0x584f_5257); // "XORW"
        let master = Xorwow::from_seq(&mut seq);
        let (x, d) = master.state();
        let mut state = x.to_vec();
        for _ in 0..id.0 {
            state = jump_state(m96, &state);
        }
        ([state[0], state[1], state[2], state[3], state[4]], d)
    }
}

/// Stand-alone helper used by tests: jump a XORWOW LFSR state by `k`.
pub fn xorwow_jump(state: &[u32; 5], k: u128) -> [u32; 5] {
    let m = transition_matrix(&XorwowLfsr);
    let mk = transition_power(&m, k);
    let v = jump_state(&mk, state);
    [v[0], v[1], v[2], v[3], v[4]]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_is_idempotent() {
        let reg = StreamRegistry::new(1);
        let a = reg.register("alpha", StreamConfig::default());
        let b = reg.register("alpha", StreamConfig::default());
        let c = reg.register("beta", StreamConfig::default());
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn register_checked_rejects_conflicts() {
        let reg = StreamRegistry::new(1);
        let a = reg.register_checked("alpha", StreamConfig::default()).unwrap();
        // Identical config: idempotent.
        let b = reg.register_checked("alpha", StreamConfig::default()).unwrap();
        assert_eq!(a, b);
        // Conflicting config: rejected, registry unchanged.
        let err = reg
            .register_checked("alpha", StreamConfig { blocks: 2, ..Default::default() })
            .unwrap_err();
        assert!(format!("{err}").contains("different config"), "{err}");
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn seed_override_wins_over_derivation() {
        let reg = StreamRegistry::new(7);
        let derived = reg.register("d", StreamConfig::default());
        let pinned =
            reg.register("p", StreamConfig { seed: Some(20260710), ..Default::default() });
        assert_ne!(reg.stream_seed(derived), 20260710);
        assert_eq!(reg.stream_seed(pinned), 20260710);
    }

    #[test]
    fn stream_seeds_decorrelated() {
        let reg = StreamRegistry::new(7);
        let s0 = reg.stream_seed(StreamId(0));
        let s1 = reg.stream_seed(StreamId(1));
        let diff = (s0 ^ s1).count_ones();
        assert!((16..=48).contains(&diff), "seeds too similar: {diff} differing bits");
    }

    #[test]
    fn xorwow_exact_states_disjoint_and_reproducible() {
        let reg = StreamRegistry::new(3);
        let (x0, d0) = reg.xorwow_exact_state(StreamId(0));
        let (x1, d1) = reg.xorwow_exact_state(StreamId(1));
        let (x1b, _) = reg.xorwow_exact_state(StreamId(1));
        assert_ne!(x0, x1);
        assert_eq!(x1, x1b);
        assert_eq!(d0, d1); // 2^96 steps leave the 2^32-period Weyl unchanged
    }

    #[test]
    fn exact_jump_matches_iterated_small() {
        // Verify the jump helper against brute force for small k.
        let mut g = Xorwow::new(11);
        let (x0, _) = g.state();
        for _ in 0..500 {
            g.step_raw();
        }
        assert_eq!(xorwow_jump(&x0, 500), g.state().0);
    }
}

//! Stream registry: named logical streams with provably disjoint
//! subsequences.
//!
//! Disjointness strategy (paper §4 + our gf2 machinery) is per-stream
//! configurable via [`Placement`]:
//!
//! * **[`Placement::SeedMix`]** (default): stream id `i` seeds its
//!   generator with `SeedSequence(root).child(i)` — the avalanche-mixed
//!   "consecutive seeds" scheme the paper credits xorgens'
//!   initialisation for; for the 4096-bit xorgens state the probability
//!   of overlap within any practical horizon is ~2^-4000-ish. Bit-
//!   identical to the historical behavior.
//! * **[`Placement::ExactJump`]**: registration allocates the stream
//!   `blocks` consecutive *substream slots* from a registry-wide
//!   counter; block `b` of the stream is the kind's master sequence
//!   jumped exactly `(slot + b) · 2^log2_spacing` steps via the
//!   polynomial jump engine ([`PlacedMaster`]) — *provably* disjoint
//!   while each block draws fewer than `2^log2_spacing` outputs. Works
//!   for every linear kind, including 4096-bit xorgens and the MT
//!   family, which the old dense-matrix path could not reach.
//! * **[`Placement::Leapfrog`]**: the stream's blocks deal one
//!   (seed-mixed) master sequence out round-robin, so its interleaved
//!   output is the serial master stream for any block count.
//!
//! Slot allocation happens at **registration** time, in registration
//! order, so placement is deterministic for a deterministic client
//! program regardless of which worker materialises the backend first.

use super::backend::BackendKind;
use crate::gf2::{jump_state, transition_matrix, transition_power};
use crate::prng::init::SeedSequence;
use crate::prng::place::PlacedMaster;
pub use crate::prng::place::Placement;
use crate::prng::xorwow::XorwowLfsr;
use crate::prng::GeneratorKind;
use crate::runtime::Transform;
use crate::util::error::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// Stream handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamId(pub u64);

/// Configuration for a new stream.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamConfig {
    pub kind: GeneratorKind,
    pub transform: Transform,
    pub backend: BackendKind,
    /// Blocks for the Rust backend (PJRT uses the artifact's shape).
    pub blocks: usize,
    /// Rounds per launch for the Rust backend.
    pub rounds_per_launch: usize,
    /// How this stream's blocks are placed in the master sequence (see
    /// the module docs; `SeedMix` is the historical default).
    pub placement: Placement,
    /// Explicit generator seed. `None` (the default) derives the seed from
    /// the coordinator's root seed by avalanche mixing — the disjointness
    /// scheme documented above. `Some(s)` seeds the stream's generator
    /// with exactly `s`, reproducing a library-level generator
    /// (`make_block_generator(kind, s, blocks)`) through the service —
    /// the golden-vector equivalence tests pin this path. Ignored by
    /// `ExactJump` placement (the master's offset, not a seed, is the
    /// stream's identity there).
    pub seed: Option<u64>,
    /// Explicit first substream slot for `ExactJump` placement. `None`
    /// (the default) allocates `blocks` consecutive slots from the
    /// registry's counter — within the registry's leased slot range when
    /// one is configured. `Some(s)` pins the stream's blocks to slots
    /// `s .. s + blocks` regardless of the registry counter: this is how
    /// the cluster router acts as the *global* slot authority, placing a
    /// stream at the same master-sequence offsets on whichever shard
    /// serves it (see [`crate::cluster`]). Ignored by `SeedMix` /
    /// `Leapfrog` placement.
    pub slot_base: Option<u64>,
    /// Generation-ahead depth for this stream, in launches per background
    /// prefetch job. `None` (the default) uses the coordinator's
    /// [`prefetch`](crate::coordinator::CoordinatorConfig::prefetch)
    /// default; `Some(0)` forces prefetch off for this stream;
    /// `Some(d)` keeps `d` launches generating on the fill pool while
    /// the current buffer drains. The served stream is bit-identical for
    /// every value (Rust backend, U32/F32 transforms; `Normal` never
    /// prefetches).
    pub prefetch: Option<usize>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            kind: GeneratorKind::XorgensGp,
            transform: Transform::U32,
            backend: BackendKind::Rust,
            blocks: 64,
            rounds_per_launch: 16,
            placement: Placement::SeedMix,
            seed: None,
            slot_base: None,
            prefetch: None,
        }
    }
}

/// Registry: stream name -> id + config; seeds derived from a root seed;
/// exact-jump placement slots allocated at registration.
pub struct StreamRegistry {
    root: u64,
    inner: Mutex<RegistryInner>,
    /// Per-kind placement masters (jump engine + memoized per-spacing
    /// bases), built on first exact-jump use of a kind.
    masters: Mutex<HashMap<GeneratorKind, PlacedMaster>>,
}

struct RegistryInner {
    by_name: HashMap<String, StreamId>,
    configs: HashMap<StreamId, StreamConfig>,
    next: u64,
    /// First substream slot of each exact-jump stream.
    slot_base: HashMap<StreamId, u64>,
    /// Next free substream slot (advanced by `blocks` per exact stream).
    next_slot: u64,
    /// One past the last substream slot this registry may allocate (its
    /// **leased range**, see [`crate::cluster::lease`]). `u64::MAX` for a
    /// standalone registry.
    slot_limit: u64,
}

impl StreamRegistry {
    pub fn new(root_seed: u64) -> Self {
        Self::with_slot_range(root_seed, 0..u64::MAX)
    }

    /// A registry whose automatic exact-jump slot allocation is confined
    /// to `slots` — the substream-slot **lease** of a cluster shard
    /// (shard `j` owns `j·2^32 .. (j+1)·2^32`, so the PR 3 disjointness
    /// theorem holds across processes with no coordination). Explicit
    /// [`StreamConfig::slot_base`] assignments bypass the range: they
    /// carry the router's global allocation, which is the cluster's slot
    /// authority when one is present.
    pub fn with_slot_range(root_seed: u64, slots: std::ops::Range<u64>) -> Self {
        StreamRegistry {
            root: root_seed,
            inner: Mutex::new(RegistryInner {
                by_name: HashMap::new(),
                configs: HashMap::new(),
                next: 0,
                slot_base: HashMap::new(),
                next_slot: slots.start,
                slot_limit: slots.end,
            }),
            masters: Mutex::new(HashMap::new()),
        }
    }

    /// Register (or look up) a named stream.
    ///
    /// Idempotent by name: re-registering an existing name returns the
    /// existing id and **ignores** the new config. The typed-handle
    /// builder goes through [`register_checked`] instead, which rejects
    /// conflicting re-registration.
    ///
    /// [`register_checked`]: StreamRegistry::register_checked
    pub fn register(&self, name: &str, config: StreamConfig) -> StreamId {
        let mut inner = self.inner.lock().unwrap();
        if let Some(&id) = inner.by_name.get(name) {
            return id;
        }
        // Slot exhaustion is unreachable on the default 0..u64::MAX range;
        // on a leased shard range it is a deployment error (the shard's
        // 2^32 slots are spent) — the checked path reports it, this legacy
        // infallible path surfaces it loudly.
        Self::insert(&mut inner, name, config).expect("substream slot lease exhausted")
    }

    /// Register a named stream, erroring if the name is already registered
    /// with a *different* config (re-attaching with an identical config is
    /// fine and returns the existing id).
    pub fn register_checked(&self, name: &str, config: StreamConfig) -> Result<StreamId> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(&id) = inner.by_name.get(name) {
            let existing = &inner.configs[&id];
            if *existing != config {
                bail!(
                    "stream {name:?} already registered with a different config \
                     (existing: {existing:?}, requested: {config:?})"
                );
            }
            return Ok(id);
        }
        Self::insert(&mut inner, name, config)
    }

    /// Fresh insert: assign the id and, for exact-jump placement, the
    /// stream's consecutive substream slots (one per block) — either the
    /// explicit [`StreamConfig::slot_base`] assignment, or the next free
    /// slots of the registry's leased range.
    fn insert(inner: &mut RegistryInner, name: &str, config: StreamConfig) -> Result<StreamId> {
        let id = StreamId(inner.next);
        if matches!(config.placement, Placement::ExactJump { .. }) {
            let blocks = config.blocks as u64;
            let base = match config.slot_base {
                Some(base) => {
                    ensure!(
                        base.checked_add(blocks).is_some(),
                        "stream {name:?}: explicit slot base {base} + {blocks} blocks \
                         overflows the slot space"
                    );
                    base
                }
                None => {
                    let base = inner.next_slot;
                    let end = base.checked_add(blocks);
                    ensure!(
                        end.map_or(false, |e| e <= inner.slot_limit),
                        "stream {name:?}: substream slot lease exhausted \
                         ({blocks} slots requested at {base}, lease ends at {})",
                        inner.slot_limit
                    );
                    inner.next_slot = end.unwrap();
                    base
                }
            };
            inner.slot_base.insert(id, base);
        }
        inner.next += 1;
        inner.by_name.insert(name.to_string(), id);
        inner.configs.insert(id, config);
        Ok(id)
    }

    pub fn config(&self, id: StreamId) -> Option<StreamConfig> {
        self.inner.lock().unwrap().configs.get(&id).cloned()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The seed for a stream: the explicit [`StreamConfig::seed`] override
    /// when set, otherwise the avalanche-mixed child of the root (the
    /// paper-§4 "consecutive ids, strong init" scheme).
    pub fn stream_seed(&self, id: StreamId) -> u64 {
        if let Some(seed) = self.inner.lock().unwrap().configs.get(&id).and_then(|c| c.seed) {
            return seed;
        }
        SeedSequence::new(self.root).child(id.0).next_u64()
    }

    /// The first substream slot of an exact-jump stream (its blocks own
    /// slots `base .. base + blocks`).
    pub fn slot_base(&self, id: StreamId) -> Option<u64> {
        self.inner.lock().unwrap().slot_base.get(&id).copied()
    }

    /// The placed per-block states of an exact-jump stream, concatenated
    /// in the kind's `dump_state` layout (ready for
    /// `BlockParallel::load_state`). Block `b` is the kind's master
    /// jumped `(slot + b) · 2^log2_spacing` steps.
    pub fn placed_block_states(&self, id: StreamId) -> Result<Vec<u32>> {
        let (config, slot) = {
            let inner = self.inner.lock().unwrap();
            let config = inner.configs.get(&id).context("unknown stream")?.clone();
            (config, inner.slot_base.get(&id).copied())
        };
        let Placement::ExactJump { log2_spacing } = config.placement else {
            bail!("stream {id:?} does not use exact-jump placement");
        };
        let slot = slot.context("exact-jump stream has no placement slot")?;
        // Canonicalize aliased kinds (Xorgens→XorgensGp, Mt19937→Mtgp) so
        // one expensive jump-engine probe serves both spellings.
        let kind = crate::prng::place::canonical_master_kind(config.kind);
        // Build the master OUTSIDE the lock: the min-poly probe can take
        // ~a second for MT-class state, and holding the map mutex across
        // it would stall materialization of unrelated kinds on other
        // workers. A racing duplicate build is deterministic and
        // identical; `or_insert` keeps exactly one.
        if !self.masters.lock().unwrap().contains_key(&kind) {
            let built = PlacedMaster::new(kind, self.root);
            self.masters.lock().unwrap().entry(kind).or_insert(built);
        }
        let mut masters = self.masters.lock().unwrap();
        let master = masters.get_mut(&kind).expect("just inserted");
        let mut out = Vec::with_capacity(config.blocks * master.block_words());
        for b in 0..config.blocks as u64 {
            out.extend(master.state_at(slot + b, log2_spacing));
        }
        Ok(out)
    }

    /// XORWOW legacy exact placement: the state at offset `id · 2^96` of
    /// the master sequence, now computed by the polynomial jump engine
    /// (O(deg)·log(id) instead of the old O(id) dense matrix-vector
    /// walk). The Weyl counter is unchanged: 2^96 is a multiple of its
    /// 2^32 period. Bit-identical to the dense path
    /// ([`xorwow_exact_state_dense`] pins this).
    ///
    /// [`xorwow_exact_state_dense`]: StreamRegistry::xorwow_exact_state_dense
    pub fn xorwow_exact_state(&self, id: StreamId) -> ([u32; 5], u32) {
        let mut masters = self.masters.lock().unwrap();
        let master = masters
            .entry(GeneratorKind::Xorwow)
            .or_insert_with(|| PlacedMaster::new(GeneratorKind::Xorwow, self.root));
        let s = master.state_at(id.0, Placement::DEFAULT_LOG2_SPACING);
        ([s[0], s[1], s[2], s[3], s[4]], s[5])
    }

    /// Dense-matrix reference for [`xorwow_exact_state`]: `M^(id · 2^96)`
    /// in one [`transition_power`] call (no hand-rolled squaring loop, no
    /// per-id matrix-vector walk). Kept as the independent cross-check
    /// the polynomial path is pinned against.
    ///
    /// [`xorwow_exact_state`]: StreamRegistry::xorwow_exact_state
    pub fn xorwow_exact_state_dense(&self, id: StreamId) -> ([u32; 5], u32) {
        assert!(id.0 < u32::MAX as u64, "dense reference limited to id < 2^32");
        let mut seq = SeedSequence::new(self.root ^ 0x584f_5257); // "XORW"
        let master = crate::prng::Xorwow::from_seq(&mut seq);
        let (x, d) = master.state();
        let m = transition_matrix(&XorwowLfsr);
        let mk = transition_power(&m, (id.0 as u128) << 96);
        let v = jump_state(&mk, &x);
        ([v[0], v[1], v[2], v[3], v[4]], d)
    }
}

/// Stand-alone helper used by tests: jump a XORWOW LFSR state by `k`
/// (dense-matrix path; the polynomial engine is cross-checked against it).
pub fn xorwow_jump(state: &[u32; 5], k: u128) -> [u32; 5] {
    let m = transition_matrix(&XorwowLfsr);
    let mk = transition_power(&m, k);
    let v = jump_state(&mk, state);
    [v[0], v[1], v[2], v[3], v[4]]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::xorwow::Xorwow;

    #[test]
    fn register_is_idempotent() {
        let reg = StreamRegistry::new(1);
        let a = reg.register("alpha", StreamConfig::default());
        let b = reg.register("alpha", StreamConfig::default());
        let c = reg.register("beta", StreamConfig::default());
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn register_checked_rejects_conflicts() {
        let reg = StreamRegistry::new(1);
        let a = reg.register_checked("alpha", StreamConfig::default()).unwrap();
        // Identical config: idempotent.
        let b = reg.register_checked("alpha", StreamConfig::default()).unwrap();
        assert_eq!(a, b);
        // Conflicting config: rejected, registry unchanged.
        let err = reg
            .register_checked("alpha", StreamConfig { blocks: 2, ..Default::default() })
            .unwrap_err();
        assert!(format!("{err}").contains("different config"), "{err}");
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn seed_override_wins_over_derivation() {
        let reg = StreamRegistry::new(7);
        let derived = reg.register("d", StreamConfig::default());
        let pinned =
            reg.register("p", StreamConfig { seed: Some(20260710), ..Default::default() });
        assert_ne!(reg.stream_seed(derived), 20260710);
        assert_eq!(reg.stream_seed(pinned), 20260710);
    }

    #[test]
    fn stream_seeds_decorrelated() {
        let reg = StreamRegistry::new(7);
        let s0 = reg.stream_seed(StreamId(0));
        let s1 = reg.stream_seed(StreamId(1));
        let diff = (s0 ^ s1).count_ones();
        assert!((16..=48).contains(&diff), "seeds too similar: {diff} differing bits");
    }

    #[test]
    fn exact_jump_streams_get_consecutive_slots() {
        let reg = StreamRegistry::new(1);
        let exact = |blocks| StreamConfig {
            placement: Placement::ExactJump { log2_spacing: 64 },
            blocks,
            ..Default::default()
        };
        let a = reg.register("a", exact(4));
        let mixed = reg.register("m", StreamConfig::default());
        let b = reg.register("b", exact(2));
        // Re-registration does not re-allocate.
        let a2 = reg.register("a", exact(4));
        assert_eq!(a, a2);
        assert_eq!(reg.slot_base(a), Some(0));
        assert_eq!(reg.slot_base(b), Some(4)); // after a's 4 blocks
        assert_eq!(reg.slot_base(mixed), None); // seed-mix streams have no slot
    }

    #[test]
    fn placed_block_states_disjoint_and_reproducible() {
        let reg = StreamRegistry::new(5);
        let exact = StreamConfig {
            kind: GeneratorKind::Xorwow,
            placement: Placement::ExactJump { log2_spacing: 40 },
            blocks: 2,
            ..Default::default()
        };
        let a = reg.register("a", exact.clone());
        let b = reg.register("b", exact);
        let sa = reg.placed_block_states(a).unwrap();
        let sb = reg.placed_block_states(b).unwrap();
        let sa2 = reg.placed_block_states(a).unwrap();
        assert_eq!(sa.len(), 2 * 6); // 2 blocks × (5 LFSR + 1 Weyl)
        assert_eq!(sa, sa2);
        assert_ne!(sa, sb);
        assert_ne!(&sa[..6], &sa[6..]); // blocks themselves differ
        // Seed-mix streams have no placed states.
        let m = reg.register("m", StreamConfig::default());
        assert!(reg.placed_block_states(m).is_err());
    }

    #[test]
    fn xorwow_exact_states_disjoint_and_reproducible() {
        let reg = StreamRegistry::new(3);
        let (x0, d0) = reg.xorwow_exact_state(StreamId(0));
        let (x1, d1) = reg.xorwow_exact_state(StreamId(1));
        let (x1b, _) = reg.xorwow_exact_state(StreamId(1));
        assert_ne!(x0, x1);
        assert_eq!(x1, x1b);
        assert_eq!(d0, d1); // 2^96 steps leave the 2^32-period Weyl unchanged
    }

    /// The acceptance pin: the polynomial jump path reproduces the dense
    /// transition-matrix path on XORWOW bit for bit.
    #[test]
    fn polynomial_placement_matches_dense_matrix_path() {
        let reg = StreamRegistry::new(3);
        for id in 0..4 {
            let poly = reg.xorwow_exact_state(StreamId(id));
            let dense = reg.xorwow_exact_state_dense(StreamId(id));
            assert_eq!(poly, dense, "id={id}");
        }
    }

    #[test]
    fn leased_slot_range_confines_allocation() {
        // A shard registry allocates from its leased range and errors —
        // not silently wraps — when the lease is spent.
        let reg = StreamRegistry::with_slot_range(1, 100..104);
        let exact = |blocks| StreamConfig {
            placement: Placement::ExactJump { log2_spacing: 64 },
            blocks,
            ..Default::default()
        };
        let a = reg.register_checked("a", exact(3)).unwrap();
        assert_eq!(reg.slot_base(a), Some(100));
        let err = reg.register_checked("b", exact(2)).unwrap_err();
        assert!(format!("{err}").contains("lease exhausted"), "{err}");
        // The failed insert consumed nothing: one more 1-block stream fits.
        let c = reg.register_checked("c", exact(1)).unwrap();
        assert_eq!(reg.slot_base(c), Some(103));
        // Seed-mix streams never touch the lease.
        assert!(reg.register_checked("m", StreamConfig::default()).is_ok());
    }

    #[test]
    fn explicit_slot_base_overrides_allocation() {
        // The router's global slot assignment pins the stream's offsets
        // regardless of the shard's local counter — and the placed states
        // equal what a standalone registry computes for the same global
        // slot (the cross-process disjointness story).
        let exact = |slot_base| StreamConfig {
            kind: GeneratorKind::Xorwow,
            placement: Placement::ExactJump { log2_spacing: 40 },
            blocks: 2,
            slot_base,
            ..Default::default()
        };
        let shard = StreamRegistry::with_slot_range(5, 1 << 32..2u64 << 32);
        let pinned = shard.register_checked("p", exact(Some(2))).unwrap();
        assert_eq!(shard.slot_base(pinned), Some(2));
        // Explicit assignment does not advance the shard's own counter.
        let local = shard.register_checked("l", exact(None)).unwrap();
        assert_eq!(shard.slot_base(local), Some(1 << 32));
        // Same root seed + same global slot => identical placed states,
        // whatever registry computed them.
        let single = StreamRegistry::new(5);
        let _skip = single.register_checked("skip", exact(None)).unwrap(); // slots 0..2
        let same = single.register_checked("same", exact(None)).unwrap(); // slots 2..4
        assert_eq!(
            shard.placed_block_states(pinned).unwrap(),
            single.placed_block_states(same).unwrap()
        );
    }

    #[test]
    fn exact_jump_matches_iterated_small() {
        // Verify the jump helper against brute force for small k.
        let mut g = Xorwow::new(11);
        let (x0, _) = g.state();
        for _ in 0..500 {
            g.step_raw();
        }
        assert_eq!(xorwow_jump(&x0, 500), g.state().0);
    }
}

//! Typed, zero-copy, pipelined client handles for the coordinator.
//!
//! The legacy surface (`Coordinator::draw(StreamId, n) -> Result<Draws>`)
//! made every u32-vs-f32 mismatch a *runtime* error, allocated a fresh
//! reply for every request, and could only block. This module replaces it
//! with:
//!
//! * [`Sample`] — the element types a stream can produce (`u32`, `f32`),
//!   tied to the stream's [`Transform`] at handle-construction time;
//! * [`StreamBuilder`] — a fluent builder whose *terminal* methods
//!   ([`u32`](StreamBuilder::u32), [`uniform`](StreamBuilder::uniform),
//!   [`normal`](StreamBuilder::normal)) pick the transform and the handle
//!   type together, so a transform/type mismatch is unrepresentable;
//! * [`TypedStream<T>`] — a `Copy` handle with blocking
//!   [`draw`](TypedStream::draw) / [`draw_into`](TypedStream::draw_into)
//!   (caller-owned buffer, extending the bulk-fill engine's contract
//!   across the service boundary) and non-blocking
//!   [`submit`](TypedStream::submit);
//! * [`Ticket<T>`] — an in-flight request. Clients pipeline by submitting
//!   several tickets before waiting, keeping the sharded workers busy
//!   while the client consumes earlier replies.
//!
//! **Reply-buffer lifecycle** (the zero-copy story): workers build replies
//! in buffers popped from a shared recycle pool; [`Ticket::wait_into`] /
//! [`TypedStream::draw_into`] copy the reply into the caller's slice and
//! *recycle* the buffer back to the pool, so the steady-state reply path
//! allocates nothing. [`Ticket::wait`] / [`TypedStream::draw`] instead
//! hand the reply's storage to the caller as a `Vec<T>` (ownership moves
//! out; nothing is copied, nothing is recycled).
//!
//! ```
//! use xorgens_gp::coordinator::{Coordinator, CoordinatorConfig};
//!
//! let coord = Coordinator::new(CoordinatorConfig::default());
//! // The terminal method fixes the element type: this is a `TypedStream<u32>`.
//! let raw = coord.builder("doc-raw").u32()?;
//! let mut buf = vec![0u32; 1000];
//! raw.draw_into(&mut buf)?; // zero-copy into the caller's slice
//!
//! // f32 streams come from the f32 terminals; u32 draws on them are a
//! // *compile-time* error now, not a bail!().
//! let normals = coord.builder("doc-normals").normal()?;
//! let z: Vec<f32> = normals.draw(4)?;
//! assert_eq!(z.len(), 4);
//!
//! // Pipelining: submit ahead, wait later.
//! let tickets: Vec<_> = (0..4).map(|_| raw.submit(250)).collect::<Result<_, _>>()?;
//! for t in tickets {
//!     assert_eq!(t.wait()?.len(), 250);
//! }
//! coord.shutdown();
//! # Ok::<(), xorgens_gp::util::error::Error>(())
//! ```

use super::backend::{BackendKind, Draws};
use super::service::Coordinator;
use super::stream::{Placement, StreamConfig, StreamId};
use crate::obs::trace::{self as otrace, SpanKind};
use crate::prng::GeneratorKind;
use crate::runtime::Transform;
use crate::util::error::{bail, Context, Result};
use std::marker::PhantomData;
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Mutex};

mod sealed {
    pub trait Sealed {}
    impl Sealed for u32 {}
    impl Sealed for f32 {}
}

/// An element type a stream can produce. Implemented for `u32` (raw draws,
/// [`Transform::U32`]) and `f32` ([`Transform::F32`] uniforms and
/// [`Transform::Normal`] normals). Sealed: the reply protocol only carries
/// these two layouts.
pub trait Sample: Copy + Send + Sync + 'static + sealed::Sealed {
    /// Element name for error messages ("u32" / "f32").
    const NAME: &'static str;

    /// Does a stream with transform `t` produce this element type?
    fn matches(t: Transform) -> bool;

    /// Does a delivered reply carry this element type's layout? Used to
    /// vet abandoned replies before recycling them (see [`Ticket`]'s
    /// `Drop`): a malformed reply must be dropped, not pooled.
    #[doc(hidden)]
    fn variant_matches(d: &Draws) -> bool;

    /// Take ownership of a reply's storage as `Vec<Self>`.
    #[doc(hidden)]
    fn take(d: Draws) -> Result<Vec<Self>>;

    /// Copy a reply into a caller-owned slice (lengths must match).
    #[doc(hidden)]
    fn copy_from(d: &Draws, out: &mut [Self]) -> Result<()>;
}

impl Sample for u32 {
    const NAME: &'static str = "u32";

    fn matches(t: Transform) -> bool {
        t == Transform::U32
    }

    fn variant_matches(d: &Draws) -> bool {
        matches!(d, Draws::U32(_))
    }

    fn take(d: Draws) -> Result<Vec<u32>> {
        match d {
            Draws::U32(v) => Ok(v),
            Draws::F32(_) => bail!("reply carries f32 draws, handle expects u32"),
        }
    }

    fn copy_from(d: &Draws, out: &mut [u32]) -> Result<()> {
        match d {
            Draws::U32(v) if v.len() == out.len() => {
                out.copy_from_slice(v);
                Ok(())
            }
            Draws::U32(v) => bail!("reply length {} != buffer length {}", v.len(), out.len()),
            Draws::F32(_) => bail!("reply carries f32 draws, handle expects u32"),
        }
    }
}

impl Sample for f32 {
    const NAME: &'static str = "f32";

    fn matches(t: Transform) -> bool {
        matches!(t, Transform::F32 | Transform::Normal)
    }

    fn variant_matches(d: &Draws) -> bool {
        matches!(d, Draws::F32(_))
    }

    fn take(d: Draws) -> Result<Vec<f32>> {
        match d {
            Draws::F32(v) => Ok(v),
            Draws::U32(_) => bail!("reply carries u32 draws, handle expects f32"),
        }
    }

    fn copy_from(d: &Draws, out: &mut [f32]) -> Result<()> {
        match d {
            Draws::F32(v) if v.len() == out.len() => {
                out.copy_from_slice(v);
                Ok(())
            }
            Draws::F32(v) => bail!("reply length {} != buffer length {}", v.len(), out.len()),
            Draws::U32(_) => bail!("reply carries u32 draws, handle expects f32"),
        }
    }
}

/// Retained recycled buffers per variant; bounds pool memory to
/// `2 × POOL_CAP` buffers of at most one largest-draw capacity each.
const POOL_CAP: usize = 64;

/// Shared recycle pool for reply buffers.
///
/// Workers pop a cleared buffer (capacity kept) when building a reply;
/// clients on the `draw_into`/`wait_into` path push the reply's storage
/// back after copying out. Allocation then only happens while the pool
/// warms up or when clients keep replies (`wait`/`draw`, which move the
/// storage out as the result `Vec`).
pub(crate) struct BufferPool {
    u32s: Mutex<Vec<Vec<u32>>>,
    f32s: Mutex<Vec<Vec<f32>>>,
}

impl BufferPool {
    pub(crate) fn new() -> BufferPool {
        BufferPool { u32s: Mutex::new(Vec::new()), f32s: Mutex::new(Vec::new()) }
    }

    /// Pop a recycled buffer of the variant matching `t` (empty, capacity
    /// kept), or a fresh empty one. `hit` reports which happened.
    pub(crate) fn get(&self, t: Transform) -> (Draws, bool) {
        match t {
            Transform::U32 => match self.u32s.lock().unwrap().pop() {
                Some(v) => (Draws::U32(v), true),
                None => (Draws::U32(Vec::new()), false),
            },
            Transform::F32 | Transform::Normal => match self.f32s.lock().unwrap().pop() {
                Some(v) => (Draws::F32(v), true),
                None => (Draws::F32(Vec::new()), false),
            },
        }
    }

    /// Return a buffer to the pool (cleared; dropped if the pool is full).
    pub(crate) fn put(&self, d: Draws) {
        match d {
            Draws::U32(mut v) => {
                v.clear();
                let mut guard = self.u32s.lock().unwrap();
                if guard.len() < POOL_CAP {
                    guard.push(v);
                }
            }
            Draws::F32(mut v) => {
                v.clear();
                let mut guard = self.f32s.lock().unwrap();
                if guard.len() < POOL_CAP {
                    guard.push(v);
                }
            }
        }
    }
}

/// Fluent stream construction. Obtained from [`Coordinator::builder`];
/// consumed by one of the typed terminal methods.
///
/// ```
/// use xorgens_gp::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
/// use xorgens_gp::prng::GeneratorKind;
///
/// let coord = Coordinator::new(CoordinatorConfig::default());
/// let stream = coord
///     .builder("doc-builder")
///     .kind(GeneratorKind::Xorwow)
///     .backend(BackendKind::Rust)
///     .blocks(8)
///     .rounds_per_launch(4)
///     .u32()?; // terminal: TypedStream<u32> with Transform::U32
/// assert_eq!(stream.draw(100)?.len(), 100);
/// coord.shutdown();
/// # Ok::<(), xorgens_gp::util::error::Error>(())
/// ```
#[must_use = "a StreamBuilder does nothing until a terminal method (u32/uniform/normal) runs"]
pub struct StreamBuilder<'c> {
    coord: &'c Coordinator,
    name: String,
    config: StreamConfig,
}

impl<'c> StreamBuilder<'c> {
    pub(crate) fn new(coord: &'c Coordinator, name: &str) -> StreamBuilder<'c> {
        StreamBuilder { coord, name: name.to_string(), config: StreamConfig::default() }
    }

    /// Generator kind (default: the paper's xorgensGP).
    pub fn kind(mut self, kind: GeneratorKind) -> Self {
        self.config.kind = kind;
        self
    }

    /// Backend (default: pure Rust).
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.config.backend = backend;
        self
    }

    /// Blocks for the Rust backend (PJRT uses the artifact's shape).
    pub fn blocks(mut self, blocks: usize) -> Self {
        self.config.blocks = blocks;
        self
    }

    /// Rounds per launch for the Rust backend.
    pub fn rounds_per_launch(mut self, rounds: usize) -> Self {
        self.config.rounds_per_launch = rounds;
        self
    }

    /// How the stream's blocks are placed in the master sequence:
    /// [`Placement::SeedMix`] (default), [`Placement::ExactJump`]
    /// (provably disjoint substreams via polynomial jump-ahead — any
    /// linear kind), or [`Placement::Leapfrog`] (block-count-independent
    /// serial stream).
    pub fn placement(mut self, placement: Placement) -> Self {
        self.config.placement = placement;
        self
    }

    /// Legacy shim for the old XORWOW-only boolean: `true` maps to
    /// [`Placement::ExactJump`] at the historical 2^96 spacing, `false`
    /// to [`Placement::SeedMix`].
    #[deprecated(note = "use `.placement(Placement::ExactJump { log2_spacing })` — exact \
                         placement now works for every linear generator kind")]
    pub fn exact_jump(self, on: bool) -> Self {
        self.placement(if on {
            Placement::ExactJump { log2_spacing: Placement::DEFAULT_LOG2_SPACING }
        } else {
            Placement::SeedMix
        })
    }

    /// Explicit generator seed (default: derived from the coordinator's
    /// root seed — see [`StreamConfig::seed`]).
    pub fn seed(mut self, seed: u64) -> Self {
        self.config.seed = Some(seed);
        self
    }

    /// Generation-ahead depth for this stream, in launches (default: the
    /// coordinator's [`CoordinatorConfig::prefetch`]; `0` forces prefetch
    /// off). Output is bit-identical at every depth — see
    /// [`StreamConfig::prefetch`].
    ///
    /// [`CoordinatorConfig::prefetch`]: crate::coordinator::CoordinatorConfig
    pub fn prefetch(mut self, depth: usize) -> Self {
        self.config.prefetch = Some(depth);
        self
    }

    /// Replace the whole config (the terminal method still sets the
    /// transform).
    pub fn with_config(mut self, config: StreamConfig) -> Self {
        self.config = config;
        self
    }

    /// Terminal: raw 32-bit draws ([`Transform::U32`]).
    pub fn u32(self) -> Result<TypedStream<'c, u32>> {
        self.finish(Transform::U32)
    }

    /// Terminal: uniform draws on [0, 1) ([`Transform::F32`]).
    pub fn uniform(self) -> Result<TypedStream<'c, f32>> {
        self.finish(Transform::F32)
    }

    /// Terminal: standard-normal draws ([`Transform::Normal`]).
    pub fn normal(self) -> Result<TypedStream<'c, f32>> {
        self.finish(Transform::Normal)
    }

    /// Register the stream (erroring if `name` already exists with a
    /// different config) and hand back the typed handle.
    fn finish<T: Sample>(mut self, transform: Transform) -> Result<TypedStream<'c, T>> {
        debug_assert!(T::matches(transform));
        self.config.transform = transform;
        let id = self
            .coord
            .register_checked(&self.name, self.config)
            .with_context(|| format!("building stream {:?}", self.name))?;
        Ok(TypedStream { coord: self.coord, id, transform, _elem: PhantomData })
    }
}

/// A typed handle on one coordinator stream. `Copy`: share it freely
/// across scoped threads. Created by [`StreamBuilder`]'s terminal methods
/// or by [`Coordinator::typed`].
pub struct TypedStream<'c, T: Sample> {
    coord: &'c Coordinator,
    id: StreamId,
    transform: Transform,
    _elem: PhantomData<fn() -> T>,
}

impl<T: Sample> Clone for TypedStream<'_, T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T: Sample> Copy for TypedStream<'_, T> {}

impl<T: Sample> std::fmt::Debug for TypedStream<'_, T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TypedStream")
            .field("id", &self.id)
            .field("transform", &self.transform.name())
            .field("elem", &T::NAME)
            .finish()
    }
}

impl<'c, T: Sample> TypedStream<'c, T> {
    pub(crate) fn attach(
        coord: &'c Coordinator,
        id: StreamId,
        transform: Transform,
    ) -> TypedStream<'c, T> {
        TypedStream { coord, id, transform, _elem: PhantomData }
    }

    /// The underlying registry id (interop with the legacy surface).
    pub fn id(&self) -> StreamId {
        self.id
    }

    /// The stream's output transform.
    pub fn transform(&self) -> Transform {
        self.transform
    }

    /// Enqueue a draw of `n` elements without waiting for the reply — the
    /// pipelining primitive. With `block_on_full = false` a full shard
    /// queue rejects immediately (backpressure, counted in
    /// `metrics.rejected`); otherwise the enqueue itself may block until
    /// the queue drains.
    pub fn submit(&self, n: usize) -> Result<Ticket<T>> {
        // Mint the causal trace id here — the top of the stack. It rides
        // the request into the worker loop (and from there into the fill
        // pool), so `trace dump` can reconstruct this draw end to end.
        let trace = otrace::next_trace_id();
        let start_us = if otrace::enabled() { otrace::now_us() } else { 0 };
        let rx = self.coord.submit_traced(self.id, n, trace)?;
        Ok(Ticket {
            rx: Some(rx),
            n,
            pool: self.coord.pool_handle(),
            trace,
            start_us,
            _elem: PhantomData,
        })
    }

    /// Draw `n` elements, blocking; the reply's storage becomes the
    /// returned `Vec` (no copy, no recycle).
    pub fn draw(&self, n: usize) -> Result<Vec<T>> {
        self.submit(n)?.wait()
    }

    /// Fill the caller-owned slice, blocking — the zero-copy serve path:
    /// the pooled reply buffer is copied into `out` and recycled.
    pub fn draw_into(&self, out: &mut [T]) -> Result<()> {
        self.submit(out.len())?.wait_into(out)
    }
}

/// An in-flight draw request: the client half of a pipelined submit.
/// Dropping a ticket abandons the request (the worker's reply buffer is
/// recycled, not leaked).
#[must_use = "a Ticket holds an in-flight request; wait() it (or drop it to abandon the draw)"]
pub struct Ticket<T: Sample> {
    rx: Option<Receiver<Result<Draws>>>,
    n: usize,
    pool: Arc<BufferPool>,
    /// Causal trace id minted at submit (0 = untraced).
    trace: u64,
    /// Submit timestamp for the client-side `draw` span (0 when tracing
    /// was disabled at submit — the span is then skipped).
    start_us: u64,
    _elem: PhantomData<fn() -> T>,
}

impl<T: Sample> Ticket<T> {
    /// Elements this ticket will deliver.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Block until the reply arrives; the reply's storage becomes the
    /// returned `Vec`.
    pub fn wait(mut self) -> Result<Vec<T>> {
        let d = self.recv_blocking()?;
        self.finish_draw_span();
        T::take(d)
    }

    /// Block until the reply arrives, copy it into the caller-owned slice
    /// (`out.len()` must equal [`n`](Ticket::n)), and recycle the reply
    /// buffer — the allocation-free steady-state path.
    pub fn wait_into(mut self, out: &mut [T]) -> Result<()> {
        crate::ensure!(
            out.len() == self.n,
            "buffer length {} != submitted draw size {}",
            out.len(),
            self.n
        );
        let d = self.recv_blocking()?;
        self.finish_draw_span();
        T::copy_from(&d, out)?;
        self.pool.put(d);
        Ok(())
    }

    /// Non-blocking poll: `None` while the request is still in flight,
    /// `Some(result)` exactly once when it completes (later calls return
    /// `None` again — the result has been taken).
    pub fn try_take(&mut self) -> Option<Result<Vec<T>>> {
        let rx = self.rx.as_ref()?;
        match rx.try_recv() {
            Ok(reply) => {
                self.rx = None;
                self.finish_draw_span();
                Some(reply.and_then(T::take))
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.rx = None;
                Some(Err(crate::anyhow!("worker dropped reply")))
            }
        }
    }

    fn recv_blocking(&mut self) -> Result<Draws> {
        let rx = self.rx.take().context("ticket already consumed")?;
        rx.recv().context("worker dropped reply")?
    }

    /// Commit the client-side `draw` span: submit → reply receipt.
    fn finish_draw_span(&self) {
        if self.start_us != 0 {
            otrace::record(self.trace, SpanKind::Draw, self.start_us, otrace::now_us(), self.n as u64);
        }
    }
}

impl<T: Sample> Drop for Ticket<T> {
    fn drop(&mut self) {
        // An abandoned ticket may already hold a delivered reply in its
        // channel slot; recycle that buffer. (The worker-side recycle in
        // the serve loop only covers the other ordering, where the send
        // happens after the receiver is gone and therefore fails.)
        //
        // Only a **well-formed** reply goes back to the shared pool:
        // exactly the submitted length and the element layout this handle
        // was built for. Anything else — a short reply from a connection
        // that died mid-serve, or a variant that never matched the handle
        // — is evidence of a broken producer, and pooling it would hand
        // the corruption to an unrelated stream's next draw. Drop it.
        if let Some(rx) = self.rx.take() {
            if let Ok(Ok(d)) = rx.try_recv() {
                if d.len() == self.n && T::variant_matches(&d) {
                    self.pool.put(d);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;

    fn quick() -> Coordinator {
        Coordinator::new(CoordinatorConfig { workers: 2, ..Default::default() })
    }

    #[test]
    fn sample_transform_compatibility() {
        assert!(<u32 as Sample>::matches(Transform::U32));
        assert!(!<u32 as Sample>::matches(Transform::F32));
        assert!(!<u32 as Sample>::matches(Transform::Normal));
        assert!(!<f32 as Sample>::matches(Transform::U32));
        assert!(<f32 as Sample>::matches(Transform::F32));
        assert!(<f32 as Sample>::matches(Transform::Normal));
    }

    #[test]
    fn sample_take_and_copy() {
        let v = <u32 as Sample>::take(Draws::U32(vec![1, 2, 3])).unwrap();
        assert_eq!(v, vec![1, 2, 3]);
        assert!(<u32 as Sample>::take(Draws::F32(vec![0.5])).is_err());
        let mut out = [0u32; 3];
        <u32 as Sample>::copy_from(&Draws::U32(vec![4, 5, 6]), &mut out).unwrap();
        assert_eq!(out, [4, 5, 6]);
        // Length mismatch is an error, not a truncation.
        assert!(<u32 as Sample>::copy_from(&Draws::U32(vec![1]), &mut out).is_err());
        assert!(<f32 as Sample>::take(Draws::U32(vec![1])).is_err());
    }

    #[test]
    fn pool_recycles_capacity() {
        let pool = BufferPool::new();
        let (d, hit) = pool.get(Transform::U32);
        assert!(!hit, "fresh pool cannot hit");
        let Draws::U32(mut v) = d else { panic!() };
        v.extend_from_slice(&[1, 2, 3, 4]);
        let cap = v.capacity();
        pool.put(Draws::U32(v));
        let (d, hit) = pool.get(Transform::U32);
        assert!(hit);
        let Draws::U32(v) = d else { panic!() };
        assert!(v.is_empty(), "recycled buffers come back cleared");
        assert_eq!(v.capacity(), cap, "recycled buffers keep their capacity");
        // Variants are pooled separately.
        let (_, hit) = pool.get(Transform::F32);
        assert!(!hit);
        // Normal and F32 share the f32 pool.
        pool.put(Draws::F32(vec![0.5]));
        let (_, hit) = pool.get(Transform::Normal);
        assert!(hit);
    }

    #[test]
    fn builder_typed_draws() {
        let coord = quick();
        let raw = coord.builder("h-raw").blocks(4).rounds_per_launch(2).u32().unwrap();
        let v = raw.draw(1000).unwrap();
        assert_eq!(v.len(), 1000);
        let uni = coord.builder("h-uni").blocks(2).uniform().unwrap();
        let mut buf = vec![0.0f32; 500];
        uni.draw_into(&mut buf).unwrap();
        assert!(buf.iter().all(|&x| (0.0..1.0).contains(&x)));
        let nrm = coord.builder("h-nrm").blocks(2).normal().unwrap();
        let z = nrm.draw(500).unwrap();
        assert!(z.iter().any(|&x| x < 0.0) && z.iter().any(|&x| x > 0.0));
        coord.shutdown();
    }

    #[test]
    fn builder_rejects_conflicting_reregistration() {
        let coord = quick();
        let _ = coord.builder("h-conflict").blocks(4).u32().unwrap();
        // Same name, same config: fine (re-attach).
        let again = coord.builder("h-conflict").blocks(4).u32();
        assert!(again.is_ok());
        // Same name, different transform: rejected.
        let err = coord.builder("h-conflict").blocks(4).uniform().unwrap_err();
        assert!(format!("{err:#}").contains("different config"), "{err:#}");
        coord.shutdown();
    }

    #[test]
    fn pipelined_tickets_preserve_stream_order() {
        let coord = quick();
        let s = coord.builder("h-pipe").blocks(2).rounds_per_launch(1).u32().unwrap();
        let tickets: Vec<Ticket<u32>> = (0..8).map(|_| s.submit(100).unwrap()).collect();
        let mut pipelined = Vec::new();
        for t in tickets {
            assert_eq!(t.n(), 100);
            pipelined.extend(t.wait().unwrap());
        }
        // Same stream, sequential draws: identical prefix.
        let coord2 = quick();
        let s2 = coord2.builder("h-pipe").blocks(2).rounds_per_launch(1).u32().unwrap();
        assert_eq!(pipelined, s2.draw(800).unwrap());
        coord.shutdown();
        coord2.shutdown();
    }

    #[test]
    fn try_take_polls_to_completion() {
        let coord = quick();
        let s = coord.builder("h-poll").blocks(2).u32().unwrap();
        let mut t = s.submit(10_000).unwrap();
        let mut polled = None;
        for _ in 0..10_000 {
            if let Some(r) = t.try_take() {
                polled = Some(r);
                break;
            }
            std::thread::yield_now();
        }
        let v = polled.expect("reply within poll budget").unwrap();
        assert_eq!(v.len(), 10_000);
        // The result was taken; the ticket is spent.
        assert!(t.try_take().is_none());
        coord.shutdown();
    }

    #[test]
    fn wait_into_checks_length() {
        let coord = quick();
        let s = coord.builder("h-len").blocks(2).u32().unwrap();
        let t = s.submit(64).unwrap();
        let mut wrong = vec![0u32; 32];
        assert!(t.wait_into(&mut wrong).is_err());
        coord.shutdown();
    }

    /// Regression: a dead connection (cluster serve path) can leave a
    /// malformed reply — wrong length, or a variant the handle never
    /// asked for — sitting in an abandoned ticket's channel. Dropping the
    /// ticket must NOT recycle such a reply into the shared pool, or the
    /// corruption propagates to whichever stream draws next.
    #[test]
    fn dropped_ticket_recycles_only_well_formed_replies() {
        use std::sync::mpsc::sync_channel;

        fn ticket_with_reply(pool: &Arc<BufferPool>, n: usize, reply: Draws) -> Ticket<u32> {
            let (tx, rx) = sync_channel(1);
            tx.send(Ok(reply)).unwrap();
            Ticket {
                rx: Some(rx),
                n,
                pool: Arc::clone(pool),
                trace: 0,
                start_us: 0,
                _elem: PhantomData,
            }
        }

        let pool = Arc::new(BufferPool::new());

        // Truncated reply (3 of 5 elements): dropped, not pooled.
        drop(ticket_with_reply(&pool, 5, Draws::U32(vec![1, 2, 3])));
        let (_, hit) = pool.get(Transform::U32);
        assert!(!hit, "short reply must not reach the pool");

        // Wrong variant (f32 reply on a u32 ticket): dropped, not pooled.
        drop(ticket_with_reply(&pool, 2, Draws::F32(vec![0.25, 0.75])));
        let (_, hit) = pool.get(Transform::U32);
        assert!(!hit, "mismatched variant must not reach the u32 pool");
        let (_, hit) = pool.get(Transform::F32);
        assert!(!hit, "mismatched variant must not reach the f32 pool either");

        // Well-formed reply: recycled (cleared, capacity kept).
        drop(ticket_with_reply(&pool, 4, Draws::U32(vec![7, 8, 9, 10])));
        let (d, hit) = pool.get(Transform::U32);
        assert!(hit, "well-formed reply must be recycled");
        assert_eq!(d.len(), 0, "recycled buffers come back cleared");
    }

    #[test]
    fn dropped_ticket_abandons_request() {
        let coord = quick();
        let s = coord.builder("h-drop").blocks(2).rounds_per_launch(1).u32().unwrap();
        drop(s.submit(1000).unwrap());
        // The stream position advanced by the abandoned draw; the service
        // stays healthy.
        let v = s.draw(100).unwrap();
        assert_eq!(v.len(), 100);
        coord.shutdown();
    }
}

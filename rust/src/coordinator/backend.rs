//! Stream backends: where the bits actually come from.
//!
//! Both backends serve the same canonical stream for the same seed (the
//! cross-layer bit-exactness tests in rust/tests/runtime_pjrt.rs pin this),
//! so the choice is operational: `Rust` needs no artifacts; `Pjrt` runs
//! the AOT JAX/Pallas artifacts and exercises the full three-layer stack
//! (requires the off-by-default `pjrt` cargo feature).
//!
//! **Buffer-ownership contract** (the bulk-fill engine, see README):
//! backends never hand out freshly allocated batches on the steady-state
//! path — [`Backend::launch_into`] *appends into a caller-owned
//! [`Draws`] buffer*, reusing its capacity. The coordinator owns one
//! persistent buffer per stream (the offset-cursor ring in
//! `service::StreamState`) and per-response buffers; generation flows
//! `generator fill_round → backend launch_into → ring/response` with no
//! intermediate copies and no per-launch allocation after warm-up.

use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;

use crate::coordinator::metrics::Metrics;
use crate::exec::pool::{FillPool, GenerateOutcome};
use crate::obs::registry::StreamCounters;
use crate::prng::distributions::Ziggurat;
use crate::prng::{make_block_generator, BlockParallel, GeneratorKind, Prng32};
use crate::runtime::{ArtifactMeta, PjrtRuntime, Transform};
use crate::util::error::{bail, Context, Result};

/// Backend selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Rust,
    Pjrt,
}

impl BackendKind {
    /// Shim over the [`FromStr`](std::str::FromStr) impl for callers that
    /// want an `Option` (the typed error is discarded).
    pub fn parse(s: &str) -> Option<BackendKind> {
        s.parse().ok()
    }
}

impl std::str::FromStr for BackendKind {
    type Err = crate::util::cli::ParseEnumError;

    fn from_str(s: &str) -> std::result::Result<BackendKind, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "rust" => Ok(BackendKind::Rust),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            _ => Err(crate::util::cli::ParseEnumError::new(
                "backend kind",
                s,
                "rust, pjrt (alias: xla)",
            )),
        }
    }
}

/// A batch of produced numbers.
///
/// Used both as an owned response and as the coordinator's persistent
/// per-stream buffer; the mutating methods reuse capacity, they never
/// shrink it.
#[derive(Clone, Debug, PartialEq)]
pub enum Draws {
    U32(Vec<u32>),
    F32(Vec<f32>),
}

impl Draws {
    pub fn len(&self) -> usize {
        match self {
            Draws::U32(v) => v.len(),
            Draws::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy `n` items starting at `pos` into a fresh batch.
    pub fn copy_range(&self, pos: usize, n: usize) -> Draws {
        match self {
            Draws::U32(v) => Draws::U32(v[pos..pos + n].to_vec()),
            Draws::F32(v) => Draws::F32(v[pos..pos + n].to_vec()),
        }
    }

    /// Append `src[pos..pos + n]` onto `self` — the ring-cursor serving
    /// path: one `extend_from_slice`, no temporary batch.
    pub fn extend_from_range(&mut self, src: &Draws, pos: usize, n: usize) {
        match (self, src) {
            (Draws::U32(d), Draws::U32(s)) => d.extend_from_slice(&s[pos..pos + n]),
            (Draws::F32(d), Draws::F32(s)) => d.extend_from_slice(&s[pos..pos + n]),
            _ => panic!("mixed draw types"),
        }
    }

    /// Drop all items, keeping the allocation (ring reset).
    pub fn clear(&mut self) {
        match self {
            Draws::U32(v) => v.clear(),
            Draws::F32(v) => v.clear(),
        }
    }

    /// Pre-size for `n` more items (response buffers reserve once).
    pub fn reserve(&mut self, n: usize) {
        match self {
            Draws::U32(v) => v.reserve(n),
            Draws::F32(v) => v.reserve(n),
        }
    }

    pub fn extend(&mut self, other: Draws) {
        match (self, other) {
            (Draws::U32(a), Draws::U32(b)) => a.extend(b),
            (Draws::F32(a), Draws::F32(b)) => a.extend(b),
            _ => panic!("mixed draw types"),
        }
    }

    pub fn empty_like(t: Transform) -> Draws {
        match t {
            Transform::U32 => Draws::U32(Vec::new()),
            _ => Draws::F32(Vec::new()),
        }
    }
}

/// One stream's production engine: produces launches of fixed size.
///
/// Deliberately NOT `Send`: the PJRT client wraps thread-bound FFI
/// handles. Backends are created and consumed inside a single coordinator
/// worker thread (`service::worker_loop`), which is also the natural
/// ownership model for a per-shard GPU context.
pub trait Backend {
    /// Outputs produced per launch.
    fn launch_size(&self) -> usize;

    /// The output type this backend produces.
    fn transform(&self) -> Transform;

    /// Append exactly [`launch_size`] outputs to the caller-owned buffer,
    /// reusing its capacity — the zero-copy serve path. `out` must be the
    /// matching [`Draws`] variant; on error it is left unchanged.
    ///
    /// [`launch_size`]: Backend::launch_size
    fn launch_into(&mut self, out: &mut Draws) -> Result<()>;

    /// Convenience: one launch as a fresh batch (tests, small tools —
    /// the coordinator serve loop uses `launch_into`).
    fn launch(&mut self) -> Result<Draws> {
        let mut out = Draws::empty_like(self.transform());
        self.launch_into(&mut out)?;
        Ok(out)
    }

    /// Human-readable description (for metrics/logs).
    fn describe(&self) -> String;
}

/// Pure-Rust backend: a block-parallel generator + optional transform.
///
/// With a [`FillPool`] attached ([`RustBackend::pooled`]) bulk fills run
/// on the persistent workers, and a nonzero prefetch depth turns on
/// **generation-ahead double buffering**: the backend owns two
/// launch-batch buffers; while launches are served from the `ready`
/// buffer (a pure memcpy), the pool fills the spare in the background
/// with the generator moved into the job. The served stream is
/// bit-identical to the serial interleaved stream — prefetched buffers
/// are the same whole-round fill computed early.
pub struct RustBackend {
    /// `None` only while a prefetch generate job holds the generator
    /// (U32/F32 with `prefetch_depth > 0`); always `Some` otherwise.
    gen: Option<Box<dyn BlockParallel + Send>>,
    transform: Transform,
    rounds_per_launch: usize,
    /// Process-wide shared ziggurat tables ([`Ziggurat::shared`]) — every
    /// `Normal` backend borrows the same ~6 KiB instance instead of
    /// rebuilding it per construction.
    zig: Option<&'static Ziggurat>,
    /// Persistent raw-word scratch: one launch of u32 draws for the `F32`
    /// transform, one round plus cursor for `Normal` (the ziggurat's
    /// variable consumption). Allocated on first use, reused forever —
    /// no per-launch allocation on the steady state.
    raw: Vec<u32>,
    raw_pos: usize,
    /// Worker count for the parallel fill engine ([`crate::exec`]); 1 =
    /// serial. Only the bulk `U32`/`F32` paths thread — the ziggurat's
    /// round-at-a-time source stays serial regardless.
    fill_threads: usize,
    /// Persistent worker pool; `Some` routes bulk fills through
    /// `fill_interleaved_pooled` (when `fill_threads > 1`) and carries
    /// the prefetch generate jobs.
    pool: Option<Arc<FillPool>>,
    /// Launches generated ahead per prefetch buffer; 0 = prefetch off.
    prefetch_depth: usize,
    /// Outstanding background generation (holds `gen` until it resolves).
    inflight: Option<Receiver<GenerateOutcome>>,
    /// Pre-generated raw words being drained, and the cursor into them.
    ready: Vec<u32>,
    ready_pos: usize,
    /// The other half of the double buffer, waiting to be submitted.
    spare: Option<Vec<u32>>,
    /// Prefetch hit/stall counters land here when attached.
    metrics: Option<Arc<Metrics>>,
    /// Per-stream labeled counters; every prefetch hit/stall increment
    /// pairs with the global one above, so the stream family sums
    /// exactly to the global snapshot.
    obs: Option<Arc<StreamCounters>>,
    // Geometry cached at construction so `launch_size`/`describe` answer
    // while the generator is away on a prefetch job.
    round_len: usize,
    blocks: usize,
    lane: usize,
    gen_name: &'static str,
}

impl RustBackend {
    pub fn new(
        kind: GeneratorKind,
        transform: Transform,
        seed: u64,
        blocks: usize,
        rounds_per_launch: usize,
    ) -> Self {
        Self::with_generator(make_block_generator(kind, seed, blocks), transform, rounds_per_launch)
    }

    /// Wrap an already-constructed generator — the placement-aware path:
    /// the coordinator builds exact-jump / leapfrog generators (placed
    /// states loaded, leapfrog wrapper applied) and hands them in here.
    pub fn with_generator(
        gen: Box<dyn BlockParallel + Send>,
        transform: Transform,
        rounds_per_launch: usize,
    ) -> Self {
        let (round_len, blocks, lane, gen_name) =
            (gen.round_len(), gen.blocks(), gen.lane_width(), BlockParallel::name(&gen));
        RustBackend {
            gen: Some(gen),
            transform,
            rounds_per_launch,
            zig: matches!(transform, Transform::Normal).then(Ziggurat::shared),
            raw: Vec::new(),
            raw_pos: 0,
            fill_threads: 1,
            pool: None,
            prefetch_depth: 0,
            inflight: None,
            ready: Vec::new(),
            ready_pos: 0,
            spare: None,
            metrics: None,
            obs: None,
            round_len,
            blocks,
            lane,
            gen_name,
        }
    }

    /// Set the worker count for bulk fills (builder style). The output is
    /// bit-identical for every value; fills below the engine's crossover
    /// threshold stay serial either way.
    pub fn fill_threads(mut self, n: usize) -> Self {
        self.fill_threads = n.max(1);
        self
    }

    /// Attach a persistent worker pool and set the prefetch depth
    /// (launches generated ahead per background job; 0 disables
    /// generation-ahead). The `Normal` transform never prefetches — the
    /// ziggurat consumes a data-dependent number of raw words, so there
    /// is no fixed launch batch to generate early (forced to 0 here).
    /// The served stream is bit-identical for every pool/depth setting.
    pub fn pooled(mut self, pool: Arc<FillPool>, prefetch: usize) -> Self {
        self.pool = Some(pool);
        self.prefetch_depth =
            if matches!(self.transform, Transform::Normal) { 0 } else { prefetch };
        self
    }

    /// Report prefetch hits/stalls to these metrics (builder style).
    pub fn metrics_sink(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Also mirror prefetch hits/stalls into this stream's labeled
    /// counter family (builder style).
    pub fn obs_sink(mut self, obs: Arc<StreamCounters>) -> Self {
        self.obs = Some(obs);
        self
    }

    fn count_prefetch(&self, hit: bool) {
        use std::sync::atomic::Ordering;
        if let Some(m) = &self.metrics {
            let counter = if hit { &m.prefetch_hits } else { &m.prefetch_stalls };
            counter.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(o) = &self.obs {
            let counter = if hit { &o.prefetch_hits } else { &o.prefetch_stalls };
            counter.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Produce exactly `out.len()` raw stream words (a whole number of
    /// launches) — inline through the pool/scoped engine, or from the
    /// prefetched `ready` buffer (memcpy) when generation-ahead is on.
    fn produce_words(&mut self, out: &mut [u32]) -> Result<()> {
        if self.prefetch_depth == 0 {
            let gen = self.gen.as_mut().expect("generator is resident when prefetch is off");
            match &self.pool {
                Some(pool) if self.fill_threads > 1 => gen.fill_interleaved_pooled(pool, out),
                _ => gen.fill_interleaved_threaded(self.fill_threads, out),
            }
            return Ok(());
        }
        let mut done = 0;
        while done < out.len() {
            if self.ready_pos == self.ready.len() {
                self.refill_ready()?;
            }
            let take = (out.len() - done).min(self.ready.len() - self.ready_pos);
            out[done..done + take]
                .copy_from_slice(&self.ready[self.ready_pos..self.ready_pos + take]);
            self.ready_pos += take;
            done += take;
        }
        Ok(())
    }

    /// Swap in the next prefetched buffer (waiting for the background job
    /// if it has not finished — a **stall**; a completed one is a **hit**)
    /// and immediately resubmit the generator with the drained buffer, so
    /// generation overlaps the entire drain of the new one.
    fn refill_ready(&mut self) -> Result<()> {
        let words = self.launch_size() * self.prefetch_depth;
        let pool = Arc::clone(self.pool.as_ref().expect("prefetch requires a pool"));
        if let Some(rx) = self.inflight.take() {
            let outcome = match rx.try_recv() {
                Ok(o) => {
                    self.count_prefetch(true);
                    o
                }
                Err(TryRecvError::Empty) => {
                    self.count_prefetch(false);
                    match rx.recv() {
                        Ok(o) => o,
                        Err(_) => bail!("fill pool shut down with a prefetch in flight"),
                    }
                }
                Err(TryRecvError::Disconnected) => {
                    bail!("fill pool shut down with a prefetch in flight")
                }
            };
            match outcome {
                GenerateOutcome::Filled { gen, buf } => {
                    debug_assert_eq!(buf.len(), words);
                    self.gen = Some(gen);
                    self.spare = Some(std::mem::replace(&mut self.ready, buf));
                    self.ready_pos = 0;
                }
                // Same contract as the scoped engine: a generator panic
                // resumes on the thread consuming the fill.
                GenerateOutcome::Panicked(p) => std::panic::resume_unwind(p),
            }
        } else {
            // Cold start: nothing generated ahead yet, so fill inline
            // (the client waited — count it as a stall).
            self.count_prefetch(false);
            let mut buf = self.spare.take().unwrap_or_default();
            buf.resize(words, 0);
            {
                let gen = self.gen.as_mut().expect("generator is resident at cold start");
                if self.fill_threads > 1 {
                    gen.fill_interleaved_pooled(&pool, &mut buf);
                } else {
                    gen.fill_interleaved(&mut buf);
                }
            }
            self.spare = Some(std::mem::replace(&mut self.ready, buf));
            self.ready_pos = 0;
        }
        // Generate ahead: move the generator + drained buffer into a
        // background job NOW, so it fills while the caller drains `ready`.
        let gen = self.gen.take().expect("generator restored above");
        let mut next = self.spare.take().unwrap_or_default();
        next.resize(words, 0);
        self.inflight = Some(pool.submit_generate(gen, next));
        Ok(())
    }
}

impl Backend for RustBackend {
    fn launch_size(&self) -> usize {
        self.round_len * self.rounds_per_launch
    }

    fn transform(&self) -> Transform {
        self.transform
    }

    fn launch_into(&mut self, out: &mut Draws) -> Result<()> {
        let n = self.launch_size();
        match (self.transform, out) {
            (Transform::U32, Draws::U32(v)) => {
                // Fast path: generate straight into the buffer tail. The
                // extension is left uninitialised (no memset pass —
                // measured ~20% of the serve cost): sound because the fill
                // writes every word of the slice (n is an exact multiple
                // of round_len, so serial fills are a pure sequence of
                // fill_round calls and the threaded path covers whole
                // rounds with no tail — nothing buffered, nothing
                // discarded) before anything reads it; u32 has no drop
                // glue.
                let start = v.len();
                v.reserve(n);
                unsafe { v.set_len(start + n) };
                if let Err(e) = self.produce_words(&mut v[start..]) {
                    v.truncate(start); // uphold "unchanged on error"
                    return Err(e);
                }
            }
            (Transform::F32, Draws::F32(v)) => {
                // Raw words land in the persistent scratch, the canonical
                // unit_f32 scaling streams into the caller's buffer.
                let mut raw = std::mem::take(&mut self.raw);
                raw.resize(n, 0);
                let filled = self.produce_words(&mut raw);
                self.raw = raw;
                filled?;
                let start = v.len();
                v.resize(start + n, 0.0);
                crate::prng::distributions::unit_f32_slice(&self.raw, &mut v[start..]);
            }
            (Transform::Normal, Draws::F32(v)) => {
                // Ziggurat over a round-refilled source; consumes a
                // variable number of raw draws (wedge/tail rejections).
                // Leftover raw words persist in the scratch across
                // launches — the stream position stays well-defined ("the
                // next raw outputs") with nothing discarded.
                let zig = self.zig.unwrap();
                let gen = self
                    .gen
                    .as_mut()
                    .expect("normal transform never prefetches, generator is resident");
                let mut src = RoundSource {
                    gen: gen.as_mut(),
                    buf: &mut self.raw,
                    pos: &mut self.raw_pos,
                };
                v.reserve(n);
                for _ in 0..n {
                    v.push(zig.sample(&mut src) as f32);
                }
            }
            _ => bail!("draw buffer does not match backend transform"),
        }
        Ok(())
    }

    fn describe(&self) -> String {
        format!(
            "rust:{}[B={},lane={}]/{}",
            self.gen_name,
            self.blocks,
            self.lane,
            self.transform.name()
        )
    }
}

/// Adapter: a raw-word source that drains the persistent scratch and
/// refills it one round at a time (cursor only — no allocation after the
/// first refill).
struct RoundSource<'a> {
    gen: &'a mut (dyn BlockParallel + Send),
    buf: &'a mut Vec<u32>,
    pos: &'a mut usize,
}

impl Prng32 for RoundSource<'_> {
    fn next_u32(&mut self) -> u32 {
        if *self.pos >= self.buf.len() {
            let round = self.gen.round_len();
            self.buf.resize(round, 0);
            self.gen.fill_round(self.buf);
            *self.pos = 0;
        }
        let v = self.buf[*self.pos];
        *self.pos += 1;
        v
    }

    fn name(&self) -> &'static str {
        "round-source"
    }

    fn state_words(&self) -> usize {
        0
    }

    fn period_log2(&self) -> f64 {
        0.0
    }
}

/// PJRT backend: drives an AOT artifact, carrying the canonical state.
/// Without the `pjrt` cargo feature every launch returns a clear error
/// (see `runtime::client`).
pub struct PjrtBackend {
    runtime: PjrtRuntime,
    meta: ArtifactMeta,
    state: Vec<u32>,
}

impl PjrtBackend {
    /// Build from an artifact name; the initial state comes from the
    /// equivalent Rust generator (same seed → same stream as RustBackend).
    pub fn new(artifact_dir: &std::path::Path, artifact: &str, seed: u64) -> Result<Self> {
        let runtime = PjrtRuntime::new(artifact_dir)?;
        let meta = runtime
            .manifest
            .find(artifact)
            .with_context(|| format!("artifact {artifact:?} not in manifest"))?
            .clone();
        let gen = make_block_generator(meta.kind, seed, meta.blocks);
        let state = gen.dump_state();
        Ok(PjrtBackend { runtime, meta, state })
    }

    /// Pick the best artifact for a kind+transform.
    pub fn best(
        artifact_dir: &std::path::Path,
        kind: GeneratorKind,
        transform: Transform,
        seed: u64,
    ) -> Result<Self> {
        let runtime = PjrtRuntime::new(artifact_dir)?;
        let meta = match runtime.manifest.best_for(kind, transform) {
            Some(m) => m.clone(),
            None => bail!("no artifact for {kind}/{}", transform.name()),
        };
        let gen = make_block_generator(meta.kind, seed, meta.blocks);
        let state = gen.dump_state();
        Ok(PjrtBackend { runtime, meta, state })
    }
}

impl Backend for PjrtBackend {
    fn launch_size(&self) -> usize {
        self.meta.outputs
    }

    fn transform(&self) -> Transform {
        self.meta.transform
    }

    fn launch_into(&mut self, out: &mut Draws) -> Result<()> {
        // Validate the buffer variant BEFORE launching: a launch advances
        // the carried state, so erroring afterwards would silently skip
        // one launch of the stream.
        match (&*out, self.meta.transform) {
            (Draws::U32(_), Transform::U32) => {}
            (Draws::F32(_), Transform::F32 | Transform::Normal) => {}
            _ => bail!("artifact output does not match draw buffer type"),
        }
        let (new_state, launched) = self.runtime.launch(&self.meta.name, &self.state)?;
        self.state = new_state;
        match (out, launched) {
            (Draws::U32(v), crate::runtime::LaunchOutput::U32(w)) => v.extend(w),
            (Draws::F32(v), crate::runtime::LaunchOutput::F32(w)) => v.extend(w),
            _ => bail!("artifact output does not match its declared transform"),
        }
        Ok(())
    }

    fn describe(&self) -> String {
        format!("pjrt:{}", self.meta.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_backend_u32_launches() {
        let mut b = RustBackend::new(GeneratorKind::XorgensGp, Transform::U32, 1, 4, 2);
        assert_eq!(b.launch_size(), 4 * 63 * 2);
        let d = b.launch().unwrap();
        assert_eq!(d.len(), b.launch_size());
        // Consecutive launches continue the stream (no repeats).
        let d2 = b.launch().unwrap();
        assert_ne!(d, d2);
    }

    #[test]
    fn launch_into_appends_and_reuses_capacity() {
        let mut b = RustBackend::new(GeneratorKind::XorgensGp, Transform::U32, 1, 2, 1);
        let mut acc = Draws::U32(Vec::new());
        acc.reserve(3 * b.launch_size());
        let cap_before = match &acc {
            Draws::U32(v) => v.capacity(),
            _ => unreachable!(),
        };
        for i in 1..=3 {
            b.launch_into(&mut acc).unwrap();
            assert_eq!(acc.len(), i * b.launch_size());
        }
        let cap_after = match &acc {
            Draws::U32(v) => v.capacity(),
            _ => unreachable!(),
        };
        assert_eq!(cap_before, cap_after, "no realloc within reserved capacity");
    }

    #[test]
    fn launch_into_matches_scalar_stream() {
        // The backend's bulk launches are the interleaved stream, bit-exact
        // with scalar draws from the same seed.
        use crate::prng::traits::InterleavedStream;
        use crate::prng::XorgensGp;
        let mut b = RustBackend::new(GeneratorKind::XorgensGp, Transform::U32, 5, 2, 3);
        let mut acc = Draws::U32(Vec::new());
        b.launch_into(&mut acc).unwrap();
        b.launch_into(&mut acc).unwrap();
        let Draws::U32(got) = acc else { panic!() };
        let mut scalar = InterleavedStream::new(XorgensGp::new(5, 2));
        let expect: Vec<u32> = (0..got.len()).map(|_| scalar.next_u32()).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn f32_transform_in_unit_interval() {
        let mut b = RustBackend::new(GeneratorKind::Xorwow, Transform::F32, 2, 8, 4);
        if let Draws::F32(v) = b.launch().unwrap() {
            assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
        } else {
            panic!("expected f32");
        }
    }

    #[test]
    fn normal_transform_moments() {
        let mut b = RustBackend::new(GeneratorKind::XorgensGp, Transform::Normal, 3, 8, 8);
        let mut all = Vec::new();
        for _ in 0..20 {
            if let Draws::F32(v) = b.launch().unwrap() {
                all.extend(v);
            }
        }
        let n = all.len() as f64;
        let mean = all.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = all.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn normal_backends_share_one_ziggurat() {
        // Every `Normal` backend borrows the same process-wide table
        // instance (no per-backend ~6 KiB rebuild), and sharing is
        // invisible in the output: same seed, same stream.
        let mut a = RustBackend::new(GeneratorKind::XorgensGp, Transform::Normal, 9, 4, 2);
        let mut b = RustBackend::new(GeneratorKind::XorgensGp, Transform::Normal, 9, 4, 2);
        assert!(
            std::ptr::eq(a.zig.unwrap(), b.zig.unwrap()),
            "Normal backends must share the process-wide ziggurat tables"
        );
        let (da, db) = (a.launch().unwrap(), b.launch().unwrap());
        let (Draws::F32(va), Draws::F32(vb)) = (da, db) else { panic!("expected f32") };
        assert_eq!(
            va.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            vb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fill_threads_is_bit_identical() {
        // One launch of 64 blocks × 16 rounds = 64512 words — above the
        // parallel-fill crossover, so the threaded backend actually
        // threads, and the stream must not change.
        let mut serial = RustBackend::new(GeneratorKind::XorgensGp, Transform::U32, 7, 64, 16);
        let mut threaded =
            RustBackend::new(GeneratorKind::XorgensGp, Transform::U32, 7, 64, 16).fill_threads(4);
        assert!(serial.launch_size() >= crate::exec::PAR_FILL_MIN_WORDS);
        for _ in 0..2 {
            assert_eq!(serial.launch().unwrap(), threaded.launch().unwrap());
        }
    }

    fn test_pool(workers: usize) -> Arc<FillPool> {
        Arc::new(FillPool::new(crate::exec::pool::PoolConfig { workers, pin_cores: false }))
    }

    /// Prefetched launches ARE the serial stream, computed early: for
    /// depth {1, 2} × fill_threads {1, 4}, every launch equals the plain
    /// backend's, across enough launches to cycle the double buffer
    /// several times.
    #[test]
    fn prefetch_is_bit_identical_u32() {
        for depth in [1usize, 2] {
            for threads in [1usize, 4] {
                let pool = test_pool(threads.saturating_sub(1).max(1));
                let mut plain = RustBackend::new(GeneratorKind::XorgensGp, Transform::U32, 7, 8, 4);
                let mut pre = RustBackend::new(GeneratorKind::XorgensGp, Transform::U32, 7, 8, 4)
                    .fill_threads(threads)
                    .pooled(Arc::clone(&pool), depth);
                for i in 0..7 {
                    assert_eq!(
                        plain.launch().unwrap(),
                        pre.launch().unwrap(),
                        "depth={depth} threads={threads} launch={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn prefetch_is_bit_identical_f32() {
        let pool = test_pool(2);
        let mut plain = RustBackend::new(GeneratorKind::Mtgp, Transform::F32, 3, 4, 2);
        let mut pre = RustBackend::new(GeneratorKind::Mtgp, Transform::F32, 3, 4, 2)
            .fill_threads(3)
            .pooled(pool, 2);
        for i in 0..5 {
            assert_eq!(plain.launch().unwrap(), pre.launch().unwrap(), "launch {i}");
        }
    }

    /// The Normal transform silently disables prefetch (data-dependent
    /// raw consumption) but still serves the identical stream.
    #[test]
    fn normal_transform_ignores_prefetch() {
        let pool = test_pool(2);
        let mut plain = RustBackend::new(GeneratorKind::XorgensGp, Transform::Normal, 3, 4, 4);
        let mut pre = RustBackend::new(GeneratorKind::XorgensGp, Transform::Normal, 3, 4, 4)
            .pooled(pool, 2);
        for _ in 0..3 {
            assert_eq!(plain.launch().unwrap(), pre.launch().unwrap());
        }
    }

    /// Hit/stall accounting: the first refill is a cold-start stall;
    /// once the pipeline is primed and drained slowly, refills are hits.
    #[test]
    fn prefetch_metrics_count_hits_and_stalls() {
        let pool = test_pool(1);
        let metrics = Arc::new(Metrics::default());
        let mut b = RustBackend::new(GeneratorKind::XorgensGp, Transform::U32, 1, 4, 2)
            .pooled(pool, 1)
            .metrics_sink(Arc::clone(&metrics));
        b.launch().unwrap(); // cold start: 1 stall
        let snap = metrics.snapshot();
        assert_eq!(snap.prefetch_stalls, 1);
        // Give the tiny background job ample time, then draw through the
        // ready buffer into the next refill: a hit.
        std::thread::sleep(std::time::Duration::from_millis(200));
        b.launch().unwrap(); // drains the rest of the cold buffer? depth=1 -> refill
        let snap = metrics.snapshot();
        assert!(
            snap.prefetch_hits >= 1,
            "expected a prefetch hit after sleeping: {snap:?}"
        );
    }

    #[test]
    fn mismatched_buffer_type_is_error() {
        let mut b = RustBackend::new(GeneratorKind::XorgensGp, Transform::U32, 1, 2, 1);
        let mut wrong = Draws::F32(Vec::new());
        assert!(b.launch_into(&mut wrong).is_err());
        assert!(wrong.is_empty(), "buffer untouched on error");
    }

    #[test]
    fn draws_ring_primitives() {
        let mut d = Draws::U32(vec![1, 2, 3, 4, 5]);
        assert_eq!(d.copy_range(1, 3), Draws::U32(vec![2, 3, 4]));
        let mut resp = Draws::U32(vec![9]);
        resp.extend_from_range(&d, 2, 2);
        assert_eq!(resp, Draws::U32(vec![9, 3, 4]));
        let cap = match &d {
            Draws::U32(v) => v.capacity(),
            _ => unreachable!(),
        };
        d.clear();
        assert!(d.is_empty());
        match &d {
            Draws::U32(v) => assert_eq!(v.capacity(), cap, "clear keeps the allocation"),
            _ => unreachable!(),
        }
        let mut acc = Draws::empty_like(Transform::U32);
        acc.extend(Draws::U32(vec![1, 2]));
        acc.extend(Draws::U32(vec![3]));
        assert_eq!(acc, Draws::U32(vec![1, 2, 3]));
    }
}

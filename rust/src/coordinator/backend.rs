//! Stream backends: where the bits actually come from.
//!
//! Both backends serve the same canonical stream for the same seed (the
//! cross-layer bit-exactness tests in rust/tests/runtime_pjrt.rs pin this),
//! so the choice is operational: `Rust` needs no artifacts; `Pjrt` runs
//! the AOT JAX/Pallas artifacts and exercises the full three-layer stack.

use crate::prng::distributions::Ziggurat;
use crate::prng::{make_block_generator, BlockParallel, GeneratorKind};
use crate::runtime::{ArtifactMeta, PjrtRuntime, Transform};
use anyhow::{bail, Context, Result};

/// Backend selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Rust,
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "rust" => Some(BackendKind::Rust),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }
}

/// A batch of produced numbers.
#[derive(Clone, Debug, PartialEq)]
pub enum Draws {
    U32(Vec<u32>),
    F32(Vec<f32>),
}

impl Draws {
    pub fn len(&self) -> usize {
        match self {
            Draws::U32(v) => v.len(),
            Draws::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn split_off(&mut self, n: usize) -> Draws {
        match self {
            Draws::U32(v) => Draws::U32(v.drain(..n).collect()),
            Draws::F32(v) => Draws::F32(v.drain(..n).collect()),
        }
    }

    /// Copy `n` items starting at `pos` (offset-buffer serving path).
    pub fn copy_range(&self, pos: usize, n: usize) -> Draws {
        match self {
            Draws::U32(v) => Draws::U32(v[pos..pos + n].to_vec()),
            Draws::F32(v) => Draws::F32(v[pos..pos + n].to_vec()),
        }
    }

    /// Drop the first `n` items (buffer compaction).
    pub fn discard_front(&mut self, n: usize) {
        match self {
            Draws::U32(v) => {
                v.copy_within(n.., 0);
                v.truncate(v.len() - n);
            }
            Draws::F32(v) => {
                v.copy_within(n.., 0);
                v.truncate(v.len() - n);
            }
        }
    }

    pub fn extend(&mut self, other: Draws) {
        match (self, other) {
            (Draws::U32(a), Draws::U32(b)) => a.extend(b),
            (Draws::F32(a), Draws::F32(b)) => a.extend(b),
            _ => panic!("mixed draw types"),
        }
    }

    pub fn empty_like(t: Transform) -> Draws {
        match t {
            Transform::U32 => Draws::U32(Vec::new()),
            _ => Draws::F32(Vec::new()),
        }
    }
}

/// One stream's production engine: produces launches of fixed size.
///
/// Deliberately NOT `Send`: the PJRT client wraps thread-bound FFI
/// handles. Backends are created and consumed inside a single coordinator
/// worker thread (`service::worker_loop`), which is also the natural
/// ownership model for a per-shard GPU context.
pub trait Backend {
    /// Outputs produced per launch.
    fn launch_size(&self) -> usize;
    /// Produce one launch worth of numbers.
    fn launch(&mut self) -> Result<Draws>;
    /// Append one launch directly onto `out` (EXPERIMENTS.md §Perf L3-5:
    /// lets the service build large responses with a single generation
    /// pass). Default: launch + extend.
    fn launch_append(&mut self, out: &mut Draws) -> Result<()> {
        let d = self.launch()?;
        if out.is_empty() {
            *out = d;
        } else {
            out.extend(d);
        }
        Ok(())
    }
    /// Human-readable description (for metrics/logs).
    fn describe(&self) -> String;
}

/// Pure-Rust backend: a block-parallel generator + optional transform.
pub struct RustBackend {
    gen: Box<dyn BlockParallel + Send>,
    transform: Transform,
    rounds_per_launch: usize,
    zig: Option<Ziggurat>,
}

impl RustBackend {
    pub fn new(
        kind: GeneratorKind,
        transform: Transform,
        seed: u64,
        blocks: usize,
        rounds_per_launch: usize,
    ) -> Self {
        RustBackend {
            gen: make_block_generator(kind, seed, blocks),
            transform,
            rounds_per_launch,
            zig: matches!(transform, Transform::Normal).then(Ziggurat::new),
        }
    }
}

impl Backend for RustBackend {
    fn launch_size(&self) -> usize {
        let per_round = self.gen.blocks() * self.gen.lane_width();
        let raw = per_round * self.rounds_per_launch;
        match self.transform {
            Transform::Normal => raw, // ziggurat consumes a variable amount; see launch()
            _ => raw,
        }
    }

    fn launch(&mut self) -> Result<Draws> {
        let mut raw = Vec::with_capacity(self.launch_size());
        for _ in 0..self.rounds_per_launch {
            self.gen.next_round(&mut raw);
        }
        Ok(match self.transform {
            Transform::U32 => Draws::U32(raw),
            Transform::F32 => {
                Draws::F32(raw.iter().map(|&u| (u >> 8) as f32 * (1.0 / 16_777_216.0)).collect())
            }
            Transform::Normal => {
                // Ziggurat over an adapter stream; may consume extra draws
                // from the generator for wedge/tail cases — stream position
                // remains well-defined (it is just "the next raw outputs").
                let zig = self.zig.as_ref().unwrap();
                let n = raw.len();
                let mut src = BufferedStream { buf: raw, pos: 0, gen: self.gen.as_mut() };
                let out: Vec<f32> = (0..n).map(|_| zig.sample(&mut src) as f32).collect();
                Draws::F32(out)
            }
        })
    }

    fn launch_append(&mut self, out: &mut Draws) -> Result<()> {
        if let (Transform::U32, Draws::U32(v)) = (self.transform, &mut *out) {
            // Fast path: generate straight into the response tail. The
            // extension is left uninitialised (no memset pass — measured
            // ~20% of the serve cost): sound because fill_interleaved
            // writes every word of the slice before set_len exposes it.
            let start = v.len();
            let total = start + self.launch_size();
            v.reserve(total - start);
            // SAFETY: capacity reserved above; every element in
            // start..total is written by fill_interleaved below before any
            // read; u32 has no drop glue.
            unsafe { v.set_len(total) };
            let mut slice = &mut v[start..];
            for _ in 0..self.rounds_per_launch {
                let per_round = self.gen.blocks() * self.gen.lane_width();
                let (head, rest) = slice.split_at_mut(per_round);
                self.gen.fill_interleaved(head);
                slice = rest;
            }
            return Ok(());
        }
        let d = self.launch()?;
        if out.is_empty() {
            *out = d;
        } else {
            out.extend(d);
        }
        Ok(())
    }

    fn describe(&self) -> String {
        format!(
            "rust:{}[B={},lane={}]/{}",
            self.gen.name(),
            self.gen.blocks(),
            self.gen.lane_width(),
            self.transform.name()
        )
    }
}

/// Adapter: drain a prefilled buffer, then fall back to the generator.
struct BufferedStream<'a> {
    buf: Vec<u32>,
    pos: usize,
    gen: &'a mut (dyn BlockParallel + Send),
}

impl crate::prng::Prng32 for BufferedStream<'_> {
    fn next_u32(&mut self) -> u32 {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.gen.next_round(&mut self.buf);
            self.pos = 0;
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }

    fn name(&self) -> &'static str {
        "buffered"
    }

    fn state_words(&self) -> usize {
        0
    }

    fn period_log2(&self) -> f64 {
        0.0
    }
}

/// PJRT backend: drives an AOT artifact, carrying the canonical state.
pub struct PjrtBackend {
    runtime: PjrtRuntime,
    meta: ArtifactMeta,
    state: Vec<u32>,
}

impl PjrtBackend {
    /// Build from an artifact name; the initial state comes from the
    /// equivalent Rust generator (same seed → same stream as RustBackend).
    pub fn new(artifact_dir: &std::path::Path, artifact: &str, seed: u64) -> Result<Self> {
        let runtime = PjrtRuntime::new(artifact_dir)?;
        let meta = runtime
            .manifest
            .find(artifact)
            .with_context(|| format!("artifact {artifact:?} not in manifest"))?
            .clone();
        let gen = make_block_generator(meta.kind, seed, meta.blocks);
        let state = gen.dump_state();
        Ok(PjrtBackend { runtime, meta, state })
    }

    /// Pick the best artifact for a kind+transform.
    pub fn best(
        artifact_dir: &std::path::Path,
        kind: GeneratorKind,
        transform: Transform,
        seed: u64,
    ) -> Result<Self> {
        let runtime = PjrtRuntime::new(artifact_dir)?;
        let meta = match runtime.manifest.best_for(kind, transform) {
            Some(m) => m.clone(),
            None => bail!("no artifact for {kind}/{}", transform.name()),
        };
        let gen = make_block_generator(meta.kind, seed, meta.blocks);
        let state = gen.dump_state();
        Ok(PjrtBackend { runtime, meta, state })
    }
}

impl Backend for PjrtBackend {
    fn launch_size(&self) -> usize {
        self.meta.outputs
    }

    fn launch(&mut self) -> Result<Draws> {
        let (new_state, out) = self.runtime.launch(&self.meta.name, &self.state)?;
        self.state = new_state;
        Ok(match out {
            crate::runtime::LaunchOutput::U32(v) => Draws::U32(v),
            crate::runtime::LaunchOutput::F32(v) => Draws::F32(v),
        })
    }

    fn describe(&self) -> String {
        format!("pjrt:{}", self.meta.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_backend_u32_launches() {
        let mut b = RustBackend::new(GeneratorKind::XorgensGp, Transform::U32, 1, 4, 2);
        assert_eq!(b.launch_size(), 4 * 63 * 2);
        let d = b.launch().unwrap();
        assert_eq!(d.len(), b.launch_size());
        // Consecutive launches continue the stream (no repeats).
        let d2 = b.launch().unwrap();
        assert_ne!(d, d2);
    }

    #[test]
    fn f32_transform_in_unit_interval() {
        let mut b = RustBackend::new(GeneratorKind::Xorwow, Transform::F32, 2, 8, 4);
        if let Draws::F32(v) = b.launch().unwrap() {
            assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
        } else {
            panic!("expected f32");
        }
    }

    #[test]
    fn normal_transform_moments() {
        let mut b = RustBackend::new(GeneratorKind::XorgensGp, Transform::Normal, 3, 8, 8);
        let mut all = Vec::new();
        for _ in 0..20 {
            if let Draws::F32(v) = b.launch().unwrap() {
                all.extend(v);
            }
        }
        let n = all.len() as f64;
        let mean = all.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = all.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn draws_split_and_extend() {
        let mut d = Draws::U32(vec![1, 2, 3, 4, 5]);
        let head = d.split_off(2);
        assert_eq!(head, Draws::U32(vec![1, 2]));
        assert_eq!(d.len(), 3);
        let mut acc = Draws::empty_like(Transform::U32);
        acc.extend(head);
        acc.extend(d);
        assert_eq!(acc, Draws::U32(vec![1, 2, 3, 4, 5]));
    }
}

//! Service metrics: counters + latency histogram (log2 buckets), all
//! lock-free on the hot path (atomics).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Global service counters.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub numbers_served: AtomicU64,
    pub launches: AtomicU64,
    pub rejected: AtomicU64,
    /// Reply buffers served from the recycle pool (steady-state path).
    pub pool_hits: AtomicU64,
    /// Reply buffers freshly allocated (pool empty — warm-up or burst).
    pub pool_misses: AtomicU64,
    /// Idempotent operations re-sent after a transient failure (cluster
    /// router path; always zero on a local coordinator).
    pub retries: AtomicU64,
    /// Streams re-registered on a surviving shard after their home shard
    /// died (cluster router path; always zero on a local coordinator).
    pub failovers: AtomicU64,
    /// Launch batches served from a completed generation-ahead job (the
    /// steady-state prefetch path: draw latency is a memcpy).
    pub prefetch_hits: AtomicU64,
    /// Launch batches that had to wait for generation — cold starts, or
    /// the client draining faster than the pool refills.
    pub prefetch_stalls: AtomicU64,
    /// Fill-pool queue depth gauge, maintained **live** by the pool's
    /// enqueue/dequeue sites (the `Arc` is handed to
    /// `FillPool::set_depth_gauge` at coordinator construction), so a
    /// scrape mid-load sees the real backlog, not a snapshot-time probe.
    pub pool_queue_depth: Arc<AtomicU64>,
    /// log2-bucketed request latency histogram, buckets of 2^i microseconds.
    lat_buckets: [AtomicU64; 24],
    lat_total_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(23);
        self.lat_buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.lat_total_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let buckets: Vec<u64> =
            self.lat_buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count: u64 = buckets.iter().sum();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            numbers_served: self.numbers_served.load(Ordering::Relaxed),
            launches: self.launches.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            pool_hits: self.pool_hits.load(Ordering::Relaxed),
            pool_misses: self.pool_misses.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            prefetch_stalls: self.prefetch_stalls.load(Ordering::Relaxed),
            pool_queue_depth: self.pool_queue_depth.load(Ordering::Relaxed),
            mean_latency_us: if count == 0 {
                0.0
            } else {
                self.lat_total_us.load(Ordering::Relaxed) as f64 / count as f64
            },
            p99_latency_us: percentile_from_buckets(&buckets, 0.99),
            lat_buckets: buckets,
        }
    }
}

/// Percentile estimate from a log2-bucketed histogram: the **upper
/// bound** `2^(i+1)` µs of the bucket containing the `q`-quantile
/// sample, so the reported value is a guaranteed `p ≤ bound`, never an
/// up-to-2× underestimate (the lower bound would claim a latency no
/// observed sample is guaranteed to meet). Empty histograms report 0.
fn percentile_from_buckets(buckets: &[u64], q: f64) -> f64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = (total as f64 * q).ceil() as u64;
    let mut acc = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        acc += c;
        if acc >= target {
            return 2f64.powi(i as i32 + 1); // bucket upper bound in µs
        }
    }
    2f64.powi(buckets.len() as i32)
}

/// A point-in-time copy of the metrics.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub numbers_served: u64,
    pub launches: u64,
    pub rejected: u64,
    pub pool_hits: u64,
    pub pool_misses: u64,
    pub retries: u64,
    pub failovers: u64,
    pub prefetch_hits: u64,
    pub prefetch_stalls: u64,
    pub pool_queue_depth: u64,
    pub mean_latency_us: f64,
    pub p99_latency_us: f64,
    pub lat_buckets: Vec<u64>,
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests={} numbers={} launches={} rejected={} pool_hits={} pool_misses={} \
             retries={} failovers={} prefetch_hits={} prefetch_stalls={} pool_queue_depth={} \
             mean_lat={:.1}us p99_lat<={:.0}us",
            self.requests,
            self.numbers_served,
            self.launches,
            self.rejected,
            self.pool_hits,
            self.pool_misses,
            self.retries,
            self.failovers,
            self.prefetch_hits,
            self.prefetch_stalls,
            self.pool_queue_depth,
            self.mean_latency_us,
            self.p99_latency_us
        )
    }

    /// Serialize for scraping (the `stats` wire verb and `--stats-json`
    /// CLI flags). Latency buckets are emitted in full so a scraper can
    /// reconstruct any percentile, not just the two pre-computed ones.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        o.push("requests", Json::Int(self.requests as i64))
            .push("numbers_served", Json::Int(self.numbers_served as i64))
            .push("launches", Json::Int(self.launches as i64))
            .push("rejected", Json::Int(self.rejected as i64))
            .push("pool_hits", Json::Int(self.pool_hits as i64))
            .push("pool_misses", Json::Int(self.pool_misses as i64))
            .push("retries", Json::Int(self.retries as i64))
            .push("failovers", Json::Int(self.failovers as i64))
            .push("prefetch_hits", Json::Int(self.prefetch_hits as i64))
            .push("prefetch_stalls", Json::Int(self.prefetch_stalls as i64))
            .push("pool_queue_depth", Json::Int(self.pool_queue_depth as i64))
            .push("mean_latency_us", Json::Num(self.mean_latency_us))
            .push("p99_latency_us", Json::Num(self.p99_latency_us))
            .push(
                "lat_buckets_log2_us",
                Json::Arr(self.lat_buckets.iter().map(|&c| Json::Int(c as i64)).collect()),
            );
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_buckets() {
        let m = Metrics::new();
        m.record_latency(Duration::from_micros(3)); // bucket 1 (2-4us)
        m.record_latency(Duration::from_micros(1000)); // bucket 9 (512-1024)
        m.record_latency(Duration::from_micros(1500)); // bucket 10
        let s = m.snapshot();
        assert_eq!(s.lat_buckets.iter().sum::<u64>(), 3);
        assert!(s.mean_latency_us > 500.0);
        assert!(s.p99_latency_us >= 1024.0);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.numbers_served.fetch_add(1000, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.requests, 5);
        assert_eq!(s.numbers_served, 1000);
        assert!(s.render().contains("requests=5"));
    }

    #[test]
    fn snapshot_serializes_to_json() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.retries.fetch_add(2, Ordering::Relaxed);
        m.failovers.fetch_add(1, Ordering::Relaxed);
        m.record_latency(Duration::from_micros(100));
        let j = m.snapshot().to_json().to_string();
        assert!(j.contains(r#""requests":3"#), "{j}");
        assert!(j.contains(r#""retries":2"#), "{j}");
        assert!(j.contains(r#""failovers":1"#), "{j}");
        assert!(j.contains(r#""lat_buckets_log2_us":[0,"#), "{j}");
        m.prefetch_hits.fetch_add(4, Ordering::Relaxed);
        m.prefetch_stalls.fetch_add(1, Ordering::Relaxed);
        m.pool_queue_depth.store(2, Ordering::Relaxed);
        let j = m.snapshot().to_json().to_string();
        assert!(j.contains(r#""prefetch_hits":4"#), "{j}");
        assert!(j.contains(r#""prefetch_stalls":1"#), "{j}");
        assert!(j.contains(r#""pool_queue_depth":2"#), "{j}");
        // One sample in bucket 6 (64-128us): the bucket array sums to 1.
        let buckets = j.split(r#""lat_buckets_log2_us":["#).nth(1).unwrap();
        let buckets = buckets.split(']').next().unwrap();
        let sum: u64 = buckets.split(',').map(|x| x.parse::<u64>().unwrap()).sum();
        assert_eq!(sum, 1);
    }

    #[test]
    fn percentile_empty_histogram_is_zero() {
        let buckets = [0u64; 24];
        assert_eq!(percentile_from_buckets(&buckets, 0.99), 0.0);
        assert_eq!(percentile_from_buckets(&buckets, 0.5), 0.0);
    }

    #[test]
    fn percentile_one_sample_reports_bucket_upper_bound() {
        // One sample in bucket 3 (8..16 µs): every quantile must report
        // the bucket's upper bound 16, not the lower bound 8.
        let mut buckets = [0u64; 24];
        buckets[3] = 1;
        assert_eq!(percentile_from_buckets(&buckets, 0.99), 16.0);
        assert_eq!(percentile_from_buckets(&buckets, 0.01), 16.0);
    }

    #[test]
    fn percentile_all_in_last_bucket() {
        // Everything in the final bucket (2^23..2^24 µs): the estimate is
        // the histogram's ceiling 2^24, for any quantile.
        let mut buckets = [0u64; 24];
        buckets[23] = 1000;
        assert_eq!(percentile_from_buckets(&buckets, 0.99), 2f64.powi(24));
        assert_eq!(percentile_from_buckets(&buckets, 0.5), 2f64.powi(24));
    }

    #[test]
    fn percentile_splits_across_buckets() {
        // 99 fast samples (bucket 1) + 1 slow (bucket 10): p50 lands in
        // bucket 1 (upper bound 4), p99 still in bucket 1 (ceil(99·0.99)
        // = 99 ≤ 99 cumulative), p100 in bucket 10 (upper bound 2048).
        let mut buckets = [0u64; 24];
        buckets[1] = 99;
        buckets[10] = 1;
        assert_eq!(percentile_from_buckets(&buckets, 0.5), 4.0);
        assert_eq!(percentile_from_buckets(&buckets, 0.99), 4.0);
        assert_eq!(percentile_from_buckets(&buckets, 1.0), 2048.0);
    }
}

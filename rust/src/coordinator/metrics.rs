//! Service metrics: counters + latency histogram (log2 buckets), all
//! lock-free on the hot path (atomics).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Global service counters.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub numbers_served: AtomicU64,
    pub launches: AtomicU64,
    pub rejected: AtomicU64,
    /// Reply buffers served from the recycle pool (steady-state path).
    pub pool_hits: AtomicU64,
    /// Reply buffers freshly allocated (pool empty — warm-up or burst).
    pub pool_misses: AtomicU64,
    /// log2-bucketed request latency histogram, buckets of 2^i microseconds.
    lat_buckets: [AtomicU64; 24],
    lat_total_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(23);
        self.lat_buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.lat_total_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let buckets: Vec<u64> =
            self.lat_buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count: u64 = buckets.iter().sum();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            numbers_served: self.numbers_served.load(Ordering::Relaxed),
            launches: self.launches.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            pool_hits: self.pool_hits.load(Ordering::Relaxed),
            pool_misses: self.pool_misses.load(Ordering::Relaxed),
            mean_latency_us: if count == 0 {
                0.0
            } else {
                self.lat_total_us.load(Ordering::Relaxed) as f64 / count as f64
            },
            p99_latency_us: percentile_from_buckets(&buckets, 0.99),
            lat_buckets: buckets,
        }
    }
}

fn percentile_from_buckets(buckets: &[u64], q: f64) -> f64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = (total as f64 * q).ceil() as u64;
    let mut acc = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        acc += c;
        if acc >= target {
            return 2f64.powi(i as i32 + 1); // bucket upper bound in µs
        }
    }
    2f64.powi(buckets.len() as i32)
}

/// A point-in-time copy of the metrics.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub numbers_served: u64,
    pub launches: u64,
    pub rejected: u64,
    pub pool_hits: u64,
    pub pool_misses: u64,
    pub mean_latency_us: f64,
    pub p99_latency_us: f64,
    pub lat_buckets: Vec<u64>,
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests={} numbers={} launches={} rejected={} pool_hits={} pool_misses={} \
             mean_lat={:.1}us p99_lat<={:.0}us",
            self.requests,
            self.numbers_served,
            self.launches,
            self.rejected,
            self.pool_hits,
            self.pool_misses,
            self.mean_latency_us,
            self.p99_latency_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_histogram_buckets() {
        let m = Metrics::new();
        m.record_latency(Duration::from_micros(3)); // bucket 1 (2-4us)
        m.record_latency(Duration::from_micros(1000)); // bucket 9 (512-1024)
        m.record_latency(Duration::from_micros(1500)); // bucket 10
        let s = m.snapshot();
        assert_eq!(s.lat_buckets.iter().sum::<u64>(), 3);
        assert!(s.mean_latency_us > 500.0);
        assert!(s.p99_latency_us >= 1024.0);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.numbers_served.fetch_add(1000, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.requests, 5);
        assert_eq!(s.numbers_served, 1000);
        assert!(s.render().contains("requests=5"));
    }
}

//! Dynamic batching: pure planning logic (kept side-effect free so the
//! proptests in rust/tests/proptests.rs can hammer its invariants).
//!
//! Given the pending requests of one stream, the stream's buffered
//! remainder (the live span of the service's offset-cursor ring), and the
//! backend's fixed launch size, compute how many launches to run and how
//! outputs are split across requests in arrival order. Invariants: no
//! request is dropped or duplicated; allocation is FIFO; launches are the
//! minimum needed to cover the demanded total — which also bounds the
//! ring: `leftover < launch_size`, so the per-stream buffer never holds
//! more than one launch.

use super::stream::StreamId;
use std::collections::HashMap;

/// A pending draw request (one client call).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PendingRequest {
    pub request_id: u64,
    pub n: usize,
}

/// Group one batching window's requests by stream, preserving FIFO order
/// both across streams (first-arrival order of the returned ids) and
/// within each stream's queue. Generic over the payload so the invariant
/// is testable without channels; the worker loop drives it with
/// `(PendingRequest, reply, enqueue-time)` tuples.
pub fn group_fifo<T>(items: Vec<(StreamId, T)>) -> (Vec<StreamId>, HashMap<StreamId, Vec<T>>) {
    let mut order: Vec<StreamId> = Vec::new();
    let mut by_stream: HashMap<StreamId, Vec<T>> = HashMap::new();
    for (stream, item) in items {
        if !by_stream.contains_key(&stream) {
            order.push(stream);
        }
        by_stream.entry(stream).or_default().push(item);
    }
    (order, by_stream)
}

/// The batcher's plan for one stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    /// Number of backend launches to run.
    pub launches: usize,
    /// Per-request allocations `(request_id, n)` in service order.
    pub allocations: Vec<(u64, usize)>,
    /// Outputs left in the stream buffer afterwards.
    pub leftover: usize,
}

/// Plan servicing `requests` given `buffered` outputs on hand and a fixed
/// `launch_size` per backend launch.
pub fn plan_batch(requests: &[PendingRequest], buffered: usize, launch_size: usize) -> BatchPlan {
    assert!(launch_size > 0);
    let total: usize = requests.iter().map(|r| r.n).sum();
    let needed = total.saturating_sub(buffered);
    let launches = needed.div_ceil(launch_size);
    let available = buffered + launches * launch_size;
    BatchPlan {
        launches,
        allocations: requests.iter().map(|r| (r.request_id, r.n)).collect(),
        leftover: available - total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(ns: &[usize]) -> Vec<PendingRequest> {
        ns.iter().enumerate().map(|(i, &n)| PendingRequest { request_id: i as u64, n }).collect()
    }

    #[test]
    fn covers_demand_exactly() {
        let plan = plan_batch(&reqs(&[10, 20, 30]), 0, 25);
        assert_eq!(plan.launches, 3); // 60 needed, 25 each -> 3 launches = 75
        assert_eq!(plan.leftover, 15);
        assert_eq!(plan.allocations.iter().map(|a| a.1).sum::<usize>(), 60);
    }

    #[test]
    fn uses_buffer_first() {
        let plan = plan_batch(&reqs(&[10]), 15, 100);
        assert_eq!(plan.launches, 0);
        assert_eq!(plan.leftover, 5);
    }

    #[test]
    fn empty_requests_no_launches() {
        let plan = plan_batch(&[], 7, 10);
        assert_eq!(plan.launches, 0);
        assert_eq!(plan.leftover, 7);
        assert!(plan.allocations.is_empty());
    }

    #[test]
    fn fifo_order_preserved() {
        let plan = plan_batch(&reqs(&[5, 6, 7]), 0, 100);
        let ids: Vec<u64> = plan.allocations.iter().map(|a| a.0).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn leftover_bounded_by_launch_size() {
        // The ring-size bound the service relies on: whenever launches run,
        // the leftover is strictly less than one launch.
        for (ns, buf, ls) in [
            (vec![100usize], 0usize, 64usize),
            (vec![1, 1, 1], 0, 1000),
            (vec![5000], 4999, 7),
        ] {
            let plan = plan_batch(&reqs(&ns), buf, ls);
            if plan.launches > 0 {
                assert!(plan.leftover < ls, "{ns:?} {buf} {ls} -> {}", plan.leftover);
            }
        }
    }

    #[test]
    fn group_fifo_preserves_both_orders() {
        let items = vec![
            (StreamId(3), "a"),
            (StreamId(1), "b"),
            (StreamId(3), "c"),
            (StreamId(2), "d"),
            (StreamId(1), "e"),
        ];
        let (order, by_stream) = group_fifo(items);
        assert_eq!(order, vec![StreamId(3), StreamId(1), StreamId(2)]);
        assert_eq!(by_stream[&StreamId(3)], vec!["a", "c"]);
        assert_eq!(by_stream[&StreamId(1)], vec!["b", "e"]);
        assert_eq!(by_stream[&StreamId(2)], vec!["d"]);
    }

    #[test]
    fn conservation_property_spot() {
        for (ns, buf, ls) in [
            (vec![1usize, 2, 3], 0usize, 7usize),
            (vec![100], 3, 64),
            (vec![0, 0, 5], 2, 3),
            (vec![63, 63, 63], 62, 63),
        ] {
            let plan = plan_batch(&reqs(&ns), buf, ls);
            let total: usize = ns.iter().sum();
            assert_eq!(buf + plan.launches * ls, total + plan.leftover, "{ns:?} {buf} {ls}");
        }
    }
}

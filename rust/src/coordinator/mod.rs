//! L3 coordinator — the serving layer (vLLM-router-shaped, per the
//! reproduction architecture): named random-number **streams** with
//! provably disjoint subsequences, a **dynamic batcher** that coalesces
//! client requests into fixed-shape kernel launches, **backpressure**, and
//! pluggable backends (pure-Rust block generators, or the PJRT runtime
//! executing the AOT JAX/Pallas artifacts).
//!
//! The paper's GPU mapping (one independent subsequence per block, §2) is
//! the unit of state here: a stream owns a block-parallel generator whose
//! launches produce `blocks × rounds × lane` outputs; the batcher packs
//! arbitrary client `draw(n)` requests into those launches and buffers the
//! remainder.
//!
//! Clients consume through **typed stream handles** ([`handle`]): a
//! [`StreamBuilder`] whose terminal methods fix the element type
//! (`TypedStream<u32>` / `TypedStream<f32>`) at compile time, caller-owned
//! `draw_into` buffers with pooled reply recycling, and non-blocking
//! `submit` tickets for pipelining. The untyped `Coordinator::draw*`
//! methods are deprecated shims over the same path.

pub mod backend;
pub mod batcher;
pub mod handle;
pub mod metrics;
pub mod service;
pub mod stream;

pub use backend::{Backend, BackendKind, Draws, PjrtBackend, RustBackend};
pub use batcher::{plan_batch, BatchPlan, PendingRequest};
pub use handle::{Sample, StreamBuilder, Ticket, TypedStream};
pub use metrics::MetricsSnapshot;
pub use service::{Coordinator, CoordinatorConfig};
pub use stream::{Placement, StreamConfig, StreamId, StreamRegistry};

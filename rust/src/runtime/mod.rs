//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, built once
//! by `make artifacts` from the JAX/Pallas compile path) and execute them
//! from the Rust hot path. No Python at request time.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* → `HloModuleProto`
//! → `XlaComputation` → `PjRtClient::compile` → `execute`.
//!
//! The executing client needs the `xla` crate and is gated behind the
//! off-by-default `pjrt` cargo feature; the default offline build keeps
//! artifact discovery/validation but stubs the launcher (clear error).

mod artifact;
mod client;

pub use artifact::{default_dir, ArtifactMeta, Manifest, Transform};
pub use client::{LaunchOutput, PjrtRuntime};

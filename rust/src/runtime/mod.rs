//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, built once
//! by `make artifacts` from the JAX/Pallas compile path) and execute them
//! from the Rust hot path. No Python at request time.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* → `HloModuleProto`
//! → `XlaComputation` → `PjRtClient::compile` → `execute`.

mod artifact;
mod client;

pub use artifact::{default_dir, ArtifactMeta, Manifest, Transform};
pub use client::{LaunchOutput, PjrtRuntime};

//! Artifact discovery: parse `artifacts/manifest.txt` (written by
//! `python/compile/aot.py`) into typed metadata.

use crate::prng::GeneratorKind;
use crate::util::error::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Output transform baked into an artifact (L2 graph).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transform {
    U32,
    F32,
    Normal,
}

impl Transform {
    pub fn parse(s: &str) -> Result<Transform> {
        Ok(match s {
            "u32" => Transform::U32,
            "f32" => Transform::F32,
            "normal" => Transform::Normal,
            other => bail!("unknown transform {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Transform::U32 => "u32",
            Transform::F32 => "f32",
            Transform::Normal => "normal",
        }
    }
}

/// One artifact's metadata (a line of manifest.txt).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: GeneratorKind,
    pub transform: Transform,
    pub blocks: usize,
    pub rounds: usize,
    pub lane: usize,
    pub outputs: usize,
    pub state_args: usize,
    pub path: PathBuf,
}

impl ArtifactMeta {
    /// Words of state per block in the canonical interchange layout.
    pub fn state_words_per_block(&self) -> usize {
        match self.kind {
            GeneratorKind::XorgensGp | GeneratorKind::Xorgens => 129,
            GeneratorKind::Mtgp | GeneratorKind::Mt19937 => 624,
            GeneratorKind::Xorwow => 6,
        }
    }
}

/// Parsed manifest.
#[derive(Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() != 8 {
                bail!("manifest line {} malformed: {line:?}", lineno + 1);
            }
            let name = fields[0].to_string();
            let kind = GeneratorKind::parse(fields[1])
                .with_context(|| format!("unknown generator kind {:?}", fields[1]))?;
            let meta = ArtifactMeta {
                path: dir.join(format!("{name}.hlo.txt")),
                name,
                kind,
                transform: Transform::parse(fields[2])?,
                blocks: fields[3].parse()?,
                rounds: fields[4].parse()?,
                lane: fields[5].parse()?,
                outputs: fields[6].parse()?,
                state_args: fields[7].parse()?,
            };
            if !meta.path.exists() {
                bail!("artifact file missing: {:?}", meta.path);
            }
            if meta.outputs != meta.blocks * meta.rounds * meta.lane {
                bail!("inconsistent manifest entry for {}", meta.name);
            }
            artifacts.push(meta);
        }
        Ok(Manifest { artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Pick the largest-launch artifact for a generator kind + transform
    /// (the coordinator's default choice).
    pub fn best_for(&self, kind: GeneratorKind, transform: Transform) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == kind && a.transform == transform)
            .max_by_key(|a| a.outputs)
    }
}

/// Default artifacts dir: `$XORGENSGP_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("XORGENSGP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = default_dir();
        dir.join("manifest.txt").exists().then_some(dir)
    }

    #[test]
    fn manifest_parses_when_built() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 8, "expected the full artifact set");
        let a = m.find("xorgensgp_u32_b8_r2").expect("test artifact present");
        assert_eq!(a.blocks, 8);
        assert_eq!(a.lane, 63);
        assert_eq!(a.state_args, 2);
        let best = m.best_for(GeneratorKind::XorgensGp, Transform::U32).unwrap();
        assert_eq!(best.name, "xorgensgp_u32_b64_r64"); // §Perf L2-1 launch shape
    }

    #[test]
    fn transform_roundtrip() {
        for t in [Transform::U32, Transform::F32, Transform::Normal] {
            assert_eq!(Transform::parse(t.name()).unwrap(), t);
        }
        assert!(Transform::parse("nope").is_err());
    }
}

//! The PJRT CPU client wrapper: compile cache + typed launch.
//!
//! The real client drives the `xla` crate (HLO *text* → `HloModuleProto`
//! → `XlaComputation` → `PjRtClient::compile` → `execute`, following
//! /opt/xla-example/load_hlo) and is gated on **both** the off-by-default
//! `pjrt` cargo feature and the build-script-detected `xla_vendored` cfg
//! (set when `rust/../vendor/xla` exists — see rust/build.rs). That split
//! keeps `cargo build --features pjrt` compiling on machines without the
//! vendored crate: the stub below still loads and validates manifests (so
//! artifact plumbing and its error paths stay testable) but `launch`
//! returns a clear error.

use super::artifact::{ArtifactMeta, Manifest};
use crate::util::error::{bail, Context, Result};
use std::path::Path;

/// Output of one artifact launch.
#[derive(Debug)]
pub enum LaunchOutput {
    U32(Vec<u32>),
    F32(Vec<f32>),
}

impl LaunchOutput {
    pub fn len(&self) -> usize {
        match self {
            LaunchOutput::U32(v) => v.len(),
            LaunchOutput::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_u32(&self) -> Option<&[u32]> {
        match self {
            LaunchOutput::U32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            LaunchOutput::F32(v) => Some(v),
            _ => None,
        }
    }
}

/// Validate the canonical concatenated state size for an artifact (shared
/// by the stub and the real client — catches wrong-state bugs before any
/// launch is attempted).
fn check_state_size(meta: &ArtifactMeta, state: &[u32]) -> Result<()> {
    let spb = meta.state_words_per_block();
    if state.len() != meta.blocks * spb {
        bail!(
            "state size mismatch for {}: got {} words, want {}",
            meta.name,
            state.len(),
            meta.blocks * spb
        );
    }
    Ok(())
}

#[cfg(not(all(feature = "pjrt", xla_vendored)))]
mod imp {
    use super::*;

    /// PJRT runtime stub (the `pjrt` feature is disabled, or no `xla`
    /// crate is vendored): manifests load and validate, launches error out
    /// with instructions.
    pub struct PjrtRuntime {
        pub manifest: Manifest,
    }

    impl PjrtRuntime {
        /// Load the manifest from `dir`. Succeeds without the feature so
        /// artifact discovery and validation stay exercised offline.
        pub fn new(dir: &Path) -> Result<PjrtRuntime> {
            let manifest = Manifest::load(dir)?;
            Ok(PjrtRuntime { manifest })
        }

        pub fn platform(&self) -> String {
            "unavailable (built without the `pjrt` feature + vendored `xla`)".to_string()
        }

        pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
            self.manifest.find(name).with_context(|| format!("unknown artifact {name:?}"))?;
            bail!(
                "cannot compile artifact {name:?}: this binary was built without the real \
                 PJRT client (vendor the `xla` crate under vendor/xla, add it to \
                 rust/Cargo.toml as an optional dependency wired to the `pjrt` feature, \
                 and rebuild with `--features pjrt`)"
            )
        }

        pub fn launch(&mut self, name: &str, state: &[u32]) -> Result<(Vec<u32>, LaunchOutput)> {
            let meta = self
                .manifest
                .find(name)
                .with_context(|| format!("unknown artifact {name:?}"))?;
            check_state_size(meta, state)?;
            bail!(
                "cannot launch artifact {name:?}: this binary was built without the real \
                 PJRT client (vendor the `xla` crate under vendor/xla, add it to \
                 rust/Cargo.toml as an optional dependency wired to the `pjrt` feature, \
                 and rebuild with `--features pjrt`)"
            )
        }
    }
}

#[cfg(all(feature = "pjrt", xla_vendored))]
mod imp {
    use super::*;
    use crate::runtime::artifact::Transform;
    use std::collections::HashMap;

    /// PJRT CPU runtime with a compile cache keyed by artifact name.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        pub manifest: Manifest,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl PjrtRuntime {
        /// Create a CPU client and load the manifest from `dir`.
        pub fn new(dir: &Path) -> Result<PjrtRuntime> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| crate::anyhow!("{e}"))
                .context("creating PJRT CPU client")?;
            let manifest = Manifest::load(dir)?;
            Ok(PjrtRuntime { client, manifest, executables: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) an artifact by name.
        pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
            if self.executables.contains_key(name) {
                return Ok(());
            }
            let meta =
                self.manifest.find(name).with_context(|| format!("unknown artifact {name:?}"))?;
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| crate::anyhow!("{e}"))
            .with_context(|| format!("parsing HLO text {:?}", meta.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| crate::anyhow!("{e}"))
                .with_context(|| format!("compiling {name}"))?;
            self.executables.insert(name.to_string(), exe);
            Ok(())
        }

        /// Launch an artifact: `state` is the canonical per-block
        /// interchange layout concatenated over blocks (see
        /// `prng::BlockParallel::dump_state`); returns
        /// `(new_state, outputs)` in the same layout.
        pub fn launch(&mut self, name: &str, state: &[u32]) -> Result<(Vec<u32>, LaunchOutput)> {
            self.ensure_compiled(name)?;
            let meta = self.manifest.find(name).unwrap().clone();
            let exe = self.executables.get(name).unwrap();
            let args = split_state_to_literals(&meta, state)?;
            let result = exe.execute::<xla::Literal>(&args).map_err(|e| crate::anyhow!("{e}"))?;
            let out = result[0][0].to_literal_sync().map_err(|e| crate::anyhow!("{e}"))?;
            // aot.py lowers with return_tuple=True: a single tuple literal.
            let mut parts = out.to_tuple().map_err(|e| crate::anyhow!("{e}"))?;
            if parts.len() != meta.state_args + 1 {
                bail!(
                    "artifact {name}: expected {} outputs, got {}",
                    meta.state_args + 1,
                    parts.len()
                );
            }
            let stream_lit = parts.pop().unwrap();
            let new_state = join_literals_to_state(&meta, &parts)?;
            let stream = match meta.transform {
                Transform::U32 => LaunchOutput::U32(
                    stream_lit.to_vec::<u32>().map_err(|e| crate::anyhow!("{e}"))?,
                ),
                Transform::F32 | Transform::Normal => LaunchOutput::F32(
                    stream_lit.to_vec::<f32>().map_err(|e| crate::anyhow!("{e}"))?,
                ),
            };
            if stream.len() != meta.outputs {
                bail!("artifact {name}: expected {} outputs, got {}", meta.outputs, stream.len());
            }
            Ok((new_state, stream))
        }
    }

    /// Split the canonical concatenated state into the artifact's input
    /// literals. Layouts (per block): xorgensgp `q[128], w`; mtgp `q[624]`;
    /// xorwow `x[5], d`.
    fn split_state_to_literals(meta: &ArtifactMeta, state: &[u32]) -> Result<Vec<xla::Literal>> {
        check_state_size(meta, state)?;
        let spb = meta.state_words_per_block();
        let b = meta.blocks;
        match meta.state_args {
            1 => {
                // mtgp: (B, 624) contiguous — canonical layout is already that.
                let lit = xla::Literal::vec1(state)
                    .reshape(&[b as i64, spb as i64])
                    .map_err(|e| crate::anyhow!("{e}"))?;
                Ok(vec![lit])
            }
            2 => {
                // (B, spb-1) array + (B,) scalar tail per block.
                let main_w = spb - 1;
                let mut main = Vec::with_capacity(b * main_w);
                let mut tail = Vec::with_capacity(b);
                for blk in 0..b {
                    let s = &state[blk * spb..(blk + 1) * spb];
                    main.extend_from_slice(&s[..main_w]);
                    tail.push(s[main_w]);
                }
                Ok(vec![
                    xla::Literal::vec1(&main)
                        .reshape(&[b as i64, main_w as i64])
                        .map_err(|e| crate::anyhow!("{e}"))?,
                    xla::Literal::vec1(&tail),
                ])
            }
            n => bail!("unsupported state_args {n}"),
        }
    }

    /// Inverse of [`split_state_to_literals`] for the returned state parts.
    fn join_literals_to_state(meta: &ArtifactMeta, parts: &[xla::Literal]) -> Result<Vec<u32>> {
        let spb = meta.state_words_per_block();
        let b = meta.blocks;
        match parts {
            [main] => main.to_vec::<u32>().map_err(|e| crate::anyhow!("{e}")),
            [main, tail] => {
                let main = main.to_vec::<u32>().map_err(|e| crate::anyhow!("{e}"))?;
                let tail = tail.to_vec::<u32>().map_err(|e| crate::anyhow!("{e}"))?;
                let main_w = spb - 1;
                let mut out = Vec::with_capacity(b * spb);
                for blk in 0..b {
                    out.extend_from_slice(&main[blk * main_w..(blk + 1) * main_w]);
                    out.push(tail[blk]);
                }
                Ok(out)
            }
            _ => bail!("unsupported state parts"),
        }
    }
}

pub use imp::PjrtRuntime;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Transform;

    #[test]
    fn state_size_check() {
        use crate::prng::GeneratorKind;
        let meta = ArtifactMeta {
            name: "t".into(),
            kind: GeneratorKind::Xorwow,
            transform: Transform::U32,
            blocks: 2,
            rounds: 1,
            lane: 1,
            outputs: 2,
            state_args: 2,
            path: std::path::PathBuf::from("t.hlo.txt"),
        };
        assert!(check_state_size(&meta, &[0u32; 12]).is_ok());
        let err = check_state_size(&meta, &[0u32; 5]).unwrap_err();
        assert!(format!("{err}").contains("state size mismatch"));
    }
}

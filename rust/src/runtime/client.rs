//! The PJRT CPU client wrapper: compile cache + typed launch.

use super::artifact::{ArtifactMeta, Manifest, Transform};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Output of one artifact launch.
#[derive(Debug)]
pub enum LaunchOutput {
    U32(Vec<u32>),
    F32(Vec<f32>),
}

impl LaunchOutput {
    pub fn len(&self) -> usize {
        match self {
            LaunchOutput::U32(v) => v.len(),
            LaunchOutput::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_u32(&self) -> Option<&[u32]> {
        match self {
            LaunchOutput::U32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            LaunchOutput::F32(v) => Some(v),
            _ => None,
        }
    }
}

/// PJRT CPU runtime with a compile cache keyed by artifact name.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(dir)?;
        Ok(PjrtRuntime { client, manifest, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let meta =
            self.manifest.find(name).with_context(|| format!("unknown artifact {name:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            meta.path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", meta.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Launch an artifact: `state` is the canonical per-block interchange
    /// layout concatenated over blocks (see `prng::BlockParallel::dump_state`);
    /// returns `(new_state, outputs)` in the same layout.
    pub fn launch(&mut self, name: &str, state: &[u32]) -> Result<(Vec<u32>, LaunchOutput)> {
        self.ensure_compiled(name)?;
        let meta = self.manifest.find(name).unwrap().clone();
        let exe = self.executables.get(name).unwrap();
        let args = split_state_to_literals(&meta, state)?;
        let result = exe.execute::<xla::Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: a single tuple literal.
        let mut parts = out.to_tuple()?;
        if parts.len() != meta.state_args + 1 {
            bail!("artifact {name}: expected {} outputs, got {}", meta.state_args + 1, parts.len());
        }
        let stream_lit = parts.pop().unwrap();
        let new_state = join_literals_to_state(&meta, &parts)?;
        let stream = match meta.transform {
            Transform::U32 => LaunchOutput::U32(stream_lit.to_vec::<u32>()?),
            Transform::F32 | Transform::Normal => LaunchOutput::F32(stream_lit.to_vec::<f32>()?),
        };
        if stream.len() != meta.outputs {
            bail!("artifact {name}: expected {} outputs, got {}", meta.outputs, stream.len());
        }
        Ok((new_state, stream))
    }
}

/// Split the canonical concatenated state into the artifact's input
/// literals. Layouts (per block): xorgensgp `q[128], w`; mtgp `q[624]`;
/// xorwow `x[5], d`.
fn split_state_to_literals(meta: &ArtifactMeta, state: &[u32]) -> Result<Vec<xla::Literal>> {
    let spb = meta.state_words_per_block();
    if state.len() != meta.blocks * spb {
        bail!(
            "state size mismatch for {}: got {} words, want {}",
            meta.name,
            state.len(),
            meta.blocks * spb
        );
    }
    let b = meta.blocks;
    match meta.state_args {
        1 => {
            // mtgp: (B, 624) contiguous — canonical layout is already that.
            let lit = xla::Literal::vec1(state).reshape(&[b as i64, spb as i64])?;
            Ok(vec![lit])
        }
        2 => {
            // (B, spb-1) array + (B,) scalar tail per block.
            let main_w = spb - 1;
            let mut main = Vec::with_capacity(b * main_w);
            let mut tail = Vec::with_capacity(b);
            for blk in 0..b {
                let s = &state[blk * spb..(blk + 1) * spb];
                main.extend_from_slice(&s[..main_w]);
                tail.push(s[main_w]);
            }
            Ok(vec![
                xla::Literal::vec1(&main).reshape(&[b as i64, main_w as i64])?,
                xla::Literal::vec1(&tail),
            ])
        }
        n => bail!("unsupported state_args {n}"),
    }
}

/// Inverse of [`split_state_to_literals`] for the returned state parts.
fn join_literals_to_state(meta: &ArtifactMeta, parts: &[xla::Literal]) -> Result<Vec<u32>> {
    let spb = meta.state_words_per_block();
    let b = meta.blocks;
    match parts {
        [main] => Ok(main.to_vec::<u32>()?),
        [main, tail] => {
            let main = main.to_vec::<u32>()?;
            let tail = tail.to_vec::<u32>()?;
            let main_w = spb - 1;
            let mut out = Vec::with_capacity(b * spb);
            for blk in 0..b {
                out.extend_from_slice(&main[blk * main_w..(blk + 1) * main_w]);
                out.push(tail[blk]);
            }
            Ok(out)
        }
        _ => bail!("unsupported state parts"),
    }
}

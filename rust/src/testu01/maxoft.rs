//! Max-of-t test (Knuth; TestU01 `sknuth_MaxOft`).
//!
//! The maximum of `t` uniforms has CDF `x^t`; transforming by the CDF gives
//! uniforms, checked by both chi-square (binned) and Kolmogorov–Smirnov.

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::{chi2_test, ks_uniform_p};

pub fn max_of_t(rng: &mut dyn Prng32, n_groups: usize, t: usize) -> TestResult {
    assert!(t >= 2);
    let mut rng = ChunkedRng::new(rng);
    let mut transformed: Vec<f64> = Vec::with_capacity(n_groups);
    for _ in 0..n_groups {
        let mut m = 0.0f64;
        for _ in 0..t {
            m = m.max(rng.next_f64());
        }
        transformed.push(m.powi(t as i32)); // CDF transform -> U(0,1)
    }
    // Chi-square over bins.
    let bins = (n_groups / 32).clamp(8, 128);
    let mut counts = vec![0u64; bins];
    for &u in &transformed {
        counts[((u * bins as f64) as usize).min(bins - 1)] += 1;
    }
    let expected = vec![n_groups as f64 / bins as f64; bins];
    let (chi2, p_chi2) = chi2_test(&counts, &expected);
    // KS on the same transformed sample.
    transformed.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p_ks = ks_uniform_p(&transformed);
    // Combine conservatively: take the worse tail, Bonferroni factor 2.
    let p = (2.0 * p_chi2.min(p_ks)).min(1.0);
    TestResult::new("max-of-t", format!("n={n_groups} t={t}"), chi2, p, rng.count).folded()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Xorgens, Xorwow};

    #[test]
    fn good_generators_pass() {
        let r = max_of_t(&mut Xorgens::new(6), 4000, 8);
        assert!(!r.is_fail(), "p={}", r.p_value);
        let r = max_of_t(&mut Xorwow::new(6), 4000, 8);
        assert!(!r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn biased_generator_fails() {
        // Only emits values below 0.5: max-of-t never reaches upper range.
        struct Low(crate::prng::Xorgens);
        impl Prng32 for Low {
            fn next_u32(&mut self) -> u32 {
                self.0.next_u32() >> 1
            }
            fn name(&self) -> &'static str {
                "low"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let r = max_of_t(&mut Low(Xorgens::new(1)), 4000, 8);
        assert!(r.is_fail(), "p={}", r.p_value);
    }
}

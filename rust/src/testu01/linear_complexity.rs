//! Linear-complexity test (TestU01 `scomp_LinearComp`) — **the test family
//! that produces paper Table 2's discrimination pattern**.
//!
//! Extract one bit position from each output and run Berlekamp–Massey over
//! `n` bits. For truly random bits the complexity `L` concentrates tightly
//! around `n/2` (Rueppel): `P(|L − n/2| ≥ k)` decays like `4^{−k}`. A
//! GF(2)-linear generator with state `m < n/2` bits is caught *exactly*:
//! BM locks onto the recurrence after `2m` bits and `L ≈ m`, giving
//! p-values of order `2^{−(n−2m)}` — astronomically failing, as the paper
//! puts it, "of the order 10^-10" (here far smaller).
//!
//! ## Why this reproduces Table 2 (see EXPERIMENTS.md for measurements)
//!
//! * **MTGP / MT19937**: every output bit is a linear function of the
//!   19937-bit state → both the high-bit and low-bit instances fail as soon
//!   as `n > 2·19937` — our Crush and BigCrush tiers (TestU01: Crush
//!   #71/#72, BigCrush #80/#81).
//! * **XORWOW**: output is `v + d (mod 2^32)` — LFSR word plus a counter.
//!   Bit 31 mixes ~31 carry levels → huge complexity → passes. Bit 2 (what
//!   TestU01 reaches with its `r = 29` parameter) sees only two carry
//!   levels: its complexity is a few tens of thousands — *between* our
//!   Crush-tier `n/2` and BigCrush-tier `n/2`. Hence: passes Crush, fails
//!   only the low-bit BigCrush instance — exactly CURAND's `#81`-only
//!   failure in Table 2.
//! * **xorgensGP**: output is `x + (w ^ (w >> 16))`; even bit 0 contains
//!   the period-2^17 Weyl bit-16 sequence (complexity ~2^17) plus the
//!   4096-bit LFSR, and bit 2 carries products of those — beyond every
//!   tier's detection horizon → passes everything, like the paper.

use super::suite::{ChunkedRng, TestResult};
use crate::gf2::{berlekamp_massey, lfsr_check};
use crate::prng::Prng32;

/// Run BM on bit `bit` (0 = LSB) of `n` consecutive outputs.
pub fn linear_complexity_test(rng: &mut dyn Prng32, n: usize, bit: u32) -> TestResult {
    assert!(bit < 32);
    let mut rng = ChunkedRng::new(rng);
    let mut words = vec![0u32; n];
    rng.fill_u32(&mut words);
    let bits: Vec<bool> = words.iter().map(|w| (w >> bit) & 1 == 1).collect();
    drop(words);
    let (c, l) = berlekamp_massey(&bits);
    // Sanity: the recovered recurrence must actually regenerate the
    // sequence (defends the test itself against BM regressions).
    debug_assert!(l > n / 4 || lfsr_check(&c, l, &bits), "BM poly fails to regenerate input");
    // Rueppel expectation: E[L] = n/2 + (4 + r_n)/18 with r_n = n mod 2.
    let expect = n as f64 / 2.0 + (4.0 + (n % 2) as f64) / 18.0;
    let dev = l as f64 - expect;
    // Two-sided tail from the complexity distribution
    // P(L = n/2 + d) ~ 2^{-2|d|}: log2 p ≈ 1 − 2|dev|.
    let log2_p = (1.0 - 2.0 * dev.abs()).min(0.0);
    let p = 2f64.powf(log2_p.max(-1020.0)); // representable floor; log2_p keeps the true value
    TestResult::new(
        "linear-complexity",
        format!("n={n} bit={bit} L={l}"),
        dev,
        p,
        rng.count,
    )
    .with_log2_p(log2_p)
    .folded()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::traits::InterleavedStream;
    use crate::prng::{Mt19937, Mtgp, Xorgens, Xorwow};

    #[test]
    fn xorgens_passes_all_bits() {
        for bit in [0, 1, 2, 31] {
            let mut g = Xorgens::new(31);
            let r = linear_complexity_test(&mut g, 20_000, bit);
            assert!(!r.is_fail(), "bit {bit}: p={} stat={}", r.p_value, r.statistic);
        }
    }

    /// The decisive MT failure: n > 2·19937 exposes the recurrence.
    #[test]
    fn mt19937_fails_when_n_exceeds_twice_state() {
        let mut g = Mt19937::new(7);
        let r = linear_complexity_test(&mut g, 50_000, 31);
        assert!(r.is_fail(), "p={} log2p={:?}", r.p_value, r.log2_p);
        // L should be ~19937, far below n/2 = 25000.
        assert!(r.statistic < -4000.0, "deviation {}", r.statistic);
    }

    /// …and passes when n is below the detection horizon (SmallCrush-like).
    #[test]
    fn mt19937_passes_small_n() {
        let mut g = Mt19937::new(7);
        let r = linear_complexity_test(&mut g, 10_000, 31);
        assert!(!r.is_fail(), "p={}", r.p_value);
    }

    /// XORWOW's LSB is v₀ ⊕ d₀ with d₀ of period 2: complexity ≈ 162,
    /// caught even at tiny n (which is why the battery's low-bit instances
    /// use bit 2, matching TestU01's r = 29 — see module docs).
    #[test]
    fn xorwow_bit0_is_nearly_linear() {
        let mut g = Xorwow::new(5);
        let r = linear_complexity_test(&mut g, 2_000, 0);
        assert!(r.is_fail(), "p={} L-dev={}", r.p_value, r.statistic);
    }

    /// Bit 31 (maximal carry mixing) passes at Crush scale.
    #[test]
    fn xorwow_bit31_passes() {
        let mut g = Xorwow::new(5);
        let r = linear_complexity_test(&mut g, 40_000, 31);
        assert!(!r.is_fail(), "p={}", r.p_value);
    }

    /// A single-block MTGP stream is the serial MT sequence and fails like
    /// it — this is the stream the battery evaluates (paper Table 2 rates
    /// the *algorithm*; §4 discusses multi-block initialisation separately).
    #[test]
    fn single_block_mtgp_fails_like_serial_mt() {
        let mut g = InterleavedStream::new(Mtgp::new(3, 1));
        let r = linear_complexity_test(&mut g, 50_000, 31);
        assert!(r.is_fail(), "p={} stat={}", r.p_value, r.statistic);
    }

    /// Documentation test for a subtlety: *chunk*-interleaving B blocks
    /// (227 outputs per block per round) hides the per-block recurrence
    /// from a stream-global BM — the combined sequence needs a time-varying
    /// selection, pushing the complexity far above n/2's detection horizon.
    /// This is WHY the battery tests per-block streams rather than the
    /// round-interleaved stream.
    #[test]
    fn chunk_interleaving_masks_linearity() {
        let mut g = InterleavedStream::new(Mtgp::new(3, 2));
        let r = linear_complexity_test(&mut g, 90_000, 31);
        assert!(!r.is_fail(), "chunk-interleaved stream unexpectedly failed: p={}", r.p_value);
    }
}

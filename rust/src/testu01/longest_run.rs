//! Longest-run-of-ones test (NIST SP 800-22 §2.4 relative).
//!
//! Split the bit stream into blocks of `m` bits; the longest run of ones
//! per block has an exactly computable distribution (DP below). Chi-square
//! over run-length categories.

use super::coupon::merge_small_buckets;
use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::chi2_test;

/// P(longest run of ones in an m-bit fair block == L) for L in 0..=cap,
/// last entry aggregates >= cap. Exact DP over (position, current run,
/// best run) collapsed to P(longest <= L) via the standard recurrence.
pub fn longest_run_pmf(m: usize, cap: usize) -> Vec<f64> {
    // P(longest <= L): count bit strings of length m with no run of L+1
    // ones, via dp[i] = number of valid strings of length i ending rules —
    // classic: a(i) = sum_{k=0..L} a(i-1-k) with a(negative)=..., use
    // probability DP instead for numeric stability.
    let p_le = |l: usize| -> f64 {
        // dp[j] = P(valid prefix of current length with suffix of exactly
        // j trailing ones), j <= l.
        let mut dp = vec![0.0f64; l + 1];
        dp[0] = 1.0;
        for _ in 0..m {
            let mut next = vec![0.0f64; l + 1];
            for (j, &pj) in dp.iter().enumerate() {
                if pj == 0.0 {
                    continue;
                }
                next[0] += pj * 0.5; // append 0
                if j + 1 <= l {
                    next[j + 1] += pj * 0.5; // append 1
                }
            }
            dp = next;
        }
        dp.iter().sum()
    };
    let mut pmf = Vec::with_capacity(cap + 1);
    let mut prev = 0.0;
    for l in 0..cap {
        let cum = p_le(l);
        pmf.push(cum - prev);
        prev = cum;
    }
    pmf.push(1.0 - prev); // >= cap
    pmf
}

pub fn longest_run(rng: &mut dyn Prng32, n_blocks: usize, m_bits: usize) -> TestResult {
    assert!(m_bits % 32 == 0);
    let mut rng = ChunkedRng::new(rng);
    let cap = 2 * (m_bits as f64).log2() as usize; // generous upper category
    let pmf = longest_run_pmf(m_bits, cap);
    let mut counts = vec![0u64; cap + 1];
    for _ in 0..n_blocks {
        let mut longest = 0u32;
        let mut current = 0u32;
        for _ in 0..m_bits / 32 {
            let mut w = rng.next_u32();
            for _ in 0..32 {
                if w & 1 == 1 {
                    current += 1;
                    longest = longest.max(current);
                } else {
                    current = 0;
                }
                w >>= 1;
            }
        }
        counts[(longest as usize).min(cap)] += 1;
    }
    let expected: Vec<f64> = pmf.iter().map(|p| p * n_blocks as f64).collect();
    let (counts, expected) = merge_small_buckets(&counts, &expected, 5.0);
    let (stat, p) = chi2_test(&counts, &expected);
    TestResult::new("longest-run", format!("n={n_blocks} m={m_bits}"), stat, p, rng.count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Mt19937, Xorgens};

    #[test]
    fn pmf_sums_to_one() {
        for (m, cap) in [(32usize, 10usize), (128, 14), (512, 18)] {
            let pmf = longest_run_pmf(m, cap);
            assert!((pmf.iter().sum::<f64>() - 1.0).abs() < 1e-9, "m={m}");
        }
    }

    #[test]
    fn pmf_mode_near_log2_m() {
        // Longest run in m fair bits concentrates near log2(m).
        let pmf = longest_run_pmf(256, 20);
        let mode = pmf.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert!((6..=9).contains(&mode), "mode {mode}");
    }

    #[test]
    fn good_generators_pass() {
        let r = longest_run(&mut Xorgens::new(44), 2000, 128);
        assert!(!r.is_fail(), "xorgens p={}", r.p_value);
        let r = longest_run(&mut Mt19937::new(44), 2000, 128);
        assert!(!r.is_fail(), "mt p={}", r.p_value);
    }

    #[test]
    fn sparse_bits_fail() {
        // P(one) = 1/4: longest runs far shorter than fair.
        struct Sparse(Xorgens);
        impl Prng32 for Sparse {
            fn next_u32(&mut self) -> u32 {
                self.0.next_u32() & self.0.next_u32()
            }
            fn name(&self) -> &'static str {
                "sparse"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let r = longest_run(&mut Sparse(Xorgens::new(1)), 2000, 128);
        assert!(r.is_fail(), "p={}", r.p_value);
    }
}

//! Bit autocorrelation at a lag (TestU01 `sstring_AutoCor` relative).
//!
//! Over `n` bits (one chosen bit per output), count agreements between the
//! sequence and itself shifted by `lag`; the agreement count is
//! Binomial(n − lag, 1/2) under the null.

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::normal_two_sided_p;

pub fn autocorrelation(rng: &mut dyn Prng32, n: usize, lag: usize, bit: u32) -> TestResult {
    assert!(lag >= 1 && lag < n && bit < 32);
    let mut rng = ChunkedRng::new(rng);
    let mut words = vec![0u32; n];
    rng.fill_u32(&mut words);
    let bits: Vec<bool> = words.iter().map(|w| (w >> bit) & 1 == 1).collect();
    drop(words);
    let agreements = bits.windows(lag + 1).filter(|w| w[0] == w[lag]).count() as f64;
    let trials = (n - lag) as f64;
    let z = (agreements - trials / 2.0) / (trials / 4.0).sqrt();
    TestResult::new(
        "autocorrelation",
        format!("n={n} lag={lag} bit={bit}"),
        z,
        normal_two_sided_p(z),
        rng.count,
    )
    .folded()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xorgens;

    #[test]
    fn good_generator_passes_multiple_lags() {
        for lag in [1, 2, 7] {
            let mut g = Xorgens::new(23);
            let r = autocorrelation(&mut g, 1 << 16, lag, 0);
            assert!(!r.is_fail(), "lag {lag}: p={}", r.p_value);
        }
    }

    #[test]
    fn periodic_bit_fails() {
        // LSB alternates -> lag-2 agreement is 100%.
        struct AltBit(u32);
        impl Prng32 for AltBit {
            fn next_u32(&mut self) -> u32 {
                self.0 = self.0.wrapping_add(1);
                self.0
            }
            fn name(&self) -> &'static str {
                "altbit"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                32.0
            }
        }
        let r = autocorrelation(&mut AltBit(0), 1 << 14, 2, 0);
        assert!(r.is_fail(), "p={}", r.p_value);
    }
}

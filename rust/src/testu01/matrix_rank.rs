//! Matrix-rank test (DIEHARD / TestU01 `smarsa_MatrixRank`).
//!
//! Build `m` random `L×L` GF(2) matrices from consecutive output bits and
//! compare the rank distribution against the exact null probabilities.
//! Any GF(2)-linear generator whose state is *smaller* than `L²` bits shows
//! rank collapse; for the paper's generators the matrix sizes that fit in a
//! laptop-scale tier all pass (as in Table 2, where the MT failures come
//! from the linear-complexity tests instead) — the test is included for
//! battery fidelity and to catch grossly defective generators.

use super::suite::{ChunkedRng, TestResult};
use crate::gf2::BitMatrix;
use crate::prng::Prng32;
use crate::util::stats::chi2_test;

/// Exact P(rank = L − k) for a uniform random L×L GF(2) matrix.
///
/// P(rank = r) = 2^(r(2L−r) − L²) · Π_{i=0}^{r−1} ( (1 − 2^{i−L})² / (1 − 2^{i−r}) )
pub fn rank_pmf(l: usize, deficiencies: usize) -> Vec<f64> {
    let mut pmf = Vec::with_capacity(deficiencies + 1);
    for k in 0..=deficiencies {
        let r = l - k;
        // log2 of the probability to avoid under/overflow for big L.
        let mut log2p = (r as f64) * (2.0 * l as f64 - r as f64) - (l as f64) * (l as f64);
        let mut factor = 0.0f64;
        for i in 0..r {
            let a = 1.0 - 2f64.powi(i as i32 - l as i32);
            let b = 1.0 - 2f64.powi(i as i32 - r as i32);
            factor += a.log2() * 2.0 - b.log2();
        }
        log2p += factor;
        pmf.push(2f64.powf(log2p));
    }
    pmf
}

pub fn matrix_rank(rng: &mut dyn Prng32, n_matrices: usize, l: usize) -> TestResult {
    assert!(l % 32 == 0, "L must be a multiple of 32");
    let mut rng = ChunkedRng::new(rng);
    // Buckets: deficiency 0, 1, 2, >=3.
    let mut pmf = rank_pmf(l, 2);
    let tail = 1.0 - pmf.iter().sum::<f64>();
    pmf.push(tail);
    let mut counts = vec![0u64; 4];
    let words_per_row = l / 32;
    for _ in 0..n_matrices {
        let m = BitMatrix::from_fn(l, l, |_i, _j| false); // placeholder; fill below
        let mut m = m;
        for i in 0..l {
            for w in 0..words_per_row {
                let v = rng.next_u32();
                for b in 0..32 {
                    if (v >> b) & 1 == 1 {
                        m.set(i, w * 32 + b, true);
                    }
                }
            }
        }
        let deficiency = l - m.rank();
        counts[deficiency.min(3)] += 1;
    }
    let expected: Vec<f64> = pmf.iter().map(|p| p * n_matrices as f64).collect();
    // Merge tiny expected buckets (deficiency >= 3 is ~5e-3 of cases).
    let (counts, expected) = super::coupon::merge_small_buckets(&counts, &expected, 5.0);
    let (stat, p) = chi2_test(&counts, &expected);
    TestResult::new("matrix-rank", format!("n={n_matrices} L={l}"), stat, p, rng.count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Mt19937, Xorgens};

    #[test]
    fn pmf_large_l_limits() {
        // Known limits for large L: P(def 0) ≈ 0.2888, P(def 1) ≈ 0.5776,
        // P(def 2) ≈ 0.1284.
        let pmf = rank_pmf(64, 2);
        assert!((pmf[0] - 0.2888).abs() < 0.002, "{}", pmf[0]);
        assert!((pmf[1] - 0.5776).abs() < 0.002, "{}", pmf[1]);
        assert!((pmf[2] - 0.1284).abs() < 0.002, "{}", pmf[2]);
    }

    #[test]
    fn good_generators_pass() {
        let r = matrix_rank(&mut Xorgens::new(21), 300, 32);
        assert!(!r.is_fail(), "xorgens p={}", r.p_value);
        // MT19937 passes small matrix ranks (its failures are at
        // linear-complexity scale) — matching Table 2.
        let r = matrix_rank(&mut Mt19937::new(21), 300, 32);
        assert!(!r.is_fail(), "mt p={}", r.p_value);
    }

    #[test]
    fn low_rank_source_fails() {
        // A generator that repeats each output 32 times produces rank-1-ish
        // row blocks -> massive deficiency.
        struct Repeat {
            inner: Xorgens,
            cur: u32,
            k: usize,
        }
        impl Prng32 for Repeat {
            fn next_u32(&mut self) -> u32 {
                if self.k == 0 {
                    self.cur = self.inner.next_u32();
                    self.k = 32;
                }
                self.k -= 1;
                self.cur
            }
            fn name(&self) -> &'static str {
                "repeat"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let mut g = Repeat { inner: Xorgens::new(2), cur: 0, k: 0 };
        let r = matrix_rank(&mut g, 100, 32);
        assert!(r.is_fail(), "p={}", r.p_value);
    }
}

//! Random-walk test (TestU01 `swalk_RandomWalk1` relative).
//!
//! `m` independent ±1 walks of length `len` (one bit per step). Two
//! statistics: (a) the endpoints normalised by √len are ~N(0,1), so the sum
//! of their squares is χ²(m); (b) the fraction of walks ending positive is
//! Binomial(m, ~1/2).

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::{chi2_sf, normal_two_sided_p};

pub fn random_walk(rng: &mut dyn Prng32, m_walks: usize, len: usize) -> TestResult {
    assert!(len % 32 == 0);
    let mut rng = ChunkedRng::new(rng);
    let mut chi2 = 0.0f64;
    let mut positive = 0u64;
    for _ in 0..m_walks {
        let mut s: i64 = 0;
        for _ in 0..len / 32 {
            let w = rng.next_u32();
            // ±1 per bit: sum = 2*popcount - 32.
            s += 2 * w.count_ones() as i64 - 32;
        }
        let z = s as f64 / (len as f64).sqrt();
        chi2 += z * z;
        if s > 0 {
            positive += 1;
        }
    }
    let p_chi2 = chi2_sf(chi2, m_walks as f64);
    // Endpoint sign: P(S > 0) = (1 - P(S = 0)) / 2 with
    // P(S=0) = C(len, len/2) 2^-len ≈ sqrt(2/(pi len)).
    let p0 = (2.0 / (std::f64::consts::PI * len as f64)).sqrt();
    let p_pos = (1.0 - p0) / 2.0;
    let z_sign = (positive as f64 - m_walks as f64 * p_pos)
        / (m_walks as f64 * p_pos * (1.0 - p_pos)).sqrt();
    let p_sign = normal_two_sided_p(z_sign);
    let p = (2.0 * p_chi2.min(p_sign)).min(1.0);
    TestResult::new(
        "random-walk",
        format!("m={m_walks} len={len}"),
        chi2 / m_walks as f64,
        p,
        rng.count,
    )
    .folded()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Xorgens, Xorwow};

    #[test]
    fn good_generators_pass() {
        let r = random_walk(&mut Xorgens::new(19), 512, 1024);
        assert!(!r.is_fail(), "p={}", r.p_value);
        let r = random_walk(&mut Xorwow::new(19), 512, 1024);
        assert!(!r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn drifting_generator_fails() {
        // 60% ones per word -> walks drift upward.
        struct Drift(Xorgens);
        impl Prng32 for Drift {
            fn next_u32(&mut self) -> u32 {
                let a = self.0.next_u32();
                let b = self.0.next_u32();
                a | (b & self.0.next_u32()) // P(bit=1) = 1/2 + 1/8
            }
            fn name(&self) -> &'static str {
                "drift"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let r = random_walk(&mut Drift(Xorgens::new(3)), 256, 1024);
        assert!(r.is_fail(), "p={}", r.p_value);
    }
}

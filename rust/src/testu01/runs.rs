//! Runs test (Wald–Wolfowitz on the median split; TestU01 `sknuth_Run`
//! relative).
//!
//! Count runs of consecutive values on the same side of 1/2. Conditional on
//! `n1` values above and `n2` below, the run count is asymptotically normal
//! with mean `1 + 2 n1 n2 / n` and a known variance.

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::normal_two_sided_p;

pub fn runs_median(rng: &mut dyn Prng32, n: usize) -> TestResult {
    let mut rng = ChunkedRng::new(rng);
    let mut n1 = 0u64; // above
    let mut runs = 0u64;
    let mut prev: Option<bool> = None;
    for _ in 0..n {
        let above = rng.next_u32() >= 0x8000_0000;
        if above {
            n1 += 1;
        }
        if prev != Some(above) {
            runs += 1;
        }
        prev = Some(above);
    }
    let n2 = n as u64 - n1;
    let nf = n as f64;
    let (n1f, n2f) = (n1 as f64, n2 as f64);
    let mean = 1.0 + 2.0 * n1f * n2f / nf;
    let var = (mean - 1.0) * (mean - 2.0) / (nf - 1.0);
    let z = (runs as f64 - mean) / var.sqrt();
    TestResult::new(
        "runs-median",
        format!("n={n}"),
        z,
        normal_two_sided_p(z),
        rng.count,
    )
    .folded()
}

/// Runs-up test with independence restoration: after each run ends, the
/// value that broke the run is discarded (Knuth's trick to de-correlate
/// consecutive runs). Chi-square over run lengths 1..=6+.
pub fn runs_up(rng: &mut dyn Prng32, n_runs: usize) -> TestResult {
    let mut rng = ChunkedRng::new(rng);
    // P(run length = L) = 1/L! - 1/(L+1)!
    let probs: Vec<f64> = (1..=6)
        .map(|l: i32| {
            let fact = |k: i32| (1..=k).map(|i| i as f64).product::<f64>();
            1.0 / fact(l) - 1.0 / fact(l + 1)
        })
        .collect();
    let tail = 1.0 - probs.iter().sum::<f64>();
    let mut counts = vec![0u64; 7];
    for _ in 0..n_runs {
        let mut len = 1u32;
        let mut prev = rng.next_f64();
        loop {
            let cur = rng.next_f64();
            if cur > prev {
                len += 1;
                prev = cur;
            } else {
                break; // breaker value discarded -> independence
            }
        }
        counts[(len.min(7) - 1) as usize] += 1;
    }
    let mut expected: Vec<f64> = probs.iter().map(|p| p * n_runs as f64).collect();
    expected.push(tail * n_runs as f64);
    let (stat, p) = crate::util::stats::chi2_test(&counts, &expected);
    TestResult::new("runs-up", format!("n={n_runs}"), stat, p, rng.count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xorgens;

    #[test]
    fn good_generator_passes_both() {
        let r = runs_median(&mut Xorgens::new(10), 1 << 16);
        assert!(!r.is_fail(), "median p={}", r.p_value);
        let r = runs_up(&mut Xorgens::new(10), 1 << 14);
        assert!(!r.is_fail(), "up p={}", r.p_value);
    }

    #[test]
    fn alternating_fails_median_runs() {
        struct Alt(bool);
        impl Prng32 for Alt {
            fn next_u32(&mut self) -> u32 {
                self.0 = !self.0;
                if self.0 {
                    u32::MAX
                } else {
                    0
                }
            }
            fn name(&self) -> &'static str {
                "alt"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let r = runs_median(&mut Alt(false), 1 << 14);
        assert!(r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn monotone_fails_runs_up() {
        struct Ramp(u32);
        impl Prng32 for Ramp {
            fn next_u32(&mut self) -> u32 {
                self.0 = self.0.wrapping_add(1 << 8);
                self.0
            }
            fn name(&self) -> &'static str {
                "ramp"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                24.0
            }
        }
        let r = runs_up(&mut Ramp(0), 1 << 12);
        assert!(r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn run_length_probs_sum() {
        let probs: Vec<f64> = (1..=6)
            .map(|l: i32| {
                let fact = |k: i32| (1..=k).map(|i| i as f64).product::<f64>();
                1.0 / fact(l) - 1.0 / fact(l + 1)
            })
            .collect();
        let total: f64 = probs.iter().sum();
        assert!(total < 1.0 && total > 0.999, "sum={total}");
    }
}

//! Hamming-weight tests (TestU01 `svaria_WeightDistrib`,
//! `sstring_HammingIndep` relatives).

use super::coupon::merge_small_buckets;
use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::{chi2_test, normal_two_sided_p};

/// Chi-square of the per-word popcount distribution vs Binomial(32, 1/2).
pub fn hamming_weight(rng: &mut dyn Prng32, n_words: usize) -> TestResult {
    let mut rng = ChunkedRng::new(rng);
    let mut counts = vec![0u64; 33];
    for _ in 0..n_words {
        counts[rng.next_u32().count_ones() as usize] += 1;
    }
    // Binomial(32, 1/2) pmf.
    let mut pmf = vec![0.0f64; 33];
    let mut c = 1.0f64; // C(32, 0)
    for (k, p) in pmf.iter_mut().enumerate() {
        *p = c * 2f64.powi(-32);
        c = c * (32 - k) as f64 / (k + 1) as f64;
    }
    let expected: Vec<f64> = pmf.iter().map(|p| p * n_words as f64).collect();
    let (counts, expected) = merge_small_buckets(&counts, &expected, 5.0);
    let (stat, p) = chi2_test(&counts, &expected);
    TestResult::new("hamming-weight", format!("n={n_words}"), stat, p, rng.count)
}

/// Correlation between the weights of successive words: under the null the
/// centered weights are independent, so the lag-1 sample correlation times
/// sqrt(n) is standard normal.
pub fn hamming_correlation(rng: &mut dyn Prng32, n_words: usize) -> TestResult {
    let mut rng = ChunkedRng::new(rng);
    let mut prev = rng.next_u32().count_ones() as f64 - 16.0;
    let mut sum = 0.0f64;
    for _ in 1..n_words {
        let cur = rng.next_u32().count_ones() as f64 - 16.0;
        sum += prev * cur;
        prev = cur;
    }
    // Var(weight) = 32/4 = 8, so E[w_i w_{i+1}] = 0, Var(sum) = n * 64.
    let z = sum / ((n_words as f64 - 1.0).sqrt() * 8.0);
    TestResult::new(
        "hamming-correlation",
        format!("n={n_words}"),
        z,
        normal_two_sided_p(z),
        rng.count,
    )
    .folded()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Mt19937, Xorgens};

    #[test]
    fn good_generators_pass_weight() {
        let r = hamming_weight(&mut Xorgens::new(17), 1 << 16);
        assert!(!r.is_fail(), "p={}", r.p_value);
        let r = hamming_weight(&mut Mt19937::new(17), 1 << 16);
        assert!(!r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn good_generator_passes_correlation() {
        let r = hamming_correlation(&mut Xorgens::new(18), 1 << 16);
        assert!(!r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn biased_weight_fails() {
        struct Sparse(Xorgens);
        impl Prng32 for Sparse {
            fn next_u32(&mut self) -> u32 {
                self.0.next_u32() & self.0.next_u32() // E[weight] = 8
            }
            fn name(&self) -> &'static str {
                "sparse"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let r = hamming_weight(&mut Sparse(Xorgens::new(1)), 1 << 14);
        assert!(r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn correlated_weights_fail() {
        // Repeat each word twice: lag-1 correlation = 1 on half the pairs.
        struct Twice {
            inner: Xorgens,
            cur: u32,
            flip: bool,
        }
        impl Prng32 for Twice {
            fn next_u32(&mut self) -> u32 {
                self.flip = !self.flip;
                if self.flip {
                    self.cur = self.inner.next_u32();
                }
                self.cur
            }
            fn name(&self) -> &'static str {
                "twice"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let mut g = Twice { inner: Xorgens::new(2), cur: 0, flip: false };
        let r = hamming_correlation(&mut g, 1 << 14);
        assert!(r.is_fail(), "p={}", r.p_value);
    }
}

//! Sample-mean / sample-variance test (TestU01 `svaria_SampleMean`
//! relative): over groups of `t` uniforms, the standardised group mean is
//! ~N(0,1) (CLT at t >= ~30); combine group means by chi-square and the
//! global mean by a z-test.

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::{chi2_sf, normal_two_sided_p};

pub fn sample_mean(rng: &mut dyn Prng32, n_groups: usize, t: usize) -> TestResult {
    assert!(t >= 16);
    let mut rng = ChunkedRng::new(rng);
    let sigma = (1.0 / 12.0f64 / t as f64).sqrt(); // stdev of a U(0,1) mean
    let mut chi2 = 0.0f64;
    let mut grand = 0.0f64;
    for _ in 0..n_groups {
        let mean = (0..t).map(|_| rng.next_f64()).sum::<f64>() / t as f64;
        let z = (mean - 0.5) / sigma;
        chi2 += z * z;
        grand += z;
    }
    // Two-sided chi-square: too-small variance (chi2 near 0) is as
    // defective as too-large (e.g. a stream of averaged outputs).
    let sf = chi2_sf(chi2, n_groups as f64);
    let p_chi2 = (2.0 * sf.min(1.0 - sf)).min(1.0);
    let z_grand = grand / (n_groups as f64).sqrt();
    let p_grand = normal_two_sided_p(z_grand);
    let p = (2.0 * p_chi2.min(p_grand)).min(1.0);
    TestResult::new(
        "sample-mean",
        format!("n={n_groups} t={t}"),
        chi2 / n_groups as f64,
        p,
        rng.count,
    )
    .folded()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Xorgens, Xorwow};

    #[test]
    fn good_generators_pass() {
        let r = sample_mean(&mut Xorgens::new(55), 2000, 32);
        assert!(!r.is_fail(), "xorgens p={}", r.p_value);
        let r = sample_mean(&mut Xorwow::new(55), 2000, 32);
        assert!(!r.is_fail(), "xorwow p={}", r.p_value);
    }

    #[test]
    fn biased_mean_fails() {
        struct Biased(Xorgens);
        impl Prng32 for Biased {
            fn next_u32(&mut self) -> u32 {
                self.0.next_u32() | 0x1000_0000 // slight upward bias
            }
            fn name(&self) -> &'static str {
                "biased"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let r = sample_mean(&mut Biased(Xorgens::new(2)), 2000, 32);
        assert!(r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn low_variance_fails() {
        // Averaging adjacent outputs halves the variance of the stream.
        struct Smoothed(Xorgens);
        impl Prng32 for Smoothed {
            fn next_u32(&mut self) -> u32 {
                ((self.0.next_u32() as u64 + self.0.next_u32() as u64) / 2) as u32
            }
            fn name(&self) -> &'static str {
                "smoothed"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let r = sample_mean(&mut Smoothed(Xorgens::new(3)), 2000, 32);
        assert!(r.is_fail(), "p={}", r.p_value);
    }
}

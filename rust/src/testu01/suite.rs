//! Battery framework: test results, pass/fail classification (paper §1.2's
//! p-value interpretation), and test-instance plumbing.

use crate::prng::Prng32;

/// Outcome of one statistical test.
#[derive(Clone, Debug)]
pub struct TestResult {
    /// Test family (e.g. "linear-complexity").
    pub family: &'static str,
    /// Human-readable parameterisation.
    pub params: String,
    /// The test statistic.
    pub statistic: f64,
    /// p-value (probability of a statistic at least this extreme under the
    /// uniform-i.i.d. null). Exact zeros arise from astronomically
    /// significant failures underflowing f64 — see `log2_p`.
    pub p_value: f64,
    /// Optional exact log2(p) for failures too extreme for f64
    /// (e.g. the linear-complexity test on an LFSR).
    pub log2_p: Option<f64>,
    /// True when the p-value already folds both tails (two-sided z / Poisson
    /// / Bonferroni-combined statistics): `p ≈ 1` is then benign ("dead
    /// centre"), not suspicious. One-sided chi-square upper tails keep
    /// `folded = false`, where `p ≈ 1` means a suspiciously *too uniform*
    /// sample.
    pub folded: bool,
    /// Raw 32-bit draws consumed.
    pub consumed: u64,
}

/// Classification thresholds, following the paper's §1.2 discussion and
/// TestU01's convention.
pub const FAIL_P: f64 = 1e-10;
pub const SUSPECT_P: f64 = 1e-4;

/// Pass / suspect / fail verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Pass,
    /// Worth re-running with another seed — not counted as failure
    /// (with many tests, p-values near 1/N are expected; paper §1.2).
    Suspect,
    Fail,
}

impl TestResult {
    pub fn verdict(&self) -> Verdict {
        let p = self.p_value;
        if self.log2_p.map_or(false, |l| l < -33.2) {
            // log2(1e-10) ≈ -33.2
            return Verdict::Fail;
        }
        if p < FAIL_P || (!self.folded && p > 1.0 - FAIL_P) {
            Verdict::Fail
        } else if p < SUSPECT_P || (!self.folded && p > 1.0 - SUSPECT_P) {
            Verdict::Suspect
        } else {
            Verdict::Pass
        }
    }

    pub fn is_fail(&self) -> bool {
        self.verdict() == Verdict::Fail
    }

    pub fn new(
        family: &'static str,
        params: impl Into<String>,
        statistic: f64,
        p: f64,
        consumed: u64,
    ) -> Self {
        TestResult {
            family,
            params: params.into(),
            statistic,
            p_value: p,
            log2_p: None,
            folded: false,
            consumed,
        }
    }

    pub fn with_log2_p(mut self, log2_p: f64) -> Self {
        self.log2_p = Some(log2_p);
        self
    }

    /// Mark the p-value as both-tails-folded (see [`TestResult::folded`]).
    pub fn folded(mut self) -> Self {
        self.folded = true;
        self
    }
}

/// A runnable, parameterised test instance within a battery tier.
pub struct TestInstance {
    /// Battery-local id, e.g. "crush-11".
    pub id: String,
    /// Display name with parameters.
    pub name: String,
    /// Which TestU01 test this instance mirrors, where the paper's Table 2
    /// names one (e.g. "Crush #71").
    pub paper_analog: Option<&'static str>,
    /// The test body.
    pub run: Box<dyn Fn(&mut dyn Prng32) -> TestResult + Send + Sync>,
}

impl TestInstance {
    pub fn new(
        id: impl Into<String>,
        name: impl Into<String>,
        run: impl Fn(&mut dyn Prng32) -> TestResult + Send + Sync + 'static,
    ) -> Self {
        TestInstance { id: id.into(), name: name.into(), paper_analog: None, run: Box::new(run) }
    }

    pub fn analog(mut self, a: &'static str) -> Self {
        self.paper_analog = Some(a);
        self
    }
}

/// Scratch-buffer chunk size for battery consumption: 4096 words (16 KiB —
/// fits L1/L2 comfortably while amortising the virtual `fill_u32` call
/// over thousands of draws).
pub const CHUNK_WORDS: usize = 4096;

/// The battery's draw source: a chunked reader over a [`Prng32`].
///
/// Every test instance consumes through this adapter instead of calling
/// `next_u32` on the `dyn Prng32` directly: draws are pulled in
/// [`CHUNK_WORDS`]-sized `fill_u32` batches into a scratch buffer owned
/// here, so BigCrush-scale runs pay one virtual call (and one trip through
/// the generator's bulk fill pipeline) per 4096 draws rather than one per
/// draw. The served sequence is bit-identical to scalar consumption; the
/// only difference is that up to one chunk of prefetched tail is discarded
/// when the test finishes (each battery instance owns a fresh generator,
/// so nothing downstream observes the discard).
///
/// `count` reports the draws actually *served* to the test (the
/// `TestResult::consumed` metadata), not the prefetched total.
pub struct ChunkedRng<'a> {
    inner: &'a mut dyn Prng32,
    /// Scratch buffer, allocated once on first refill.
    buf: Vec<u32>,
    pos: usize,
    /// Draws served.
    pub count: u64,
}

impl<'a> ChunkedRng<'a> {
    pub fn new(inner: &'a mut dyn Prng32) -> Self {
        ChunkedRng { inner, buf: Vec::new(), pos: 0, count: 0 }
    }

    #[cold]
    fn refill(&mut self) {
        if self.buf.is_empty() {
            self.buf = vec![0u32; CHUNK_WORDS];
        }
        self.inner.fill_u32(&mut self.buf);
        self.pos = 0;
    }

    /// Next raw draw, from the scratch buffer (no virtual dispatch on the
    /// hot path).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.pos == self.buf.len() {
            self.refill();
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        self.count += 1;
        v
    }

    /// Uniform on [0, 1) — same mapping as [`Prng32::next_f64`].
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4294967296.0)
    }

    /// Uniform on [0, 1) single precision.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / 16777216.0)
    }

    /// Bulk copy into a caller slice (tests that digest whole words in
    /// batches, e.g. spectral/linear-complexity bit extraction). Serves
    /// the buffered head, then hands the remainder straight to the
    /// source's `fill_u32` — no bounce through the scratch for large
    /// reads.
    pub fn fill_u32(&mut self, out: &mut [u32]) {
        let head = out.len().min(self.buf.len() - self.pos);
        out[..head].copy_from_slice(&self.buf[self.pos..self.pos + head]);
        self.pos += head;
        if head < out.len() {
            self.inner.fill_u32(&mut out[head..]);
        }
        self.count += out.len() as u64;
    }

    /// Bulk unit-interval draws: `fill_u32` raw words, then the canonical
    /// [`unit_f32`](crate::prng::distributions::unit_f32) map through the
    /// vectorized slice transform ([`crate::simd`]). Bit-identical to
    /// calling [`next_f32`](Self::next_f32) `out.len()` times.
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        // Serve the buffered head elementwise, then pull the remainder as
        // one bulk raw fill and run the vectorized transform over it.
        let head = out.len().min(self.buf.len() - self.pos);
        for (o, &w) in out[..head].iter_mut().zip(&self.buf[self.pos..self.pos + head]) {
            *o = crate::prng::distributions::unit_f32(w);
        }
        self.pos += head;
        self.count += head as u64;
        let rest = &mut out[head..];
        if !rest.is_empty() {
            let mut raw = vec![0u32; rest.len()];
            self.inner.fill_u32(&mut raw);
            crate::prng::distributions::unit_f32_slice(&raw, rest);
            self.count += raw.len() as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verdict_thresholds() {
        let mk = |p: f64| TestResult::new("t", "", 0.0, p, 0);
        assert_eq!(mk(0.5).verdict(), Verdict::Pass);
        assert_eq!(mk(1e-5).verdict(), Verdict::Suspect);
        assert_eq!(mk(1.0 - 1e-5).verdict(), Verdict::Suspect);
        assert_eq!(mk(1e-11).verdict(), Verdict::Fail);
        assert_eq!(mk(1.0 - 1e-11).verdict(), Verdict::Fail);
        assert_eq!(mk(0.0).verdict(), Verdict::Fail);
    }

    #[test]
    fn log2_p_overrides() {
        let r = TestResult::new("t", "", 0.0, 1.0, 0).with_log2_p(-60000.0);
        assert_eq!(r.verdict(), Verdict::Fail);
        let r = TestResult::new("t", "", 0.0, 0.5, 0).with_log2_p(-3.0);
        assert_eq!(r.verdict(), Verdict::Pass);
    }

    #[test]
    fn chunked_rng_counts_served_draws() {
        let mut g = crate::prng::Xorgens::new(1);
        let mut c = ChunkedRng::new(&mut g);
        c.next_u32();
        let mut buf = [0u32; 10];
        c.fill_u32(&mut buf);
        assert_eq!(c.count, 11);
    }

    #[test]
    fn chunked_rng_serves_the_scalar_stream() {
        let mut a = crate::prng::Xorgens::new(9);
        let expect: Vec<u32> = (0..CHUNK_WORDS + 100).map(|_| a.next_u32()).collect();
        let mut b = crate::prng::Xorgens::new(9);
        let mut c = ChunkedRng::new(&mut b);
        // Mixed scalar/bulk consumption across a refill boundary.
        let got_head: Vec<u32> = (0..70).map(|_| c.next_u32()).collect();
        let mut got_mid = vec![0u32; CHUNK_WORDS];
        c.fill_u32(&mut got_mid);
        let got_tail: Vec<u32> = (0..30).map(|_| c.next_u32()).collect();
        assert_eq!(c.count, (CHUNK_WORDS + 100) as u64);
        let mut got = got_head;
        got.extend(got_mid);
        got.extend(got_tail);
        assert_eq!(got, expect);
    }

    #[test]
    fn chunked_rng_fill_f32_matches_repeated_next_f32() {
        let mut a = crate::prng::Xorgens::new(6);
        let mut ca = ChunkedRng::new(&mut a);
        let expect: Vec<u32> =
            (0..CHUNK_WORDS + 100).map(|_| ca.next_f32().to_bits()).collect();
        let mut b = crate::prng::Xorgens::new(6);
        let mut cb = ChunkedRng::new(&mut b);
        // Mixed scalar/bulk consumption across a refill boundary, like the
        // u32 pin above.
        let mut got: Vec<u32> = (0..70).map(|_| cb.next_f32().to_bits()).collect();
        let mut mid = vec![0f32; CHUNK_WORDS];
        cb.fill_f32(&mut mid);
        got.extend(mid.iter().map(|x| x.to_bits()));
        got.extend((0..30).map(|_| cb.next_f32().to_bits()));
        assert_eq!(cb.count, (CHUNK_WORDS + 100) as u64);
        assert_eq!(got, expect);
    }

    #[test]
    fn chunked_rng_f64_matches_prng32_mapping() {
        let mut a = crate::prng::Xorgens::new(4);
        let expect = a.next_f64();
        let mut b = crate::prng::Xorgens::new(4);
        let mut c = ChunkedRng::new(&mut b);
        assert_eq!(c.next_f64(), expect);
    }
}

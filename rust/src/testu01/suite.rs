//! Battery framework: test results, pass/fail classification (paper §1.2's
//! p-value interpretation), and test-instance plumbing.

use crate::prng::Prng32;

/// Outcome of one statistical test.
#[derive(Clone, Debug)]
pub struct TestResult {
    /// Test family (e.g. "linear-complexity").
    pub family: &'static str,
    /// Human-readable parameterisation.
    pub params: String,
    /// The test statistic.
    pub statistic: f64,
    /// p-value (probability of a statistic at least this extreme under the
    /// uniform-i.i.d. null). Exact zeros arise from astronomically
    /// significant failures underflowing f64 — see `log2_p`.
    pub p_value: f64,
    /// Optional exact log2(p) for failures too extreme for f64
    /// (e.g. the linear-complexity test on an LFSR).
    pub log2_p: Option<f64>,
    /// True when the p-value already folds both tails (two-sided z / Poisson
    /// / Bonferroni-combined statistics): `p ≈ 1` is then benign ("dead
    /// centre"), not suspicious. One-sided chi-square upper tails keep
    /// `folded = false`, where `p ≈ 1` means a suspiciously *too uniform*
    /// sample.
    pub folded: bool,
    /// Raw 32-bit draws consumed.
    pub consumed: u64,
}

/// Classification thresholds, following the paper's §1.2 discussion and
/// TestU01's convention.
pub const FAIL_P: f64 = 1e-10;
pub const SUSPECT_P: f64 = 1e-4;

/// Pass / suspect / fail verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Pass,
    /// Worth re-running with another seed — not counted as failure
    /// (with many tests, p-values near 1/N are expected; paper §1.2).
    Suspect,
    Fail,
}

impl TestResult {
    pub fn verdict(&self) -> Verdict {
        let p = self.p_value;
        if self.log2_p.map_or(false, |l| l < -33.2) {
            // log2(1e-10) ≈ -33.2
            return Verdict::Fail;
        }
        if p < FAIL_P || (!self.folded && p > 1.0 - FAIL_P) {
            Verdict::Fail
        } else if p < SUSPECT_P || (!self.folded && p > 1.0 - SUSPECT_P) {
            Verdict::Suspect
        } else {
            Verdict::Pass
        }
    }

    pub fn is_fail(&self) -> bool {
        self.verdict() == Verdict::Fail
    }

    pub fn new(family: &'static str, params: impl Into<String>, statistic: f64, p: f64, consumed: u64) -> Self {
        TestResult {
            family,
            params: params.into(),
            statistic,
            p_value: p,
            log2_p: None,
            folded: false,
            consumed,
        }
    }

    pub fn with_log2_p(mut self, log2_p: f64) -> Self {
        self.log2_p = Some(log2_p);
        self
    }

    /// Mark the p-value as both-tails-folded (see [`TestResult::folded`]).
    pub fn folded(mut self) -> Self {
        self.folded = true;
        self
    }
}

/// A runnable, parameterised test instance within a battery tier.
pub struct TestInstance {
    /// Battery-local id, e.g. "crush-11".
    pub id: String,
    /// Display name with parameters.
    pub name: String,
    /// Which TestU01 test this instance mirrors, where the paper's Table 2
    /// names one (e.g. "Crush #71").
    pub paper_analog: Option<&'static str>,
    /// The test body.
    pub run: Box<dyn Fn(&mut dyn Prng32) -> TestResult + Send + Sync>,
}

impl TestInstance {
    pub fn new(
        id: impl Into<String>,
        name: impl Into<String>,
        run: impl Fn(&mut dyn Prng32) -> TestResult + Send + Sync + 'static,
    ) -> Self {
        TestInstance { id: id.into(), name: name.into(), paper_analog: None, run: Box::new(run) }
    }

    pub fn analog(mut self, a: &'static str) -> Self {
        self.paper_analog = Some(a);
        self
    }
}

/// A counting wrapper so tests report how many draws they consumed.
pub struct CountingRng<'a> {
    inner: &'a mut dyn Prng32,
    pub count: u64,
}

impl<'a> CountingRng<'a> {
    pub fn new(inner: &'a mut dyn Prng32) -> Self {
        CountingRng { inner, count: 0 }
    }
}

impl Prng32 for CountingRng<'_> {
    fn next_u32(&mut self) -> u32 {
        self.count += 1;
        self.inner.next_u32()
    }

    fn fill_u32(&mut self, out: &mut [u32]) {
        self.count += out.len() as u64;
        self.inner.fill_u32(out);
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn state_words(&self) -> usize {
        self.inner.state_words()
    }

    fn period_log2(&self) -> f64 {
        self.inner.period_log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verdict_thresholds() {
        let mk = |p: f64| TestResult::new("t", "", 0.0, p, 0);
        assert_eq!(mk(0.5).verdict(), Verdict::Pass);
        assert_eq!(mk(1e-5).verdict(), Verdict::Suspect);
        assert_eq!(mk(1.0 - 1e-5).verdict(), Verdict::Suspect);
        assert_eq!(mk(1e-11).verdict(), Verdict::Fail);
        assert_eq!(mk(1.0 - 1e-11).verdict(), Verdict::Fail);
        assert_eq!(mk(0.0).verdict(), Verdict::Fail);
    }

    #[test]
    fn log2_p_overrides() {
        let r = TestResult::new("t", "", 0.0, 1.0, 0).with_log2_p(-60000.0);
        assert_eq!(r.verdict(), Verdict::Fail);
        let r = TestResult::new("t", "", 0.0, 0.5, 0).with_log2_p(-3.0);
        assert_eq!(r.verdict(), Verdict::Pass);
    }

    #[test]
    fn counting_rng_counts() {
        let mut g = crate::prng::Xorgens::new(1);
        let mut c = CountingRng::new(&mut g);
        c.next_u32();
        let mut buf = [0u32; 10];
        c.fill_u32(&mut buf);
        assert_eq!(c.count, 11);
    }
}

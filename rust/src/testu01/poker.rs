//! Simplified poker test (Knuth; TestU01 `sknuth_SimpPoker`).
//!
//! Hands of `k` values in `0..d`; count distinct values per hand. The
//! distinct-count distribution is exact (same Markov chain as the coupon
//! collector). Chi-square over the distinct counts.

use super::coupon::merge_small_buckets;
use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::chi2_test;

/// Exact P(#distinct = r) after k draws from d values.
pub fn distinct_pmf(d: usize, k: usize) -> Vec<f64> {
    let mut dp = vec![0.0f64; d + 1];
    dp[0] = 1.0;
    for _ in 0..k {
        let mut next = vec![0.0f64; d + 1];
        for s in 0..=d.min(k) {
            if dp[s] == 0.0 {
                continue;
            }
            if s < d {
                next[s + 1] += dp[s] * (d - s) as f64 / d as f64;
            }
            next[s] += dp[s] * s as f64 / d as f64;
        }
        dp = next;
    }
    dp
}

pub fn simple_poker(rng: &mut dyn Prng32, n_hands: usize, k: usize, d: usize) -> TestResult {
    assert!(d >= 2 && d <= 64 && k >= 2);
    let mut rng = ChunkedRng::new(rng);
    let pmf = distinct_pmf(d, k);
    let mut counts = vec![0u64; d + 1];
    for _ in 0..n_hands {
        let mut seen = 0u64;
        for _ in 0..k {
            let v = (rng.next_u32() as u64 * d as u64 >> 32) as usize;
            seen |= 1 << v;
        }
        counts[seen.count_ones() as usize] += 1;
    }
    let expected: Vec<f64> = pmf.iter().map(|p| p * n_hands as f64).collect();
    let (counts, expected) = merge_small_buckets(&counts, &expected, 5.0);
    let (stat, pv) = chi2_test(&counts, &expected);
    TestResult::new(
        "simple-poker",
        format!("n={n_hands} k={k} d={d}"),
        stat,
        pv,
        rng.count,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Mtgp, Xorgens};
    use crate::prng::traits::InterleavedStream;

    #[test]
    fn pmf_is_probability() {
        let pmf = distinct_pmf(8, 5);
        assert!((pmf.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // After 5 draws from 8 you cannot have seen more than 5.
        assert_eq!(pmf[6], 0.0);
        assert!(pmf[5] > 0.0);
        // P(all distinct) = 8*7*6*5*4 / 8^5
        let exact = (8.0 * 7.0 * 6.0 * 5.0 * 4.0) / 8f64.powi(5);
        assert!((pmf[5] - exact).abs() < 1e-12);
    }

    #[test]
    fn good_generators_pass() {
        let r = simple_poker(&mut Xorgens::new(4), 4000, 5, 8);
        assert!(!r.is_fail(), "xorgens p={}", r.p_value);
        let mut mtgp = InterleavedStream::new(Mtgp::new(4, 4));
        let r = simple_poker(&mut mtgp, 4000, 5, 8);
        assert!(!r.is_fail(), "mtgp p={}", r.p_value);
    }

    #[test]
    fn constant_generator_fails() {
        struct Const;
        impl Prng32 for Const {
            fn next_u32(&mut self) -> u32 {
                42
            }
            fn name(&self) -> &'static str {
                "const"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                0.0
            }
        }
        let r = simple_poker(&mut Const, 4000, 5, 8);
        assert!(r.is_fail());
    }
}

//! Battery tiers (SmallCrush / Crush / BigCrush analogs) and the runner
//! that regenerates paper Table 2.
//!
//! Instance sizing: TestU01's real batteries consume up to 2^38 draws and
//! run for hours on the paper's hardware; these tiers are scaled to
//! laptop-class minutes while preserving every *discriminating* structure
//! of Table 2 (see `linear_complexity.rs` module docs for the analysis of
//! why the scaled thresholds still separate xorgensGP / MTGP / CURAND).

use super::suite::{TestInstance, TestResult, Verdict};
use crate::prng::{GeneratorKind, Prng32};
use std::time::Instant;

/// Battery tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Small,
    Crush,
    Big,
}

impl Tier {
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Small => "smallcrush",
            Tier::Crush => "crush",
            Tier::Big => "bigcrush",
        }
    }

    /// Shim over the [`FromStr`](std::str::FromStr) impl for callers that
    /// want an `Option` (the typed error is discarded).
    pub fn parse(s: &str) -> Option<Tier> {
        s.parse().ok()
    }

    pub const ALL: [Tier; 3] = [Tier::Small, Tier::Crush, Tier::Big];
}

impl std::str::FromStr for Tier {
    type Err = crate::util::cli::ParseEnumError;

    fn from_str(s: &str) -> Result<Tier, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "small" | "smallcrush" => Ok(Tier::Small),
            "crush" => Ok(Tier::Crush),
            "big" | "bigcrush" => Ok(Tier::Big),
            _ => Err(crate::util::cli::ParseEnumError::new(
                "battery tier",
                s,
                "small, crush, big (aliases: smallcrush, bigcrush)",
            )),
        }
    }
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

use super::autocorrelation::autocorrelation;
use super::birthday::birthday_spacings;
use super::collision::collision;
use super::coupon::coupon_collector;
use super::gap::gap;
use super::hamming::{hamming_correlation, hamming_weight};
use super::linear_complexity::linear_complexity_test;
use super::longest_run::longest_run;
use super::matrix_rank::matrix_rank;
use super::maxoft::max_of_t;
use super::permutation::permutation;
use super::poker::simple_poker;
use super::random_walk::random_walk;
use super::runs::{runs_median, runs_up};
use super::sample_mean::sample_mean;
use super::serial::serial_tuples;
use super::spectral::spectral;

macro_rules! inst {
    ($id:expr, $name:expr, $body:expr) => {
        TestInstance::new($id, $name, $body)
    };
}

/// The SmallCrush-analog tier: ten quick instances mirroring TestU01's
/// SmallCrush families (which contains *no* LinearComp — that is why MTGP
/// and CURAND pass it in Table 2).
pub fn small_tier() -> Vec<TestInstance> {
    vec![
        inst!("small-01", "birthday-spacings n=2^13 d=2^37", |g: &mut dyn Prng32| {
            birthday_spacings(g, 1 << 13, 37)
        }),
        inst!("small-02", "collision n=2^13 k=2^24", |g: &mut dyn Prng32| {
            collision(g, 1 << 13, 24)
        }),
        inst!("small-03", "gap n=2^12 [0,1/16)", |g: &mut dyn Prng32| {
            gap(g, 1 << 12, 0.0, 0.0625)
        }),
        inst!("small-04", "simple-poker n=4000 k=5 d=8", |g: &mut dyn Prng32| {
            simple_poker(g, 4000, 5, 8)
        }),
        inst!("small-05", "coupon-collector n=2000 d=8", |g: &mut dyn Prng32| {
            coupon_collector(g, 2000, 8)
        }),
        inst!("small-06", "max-of-t n=2^13 t=8", |g: &mut dyn Prng32| max_of_t(g, 1 << 13, 8)),
        inst!("small-07", "hamming-weight n=2^16", |g: &mut dyn Prng32| {
            hamming_weight(g, 1 << 16)
        }),
        inst!("small-08", "matrix-rank n=200 L=64", |g: &mut dyn Prng32| matrix_rank(g, 200, 64)),
        inst!("small-09", "hamming-correlation n=2^16", |g: &mut dyn Prng32| {
            hamming_correlation(g, 1 << 16)
        }),
        inst!("small-10", "random-walk m=512 len=1024", |g: &mut dyn Prng32| {
            random_walk(g, 512, 1024)
        }),
        inst!("small-11", "longest-run n=1000 m=128", |g: &mut dyn Prng32| {
            longest_run(g, 1000, 128)
        }),
        inst!("small-12", "sample-mean n=1000 t=32", |g: &mut dyn Prng32| {
            sample_mean(g, 1000, 32)
        }),
    ]
}

/// The Crush-analog tier. Instances crush-25/26 are the analogs of TestU01
/// Crush #71/#72 (LinearComp with r=0 / r=29) that MTGP fails in Table 2.
pub fn crush_tier() -> Vec<TestInstance> {
    vec![
        inst!("crush-01", "birthday-spacings n=2^14 d=2^40", |g: &mut dyn Prng32| {
            birthday_spacings(g, 1 << 14, 40)
        }),
        inst!("crush-02", "birthday-spacings n=2^15 d=2^44", |g: &mut dyn Prng32| {
            birthday_spacings(g, 1 << 15, 44)
        }),
        inst!("crush-03", "collision n=2^14 k=2^24", |g: &mut dyn Prng32| {
            collision(g, 1 << 14, 24)
        }),
        inst!("crush-04", "collision n=2^15 k=2^28", |g: &mut dyn Prng32| {
            collision(g, 1 << 15, 28)
        }),
        inst!("crush-05", "gap n=2^14 [0,1/16)", |g: &mut dyn Prng32| {
            gap(g, 1 << 14, 0.0, 0.0625)
        }),
        inst!("crush-06", "gap n=2^14 [0.4,0.6)", |g: &mut dyn Prng32| gap(g, 1 << 14, 0.4, 0.6)),
        inst!("crush-07", "simple-poker n=2^14 k=5 d=8", |g: &mut dyn Prng32| {
            simple_poker(g, 1 << 14, 5, 8)
        }),
        inst!("crush-08", "simple-poker n=2^14 k=8 d=32", |g: &mut dyn Prng32| {
            simple_poker(g, 1 << 14, 8, 32)
        }),
        inst!("crush-09", "coupon-collector n=2^13 d=8", |g: &mut dyn Prng32| {
            coupon_collector(g, 1 << 13, 8)
        }),
        inst!("crush-10", "coupon-collector n=2^12 d=16", |g: &mut dyn Prng32| {
            coupon_collector(g, 1 << 12, 16)
        }),
        inst!("crush-11", "max-of-t n=2^14 t=8", |g: &mut dyn Prng32| max_of_t(g, 1 << 14, 8)),
        inst!("crush-12", "max-of-t n=2^14 t=16", |g: &mut dyn Prng32| max_of_t(g, 1 << 14, 16)),
        inst!("crush-13", "serial-tuples n=2^17 t=2 bits=6", |g: &mut dyn Prng32| {
            serial_tuples(g, 1 << 17, 2, 6)
        }),
        inst!("crush-14", "serial-tuples n=2^17 t=3 bits=4", |g: &mut dyn Prng32| {
            serial_tuples(g, 1 << 17, 3, 4)
        }),
        inst!("crush-15", "permutation n=2^15 t=4", |g: &mut dyn Prng32| {
            permutation(g, 1 << 15, 4)
        }),
        inst!("crush-16", "permutation n=2^15 t=5", |g: &mut dyn Prng32| {
            permutation(g, 1 << 15, 5)
        }),
        inst!("crush-17", "runs-median n=2^18", |g: &mut dyn Prng32| runs_median(g, 1 << 18)),
        inst!("crush-18", "runs-up n=2^16", |g: &mut dyn Prng32| runs_up(g, 1 << 16)),
        inst!("crush-19", "hamming-weight n=2^18", |g: &mut dyn Prng32| {
            hamming_weight(g, 1 << 18)
        }),
        inst!("crush-20", "hamming-correlation n=2^18", |g: &mut dyn Prng32| {
            hamming_correlation(g, 1 << 18)
        }),
        inst!("crush-21", "matrix-rank n=1000 L=64", |g: &mut dyn Prng32| {
            matrix_rank(g, 1000, 64)
        }),
        inst!("crush-22", "matrix-rank n=100 L=256", |g: &mut dyn Prng32| {
            matrix_rank(g, 100, 256)
        }),
        inst!("crush-23", "random-walk m=1024 len=4096", |g: &mut dyn Prng32| {
            random_walk(g, 1024, 4096)
        }),
        inst!("crush-24", "autocorrelation n=2^18 lag=1 bit=0", |g: &mut dyn Prng32| {
            autocorrelation(g, 1 << 18, 1, 0)
        }),
        inst!("crush-27", "longest-run n=4000 m=256", |g: &mut dyn Prng32| {
            longest_run(g, 4000, 256)
        }),
        inst!("crush-28", "sample-mean n=8000 t=32", |g: &mut dyn Prng32| {
            sample_mean(g, 8000, 32)
        }),
        inst!("crush-29", "spectral n=2^15 bit=31", |g: &mut dyn Prng32| {
            spectral(g, 1 << 15, 31)
        }),
        inst!("crush-30", "spectral n=2^15 bit=0", |g: &mut dyn Prng32| {
            spectral(g, 1 << 15, 0)
        }),
        // n = 45_000 is calibrated (EXPERIMENTS.md §T2): the tier must sit
        // between MT19937's linear complexity (19 937 — detected, n/2 >
        // 19 937) and XORWOW's measured bit-2 complexity (~26 000 — NOT
        // detected, n/2 < 26 000), preserving Table 2's "MTGP fails Crush
        // #71/#72, CURAND passes Crush" pattern at reduced scale.
        inst!("crush-25", "linear-complexity n=45000 bit=31", |g: &mut dyn Prng32| {
            linear_complexity_test(g, 45_000, 31)
        })
        .analog("Crush #71"),
        inst!("crush-26", "linear-complexity n=45000 bit=2", |g: &mut dyn Prng32| {
            linear_complexity_test(g, 45_000, 2)
        })
        .analog("Crush #72"),
    ]
}

/// The BigCrush-analog tier. Instances big-29/30 are the analogs of
/// BigCrush #80/#81 — the low-bit instance (#81) is the single test CURAND
/// fails in Table 2.
pub fn big_tier() -> Vec<TestInstance> {
    let mut v = vec![
        inst!("big-01", "birthday-spacings n=2^16 d=2^48", |g: &mut dyn Prng32| {
            birthday_spacings(g, 1 << 16, 48)
        }),
        inst!("big-02", "birthday-spacings n=2^17 d=2^51", |g: &mut dyn Prng32| {
            birthday_spacings(g, 1 << 17, 51)
        }),
        inst!("big-03", "collision n=2^16 k=2^28", |g: &mut dyn Prng32| {
            collision(g, 1 << 16, 28)
        }),
        inst!("big-04", "collision n=2^17 k=2^30", |g: &mut dyn Prng32| {
            collision(g, 1 << 17, 30)
        }),
        inst!("big-05", "gap n=2^16 [0,1/32)", |g: &mut dyn Prng32| {
            gap(g, 1 << 16, 0.0, 0.03125)
        }),
        inst!("big-06", "gap n=2^16 [0.45,0.55)", |g: &mut dyn Prng32| {
            gap(g, 1 << 16, 0.45, 0.55)
        }),
        inst!("big-07", "simple-poker n=2^16 k=5 d=8", |g: &mut dyn Prng32| {
            simple_poker(g, 1 << 16, 5, 8)
        }),
        inst!("big-08", "simple-poker n=2^15 k=8 d=64", |g: &mut dyn Prng32| {
            simple_poker(g, 1 << 15, 8, 64)
        }),
        inst!("big-09", "coupon-collector n=2^14 d=8", |g: &mut dyn Prng32| {
            coupon_collector(g, 1 << 14, 8)
        }),
        inst!("big-10", "coupon-collector n=2^13 d=32", |g: &mut dyn Prng32| {
            coupon_collector(g, 1 << 13, 32)
        }),
        inst!("big-11", "max-of-t n=2^16 t=8", |g: &mut dyn Prng32| max_of_t(g, 1 << 16, 8)),
        inst!("big-12", "max-of-t n=2^15 t=24", |g: &mut dyn Prng32| max_of_t(g, 1 << 15, 24)),
        inst!("big-13", "serial-tuples n=2^19 t=2 bits=7", |g: &mut dyn Prng32| {
            serial_tuples(g, 1 << 19, 2, 7)
        }),
        inst!("big-14", "serial-tuples n=2^19 t=4 bits=4", |g: &mut dyn Prng32| {
            serial_tuples(g, 1 << 19, 4, 4)
        }),
        inst!("big-15", "permutation n=2^17 t=5", |g: &mut dyn Prng32| {
            permutation(g, 1 << 17, 5)
        }),
        inst!("big-16", "permutation n=2^16 t=6", |g: &mut dyn Prng32| {
            permutation(g, 1 << 16, 6)
        }),
        inst!("big-17", "runs-median n=2^20", |g: &mut dyn Prng32| runs_median(g, 1 << 20)),
        inst!("big-18", "runs-up n=2^18", |g: &mut dyn Prng32| runs_up(g, 1 << 18)),
        inst!("big-19", "hamming-weight n=2^20", |g: &mut dyn Prng32| {
            hamming_weight(g, 1 << 20)
        }),
        inst!("big-20", "hamming-correlation n=2^20", |g: &mut dyn Prng32| {
            hamming_correlation(g, 1 << 20)
        }),
        inst!("big-21", "matrix-rank n=4000 L=64", |g: &mut dyn Prng32| {
            matrix_rank(g, 4000, 64)
        }),
        inst!("big-22", "matrix-rank n=400 L=256", |g: &mut dyn Prng32| {
            matrix_rank(g, 400, 256)
        }),
        inst!("big-23", "random-walk m=4096 len=4096", |g: &mut dyn Prng32| {
            random_walk(g, 4096, 4096)
        }),
        inst!("big-24", "autocorrelation n=2^20 lag=1 bit=0", |g: &mut dyn Prng32| {
            autocorrelation(g, 1 << 20, 1, 0)
        }),
        inst!("big-25", "autocorrelation n=2^20 lag=2 bit=31", |g: &mut dyn Prng32| {
            autocorrelation(g, 1 << 20, 2, 31)
        }),
        inst!("big-26", "gap n=2^16 [0,1/64)", |g: &mut dyn Prng32| {
            gap(g, 1 << 16, 0.0, 0.015625)
        }),
        inst!("big-27", "collision n=2^18 k=2^30", |g: &mut dyn Prng32| {
            collision(g, 1 << 18, 30)
        }),
        inst!("big-28", "serial-tuples n=2^20 t=2 bits=8", |g: &mut dyn Prng32| {
            serial_tuples(g, 1 << 20, 2, 8)
        }),
    ];
    v.push(inst!("big-31", "longest-run n=10^4 m=512", |g: &mut dyn Prng32| {
        longest_run(g, 10_000, 512)
    }));
    v.push(inst!("big-32", "sample-mean n=2^15 t=64", |g: &mut dyn Prng32| {
        sample_mean(g, 1 << 15, 64)
    }));
    v.push(inst!("big-33", "spectral n=2^17 bit=31", |g: &mut dyn Prng32| {
        spectral(g, 1 << 17, 31)
    }));
    v.push(inst!("big-34", "spectral n=2^17 bit=0", |g: &mut dyn Prng32| {
        spectral(g, 1 << 17, 0)
    }));
    v.push(
        inst!("big-29", "linear-complexity n=4*10^5 bit=31", |g: &mut dyn Prng32| {
            linear_complexity_test(g, 400_000, 31)
        })
        .analog("BigCrush #80"),
    );
    v.push(
        inst!("big-30", "linear-complexity n=4*10^5 bit=2", |g: &mut dyn Prng32| {
            linear_complexity_test(g, 400_000, 2)
        })
        .analog("BigCrush #81"),
    );
    v
}

pub fn tier_instances(tier: Tier) -> Vec<TestInstance> {
    match tier {
        Tier::Small => small_tier(),
        Tier::Crush => crush_tier(),
        Tier::Big => big_tier(),
    }
}

/// One row of a battery report.
pub struct InstanceReport {
    pub id: String,
    pub name: String,
    pub paper_analog: Option<&'static str>,
    pub result: TestResult,
    pub seconds: f64,
}

/// Full report of one battery run.
pub struct BatteryReport {
    pub tier: Tier,
    pub generator: String,
    pub rows: Vec<InstanceReport>,
}

impl BatteryReport {
    pub fn failures(&self) -> Vec<&InstanceReport> {
        self.rows.iter().filter(|r| r.result.verdict() == Verdict::Fail).collect()
    }

    pub fn suspects(&self) -> Vec<&InstanceReport> {
        self.rows.iter().filter(|r| r.result.verdict() == Verdict::Suspect).collect()
    }

    /// Table 2-style summary: "None" or the failing instance ids
    /// (with TestU01 analogs where defined).
    pub fn table2_cell(&self) -> String {
        let fails = self.failures();
        if fails.is_empty() {
            "None".to_string()
        } else {
            fails
                .iter()
                .map(|f| f.paper_analog.map(|a| a.to_string()).unwrap_or_else(|| f.id.clone()))
                .collect::<Vec<_>>()
                .join(", ")
        }
    }

    /// Machine-readable report via [`crate::util::json`]: tier, generator,
    /// one row per instance (id / name / analog / p-value / verdict /
    /// seconds), and the Table 2 failures cell. Emitted by the CLI's
    /// `battery --stats-json` for the scheduled sweep to archive.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut row = Json::obj();
                row.push("id", Json::Str(r.id.clone()))
                    .push("name", Json::Str(r.name.clone()))
                    .push(
                        "analog",
                        match r.paper_analog {
                            Some(a) => Json::Str(a.to_string()),
                            None => Json::Null,
                        },
                    )
                    .push("p_value", Json::Num(r.result.p_value))
                    .push(
                        "log2_p",
                        match r.result.log2_p {
                            Some(l) => Json::Num(l),
                            None => Json::Null,
                        },
                    )
                    .push(
                        "verdict",
                        Json::Str(
                            match r.result.verdict() {
                                Verdict::Pass => "pass",
                                Verdict::Suspect => "suspect",
                                Verdict::Fail => "fail",
                            }
                            .to_string(),
                        ),
                    )
                    .push("seconds", Json::Num(r.seconds));
                row
            })
            .collect();
        let mut j = Json::obj();
        j.push("tier", Json::Str(self.tier.name().to_string()))
            .push("generator", Json::Str(self.generator.clone()))
            .push("rows", Json::Arr(rows))
            .push("failures", Json::Str(self.table2_cell()));
        j
    }

    pub fn render(&self, verbose: bool) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "battery={} generator={} instances={}\n",
            self.tier.name(),
            self.generator,
            self.rows.len()
        ));
        for r in &self.rows {
            let verdict = match r.result.verdict() {
                Verdict::Pass => "pass",
                Verdict::Suspect => "SUSPECT",
                Verdict::Fail => "FAIL",
            };
            if verbose || verdict != "pass" {
                let analog =
                    r.paper_analog.map(|a| format!(" [{a}]")).unwrap_or_default();
                let log2p = r
                    .result
                    .log2_p
                    .map(|l| format!(" log2p={l:.0}"))
                    .unwrap_or_default();
                out.push_str(&format!(
                    "  {:<10} {:<42} p={:<12.5e}{} {:>8} ({:.2}s)\n",
                    r.id, r.name, r.result.p_value, log2p, format!("{verdict}{analog}"), r.seconds
                ));
            }
        }
        out.push_str(&format!("  => failures: {}\n", self.table2_cell()));
        out
    }
}

/// Run a tier against a generator kind (fresh generator per instance,
/// common seed — instances are independent and parallelisable).
///
/// The battery evaluates the generator's **per-block stream** (a single
/// block/subsequence), which is what paper Table 2 rates: the quality of
/// the algorithm's output sequence. Multi-block *initialisation* quality
/// (paper §4) is probed separately by [`run_battery_interleaved`] and the
/// weak-init ablation, where cross-block correlations show up in the
/// collision/birthday/serial families. (Chunk-interleaved streams would
/// also structurally mask single-stream linearity from Berlekamp–Massey —
/// see `linear_complexity.rs` tests.)
pub fn run_battery(tier: Tier, kind: GeneratorKind, seed: u64) -> BatteryReport {
    use crate::prng::traits::InterleavedStream;
    use crate::prng::{Mt19937, Mtgp, Xorgens, XorgensGp, Xorwow};
    run_battery_with(tier, kind.name(), move || -> Box<dyn Prng32 + Send> {
        match kind {
            GeneratorKind::Xorgens => Box::new(Xorgens::new(seed)),
            GeneratorKind::XorgensGp => Box::new(InterleavedStream::new(XorgensGp::new(seed, 1))),
            GeneratorKind::Mt19937 => Box::new(Mt19937::new(seed as u32)),
            GeneratorKind::Mtgp => Box::new(InterleavedStream::new(Mtgp::new(seed, 1))),
            GeneratorKind::Xorwow => Box::new(Xorwow::new(seed)),
        }
    })
}

/// Run a tier against the `blocks`-way round-interleaved stream — the
/// initialisation-quality probe of paper §4. `weak_init` reproduces the
/// paper's hypothesis for CURAND's failure (consecutive raw seeds without
/// avalanche mixing).
///
/// `fill_threads` routes each instance's stream through the parallel fill
/// engine ([`crate::exec`]); the battery's 4096-word refill chunks sit
/// below the engine's crossover threshold, so this is a correctness knob
/// (the CI oversubscription job pins bit-identical verdicts), not a
/// battery speed-up.
pub fn run_battery_interleaved(
    tier: Tier,
    kind: GeneratorKind,
    seed: u64,
    blocks: usize,
    weak_init: bool,
    fill_threads: usize,
) -> BatteryReport {
    use crate::prng::traits::InterleavedStream;
    use crate::prng::xorwow::XorwowBlock;
    let name = format!("{}[B={blocks}{}]", kind.name(), if weak_init { ",weak-init" } else { "" });
    run_battery_with(tier, &name, move || -> Box<dyn Prng32 + Send> {
        if weak_init {
            assert_eq!(kind, GeneratorKind::Xorwow, "weak-init ablation is XORWOW-specific");
            return Box::new(
                InterleavedStream::new(XorwowBlock::new_weak_init(seed, blocks))
                    .fill_threads(fill_threads),
            );
        }
        match kind {
            GeneratorKind::Xorwow => Box::new(
                InterleavedStream::new(XorwowBlock::new(seed, blocks)).fill_threads(fill_threads),
            ),
            _ => {
                // Boxed generators are BlockParallel themselves (the
                // forwarding impl in prng::traits), so they plug straight
                // into the interleaved adapter.
                let g = crate::prng::make_block_generator(kind, seed, blocks);
                Box::new(InterleavedStream::new(g).fill_threads(fill_threads))
            }
        }
    })
}

/// Run a tier against the round-interleaved merge of `substreams`
/// **exact-jump placed** substreams of `kind`'s master sequence
/// (substream `i` at offset `i · 2^log2_spacing`) — the stream-placement
/// regression probe: the battery's collision / birthday / serial families
/// act as cross-correlation tests on the merged stream, so a placement
/// bug (overlapping or correlated substreams) fails here instead of in a
/// user's simulation.
pub fn run_battery_placed(
    tier: Tier,
    kind: GeneratorKind,
    seed: u64,
    substreams: usize,
    log2_spacing: u32,
    fill_threads: usize,
) -> BatteryReport {
    use crate::prng::place::PlacedMaster;
    use crate::prng::traits::InterleavedStream;
    assert!(substreams >= 1);
    let name = format!("{}[K={substreams},exact-jump:{log2_spacing}]", kind.name());
    // Place once, share the states across instances (the jump engine and
    // per-spacing base polynomial are the expensive part).
    let mut master = PlacedMaster::new(kind, seed);
    let states: Vec<u32> =
        (0..substreams as u64).flat_map(|i| master.state_at(i, log2_spacing)).collect();
    run_battery_with(tier, &name, move || -> Box<dyn Prng32 + Send> {
        // Cold-start straight from the placed states — no throwaway
        // seed-and-warm pass for load_state to overwrite.
        let g = crate::prng::make_block_generator_from_state(kind, substreams, &states);
        Box::new(InterleavedStream::new(g).fill_threads(fill_threads))
    })
}

/// Run a tier against the `blocks`-way **leapfrog** dealing of `kind`'s
/// master sequence ([`crate::prng::place::LeapfrogBlock`]): the virtual
/// blocks deal one sequence round-robin, so the interleaved merge *is*
/// the master sequence and the verdicts probe the dealing machinery, not
/// a different stream. Complements [`run_battery_placed`] (exact-jump)
/// for the weekly placement sweep.
pub fn run_battery_leapfrog(
    tier: Tier,
    kind: GeneratorKind,
    seed: u64,
    blocks: usize,
    fill_threads: usize,
) -> BatteryReport {
    use crate::prng::place::LeapfrogBlock;
    use crate::prng::traits::InterleavedStream;
    assert!(blocks >= 1);
    let name = format!("{}[B={blocks},leapfrog]", kind.name());
    run_battery_with(tier, &name, move || -> Box<dyn Prng32 + Send> {
        let inner = crate::prng::make_block_generator(kind, seed, 1);
        Box::new(
            InterleavedStream::new(LeapfrogBlock::new(inner, blocks)).fill_threads(fill_threads),
        )
    })
}

/// Run a tier against any generator factory.
pub fn run_battery_with(
    tier: Tier,
    gen_name: &str,
    factory: impl Fn() -> Box<dyn Prng32 + Send> + Sync,
) -> BatteryReport {
    let instances = tier_instances(tier);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let mut rows: Vec<Option<InstanceReport>> = Vec::new();
    rows.resize_with(instances.len(), || None);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let rows_mx = std::sync::Mutex::new(&mut rows);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if i >= instances.len() {
                    break;
                }
                let inst = &instances[i];
                let mut g = factory();
                let t0 = Instant::now();
                let result = (inst.run)(g.as_mut());
                let report = InstanceReport {
                    id: inst.id.clone(),
                    name: inst.name.clone(),
                    paper_analog: inst.paper_analog,
                    result,
                    seconds: t0.elapsed().as_secs_f64(),
                };
                rows_mx.lock().unwrap()[i] = Some(report);
            });
        }
    });
    BatteryReport {
        tier,
        generator: gen_name.to_string(),
        rows: rows.into_iter().map(|r| r.expect("instance not run")).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiers_have_expected_shape() {
        assert_eq!(small_tier().len(), 12);
        assert!(crush_tier().len() >= 26);
        assert!(big_tier().len() >= 30);
        // The discriminating instances carry their paper analogs.
        let crush = crush_tier();
        let analogs: Vec<_> = crush.iter().filter_map(|i| i.paper_analog).collect();
        assert_eq!(analogs, vec!["Crush #71", "Crush #72"]);
        let big = big_tier();
        let analogs: Vec<_> = big.iter().filter_map(|i| i.paper_analog).collect();
        assert_eq!(analogs, vec!["BigCrush #80", "BigCrush #81"]);
    }

    #[test]
    fn ids_unique() {
        for tier in Tier::ALL {
            let mut ids: Vec<String> = tier_instances(tier).iter().map(|i| i.id.clone()).collect();
            let n = ids.len();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), n, "{tier:?}");
        }
    }

    #[test]
    fn smallcrush_xorgensgp_passes() {
        let report = run_battery(Tier::Small, GeneratorKind::XorgensGp, 20260710);
        assert_eq!(report.failures().len(), 0, "{}", report.render(true));
    }

    #[test]
    fn tier_parses_via_fromstr_with_typed_error() {
        for tier in Tier::ALL {
            assert_eq!(Tier::parse(tier.name()), Some(tier));
            assert_eq!(tier.name().parse::<Tier>(), Ok(tier));
        }
        assert_eq!("small".parse::<Tier>(), Ok(Tier::Small));
        assert_eq!("BIG".parse::<Tier>(), Ok(Tier::Big));
        let err = "huge".parse::<Tier>().unwrap_err();
        assert_eq!(err.what, "battery tier");
        assert!(err.to_string().contains("\"huge\""), "{err}");
        assert_eq!(Tier::parse("huge"), None);
    }

    #[test]
    fn report_serializes_to_json() {
        let report = BatteryReport {
            tier: Tier::Small,
            generator: "demo".into(),
            rows: vec![InstanceReport {
                id: "small-01".into(),
                name: "demo instance".into(),
                paper_analog: Some("Crush #71"),
                result: TestResult::new("demo", "n=1", 0.0, 1e-12, 1),
                seconds: 0.25,
            }],
        };
        let s = report.to_json().to_string();
        assert!(s.contains("\"tier\":\"smallcrush\""), "{s}");
        assert!(s.contains("\"generator\":\"demo\""), "{s}");
        assert!(s.contains("\"id\":\"small-01\""), "{s}");
        assert!(s.contains("\"analog\":\"Crush #71\""), "{s}");
        assert!(s.contains("\"verdict\":\"fail\""), "{s}");
        assert!(s.contains("\"failures\":\"Crush #71\""), "{s}");
        assert!(s.contains("\"log2_p\":null"), "{s}");
    }

    #[test]
    fn leapfrog_battery_matches_master_stream_naming() {
        // One leapfrog instance: the merged stream IS the master sequence,
        // so the verdicts match run_battery's per-block stream for a
        // B=1-equivalent deal. Just pin the cheap structural bits here —
        // the statistical equivalence is covered by prng::place tests.
        let report =
            run_battery_leapfrog(Tier::Small, GeneratorKind::Xorwow, 20260710, 4, 1);
        assert_eq!(report.generator, "xorwow[B=4,leapfrog]");
        assert_eq!(report.rows.len(), small_tier().len());
        assert_eq!(report.failures().len(), 0, "{}", report.render(true));
    }

    #[test]
    fn smallcrush_placed_xorwow_passes() {
        // 4 exact-jump substreams, 2^48 apart, merged round-robin: the
        // cross-correlation families must see nothing (the substreams are
        // disjoint spans of one healthy sequence).
        let report = run_battery_placed(Tier::Small, GeneratorKind::Xorwow, 20260710, 4, 48, 1);
        assert_eq!(report.failures().len(), 0, "{}", report.render(true));
    }
}

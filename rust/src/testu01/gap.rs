//! Gap test (Knuth; TestU01 `sknuth_Gap`).
//!
//! Record the gaps between successive visits of `u ∈ [alpha, beta)`; gap
//! lengths are geometric(p = beta − alpha). Chi-square over gap-length
//! buckets `0..t` plus a tail bucket.

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::chi2_test;

pub fn gap(rng: &mut dyn Prng32, n_gaps: usize, alpha: f64, beta: f64) -> TestResult {
    assert!((0.0..1.0).contains(&alpha) && alpha < beta && beta <= 1.0);
    let mut rng = ChunkedRng::new(rng);
    let p = beta - alpha;
    // Bucket count: keep expected tail >= ~8 observations.
    let t = (((8.0 / n_gaps as f64).ln() / (1.0 - p).ln()).floor() as usize).clamp(4, 64);
    let mut counts = vec![0u64; t + 1];
    let mut gap_len = 0usize;
    let mut found = 0usize;
    // Cap total draws defensively (expected n_gaps / p).
    let max_draws = (n_gaps as f64 / p * 20.0) as u64;
    while found < n_gaps && rng.count < max_draws {
        let u = rng.next_f64();
        if u >= alpha && u < beta {
            counts[gap_len.min(t)] += 1;
            found += 1;
            gap_len = 0;
        } else {
            gap_len += 1;
        }
    }
    let mut expected = vec![0.0f64; t + 1];
    for (j, e) in expected.iter_mut().enumerate().take(t) {
        *e = n_gaps as f64 * p * (1.0 - p).powi(j as i32);
    }
    expected[t] = n_gaps as f64 * (1.0 - p).powi(t as i32);
    let (stat, pv) = chi2_test(&counts, &expected);
    TestResult::new(
        "gap",
        format!("n={n_gaps} [{alpha},{beta}) t={t}"),
        stat,
        pv,
        rng.count,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xorgens;

    #[test]
    fn good_generator_passes() {
        let r = gap(&mut Xorgens::new(8), 1 << 12, 0.0, 0.125);
        assert!(!r.is_fail(), "p={}", r.p_value);
    }

    /// Perfectly periodic visits have constant gaps -> chi2 explodes.
    #[test]
    fn periodic_fails() {
        struct Period8(u32);
        impl Prng32 for Period8 {
            fn next_u32(&mut self) -> u32 {
                self.0 = self.0.wrapping_add(1);
                if self.0 % 8 == 0 {
                    0 // u = 0.0 -> inside [0, 0.125)
                } else {
                    u32::MAX // u ~ 1.0 -> outside
                }
            }
            fn name(&self) -> &'static str {
                "period8"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                3.0
            }
        }
        let r = gap(&mut Period8(0), 1 << 12, 0.0, 0.125);
        assert!(r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn expected_counts_sum_to_n() {
        // Internal consistency: geometric bucket probabilities sum to 1.
        let n = 4096.0;
        let p = 0.125;
        let t = 20;
        let mut sum = 0.0;
        for j in 0..t {
            sum += p * (1.0f64 - p).powi(j);
        }
        sum += (1.0f64 - p).powi(t);
        assert!((sum - 1.0).abs() < 1e-12);
        let _ = n;
    }
}

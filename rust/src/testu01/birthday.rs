//! Birthday spacings (Marsaglia; TestU01 `smarsa_BirthdaySpacings`).
//!
//! Throw `n` "birthdays" uniformly into `d = 2^bits` days (cells built from
//! `t` consecutive draws), sort them, and count collisions among the sorted
//! *spacings*. Under the null the collision count is ~Poisson with
//! λ = n³ / (4d). Lattice-structured generators (LCGs etc.) fail hard;
//! good generators give two-sided Poisson p-values.

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::poisson_two_sided_p;

/// One birthday-spacings run.
///
/// `bits_total` ≤ 63 is the log2 of the number of days; each birthday uses
/// `ceil(bits_total / 32)` draws.
pub fn birthday_spacings(rng: &mut dyn Prng32, n: usize, bits_total: u32) -> TestResult {
    assert!(bits_total <= 63);
    let mut rng = ChunkedRng::new(rng);
    let lambda = (n as f64).powi(3) / (4.0 * 2f64.powi(bits_total as i32));
    let mut days: Vec<u64> = Vec::with_capacity(n);
    for _ in 0..n {
        let v = if bits_total > 32 {
            let hi = rng.next_u32() as u64;
            let lo = rng.next_u32() as u64;
            ((hi << 32) | lo) >> (64 - bits_total)
        } else {
            (rng.next_u32() >> (32 - bits_total)) as u64
        };
        days.push(v);
    }
    days.sort_unstable();
    let mut spacings: Vec<u64> = days.windows(2).map(|w| w[1] - w[0]).collect();
    spacings.sort_unstable();
    let collisions = spacings.windows(2).filter(|w| w[0] == w[1]).count() as u64;
    let p = poisson_two_sided_p(collisions, lambda);
    TestResult::new(
        "birthday-spacings",
        format!("n={n} d=2^{bits_total} lambda={lambda:.2}"),
        collisions as f64,
        p,
        rng.count,
    )
    .folded()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xorgens;

    #[test]
    fn good_generator_passes() {
        let mut g = Xorgens::new(11);
        let r = birthday_spacings(&mut g, 1 << 12, 34);
        assert!(!r.is_fail(), "p={}", r.p_value);
        assert!(r.consumed >= 2 * (1 << 12));
    }

    /// A counter (maximally regular spacings) must fail catastrophically.
    #[test]
    fn counter_fails() {
        struct Ramp(u32);
        impl Prng32 for Ramp {
            fn next_u32(&mut self) -> u32 {
                self.0 = self.0.wrapping_add(1 << 16);
                self.0
            }
            fn name(&self) -> &'static str {
                "ramp"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                16.0
            }
        }
        let mut g = Ramp(0);
        let r = birthday_spacings(&mut g, 1 << 12, 34);
        // All spacings equal -> collisions ≈ n, p ~ 0.
        assert!(r.is_fail(), "p={} collisions={}", r.p_value, r.statistic);
    }

    #[test]
    fn lambda_scaling_sane() {
        // n=2^12, d=2^34: lambda = 2^36/2^36 = 1.
        let mut g = Xorgens::new(5);
        let r = birthday_spacings(&mut g, 1 << 12, 34);
        assert!(r.params.contains("lambda=1.00"), "{}", r.params);
    }
}

//! Coupon collector test (Knuth; TestU01 `sknuth_CouponCollector`).
//!
//! Draw values in `0..d` until all `d` are seen; the segment length `T`
//! has an exactly computable distribution (Markov chain on the number of
//! distinct coupons). Chi-square over `T ∈ {d, .., tmax}` + tail.

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::chi2_test;

/// Exact P(T = t) for t in d..=tmax, plus P(T > tmax) appended.
pub fn coupon_length_pmf(d: usize, tmax: usize) -> Vec<f64> {
    // dp[s] = P(s distinct seen) after k draws.
    let mut dp = vec![0.0f64; d + 1];
    dp[0] = 1.0;
    let mut pmf = vec![0.0; tmax - d + 2];
    let mut absorbed = 0.0;
    for k in 1..=tmax {
        let mut next = vec![0.0f64; d + 1];
        for s in 0..d {
            if dp[s] == 0.0 {
                continue;
            }
            let p_new = (d - s) as f64 / d as f64;
            next[s + 1] += dp[s] * p_new;
            next[s] += dp[s] * (1.0 - p_new);
        }
        if k >= d {
            pmf[k - d] = next[d]; // probability of completing exactly at k
            absorbed += next[d];
        }
        next[d] = 0.0; // restart chains that completed (we only track one segment)
        dp = next;
    }
    *pmf.last_mut().unwrap() = 1.0 - absorbed; // tail
    pmf
}

pub fn coupon_collector(rng: &mut dyn Prng32, n_segments: usize, d: usize) -> TestResult {
    assert!(d >= 2 && d <= 64);
    let mut rng = ChunkedRng::new(rng);
    // tmax: keep expected tail >= ~5.
    let mut tmax = d * 3;
    let mut pmf = coupon_length_pmf(d, tmax);
    while *pmf.last().unwrap() * n_segments as f64 > 5.0 && tmax < d * 30 {
        tmax += d;
        pmf = coupon_length_pmf(d, tmax);
    }
    let mut counts = vec![0u64; pmf.len()];
    for _ in 0..n_segments {
        let mut seen = 0u64;
        let mut distinct = 0;
        let mut t = 0usize;
        while distinct < d && t < 100 * d {
            let v = (rng.next_u32() as u64 * d as u64 >> 32) as usize;
            t += 1;
            if seen >> v & 1 == 0 {
                seen |= 1 << v;
                distinct += 1;
            }
        }
        let idx = if t <= tmax { t - d } else { pmf.len() - 1 };
        counts[idx] += 1;
    }
    // Merge low-expectation buckets from the front (T=d is rare for big d).
    let expected: Vec<f64> = pmf.iter().map(|p| p * n_segments as f64).collect();
    let (counts, expected) = merge_small_buckets(&counts, &expected, 5.0);
    let (stat, pv) = chi2_test(&counts, &expected);
    TestResult::new(
        "coupon-collector",
        format!("n={n_segments} d={d} tmax={tmax}"),
        stat,
        pv,
        rng.count,
    )
}

/// Merge adjacent buckets until every expected count >= min_e.
pub fn merge_small_buckets(counts: &[u64], expected: &[f64], min_e: f64) -> (Vec<u64>, Vec<f64>) {
    let mut mc = Vec::new();
    let mut me = Vec::new();
    let (mut acc_c, mut acc_e) = (0u64, 0.0f64);
    for (&c, &e) in counts.iter().zip(expected) {
        acc_c += c;
        acc_e += e;
        if acc_e >= min_e {
            mc.push(acc_c);
            me.push(acc_e);
            acc_c = 0;
            acc_e = 0.0;
        }
    }
    if acc_e > 0.0 {
        if let (Some(lc), Some(le)) = (mc.last_mut(), me.last_mut()) {
            *lc += acc_c;
            *le += acc_e;
        } else {
            mc.push(acc_c);
            me.push(acc_e);
        }
    }
    (mc, me)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xorgens;

    #[test]
    fn pmf_sums_to_one() {
        let pmf = coupon_length_pmf(8, 60);
        let sum: f64 = pmf.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12, "sum={sum}");
        // Mean of T should be d * H_d ≈ 8 * 2.7179 ≈ 21.7.
        let mean: f64 = pmf
            .iter()
            .enumerate()
            .take(pmf.len() - 1)
            .map(|(i, p)| (i + 8) as f64 * p)
            .sum::<f64>()
            + pmf.last().unwrap() * 61.0;
        assert!((mean - 21.74).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn good_generator_passes() {
        let r = coupon_collector(&mut Xorgens::new(2), 2000, 8);
        assert!(!r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn cyclic_generator_fails() {
        // Emits 0,1,..,7 cyclically: every segment completes in exactly d.
        struct Cycle(u32);
        impl Prng32 for Cycle {
            fn next_u32(&mut self) -> u32 {
                self.0 = (self.0 + 1) % 8;
                self.0 << 29
            }
            fn name(&self) -> &'static str {
                "cycle"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                3.0
            }
        }
        let r = coupon_collector(&mut Cycle(0), 2000, 8);
        assert!(r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn merge_small_buckets_works() {
        let counts = vec![1u64, 2, 3, 100, 4];
        let expected = vec![1.0, 2.0, 3.0, 100.0, 4.0];
        let (c, e) = merge_small_buckets(&counts, &expected, 5.0);
        assert_eq!(c.iter().sum::<u64>(), 110);
        assert!((e.iter().sum::<f64>() - 110.0).abs() < 1e-12);
        assert!(e.iter().all(|&x| x >= 5.0));
    }
}

//! Permutation test (Knuth; TestU01 `sknuth_Permutation`).
//!
//! The relative order of `t` consecutive uniforms is one of `t!` equally
//! likely permutations. Chi-square over the factorial-number-system index.

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::chi2_test;

/// Lehmer/factorial index of the order pattern of `vals` (0..t!-1).
pub fn permutation_index(vals: &[f64]) -> usize {
    let t = vals.len();
    let mut idx = 0usize;
    for i in 0..t {
        let rank = vals[i + 1..].iter().filter(|&&v| v < vals[i]).count();
        idx = idx * (t - i) + rank;
    }
    idx
}

pub fn permutation(rng: &mut dyn Prng32, n_groups: usize, t: usize) -> TestResult {
    assert!((2..=8).contains(&t));
    let mut rng = ChunkedRng::new(rng);
    let tfact: usize = (1..=t).product();
    let mut counts = vec![0u64; tfact];
    let mut vals = vec![0.0f64; t];
    for _ in 0..n_groups {
        for v in vals.iter_mut() {
            *v = rng.next_f64();
        }
        counts[permutation_index(&vals)] += 1;
    }
    let expected = vec![n_groups as f64 / tfact as f64; tfact];
    let (stat, p) = chi2_test(&counts, &expected);
    TestResult::new("permutation", format!("n={n_groups} t={t}"), stat, p, rng.count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xorgens;

    #[test]
    fn index_bijective_for_t3() {
        // All 6 orderings of distinct values map to distinct indices.
        let perms: [[f64; 3]; 6] = [
            [0.1, 0.2, 0.3],
            [0.1, 0.3, 0.2],
            [0.2, 0.1, 0.3],
            [0.3, 0.1, 0.2],
            [0.2, 0.3, 0.1],
            [0.3, 0.2, 0.1],
        ];
        let mut seen = std::collections::HashSet::new();
        for p in &perms {
            let idx = permutation_index(p);
            assert!(idx < 6);
            assert!(seen.insert(idx), "duplicate index {idx}");
        }
    }

    #[test]
    fn good_generator_passes() {
        let r = permutation(&mut Xorgens::new(13), 12_000, 4);
        assert!(!r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn sorted_output_fails() {
        struct Saw(u32);
        impl Prng32 for Saw {
            fn next_u32(&mut self) -> u32 {
                self.0 = (self.0 + 1) % 16;
                self.0 << 28
            }
            fn name(&self) -> &'static str {
                "saw"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                4.0
            }
        }
        let r = permutation(&mut Saw(0), 12_000, 4);
        assert!(r.is_fail(), "p={}", r.p_value);
    }
}

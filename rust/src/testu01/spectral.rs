//! Spectral (discrete Fourier) test — NIST SP 800-22 §2.6 relative.
//!
//! Map bits to ±1, take the DFT magnitude spectrum of the first half, and
//! count peaks below the 95% threshold `sqrt(ln(1/0.05) n)`; the count is
//! ~N(0.95 n/2, n·0.95·0.05/4) under the null. Detects periodic features
//! that the time-domain tests miss.
//!
//! The radix-2 FFT lives here too (no external crates — see DESIGN.md
//! §Build-environment): iterative Cooley–Tukey over `(f64, f64)` pairs.

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::normal_two_sided_p;

/// In-place iterative radix-2 Cooley–Tukey FFT on interleaved (re, im).
pub fn fft_in_place(re: &mut [f64], im: &mut [f64]) {
    let n = re.len();
    assert!(n.is_power_of_two() && n == im.len());
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 0..n {
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
        let mut m = n >> 1;
        while m >= 1 && j & m != 0 {
            j ^= m;
            m >>= 1;
        }
        j |= m;
    }
    // Butterflies.
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        for start in (0..n).step_by(len) {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ar, ai) = (re[start + k], im[start + k]);
                let (br, bi) = (re[start + k + len / 2], im[start + k + len / 2]);
                let (tr, ti) = (br * cr - bi * ci, br * ci + bi * cr);
                re[start + k] = ar + tr;
                im[start + k] = ai + ti;
                re[start + k + len / 2] = ar - tr;
                im[start + k + len / 2] = ai - ti;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
        }
        len <<= 1;
    }
}

/// The spectral test over `n` bits (power of two) from bit `bit`.
pub fn spectral(rng: &mut dyn Prng32, n: usize, bit: u32) -> TestResult {
    assert!(n.is_power_of_two() && bit < 32);
    let mut rng = ChunkedRng::new(rng);
    let mut words = vec![0u32; n];
    rng.fill_u32(&mut words);
    let mut re: Vec<f64> =
        words.iter().map(|w| if (w >> bit) & 1 == 1 { 1.0 } else { -1.0 }).collect();
    drop(words);
    let mut im = vec![0.0f64; n];
    fft_in_place(&mut re, &mut im);
    let threshold = ((1.0f64 / 0.05).ln() * n as f64).sqrt();
    let half = n / 2;
    let below = re[..half]
        .iter()
        .zip(&im[..half])
        .filter(|(r, i)| (*r * *r + *i * *i).sqrt() < threshold)
        .count() as f64;
    let expect = 0.95 * half as f64;
    let var = n as f64 * 0.95 * 0.05 / 4.0;
    let z = (below - expect) / var.sqrt();
    TestResult::new("spectral", format!("n={n} bit={bit}"), z, normal_two_sided_p(z), rng.count)
        .folded()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Xorgens, Xorwow};

    #[test]
    fn fft_matches_naive_dft() {
        let n = 64;
        let mut x = 77u64;
        let sig: Vec<f64> = (0..n)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 32) as f64 / 4e9 - 0.5
            })
            .collect();
        let mut re = sig.clone();
        let mut im = vec![0.0; n];
        fft_in_place(&mut re, &mut im);
        for k in 0..n {
            let (mut sr, mut si) = (0.0f64, 0.0f64);
            for (t, &v) in sig.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
                sr += v * ang.cos();
                si += v * ang.sin();
            }
            assert!((re[k] - sr).abs() < 1e-9 && (im[k] - si).abs() < 1e-9, "bin {k}");
        }
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut re = vec![0.0; 16];
        let mut im = vec![0.0; 16];
        re[0] = 1.0;
        fft_in_place(&mut re, &mut im);
        for k in 0..16 {
            assert!((re[k] - 1.0).abs() < 1e-12 && im[k].abs() < 1e-12);
        }
    }

    #[test]
    fn good_generators_pass() {
        let r = spectral(&mut Xorgens::new(33), 1 << 14, 31);
        assert!(!r.is_fail(), "xorgens p={}", r.p_value);
        let r = spectral(&mut Xorwow::new(33), 1 << 14, 31);
        assert!(!r.is_fail(), "xorwow p={}", r.p_value);
    }

    #[test]
    fn periodic_signal_fails() {
        // Strong period-8 structure in the tested bit.
        struct Period8(u32);
        impl Prng32 for Period8 {
            fn next_u32(&mut self) -> u32 {
                self.0 = self.0.wrapping_add(1);
                if self.0 % 8 < 6 {
                    0x8000_0000
                } else {
                    0
                }
            }
            fn name(&self) -> &'static str {
                "period8"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                3.0
            }
        }
        let r = spectral(&mut Period8(0), 1 << 12, 31);
        assert!(r.is_fail(), "p={}", r.p_value);
    }
}

//! Collision test (Knuth; TestU01 `sknuth_Collision`).
//!
//! Throw `n` balls into `k = 2^bits` urns with `n ≪ k`; the number of times
//! a ball lands in an occupied urn is ~Poisson with λ ≈ n²/(2k).

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::poisson_two_sided_p;

pub fn collision(rng: &mut dyn Prng32, n: usize, bits: u32) -> TestResult {
    assert!(bits <= 32);
    let mut rng = ChunkedRng::new(rng);
    let k = 1u64 << bits;
    let lambda = (n as f64) * (n as f64) / (2.0 * k as f64);
    let mut occupied = vec![0u64; (k as usize).div_ceil(64)];
    let mut collisions = 0u64;
    for _ in 0..n {
        let cell = (rng.next_u32() >> (32 - bits)) as usize;
        let (w, b) = (cell / 64, cell % 64);
        if occupied[w] >> b & 1 == 1 {
            collisions += 1;
        } else {
            occupied[w] |= 1 << b;
        }
    }
    let p = poisson_two_sided_p(collisions, lambda);
    TestResult::new(
        "collision",
        format!("n={n} k=2^{bits} lambda={lambda:.2}"),
        collisions as f64,
        p,
        rng.count,
    )
    .folded()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Mt19937, Xorgens, Xorwow};

    #[test]
    fn good_generators_pass() {
        let r = collision(&mut Xorgens::new(3), 1 << 13, 24);
        assert!(!r.is_fail(), "xorgens p={}", r.p_value);
        let r = collision(&mut Mt19937::new(3), 1 << 13, 24);
        assert!(!r.is_fail(), "mt p={}", r.p_value);
        let r = collision(&mut Xorwow::new(3), 1 << 13, 24);
        assert!(!r.is_fail(), "xorwow p={}", r.p_value);
    }

    /// A generator stuck on few values collides constantly.
    #[test]
    fn degenerate_fails() {
        struct Stuck(u32);
        impl Prng32 for Stuck {
            fn next_u32(&mut self) -> u32 {
                self.0 ^= 0x80000000;
                self.0
            }
            fn name(&self) -> &'static str {
                "stuck"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let r = collision(&mut Stuck(7), 1 << 13, 24);
        assert!(r.is_fail());
    }
}

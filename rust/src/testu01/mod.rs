//! "crushr" — a from-scratch TestU01-style statistical battery (paper §1.2).
//!
//! TestU01 (L'Ecuyer & Simard 2007) is a C library and not reproducible
//! here offline; this module implements the same *methodology*: a battery
//! of tests, each computing a statistic with a known null distribution over
//! uniform i.i.d. input and reporting a p-value; a generator **fails** a
//! test when the p-value is astronomically small (the paper's "of the order
//! 10^-10") or equally close to 1.
//!
//! Three tiers mirror SmallCrush / Crush / BigCrush at reduced sample
//! sizes (this is a CPU reproduction; TestU01's BigCrush consumes ~2^38
//! draws and runs for hours). Crucially the tiers preserve the
//! *discriminating structure* of paper Table 2: the Crush and BigCrush
//! tiers include the two linear-complexity instances (high bit / low bit —
//! TestU01's `r = 0` and `r = 29` parameters) that separate the three
//! generators; see `linear_complexity.rs` for the analysis.

pub mod battery;
pub mod suite;

pub mod autocorrelation;
pub mod birthday;
pub mod collision;
pub mod coupon;
pub mod gap;
pub mod hamming;
pub mod linear_complexity;
pub mod longest_run;
pub mod matrix_rank;
pub mod maxoft;
pub mod permutation;
pub mod poker;
pub mod random_walk;
pub mod runs;
pub mod sample_mean;
pub mod serial;
pub mod spectral;

pub use battery::{run_battery, BatteryReport, Tier};
pub use suite::{TestInstance, TestResult};

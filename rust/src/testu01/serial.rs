//! Serial (pairs) test — chi-square on non-overlapping `t`-tuples of
//! high bits (TestU01 `smultin_MultinomialBits` relative).

use super::suite::{ChunkedRng, TestResult};
use crate::prng::Prng32;
use crate::util::stats::chi2_test;

/// Non-overlapping `t`-tuples, `bits` top bits per value: `2^(bits·t)` cells.
pub fn serial_tuples(rng: &mut dyn Prng32, n_tuples: usize, t: usize, bits: u32) -> TestResult {
    assert!(t >= 1 && (bits as usize) * t <= 24, "cell table must fit memory");
    let mut rng = ChunkedRng::new(rng);
    let cells = 1usize << (bits as usize * t);
    let mut counts = vec![0u64; cells];
    for _ in 0..n_tuples {
        let mut idx = 0usize;
        for _ in 0..t {
            idx = (idx << bits) | (rng.next_u32() >> (32 - bits)) as usize;
        }
        counts[idx] += 1;
    }
    let expected = vec![n_tuples as f64 / cells as f64; cells];
    let (stat, p) = chi2_test(&counts, &expected);
    TestResult::new(
        "serial-tuples",
        format!("n={n_tuples} t={t} bits={bits}"),
        stat,
        p,
        rng.count,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Xorgens, Xorwow};

    #[test]
    fn good_generators_pass() {
        let r = serial_tuples(&mut Xorgens::new(12), 1 << 16, 2, 6);
        assert!(!r.is_fail(), "p={}", r.p_value);
        let r = serial_tuples(&mut Xorwow::new(12), 1 << 16, 2, 6);
        assert!(!r.is_fail(), "p={}", r.p_value);
    }

    #[test]
    fn correlated_pairs_fail() {
        // Every second output repeats the previous one: pairs land on the
        // diagonal cells only.
        struct Echo {
            inner: Xorgens,
            last: u32,
            flip: bool,
        }
        impl Prng32 for Echo {
            fn next_u32(&mut self) -> u32 {
                self.flip = !self.flip;
                if self.flip {
                    self.last = self.inner.next_u32();
                }
                self.last
            }
            fn name(&self) -> &'static str {
                "echo"
            }
            fn state_words(&self) -> usize {
                1
            }
            fn period_log2(&self) -> f64 {
                1.0
            }
        }
        let mut e = Echo { inner: Xorgens::new(3), last: 0, flip: false };
        let r = serial_tuples(&mut e, 1 << 14, 2, 6);
        assert!(r.is_fail(), "p={}", r.p_value);
    }
}

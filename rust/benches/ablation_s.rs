//! Ablation of the paper's §2 design choice: the tap `s` controls the
//! intra-block parallel degree `min(s, r−s)`; the paper picks `s = 65 =
//! r/2 + 1` (gcd(r, s) = 1 forbids r/2 exactly). This bench sweeps valid
//! `s` values for r = 128 and reports:
//!
//!   * the parallel degree (the paper's analytical claim),
//!   * measured lockstep throughput of the block engine at that `s`
//!     (smaller lanes -> more rounds + more sync overhead per output),
//!   * modeled GPU throughput via the device model's sync amortisation.
//!
//! Also regenerates the §4 ablation: per-block parameter tables vs one
//! shared set (occupancy cost on both paper devices).
//!
//!   cargo bench --bench ablation_s

use xorgens_gp::device::{occupancy, predict_rn_per_sec, GeneratorKernelProfile, GTX_295, GTX_480};
use xorgens_gp::prng::params::XorgensParams;
use xorgens_gp::prng::traits::InterleavedStream;
use xorgens_gp::prng::{BlockParallel, Prng32, XorgensGp};
use xorgens_gp::util::bench::{black_box, Bencher};

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(4);
    println!("=== §2 ablation: tap position s vs parallel degree and throughput (r=128) ===\n");
    println!(
        "{:>5} {:>14} {:>16} {:>16} {:>16} {:>8} {:>20} {:>20}",
        "s",
        "min(s,r-s)",
        "bulk RN/s",
        &format!("bulk {threads}T RN/s"),
        "scalar RN/s",
        "speedup",
        "GTX480 model RN/s",
        "GTX295 model RN/s"
    );
    // Valid s: gcd(128, s) = 1 -> odd s. Sweep representative values.
    let bencher = Bencher::with_budget(100, 600);
    for s in [1usize, 5, 15, 33, 47, 63, 65, 95, 111, 127] {
        let params = XorgensParams { s, ..XorgensParams::GP_4096 };
        params.validate().expect("odd s < r is valid");
        let lane = params.parallel_degree();
        // Bulk-fill throughput of the block engine with this parameter set.
        let mut gen = XorgensGp::with_params(1, 64, params);
        let mut buf = vec![0u32; 1 << 16];
        let result = bencher.run(&format!("s={s}"), buf.len() as f64, || {
            gen.fill_interleaved(&mut buf);
            black_box(buf[0]);
        });
        // Same fill through the parallel fill engine (the 64 blocks split
        // across workers; 2^16 words sits above the crossover threshold).
        let threaded = bencher.run(&format!("s={s}-{threads}t"), buf.len() as f64, || {
            gen.fill_interleaved_threaded(threads, &mut buf);
            black_box(buf[0]);
        });
        // Per-call scalar throughput through the interleaved adapter (the
        // pre-bulk-engine access pattern) for the speedup column.
        let mut st = InterleavedStream::new(XorgensGp::with_params(1, 64, params));
        let n_scalar = 1 << 16;
        let scalar = bencher.run(&format!("s={s}-scalar"), n_scalar as f64, || {
            let mut acc = 0u32;
            for _ in 0..n_scalar {
                acc = acc.wrapping_add(st.next_u32());
            }
            black_box(acc);
        });
        // Device model: lane width changes the sync amortisation.
        let mut prof = GeneratorKernelProfile::xorgens_gp();
        prof.syncs = 1.0 / lane as f64;
        prof.resources.threads_per_block = (lane as u32 + 1).next_multiple_of(32).max(32);
        let p480 = predict_rn_per_sec(&GTX_480, &prof);
        let p295 = predict_rn_per_sec(&GTX_295, &prof);
        let marker = if s == 65 { "  <- paper's choice" } else { "" };
        println!(
            "{:>5} {:>14} {:>16.3e} {:>16.3e} {:>16.3e} {:>7.2}x {:>20.3e} {:>20.3e}{}",
            s,
            lane,
            result.rate(),
            threaded.rate(),
            scalar.rate(),
            result.rate() / scalar.rate(),
            p480,
            p295,
            marker
        );
    }
    println!(
        "\nReading: min(s, r-s) peaks at s = 63/65 (63 lanes). On the modeled GPUs the \
         sync amortisation makes small-lane configurations sharply slower — the paper's \
         s = r/2 ± 1 rule. CPU lockstep bulk throughput is flatter (no barrier cost); \
         the scalar column shows the per-draw dispatch overhead the bulk engine removes."
    );

    println!("\n=== §4 ablation: shared vs per-block parameter sets ===\n");
    let shared = GeneratorKernelProfile::xorgens_gp().resources;
    let mut perblock = shared;
    perblock.shared_mem_per_block += 1024; // MTGP-style parameter tables
    perblock.registers_per_thread += 4;
    for dev in [&GTX_480, &GTX_295] {
        let a = occupancy(dev, &shared);
        let b = occupancy(dev, &perblock);
        println!(
            "{:<18} shared: occ={:.2} ({} blocks/MP) | per-block: occ={:.2} ({} blocks/MP)",
            dev.name, a.fraction, a.blocks_per_mp, b.fraction, b.blocks_per_mp
        );
    }
    println!(
        "\nReading: the per-block-parameter variant costs occupancy (and §4 reports no \
         quality gain) — why xorgensGP ships one shared parameter set."
    );
}

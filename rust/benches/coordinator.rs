//! Coordinator/serving benchmarks: request latency and throughput vs
//! draw size, batching effectiveness, backend comparison (pure Rust vs
//! PJRT AOT artifacts), and blocking-vs-pipelined client API. This is the
//! paper's headline-throughput claim translated to the serving layer of
//! this reproduction.
//!
//!   cargo bench --bench coordinator

use std::collections::VecDeque;
use std::time::Instant;
use xorgens_gp::coordinator::{BackendKind, Coordinator, CoordinatorConfig};
use xorgens_gp::prng::{make_block_generator, GeneratorKind};

fn bench_backend(backend: BackendKind, label: &str) {
    if backend == BackendKind::Pjrt
        && !xorgens_gp::runtime::default_dir().join("manifest.txt").exists()
    {
        println!("{label}: skipped (artifacts not built)");
        return;
    }
    println!("--- {label} ---");
    println!(
        "{:>10} {:>8} {:>14} {:>12} {:>12}",
        "draw n", "clients", "RN/s", "mean lat", "p99 lat"
    );
    for &(n, clients) in
        &[(1024usize, 1usize), (65_536, 1), (262_144, 1), (65_536, 8), (262_144, 8)]
    {
        let coord = Coordinator::new(CoordinatorConfig::default());
        let draws = (64 * (1 << 20) / n / clients).max(4); // ~64M numbers total
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let coord = &coord;
                scope.spawn(move || {
                    let s = coord
                        .builder(&format!("bench-{c}"))
                        .backend(backend)
                        .u32()
                        .expect("stream");
                    let mut buf = vec![0u32; n];
                    for _ in 0..draws {
                        s.draw_into(&mut buf).expect("draw");
                    }
                });
            }
        });
        let dt = t0.elapsed().as_secs_f64();
        let m = coord.metrics();
        println!(
            "{:>10} {:>8} {:>14.3e} {:>10.0}us {:>10.0}us",
            n,
            clients,
            m.numbers_served as f64 / dt,
            m.mean_latency_us,
            m.p99_latency_us
        );
    }
}

/// Blocking draw_into vs pipelined submit/wait_into at increasing queue
/// depth, one client, one stream. Depth 1 *is* the blocking pattern
/// (strictly alternating client-wait / worker-generate); deeper queues
/// keep `depth` requests in flight, so the worker generates while the
/// client consumes — the win is the overlap. The reply path allocates
/// nothing at steady state: every reply buffer is recycled by `wait_into`
/// and reused by the worker (pool_hits ≈ requests after warm-up).
fn bench_pipelined() {
    println!("--- pipelined submit/wait_into vs blocking (rust backend) ---");
    println!(
        "{:>6} {:>14} {:>12} {:>12} {:>22}",
        "depth", "RN/s", "mean lat", "p99 lat", "pool hit/miss"
    );
    let n = 1 << 18;
    let total = 128usize << 20;
    let draws = total / n;
    for &depth in &[1usize, 2, 4, 8] {
        let coord = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
        let s = coord.builder("pipe").u32().expect("stream");
        let mut buf = vec![0u32; n];
        let mut inflight = VecDeque::new();
        let t0 = Instant::now();
        for _ in 0..draws {
            while inflight.len() >= depth {
                inflight.pop_front().unwrap().wait_into(&mut buf).expect("draw");
            }
            inflight.push_back(s.submit(n).expect("submit"));
        }
        for t in inflight {
            t.wait_into(&mut buf).expect("draw");
        }
        let dt = t0.elapsed().as_secs_f64();
        let m = coord.metrics();
        println!(
            "{:>6} {:>14.3e} {:>10.0}us {:>10.0}us {:>15}/{}",
            depth,
            m.numbers_served as f64 / dt,
            m.mean_latency_us,
            m.p99_latency_us,
            m.pool_hits,
            m.pool_misses,
        );
        coord.shutdown();
    }
}

/// Coordinator overhead: serving through the full stack vs driving the
/// identical generator directly (target: <5% on large draws).
fn bench_overhead() {
    println!("--- coordinator overhead vs direct generator ---");
    let n_total = 128usize << 20;
    // Direct.
    let mut gen = make_block_generator(GeneratorKind::XorgensGp, 1, 64);
    let mut buf = vec![0u32; 1 << 18];
    let t0 = Instant::now();
    let mut done = 0;
    while done < n_total {
        gen.fill_interleaved(&mut buf);
        done += buf.len();
    }
    let direct = n_total as f64 / t0.elapsed().as_secs_f64();
    // Via coordinator (same launch shape, typed handle into the same
    // reusable caller buffer).
    let coord = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
    let s = coord.builder("ovh").u32().expect("stream");
    let t0 = Instant::now();
    let mut done = 0;
    while done < n_total {
        s.draw_into(&mut buf).expect("draw");
        done += buf.len();
    }
    let served = n_total as f64 / t0.elapsed().as_secs_f64();
    println!(
        "direct: {:.3e} RN/s | coordinator: {:.3e} RN/s | overhead: {:.1}%",
        direct,
        served,
        100.0 * (1.0 - served / direct)
    );
    coord.shutdown();
}

fn main() {
    bench_backend(BackendKind::Rust, "rust backend");
    bench_backend(BackendKind::Pjrt, "pjrt backend (AOT JAX/Pallas artifacts)");
    bench_pipelined();
    bench_overhead();
}

//! Coordinator/serving benchmarks: request latency and throughput vs
//! draw size, batching effectiveness, and backend comparison (pure Rust
//! vs PJRT AOT artifacts). This is the paper's headline-throughput claim
//! translated to the serving layer of this reproduction.
//!
//!   cargo bench --bench coordinator

use std::sync::Arc;
use std::time::Instant;
use xorgens_gp::coordinator::{BackendKind, Coordinator, CoordinatorConfig, StreamConfig};
use xorgens_gp::prng::{make_block_generator, GeneratorKind};

fn bench_backend(backend: BackendKind, label: &str) {
    if backend == BackendKind::Pjrt
        && !xorgens_gp::runtime::default_dir().join("manifest.txt").exists()
    {
        println!("{label}: skipped (artifacts not built)");
        return;
    }
    println!("--- {label} ---");
    println!(
        "{:>10} {:>8} {:>14} {:>12} {:>12}",
        "draw n", "clients", "RN/s", "mean lat", "p99 lat"
    );
    for &(n, clients) in
        &[(1024usize, 1usize), (65_536, 1), (262_144, 1), (65_536, 8), (262_144, 8)]
    {
        let coord = Arc::new(Coordinator::new(CoordinatorConfig::default()));
        let draws = (64 * (1 << 20) / n / clients).max(4); // ~64M numbers total
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let coord = coord.clone();
                scope.spawn(move || {
                    let s = coord.stream(
                        &format!("bench-{c}"),
                        StreamConfig { backend, ..Default::default() },
                    );
                    for _ in 0..draws {
                        coord.draw_u32(s, n).expect("draw");
                    }
                });
            }
        });
        let dt = t0.elapsed().as_secs_f64();
        let m = coord.metrics();
        println!(
            "{:>10} {:>8} {:>14.3e} {:>10.0}us {:>10.0}us",
            n,
            clients,
            m.numbers_served as f64 / dt,
            m.mean_latency_us,
            m.p99_latency_us
        );
    }
}

/// Coordinator overhead: serving through the full stack vs driving the
/// identical generator directly (target: <5% on large draws).
fn bench_overhead() {
    println!("--- coordinator overhead vs direct generator ---");
    let n_total = 128usize << 20;
    // Direct.
    let mut gen = make_block_generator(GeneratorKind::XorgensGp, 1, 64);
    let mut buf = vec![0u32; 1 << 18];
    let t0 = Instant::now();
    let mut done = 0;
    while done < n_total {
        gen.fill_interleaved(&mut buf);
        done += buf.len();
    }
    let direct = n_total as f64 / t0.elapsed().as_secs_f64();
    // Via coordinator (same launch shape).
    let coord = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
    let s = coord.stream("ovh", StreamConfig::default());
    let t0 = Instant::now();
    let mut done = 0;
    while done < n_total {
        done += coord.draw_u32(s, 1 << 18).expect("draw").len();
    }
    let served = n_total as f64 / t0.elapsed().as_secs_f64();
    println!(
        "direct: {:.3e} RN/s | coordinator: {:.3e} RN/s | overhead: {:.1}%",
        direct,
        served,
        100.0 * (1.0 - served / direct)
    );
    coord.shutdown();
}

fn main() {
    bench_backend(BackendKind::Rust, "rust backend");
    bench_backend(BackendKind::Pjrt, "pjrt backend (AOT JAX/Pallas artifacts)");
    bench_overhead();
}

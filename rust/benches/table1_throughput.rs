//! Regenerates paper **Table 1**: per-generator state footprint, period,
//! and RN/s — measured on this CPU (single thread and multi-thread) plus
//! the device model's GTX 480 / GTX 295 predictions next to the paper's
//! reported numbers.
//!
//!   cargo bench --bench table1_throughput
//!
//! (criterion is unavailable offline; this uses the in-crate harness.)

use xorgens_gp::device::model::paper_table1_rn_per_sec;
use xorgens_gp::device::{predict_rn_per_sec, GeneratorKernelProfile, GTX_295, GTX_480};
use xorgens_gp::prng::{make_block_generator, GeneratorKind};
use xorgens_gp::util::bench::{black_box, Bencher};

fn measured_rate(kind: GeneratorKind, threads: usize) -> f64 {
    // Each thread owns an independent block-parallel generator — the same
    // structure as the paper's grid of blocks split across MPs.
    let per_thread = 1 << 22; // 4M numbers per thread per run
    let b = Bencher::with_budget(300, 1500);
    let result = b.run(&format!("{kind}-{threads}t"), (per_thread * threads) as f64, || {
        std::thread::scope(|scope| {
            for t in 0..threads {
                scope.spawn(move || {
                    let mut gen = make_block_generator(kind, t as u64 + 1, 64);
                    let mut buf = vec![0u32; 1 << 16];
                    let mut done = 0usize;
                    while done < per_thread {
                        gen.fill_interleaved(&mut buf);
                        done += buf.len();
                    }
                    black_box(buf[0]);
                });
            }
        });
    });
    result.rate()
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    println!("=== Table 1 regeneration (measured CPU + device model) ===\n");
    println!(
        "{:<12} {:>12} {:>12} {:>13} {:>13} {:>24} {:>24}",
        "Generator", "State/block", "Period", "CPU 1T RN/s", &format!("CPU {cores}T RN/s"),
        "GTX480 model (paper)", "GTX295 model (paper)"
    );
    for kind in GeneratorKind::PAPER_SET {
        let gen = make_block_generator(kind, 1, 1);
        let prof = GeneratorKernelProfile::for_kind(kind);
        let r1 = measured_rate(kind, 1);
        let rn = measured_rate(kind, cores);
        let p480 = predict_rn_per_sec(&GTX_480, &prof);
        let p295 = predict_rn_per_sec(&GTX_295, &prof);
        println!(
            "{:<12} {:>10}w {:>11} {:>13.3e} {:>13.3e} {:>13.2e} ({:>7.2e}) {:>13.2e} ({:>7.2e})",
            kind.name(),
            gen.state_words_per_block(),
            format!("2^{:.0}", gen.period_log2()),
            r1,
            rn,
            p480,
            paper_table1_rn_per_sec(kind, &GTX_480).unwrap(),
            p295,
            paper_table1_rn_per_sec(kind, &GTX_295).unwrap(),
        );
    }
    println!(
        "\nShape checks (paper §3): GTX480 ordering CURAND > xorgensGP > MTGP; \
         GTX295 ordering MTGP > xorgensGP > CURAND; all rates within ~1.5x of each other."
    );
    // Assert the model preserves both orderings (same checks as unit tests,
    // repeated here so `cargo bench` fails loudly if calibration drifts).
    let r480: Vec<f64> = GeneratorKind::PAPER_SET
        .iter()
        .map(|&k| predict_rn_per_sec(&GTX_480, &GeneratorKernelProfile::for_kind(k)))
        .collect();
    assert!(r480[2] > r480[0] && r480[0] > r480[1], "GTX480 ordering broken");
    let r295: Vec<f64> = GeneratorKind::PAPER_SET
        .iter()
        .map(|&k| predict_rn_per_sec(&GTX_295, &GeneratorKernelProfile::for_kind(k)))
        .collect();
    assert!(r295[1] > r295[0] && r295[0] > r295[2], "GTX295 ordering broken");
    println!("orderings reproduced: OK");
}

//! Regenerates paper **Table 1**: per-generator state footprint, period,
//! and RN/s — measured on this CPU (single thread and multi-thread) plus
//! the device model's GTX 480 / GTX 295 predictions next to the paper's
//! reported numbers. Also measures the **scalar-vs-bulk ablation**: the
//! per-call `next_u32` path against the zero-copy `fill_round` pipeline,
//! the speedup that motivated the bulk-fill engine.
//!
//!   cargo bench --bench table1_throughput
//!
//! Pass `-- --metrics-overhead` to also run the observability ablation
//! (serve-path throughput with the span journal on vs off, written to
//! `BENCH_obs.json`).
//!
//! (criterion is unavailable offline; this uses the in-crate harness.)

use std::sync::Arc;
use xorgens_gp::coordinator::{Backend, Draws, RustBackend};
use xorgens_gp::device::model::paper_table1_rn_per_sec;
use xorgens_gp::device::{predict_rn_per_sec, GeneratorKernelProfile, GTX_295, GTX_480};
use xorgens_gp::exec::pool::{FillPool, PoolConfig};
use xorgens_gp::prng::traits::InterleavedStream;
use xorgens_gp::prng::{make_block_generator, GeneratorKind, Prng32};
use xorgens_gp::runtime::Transform;
use xorgens_gp::util::bench::{black_box, Bencher};
use xorgens_gp::util::json::Json;

fn measured_rate(kind: GeneratorKind, threads: usize) -> f64 {
    // Each thread owns an independent block-parallel generator — the same
    // structure as the paper's grid of blocks split across MPs.
    let per_thread = 1 << 22; // 4M numbers per thread per run
    let b = Bencher::with_budget(300, 1500);
    let result = b.run(&format!("{kind}-{threads}t"), (per_thread * threads) as f64, || {
        std::thread::scope(|scope| {
            for t in 0..threads {
                scope.spawn(move || {
                    let mut gen = make_block_generator(kind, t as u64 + 1, 64);
                    let mut buf = vec![0u32; 1 << 16];
                    let mut done = 0usize;
                    while done < per_thread {
                        gen.fill_interleaved(&mut buf);
                        done += buf.len();
                    }
                    black_box(buf[0]);
                });
            }
        });
    });
    result.rate()
}

/// Scalar path: one virtual `next_u32` per draw through the interleaved
/// adapter — the pre-bulk-engine access pattern.
fn scalar_rate(kind: GeneratorKind) -> f64 {
    let n = 1 << 22;
    let b = Bencher::with_budget(200, 1000);
    let mut gen = wrap_scalar(kind);
    b.run(&format!("{kind}-scalar"), n as f64, || {
        let mut acc = 0u32;
        for _ in 0..n {
            acc = acc.wrapping_add(gen.next_u32());
        }
        black_box(acc);
    })
    .rate()
}

/// Bulk path: the same stream through `fill_u32` over a reused buffer.
fn bulk_rate(kind: GeneratorKind) -> f64 {
    let n = 1 << 22;
    let chunk = 1 << 16;
    let b = Bencher::with_budget(200, 1000);
    let mut gen = wrap_scalar(kind);
    let mut buf = vec![0u32; chunk];
    b.run(&format!("{kind}-bulk"), n as f64, || {
        let mut done = 0;
        while done < n {
            gen.fill_u32(&mut buf);
            done += chunk;
        }
        black_box(buf[0]);
    })
    .rate()
}

/// Parallel fill engine rate: ONE generator, one caller buffer; `None`
/// runs the serial `fill_interleaved` baseline, `Some(t)` partitions the
/// 64 blocks across `t` scoped workers via `fill_interleaved_threaded`
/// (same stream, bit for bit — `measured_rate` above scales with
/// *independent* generators instead, the paper's multi-stream shape).
fn fill_rate(kind: GeneratorKind, threads: Option<usize>) -> f64 {
    let mut gen = make_block_generator(kind, 1, 64);
    // ~2M words, an exact number of rounds, well above the engine's
    // crossover threshold so Some(t) genuinely threads.
    let n = (1 << 21) / gen.round_len() * gen.round_len();
    let mut buf = vec![0u32; n];
    let label = match threads {
        None => format!("{kind}-fill-serial"),
        Some(t) => format!("{kind}-fill-{t}t"),
    };
    let b = Bencher::with_budget(200, 800);
    b.run(&label, n as f64, || {
        match threads {
            None => gen.fill_interleaved(&mut buf),
            Some(t) => gen.fill_interleaved_threaded(t, &mut buf),
        }
        black_box(buf[0]);
    })
    .rate()
}

/// Serve-path launch rate through a `RustBackend` (64 blocks × 16 rounds
/// per launch — the coordinator's shape, well above the engine's
/// crossover). `pool: None` is the scoped-threads baseline; `Some((p, d))`
/// dispatches through the persistent pool at generation-ahead depth `d`
/// (0 = pool dispatch only, ≥1 = the steady-state draw is a memcpy while
/// the pool refills in the background). Returns words/sec; the caller
/// derives per-launch latency as `launch_words / rate`.
fn serve_rate(kind: GeneratorKind, threads: usize, pool: Option<(&Arc<FillPool>, usize)>) -> f64 {
    let mut be =
        RustBackend::new(kind, Transform::U32, 1, 64, 16).fill_threads(threads);
    let label = match pool {
        None => format!("{kind}-serve-scoped-{threads}t"),
        Some((p, d)) => {
            be = be.pooled(Arc::clone(p), d);
            format!("{kind}-serve-pool-{threads}t-d{d}")
        }
    };
    let n = be.launch_size();
    let mut out = Draws::U32(Vec::with_capacity(n));
    // Warm-up launch: primes the prefetch pipeline so the measured loop
    // is steady state, not the cold-start stall.
    be.launch_into(&mut out).expect("warmup launch");
    let launches = 64;
    let b = Bencher::with_budget(200, 800);
    b.run(&label, (n * launches) as f64, || {
        for _ in 0..launches {
            out.clear();
            be.launch_into(&mut out).expect("launch");
        }
        black_box(out.len());
    })
    .rate()
}

/// Observability ablation: the full coordinator serve path (submit →
/// worker → pooled fill → prefetch swap) with the span journal on vs
/// off. The labeled family counters have no off switch — they *are* the
/// serve-path accounting — so this isolates the tracer's seqlock ring
/// writes, the only recurring cost the obs layer added to the hot path.
fn obs_rate(traced: bool) -> f64 {
    use xorgens_gp::coordinator::{Coordinator, CoordinatorConfig};
    xorgens_gp::obs::set_enabled(traced);
    let c = Coordinator::new(CoordinatorConfig {
        workers: 2,
        fill_threads: 4,
        prefetch: 1,
        ..Default::default()
    });
    let s = c.builder("obs-bench").blocks(64).rounds_per_launch(16).u32().unwrap();
    // One full 64-block × 16-round launch per draw (63 words/block-round):
    // every draw exercises launch spans, pool parts, and the prefetch swap.
    let n = 64 * 16 * 63;
    // Warm-up draw primes the prefetch pipeline past the cold-start stall.
    assert_eq!(s.draw(n).unwrap().len(), n);
    let label = if traced { "obs-traced" } else { "obs-untraced" };
    let b = Bencher::with_budget(200, 800);
    let rate = b
        .run(label, (n * 8) as f64, || {
            for _ in 0..8 {
                black_box(s.draw(n).unwrap().len());
            }
        })
        .rate();
    c.shutdown();
    xorgens_gp::obs::set_enabled(true);
    rate
}

fn wrap_scalar(kind: GeneratorKind) -> Box<dyn Prng32> {
    // Box the interleaved adapter so the scalar column pays the same
    // virtual dispatch the battery used to pay per draw.
    struct Boxed(Box<dyn xorgens_gp::prng::BlockParallel + Send>);
    impl xorgens_gp::prng::BlockParallel for Boxed {
        fn blocks(&self) -> usize {
            self.0.blocks()
        }
        fn lane_width(&self) -> usize {
            self.0.lane_width()
        }
        fn fill_round(&mut self, out: &mut [u32]) {
            self.0.fill_round(out)
        }
        fn dump_state(&self) -> Vec<u32> {
            self.0.dump_state()
        }
        fn load_state(&mut self, words: &[u32]) {
            self.0.load_state(words)
        }
        fn name(&self) -> &'static str {
            self.0.name()
        }
        fn state_words_per_block(&self) -> usize {
            self.0.state_words_per_block()
        }
        fn period_log2(&self) -> f64 {
            self.0.period_log2()
        }
    }
    Box::new(InterleavedStream::new(Boxed(make_block_generator(kind, 1, 64))))
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    println!("=== Table 1 regeneration (measured CPU + device model) ===\n");
    println!(
        "{:<12} {:>12} {:>12} {:>13} {:>13} {:>24} {:>24}",
        "Generator", "State/block", "Period", "CPU 1T RN/s", &format!("CPU {cores}T RN/s"),
        "GTX480 model (paper)", "GTX295 model (paper)"
    );
    for kind in GeneratorKind::PAPER_SET {
        let gen = make_block_generator(kind, 1, 1);
        let prof = GeneratorKernelProfile::for_kind(kind);
        let r1 = measured_rate(kind, 1);
        let rn = measured_rate(kind, cores);
        let p480 = predict_rn_per_sec(&GTX_480, &prof);
        let p295 = predict_rn_per_sec(&GTX_295, &prof);
        println!(
            "{:<12} {:>10}w {:>11} {:>13.3e} {:>13.3e} {:>13.2e} ({:>7.2e}) {:>13.2e} ({:>7.2e})",
            kind.name(),
            gen.state_words_per_block(),
            format!("2^{:.0}", gen.period_log2()),
            r1,
            rn,
            p480,
            paper_table1_rn_per_sec(kind, &GTX_480).unwrap(),
            p295,
            paper_table1_rn_per_sec(kind, &GTX_295).unwrap(),
        );
    }

    println!("\n=== scalar-vs-bulk ablation (the bulk-fill engine's win) ===\n");
    println!("{:<12} {:>16} {:>16} {:>9}", "Generator", "scalar RN/s", "bulk RN/s", "speedup");
    let mut gp_speedup = 0.0;
    let mut any_regression = false;
    for kind in GeneratorKind::PAPER_SET {
        let s = scalar_rate(kind);
        let f = bulk_rate(kind);
        let speedup = f / s;
        if kind == GeneratorKind::XorgensGp {
            gp_speedup = speedup;
        }
        if speedup < 1.0 {
            any_regression = true;
        }
        println!("{:<12} {:>16.3e} {:>16.3e} {:>8.2}x", kind.name(), s, f, speedup);
    }
    // Report the acceptance check; hard-fail only under STRICT_PERF=1 so
    // a noisy/loaded machine can't turn the Table 1 tool into a panic.
    let gp_ok = gp_speedup >= 2.0 && !any_regression;
    println!(
        "bulk-fill acceptance: xorgensGP speedup {gp_speedup:.2}x (target >= 2x), \
         regressions: {} -> {}",
        if any_regression { "yes" } else { "none" },
        if gp_ok { "OK" } else { "BELOW TARGET" }
    );
    if std::env::var_os("STRICT_PERF").is_some() {
        assert!(gp_ok, "scalar-vs-bulk acceptance failed (see table above)");
    }

    println!("\n=== parallel fill engine: thread sweep (one generator, partitioned blocks) ===\n");
    let sweep: Vec<usize> = [1, 2, 4].into_iter().filter(|&t| t == 1 || t <= cores).collect();
    let header: String =
        sweep.iter().map(|t| format!(" {:>12}", format!("{t}T RN/s"))).collect();
    println!("{:<12} {:>12}{header} {:>9} {:>11}", "Generator", "serial RN/s", "speedup", "efficiency");
    let mut gens_json = Vec::new();
    let mut engine_ok = true;
    for kind in GeneratorKind::PAPER_SET {
        let serial = fill_rate(kind, None);
        let rates: Vec<f64> = sweep.iter().map(|&t| fill_rate(kind, Some(t))).collect();
        let best_t = *sweep.last().unwrap();
        let best = *rates.last().unwrap();
        let cols: String = rates.iter().map(|r| format!(" {r:>12.3e}")).collect();
        println!(
            "{:<12} {serial:>12.3e}{cols} {:>8.2}x {:>10.0}%",
            kind.name(),
            best / serial,
            100.0 * best / serial / best_t as f64
        );
        // Acceptance (ISSUE): >= 1.5x at 4 threads for xorgensGP and MTGP,
        // and no measurable regression when the engine runs with 1 worker.
        if matches!(kind, GeneratorKind::XorgensGp | GeneratorKind::Mtgp) {
            if best_t >= 4 && best / serial < 1.5 {
                engine_ok = false;
            }
            if rates[0] < 0.8 * serial {
                engine_ok = false;
            }
        }
        let mut g = Json::obj();
        g.push("name", Json::Str(kind.name().into()))
            .push("serial", Json::Num(serial))
            .push("threaded", Json::Arr(rates.iter().map(|&r| Json::Num(r)).collect()));
        gens_json.push(g);
    }
    let mut snap = Json::obj();
    snap.push("bench", Json::Str("fill".into()))
        .push("units", Json::Str("u32 words/sec".into()))
        .push("cores", Json::Int(cores as i64))
        .push("threads", Json::Arr(sweep.iter().map(|&t| Json::Int(t as i64)).collect()))
        .push("generators", Json::Arr(gens_json));
    let dir = xorgens_gp::runtime::default_dir();
    let path = dir.join("BENCH_fill.json");
    match std::fs::create_dir_all(&dir).and_then(|()| std::fs::write(&path, snap.to_string())) {
        Ok(()) => println!("\nthroughput snapshot written to {}", path.display()),
        Err(e) => println!("\n(could not write {}: {e})", path.display()),
    }
    println!(
        "parallel-fill acceptance: xorgensGP/MTGP >= 1.5x at 4T, no 1T regression -> {}",
        if engine_ok { "OK" } else { "BELOW TARGET" }
    );
    if std::env::var_os("STRICT_PERF").is_some() {
        assert!(engine_ok, "parallel fill engine acceptance failed (see sweep above)");
    }

    println!("\n=== persistent pool vs scoped fan-out (serve path, 64 blocks x 16 rounds) ===\n");
    println!(
        "{:<12} {:>3} {:>14} {:>14} {:>14} {:>14} {:>12}",
        "Generator", "T", "scoped RN/s", "pool d0 RN/s", "pool d1 RN/s", "pool d2 RN/s",
        "d1 lat win"
    );
    let pool_threads: Vec<usize> = [1usize, 4].into_iter().filter(|&t| t == 1 || t <= cores).collect();
    let depths = [0usize, 1, 2];
    let mut pool_json = Vec::new();
    let mut pool_ok = true;
    for kind in [GeneratorKind::XorgensGp, GeneratorKind::Mtgp] {
        for &t in &pool_threads {
            // One pool per (kind, T) config: its worker count is part of
            // what is being measured. Caller participates as part 0.
            let pool = Arc::new(FillPool::new(PoolConfig {
                workers: t.saturating_sub(1).max(1),
                pin_cores: false,
            }));
            let scoped = serve_rate(kind, t, None);
            let pooled: Vec<f64> =
                depths.iter().map(|&d| serve_rate(kind, t, Some((&pool, d)))).collect();
            // Steady-state latency win at depth 1: draws should be ~a
            // memcpy, so the rate (inverse per-launch latency) climbs.
            let win = pooled[1] / scoped;
            println!(
                "{:<12} {:>3} {:>14.3e} {:>14.3e} {:>14.3e} {:>14.3e} {:>11.2}x",
                kind.name(), t, scoped, pooled[0], pooled[1], pooled[2], win
            );
            // Acceptance (ISSUE): the pool must not regress the 1-thread
            // serve path, and with prefetch on at 4 threads the
            // steady-state per-launch latency must win by >= 1.3x.
            if t == 1 && pooled[0] < 0.8 * scoped {
                pool_ok = false;
            }
            if t >= 4 && win < 1.3 {
                pool_ok = false;
            }
            let mut g = Json::obj();
            g.push("name", Json::Str(kind.name().into()))
                .push("threads", Json::Int(t as i64))
                .push("scoped", Json::Num(scoped))
                .push("pooled", Json::Arr(pooled.iter().map(|&r| Json::Num(r)).collect()));
            pool_json.push(g);
        }
    }
    let mut psnap = Json::obj();
    psnap
        .push("bench", Json::Str("pool".into()))
        .push("units", Json::Str("u32 words/sec".into()))
        .push("cores", Json::Int(cores as i64))
        .push("depths", Json::Arr(depths.iter().map(|&d| Json::Int(d as i64)).collect()))
        .push("configs", Json::Arr(pool_json));
    let ppath = dir.join("BENCH_pool.json");
    match std::fs::write(&ppath, psnap.to_string()) {
        Ok(()) => println!("\npool snapshot written to {}", ppath.display()),
        Err(e) => println!("\n(could not write {}: {e})", ppath.display()),
    }
    println!(
        "pool acceptance: no 1T regression, >= 1.3x steady-state latency win at 4T+prefetch -> {}",
        if pool_ok { "OK" } else { "BELOW TARGET" }
    );
    if std::env::var_os("STRICT_PERF").is_some() {
        assert!(pool_ok, "persistent pool acceptance failed (see table above)");
    }

    println!("\n=== SIMD kernel sweep (forced scalar vs widest available, 1T and 4T) ===\n");
    // Output is bit-identical for every kernel (pinned by rust/tests/simd.rs),
    // so the sweep is pure throughput: `scalar` forced is the pre-SIMD fill
    // loop verbatim, which makes `wide >= ~scalar` exactly the "no
    // scalar-path regression" check — auto selection resolves to the wide
    // kernel, and it must not lose to the baseline it replaced.
    use xorgens_gp::simd::{self, KernelChoice, SimdKernel};
    let widest = simd::detect();
    let simd_threads: Vec<usize> =
        [1usize, 4].into_iter().filter(|&t| t == 1 || t <= cores).collect();
    let simd_kernels: Vec<SimdKernel> = if widest == SimdKernel::Scalar {
        vec![SimdKernel::Scalar]
    } else {
        vec![SimdKernel::Scalar, widest]
    };
    let theader: String =
        simd_threads.iter().map(|t| format!(" {:>13}", format!("{t}T RN/s"))).collect();
    println!("{:<12} {:<7}{theader} {:>12}", "Generator", "kernel", "1T vs scalar");
    let mut simd_json = Vec::new();
    let mut simd_ok = true;
    let mut gp_simd_win = f64::NAN;
    for kind in GeneratorKind::PAPER_SET {
        let mut scalar_1t = f64::NAN;
        let mut kjson = Vec::new();
        for &k in &simd_kernels {
            simd::set_forced(KernelChoice::Force(k));
            let rates: Vec<f64> = simd_threads
                .iter()
                .map(|&t| if t == 1 { fill_rate(kind, None) } else { fill_rate(kind, Some(t)) })
                .collect();
            if k == SimdKernel::Scalar {
                scalar_1t = rates[0];
            }
            let win = rates[0] / scalar_1t;
            if k == widest {
                // No scalar-path regression, any kind: the wide kernel the
                // auto selector picks must not lose to the old loop.
                if win < 0.95 {
                    simd_ok = false;
                }
                if kind == GeneratorKind::XorgensGp {
                    gp_simd_win = win;
                }
            }
            let cols: String = rates.iter().map(|r| format!(" {r:>13.3e}")).collect();
            println!("{:<12} {:<7}{cols} {:>11.2}x", kind.name(), k.name(), win);
            let mut kj = Json::obj();
            kj.push("kernel", Json::Str(k.name().into()))
                .push("rates", Json::Arr(rates.iter().map(|&r| Json::Num(r)).collect()));
            kjson.push(kj);
        }
        let mut g = Json::obj();
        g.push("name", Json::Str(kind.name().into())).push("kernels", Json::Arr(kjson));
        simd_json.push(g);
    }
    simd::set_forced(KernelChoice::Auto);
    // Acceptance (ISSUE): single-thread xorgensGP fill must win >= 1.8x
    // with a genuinely wide kernel (AVX2 or NEON; SSE2's 4 lanes against
    // an auto-vectorizing scalar loop is not held to that bar).
    let wide_gate = matches!(widest, SimdKernel::Avx2 | SimdKernel::Neon);
    if wide_gate && !(gp_simd_win >= 1.8) {
        simd_ok = false;
    }
    let mut ssnap = Json::obj();
    ssnap
        .push("bench", Json::Str("simd".into()))
        .push("units", Json::Str("u32 words/sec".into()))
        .push("cores", Json::Int(cores as i64))
        .push("widest", Json::Str(widest.name().into()))
        .push(
            "threads",
            Json::Arr(simd_threads.iter().map(|&t| Json::Int(t as i64)).collect()),
        )
        .push("generators", Json::Arr(simd_json));
    let spath = dir.join("BENCH_simd.json");
    match std::fs::write(&spath, ssnap.to_string()) {
        Ok(()) => println!("\nsimd snapshot written to {}", spath.display()),
        Err(e) => println!("\n(could not write {}: {e})", spath.display()),
    }
    println!(
        "simd acceptance: no scalar-path regression{} (widest: {}) -> {}",
        if wide_gate {
            format!(", xorgensGP 1T win {gp_simd_win:.2}x (target >= 1.8x)")
        } else {
            String::new()
        },
        widest.name(),
        if simd_ok { "OK" } else { "BELOW TARGET" }
    );
    if std::env::var_os("STRICT_PERF").is_some() {
        assert!(simd_ok, "simd kernel acceptance failed (see sweep above)");
    }

    if std::env::args().any(|a| a == "--metrics-overhead") {
        println!("\n=== observability overhead ablation (span journal on vs off) ===\n");
        let untraced = obs_rate(false);
        let traced = obs_rate(true);
        let overhead = 1.0 - traced / untraced;
        println!(
            "{:<12} {:>16} {:>16} {:>10}",
            "serve path", "untraced RN/s", "traced RN/s", "overhead"
        );
        println!(
            "{:<12} {:>16.3e} {:>16.3e} {:>9.2}%",
            "xorgensGP", untraced, traced, 100.0 * overhead
        );
        let mut osnap = Json::obj();
        osnap
            .push("bench", Json::Str("obs".into()))
            .push("units", Json::Str("u32 words/sec".into()))
            .push("cores", Json::Int(cores as i64))
            .push("untraced", Json::Num(untraced))
            .push("traced", Json::Num(traced))
            .push("overhead_frac", Json::Num(overhead));
        let opath = dir.join("BENCH_obs.json");
        match std::fs::write(&opath, osnap.to_string()) {
            Ok(()) => println!("\nobs snapshot written to {}", opath.display()),
            Err(e) => println!("\n(could not write {}: {e})", opath.display()),
        }
        // Acceptance (ISSUE): tracing the serve path costs < 3%. Negative
        // overhead is measurement noise and passes.
        let obs_ok = overhead < 0.03;
        println!(
            "observability acceptance: span-journal overhead < 3% -> {}",
            if obs_ok { "OK" } else { "BELOW TARGET" }
        );
        if std::env::var_os("STRICT_PERF").is_some() {
            assert!(obs_ok, "observability overhead acceptance failed (see ablation above)");
        }
    }

    println!(
        "\nShape checks (paper §3): GTX480 ordering CURAND > xorgensGP > MTGP; \
         GTX295 ordering MTGP > xorgensGP > CURAND; all rates within ~1.5x of each other."
    );
    // Assert the model preserves both orderings (same checks as unit tests,
    // repeated here so `cargo bench` fails loudly if calibration drifts).
    let r480: Vec<f64> = GeneratorKind::PAPER_SET
        .iter()
        .map(|&k| predict_rn_per_sec(&GTX_480, &GeneratorKernelProfile::for_kind(k)))
        .collect();
    assert!(r480[2] > r480[0] && r480[0] > r480[1], "GTX480 ordering broken");
    let r295: Vec<f64> = GeneratorKind::PAPER_SET
        .iter()
        .map(|&k| predict_rn_per_sec(&GTX_295, &GeneratorKernelProfile::for_kind(k)))
        .collect();
    assert!(r295[1] > r295[0] && r295[0] > r295[2], "GTX295 ordering broken");
    println!("orderings reproduced: OK");
}

//! Regenerates paper **Table 2**: tests failed per battery tier per
//! generator, printed in the paper's exact format.
//!
//!   cargo bench --bench table2_battery              (all tiers)
//!   BATTERY_TIERS=small,crush cargo bench --bench table2_battery
//!
//! Expected reproduction (see EXPERIMENTS.md §T2):
//!   xorgensGP   None | None        | None
//!   MTGP        None | #71, #72    | #80, #81
//!   CURAND      None | None        | #81

use std::time::Instant;
use xorgens_gp::prng::GeneratorKind;
use xorgens_gp::testu01::battery::{run_battery, Tier};

fn main() {
    let tiers_env = std::env::var("BATTERY_TIERS").unwrap_or_else(|_| "small,crush,big".into());
    let tiers: Vec<Tier> = tiers_env
        .split(',')
        .filter_map(|t| Tier::parse(t.trim()))
        .collect();
    let seed = 20260710;
    println!("=== Table 2 regeneration (crushr battery, seed {seed}) ===\n");
    let mut rows: Vec<(String, Vec<String>)> = GeneratorKind::PAPER_SET
        .iter()
        .map(|k| (k.name().to_string(), Vec::new()))
        .collect();
    for &tier in &tiers {
        for (i, &kind) in GeneratorKind::PAPER_SET.iter().enumerate() {
            let t0 = Instant::now();
            let report = run_battery(tier, kind, seed);
            let cell = report.table2_cell();
            let secs = t0.elapsed().as_secs_f64();
            let consumed: u64 = report.rows.iter().map(|r| r.result.consumed).sum();
            println!(
                "{:<10} {:<10} -> {:<28} ({:>5.1}s, {:.1e} draws, {} suspects)",
                tier.name(),
                kind.name(),
                cell,
                secs,
                consumed as f64,
                report.suspects().len()
            );
            rows[i].1.push(cell);
        }
    }
    println!("\nTable 2. Tests failed in each standard benchmark.");
    print!("{:<12}", "Generator");
    for tier in &tiers {
        print!(" | {:<22}", tier.name());
    }
    println!();
    let paper: [(&str, [&str; 3]); 3] = [
        ("xorgensgp", ["None", "None", "None"]),
        ("mtgp", ["None", "#71,#72", "#80,#81"]),
        ("xorwow", ["None", "None", "#81"]),
    ];
    for (i, (name, cells)) in rows.iter().enumerate() {
        print!("{name:<12}");
        for cell in cells {
            print!(" | {cell:<22}");
        }
        println!("   (paper: {})", paper[i].1.join(" | "));
    }
}

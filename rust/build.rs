//! Detect whether an `xla` crate has been vendored (see the `pjrt` feature
//! notes in Cargo.toml). The real PJRT client is gated on
//! `all(feature = "pjrt", xla_vendored)`, so `--features pjrt` compiles the
//! stub on machines without the vendored crate — the CI feature-matrix job
//! relies on this.

fn main() {
    println!("cargo::rustc-check-cfg=cfg(xla_vendored)");
    let vendored = std::path::Path::new("../vendor/xla/Cargo.toml").exists();
    if vendored {
        println!("cargo::rustc-cfg=xla_vendored");
    }
    println!("cargo::rerun-if-changed=../vendor/xla/Cargo.toml");
}

//! Typed-handle API equivalence: the new `StreamBuilder`/`TypedStream`/
//! `Ticket` surface must serve streams bit-identical to the legacy
//! `draw`/`draw_u32`/`draw_f32` path for every generator kind, and — via
//! the `seed` override — bit-identical to the committed cross-language
//! golden vectors where the served stream *is* a golden stream.

mod common;

use common::{fnv64, read_fillpath};
use xorgens_gp::coordinator::{Coordinator, CoordinatorConfig, StreamConfig, Ticket};
use xorgens_gp::prng::distributions::unit_f32;
use xorgens_gp::prng::traits::InterleavedStream;
use xorgens_gp::prng::xorwow::XorwowBlock;
use xorgens_gp::prng::{GeneratorKind, Prng32};

const GOLDEN_SEEDS: [u64; 2] = [20260710, 424242];

fn coord() -> Coordinator {
    Coordinator::new(CoordinatorConfig { workers: 2, ..Default::default() })
}

/// The headline equivalence: for all five generator kinds, drawing through
/// a typed handle is bit-identical to the deprecated untyped path — same
/// stream name, same root seed, mixed draw sizes crossing launch
/// boundaries.
#[test]
#[allow(deprecated)]
fn typed_path_bit_identical_to_legacy_for_all_kinds() {
    for kind in GeneratorKind::ALL {
        let c_typed = coord();
        let c_legacy = coord();
        let typed = c_typed
            .builder("equiv")
            .kind(kind)
            .blocks(4)
            .rounds_per_launch(2)
            .u32()
            .unwrap();
        let legacy = c_legacy.stream(
            "equiv",
            StreamConfig { kind, blocks: 4, rounds_per_launch: 2, ..Default::default() },
        );
        // Mixed draw sizes, including ones that split launches.
        for n in [7usize, 500, 1009, 4096] {
            let a = typed.draw(n).unwrap();
            let b = c_legacy.draw_u32(legacy, n).unwrap();
            assert_eq!(a, b, "{kind}: typed != legacy at draw({n})");
        }
        // draw_into serves the same continuation as draw.
        let mut buf = vec![0u32; 333];
        typed.draw_into(&mut buf).unwrap();
        assert_eq!(buf, c_legacy.draw_u32(legacy, 333).unwrap(), "{kind}: draw_into != legacy");
        c_typed.shutdown();
        c_legacy.shutdown();
    }
}

/// f32 equivalence, both transforms: the typed surface serves the same
/// floats as the legacy one, and the F32 transform is exactly the
/// canonical `unit_f32` map over the u32 stream.
#[test]
#[allow(deprecated)]
fn typed_f32_paths_bit_identical_to_legacy() {
    for kind in GeneratorKind::ALL {
        let c_typed = coord();
        let c_legacy = coord();
        let uni = c_typed.builder("f32eq").kind(kind).blocks(2).uniform().unwrap();
        let nrm = c_typed.builder("nrmeq").kind(kind).blocks(2).normal().unwrap();
        let id_uni = c_legacy.stream(
            "f32eq",
            StreamConfig {
                kind,
                blocks: 2,
                transform: xorgens_gp::runtime::Transform::F32,
                ..Default::default()
            },
        );
        let id_nrm = c_legacy.stream(
            "nrmeq",
            StreamConfig {
                kind,
                blocks: 2,
                transform: xorgens_gp::runtime::Transform::Normal,
                ..Default::default()
            },
        );
        assert_eq!(uni.draw(2000).unwrap(), c_legacy.draw_f32(id_uni, 2000).unwrap(), "{kind}");
        assert_eq!(nrm.draw(2000).unwrap(), c_legacy.draw_f32(id_nrm, 2000).unwrap(), "{kind}");
        c_typed.shutdown();
        c_legacy.shutdown();
    }
    // F32 == unit_f32 ∘ U32 for the same underlying stream (seed pinned so
    // both streams walk identical generators).
    let c1 = coord();
    let c2 = coord();
    let uni = c1.builder("map").seed(99).blocks(4).uniform().unwrap();
    let raw = c2.builder("map").seed(99).blocks(4).u32().unwrap();
    let f = uni.draw(4096).unwrap();
    let u = raw.draw(4096).unwrap();
    let expect: Vec<f32> = u.iter().map(|&x| unit_f32(x)).collect();
    assert_eq!(f, expect);
    c1.shutdown();
    c2.shutdown();
}

/// Golden pinning through the service: with the `seed` override and the
/// library-default block count, a served stream IS the committed golden
/// stream. Generator kinds map onto the golden files the way
/// `make_block_generator` maps them onto block engines: `xorgens` and
/// `xorgensgp` serve the xorgensGP block stream, `mt19937` and `mtgp`
/// serve the MTGP block stream (the serial golden vectors for xorgens /
/// mt19937 / xorwow pin `make_generator`, which the coordinator does not
/// expose).
#[test]
fn typed_handles_serve_golden_streams() {
    // (served kind, golden file, golden blocks)
    let cases = [
        (GeneratorKind::XorgensGp, "xorgensgp", 64usize),
        (GeneratorKind::Xorgens, "xorgensgp", 64),
        (GeneratorKind::Mtgp, "mtgp", 64),
        (GeneratorKind::Mt19937, "mtgp", 64),
    ];
    for (kind, golden, blocks) in cases {
        for seed in GOLDEN_SEEDS {
            let c = coord();
            let s = c
                .builder("golden")
                .kind(kind)
                .seed(seed)
                .blocks(blocks)
                .rounds_per_launch(1)
                .u32()
                .unwrap();
            let got = s.draw(4096).unwrap();
            let (head, hash) = read_fillpath(golden, seed);
            assert_eq!(&got[..32], &head[..], "{kind}/{seed}: head != golden");
            assert_eq!(fnv64(&got), hash, "{kind}/{seed}: fnv64 != golden");
            c.shutdown();
        }
    }
}

/// A threaded coordinator (`fill_threads: 3` — odd, oversubscribing the
/// 64-block partition unevenly) serves the committed golden streams
/// unchanged: the parallel fill engine is invisible in the output.
#[test]
fn threaded_coordinator_serves_golden_streams() {
    for seed in GOLDEN_SEEDS {
        let c = Coordinator::new(CoordinatorConfig {
            workers: 2,
            fill_threads: 3,
            ..Default::default()
        });
        // 64 blocks × 1 round/launch is below the engine's crossover; 16
        // rounds/launch is above it — both must pin to the same golden.
        for (name, rounds) in [("g-small", 1usize), ("g-big", 16)] {
            let s = c
                .builder(name)
                .kind(GeneratorKind::XorgensGp)
                .seed(seed)
                .blocks(64)
                .rounds_per_launch(rounds)
                .u32()
                .unwrap();
            let got = s.draw(4096).unwrap();
            let (head, hash) = read_fillpath("xorgensgp", seed);
            assert_eq!(&got[..32], &head[..], "rounds={rounds} seed={seed}: head != golden");
            assert_eq!(fnv64(&got), hash, "rounds={rounds} seed={seed}: fnv64 != golden");
        }
        c.shutdown();
    }
}

/// XORWOW has no committed block-interleaved golden file (its golden
/// vector pins the *serial* generator), so pin the served stream against
/// the library construction the backend documents: the interleaved
/// `XorwowBlock` stream with the same seed.
#[test]
fn xorwow_served_stream_matches_library_construction() {
    for seed in GOLDEN_SEEDS {
        let c = coord();
        let s = c
            .builder("xw-golden")
            .kind(GeneratorKind::Xorwow)
            .seed(seed)
            .blocks(16)
            .rounds_per_launch(8)
            .u32()
            .unwrap();
        let got = s.draw(4096).unwrap();
        let mut oracle = InterleavedStream::new(XorwowBlock::new(seed, 16));
        let expect: Vec<u32> = (0..4096).map(|_| oracle.next_u32()).collect();
        assert_eq!(got, expect, "seed {seed}");
        c.shutdown();
    }
}

/// Pipelined consumption (tickets, any interleaving of submit/wait) reads
/// the same stream as blocking draws — pinned against the golden vector so
/// a reordering bug cannot cancel out between two live paths.
#[test]
fn pipelined_tickets_serve_golden_stream() {
    let c = coord();
    let s = c
        .builder("golden-pipe")
        .seed(20260710)
        .blocks(64)
        .rounds_per_launch(1)
        .u32()
        .unwrap();
    // 8 tickets of 512, submitted before any wait.
    let tickets: Vec<Ticket<u32>> = (0..8).map(|_| s.submit(512).unwrap()).collect();
    let mut got = Vec::new();
    for t in tickets {
        let mut chunk = vec![0u32; 512];
        t.wait_into(&mut chunk).unwrap();
        got.extend(chunk);
    }
    let (head, hash) = read_fillpath("xorgensgp", 20260710);
    assert_eq!(&got[..32], &head[..]);
    assert_eq!(fnv64(&got), hash);
    c.shutdown();
}

/// The seed override reproduces streams across coordinators with different
/// root seeds (the derivation no longer matters once pinned).
#[test]
fn seed_override_is_root_independent() {
    let c1 = Coordinator::new(CoordinatorConfig { root_seed: 1, ..Default::default() });
    let c2 = Coordinator::new(CoordinatorConfig { root_seed: 2, ..Default::default() });
    let s1 = c1.builder("a").seed(777).blocks(2).u32().unwrap();
    let s2 = c2.builder("b").seed(777).blocks(2).u32().unwrap();
    assert_eq!(s1.draw(1000).unwrap(), s2.draw(1000).unwrap());
    // Without the override, different roots give different streams.
    let d1 = c1.builder("c").blocks(2).u32().unwrap();
    let d2 = c2.builder("c").blocks(2).u32().unwrap();
    assert_ne!(d1.draw(64).unwrap(), d2.draw(64).unwrap());
    c1.shutdown();
    c2.shutdown();
}

//! Observability integration suite: the causal trace journal must cover
//! a routed draw end to end (router → shard server → coordinator worker
//! → fill-pool) under one trace id, the labeled families must sum
//! exactly to the legacy global snapshot, per-shard telemetry must sum
//! to the router's globals over the wire, and the HTTP scrape surface
//! must serve a live coordinator's exposition.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use xorgens_gp::cluster::{Router, RouterConfig, ShardServer, ShardServerConfig};
use xorgens_gp::coordinator::{Coordinator, CoordinatorConfig};
use xorgens_gp::obs::{self, registry::stream_counter_values, SpanKind};

/// One full 64-block × 16-round launch (63 words per block-round):
/// above the parallel-fill crossover, so parts genuinely hit the pool.
const LAUNCH_WORDS: usize = 64 * 16 * 63;

fn pooled_shard(id: u64) -> ShardServer {
    ShardServer::bind(
        "127.0.0.1:0",
        ShardServerConfig {
            shard_id: id,
            coordinator: CoordinatorConfig {
                workers: 2,
                fill_threads: 3,
                prefetch: 1,
                ..Default::default()
            },
            ..Default::default()
        },
    )
    .unwrap()
}

/// Pull the integer after `key` out of a JSON string (first occurrence —
/// for the exposition JSON that is the `global` block's value).
fn extract_int(json: &str, key: &str) -> u64 {
    let tail = json.split(key).nth(1).unwrap_or_else(|| panic!("{key} not in {json}"));
    let digits: String = tail.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().unwrap_or_else(|_| panic!("no integer after {key} in {json}"))
}

/// The acceptance pin: a draw through a loopback two-shard cluster
/// leaves a dumpable trace whose single causal id covers the router's
/// `route` span, the shard server's `draw` span, the coordinator
/// worker's `launch` span, and at least one fill-pool span (`generate`
/// or `fill_part`) — client edge to worker thread, one trace id.
#[test]
fn routed_draw_trace_covers_client_to_fill_worker() {
    obs::set_enabled(true);
    let s0 = pooled_shard(0);
    let s1 = pooled_shard(1);
    let router = Router::connect(RouterConfig {
        shards: vec![s0.addr().to_string(), s1.addr().to_string()],
        ..Default::default()
    })
    .unwrap();
    let stream = router.builder("traced").blocks(64).rounds_per_launch(16).u32().unwrap();
    // The cold-start draw must fill synchronously inside its own serve,
    // so its trace id reaches the pool (later draws may be served from
    // buffers prefetched under an earlier draw's id).
    assert_eq!(stream.draw(LAUNCH_WORDS).unwrap().len(), LAUNCH_WORDS);
    let records = obs::dump(usize::MAX);
    let mut covered = false;
    for r in records.iter().filter(|r| r.kind == SpanKind::Route) {
        let kinds: Vec<SpanKind> = records
            .iter()
            .filter(|s| s.trace_id == r.trace_id)
            .map(|s| s.kind)
            .collect();
        if kinds.contains(&SpanKind::Draw)
            && kinds.contains(&SpanKind::Launch)
            && (kinds.contains(&SpanKind::Generate) || kinds.contains(&SpanKind::FillPart))
        {
            covered = true;
            break;
        }
    }
    assert!(
        covered,
        "no route trace covers draw + launch + a pool span; dump:\n{}",
        obs::render_dump(&records)
    );
    router.shutdown_shards();
}

/// The sum-exactness contract: every per-stream family counter pairs
/// with its global increment at the same site, so after quiescent draws
/// the families sum *exactly* to the legacy snapshot — not approximately.
#[test]
fn stream_families_sum_exactly_to_global_snapshot() {
    let c = Coordinator::new(CoordinatorConfig {
        workers: 2,
        fill_threads: 2,
        prefetch: 0,
        ..Default::default()
    });
    let a = c.builder("fam-a").blocks(64).rounds_per_launch(16).u32().unwrap();
    let b = c.builder("fam-b").blocks(8).rounds_per_launch(4).uniform().unwrap();
    for _ in 0..5 {
        assert_eq!(a.draw(LAUNCH_WORDS).unwrap().len(), LAUNCH_WORDS);
        assert_eq!(b.draw(1000).unwrap().len(), 1000);
    }
    let exp = c.exposition();
    let g = &exp.global;
    let sum = |field: &str| -> u64 {
        exp.streams
            .iter()
            .map(|(_, _, sc)| {
                stream_counter_values(sc)
                    .iter()
                    .find(|(n, _)| *n == field)
                    .map(|(_, v)| *v)
                    .unwrap()
            })
            .sum()
    };
    assert!(g.requests >= 10, "draws must have been counted: {}", g.requests);
    for (field, global) in [
        ("requests", g.requests),
        ("numbers_served", g.numbers_served),
        ("launches", g.launches),
        ("rejected", g.rejected),
        ("pool_hits", g.pool_hits),
        ("pool_misses", g.pool_misses),
        ("prefetch_hits", g.prefetch_hits),
        ("prefetch_stalls", g.prefetch_stalls),
    ] {
        assert_eq!(sum(field), global, "family {field} does not sum to the global counter");
    }
    // Labels come from the stream configs, not placeholders.
    assert!(exp.streams.iter().any(|(_, l, _)| l.transform == "u32"), "{:?}", exp.streams);
    assert!(exp.streams.iter().any(|(_, l, _)| l.transform == "f32"), "{:?}", exp.streams);
    c.shutdown();
}

/// Cluster telemetry closes over the wire: the per-shard `metrics` verb
/// expositions, summed across shards, equal the router's own globals for
/// requests and numbers served (healthy loopback: no retries, so every
/// routed draw is exactly one shard submit). Each serving shard also
/// reports its shard identity block with a live connection.
#[test]
fn shard_expositions_sum_to_router_globals() {
    let s0 = pooled_shard(0);
    let s1 = pooled_shard(1);
    let router = Router::connect(RouterConfig {
        shards: vec![s0.addr().to_string(), s1.addr().to_string()],
        ..Default::default()
    })
    .unwrap();
    // Enough streams that the fnv placement hash spreads work around.
    for i in 0..6 {
        let s = router.builder(&format!("spread-{i}")).blocks(8).rounds_per_launch(4).u32().unwrap();
        for _ in 0..3 {
            assert_eq!(s.draw(500).unwrap().len(), 500);
        }
    }
    let rm = router.metrics();
    let mut shard_requests = 0u64;
    let mut shard_numbers = 0u64;
    for (addr, metrics) in router.shard_metrics() {
        let json = metrics.unwrap_or_else(|e| panic!("{addr}: {e:#}"));
        shard_requests += extract_int(&json, "\"requests\":");
        shard_numbers += extract_int(&json, "\"numbers_served\":");
        assert!(json.contains("\"shard\":{"), "{addr}: no shard block in {json}");
        assert!(
            extract_int(&json, "\"connections_total\":") >= 1,
            "{addr}: no connections counted: {json}"
        );
    }
    assert_eq!(shard_requests, rm.requests, "per-shard requests must sum to router total");
    assert_eq!(
        shard_numbers, rm.numbers_served,
        "per-shard numbers_served must sum to router total"
    );
    router.shutdown_shards();
}

/// The HTTP scrape surface over a live coordinator: `/metrics` serves
/// Prometheus text with the labeled families filled in, `/metrics.json`
/// the JSON exposition — both reflecting draws that already happened.
#[test]
fn http_scrape_serves_live_exposition() {
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers: 2,
        fill_threads: 2,
        ..Default::default()
    }));
    let s = coord.builder("scraped").blocks(8).rounds_per_launch(4).u32().unwrap();
    for _ in 0..4 {
        assert_eq!(s.draw(1000).unwrap().len(), 1000);
    }
    let c1 = Arc::clone(&coord);
    let c2 = Arc::clone(&coord);
    let server = obs::MetricsServer::bind(
        "127.0.0.1:0",
        obs::ScrapeHandlers {
            prometheus: Box::new(move || c1.exposition().to_prometheus()),
            json: Box::new(move || c2.exposition().to_json().to_string()),
        },
    )
    .unwrap();
    let addr = server.addr().to_string();
    let prom = obs::http_get(&addr, "/metrics").unwrap();
    for fam in obs::FAMILY_NAMES.iter().filter(|f| !f.starts_with("xg_shard_")) {
        assert!(prom.contains(*fam), "family {fam} missing from scrape:\n{prom}");
    }
    assert!(
        extract_int(&prom, "\nxg_requests_total ") >= 4,
        "scrape must reflect the draws: {prom}"
    );
    assert!(prom.contains("xg_stream_requests_total{stream=\"0\""), "{prom}");
    let json = obs::http_get(&addr, "/metrics.json").unwrap();
    assert!(json.contains("\"global\":{"), "{json}");
    assert!(json.contains("\"workers\":[{"), "{json}");
    drop(server);
    // A second draw after the listener is gone still works (the scrape
    // surface is an observer, never a dependency of the serve path).
    assert_eq!(s.draw(100).unwrap().len(), 100);
    coord.shutdown();
}

/// Counters keep counting when spans are untraced (a draw through a
/// plain `Ticket` path with tracing globally on still increments every
/// family — the journal and the registry are independent layers).
/// NOTE: this test deliberately does NOT flip the global enable flag —
/// tests in one binary run concurrently and the tracer is process-wide,
/// so toggling it here would race the trace-coverage test. The
/// disabled-path contract is pinned by the `obs::trace` unit tests and
/// exercised by the bench ablation.
#[test]
fn families_count_independently_of_the_span_journal() {
    let c = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
    let s = c.builder("quiet").blocks(8).rounds_per_launch(4).u32().unwrap();
    assert_eq!(s.draw(2000).unwrap().len(), 2000);
    let exp = c.exposition();
    assert!(exp.global.requests >= 1);
    let (_, _, sc) = &exp.streams[0];
    assert_eq!(sc.requests.load(Ordering::Relaxed), exp.global.requests);
    assert_eq!(sc.numbers_served.load(Ordering::Relaxed), 2000);
    c.shutdown();
}

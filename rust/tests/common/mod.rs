//! Helpers shared between the integration-test binaries (included via
//! `mod common;` — `tests/common/` is not itself a test binary).

/// FNV-1a 64 over the little-endian bytes of the outputs (mirrored in
/// python/tools/gen_golden_vectors.py).
pub fn fnv64(values: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &v in values {
        for byte in v.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Parse a committed fillpath golden vector by file stem (the
/// `make_generator` kind name): first 32 outputs + fnv64 of 4096.
pub fn read_fillpath(name: &str, seed: u64) -> (Vec<u32>, u64) {
    let path = format!("tests/golden/fillpath-{name}-{seed}.txt");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("golden vector {path} missing: {e}"));
    let mut lines = text.lines();
    let head: Vec<u32> = lines
        .next()
        .expect("head line")
        .split_whitespace()
        .map(|t| t.parse().expect("golden head corrupt"))
        .collect();
    let hash: u64 = lines.next().expect("hash line").trim().parse().expect("golden hash corrupt");
    assert_eq!(head.len(), 32, "{path}");
    (head, hash)
}

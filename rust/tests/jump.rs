//! Cross-layer tests of the substream placement engine: polynomial
//! jump-ahead algebra for every `LinearStep` generator, agreement with
//! the dense-matrix path, the tractability pin for the 4096-bit xorgens
//! state, and end-to-end wiring through the coordinator.

use xorgens_gp::coordinator::{Coordinator, CoordinatorConfig, Placement};
use xorgens_gp::gf2::{jump_state, transition_matrix, transition_power, JumpEngine, LinearStep};
use xorgens_gp::prng::mt19937::MtStep;
use xorgens_gp::prng::place::PlacedMaster;
use xorgens_gp::prng::traits::InterleavedStream;
use xorgens_gp::prng::xorgens::XorgensLfsr;
use xorgens_gp::prng::xorwow::XorwowLfsr;
use xorgens_gp::prng::{make_block_generator, BlockParallel, GeneratorKind, Prng32, XorgensParams};
use xorgens_gp::util::prop::check;

/// Every `LinearStep` impl in the crate, by name.
fn steppers() -> Vec<(&'static str, Box<dyn LinearStep>)> {
    vec![
        ("xorwow", Box::new(XorwowLfsr)),
        ("xorgens-test64", Box::new(XorgensLfsr(XorgensParams::TEST_64))),
        ("xorgens-gp4096", Box::new(XorgensLfsr(XorgensParams::GP_4096))),
        ("mt19937", Box::new(MtStep)),
    ]
}

/// Deterministic nonzero probe state for an `n/32`-word generator.
fn probe_state(words: usize, salt: u64) -> Vec<u32> {
    let mut x = salt.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
    (0..words)
        .map(|_| {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((x >> 32) as u32) | 1 // never all-zero
        })
        .collect()
}

/// Acceptance pin: `jump(k)` equals `k` brute-force steps for every
/// `LinearStep` impl, including the 4096-bit xorgens state and the
/// 19968-bit MT window.
#[test]
fn polynomial_jump_equals_brute_force_for_every_stepper() {
    for (name, g) in steppers() {
        let engine = JumpEngine::probe(g.as_ref());
        let words = g.n_bits() / 32;
        let state = probe_state(words, 0xabcd);
        for k in [0usize, 1, 7, 63, 227, 301] {
            let mut jumped = state.clone();
            engine.jump(g.as_ref(), &mut jumped, k as u128);
            let mut iterated = state.clone();
            for _ in 0..k {
                g.step_words(&mut iterated);
            }
            assert_eq!(jumped, iterated, "{name} k={k}");
        }
    }
}

/// Acceptance pin: the polynomial path reproduces the dense
/// transition-matrix path bit for bit (small-state generators, where the
/// dense path is tractable; the XORWOW 2^96 pin lives in the registry's
/// unit tests).
#[test]
fn polynomial_jump_matches_dense_matrix() {
    let small: Vec<(&str, Box<dyn LinearStep>)> = vec![
        ("xorwow", Box::new(XorwowLfsr)),
        ("xorgens-test64", Box::new(XorgensLfsr(XorgensParams::TEST_64))),
    ];
    for (name, g) in small {
        let engine = JumpEngine::probe(g.as_ref());
        let m = transition_matrix(g.as_ref());
        let state = probe_state(g.n_bits() / 32, 0x77);
        for k in [1u128, 1000, 123_456_789, 1u128 << 63] {
            let dense = jump_state(&transition_power(&m, k), &state);
            let mut poly = state.clone();
            engine.jump(g.as_ref(), &mut poly, k);
            assert_eq!(poly, dense, "{name} k={k}");
        }
    }
}

/// Jump algebra: `jump(a+b) == jump(b) ∘ jump(a)` (property test over
/// random offsets and states, cheap steppers).
#[test]
fn prop_jump_composes_additively() {
    let small: Vec<(&str, Box<dyn LinearStep>)> = vec![
        ("xorwow", Box::new(XorwowLfsr)),
        ("xorgens-test64", Box::new(XorgensLfsr(XorgensParams::TEST_64))),
    ];
    for (name, g) in small {
        let engine = JumpEngine::probe(g.as_ref());
        let words = g.n_bits() / 32;
        check(name, 15, 11, |c| {
            let a = c.range(0, 5000) as u128;
            let b = c.range(0, 5000) as u128;
            let state = probe_state(words, c.u64());
            let mut once = state.clone();
            engine.jump(g.as_ref(), &mut once, a + b);
            let mut twice = state;
            engine.jump(g.as_ref(), &mut twice, a);
            engine.jump(g.as_ref(), &mut twice, b);
            assert_eq!(once, twice, "a={a} b={b}");
        });
    }
}

/// Acceptance pin: a 2^96-step jump of the 4096-bit xorgens r=128 state
/// is tractable — this test must finish inside the default test timeout
/// (the old dense path would need 96 squarings of a 4096×4096 matrix).
#[test]
fn xorgens4096_jump_2pow96_completes() {
    let mut master = PlacedMaster::new(GeneratorKind::XorgensGp, 1);
    // The GP_4096 recurrence is maximal-period, so the minimal polynomial
    // is the full 4096-degree characteristic polynomial.
    assert_eq!(master.engine().min_poly().degree(), Some(4096));
    let direct = master.state_at_offset(1u128 << 96);
    // The spaced-placement API lands on the same state.
    let spaced = master.state_at(1, 96);
    assert_eq!(direct, spaced);
    assert_eq!(direct.len(), 129); // r words + Weyl
    assert_ne!(&direct[..], master.master_state());
    // 2^96 is a multiple of 2^32: the Weyl counter is unchanged.
    assert_eq!(direct[128], master.master_state()[128]);
}

/// End-to-end wiring: an exact-jump coordinator stream serves exactly the
/// interleaved stream of blocks loaded with the registry's placed master
/// states (slots 0..blocks of the root-seeded master).
#[test]
fn coordinator_exact_jump_serves_placed_master_substreams() {
    let config = CoordinatorConfig { workers: 1, ..Default::default() };
    let root = config.root_seed;
    let coord = Coordinator::new(config);
    let s = coord
        .builder("placed")
        .kind(GeneratorKind::Xorwow)
        .blocks(2)
        .rounds_per_launch(1)
        .placement(Placement::ExactJump { log2_spacing: 40 })
        .u32()
        .unwrap();
    let got = s.draw(200).unwrap();
    coord.shutdown();
    // Manual reconstruction: substream slots 0 and 1 at spacing 2^40.
    let mut master = PlacedMaster::new(GeneratorKind::Xorwow, root);
    let mut states = master.state_at(0, 40);
    states.extend(master.state_at(1, 40));
    let mut g = make_block_generator(GeneratorKind::Xorwow, 0, 2);
    g.load_state(&states);
    let mut expect = vec![0u32; 200];
    InterleavedStream::new(g).fill_u32(&mut expect);
    assert_eq!(got, expect);
}

/// The deprecated boolean shim maps onto the placement enum.
#[test]
#[allow(deprecated)]
fn exact_jump_shim_maps_to_placement() {
    let coord = Coordinator::new(CoordinatorConfig { workers: 1, ..Default::default() });
    let via_shim = coord
        .builder("shim")
        .kind(GeneratorKind::Xorwow)
        .blocks(2)
        .rounds_per_launch(1)
        .exact_jump(true)
        .u32()
        .unwrap();
    // Re-attaching with the equivalent explicit placement is accepted
    // (identical config), proving the shim produced ExactJump{96}.
    let via_enum = coord
        .builder("shim")
        .kind(GeneratorKind::Xorwow)
        .blocks(2)
        .rounds_per_launch(1)
        .placement(Placement::ExactJump { log2_spacing: 96 })
        .u32()
        .unwrap();
    assert_eq!(via_shim.id(), via_enum.id());
    // And exact_jump(false) is plain seed-mix.
    let off = coord.builder("shim-off").exact_jump(false).u32().unwrap();
    let same = coord.builder("shim-off").placement(Placement::SeedMix).u32().unwrap();
    assert_eq!(off.id(), same.id());
    coord.shutdown();
}

//! Loopback integration tests for the sharded coordinator cluster: a
//! router over two in-process shard servers must be **bit-identical** to
//! one local coordinator — for every paper generator kind, under both
//! seed-mix and exact-jump placement — and must survive a shard dying
//! mid-stream by replaying the failed-over stream from its origin.

mod common;

use common::{fnv64, read_fillpath};
use xorgens_gp::cluster::{Router, RouterConfig, ShardServer, ShardServerConfig};
use xorgens_gp::coordinator::{Coordinator, CoordinatorConfig};
use xorgens_gp::prng::{GeneratorKind, Placement};

fn shard(id: u64) -> ShardServer {
    ShardServer::bind(
        "127.0.0.1:0",
        ShardServerConfig {
            shard_id: id,
            coordinator: CoordinatorConfig { workers: 2, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap()
}

fn router_over(shards: &[&ShardServer]) -> Router {
    Router::connect(RouterConfig {
        shards: shards.iter().map(|s| s.addr().to_string()).collect(),
        ..Default::default()
    })
    .unwrap()
}

/// The headline acceptance: for all paper kinds × {seed-mix, exact-jump},
/// streams drawn through a 2-shard routed cluster equal the same streams
/// drawn from a single local coordinator with the same root seed, because
/// the router pins each stream's global identity (derived seed or global
/// slot base) before choosing a shard.
#[test]
fn routed_cluster_bit_identical_to_local_coordinator() {
    let s0 = shard(0);
    let s1 = shard(1);
    let router = router_over(&[&s0, &s1]);
    let local = Coordinator::new(CoordinatorConfig { workers: 2, ..Default::default() });
    let mut homes = std::collections::HashSet::new();
    for kind in GeneratorKind::PAPER_SET {
        for placement in [Placement::SeedMix, Placement::ExactJump { log2_spacing: 40 }] {
            // Register in the SAME order on both sides: global stream ids
            // and slot allocation are registration-ordered.
            let name = format!("{kind}-{placement:?}");
            let routed = router
                .builder(&name)
                .kind(kind)
                .blocks(4)
                .rounds_per_launch(2)
                .placement(placement)
                .u32()
                .unwrap();
            let direct = local
                .builder(&name)
                .kind(kind)
                .blocks(4)
                .rounds_per_launch(2)
                .placement(placement)
                .u32()
                .unwrap();
            homes.insert(router.stream_home(&name).unwrap());
            // Mixed draw sizes crossing launch boundaries.
            for n in [100usize, 1009] {
                assert_eq!(
                    routed.draw(n).unwrap(),
                    direct.draw(n).unwrap(),
                    "{name}: routed != local at draw({n})"
                );
            }
        }
    }
    // Both shards participated (otherwise this proves much less).
    assert_eq!(homes.len(), 2, "stream hashing left a shard idle: {homes:?}");
    // The stats verb round-trips a JSON metrics snapshot from each shard.
    for (addr, stats) in router.shard_stats() {
        let json = stats.unwrap_or_else(|e| panic!("stats from {addr}: {e:#}"));
        assert!(json.contains("\"requests\":"), "{addr}: {json}");
        assert!(json.contains("\"numbers_served\":"), "{addr}: {json}");
    }
    local.shutdown();
    router.shutdown_shards();
}

/// Golden pinning across the wire: a routed stream with the explicit seed
/// override and library-default geometry IS the committed fillpath golden
/// stream — the network path adds or reorders nothing.
#[test]
fn routed_stream_pins_to_committed_golden() {
    let s0 = shard(0);
    let s1 = shard(1);
    let router = router_over(&[&s0, &s1]);
    for seed in [20260710u64, 424242] {
        let s = router
            .builder(&format!("golden-{seed}"))
            .kind(GeneratorKind::XorgensGp)
            .seed(seed)
            .blocks(64)
            .rounds_per_launch(1)
            .u32()
            .unwrap();
        let got = s.draw(4096).unwrap();
        let (head, hash) = read_fillpath("xorgensgp", seed);
        assert_eq!(&got[..32], &head[..], "seed {seed}: head != golden");
        assert_eq!(fnv64(&got), hash, "seed {seed}: fnv64 != golden");
    }
}

/// Kill-one-shard failover: a stream homed on the dead shard re-homes on
/// the survivor and replays its deterministic sequence from the origin
/// (at-least-once delivery of the pinned stream, as documented), the
/// failover counter ticks, and the dead shard's lease is revoked.
#[test]
fn router_survives_shard_death_with_streams_replayed_from_origin() {
    let s0 = shard(0);
    let s1 = shard(1);
    let router = router_over(&[&s0, &s1]);
    // Register streams until one homes on shard 1 (the one we kill).
    let mut victim = None;
    for i in 0..64 {
        let name = format!("victim-{i}");
        let s = router.builder(&name).blocks(4).rounds_per_launch(2).u32().unwrap();
        if router.stream_home(&name) == Some(1) {
            victim = Some(s);
            break;
        }
    }
    let s = victim.expect("64 names all hashed to shard 0");
    let before = s.draw(600).unwrap();
    s1.stop();
    // The next draw hits a dead connection: the router marks the shard
    // dead, re-registers the pinned stream on the survivor, and the
    // stream restarts from its origin — same numbers, bit for bit.
    let after = s.draw(600).unwrap();
    assert_eq!(before, after, "failed-over stream is not the pinned sequence");
    let m = router.metrics();
    assert!(m.failovers >= 1, "no failover recorded: {m:?}");
    assert_eq!(router.active_shards(), vec![0], "dead shard's lease not revoked");
    // The surviving shard keeps serving (the continuation past the replay).
    assert_eq!(s.draw(100).unwrap().len(), 100);
}
